//! Quickstart: the PULSE pipeline in one file.
//!
//! 1. Load the AOT-compiled tiny model (run `make artifacts` first).
//! 2. Take a few GRPO training steps.
//! 3. Watch ~99% of per-step weight updates vanish after the BF16 cast
//!    (the paper's core observation) and PULSESync ship only the rest,
//!    bit-identically.
//!
//! Run: cargo run --release --example quickstart

use pulse::coordinator::{self, TrainConfig};
use pulse::pulse::sync::{Consumer, Publisher};
use pulse::runtime::{artifacts_dir, ModelRuntime};
use pulse::util::fmt_bytes;

fn main() -> anyhow::Result<()> {
    let rt = ModelRuntime::load(&artifacts_dir(), "tiny", &[])?;
    println!("loaded '{}' ({} params) on {}", rt.manifest.name, rt.manifest.n_params, rt.platform());

    // -- train a few GRPO steps with the default (paper Table 8) setup
    let cfg = TrainConfig { steps: 6, n_eval: 32, ..Default::default() };
    let res = coordinator::train(&rt, &cfg)?;
    println!("\nper-step BF16 weight-update sparsity (paper Fig. 2):");
    for s in &res.steps {
        let s1 = s.sparsity.iter().find(|(k, _)| *k == 1).map(|(_, v)| *v).unwrap_or(0.0);
        println!(
            "  step {}  sparsity {:.4}  grad_density {:.3}  reward {:.3}",
            s.step, s1, s.grad_density, s.mean_reward
        );
    }

    // -- PULSESync: publish sparse patches, reconstruct bit-identically
    let mut master = coordinator::init_master(&rt, 0)?;
    let store = pulse::storage::ObjectStore::temp("quickstart")?;
    let mut view = Vec::new();
    pulse::bf16::cast_slice_par(&master, &mut view);
    let mut publisher = Publisher::new(store.clone(), "w", rt.manifest.layout.clone(), view, 50)?;
    let mut consumer = Consumer::new(store, "w", rt.manifest.layout.clone());
    consumer.synchronize()?;
    let mut rng = pulse::util::rng::Rng::new(1);
    println!("\nPULSESync patches (vs {} full checkpoint):", fmt_bytes((rt.manifest.n_params * 2) as u64));
    for step in 1..=5u64 {
        for x in master.iter_mut() {
            // Adam-scale drift at the paper's learning rate
            *x += 3e-6 * if rng.f64() < 0.5 { -1.0 } else { 1.0 };
        }
        let mut view = Vec::new();
        pulse::bf16::cast_slice_par(&master, &mut view);
        let ps = publisher.publish(step, &view)?;
        consumer.synchronize()?;
        assert_eq!(consumer.weights.as_ref().unwrap(), &view, "lossless by construction");
        println!(
            "  step {}  sparsity {:.4}  patch {}  (reduction {:.0}x)",
            step,
            ps.sparsity,
            fmt_bytes(ps.patch_bytes),
            (rt.manifest.n_params * 2) as f64 / ps.patch_bytes as f64
        );
    }
    println!("\nall patches reconstructed bit-identically ✓");
    Ok(())
}
