//! End-to-end training driver (DESIGN.md deliverable (b) / system-prompt
//! requirement): train a multi-million-parameter transformer with GRPO
//! on the synthetic verifiable-math corpus for a few hundred steps,
//! through ALL layers of the stack —
//!
//!   L1 Pallas attention kernel → L2 JAX fwd/bwd graphs (AOT HLO) →
//!   L3 rust coordinator (rollouts, rewards, advantages, AdamW,
//!   BF16-gated PULSESync publishing with bit-identical verification)
//!
//! — and log the loss/reward curve plus the paper's sparsity metrics.
//!
//! Sizes: med ≈ 4.8M (default, minutes on CPU), large ≈ 25.4M,
//! xl ≈ 113M (build with `make artifacts-large` / `make artifacts-xl`).
//!
//! Run: cargo run --release --example train_e2e -- --size large --steps 300

use pulse::coordinator::{self, metrics::CsvWriter};
use pulse::optim::AdamConfig;
use pulse::pulse::sync::{Consumer, Publisher};
use pulse::rl::grpo::{self, GrpoConfig};
use pulse::rl::tasks::MathTask;
use pulse::runtime::{artifacts_dir, ModelRuntime};
use pulse::util::cli::Args;
use pulse::util::{fmt_bytes, Stopwatch};

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let size = args.str_or("size", "med");
    let steps = args.usize_or("steps", 300);
    let eval_every = args.usize_or("eval-every", 25);
    let lr = args.f64_or("lr", 3e-6) as f32;

    let t_load = Stopwatch::start();
    let rt = ModelRuntime::load(&artifacts_dir(), &size, &["rollout", "grad", "score"])?;
    println!(
        "[e2e] loaded '{}' ({:.1}M params) in {:.1}s on {}",
        size,
        rt.manifest.n_params as f64 / 1e6,
        t_load.secs(),
        rt.platform()
    );

    let task = MathTask::default();
    let grpo_cfg = GrpoConfig::default();
    let mut rng = pulse::util::rng::Rng::new(args.u64_or("seed", 0));
    let mut master = coordinator::init_master(&rt, args.u64_or("seed", 0))?;
    let mut opt = pulse::optim::AdamW::new(
        master.len(),
        AdamConfig { lr, ..AdamConfig::default() },
    );
    let mut meter = pulse::coordinator::sparsity::SparsityMeter::new(vec![1, 8]);
    meter.record(&master);

    // PULSESync: every step's BF16 view ships as a verified sparse patch
    let store = pulse::storage::ObjectStore::temp("e2e")?;
    let mut view = Vec::new();
    pulse::bf16::cast_slice_par(&master, &mut view);
    let mut publisher =
        Publisher::new(store.clone(), "ckpt", rt.manifest.layout.clone(), view, 50)?;
    let mut consumer = Consumer::new(store, "ckpt", rt.manifest.layout.clone());
    consumer.synchronize()?;

    let csv_path = pulse::coordinator::metrics::results_dir().join(format!("e2e_{}.csv", size));
    let mut csv = CsvWriter::create(
        &csv_path,
        &["step", "loss", "reward", "correct", "grad_density", "s1", "patch_bytes", "pass1", "secs"],
    )?;

    let full_bytes = (rt.manifest.n_params * 2) as u64;
    let t_train = Stopwatch::start();
    let mut patch_total = 0u64;
    for step in 1..=steps as u64 {
        let t_step = Stopwatch::start();
        // rollout workers serve the *published* checkpoint — expand the
        // consumer's BF16 weights exactly as an inference node would
        let rollout_policy: Vec<f32> = consumer
            .weights
            .as_ref()
            .unwrap()
            .iter()
            .map(|&b| pulse::bf16::bf16_bits_to_f32(b))
            .collect();
        let batch = grpo::generate_batch(&rt, &rollout_policy, &task, grpo_cfg, &mut rng)?;
        let out = rt.grad(
            &master,
            &batch.tokens,
            &batch.advantages,
            &batch.old_logprobs,
            &batch.mask,
        )?;
        opt.step(&mut master, &out.grads);
        let spars = meter.record(&master);
        let s1 = spars.iter().find(|(k, _)| *k == 1).map(|(_, v)| *v).unwrap_or(1.0);

        let mut view = Vec::new();
        pulse::bf16::cast_slice_par(&master, &mut view);
        let ps = publisher.publish(step, &view)?;
        patch_total += ps.patch_bytes;
        let cs = consumer.synchronize()?;
        assert!(cs.verified);
        assert_eq!(consumer.weights.as_ref().unwrap(), &view, "lossless sync");

        let pass1 = if step % eval_every as u64 == 0 || step == steps as u64 {
            let p = grpo::pass_at_1(&rt, &rollout_policy, &task, 64, &mut rng)?;
            Some(p)
        } else {
            None
        };
        if step % 5 == 0 || pass1.is_some() || step == 1 {
            println!(
                "step {:>4}/{}  loss {:+.5}  reward {:.3}  correct {:.3}  S1 {:.4}  patch {:>9}  pass@1 {}  ({:.2}s/step)",
                step,
                steps,
                out.loss,
                batch.mean_reward,
                batch.correct_rate,
                s1,
                fmt_bytes(ps.patch_bytes),
                pass1.map(|p| format!("{:.3}", p)).unwrap_or_else(|| "-".into()),
                t_step.secs(),
            );
        }
        csv.rowf(&[
            step as f64,
            out.loss as f64,
            batch.mean_reward,
            batch.correct_rate,
            out.grad_density as f64,
            s1,
            ps.patch_bytes as f64,
            pass1.unwrap_or(f64::NAN),
            t_step.secs(),
        ])?;
    }
    println!(
        "\n[e2e] {} steps in {:.1}s  |  mean patch {} vs full ckpt {} ({:.0}x reduction)  |  wrote {}",
        steps,
        t_train.secs(),
        fmt_bytes(patch_total / steps as u64),
        fmt_bytes(full_bytes),
        full_bytes as f64 / (patch_total as f64 / steps as f64),
        csv_path.display()
    );
    Ok(())
}
