//! Live weight synchronization over real TCP sockets (paper Fig. 5):
//! a trainer publishes sparse BF16 patches as **sharded v3 frames**
//! through a relay; inference workers subscribe (including a late
//! joiner that catches up from the anchor) and verify bit-identical
//! reconstruction end to end — each shard against its subtree root,
//! each step against the global hash-tree root.
//!
//! Run: cargo run --release --example live_sync

use pulse::bf16;
use pulse::net::relay::Relay;
use pulse::net::tcp::{self, kind, Frame};
use pulse::pulse::sync::ShardedEncoder;
use pulse::sparse::container::{self, EncodeOpts, Values};
use pulse::sparse::hashtree::{HashTree, ShardPatchRef, DEFAULT_CHUNK_ELEMS};
use pulse::sparse::{synthetic_layout, TensorShape};
use pulse::util::rng::Rng;

const SHARDS: usize = 4;

/// Worker loop: anchor → weights + tree, then one sharded step at a
/// time (frames arrive shard 0..S-1 in order on the stream), applied
/// in parallel with per-shard verification.
fn run_worker(
    port: u16,
    layout: Vec<TensorShape>,
    n: usize,
) -> anyhow::Result<(usize, u64)> {
    let mut conn = tcp::connect_local(port)?;
    let first = tcp::read_frame(&mut conn)?;
    assert_eq!(first.kind, kind::ANCHOR);
    let raw = zstd::bulk::decompress(&first.payload, n * 2)?;
    let mut weights = pulse::util::bytes_to_u16(&raw);
    let mut tree = HashTree::build(&weights, DEFAULT_CHUNK_ELEMS);
    let mut steps = 0usize;
    let mut bytes = first.payload.len() as u64;
    loop {
        let f = tcp::read_frame(&mut conn)?;
        match f.kind {
            kind::PATCH => {
                bytes += f.payload.len() as u64;
                let meta = container::peek_meta(&f.payload)?;
                // collect the rest of this step's shard frames; an
                // ANCHOR arriving mid-step means the relay coalesced a
                // catch-up for us — resync from it instead
                let mut frames = vec![f];
                let mut resynced = false;
                while frames.len() < meta.shard_count as usize {
                    let nf = tcp::read_frame(&mut conn)?;
                    bytes += nf.payload.len() as u64;
                    match nf.kind {
                        kind::PATCH => frames.push(nf),
                        kind::ANCHOR => {
                            let raw = zstd::bulk::decompress(&nf.payload, n * 2)?;
                            weights = pulse::util::bytes_to_u16(&raw);
                            tree = HashTree::build(&weights, DEFAULT_CHUNK_ELEMS);
                            resynced = true;
                            break;
                        }
                        kind::CLOSE => return Ok((steps, bytes)),
                        _ => {}
                    }
                }
                if resynced {
                    continue;
                }
                let patches: Vec<_> = frames
                    .iter()
                    .map(|fr| container::decode(&fr.payload, &layout))
                    .collect::<anyhow::Result<_>>()?;
                let refs: Vec<ShardPatchRef> = patches
                    .iter()
                    .map(|p| ShardPatchRef {
                        elem_lo: p.elem_offset as usize,
                        elem_hi: (p.elem_offset + p.elem_len) as usize,
                        indices: &p.indices,
                        values: match &p.values {
                            Values::Bf16(v) => v,
                            _ => panic!("wrong value kind"),
                        },
                        expect_root: &p.shard_root,
                    })
                    .collect();
                let ok = tree.apply_and_rehash_shards(&mut weights, &refs);
                assert!(ok.iter().all(|&v| v), "shard subtree verification failed");
                assert_eq!(
                    tree.root_hex(),
                    patches[0].result_hash,
                    "global root mismatch after step {}",
                    meta.step
                );
                steps += 1;
            }
            kind::ANCHOR => {
                // coalesced catch-up restart
                let raw = zstd::bulk::decompress(&f.payload, n * 2)?;
                weights = pulse::util::bytes_to_u16(&raw);
                tree = HashTree::build(&weights, DEFAULT_CHUNK_ELEMS);
                bytes += f.payload.len() as u64;
            }
            kind::CLOSE => return Ok((steps, bytes)),
            _ => {}
        }
    }
}

fn main() -> anyhow::Result<()> {
    let n = 500_000usize;
    let layout = synthetic_layout(n, 1024);
    let relay = Relay::start()?;
    println!("relay listening on 127.0.0.1:{} ({} shards/step)", relay.port, SHARDS);

    // trainer-side state: FP32 masters + previous BF16 view
    let mut rng = Rng::new(3);
    let mut master: Vec<f32> = (0..n)
        .map(|_| {
            let z = rng.normal();
            let s = if z < 0.0 { 1.48 } else { 0.72 };
            ((-4.47 + s * z).exp() * if rng.f64() < 0.5 { -1.0 } else { 1.0 }) as f32
        })
        .collect();
    let mut prev = Vec::new();
    bf16::cast_slice_par(&master, &mut prev);

    // ANCHOR frame: compressed full BF16 view
    let anchor_payload = zstd::bulk::compress(pulse::util::u16_as_bytes(&prev), 1)?;
    relay.publish(Frame { kind: kind::ANCHOR, payload: anchor_payload });

    // two workers: one subscribes immediately, one joins late and
    // catches up from the relayed anchor + tail — each drained by its
    // own per-subscriber relay queue
    let (port, l1, l2) = (relay.port, layout.clone(), layout.clone());
    let fast = std::thread::spawn(move || run_worker(port, l1, n));
    let late = std::thread::spawn(move || {
        std::thread::sleep(std::time::Duration::from_millis(150));
        run_worker(port, l2, n)
    });
    // wait for both (the late joiner replays the anchor + any tail it
    // missed from the relay's catch-up preload) before streaming ends
    while relay.subscriber_count() < 2 {
        std::thread::sleep(std::time::Duration::from_millis(10));
    }

    // trainer: 10 steps of Adam-scale drift → sharded sparse patches
    let mut enc = ShardedEncoder::new(prev, 0);
    let mut total_patch_bytes = 0u64;
    for step in 1..=10u64 {
        for x in master.iter_mut() {
            *x += 3e-6 * if rng.f64() < 0.5 { -1.0 } else { 1.0 };
        }
        let mut view = Vec::new();
        bf16::cast_slice_par(&master, &mut view);
        let encoded = enc.encode_step(step, &view, &layout, EncodeOpts::default(), SHARDS)?;
        let step_bytes: u64 = encoded.frames.iter().map(|f| f.bytes.len() as u64).sum();
        total_patch_bytes += step_bytes;
        println!(
            "trainer step {:>2}: nnz {:>6} / {}  {} shards  {:>9} total",
            step,
            encoded.nnz,
            n,
            encoded.frames.len(),
            pulse::util::fmt_bytes(step_bytes)
        );
        for f in encoded.frames {
            relay.publish(Frame { kind: kind::PATCH, payload: f.bytes });
        }
    }
    relay.publish(Frame { kind: kind::CLOSE, payload: vec![] });
    let (fast_steps, fast_bytes) = fast.join().unwrap()?;
    let (late_steps, late_bytes) = late.join().unwrap()?;
    println!(
        "\nearly worker applied {} sharded steps over TCP ({}), all hash-verified ✓",
        fast_steps,
        pulse::util::fmt_bytes(fast_bytes)
    );
    println!(
        "late joiner applied {} steps ({}) after anchor catch-up ✓",
        late_steps,
        pulse::util::fmt_bytes(late_bytes)
    );
    println!(
        "full-checkpoint streaming would have been {} ({}x more)",
        pulse::util::fmt_bytes((n as u64 * 2) * 10),
        (n as u64 * 2 * 10) / total_patch_bytes.max(1)
    );
    relay.stop();
    Ok(())
}
