//! Live weight synchronization over real TCP sockets (paper Fig. 5):
//! a trainer publishes sparse BF16 patches as **sharded v3 frames**
//! through a relay; inference workers subscribe (including a late
//! joiner that catches up from the anchor) and verify bit-identical
//! reconstruction end to end.
//!
//! This used to hand-wire the relay protocol; it now runs the library
//! `Publisher`/`Consumer` over `RelayTransport` — the exact same state
//! machines the object-store path uses, on a different fabric. The
//! workers poll `latest_ready()` (one scan per poll, cached into the
//! following `synchronize()`), and a corrupted shard would be healed
//! by a per-subscriber NACK retransmit without rebroadcasting.
//!
//! Run: cargo run --release --example live_sync
//!
//! With `--tree` (or `PULSE_TREE=1`) the workers subscribe through a
//! chained `RelayNode` instead of the root relay — a 2-level
//! distribution tree: the root fans out to one node, the node re-stages
//! the stream and serves both workers' catch-up and NACK repair from
//! its own staging. Same stream, same bit-identity, one more hop.
//!
//! `--index-bound N` (or `PULSE_INDEX_BOUND=N`) sets how many distinct
//! steps each hop's NACK frame index retains (default
//! `relay::INDEX_STEPS` = 8). Shrink it deliberately — e.g.
//! `PULSE_INDEX_BOUND=1` — to force repair NACKs past the local index:
//! in tree mode they escalate upstream, which is exactly the failover
//! path `paper control` measures.
//!
//! `--chaos-seed N` (or `PULSE_CHAOS_SEED=N`) runs the same demo over
//! a faulty wire: every relay/node socket is wrapped in the seeded
//! `net::chaos` fault layer. By default only the non-damaging faults
//! fire (partial writes, added latency — the framing absorbs both and
//! the end-of-run bit-identity asserts still hold); set
//! `PULSE_CHAOS_BUDGET=K` to also admit K resets/corruptions, which
//! this unsupervised demo is NOT built to heal — the control-plane
//! chaos suite (`tests/integration_chaos.rs`) is. See the README
//! "Failure model" section.
//!
//! `--store <addr>` (or `PULSE_STORE_ADDR=<addr>`) runs the stream
//! over the **store plane** instead of the relay: the trainer PUTs
//! frames into an origin store server and both workers pull through a
//! caching hop (`RemoteStoreTransport`), so the origin serves each
//! patch object once no matter how many workers ride the hop. `<addr>`
//! is `host:port` or a bare port (loopback only — the store wire is
//! the local tcp framing), or `local` to self-host an origin over a
//! temp object store. Unlike the relay path, chaos-seeded corruption
//! IS healed here: the store client retries damaged rpcs under its
//! budgeted backoff.

use pulse::bf16;
use pulse::net::chaos::ChaosConfig;
use pulse::net::node::RelayNode;
use pulse::net::relay::Relay;
use pulse::net::store::{caching_hop, DirectStore, RemoteStoreTransport, StoreServer};
use pulse::net::transport::{RelayTransport, SyncTransport};
use pulse::pulse::sync::{Consumer, Publisher, SyncPath};
use pulse::sparse::{synthetic_layout, TensorShape};
use pulse::storage::retention::RetentionPolicy;
use pulse::storage::ObjectStore;
use pulse::util::rng::Rng;
use std::sync::atomic::Ordering;
use std::sync::Arc;

const SHARDS: usize = 4;

/// Worker loop: a `Consumer<RelayTransport>` polling the staged stream
/// until the trainer closes it. Returns (steps applied, bytes fetched,
/// final root).
fn run_worker(
    port: u16,
    layout: Vec<TensorShape>,
) -> anyhow::Result<(usize, u64, String)> {
    let transport = RelayTransport::subscribe(port)?;
    let mut consumer = Consumer::over(transport, layout);
    let mut steps = 0usize;
    loop {
        // read the close flag BEFORE polling: the receiver stages every
        // in-flight frame before it sets closed, so "closed and the
        // subsequent poll saw nothing new" means fully drained
        let closed = consumer.transport.stream_closed();
        let head = consumer.latest_ready()?;
        let behind =
            head.is_some_and(|h| consumer.weights.is_none() || h > consumer.step);
        if behind {
            let cs = consumer.synchronize()?;
            assert!(cs.verified);
            assert_eq!(cs.shard_refetches, 0);
            if cs.path != SyncPath::UpToDate {
                steps += cs.patches_applied + cs.anchors_restored;
            }
        } else if closed {
            break;
        } else {
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
    }
    let bytes = consumer.transport.counters().bytes_fetched;
    let root = consumer.tree_root().unwrap_or_default();
    Ok((steps, bytes, root))
}

/// Store-plane worker: a cold `Consumer<RemoteStoreTransport>` pulling
/// from the store server on `port` until `target` is applied. Returns
/// (steps applied, bytes fetched, final root).
fn run_store_worker(
    port: u16,
    layout: Vec<TensorShape>,
    target: u64,
) -> anyhow::Result<(usize, u64, String)> {
    let mut consumer = Consumer::over(RemoteStoreTransport::connect(port, "live"), layout);
    let mut steps = 0usize;
    loop {
        let head = consumer.latest_ready()?;
        let behind =
            head.is_some_and(|h| consumer.weights.is_none() || h > consumer.step);
        if behind {
            let cs = consumer.synchronize()?;
            assert!(cs.verified);
            if cs.path != SyncPath::UpToDate {
                steps += cs.patches_applied + cs.anchors_restored;
            }
        } else if consumer.weights.is_some() && consumer.step >= target {
            break;
        } else {
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
    }
    let bytes = consumer.transport.counters().bytes_fetched;
    let root = consumer.tree_root().unwrap_or_default();
    Ok((steps, bytes, root))
}

/// The relay demo's stream, re-run over the store plane: publisher →
/// origin store server, workers ← caching hop. See the module docs for
/// the `--store` grammar.
fn run_over_store(addr: &str, chaos: Option<ChaosConfig>) -> anyhow::Result<()> {
    let n = 200_000usize;
    let layout = synthetic_layout(n, 1024);
    // `local` self-hosts the origin; anything else is an already
    // running store server (e.g. another process of this example)
    let (origin, temp) = if addr == "local" {
        let store = ObjectStore::temp("live_store")?;
        let server =
            StoreServer::serve(Arc::new(DirectStore::new(store.clone())), chaos.clone())?;
        (Some(server), Some(store))
    } else {
        (None, None)
    };
    let origin_port = match &origin {
        Some(s) => s.port(),
        None => addr.rsplit(':').next().unwrap_or(addr).parse::<u16>().map_err(|_| {
            anyhow::anyhow!("--store expects host:port, a port, or 'local' (got '{}')", addr)
        })?,
    };
    let (hop, hop_cache) = caching_hop(origin_port, RetentionPolicy::default(), chaos.clone())?;
    println!(
        "store plane: origin 127.0.0.1:{} -> caching hop 127.0.0.1:{}",
        origin_port,
        hop.port()
    );
    if let Some(c) = &chaos {
        println!(
            "chaos wire enabled on every store hop: seed {}, damaging-fault budget {} \
             (client retries heal the damage)",
            c.seed,
            c.budget_remaining().unwrap_or(0)
        );
    }

    // trainer-side state, same drift model as the relay path
    let mut rng = Rng::new(3);
    let mut master: Vec<f32> = (0..n)
        .map(|_| {
            let z = rng.normal();
            let s = if z < 0.0 { 1.48 } else { 0.72 };
            ((-4.47 + s * z).exp() * if rng.f64() < 0.5 { -1.0 } else { 1.0 }) as f32
        })
        .collect();
    let mut prev = Vec::new();
    bf16::cast_slice_par(&master, &mut prev);
    let mut publisher = Publisher::over(
        RemoteStoreTransport::connect(origin_port, "live"),
        layout.clone(),
        prev,
        1_000,
    )?
    .with_shards(SHARDS);

    let steps = 10u64;
    let (p, l1, l2) = (hop.port(), layout.clone(), layout);
    let fast = std::thread::spawn(move || run_store_worker(p, l1, steps));
    let late = std::thread::spawn(move || {
        std::thread::sleep(std::time::Duration::from_millis(150));
        run_store_worker(p, l2, steps)
    });
    let mut total_patch_bytes = 0u64;
    for step in 1..=steps {
        for x in master.iter_mut() {
            *x += 3e-6 * if rng.f64() < 0.5 { -1.0 } else { 1.0 };
        }
        let mut view = Vec::new();
        bf16::cast_slice_par(&master, &mut view);
        let ps = publisher.publish(step, &view)?;
        total_patch_bytes += ps.patch_bytes;
        println!(
            "trainer step {:>2}: nnz {:>6} / {}  {} shards  {:>9} total",
            step,
            ps.nnz,
            n,
            ps.shard_count,
            pulse::util::fmt_bytes(ps.patch_bytes)
        );
    }
    let (fast_steps, fast_bytes, fast_root) = fast.join().unwrap()?;
    let (late_steps, late_bytes, late_root) = late.join().unwrap()?;
    assert_eq!(fast_root, publisher.tree().root_hex(), "early worker root mismatch");
    assert_eq!(late_root, publisher.tree().root_hex(), "late joiner root mismatch");
    println!(
        "\nearly worker applied {} steps over the store wire ({}), all hash-verified ✓",
        fast_steps,
        pulse::util::fmt_bytes(fast_bytes)
    );
    println!(
        "late joiner applied {} steps ({}) after anchor catch-up ✓",
        late_steps,
        pulse::util::fmt_bytes(late_bytes)
    );
    println!(
        "caching hop: {} hits / {} misses, {} origin fetches, {} revalidations NOT_MODIFIED \
         — the origin served each patch object once for {} total patch bytes",
        hop_cache.counters.hits.load(Ordering::Relaxed),
        hop_cache.counters.misses.load(Ordering::Relaxed),
        hop_cache.counters.origin_fetches.load(Ordering::Relaxed),
        hop_cache.counters.not_modified.load(Ordering::Relaxed),
        pulse::util::fmt_bytes(total_patch_bytes)
    );
    hop.stop();
    if let Some(o) = &origin {
        o.stop();
    }
    if let Some(store) = temp {
        let _ = std::fs::remove_dir_all(store.root());
    }
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().collect();
    let tree = argv.iter().any(|a| a == "--tree")
        || std::env::var("PULSE_TREE").map_or(false, |v| v == "1");
    // relay frame-index bound: `--index-bound N` wins over
    // PULSE_INDEX_BOUND; default keeps the library's INDEX_STEPS (8).
    // Failover experiments shrink it to force NACK escalation.
    let index_bound = argv
        .iter()
        .position(|a| a == "--index-bound")
        .and_then(|i| argv.get(i + 1))
        .and_then(|v| v.parse::<usize>().ok())
        .or_else(|| {
            std::env::var("PULSE_INDEX_BOUND").ok().and_then(|v| v.parse().ok())
        })
        .unwrap_or(pulse::net::relay::INDEX_STEPS)
        .max(1);
    // seeded wire-fault layer: `--chaos-seed N` wins over
    // PULSE_CHAOS_SEED; absent → clean wire. The damaging-fault budget
    // defaults to 0 here (partial writes + latency only): this demo
    // hand-wires its subscribers, so it has no supervisor to heal an
    // injected reset — the supervised chaos suite (integration_chaos)
    // owns those. Raise PULSE_CHAOS_BUDGET to let resets/corruption
    // through anyway.
    let chaos = argv
        .iter()
        .position(|a| a == "--chaos-seed")
        .and_then(|i| argv.get(i + 1))
        .and_then(|v| v.parse::<u64>().ok())
        .or_else(|| std::env::var("PULSE_CHAOS_SEED").ok().and_then(|v| v.parse().ok()))
        .map(|seed| {
            let budget = std::env::var("PULSE_CHAOS_BUDGET")
                .ok()
                .and_then(|v| v.parse().ok())
                .unwrap_or(0);
            ChaosConfig::light(seed).with_budget(budget)
        });
    // store plane: `--store <addr>` wins over PULSE_STORE_ADDR; when
    // present the whole demo runs over the patch CDN instead of the
    // relay fabric (see run_over_store)
    let store_addr = argv
        .iter()
        .position(|a| a == "--store")
        .and_then(|i| argv.get(i + 1).cloned())
        .or_else(|| std::env::var("PULSE_STORE_ADDR").ok());
    if let Some(addr) = store_addr {
        return run_over_store(&addr, chaos);
    }
    let n = 500_000usize;
    let layout = synthetic_layout(n, 1024);
    let relay = Arc::new(Relay::start_with_chaos(
        pulse::net::relay::DEFAULT_QUEUE_DEPTH,
        index_bound,
        chaos.clone(),
    )?);
    // opt-in 2-level tree: workers subscribe to a chained node that
    // re-stages the root's stream
    let node = if tree {
        Some(RelayNode::join_with_chaos(
            relay.port,
            pulse::net::relay::DEFAULT_QUEUE_DEPTH,
            index_bound,
            chaos.clone(),
        )?)
    } else {
        None
    };
    if let Some(c) = &chaos {
        println!(
            "chaos wire enabled: seed {}, damaging-fault budget {} \
             (bit-identity asserts still apply)",
            c.seed,
            c.budget_remaining().unwrap_or(0)
        );
    }
    let sub_port = node.as_ref().map_or(relay.port, |n| n.port());
    match &node {
        Some(nd) => println!(
            "relay tree: root 127.0.0.1:{} -> node 127.0.0.1:{} ({} shards/step, \
             NACK index bound {} steps/hop)",
            relay.port,
            nd.port(),
            SHARDS,
            index_bound
        ),
        None => println!(
            "relay listening on 127.0.0.1:{} ({} shards/step, NACK index bound {} steps)",
            relay.port, SHARDS, index_bound
        ),
    }

    // trainer-side state: FP32 masters + previous BF16 view
    let mut rng = Rng::new(3);
    let mut master: Vec<f32> = (0..n)
        .map(|_| {
            let z = rng.normal();
            let s = if z < 0.0 { 1.48 } else { 0.72 };
            ((-4.47 + s * z).exp() * if rng.f64() < 0.5 { -1.0 } else { 1.0 }) as f32
        })
        .collect();
    let mut prev = Vec::new();
    bf16::cast_slice_par(&master, &mut prev);

    // publisher over the relay fabric: anchor 0 goes out immediately
    let mut publisher =
        Publisher::over(RelayTransport::publisher(relay.clone()), layout.clone(), prev, 1_000)?
            .with_shards(SHARDS)
            .with_shard_balancing(true);

    // two workers: one subscribes immediately, one joins late and
    // catches up from the relayed anchor + tail — each drained by its
    // own per-subscriber queue (on the node in tree mode, so the late
    // join never touches the root)
    let (port, l1, l2) = (sub_port, layout.clone(), layout.clone());
    let fast = std::thread::spawn(move || run_worker(port, l1));
    let late = std::thread::spawn(move || {
        std::thread::sleep(std::time::Duration::from_millis(150));
        run_worker(port, l2)
    });
    // wait for both (the late joiner replays the anchor + any tail it
    // missed from the relay's catch-up preload) before streaming ends —
    // CLOSE is a control broadcast, not part of the replayable tail
    let worker_relay = node.as_ref().map_or(&relay, |n| n.relay());
    while worker_relay.subscriber_count() < 2 {
        std::thread::sleep(std::time::Duration::from_millis(10));
    }

    // trainer: 10 steps of Adam-scale drift → sharded sparse patches
    let mut total_patch_bytes = 0u64;
    for step in 1..=10u64 {
        for x in master.iter_mut() {
            *x += 3e-6 * if rng.f64() < 0.5 { -1.0 } else { 1.0 };
        }
        let mut view = Vec::new();
        bf16::cast_slice_par(&master, &mut view);
        let ps = publisher.publish(step, &view)?;
        total_patch_bytes += ps.patch_bytes;
        println!(
            "trainer step {:>2}: nnz {:>6} / {}  {} shards  {:>9} total",
            step,
            ps.nnz,
            n,
            ps.shard_count,
            pulse::util::fmt_bytes(ps.patch_bytes)
        );
    }
    // CLOSE travels FIFO behind the data frames on every subscriber
    // queue, so workers drain everything before they observe it
    publisher.transport.close();
    let (fast_steps, fast_bytes, fast_root) = fast.join().unwrap()?;
    let (late_steps, late_bytes, late_root) = late.join().unwrap()?;
    assert_eq!(fast_root, publisher.tree().root_hex(), "early worker root mismatch");
    assert_eq!(late_root, publisher.tree().root_hex(), "late joiner root mismatch");
    println!(
        "\nearly worker applied {} steps over TCP ({}), all hash-verified ✓",
        fast_steps,
        pulse::util::fmt_bytes(fast_bytes)
    );
    println!(
        "late joiner applied {} steps ({}) after anchor catch-up ✓",
        late_steps,
        pulse::util::fmt_bytes(late_bytes)
    );
    println!(
        "full-checkpoint streaming would have been {} ({}x more)",
        pulse::util::fmt_bytes((n as u64 * 2) * 10),
        (n as u64 * 2 * 10) / total_patch_bytes.max(1)
    );
    if let Some(nd) = &node {
        println!("tree hop depth at the node: {} (root = 0)", nd.hop());
        nd.stop();
    }
    relay.stop();
    Ok(())
}
