//! Live weight synchronization over real TCP sockets (paper Fig. 5):
//! a trainer publishes sparse BF16 patches through a relay; inference
//! workers subscribe (including a late joiner that catches up from the
//! anchor) and verify bit-identical reconstruction end to end.
//!
//! Run: cargo run --release --example live_sync

use pulse::bf16;
use pulse::net::relay::Relay;
use pulse::net::tcp::{self, kind, Frame};
use pulse::sparse::container::{self, EncodeOpts, Patch, Values};
use pulse::sparse::hashtree::{HashTree, DEFAULT_CHUNK_ELEMS};
use pulse::sparse::{self, synthetic_layout};
use pulse::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let n = 500_000usize;
    let layout = synthetic_layout(n, 1024);
    let relay = Relay::start()?;
    println!("relay listening on 127.0.0.1:{}", relay.port);

    // trainer-side state: FP32 masters + previous BF16 view
    let mut rng = Rng::new(3);
    let mut master: Vec<f32> = (0..n)
        .map(|_| {
            let z = rng.normal();
            let s = if z < 0.0 { 1.48 } else { 0.72 };
            ((-4.47 + s * z).exp() * if rng.f64() < 0.5 { -1.0 } else { 1.0 }) as f32
        })
        .collect();
    let mut prev = Vec::new();
    bf16::cast_slice_par(&master, &mut prev);

    // ANCHOR frame: compressed full BF16 view
    let anchor_payload = zstd::bulk::compress(pulse::util::u16_as_bytes(&prev), 1)?;
    relay.publish(Frame { kind: kind::ANCHOR, payload: anchor_payload.clone() });

    // early worker subscribes, decodes the anchor
    let port = relay.port;
    let layout_w = layout.clone();
    let worker = std::thread::spawn(move || -> anyhow::Result<(usize, u64)> {
        let mut conn = tcp::connect_local(port)?;
        let first = tcp::read_frame(&mut conn)?;
        assert_eq!(first.kind, kind::ANCHOR);
        let raw = zstd::bulk::decompress(&first.payload, 500_000 * 2)?;
        let mut weights = pulse::util::bytes_to_u16(&raw);
        // one tree build at join time; every patch after that verifies
        // via fused apply+rehash over only the touched chunks (O(nnz))
        let mut tree = HashTree::build(&weights, DEFAULT_CHUNK_ELEMS);
        let mut patches = 0usize;
        let mut bytes = first.payload.len() as u64;
        loop {
            let f = tcp::read_frame(&mut conn)?;
            match f.kind {
                kind::PATCH => {
                    bytes += f.payload.len() as u64;
                    let patch = container::decode(&f.payload, &layout_w)?;
                    let vals = match &patch.values {
                        Values::Bf16(v) => v.clone(),
                        _ => anyhow::bail!("wrong value kind"),
                    };
                    assert_eq!(patch.chunk_elems as usize, tree.chunk_elems());
                    tree.apply_and_rehash(&mut weights, &patch.indices, &vals);
                    assert_eq!(tree.root_hex(), patch.result_hash, "root mismatch after patch");
                    patches += 1;
                }
                kind::CLOSE => return Ok((patches, bytes)),
                _ => {}
            }
        }
    });
    // give the worker time to register before streaming
    while relay.subscriber_count() < 1 {
        std::thread::sleep(std::time::Duration::from_millis(10));
    }

    // trainer: 10 steps of Adam-scale drift → sparse patches, with the
    // hash-tree root updated incrementally (only touched chunks rehash)
    let mut tree = HashTree::build(&prev, DEFAULT_CHUNK_ELEMS);
    let mut total_patch_bytes = 0u64;
    for step in 1..=10u64 {
        for x in master.iter_mut() {
            *x += 3e-6 * if rng.f64() < 0.5 { -1.0 } else { 1.0 };
        }
        let mut view = Vec::new();
        bf16::cast_slice_par(&master, &mut view);
        let (indices, values) = sparse::diff_gather_bf16(&prev, &view);
        tree.update(&view, &indices);
        let patch = Patch {
            step,
            base_step: step - 1,
            total_params: n as u64,
            indices,
            values: Values::Bf16(values),
            result_hash: tree.root_hex(),
            chunk_elems: tree.chunk_elems() as u64,
        };
        let obj = container::encode(&patch, &layout, EncodeOpts::default())?;
        total_patch_bytes += obj.len() as u64;
        println!(
            "trainer step {:>2}: nnz {:>6} / {}  patch {:>9}",
            step,
            patch.indices.len(),
            n,
            pulse::util::fmt_bytes(obj.len() as u64)
        );
        relay.publish(Frame { kind: kind::PATCH, payload: obj });
        prev = view;
    }
    relay.publish(Frame { kind: kind::CLOSE, payload: vec![] });
    let (patches, bytes) = worker.join().unwrap()?;
    println!(
        "\nworker applied {} patches over TCP ({} total), all hash-verified ✓",
        patches,
        pulse::util::fmt_bytes(bytes)
    );
    println!(
        "full-checkpoint streaming would have been {} ({}x more)",
        pulse::util::fmt_bytes((n as u64 * 2) * 10),
        (n as u64 * 2 * 10) / total_patch_bytes.max(1)
    );
    relay.stop();
    Ok(())
}
