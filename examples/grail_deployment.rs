//! grail deployment (paper §E / Fig. 6): trainer + miners + validator
//! coordinating through an object store; PULSESync keeps the rollout
//! fleet current with ~100x less bandwidth than full checkpoints, and
//! grail-Proof sketches keep miners honest.
//!
//! Run: cargo run --release --example grail_deployment -- --windows 6

use pulse::coordinator;
use pulse::grail::{GrailConfig, GrailSim};
use pulse::optim::AdamConfig;
use pulse::rl::tasks::MathTask;
use pulse::runtime::{artifacts_dir, ModelRuntime};
use pulse::util::cli::Args;
use pulse::util::fmt_bytes;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let size = args.str_or("size", "tiny");
    let windows = args.usize_or("windows", 6);
    let rt = ModelRuntime::load(&artifacts_dir(), &size, &[])?;
    let task = MathTask::default();
    let master = coordinator::init_master(&rt, 0)?;
    let mut sim = GrailSim::new(
        &rt,
        &task,
        GrailConfig {
            n_miners: args.usize_or("miners", 3),
            steps_per_window: args.usize_or("steps-per-window", 6),
            ..Default::default()
        },
        master,
        AdamConfig::post_training(),
        42,
    )?;
    println!("grail deployment on '{}': {} windows, 3 miners, 1 validator", size, windows);
    println!("(every upload below is a sparse BF16 patch; full ckpt = {})\n",
        fmt_bytes((rt.manifest.n_params * 2) as u64));
    let mut csv = pulse::coordinator::metrics::CsvWriter::create(
        &pulse::coordinator::metrics::results_dir().join("grail_deployment.csv"),
        &["window", "pass1", "upload_bytes", "full_bytes", "verified", "rejected", "replay_age"],
    )?;
    for w in 0..windows as u64 {
        let st = sim.run_window(w)?;
        println!(
            "window {:>2}  pass@1 {:.3}  mean_reward {:.3}  upload {:>9}  verified {}/{}  replay_age {:.2}",
            st.window, st.pass_at_1, st.mean_reward,
            fmt_bytes(st.upload_bytes), st.verified, st.verified + st.rejected, st.replay_mean_age,
        );
        csv.rowf(&[
            st.window as f64,
            st.pass_at_1,
            st.upload_bytes as f64,
            st.full_checkpoint_bytes as f64,
            st.verified as f64,
            st.rejected as f64,
            st.replay_mean_age,
        ])?;
    }
    println!("\nwrote {}", csv.path.display());
    Ok(())
}
