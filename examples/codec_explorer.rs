//! Bandwidth-aware codec selection (paper §C / Fig. 11): measures every
//! codec's ratio and throughput on a realistic sparse patch, then
//! reports which codec minimizes end-to-end transfer time at your link
//! rate — the paper's datacenter / cloud / constrained regimes.
//!
//! Run: cargo run --release --example codec_explorer -- --mbps 100

use pulse::codec::Codec;
use pulse::net::{total_transfer_time, SimLink};
use pulse::util::cli::Args;
use pulse::util::rng::Rng;
use pulse::util::Stopwatch;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let mbps = args.f64_or("mbps", 100.0);
    // build a realistic sparse patch payload (~99% sparse, 4M params)
    let n = 4_000_000usize;
    let layout = pulse::sparse::synthetic_layout(n, 1024);
    let mut rng = Rng::new(9);
    let mut idx: Vec<u64> = (0..n / 100).map(|_| rng.below(n as u64)).collect();
    idx.sort_unstable();
    idx.dedup();
    let vals: Vec<u16> = idx
        .iter()
        .map(|_| pulse::bf16::f32_to_bf16_bits((rng.normal() * 0.02) as f32))
        .collect();
    let mut raw = pulse::sparse::PatchFormat::CooDownscaled.encode_indices(&idx, &layout);
    raw.extend_from_slice(pulse::util::u16_as_bytes(&vals));
    println!("payload: {} changed values, {} pre-codec bytes\n", idx.len(), raw.len());

    println!(
        "{:<8} {:>9} {:>12} {:>12} {:>14}",
        "codec", "ratio", "enc MB/s", "dec MB/s", "total @ link"
    );
    let link = SimLink::mbit(mbps);
    let mut best: Option<(Codec, f64)> = None;
    for codec in Codec::ALL {
        let t = Stopwatch::start();
        let mut comp = Vec::new();
        let reps = 5;
        for _ in 0..reps {
            comp = codec.compress(&raw)?;
        }
        let enc_mbps = (raw.len() * reps) as f64 / 1e6 / t.secs();
        let t = Stopwatch::start();
        for _ in 0..reps {
            let d = codec.decompress(&comp, raw.len())?;
            assert_eq!(d.len(), raw.len());
        }
        let dec_mbps = (raw.len() * reps) as f64 / 1e6 / t.secs();
        let ratio = raw.len() as f64 / comp.len() as f64;
        let total = total_transfer_time(raw.len() as u64, ratio, enc_mbps, dec_mbps, link);
        println!(
            "{:<8} {:>8.2}x {:>12.0} {:>12.0} {:>12.3} s",
            codec.name(),
            ratio,
            enc_mbps,
            dec_mbps,
            total
        );
        if best.map(|(_, t0)| total < t0).unwrap_or(true) {
            best = Some((codec, total));
        }
    }
    let (winner, t) = best.unwrap();
    println!(
        "\nat {} Mbit/s the end-to-end winner is {} ({:.3} s per sync)",
        mbps,
        winner.name(),
        t
    );
    println!("paper regimes: >800 Mbit/s → lz4/snappy; 14–800 → zstd-1; <14 → zstd-3");
    Ok(())
}
