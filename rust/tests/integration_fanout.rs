//! Integration tests for the sharded pipelined patch fan-out:
//!
//! * relay isolation — a throttled subscriber must not delay a fast
//!   subscriber's patch delivery (per-subscriber queues + coalescing
//!   catch-up, `net::relay`);
//! * shard recovery — flipping bytes in one shard frame triggers a
//!   single-shard NACK/resend while the other shards stay applied
//!   (`sparse::container` v3 + `sparse::hashtree` subtree roots);
//! * end-to-end bit-identity of the sharded stream over a real relay.

use pulse::net::relay::Relay;
use pulse::net::tcp::{self, kind, Frame};
use pulse::pulse::sync::ShardedEncoder;
use pulse::sparse::container::{self, EncodeOpts, Patch, Values};
use pulse::sparse::hashtree::{HashTree, ShardPatchRef, DEFAULT_CHUNK_ELEMS};
use pulse::sparse::{synthetic_layout, TensorShape};
use pulse::util::rng::Rng;

fn perturb(rng: &mut Rng, w: &mut [u16], count: usize) {
    for _ in 0..count {
        let i = rng.below(w.len() as u64) as usize;
        w[i] = rng.next_u32() as u16;
    }
}

/// Apply one step's decoded shard patches; returns the indices of
/// shards that failed subtree verification (their state is restored).
fn apply_step(
    weights: &mut Vec<u16>,
    tree: &mut HashTree,
    patches: &[Patch],
) -> Vec<usize> {
    let refs: Vec<ShardPatchRef> = patches
        .iter()
        .map(|p| ShardPatchRef {
            elem_lo: p.elem_offset as usize,
            elem_hi: (p.elem_offset + p.elem_len) as usize,
            indices: &p.indices,
            values: match &p.values {
                Values::Bf16(v) => v,
                _ => panic!("wrong value kind"),
            },
            expect_root: &p.shard_root,
        })
        .collect();
    tree.apply_and_rehash_shards(weights, &refs)
        .into_iter()
        .enumerate()
        .filter(|(_, ok)| !ok)
        .map(|(i, _)| i)
        .collect()
}

/// A throttled (non-reading) subscriber must not delay a fast
/// subscriber. Under the old single-mutex relay, `publish` blocked on
/// the stalled socket once kernel buffers filled, so the fast
/// subscriber starved; with per-subscriber queues the fast reader
/// drains everything while the slow one is still stalled.
#[test]
fn slow_subscriber_does_not_delay_fast_subscriber() {
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;
    const STEPS: u8 = 40;
    const MB: usize = 1 << 20;
    let relay = Relay::start_with_depth(4).unwrap();

    // fast subscriber: reads eagerly on its own thread
    let mut fast_conn = tcp::connect_local(relay.port).unwrap();
    // slow subscriber: connected but NOT read until the fast one is done
    let mut slow_conn = tcp::connect_local(relay.port).unwrap();
    while relay.subscriber_count() < 2 {
        std::thread::sleep(std::time::Duration::from_millis(5));
    }

    let fast_read = Arc::new(AtomicUsize::new(0));
    let fast_read_w = fast_read.clone();
    let fast = std::thread::spawn(move || -> anyhow::Result<(Vec<u8>, f64)> {
        let t = std::time::Instant::now();
        let mut tags = Vec::new();
        loop {
            let f = tcp::read_frame(&mut fast_conn)?;
            match f.kind {
                kind::ANCHOR | kind::PATCH => {
                    tags.push(f.payload[0]);
                    fast_read_w.fetch_add(1, Ordering::SeqCst);
                }
                kind::CLOSE => return Ok((tags, t.elapsed().as_secs_f64())),
                _ => {}
            }
        }
    });

    // Publish ~42 MB, pacing against the FAST reader only (its queue
    // stays within depth, so its stream is the exact published
    // sequence). The slow subscriber reads nothing: its socket buffers
    // fill, its writer stalls, its queue overflows and coalesces —
    // none of which may hold up the publisher or the fast reader.
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(60);
    let mut published = 0usize;
    let mut pace = |relay: &Relay, frame: Frame| {
        relay.publish(frame);
        published += 1;
        while fast_read.load(Ordering::SeqCst) + 2 < published {
            assert!(
                std::time::Instant::now() < deadline,
                "fast subscriber stalled — isolation failed"
            );
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
    };
    pace(&relay, Frame { kind: kind::ANCHOR, payload: vec![0u8; MB] });
    for step in 1..=STEPS {
        pace(&relay, Frame { kind: kind::PATCH, payload: vec![step; MB] });
    }
    // second anchor supersedes whatever the slow subscriber missed
    pace(&relay, Frame { kind: kind::ANCHOR, payload: vec![100u8; MB] });
    relay.publish(Frame { kind: kind::CLOSE, payload: vec![] });

    // the fast subscriber finishes while the slow one has read nothing
    let (fast_tags, fast_secs) = fast.join().unwrap().unwrap();
    assert_eq!(fast_tags.len(), STEPS as usize + 2, "fast subscriber missed frames");
    assert_eq!(fast_tags[0], 0);
    for (i, &tag) in fast_tags[1..=STEPS as usize].iter().enumerate() {
        assert_eq!(tag as usize, i + 1, "fast subscriber saw out-of-order patches");
    }
    assert_eq!(fast_tags[STEPS as usize + 1], 100);
    assert!(
        fast_secs < 60.0,
        "fast subscriber took {:.1}s — it was waiting on the slow one",
        fast_secs
    );
    assert!(
        relay.coalesced_catchups() > 0 || relay.dropped_frames() > 0,
        "the stalled subscriber never triggered coalescing"
    );

    // now drain the slow subscriber: it sees a valid restart — whatever
    // was in flight, then the superseding anchor, then CLOSE
    let mut slow_tags = Vec::new();
    loop {
        let f = tcp::read_frame(&mut slow_conn).unwrap();
        match f.kind {
            kind::ANCHOR | kind::PATCH => slow_tags.push((f.kind, f.payload[0])),
            kind::CLOSE => break,
            _ => {}
        }
    }
    assert_eq!(
        slow_tags.last().copied(),
        Some((kind::ANCHOR, 100)),
        "slow subscriber must end on the superseding anchor"
    );
    assert!(
        slow_tags.len() < STEPS as usize + 2,
        "slow subscriber received everything — nothing was coalesced"
    );
    relay.stop();
}

/// Build a decodable-but-corrupt copy of one shard frame: same header
/// and commitments, one flipped value — exactly what bit rot in
/// transit looks like after framing survives. The shard's subtree root
/// no longer matches, so the worker NACKs just that shard.
fn tamper_frame(good: &[u8], layout: &[TensorShape]) -> Vec<u8> {
    let mut p = container::decode(good, layout).unwrap();
    match &mut p.values {
        Values::Bf16(v) => {
            assert!(!v.is_empty(), "test shard must carry at least one value");
            v[0] ^= 0x0101;
        }
        _ => panic!("wrong value kind"),
    }
    container::encode(&p, layout, EncodeOpts::default()).unwrap()
}

#[test]
fn corrupted_shard_frame_triggers_single_shard_refetch() {
    let n = 100_000usize;
    let layout = synthetic_layout(n, 1024);
    let mut rng = Rng::new(41);
    let old: Vec<u16> = (0..n).map(|_| rng.next_u32() as u16).collect();
    let mut new = old.clone();
    perturb(&mut rng, &mut new, 2_000);

    let mut enc = ShardedEncoder::new(old.clone(), 0);
    let encoded = enc.encode_step(1, &new, &layout, EncodeOpts::default(), 4).unwrap();
    assert_eq!(encoded.frames.len(), 4);
    let frames: Vec<Vec<u8>> = encoded.frames.iter().map(|f| f.bytes.clone()).collect();
    let expect_root = encoded.root.clone();

    let (listener, port) = tcp::listen_local().unwrap();
    let layout_pub = layout.clone();
    let frames_pub = frames.clone();
    let publisher = std::thread::spawn(move || -> anyhow::Result<u32> {
        let (mut s, _) = listener.accept()?;
        for (i, f) in frames_pub.iter().enumerate() {
            let payload =
                if i == 2 { tamper_frame(f, &layout_pub) } else { f.clone() };
            tcp::write_frame(&mut s, &Frame { kind: kind::PATCH, payload })?;
        }
        // worker NACKs the corrupted shard; resend the good frame
        let nack = tcp::read_frame(&mut s)?;
        assert_eq!(nack.kind, kind::NACK);
        let (step, shard) = tcp::parse_shard_ack(&nack.payload)?;
        assert_eq!(step, 1);
        tcp::write_frame(
            &mut s,
            &Frame { kind: kind::PATCH, payload: frames_pub[shard as usize].clone() },
        )?;
        let ack = tcp::read_frame(&mut s)?;
        assert_eq!(ack.kind, kind::ACK);
        Ok(shard)
    });

    // worker: receive the step, apply, NACK the failing shard only
    let mut conn = tcp::connect_local(port).unwrap();
    let mut weights = old.clone();
    let mut tree = HashTree::build(&weights, DEFAULT_CHUNK_ELEMS);
    let mut patches = Vec::new();
    for _ in 0..4 {
        let f = tcp::read_frame(&mut conn).unwrap();
        patches.push(container::decode(&f.payload, &layout).unwrap());
    }
    let failed = apply_step(&mut weights, &mut tree, &patches);
    assert_eq!(failed, vec![2], "exactly the tampered shard must fail");
    // the other three shards are already applied; the failed shard's
    // range is bit-identical to its pre-step state
    let lo = patches[2].elem_offset as usize;
    let hi = lo + patches[2].elem_len as usize;
    assert_eq!(&weights[lo..hi], &old[lo..hi]);
    assert_ne!(&weights[..lo], &old[..lo], "untampered shards must be applied");

    for shard in failed {
        tcp::write_frame(
            &mut conn,
            &Frame {
                kind: kind::NACK,
                payload: tcp::shard_ack_payload(1, shard as u32),
            },
        )
        .unwrap();
        let replacement = tcp::read_frame(&mut conn).unwrap();
        assert_eq!(replacement.kind, kind::PATCH);
        let p = container::decode(&replacement.payload, &layout).unwrap();
        let still_failed = apply_step(&mut weights, &mut tree, &[p]);
        assert!(still_failed.is_empty(), "resent shard must verify");
    }
    tcp::write_frame(
        &mut conn,
        &Frame { kind: kind::ACK, payload: tcp::shard_ack_payload(1, 2) },
    )
    .unwrap();

    assert_eq!(publisher.join().unwrap().unwrap(), 2);
    assert_eq!(weights, new, "assembled step must be bit-identical");
    assert_eq!(tree.root_hex(), expect_root, "global root must bind the step");
}

/// Full path: sharded frames through a real relay to two workers (one
/// a late joiner), ending bit-identical to the trainer's view.
#[test]
fn sharded_relay_stream_is_bit_identical() {
    let n = 60_000usize;
    let layout = synthetic_layout(n, 512);
    let mut rng = Rng::new(55);
    let init: Vec<u16> = (0..n).map(|_| rng.next_u32() as u16).collect();

    let relay = Relay::start().unwrap();
    let port = relay.port;

    fn worker(port: u16, layout: Vec<TensorShape>, n: usize) -> anyhow::Result<(Vec<u16>, String)> {
        let mut conn = tcp::connect_local(port)?;
        let first = tcp::read_frame(&mut conn)?;
        assert_eq!(first.kind, kind::ANCHOR);
        let mut weights = pulse::util::bytes_to_u16(&first.payload);
        assert_eq!(weights.len(), n);
        let mut tree = HashTree::build(&weights, DEFAULT_CHUNK_ELEMS);
        loop {
            let f = tcp::read_frame(&mut conn)?;
            match f.kind {
                kind::PATCH => {
                    let meta = container::peek_meta(&f.payload)?;
                    let mut patches =
                        vec![container::decode(&f.payload, &layout)?];
                    let mut resynced = false;
                    while patches.len() < meta.shard_count as usize {
                        let nf = tcp::read_frame(&mut conn)?;
                        match nf.kind {
                            kind::PATCH => {
                                patches.push(container::decode(&nf.payload, &layout)?)
                            }
                            kind::ANCHOR => {
                                // relay coalescing restarted the stream
                                // mid-step: resync from the anchor
                                weights = pulse::util::bytes_to_u16(&nf.payload);
                                tree = HashTree::build(&weights, DEFAULT_CHUNK_ELEMS);
                                resynced = true;
                                break;
                            }
                            kind::CLOSE => return Ok((weights, tree.root_hex())),
                            _ => {}
                        }
                    }
                    if resynced {
                        continue;
                    }
                    let failed = apply_step(&mut weights, &mut tree, &patches);
                    assert!(failed.is_empty());
                    assert_eq!(tree.root_hex(), patches[0].result_hash);
                }
                kind::ANCHOR => {
                    weights = pulse::util::bytes_to_u16(&f.payload);
                    tree = HashTree::build(&weights, DEFAULT_CHUNK_ELEMS);
                }
                kind::CLOSE => return Ok((weights, tree.root_hex())),
                _ => {}
            }
        }
    }

    let (l1, l2) = (layout.clone(), layout.clone());
    let early = std::thread::spawn(move || worker(port, l1, n));

    relay.publish(Frame {
        kind: kind::ANCHOR,
        payload: pulse::util::u16_as_bytes(&init).to_vec(),
    });
    while relay.subscriber_count() < 1 {
        std::thread::sleep(std::time::Duration::from_millis(5));
    }

    let mut enc = ShardedEncoder::new(init.clone(), 0);
    let mut view = init;
    let mut l2_opt = Some(l2);
    let mut late: Option<std::thread::JoinHandle<anyhow::Result<(Vec<u16>, String)>>> = None;
    for step in 1..=3u64 {
        perturb(&mut rng, &mut view, 500);
        let encoded = enc.encode_step(step, &view, &layout, EncodeOpts::default(), 3).unwrap();
        assert_eq!(encoded.frames.len(), 3);
        for f in encoded.frames {
            relay.publish(Frame { kind: kind::PATCH, payload: f.bytes });
        }
        if step == 1 {
            // late joiner catches up from the relayed anchor + tail
            let l2 = l2_opt.take().unwrap();
            late = Some(std::thread::spawn(move || worker(port, l2, n)));
            while relay.subscriber_count() < 2 {
                std::thread::sleep(std::time::Duration::from_millis(5));
            }
        }
    }
    relay.publish(Frame { kind: kind::CLOSE, payload: vec![] });
    let (w_early, root_early) = early.join().unwrap().unwrap();
    let (w_late, root_late) = late.unwrap().join().unwrap().unwrap();
    assert_eq!(w_early, view, "early worker must be bit-identical to the trainer");
    assert_eq!(w_late, view, "late joiner must be bit-identical to the trainer");
    assert_eq!(root_early, enc.tree().root_hex());
    assert_eq!(root_late, root_early);
    relay.stop();
}