//! Control-plane integration suite (tentpole: membership, fan-out
//! planning, live re-parenting).
//!
//! Acceptance bar (ISSUE 5):
//!
//! * a 3-level tree **self-assembles from JOINs alone** — no peer ever
//!   holds a hard-coded upstream address; the plane plans the tree
//!   from the measured leaf count and pushes ASSIGN directives;
//! * killing a mid-tree relay (crash-style: silent heartbeats, socket
//!   open) **re-parents its subtree** in the next epoch — the orphaned
//!   leaves move to the standby relay, catch up from its anchor + tail
//!   staging, and end **bit-identical to the object-store reference**;
//! * **zero duplicate frames across the epoch boundary**: every
//!   successful synchronize continues exactly where the previous one
//!   stopped (`from_step == previous to_step`), and the final
//!   up-to-date call applies nothing.

use pulse::net::control::{
    ControlConfig, ControlPlane, ControlSubscriberTransport, ControlledNode,
};
use pulse::net::node::RelayNode;
use pulse::net::relay::{Relay, DEFAULT_QUEUE_DEPTH, INDEX_STEPS};
use pulse::net::transport::{ObjectStoreTransport, RelayTransport, SyncTransport};
use pulse::coordinator::planner::Upstream;
use pulse::pulse::sync::{Consumer, Publisher, SyncPath, SyncStats};
use pulse::sparse::synthetic_layout;
use pulse::storage::ObjectStore;
use pulse::util::rng::Rng;
use std::sync::Arc;
use std::time::{Duration, Instant};

const N: usize = 12_000;
const SHARDS: usize = 4;

/// Seeded stream of views (views[0] = initial checkpoint).
fn views(n: usize, steps: u64, perturbs: usize) -> Vec<Vec<u16>> {
    let mut rng = Rng::new(137);
    let mut w: Vec<u16> = (0..n).map(|_| rng.next_u32() as u16).collect();
    let mut out = vec![w.clone()];
    for _ in 0..steps {
        for _ in 0..perturbs {
            let i = rng.below(n as u64) as usize;
            w[i] = rng.next_u32() as u16;
        }
        out.push(w.clone());
    }
    out
}

/// Poll until `step` is committed from this consumer's view, then
/// synchronize. Tolerates transient errors (mid-failover the inner
/// subscription may be dead or not yet assigned) — that resilience is
/// part of what the suite exercises.
fn wait_sync<T: SyncTransport>(c: &mut Consumer<T>, step: u64) -> SyncStats {
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        assert!(Instant::now() < deadline, "step {} never synced", step);
        match c.latest_ready() {
            Ok(Some(head)) if head >= step => match c.synchronize() {
                Ok(cs) => return cs,
                Err(_) => std::thread::sleep(Duration::from_millis(5)),
            },
            _ => std::thread::sleep(Duration::from_millis(5)),
        }
    }
}

fn wait_until(what: &str, deadline_s: u64, mut cond: impl FnMut() -> bool) {
    let deadline = Instant::now() + Duration::from_secs(deadline_s);
    while !cond() {
        assert!(Instant::now() < deadline, "timed out waiting for {}", what);
        std::thread::sleep(Duration::from_millis(5));
    }
}

#[test]
fn three_level_tree_self_assembles_from_joins() {
    let hb = Duration::from_millis(50);
    let cfg = ControlConfig {
        fanout_cap: 2,
        min_relay_levels: 2,
        heartbeat_interval: hb,
        missed_heartbeats: 40, // liveness generous: assembly is under test
        ..Default::default()
    };
    let steps = 4u64;
    let vs = views(N, steps, 200);
    let layout = synthetic_layout(N, 64);

    let root = Arc::new(Relay::start().unwrap());
    // publisher first: anchor 0 stages at the root and cascades down
    // every hop's catch-up preload as the tree assembles
    let mut publisher = Publisher::over(
        RelayTransport::publisher(root.clone()),
        layout.clone(),
        vs[0].clone(),
        100,
    )
    .unwrap()
    .with_shards(SHARDS);

    let plane = ControlPlane::start(root.port, cfg).unwrap();
    // relays know only the control port — never an upstream address
    let nodes: Vec<ControlledNode> = vec![
        ControlledNode::join_with_opts(plane.port, DEFAULT_QUEUE_DEPTH, INDEX_STEPS, hb).unwrap(),
        ControlledNode::join_with_opts(plane.port, DEFAULT_QUEUE_DEPTH, INDEX_STEPS, hb).unwrap(),
        // the RelayNode-level entry point (default heartbeat cadence —
        // well under this plane's generous timeout)
        RelayNode::connect_via_control(plane.port).unwrap(),
    ];
    let mut leaves: Vec<Consumer<ControlSubscriberTransport>> = (0..4)
        .map(|_| {
            Consumer::over(
                ControlSubscriberTransport::join_with_heartbeat(plane.port, hb).unwrap(),
                layout.clone(),
            )
        })
        .collect();

    wait_until("membership to settle", 20, || plane.live_peers() == (3, 4));
    assert_eq!(plane.depth(), Some(3), "4 leaves, cap 2, forced 2 relay levels");
    assert!(plane.epoch() >= 7, "each of the 7 joins bumps the epoch");

    for step in 1..=steps {
        publisher.publish(step, &vs[step as usize]).unwrap();
    }
    for (i, leaf) in leaves.iter_mut().enumerate() {
        let cs = wait_sync(leaf, steps);
        assert!(cs.verified, "leaf {} unverified", i);
        assert_eq!(cs.transport, "control-relay");
        assert!(cs.epoch > 0, "leaf {} never accepted an epoch", i);
        assert_eq!(
            leaf.transport.counters().epoch,
            cs.epoch,
            "SyncStats must mirror the transport's epoch"
        );
        assert_eq!(
            leaf.weights.as_ref().unwrap(),
            &vs[steps as usize],
            "leaf {} diverged",
            i
        );
    }
    // tree-ness, structurally: 4 leaves synced, yet the root fans out
    // to exactly ONE subscriber (the level-1 relay) — everything else
    // hangs below it, per the plan's [1, 2] interior shape
    assert_eq!(root.subscriber_count(), 1, "only the level-1 relay sits on the root");
    wait_until("node hop depths to settle", 10, || {
        let mut hops: Vec<u32> = nodes.iter().map(|n| n.hop()).collect();
        hops.sort_unstable();
        hops == vec![1, 2, 2]
    });
    // assembly-time replans keep every relay's upstream port stable
    // (join-order binding), so nodes attach once and stay put
    assert!(nodes.iter().all(|n| n.reparents() <= 2), "assembly must not thrash upstreams");

    drop(leaves);
    for n in &nodes {
        n.stop();
    }
    plane.stop();
    root.stop();
}

#[test]
fn mid_tree_relay_death_reparents_subtree_bit_identically() {
    let hb = Duration::from_millis(50);
    let cfg = ControlConfig {
        fanout_cap: 2,
        min_relay_levels: 0,
        heartbeat_interval: hb,
        missed_heartbeats: 8, // death timeout: 400 ms
        ..Default::default()
    };
    let steps = 6u64;
    let kill_after = 3u64;
    let vs = views(N, steps, 200);
    let layout = synthetic_layout(N, 64);

    // object-store reference: the same views through the paper's
    // default fabric — the arbiter for "bit-identical"
    let store = ObjectStore::temp("ctl_reference").unwrap();
    let mut ref_pub = Publisher::over(
        ObjectStoreTransport::new(store.clone(), "sync"),
        layout.clone(),
        vs[0].clone(),
        100,
    )
    .unwrap()
    .with_shards(SHARDS);
    let mut ref_con =
        Consumer::over(ObjectStoreTransport::new(store.clone(), "sync"), layout.clone());

    let root = Arc::new(Relay::start().unwrap());
    let mut publisher = Publisher::over(
        RelayTransport::publisher(root.clone()),
        layout.clone(),
        vs[0].clone(),
        100,
    )
    .unwrap()
    .with_shards(SHARDS);
    let plane = ControlPlane::start(root.port, cfg).unwrap();
    // 3 relays for a plan that needs 2: the third parks as a live
    // standby and is the failover target
    let nodes: Vec<ControlledNode> = (0..3)
        .map(|_| {
            ControlledNode::join_with_opts(plane.port, DEFAULT_QUEUE_DEPTH, INDEX_STEPS, hb)
                .unwrap()
        })
        .collect();
    let mut leaves: Vec<Consumer<ControlSubscriberTransport>> = (0..4)
        .map(|_| {
            Consumer::over(
                ControlSubscriberTransport::join_with_heartbeat(plane.port, hb).unwrap(),
                layout.clone(),
            )
        })
        .collect();
    wait_until("membership to settle", 20, || plane.live_peers() == (3, 4));

    for step in 1..=kill_after {
        publisher.publish(step, &vs[step as usize]).unwrap();
        ref_pub.publish(step, &vs[step as usize]).unwrap();
    }
    // all leaves verified at the pre-kill head; every later sync must
    // continue exactly at its predecessor's to_step (no duplicates, no
    // regression across the coming epoch boundary)
    let mut prev_to = vec![0u64; leaves.len()];
    let mut pre_epoch = vec![0u64; leaves.len()];
    for (i, leaf) in leaves.iter_mut().enumerate() {
        let cs = wait_sync(leaf, kill_after);
        assert!(cs.verified);
        assert_eq!(leaf.weights.as_ref().unwrap(), &vs[kill_after as usize]);
        prev_to[i] = cs.to_step;
        pre_epoch[i] = cs.epoch;
    }
    let reparents_before: Vec<u64> =
        leaves.iter().map(|l| l.transport.reparents()).collect();

    // victim: the relay parenting leaf 0 under the CURRENT plan;
    // orphans: every leaf under it
    let plan = plane.plan().unwrap();
    let leaf_ids: Vec<u64> =
        leaves.iter().map(|l| l.transport.peer_id().unwrap()).collect();
    let parent_of = |leaf_id: u64| match plan.assignment_of(leaf_id).unwrap().upstream {
        Upstream::Peer(id) => id,
        other => panic!("leaf {} not under a relay: {:?}", leaf_id, other),
    };
    let victim_id = parent_of(leaf_ids[0]);
    let orphans: Vec<usize> = (0..leaves.len())
        .filter(|&i| parent_of(leaf_ids[i]) == victim_id)
        .collect();
    assert!(!orphans.is_empty() && orphans.len() < leaves.len());
    let victim =
        nodes.iter().find(|n| n.peer_id() == Some(victim_id)).expect("victim node");

    // crash-style kill: data plane dies, control socket stays open but
    // silent — only the heartbeat timeout can discover this
    let deaths_before = plane.deaths();
    let epoch_before = plane.epoch();
    let t_kill = Instant::now();
    victim.fail_silently();
    wait_until("failure detection", 10, || plane.deaths() > deaths_before);
    let detect = t_kill.elapsed();
    assert!(
        detect < Duration::from_secs(5),
        "detection took {:?} (budget: missed_heartbeats × interval = 400 ms + scheduling)",
        detect
    );
    assert!(plane.epoch() > epoch_before, "the death must open a new epoch");

    // the stream never stops: publish through the outage
    for step in kill_after + 1..=steps {
        publisher.publish(step, &vs[step as usize]).unwrap();
        ref_pub.publish(step, &vs[step as usize]).unwrap();
    }
    let ref_stats = ref_con.synchronize().unwrap();
    assert!(ref_stats.verified);

    for (i, leaf) in leaves.iter_mut().enumerate() {
        let cs = wait_sync(leaf, steps);
        assert!(cs.verified, "leaf {} unverified after failover", i);
        assert_eq!(
            cs.from_step, prev_to[i],
            "leaf {} must continue exactly where it stopped (no duplicates)",
            i
        );
        assert!(cs.epoch > pre_epoch[i], "leaf {} never saw the failover epoch", i);
        assert_eq!(
            leaf.weights.as_ref().unwrap(),
            ref_con.weights.as_ref().unwrap(),
            "leaf {} not bit-identical to the object-store reference",
            i
        );
        // idempotence at the boundary: nothing left to apply
        let again = leaf.synchronize().unwrap();
        assert_eq!(again.path, SyncPath::UpToDate);
        assert_eq!(again.patches_applied, 0);
    }
    for (i, leaf) in leaves.iter().enumerate() {
        let now = leaf.transport.reparents();
        if orphans.contains(&i) {
            // exactly one re-parent in the common case; a leaf that
            // raced the dying relay's accept loop may have burned one
            // extra subscription on the corpse first
            assert!(
                now >= reparents_before[i] + 1 && now <= reparents_before[i] + 2,
                "orphan leaf {} re-parented {} times (want 1, tolerate 2)",
                i,
                now - reparents_before[i]
            );
        } else {
            assert_eq!(
                now, reparents_before[i],
                "leaf {} kept its parent and must not rewire",
                i
            );
        }
    }

    drop(leaves);
    for n in &nodes {
        n.stop();
    }
    plane.stop();
    root.stop();
    std::fs::remove_dir_all(store.root()).ok();
}
