//! grail deployment integration (paper §E): trainer + miners +
//! validator coordinate through the object store with PULSESync patches
//! and grail-Proof verification. Requires `make artifacts` (tiny).

use pulse::coordinator;
use pulse::grail::{GrailConfig, GrailSim};
use pulse::optim::AdamConfig;
use pulse::rl::tasks::MathTask;
use pulse::runtime::{artifacts_dir, ModelRuntime};

/// Load the tiny runtime, or skip the test: artifacts may be absent
/// (`make artifacts` not run) or PJRT unavailable (offline build with
/// the stub `xla` crate — see vendor/README.md).
fn rt() -> Option<ModelRuntime> {
    if !artifacts_dir().join("tiny.meta.json").exists() {
        eprintln!("skipping: artifacts missing — run `make artifacts`");
        return None;
    }
    match ModelRuntime::load(&artifacts_dir(), "tiny", &[]) {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("skipping: runtime unavailable: {e:#}");
            None
        }
    }
}

#[test]
fn grail_windows_train_verify_and_stay_sparse() {
    let rt = match rt() {
        Some(rt) => rt,
        None => return,
    };
    let task = MathTask::default();
    let master = coordinator::init_master(&rt, 0).unwrap();
    let mut sim = GrailSim::new(
        &rt,
        &task,
        GrailConfig {
            n_miners: 2,
            steps_per_window: 3,
            batches_per_miner: 1,
            anchor_interval: 50,
            proof_tolerance: 2,
            n_eval: 32,
        },
        master,
        AdamConfig::post_training(),
        7,
    )
    .unwrap();
    let mut total_upload = 0u64;
    let mut total_full = 0u64;
    for w in 0..3u64 {
        let stats = sim.run_window(w).unwrap();
        assert_eq!(stats.rejected, 0, "honest miners must verify");
        assert_eq!(stats.verified, 2, "both miners' batches verified");
        assert!(stats.train_steps > 0);
        assert!(stats.pass_at_1 >= 0.0 && stats.pass_at_1 <= 1.0);
        total_upload += stats.upload_bytes;
        total_full += stats.full_checkpoint_bytes;
    }
    // sparse patches beat full checkpoints by a large factor even at
    // tiny scale (0.1M params)
    assert!(
        total_upload * 3 < total_full,
        "upload {} vs full {}",
        total_upload,
        total_full
    );
}

#[test]
fn stale_checkpoint_rollouts_are_rejected() {
    use pulse::grail::{decode_rollout, encode_rollout, proof, replay::Entry};
    let rt = match rt() {
        Some(rt) => rt,
        None => return,
    };
    let d = rt.manifest.dims.clone();
    let flat_fresh = coordinator::init_master(&rt, 0).unwrap();
    // a "stale" model: perturb weights well past BF16 cells
    let flat_stale: Vec<f32> = flat_fresh.iter().map(|&x| x * 1.2 + 0.01).collect();
    let prompts: Vec<i32> = (0..d.batch * d.prompt_len).map(|i| (i % d.vocab) as i32).collect();
    let ro = rt.rollout(&flat_stale, &prompts, [5, 6], 1.0).unwrap();
    let beacon = 99u64;
    // miner claims the rollouts came from the fresh checkpoint
    let proofs: Vec<Vec<u32>> = (0..d.batch)
        .map(|row| {
            let toks = &ro.tokens[row * d.seq + d.prompt_len..(row + 1) * d.seq];
            let lps = &ro.logprobs[row * d.gen_len..(row + 1) * d.gen_len];
            proof::prove(beacon, toks, lps)
        })
        .collect();
    let entry = Entry {
        window: 0,
        miner: 0,
        tokens: ro.tokens.clone(),
        logprobs: ro.logprobs.clone(),
        instances: vec![],
    };
    let text = encode_rollout(&entry, &proofs, beacon);
    let (e2, p2, b2) = decode_rollout(&text).unwrap();
    // validator recomputes under the FRESH checkpoint
    let (relp, _) = rt.score(&flat_fresh, &e2.tokens).unwrap();
    let mut any_rejected = false;
    for row in 0..d.batch {
        let toks = &e2.tokens[row * d.seq + d.prompt_len..(row + 1) * d.seq];
        let lps = &relp[row * d.gen_len..(row + 1) * d.gen_len];
        if !proof::verify(b2, toks, lps, &p2[row], 1) {
            any_rejected = true;
            break;
        }
    }
    assert!(any_rejected, "stale-checkpoint rollouts must fail verification");
}
