//! Relay-chaining integration suite (tentpole: relay→relay trees).
//!
//! The star topology's fault handling is covered by
//! `integration_transport.rs`; this suite checks what chaining adds —
//! that every guarantee is **recursive**:
//!
//! * a 2-level tree (root → 2 nodes → leaves) delivers the same seeded
//!   stream bit-identically to every leaf, and CLOSE survives the
//!   hops;
//! * late joiners catch up from their *node's* staging without adding
//!   load (or even a connection) at the root;
//! * a stalled leaf coalesces inside its node's per-subscriber queue
//!   while its sibling keeps streaming;
//! * a NACK the node's bounded frame index has evicted escalates
//!   upstream, and the retransmit comes back to exactly the requester;
//! * a NACK no hop can service gets an explicit NACK_MISS, the
//!   consumer degrades to the anchor slow path, and `SyncStats`
//!   counts it.

use pulse::net::node::RelayNode;
use pulse::net::relay::Relay;
use pulse::net::tcp::{self, kind, Frame};
use pulse::net::transport::{FaultInjectingTransport, RelayTransport, SyncTransport};
use pulse::pulse::sync::{Consumer, Publisher, SyncPath, SyncStats};
use pulse::sparse::synthetic_layout;
use pulse::storage::retention::Inventory;
use pulse::util::rng::Rng;
use std::sync::Arc;
use std::time::{Duration, Instant};

const N: usize = 16_000;
const SHARDS: usize = 4;

/// Seeded stream of views (views[0] = initial checkpoint).
fn views(n: usize, steps: u64, perturbs: usize) -> Vec<Vec<u16>> {
    let mut rng = Rng::new(91);
    let mut w: Vec<u16> = (0..n).map(|_| rng.next_u32() as u16).collect();
    let mut out = vec![w.clone()];
    for _ in 0..steps {
        for _ in 0..perturbs {
            let i = rng.below(n as u64) as usize;
            w[i] = rng.next_u32() as u16;
        }
        out.push(w.clone());
    }
    out
}

/// Poll until `step` is committed from this consumer's view, then
/// synchronize once.
fn wait_sync<T: SyncTransport>(c: &mut Consumer<T>, step: u64) -> SyncStats {
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        if let Ok(Some(head)) = c.latest_ready() {
            if head >= step {
                return c.synchronize().unwrap();
            }
        }
        assert!(Instant::now() < deadline, "step {} never became ready", step);
        std::thread::sleep(Duration::from_millis(3));
    }
}

/// Wait until a node has learned its hop depth from the upstream HOP
/// reply (asynchronous), so leaves attached afterwards report theirs.
fn wait_hop(node: &RelayNode, want: u32) {
    let deadline = Instant::now() + Duration::from_secs(10);
    while node.hop() != want {
        assert!(Instant::now() < deadline, "node never learned hop {}", want);
        std::thread::sleep(Duration::from_millis(3));
    }
}

#[test]
fn two_level_tree_fans_out_bit_identically() {
    let steps = 5u64;
    let vs = views(N, steps, 300);
    let layout = synthetic_layout(N, 64);

    let root = Arc::new(Relay::start().unwrap());
    let node_a = RelayNode::join(root.port).unwrap();
    let node_b = RelayNode::join(root.port).unwrap();
    wait_hop(&node_a, 1);
    wait_hop(&node_b, 1);

    // two leaves per node
    let ports = [node_a.port(), node_a.port(), node_b.port(), node_b.port()];
    let mut leaves: Vec<Consumer<RelayTransport>> = ports
        .iter()
        .map(|&p| Consumer::over(RelayTransport::subscribe(p).unwrap(), layout.clone()))
        .collect();

    let mut publisher = Publisher::over(
        RelayTransport::publisher(root.clone()),
        layout.clone(),
        vs[0].clone(),
        3,
    )
    .unwrap()
    .with_shards(SHARDS);

    for leaf in leaves.iter_mut() {
        let s0 = wait_sync(leaf, 0);
        assert_eq!(s0.path, SyncPath::Slow, "cold start is the slow path");
        assert_eq!(leaf.weights.as_ref().unwrap(), &vs[0]);
    }
    for step in 1..=steps {
        publisher.publish(step, &vs[step as usize]).unwrap();
        for (i, leaf) in leaves.iter_mut().enumerate() {
            let cs = wait_sync(leaf, step);
            assert!(cs.verified, "leaf {} unverified at step {}", i, step);
            assert_eq!(cs.shard_refetches, 0);
            assert_eq!(
                leaf.weights.as_ref().unwrap(),
                &vs[step as usize],
                "leaf {} diverged at step {}",
                i,
                step
            );
        }
    }
    // topology bookkeeping: every leaf sits two hops below the
    // publisher (root = 0 → node = 1 → leaf = 2); the HOP reply rides
    // the same queue as data, so poll briefly
    let deadline = Instant::now() + Duration::from_secs(10);
    for leaf in &leaves {
        while leaf.transport.hops() != Some(2) {
            assert!(Instant::now() < deadline, "leaf never learned hops=2");
            std::thread::sleep(Duration::from_millis(3));
        }
    }
    // the root fans out to exactly the two nodes, never the leaves
    assert_eq!(root.subscriber_count(), 2);

    // CLOSE survives both hops (commit protocol shutdown included)
    publisher.transport.close();
    let deadline = Instant::now() + Duration::from_secs(10);
    for leaf in &leaves {
        while !leaf.transport.stream_closed() {
            assert!(Instant::now() < deadline, "CLOSE never crossed the tree");
            std::thread::sleep(Duration::from_millis(3));
        }
    }
    drop(leaves);
    node_a.stop();
    node_b.stop();
    root.stop();
}

#[test]
fn late_joiner_catches_up_from_node_staging() {
    let steps = 4u64;
    let vs = views(N, steps, 250);
    let layout = synthetic_layout(N, 64);

    let root = Arc::new(Relay::start().unwrap());
    let node = RelayNode::join(root.port).unwrap();
    let mut publisher = Publisher::over(
        RelayTransport::publisher(root.clone()),
        layout.clone(),
        vs[0].clone(),
        50,
    )
    .unwrap()
    .with_shards(SHARDS);
    for step in 1..=steps {
        publisher.publish(step, &vs[step as usize]).unwrap();
    }
    // wait until the whole stream is staged at the node (the node's
    // relay replays anchor + tail to any late joiner)
    let deadline = Instant::now() + Duration::from_secs(15);
    let mut late = loop {
        let mut probe =
            Consumer::over(RelayTransport::subscribe(node.port()).unwrap(), layout.clone());
        if let Ok(Some(head)) = probe.latest_ready() {
            if head >= steps {
                break probe;
            }
        }
        assert!(Instant::now() < deadline, "node staging never completed");
        std::thread::sleep(Duration::from_millis(10));
    };
    let cs = late.synchronize().unwrap();
    assert_eq!(cs.path, SyncPath::Slow, "late join replays anchor + tail");
    assert_eq!(cs.anchors_restored, 1);
    assert_eq!(cs.patches_applied, steps as usize);
    assert_eq!(late.weights.as_ref().unwrap(), &vs[steps as usize]);
    // the late joins hit the node only: the root still sees exactly
    // one subscriber (the node itself)
    assert_eq!(root.subscriber_count(), 1);
    drop(late);
    node.stop();
    root.stop();
}

#[test]
fn slow_peers_coalesce_in_place_without_stalling_the_tree() {
    // raw-frame topology test, both stall directions at once:
    //  * a stalled (never-reading) peer at the ROOT — the stand-in for
    //    a slow mid-tree node — must coalesce inside the root's
    //    per-subscriber queue;
    //  * a stalled leaf under the NODE must coalesce inside the
    //    node's queue;
    // while a healthy leaf under the node receives the full stream in
    // publish order through both hops.
    let root = Arc::new(Relay::start_with_opts(4, 8).unwrap());
    let node = RelayNode::join_with_opts(root.port, 4, 8).unwrap();
    let _stalled_mid = tcp::connect_local(root.port).unwrap();
    let _stalled_leaf = tcp::connect_local(node.port()).unwrap();
    let mut sibling = tcp::connect_local(node.port()).unwrap();
    let deadline = Instant::now() + Duration::from_secs(10);
    while root.subscriber_count() < 2 || node.relay().subscriber_count() < 2 {
        assert!(Instant::now() < deadline, "subscribers never registered");
        std::thread::sleep(Duration::from_millis(3));
    }
    // big frames so the stalled peers' writers wedge on their sockets;
    // 12 patches against queue depth 4 force coalescing. The healthy
    // sibling reads in lockstep with the publishes, so ITS queues
    // (root→node and node→sibling) never overflow — it must see the
    // full stream in publish order.
    root.publish(Frame { kind: kind::ANCHOR, payload: vec![1u8; 2 << 20] });
    let f = tcp::read_frame(&mut sibling).unwrap();
    assert_eq!((f.kind, f.payload[0]), (kind::ANCHOR, 1));
    for i in 0..12u8 {
        root.publish(Frame { kind: kind::PATCH, payload: vec![10 + i; 2 << 20] });
        let f = tcp::read_frame(&mut sibling).unwrap();
        assert_eq!((f.kind, f.payload[0]), (kind::PATCH, 10 + i), "sibling stalled at {}", i);
    }
    let deadline = Instant::now() + Duration::from_secs(10);
    while root.coalesced_catchups() == 0 || node.relay().coalesced_catchups() == 0 {
        assert!(Instant::now() < deadline, "stalled peers never coalesced");
        std::thread::sleep(Duration::from_millis(3));
    }
    node.stop();
    root.stop();
}

#[test]
fn evicted_nack_escalates_upstream_and_heals() {
    // the node's frame index holds ONE step, so by the time the
    // consumer repairs step 1 the node must escalate the NACK to the
    // root, deliver the upstream retransmit to the requester, and
    // re-index it — one counted refetch, bit-identity preserved
    let steps = 4u64;
    let vs = views(N, steps, 250);
    let layout = synthetic_layout(N, 64);

    let root = Arc::new(Relay::start().unwrap());
    let node = RelayNode::join_with_opts(
        root.port,
        pulse::net::relay::DEFAULT_QUEUE_DEPTH,
        1, // aggressive eviction: index only the newest step
    )
    .unwrap();
    let cons = RelayTransport::subscribe(node.port()).unwrap();
    let decorated = FaultInjectingTransport::targeting(cons, 1, 0);
    let mut consumer = Consumer::over(decorated, layout.clone());
    let mut publisher = Publisher::over(
        RelayTransport::publisher(root.clone()),
        layout.clone(),
        vs[0].clone(),
        50,
    )
    .unwrap()
    .with_shards(SHARDS);
    for step in 1..=steps {
        publisher.publish(step, &vs[step as usize]).unwrap();
    }
    // cold start AFTER the whole stream landed: the chain replays step
    // 1, whose (1, 0) frame the decorator corrupts on first serve; the
    // node's index has long evicted step 1
    let cs = wait_sync(&mut consumer, steps);
    assert_eq!(cs.path, SyncPath::Slow);
    assert!(cs.verified);
    assert_eq!(cs.shard_refetches, 1, "exactly one counted refetch");
    assert_eq!(cs.nacks_unserviceable, 0);
    assert_eq!(consumer.weights.as_ref().unwrap(), &vs[steps as usize]);
    assert_eq!(node.relay().nacks_escalated(), 1, "the node must escalate the evicted slot");
    assert_eq!(root.nacks_serviced(), 1, "the root must serve the escalated NACK");
    assert_eq!(
        node.relay().nacks_serviced(),
        1,
        "the retransmit is delivered (and re-indexed) by the node"
    );
    drop(consumer);
    node.stop();
    root.stop();
}

#[test]
fn depth3_tree_bit_identity_and_double_hop_escalation() {
    // ROADMAP "3+ levels", hand-wired (no control plane): publisher →
    // root → node A → node B → leaf. Both mid nodes index only the
    // newest step, so repairing an old step NACK-escalates across TWO
    // hops to the root; the retransmit is re-indexed at every hop on
    // the way back down and delivered to exactly the requester. The
    // leaf ends bit-identical with one counted refetch.
    let steps = 4u64;
    let vs = views(N, steps, 250);
    let layout = synthetic_layout(N, 64);

    let root = Arc::new(Relay::start().unwrap());
    let node_a = RelayNode::join_with_opts(root.port, pulse::net::relay::DEFAULT_QUEUE_DEPTH, 1)
        .unwrap();
    // let A learn its depth before B subscribes, so the HOP chain
    // reports deterministically (A would otherwise reply 0 to B)
    wait_hop(&node_a, 1);
    let node_b =
        RelayNode::join_with_opts(node_a.port(), pulse::net::relay::DEFAULT_QUEUE_DEPTH, 1)
            .unwrap();
    wait_hop(&node_b, 2);

    let cons = RelayTransport::subscribe(node_b.port()).unwrap();
    let decorated = FaultInjectingTransport::targeting(cons, 1, 0);
    let mut consumer = Consumer::over(decorated, layout.clone());
    let mut publisher = Publisher::over(
        RelayTransport::publisher(root.clone()),
        layout.clone(),
        vs[0].clone(),
        50,
    )
    .unwrap()
    .with_shards(SHARDS);
    for step in 1..=steps {
        publisher.publish(step, &vs[step as usize]).unwrap();
    }
    // cold start AFTER the whole stream landed: the chain replays step
    // 1, whose (1, 0) frame the decorator corrupts on first serve; by
    // now both mid-tree indices have evicted step 1, so the NACK walks
    // B → A → root
    let cs = wait_sync(&mut consumer, steps);
    assert_eq!(cs.path, SyncPath::Slow);
    assert!(cs.verified);
    assert_eq!(cs.shard_refetches, 1, "exactly one counted refetch");
    assert_eq!(cs.nacks_unserviceable, 0);
    assert_eq!(cs.reparents, 0, "hand-wired tree: no control plane, no re-parents");
    assert_eq!(consumer.weights.as_ref().unwrap(), &vs[steps as usize]);
    assert_eq!(node_b.relay().nacks_escalated(), 1, "B must escalate the evicted slot");
    assert_eq!(node_a.relay().nacks_escalated(), 1, "A must escalate it again");
    assert_eq!(root.nacks_serviced(), 1, "only the root still held the slot");
    assert_eq!(node_a.relay().nacks_serviced(), 1, "A re-delivers (and re-indexes)");
    assert_eq!(node_b.relay().nacks_serviced(), 1, "B re-delivers (and re-indexes)");
    // topology bookkeeping across both hops
    let deadline = Instant::now() + Duration::from_secs(10);
    while consumer.transport.inner().hops() != Some(3) {
        assert!(Instant::now() < deadline, "leaf never learned hops=3");
        std::thread::sleep(Duration::from_millis(3));
    }
    // CLOSE crosses both mid hops
    publisher.transport.close();
    let deadline = Instant::now() + Duration::from_secs(10);
    while !consumer.transport.inner().stream_closed() {
        assert!(Instant::now() < deadline, "CLOSE never crossed the depth-3 tree");
        std::thread::sleep(Duration::from_millis(3));
    }
    drop(consumer);
    node_b.stop();
    node_a.stop();
    root.stop();
}

#[test]
fn unserviceable_nack_errors_fast_then_anchor_rescues() {
    // end-to-end over the wire: a repair NACK whose slot the relay has
    // evicted gets an explicit NACK_MISS — the consumer's synchronize
    // fails FAST (no NACK-timeout burn) with a detectable error, and a
    // later anchor above the poisoned step rescues the next call
    let steps = 3u64;
    let vs = views(N, steps + 1, 250);
    let layout = synthetic_layout(N, 64);

    let root =
        Arc::new(Relay::start_with_opts(pulse::net::relay::DEFAULT_QUEUE_DEPTH, 1).unwrap());
    let cons = RelayTransport::subscribe(root.port).unwrap();
    let decorated = FaultInjectingTransport::targeting(cons, 1, 0);
    let mut consumer = Consumer::over(decorated, layout.clone());
    let mut publisher = Publisher::over(
        RelayTransport::publisher(root.clone()),
        layout.clone(),
        vs[0].clone(),
        4, // anchor at step 4 = the eventual rescue point
    )
    .unwrap()
    .with_shards(SHARDS);
    // the whole stream lands before the cold start, so the root's
    // one-step frame index has long evicted step 1's slots
    for step in 1..=steps {
        publisher.publish(step, &vs[step as usize]).unwrap();
    }
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        if consumer.latest_ready().unwrap() >= Some(steps) {
            break;
        }
        assert!(Instant::now() < deadline, "stream never staged");
        std::thread::sleep(Duration::from_millis(3));
    }
    // cold start: anchor 0 + chain; (1, 0) is corrupted on first
    // serve, the repair NACK is unserviceable everywhere → hard error
    let t0 = Instant::now();
    let err = consumer.synchronize().unwrap_err();
    assert!(
        t0.elapsed() < pulse::util::retry::RetryPolicy::nack_default().total,
        "NACK_MISS must preempt the retransmit retry budget"
    );
    assert!(
        pulse::net::transport::is_unserviceable(&err),
        "the error must be detectably unserviceable: {:#}",
        err
    );
    assert_eq!(root.nacks_unserviceable(), 1);
    assert_eq!(consumer.transport.inner().counters().nacks_unserviceable, 1);
    // step 4 publishes the rescue anchor (4 % anchor_interval == 0);
    // the consumer's staging prunes the poisoned step and the next
    // synchronize restores from the new anchor
    publisher.publish(steps + 1, &vs[(steps + 1) as usize]).unwrap();
    let cs = wait_sync(&mut consumer, steps + 1);
    assert_eq!(cs.path, SyncPath::Slow, "recovery must ride the fresh anchor");
    assert!(cs.verified);
    assert_eq!(consumer.weights.as_ref().unwrap(), &vs[(steps + 1) as usize]);
    drop(consumer);
    root.stop();
}

#[test]
fn unserviceable_repair_degrades_to_anchor_and_is_counted_in_stats() {
    // SyncStats accounting: a chain attempt that dies on an
    // unserviceable repair must fall back to the anchor path within
    // the SAME synchronize call and report nacks_unserviceable — run
    // over the in-proc fabric, whose staging keeps the poisoned step
    // visible so the chain attempt really meets it
    use pulse::net::transport::InProcTransport;
    let steps = 5u64;
    let vs = views(N, steps, 250);
    let layout = synthetic_layout(N, 64);

    let fabric = InProcTransport::new();
    let decorated = FaultInjectingTransport::unserviceable(fabric.clone(), 2, 0);
    let mut consumer = Consumer::over(decorated, layout.clone());
    // anchor every 4 steps: the recovery anchor (step 4) exists by the
    // time step 2's repair turns out to be unserviceable
    let mut publisher = Publisher::over(fabric, layout.clone(), vs[0].clone(), 4)
        .unwrap()
        .with_shards(SHARDS);
    // sync cleanly to step 1 first, so the poisoned step 2 is met on
    // the CHAIN path (whose failure falls back to the anchor path)
    publisher.publish(1, &vs[1]).unwrap();
    let s1 = consumer.synchronize().unwrap();
    assert!(s1.verified);
    for step in 2..=steps {
        publisher.publish(step, &vs[step as usize]).unwrap();
    }
    let cs = consumer.synchronize().unwrap();
    assert_eq!(cs.path, SyncPath::Slow, "the failed chain must degrade to the anchor path");
    assert!(cs.verified);
    assert_eq!(cs.nacks_unserviceable, 1, "SyncStats must count the unserviceable repair");
    assert_eq!(cs.shard_refetches, 1, "the dead repair was still one counted refetch");
    assert!(cs.anchors_restored >= 1);
    assert_eq!(consumer.weights.as_ref().unwrap(), &vs[steps as usize]);
    assert_eq!(consumer.transport.injected(), 2, "first-serve corrupt + dead repair");
}

#[test]
fn chained_consumer_reads_same_inventory_as_star() {
    // the commit protocol survives the extra hop: a chained consumer's
    // inventory (committed deltas + anchors) matches a star consumer's
    // once both drained the same stream
    let steps = 4u64;
    let vs = views(8_000, steps, 150);
    let layout = synthetic_layout(8_000, 64);

    let root = Arc::new(Relay::start().unwrap());
    let node = RelayNode::join(root.port).unwrap();
    let mut star = Consumer::over(RelayTransport::subscribe(root.port).unwrap(), layout.clone());
    let mut chained =
        Consumer::over(RelayTransport::subscribe(node.port()).unwrap(), layout.clone());
    let mut publisher = Publisher::over(
        RelayTransport::publisher(root.clone()),
        layout.clone(),
        vs[0].clone(),
        2,
    )
    .unwrap()
    .with_shards(3);
    for step in 1..=steps {
        publisher.publish(step, &vs[step as usize]).unwrap();
    }
    let a = wait_sync(&mut star, steps);
    let b = wait_sync(&mut chained, steps);
    assert!(a.verified && b.verified);
    assert_eq!(star.weights, chained.weights);
    // the chained leaf drains one hop later: poll both to the steady
    // state (the final anchor staged) before comparing inventories
    let settle = |t: &RelayTransport| -> Inventory {
        let deadline = Instant::now() + Duration::from_secs(10);
        loop {
            let inv = t.latest_ready().unwrap();
            if inv.anchor_steps.contains(&steps) {
                return inv;
            }
            assert!(Instant::now() < deadline, "final anchor never staged");
            std::thread::sleep(Duration::from_millis(3));
        }
    };
    let inv_star = settle(&star.transport);
    let inv_chain = settle(&chained.transport);
    assert_eq!(inv_star.delta_steps, inv_chain.delta_steps);
    assert_eq!(inv_star.anchor_steps, inv_chain.anchor_steps);
    drop(star);
    drop(chained);
    node.stop();
    root.stop();
}
