//! End-to-end PULSESync over the object store at realistic scale:
//! a 1M-parameter BF16 stream with Adam-scale updates, retention,
//! and the bandwidth accounting of Fig. 1.

use pulse::bf16;
use pulse::net::SimLink;
use pulse::pulse::sync::{Publisher, Consumer, SyncPath};
use pulse::sparse::synthetic_layout;
use pulse::storage::{retention, ObjectStore};
use pulse::util::rng::Rng;

/// Simulate Adam-scale drift: FP32 masters move by ~eta*rho per step;
/// the BF16 view changes only when a cell boundary is crossed.
struct Drift {
    master: Vec<f32>,
    dir: Vec<f32>,
    rng: Rng,
}

impl Drift {
    fn new(n: usize, seed: u64) -> Drift {
        let mut rng = Rng::new(seed);
        let master: Vec<f32> = (0..n)
            .map(|_| {
                let z = rng.normal();
                let sigma = if z < 0.0 { 1.48 } else { 0.72 };
                ((-4.47 + sigma * z).exp() * if rng.f64() < 0.5 { -1.0 } else { 1.0 }) as f32
            })
            .collect();
        let dir: Vec<f32> = (0..n).map(|_| if rng.f64() < 0.5 { -1.0 } else { 1.0 }).collect();
        Drift { master, dir, rng }
    }

    fn step(&mut self, eta: f32) -> Vec<u16> {
        for i in 0..self.master.len() {
            // occasionally flip direction (gradient oscillation)
            if self.rng.f64() < 0.05 {
                self.dir[i] = -self.dir[i];
            }
            self.master[i] += self.dir[i] * eta;
        }
        let mut view = Vec::new();
        bf16::cast_slice_par(&self.master, &mut view);
        view
    }
}

#[test]
fn adam_scale_stream_is_sparse_and_lossless() {
    let n = 1_000_000;
    let mut drift = Drift::new(n, 1);
    let store = ObjectStore::temp("sync_scale").unwrap();
    let layout = synthetic_layout(n, 1024);
    let init = drift.step(0.0);
    let mut publisher =
        Publisher::new(store.clone(), "sync", layout.clone(), init, 10).unwrap();
    let mut consumer = Consumer::new(store.clone(), "sync", layout);
    consumer.synchronize().unwrap();

    let mut sparsities = Vec::new();
    let mut patch_bytes = Vec::new();
    for step in 1..=20u64 {
        // NOTE: this drift model places every FP32 master uniformly
        // inside its BF16 cell, so the per-step crossing probability is
        // |Δ|/cell (the paper's FP32-accumulation regime), dominated by
        // the small-|w| tail — lower sparsity than the cell-centred
        // ~99% bound. η=1e-6 is the paper's deployment LR (§F.4).
        let view = drift.step(1e-6);
        let ps = publisher.publish(step, &view).unwrap();
        sparsities.push(ps.sparsity);
        patch_bytes.push(ps.patch_bytes);
        let cs = consumer.synchronize().unwrap();
        assert!(cs.verified);
        assert_eq!(consumer.weights.as_ref().unwrap(), &view, "bit-identical at {}", step);
    }
    let mean_sparsity = sparsities.iter().sum::<f64>() / sparsities.len() as f64;
    assert!(mean_sparsity > 0.90, "mean per-step sparsity {}", mean_sparsity);

    // Fig. 1 accounting: patch payload vs full checkpoint over a link
    let full_bytes = (n * 2) as u64;
    let mean_patch = patch_bytes.iter().sum::<u64>() / patch_bytes.len() as u64;
    assert!(
        mean_patch * 5 < full_bytes,
        "patch {} vs full {}",
        mean_patch,
        full_bytes
    );
    let link = SimLink::mbit(400.0);
    let t_patch = link.transfer_time(mean_patch);
    let t_full = link.transfer_time(full_bytes);
    assert!(t_full / t_patch > 5.0);
}

#[test]
fn retention_then_slow_path_recovery() {
    let n = 50_000;
    let mut drift = Drift::new(n, 2);
    let store = ObjectStore::temp("sync_retention").unwrap();
    let layout = synthetic_layout(n, 512);
    let init = drift.step(0.0);
    let mut publisher =
        Publisher::new(store.clone(), "sync", layout.clone(), init, 5).unwrap();
    let mut final_view = Vec::new();
    for step in 1..=23u64 {
        final_view = drift.step(1e-4);
        publisher.publish(step, &final_view).unwrap();
    }
    // apply the §J.7 retention policy: keep 6 deltas, 2 anchors
    let inv = retention::scan(&store, "sync").unwrap();
    let (dd, da) = retention::plan(
        &inv,
        retention::RetentionPolicy { max_deltas: 6, max_anchors: 2 },
    );
    for s in dd {
        store.delete(&format!("sync/delta_{:08}.bin", s)).unwrap();
        store.delete(&format!("sync/delta_ready_{}", s)).unwrap();
    }
    for s in da {
        store.delete(&format!("sync/anchor_{:08}.bin", s)).unwrap();
        store.delete(&format!("sync/anchor_ready_{}", s)).unwrap();
    }
    // a cold-start consumer must still reach the head via slow path
    let mut consumer = Consumer::new(store.clone(), "sync", layout);
    let cs = consumer.synchronize().unwrap();
    assert_eq!(cs.path, SyncPath::Slow);
    assert_eq!(consumer.weights.as_ref().unwrap(), &final_view);
    assert_eq!(consumer.step, 23);
}
