//! Coordinator integration: short end-to-end GRPO training runs through
//! the AOT-compiled graphs, exercising all three trainer-sync methods.
//! Requires `make artifacts` (tiny).

use pulse::coordinator::{train, Method, TaskKind, TrainConfig};
use pulse::optim::AdamConfig;
use pulse::rl::grpo::GrpoConfig;
use pulse::runtime::{artifacts_dir, ModelRuntime};

/// Load the tiny runtime, or skip the test: artifacts may be absent
/// (`make artifacts` not run) or PJRT unavailable (offline build with
/// the stub `xla` crate — see vendor/README.md).
fn rt() -> Option<ModelRuntime> {
    if !artifacts_dir().join("tiny.meta.json").exists() {
        eprintln!("skipping: artifacts missing — run `make artifacts`");
        return None;
    }
    match ModelRuntime::load(&artifacts_dir(), "tiny", &[]) {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("skipping: runtime unavailable: {e:#}");
            None
        }
    }
}

macro_rules! require_rt {
    () => {
        match rt() {
            Some(rt) => rt,
            None => return,
        }
    };
}

#[test]
fn single_trainer_sparsity_and_density() {
    let rt = require_rt!();
    let cfg = TrainConfig {
        steps: 8,
        adam: AdamConfig { warmup_steps: 4, ..Default::default() },
        grpo: GrpoConfig { group: 8, ..Default::default() },
        sparsity_ks: vec![1, 4],
        n_eval: 32,
        ..Default::default()
    };
    let res = train(&rt, &cfg).unwrap();
    assert_eq!(res.steps.len(), 8);
    let mut active_steps = 0;
    for s in &res.steps {
        // dense gradients (paper §G.1) — on steps where the batch has
        // any advantage signal (all-constant-reward groups give exactly
        // zero grads, a real GRPO property)
        if s.grad_density > 0.0 {
            active_steps += 1;
            assert!(s.grad_density > 0.95, "step {} density {}", s.step, s.grad_density);
        }
        // high per-step BF16 sparsity at RL learning rates (paper §3)
        let s1 = s.sparsity.iter().find(|(k, _)| *k == 1).map(|(_, v)| *v).unwrap();
        assert!(s1 > 0.95, "step {} sparsity {}", s.step, s1);
    }
    assert!(active_steps >= 2, "only {} steps had gradient signal", active_steps);
    // warmup dip: sparsity at full LR ≤ sparsity at warmup start (Fig. 16)
    let first = res.steps[0].sparsity[0].1;
    let later = res.steps[5].sparsity[0].1;
    assert!(first >= later - 1e-4, "warmup {} later {}", first, later);
    assert!(res.final_pass_at_1 >= 0.0);
}

#[test]
fn rollout_staleness_keeps_sparsity_high() {
    let rt = require_rt!();
    for s_interval in [1usize, 4] {
        let cfg = TrainConfig {
            steps: 6,
            rollout_interval: s_interval,
            n_eval: 16,
            ..Default::default()
        };
        let res = train(&rt, &cfg).unwrap();
        let mean_s1: f64 = res
            .steps
            .iter()
            .filter_map(|s| s.sparsity.iter().find(|(k, _)| *k == 1).map(|(_, v)| *v))
            .sum::<f64>()
            / res.steps.len() as f64;
        assert!(mean_s1 > 0.95, "S={} sparsity {}", s_interval, mean_s1);
    }
}

#[test]
fn multi_trainer_methods_run_and_account_comm() {
    let rt = require_rt!();
    for method in [Method::Ddp, Method::DiLoCo, Method::PulseLoCo] {
        let cfg = TrainConfig {
            method,
            workers: 2,
            local_steps: 2,
            steps: 4, // 2 rounds
            n_eval: 16,
            adam: AdamConfig::post_training(),
            ..Default::default()
        };
        let res = train(&rt, &cfg).unwrap();
        assert_eq!(res.rounds.len(), 2, "{}", method.name());
        for r in &res.rounds {
            assert_eq!(r.comm.len(), 2);
            match method {
                Method::PulseLoCo => {
                    assert!(
                        r.comm[0].comm_sparsity > 0.5,
                        "pulseloco sparsity {}",
                        r.comm[0].comm_sparsity
                    );
                    assert!(r.comm[0].raw_payload_bytes < r.comm[0].dense_bytes);
                }
                Method::DiLoCo => {
                    assert_eq!(r.comm[0].comm_sparsity, 0.0);
                }
                Method::Ddp => {
                    // H dense payloads per round
                    assert_eq!(
                        r.comm[0].dense_bytes,
                        (rt.manifest.n_params * 4 * 2) as u64
                    );
                }
                _ => {}
            }
        }
    }
}
