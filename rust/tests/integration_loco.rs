//! PULSELoCo vs DiLoCo on a controllable distributed optimization:
//! R workers minimize ||w − target||² with local AdamW; the outer loop
//! must converge for both methods, with PULSELoCo transmitting a small
//! fraction of the dense payload.

use pulse::optim::{AdamConfig, AdamW};
use pulse::pulse::loco::{OuterLoop, OuterMethod};
use pulse::util::rng::Rng;

fn run(method: OuterMethod, rounds: usize, h: usize, lr: f32) -> (OuterLoop, f64, f64) {
    let n = 20_000;
    let r = 4;
    let mut rng = Rng::new(7);
    // targets at LLM-like magnitude so BF16 cells are realistic
    let target: Vec<f32> = (0..n)
        .map(|_| {
            let z = rng.normal();
            let s = if z < 0.0 { 1.48 } else { 0.72 };
            ((-4.47 + s * z).exp() * if rng.f64() < 0.5 { -1.0 } else { 1.0 }) as f32
        })
        .collect();
    let theta0: Vec<f32> = target.iter().map(|&t| t * 0.5).collect(); // start off-target
    let mut outer = OuterLoop::new(method, theta0, r);
    let mut inner: Vec<AdamW> = (0..r)
        .map(|_| {
            AdamW::new(
                n,
                AdamConfig { lr, clip_global_norm: 0.0, warmup_steps: 0, ..Default::default() },
            )
        })
        .collect();
    let mut payload_frac = Vec::new();
    for _ in 0..rounds {
        let mut locals = Vec::with_capacity(r);
        for w in 0..r {
            let mut local = outer.theta.clone();
            for _ in 0..h {
                // noisy quadratic gradient: 2(w - target) + noise
                let grads: Vec<f32> = local
                    .iter()
                    .zip(&target)
                    .map(|(&x, &t)| 2.0 * (x - t) + 0.01 * rng.normal() as f32)
                    .collect();
                inner[w].step(&mut local, &grads);
            }
            locals.push(local);
        }
        let stats = outer.round(&locals).unwrap();
        payload_frac.push(
            stats.iter().map(|s| 1.0 - s.comm_sparsity).sum::<f64>() / stats.len() as f64,
        );
    }
    let dist: f64 = outer
        .theta
        .iter()
        .zip(&target)
        .map(|(&x, &t)| ((x - t) as f64).powi(2))
        .sum::<f64>()
        .sqrt();
    let mean_frac = payload_frac.iter().sum::<f64>() / payload_frac.len() as f64;
    (outer, dist, mean_frac)
}

#[test]
fn both_methods_converge_equally_at_visible_update_scale() {
    // Large inner LR (1e-4): updates are super-cell, the gate passes
    // nearly everything, and PULSELoCo must track DiLoCo closely.
    let lr = 1e-4;
    let (_, d_diloco, _) = run(OuterMethod::DiLoCo, 30, 8, lr);
    let (_, d_ploco, frac) = run(OuterMethod::PulseLoCo, 30, 8, lr);
    assert!(d_diloco < 2.0, "diloco dist {}", d_diloco);
    assert!(
        d_ploco < d_diloco * 2.0 + 0.5,
        "ploco {} vs diloco {}",
        d_ploco,
        d_diloco
    );
    assert!(frac > 0.5, "visible-scale updates should mostly pass: {}", frac);
}

#[test]
fn rl_scale_updates_give_sparse_payloads() {
    // Paper-regime inner LR (2e-6): H=8 pseudo-gradients are sub-cell
    // at most coordinates → high communication sparsity (Table 4).
    let (_, _, frac) = run(OuterMethod::PulseLoCo, 10, 8, 5e-7);
    assert!(frac < 0.35, "mean sent fraction {}", frac);
}

#[test]
fn error_feedback_mass_is_bounded() {
    // Residuals must not grow without bound: the gate releases
    // accumulated mass once it crosses a cell.
    let (outer, _, _) = run(OuterMethod::PulseLoCo, 40, 4, 1e-4);
    for ef in &outer.feedback {
        // residual magnitude stays at sub-cell scale: |e| ≤ ~2 cells of
        // typical weights (median |w|≈0.011 → cell≈9e-5)
        assert!(ef.residual_linf() < 0.02, "residual linf {}", ef.residual_linf());
    }
}
