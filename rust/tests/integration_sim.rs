//! Scale-simulator integration suite: determinism (the replay
//! contract), churn recovery through the real control plane, slow
//! subscribers through the real coalescing path, and the NACK_MISS /
//! store-fallback repair chain — all in virtual time, no sockets.
//!
//! Replay rule (mirrors `PULSE_CHAOS_SEED`): every run here is a pure
//! function of its `SimConfig`, so a red assertion reproduces locally
//! by running the same test — no flake window, no timing dependence.

use std::time::Duration;

use pulse::net::transport::{FaultInjectingTransport, InProcTransport};
use pulse::sim::churn::{ChurnAction, ChurnScript};
use pulse::sim::topo::TopoSpec;
use pulse::sim::{run, run_with_store, SimConfig};

/// A 24-leaf / cap-4 tree (2 relay tiers) publishing 10 small steps.
fn small(seed: u64) -> SimConfig {
    let mut cfg = SimConfig::new(TopoSpec::kary(24, 4), seed);
    cfg.steps = 10;
    cfg.shards_per_step = 2;
    cfg.bytes_per_shard = 512;
    cfg.anchor_bytes = 4096;
    cfg.step_interval = Duration::from_millis(10);
    cfg.horizon = Duration::from_secs(60);
    cfg
}

#[test]
fn same_seed_and_churn_script_replay_bit_identically() {
    let mk = |seed: u64| {
        let mut cfg = small(seed);
        cfg.link = cfg.link.with_loss(10_000); // 1% frame loss
        cfg.churn = ChurnScript::seeded(
            seed,
            6,
            Duration::from_millis(20),
            Duration::from_millis(100),
        );
        cfg
    };
    let a = run(mk(11));
    let b = run(mk(11));
    // Full-report equality, not just the hash: every counter, byte
    // tally, and timestamp must replay.
    assert_eq!(a, b, "same (topology, seed, churn) must be bit-identical");
    assert_eq!(a.trace_hash, b.trace_hash);
    assert!(a.converged, "churny-but-lossy small run must converge: {:?}", a);

    let c = run(mk(12));
    assert_ne!(
        a.trace_hash, c.trace_hash,
        "a different seed must produce a different event trace"
    );
}

#[test]
fn relay_crash_is_swept_replanned_and_survivors_reconverge() {
    let mut cfg = small(3);
    cfg.steps = 20;
    // Tight failure detector so the sweep (not the stall probe) drives
    // recovery: death timeout = 100ms * 3 = 300ms.
    cfg.heartbeat_interval = Duration::from_millis(100);
    cfg.missed_heartbeats = 3;
    cfg.churn = ChurnScript::none()
        .then(Duration::from_millis(50), ChurnAction::CrashRelay { nth: 0 })
        .then(Duration::from_millis(70), ChurnAction::JoinLeaf)
        .then(Duration::from_millis(80), ChurnAction::SlowLeaf { nth: 2, factor: 8 });
    let r = run(cfg);
    assert!(r.converged, "crash + join + slowdown must still converge: {:?}", r);
    assert_eq!(r.crashes, 1);
    assert_eq!(r.joins, 1);
    assert_eq!(r.slowdowns, 1);
    assert_eq!(r.leaves_live, 25, "24 bootstrap leaves + 1 join");
    assert!(r.deaths >= 1, "the sweep must discover the silent relay crash: {:?}", r);
    assert!(
        r.reparents >= 1,
        "the dead relay's subtree must be re-parented by the replan: {:?}",
        r
    );
    // Bootstrap plan + the join + the post-sweep replan.
    assert!(r.replans >= 3, "expected at least 3 plan epochs: {:?}", r);
}

#[test]
fn slow_subscriber_is_coalesced_and_converges_through_the_store() {
    // 6 leaves directly under the root; leaf 0's ingress drops to
    // ~1 Mbit/s against a ~66 Mbit/s stream, with a 2-frame queue.
    let mut cfg = SimConfig::new(TopoSpec::kary(6, 8), 9);
    cfg.steps = 30;
    cfg.shards_per_step = 2;
    cfg.bytes_per_shard = 4096;
    cfg.anchor_bytes = 65536;
    cfg.step_interval = Duration::from_millis(1);
    cfg.queue_depth = 2;
    cfg.churn = ChurnScript::none()
        .then(Duration::from_nanos(1), ChurnAction::SlowLeaf { nth: 0, factor: 1000 });
    let r = run(cfg);
    assert!(r.converged, "the slow leaf must converge via the store: {:?}", r);
    assert_eq!(r.slowdowns, 1);
    assert!(
        r.coalesced + r.frames_superseded > 0,
        "a 2-deep queue against a 1000x-slowed edge must coalesce: {:?}",
        r
    );
    assert!(
        r.slow_paths >= 1,
        "the slow leaf cannot drain the stream in time; the stall probe \
         must hand it to the store: {:?}",
        r
    );
    // The healthy leaves were never coalesced: their cost is one clean
    // copy, so the mean stays well under 2x ideal despite leaf 0.
    assert!(r.bytes_per_leaf < 2 * r.ideal_bytes_per_leaf, "{:?}", r);
}

#[test]
fn unserviceable_store_slot_falls_back_through_nack_miss() {
    // Publish faster than the control round-trip with a 1-step NACK
    // index, so every repair lookup structurally misses its hop cache
    // and escalates to the root's store backstop. Slot (step 1, shard
    // 0) is poisoned there: NACKs for it must fail over to NACK_MISS
    // and send the affected leaves down the slow path.
    let mut cfg = SimConfig::new(TopoSpec::kary(32, 8), 17);
    cfg.steps = 4;
    cfg.shards_per_step = 2;
    cfg.bytes_per_shard = 1024;
    cfg.anchor_bytes = 8192;
    cfg.step_interval = Duration::from_micros(100); // < 200µs link latency
    cfg.index_steps = 1;
    cfg.link = cfg.link.with_loss(250_000); // 25% frame loss
    let store = FaultInjectingTransport::unserviceable(
        InProcTransport::with_window(16, 16),
        1,
        0,
    );
    let r = run_with_store(cfg, Box::new(store));
    assert!(r.converged, "poisoned slot must not block convergence: {:?}", r);
    assert!(r.frames_lost > 0);
    assert!(r.leaf_nacks > 0, "25% loss must trigger NACKs: {:?}", r);
    assert!(
        r.nacks_escalated > 0,
        "1-step hop indexes must escalate leaf NACKs upward: {:?}",
        r
    );
    assert!(
        r.store_repairs > 0,
        "healthy slots must be repaired out of the root's store: {:?}",
        r
    );
    assert!(
        r.nacks_unserviceable > 0,
        "the poisoned slot must be reported unserviceable at the root: {:?}",
        r
    );
    assert!(
        r.nack_misses > 0 && r.slow_paths > 0,
        "NACK_MISS must cascade to leaves and send them to the store: {:?}",
        r
    );
}

#[test]
fn total_blackout_converges_through_the_stall_probe() {
    // 100% loss on every tree edge: no frame ever reaches a relay or a
    // leaf, so the post-publish stall probe must route every leaf
    // through the store fallback.
    let mut cfg = SimConfig::new(TopoSpec::kary(12, 4), 21);
    cfg.steps = 5;
    cfg.step_interval = Duration::from_millis(5);
    cfg.link = cfg.link.with_loss(1_000_000);
    let r = run(cfg);
    assert!(r.converged, "blackout must converge via the store: {:?}", r);
    assert_eq!(r.slow_paths, 12, "every leaf takes exactly one slow path: {:?}", r);
    assert_eq!(r.leaf_nacks, 0, "no marker ever arrives, so nothing to NACK");
    assert!(r.frames_lost > 0);
}

#[test]
fn clean_kilo_leaf_run_pays_exactly_one_copy_per_leaf() {
    let cfg = SimConfig::new(TopoSpec::kary(1_000, 8), 1);
    let r = run(cfg);
    assert!(r.converged, "{:?}", r);
    assert_eq!(r.leaves_live, 1_000);
    assert!(r.depth >= 3, "1k leaves under cap 8 needs multiple relay tiers");
    assert_eq!(
        r.bytes_per_leaf, r.ideal_bytes_per_leaf,
        "lossless run must deliver exactly one clean copy per leaf: {:?}",
        r
    );
    assert_eq!(r.frames_lost, 0);
    assert_eq!(r.leaf_nacks + r.slow_paths + r.coalesced, 0);
}
