//! Observability integration suite (tentpole: flight-recorder tracing
//! + live introspection across the sync plane).
//!
//! * `trace_reconstructs_complete_timelines_over_two_level_tree` — the
//!   CI `obs` step's by-name target: a real root → 2 mid-tier nodes →
//!   4 leaves tree streams a sharded stream, and every published
//!   `(step, shard)` must reconstruct a complete publish → relay stage
//!   → apply timeline from the process-global recorder.
//! * `sim_trace_hash_replays_bit_identically` — the simulator's span
//!   stream is part of its determinism contract: same config + seed →
//!   identical span hash AND identical retained events, and the
//!   incremental fold agrees with [`pulse::obs::trace_hash`] over the
//!   full stream.
//! * `obs_snap_answers_from_every_node_kind` — relay root, mid-tier
//!   relay node, store server, and control plane all answer the same
//!   `OBS_SNAP` frame with their role and live counters.
//!
//! The flight recorder is process-global, so tests that clear or read
//! it serialize on a file-local mutex (separate test binaries are
//! separate processes — no cross-suite interference).

use pulse::net::control::{ControlConfig, ControlPlane};
use pulse::net::node::RelayNode;
use pulse::net::relay::Relay;
use pulse::net::store::{DirectStore, StoreServer};
use pulse::net::transport::{RelayTransport, SyncTransport};
use pulse::obs::{fetch_snapshot, reconstruct, trace_hash, Obs, SpanEvent, Stage, SNAP_WITH_EVENTS};
use pulse::pulse::sync::{Consumer, Publisher, SyncStats};
use pulse::sparse::synthetic_layout;
use pulse::storage::ObjectStore;
use pulse::util::rng::Rng;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Serializes access to the process-global recorder within this suite.
static GATE: Mutex<()> = Mutex::new(());

const N: usize = 16_000;
const SHARDS: usize = 4;

/// Seeded stream of views (views[0] = initial checkpoint).
fn views(n: usize, steps: u64, perturbs: usize) -> Vec<Vec<u16>> {
    let mut rng = Rng::new(91);
    let mut w: Vec<u16> = (0..n).map(|_| rng.next_u32() as u16).collect();
    let mut out = vec![w.clone()];
    for _ in 0..steps {
        for _ in 0..perturbs {
            let i = rng.below(n as u64) as usize;
            w[i] = rng.next_u32() as u16;
        }
        out.push(w.clone());
    }
    out
}

/// Poll until `step` is committed from this consumer's view, then
/// synchronize once.
fn wait_sync<T: SyncTransport>(c: &mut Consumer<T>, step: u64) -> SyncStats {
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        if let Ok(Some(head)) = c.latest_ready() {
            if head >= step {
                return c.synchronize().unwrap();
            }
        }
        assert!(Instant::now() < deadline, "step {} never became ready", step);
        std::thread::sleep(Duration::from_millis(3));
    }
}

fn wait_hop(node: &RelayNode, hop: u32) {
    let deadline = Instant::now() + Duration::from_secs(5);
    while node.hop() != hop {
        assert!(Instant::now() < deadline, "node never learned hop {}", hop);
        std::thread::sleep(Duration::from_millis(3));
    }
}

#[test]
fn trace_reconstructs_complete_timelines_over_two_level_tree() {
    let _g = GATE.lock().unwrap();
    let hub = Obs::global();
    hub.clear();

    let steps = 3u64;
    let vs = views(N, steps, N / 100);
    let layout = synthetic_layout(N, 1024);

    let root = Arc::new(Relay::start().unwrap());
    let node_a = RelayNode::join(root.port).unwrap();
    let node_b = RelayNode::join(root.port).unwrap();
    wait_hop(&node_a, 1);
    wait_hop(&node_b, 1);

    let mut publisher = Publisher::over(
        RelayTransport::publisher(root.clone()),
        layout.clone(),
        vs[0].clone(),
        6,
    )
    .unwrap()
    .with_shards(SHARDS);
    let ports = [node_a.port(), node_b.port(), node_a.port(), node_b.port()];
    let mut leaves: Vec<Consumer<RelayTransport>> = ports
        .iter()
        .map(|&p| Consumer::over(RelayTransport::subscribe(p).unwrap(), layout.clone()))
        .collect();
    for c in leaves.iter_mut() {
        wait_sync(c, 0);
    }
    for (step, view) in vs.iter().enumerate().skip(1) {
        publisher.publish(step as u64, view).unwrap();
        for c in leaves.iter_mut() {
            let cs = wait_sync(c, step as u64);
            assert!(cs.verified);
            assert_eq!(c.weights.as_deref(), Some(view.as_slice()));
        }
    }

    // snapshot before teardown; step 0 is the bootstrap anchor, which
    // by design has no publish span (leaves restore it via catch-up)
    let events: Vec<SpanEvent> = hub
        .recorder
        .snapshot()
        .into_iter()
        .filter(|e| e.step >= 1 && e.step <= steps)
        .collect();
    drop(leaves);
    node_a.stop();
    node_b.stop();
    root.stop();

    let report = reconstruct(&events);
    assert!(report.timelines > 0, "a streamed run must produce timelines");
    assert!(
        report.is_complete(),
        "{} of {} timelines missing an endpoint: {:?}",
        report.incomplete.len(),
        report.timelines,
        report.incomplete
    );
    let row = |s: Stage| report.rows.iter().find(|r| r.stage == s);
    let publish = row(Stage::Publish).expect("publish stage row");
    let staged = row(Stage::RelayStage).expect("relay stage row");
    let apply = row(Stage::Apply).expect("apply stage row");
    // exactly one publish span anchors each timeline at offset zero
    assert_eq!(publish.count, report.timelines);
    assert_eq!(publish.p99_us, 0);
    // every frame staged through at least the mid-tier hop; all four
    // leaves applied every timeline
    assert!(staged.count >= report.timelines, "{} staged", staged.count);
    assert_eq!(apply.count, report.timelines * ports.len());
}

#[test]
fn sim_trace_hash_replays_bit_identically() {
    // the simulator records into its own per-run recorder (not the
    // process-global hub), so no GATE is needed here
    use pulse::sim::topo::TopoSpec;
    use pulse::sim::{run, SimConfig};

    let leaves = 2_000usize;
    let mut cfg = SimConfig::new(TopoSpec::kary(leaves, 8).with_spares(2), 7);
    cfg.steps = 6;
    cfg.step_interval = Duration::from_millis(50);
    cfg.shards_per_step = 4;
    cfg.bytes_per_shard = 2048;
    cfg.anchor_bytes = 16_384;
    // hold the whole span stream so reconstruction sees every event
    cfg.recorder_capacity = leaves * 6 * 8;

    let a = run(cfg.clone());
    let b = run(cfg);
    assert!(
        a.converged,
        "clean sim must converge (head {} at {:?})",
        a.head_step, a.converged_at
    );
    assert_eq!(a.span_hash, b.span_hash, "span hash must replay bit-identically");
    assert_eq!(a.span_events, b.span_events, "retained spans must replay bit-identically");
    assert_eq!(
        a.spans as usize,
        a.span_events.len(),
        "ring must hold the full stream ({} of {})",
        a.span_events.len(),
        a.spans
    );
    // the incremental per-event fold and the batch hash agree
    assert_eq!(trace_hash(&a.span_events), a.span_hash);

    let report = reconstruct(&a.span_events);
    assert!(
        report.is_complete(),
        "{} of {} sim timelines missing an endpoint",
        report.incomplete.len(),
        report.timelines
    );
}

#[test]
fn obs_snap_answers_from_every_node_kind() {
    let _g = GATE.lock().unwrap();

    // relay root + mid-tier relay node
    let root = Arc::new(Relay::start().unwrap());
    let node = RelayNode::join(root.port).unwrap();
    wait_hop(&node, 1);
    let snap = fetch_snapshot(&root.port.to_string(), 0).unwrap();
    assert_eq!(snap.req_str("role").unwrap(), "relay");
    assert!(snap.get("histograms").is_some(), "snapshot carries the hub histograms");
    assert!(
        snap.get("recorder").unwrap().get("events").is_none(),
        "without the events flag the reply carries ring counters only"
    );
    let snap = fetch_snapshot(&format!("127.0.0.1:{}", node.port()), SNAP_WITH_EVENTS).unwrap();
    assert_eq!(snap.req_str("role").unwrap(), "relay");
    assert_eq!(snap.get("counters").unwrap().req_f64("hop").unwrap(), 1.0);
    assert!(
        snap.get("recorder").unwrap().get("events").is_some(),
        "the events flag pulls the recorder ring"
    );
    node.stop();
    root.stop();

    // store server
    let store = ObjectStore::temp("obs_snap_kinds").unwrap();
    let origin = StoreServer::serve(Arc::new(DirectStore::new(store.clone())), None).unwrap();
    let snap = fetch_snapshot(&origin.port().to_string(), 0).unwrap();
    assert_eq!(snap.req_str("role").unwrap(), "store");
    assert!(snap.get("counters").unwrap().get("gets").is_some());
    origin.stop();
    std::fs::remove_dir_all(store.root()).unwrap();

    // control plane
    let root = Arc::new(Relay::start().unwrap());
    let plane = ControlPlane::start(root.port, ControlConfig::default()).unwrap();
    let snap = fetch_snapshot(&plane.port.to_string(), 0).unwrap();
    assert_eq!(snap.req_str("role").unwrap(), "control");
    assert!(snap.get("counters").unwrap().get("epoch").is_some());
    plane.stop();
    root.stop();
}
