//! Chaos suite (tentpole: wire faults + publisher restart + relay
//! crash, together).
//!
//! Acceptance bar (ISSUE 6):
//!
//! * a control-plane relay tree runs under **seeded wire-level
//!   faults** (partial writes, mid-frame resets, payload corruption,
//!   latency, one-way partitions) on every data-plane connection —
//!   root accepts, relay-to-relay attachments, leaf subscriptions;
//! * mid-run the **publisher crashes** and a replacement resumes from
//!   the newest anchor as the next generation, republishing the
//!   abandoned tail;
//! * mid-run a **relay crashes** (silent heartbeats, socket open) and
//!   its subtree re-parents onto a standby;
//! * despite all three, every leaf ends **bit-identical to a clean
//!   object-store reference** fed the same views, with **zero
//!   duplicate applies** across the generation + epoch boundaries
//!   (`from_step == previous to_step`, final sync applies nothing).
//!
//! The seed comes from `PULSE_CHAOS_SEED` (default 1); CI loops the
//! suite over several seeds and prints the failing one, so any red run
//! reproduces locally with a single env var. Damaging faults draw from
//! one shared budget, so the noise is bounded and the final published
//! steps land on a quiet wire.

use pulse::coordinator::planner::Upstream;
use pulse::net::chaos::ChaosConfig;
use pulse::net::control::{
    ControlConfig, ControlPlane, ControlSubscriberTransport, ControlledNode,
};
use pulse::net::relay::{Relay, DEFAULT_QUEUE_DEPTH, INDEX_STEPS};
use pulse::net::transport::{
    FaultInjectingTransport, ObjectStoreTransport, RelayTransport, SyncTransport,
};
use pulse::pulse::sync::{recover_anchor_state, Consumer, Publisher, SyncPath, SyncStats};
use pulse::sparse::synthetic_layout;
use pulse::storage::ObjectStore;
use pulse::util::rng::Rng;
use pulse::util::retry::RetryPolicy;
use std::sync::Arc;
use std::time::{Duration, Instant};

const N: usize = 12_000;
const SHARDS: usize = 4;

/// The run's chaos seed: `PULSE_CHAOS_SEED` or 1. CI sweeps this.
fn chaos_seed() -> u64 {
    std::env::var("PULSE_CHAOS_SEED").ok().and_then(|v| v.parse().ok()).unwrap_or(1)
}

/// Seeded stream of views (views[0] = initial checkpoint).
fn views(n: usize, steps: u64, perturbs: usize) -> Vec<Vec<u16>> {
    let mut rng = Rng::new(137);
    let mut w: Vec<u16> = (0..n).map(|_| rng.next_u32() as u16).collect();
    let mut out = vec![w.clone()];
    for _ in 0..steps {
        for _ in 0..perturbs {
            let i = rng.below(n as u64) as usize;
            w[i] = rng.next_u32() as u16;
        }
        out.push(w.clone());
    }
    out
}

/// Poll until `step` is committed from this consumer's view, then
/// synchronize. Transient errors are the point of this suite — a fetch
/// may die mid-frame, a subscription may be between parents, a NACK
/// budget may drain — so every failure is retried until the deadline.
fn wait_sync<T: pulse::net::transport::SyncTransport>(
    c: &mut Consumer<T>,
    step: u64,
) -> SyncStats {
    let deadline = Instant::now() + Duration::from_secs(60);
    loop {
        assert!(
            Instant::now() < deadline,
            "step {} never synced (seed {})",
            step,
            chaos_seed()
        );
        match c.latest_ready() {
            Ok(Some(head)) if head >= step => match c.synchronize() {
                Ok(cs) if cs.to_step >= step => return cs,
                _ => std::thread::sleep(Duration::from_millis(5)),
            },
            _ => std::thread::sleep(Duration::from_millis(5)),
        }
    }
}

fn wait_until(what: &str, deadline_s: u64, mut cond: impl FnMut() -> bool) {
    let deadline = Instant::now() + Duration::from_secs(deadline_s);
    while !cond() {
        assert!(
            Instant::now() < deadline,
            "timed out waiting for {} (seed {})",
            what,
            chaos_seed()
        );
        std::thread::sleep(Duration::from_millis(5));
    }
}

/// The tentpole run: faulty wires everywhere on the data plane, a
/// publisher crash + generation-bumped resume, and a relay crash +
/// re-parent — one run, all three, bit-identical convergence.
#[test]
fn chaos_tree_survives_faults_restart_and_relay_crash() {
    let seed = chaos_seed();
    const BUDGET: i64 = 60;
    let chaos = ChaosConfig::light(seed).with_budget(BUDGET);
    let hb = Duration::from_millis(50);
    let cfg = ControlConfig {
        fanout_cap: 4,
        min_relay_levels: 1,
        heartbeat_interval: hb,
        missed_heartbeats: 8, // death timeout: 400 ms
        ..Default::default()
    };
    let steps = 8u64;
    let crash_after = 5u64; // publisher dies after publishing step 5
    let anchor_k = 4u64; // anchors at 0, 4, 8 — recovery points
    let vs = views(N, steps, 200);
    let layout = synthetic_layout(N, 64);

    // clean object-store reference lineage, mirrored publish-for-publish
    // (including the crash rewind) — the arbiter for "bit-identical"
    let store = ObjectStore::temp(&format!("chaos_ref_{}", seed)).unwrap();
    let mut ref_pub = Publisher::over(
        ObjectStoreTransport::new(store.clone(), "sync"),
        layout.clone(),
        vs[0].clone(),
        anchor_k,
    )
    .unwrap()
    .with_shards(SHARDS);
    let mut ref_con =
        Consumer::over(ObjectStoreTransport::new(store.clone(), "sync"), layout.clone());

    // chaos root + 3 managed relays (one becomes the crash victim,
    // the spares are failover targets) + 4 leaves. Every data-plane
    // wire — root accepts, upstream attachments, node accepts — draws
    // damaging faults from ONE shared budget, so the noise is bounded.
    let root = Arc::new(
        Relay::start_with_chaos(DEFAULT_QUEUE_DEPTH, INDEX_STEPS, Some(chaos.clone()))
            .unwrap(),
    );
    let mut publisher = Publisher::over(
        RelayTransport::publisher(root.clone()),
        layout.clone(),
        vs[0].clone(),
        anchor_k,
    )
    .unwrap()
    .with_shards(SHARDS);
    let plane = ControlPlane::start(root.port, cfg).unwrap();
    let nodes: Vec<ControlledNode> = (0..3)
        .map(|_| {
            ControlledNode::join_with_chaos(
                plane.port,
                DEFAULT_QUEUE_DEPTH,
                INDEX_STEPS,
                hb,
                Some(chaos.clone()),
            )
            .unwrap()
        })
        .collect();
    let mut leaves: Vec<Consumer<ControlSubscriberTransport>> = (0..4)
        .map(|_| {
            Consumer::over(
                ControlSubscriberTransport::join_with_heartbeat(plane.port, hb).unwrap(),
                layout.clone(),
            )
        })
        .collect();
    wait_until("membership to settle", 20, || plane.live_peers() == (3, 4));

    for step in 1..=crash_after {
        publisher.publish(step, &vs[step as usize]).unwrap();
        ref_pub.publish(step, &vs[step as usize]).unwrap();
    }
    // every leaf verified at the pre-crash head, through the faulty
    // wires; later syncs must continue exactly at to_step
    let mut prev_to = vec![0u64; leaves.len()];
    for (i, leaf) in leaves.iter_mut().enumerate() {
        let cs = wait_sync(leaf, crash_after);
        assert!(cs.verified, "leaf {} unverified pre-crash (seed {})", i, seed);
        assert_eq!(
            leaf.weights.as_ref().unwrap(),
            &vs[crash_after as usize],
            "leaf {} diverged pre-crash (seed {})",
            i,
            seed
        );
        prev_to[i] = cs.to_step;
    }

    // ---- publisher crash. The replacement recovers from the newest
    // anchor of the clean lineage (step 4: the dead publisher's step-5
    // tail is abandoned) and BOTH lineages resume as generation 1,
    // re-committing the anchor under the new tag.
    drop(publisher);
    let (w_rec, step_rec, gen_rec) =
        recover_anchor_state(&ObjectStoreTransport::new(store.clone(), "sync")).unwrap();
    assert_eq!((step_rec, gen_rec), (4, 0), "newest anchor before the crash");
    let mut publisher = Publisher::resume(
        RelayTransport::publisher(root.clone()),
        layout.clone(),
        w_rec.clone(),
        step_rec,
        gen_rec + 1,
        anchor_k,
    )
    .unwrap()
    .with_shards(SHARDS);
    let mut ref_pub = Publisher::resume(
        ObjectStoreTransport::new(store.clone(), "sync"),
        layout.clone(),
        w_rec,
        step_rec,
        gen_rec + 1,
        anchor_k,
    )
    .unwrap()
    .with_shards(SHARDS);

    // ---- relay crash, while the publisher restart is still fresh:
    // kill the relay parenting leaf 0 (silent heartbeats, socket open
    // — only the timeout can discover it)
    let plan = plane.plan().unwrap();
    let leaf_ids: Vec<u64> =
        leaves.iter().map(|l| l.transport.peer_id().unwrap()).collect();
    let parent_of = |leaf_id: u64| match plan.assignment_of(leaf_id).unwrap().upstream {
        Upstream::Peer(id) => id,
        other => panic!("leaf {} not under a relay: {:?}", leaf_id, other),
    };
    let victim_id = parent_of(leaf_ids[0]);
    let victim =
        nodes.iter().find(|n| n.peer_id() == Some(victim_id)).expect("victim node");
    let deaths_before = plane.deaths();
    victim.fail_silently();
    wait_until("failure detection", 10, || plane.deaths() > deaths_before);

    // the stream never stops: the resumed generation republishes the
    // abandoned tail and carries on to the final head (step 8 is an
    // anchor — a clean recovery point past every injected fault)
    for step in step_rec + 1..=steps {
        publisher.publish(step, &vs[step as usize]).unwrap();
        ref_pub.publish(step, &vs[step as usize]).unwrap();
    }
    let ref_stats = ref_con.synchronize().unwrap();
    assert!(ref_stats.verified);
    assert_eq!(
        ref_stats.generation, 1,
        "reference consumer must adopt the restarted lineage"
    );

    for (i, leaf) in leaves.iter_mut().enumerate() {
        let cs = wait_sync(leaf, steps);
        assert!(cs.verified, "leaf {} unverified at the end (seed {})", i, seed);
        assert_eq!(
            cs.from_step, prev_to[i],
            "leaf {} must continue exactly where it stopped — no duplicate applies \
             across the generation/epoch boundary (seed {})",
            i, seed
        );
        assert_eq!(
            leaf.weights.as_ref().unwrap(),
            ref_con.weights.as_ref().unwrap(),
            "leaf {} not bit-identical to the clean reference (seed {})",
            i,
            seed
        );
        // idempotence at the boundary: nothing left to apply
        let again = leaf.synchronize().unwrap();
        assert_eq!(again.path, SyncPath::UpToDate);
        assert_eq!(again.patches_applied, 0);
    }

    // vacuity guard: the run must actually have drawn damaging faults
    // (a broken wrap that silently hands out clean wires would make
    // everything above meaningless)
    let left = chaos.budget_remaining().unwrap();
    assert!(
        left < BUDGET,
        "no damaging fault was ever injected (seed {}, budget {}/{})",
        seed,
        left,
        BUDGET
    );

    drop(leaves);
    for n in &nodes {
        n.stop();
    }
    plane.stop();
    root.stop();
    std::fs::remove_dir_all(store.root()).ok();
}

/// Generation tags traverse the relay staging end-to-end: a subscriber
/// that anchors AFTER a publisher restart adopts the new generation
/// from the relayed `g<n>;`-tagged anchor marker.
#[test]
fn restart_generation_is_adopted_through_the_relay() {
    let steps = 4u64;
    let vs = views(N, steps, 150);
    let layout = synthetic_layout(N, 64);
    let root = Arc::new(Relay::start().unwrap());

    let mut publisher = Publisher::over(
        RelayTransport::publisher(root.clone()),
        layout.clone(),
        vs[0].clone(),
        2, // anchors at 0, 2, 4
    )
    .unwrap()
    .with_shards(SHARDS);
    let mut early =
        Consumer::over(RelayTransport::subscribe(root.port).unwrap(), layout.clone());
    for step in 1..=2 {
        publisher.publish(step, &vs[step as usize]).unwrap();
    }
    let cs = wait_sync(&mut early, 2);
    assert_eq!(cs.generation, 0, "pre-restart lineage is untagged");

    // crash + resume from the step-2 state as generation 1
    drop(publisher);
    let mut publisher = Publisher::resume(
        RelayTransport::publisher(root.clone()),
        layout.clone(),
        vs[2].clone(),
        2,
        1,
        2,
    )
    .unwrap()
    .with_shards(SHARDS);
    for step in 3..=steps {
        publisher.publish(step, &vs[step as usize]).unwrap();
    }

    // a fresh subscriber's catch-up preload replays the g1-tagged
    // anchor; its first sync lands on the new lineage
    let mut late =
        Consumer::over(RelayTransport::subscribe(root.port).unwrap(), layout.clone());
    let cs = wait_sync(&mut late, steps);
    assert!(cs.verified);
    assert_eq!(cs.generation, 1, "late subscriber must adopt the restarted lineage");
    assert_eq!(late.weights.as_ref().unwrap(), &vs[steps as usize]);
    // the early subscriber chains across the boundary (identical
    // republished content) with zero duplicate applies
    let cs = wait_sync(&mut early, steps);
    assert_eq!(cs.from_step, 2);
    assert_eq!(early.weights.as_ref().unwrap(), &vs[steps as usize]);

    root.stop();
}

/// The unified retry policy is live end-to-end: a repair NACK that can
/// never be answered re-sends on backoff boundaries (`retries`), then
/// exhausts its budget (`gave_up`) — the same synchronize call heals
/// via the slow path, and both tallies surface through `SyncStats`.
#[test]
fn nack_retry_counters_surface_in_sync_stats() {
    let steps = 3u64;
    let vs = views(N, steps, 150);
    let layout = synthetic_layout(N, 64);
    // a one-step frame index, so step 2's slots are long evicted by
    // the time the repair NACK arrives
    let root = Arc::new(Relay::start_with_opts(DEFAULT_QUEUE_DEPTH, 1).unwrap());
    // mute upstream: the escalation is "accepted" and never answered —
    // no retransmit, no NACK_MISS. To the leaf this is a one-way
    // partition towards the publisher: only its retry budget ends it.
    root.set_escalation(|_, _| true);

    let mut sub = RelayTransport::subscribe(root.port).unwrap();
    // tight budget: resends at ~20/40/40/... ms, dry inside 200 ms
    sub.set_nack_policy(RetryPolicy::new(
        Duration::from_millis(20),
        2.0,
        Duration::from_millis(40),
        Duration::from_millis(200),
    ))
    .unwrap();
    let decorated = FaultInjectingTransport::targeting(sub, 2, 0);
    let mut consumer = Consumer::over(decorated, layout.clone());

    let mut publisher = Publisher::over(
        RelayTransport::publisher(root.clone()),
        layout.clone(),
        vs[0].clone(),
        100, // anchor 0 only: recovery must re-chain through staging
    )
    .unwrap()
    .with_shards(SHARDS);
    publisher.publish(1, &vs[1]).unwrap();
    let cs = wait_sync(&mut consumer, 1);
    assert!(cs.verified);
    assert_eq!((cs.retries, cs.gave_up), (0, 0), "healthy fabric needs no retries");

    for step in 2..=steps {
        publisher.publish(step, &vs[step as usize]).unwrap();
    }
    // chain path: (2, 0) corrupts on first serve → the repair NACK
    // escalates into the mute upstream → re-sends on every backoff
    // boundary → the budget drains → the chain attempt dies, and the
    // SAME call degrades to the slow path, whose staged re-serve of
    // step 2 is clean
    let cs = wait_sync(&mut consumer, steps);
    assert_eq!(cs.path, SyncPath::Slow, "recovery must ride the anchor slow path");
    assert!(cs.verified);
    assert_eq!(consumer.weights.as_ref().unwrap(), &vs[steps as usize]);
    let counters = consumer.transport.counters();
    assert!(
        counters.retries >= 1,
        "the doomed NACK must re-send on backoff boundaries: {:?}",
        counters
    );
    assert_eq!(counters.gave_up, 1, "the retry budget must drain exactly once");
    assert_eq!(
        (cs.retries, cs.gave_up),
        (counters.retries, 1),
        "SyncStats must mirror the transport counters"
    );
    assert_eq!(consumer.transport.injected(), 1, "exactly one corrupted serve");

    root.stop();
}
