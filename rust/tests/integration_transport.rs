//! Transport conformance suite: the SAME PULSESync stream (seeded,
//! deterministic) runs over every `SyncTransport` backend —
//! object-store, in-proc, TCP relay (star AND chained through a
//! `RelayNode`), the networked store plane (`RemoteStoreTransport`
//! direct and behind caching hops), and fault-injected wrappers — and
//! must end bit-identical to the object-store reference:
//!
//! * bit-identity per step and at the end of the stream;
//! * chain catch-up and cold-start slow path on every backend;
//! * single-shard corruption healed by exactly one counted refetch on
//!   every backend (on the relay this is a real NACK retransmit; on
//!   the chained relay the retransmit is served from the *node's*
//!   staging without touching the root);
//! * the poll-then-sync pattern costs one inventory scan, not two;
//! * a zero-fault `FaultInjectingTransport` is transparent.

use pulse::net::chaos::ChaosConfig;
use pulse::net::node::RelayNode;
use pulse::net::relay::Relay;
use pulse::net::store::{caching_hop, DirectStore, RemoteStoreTransport, StoreServer};
use pulse::net::transport::{
    FaultInjectingTransport, FaultPlan, InProcTransport, ObjectStoreTransport, RelayTransport,
    SyncTransport,
};
use pulse::pulse::sync::{Consumer, Publisher, SyncPath, SyncStats};
use pulse::sparse::synthetic_layout;
use pulse::storage::retention::RetentionPolicy;
use pulse::storage::ObjectStore;
use pulse::util::rng::Rng;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

const N: usize = 24_000;
const SHARDS: usize = 4;
const STEPS: u64 = 6;

/// The canonical stream: views[0] is the initial checkpoint, views[t]
/// the view at step t. Seeded, so every backend sees identical data.
fn views(n: usize, steps: u64, perturbs: usize) -> Vec<Vec<u16>> {
    let mut rng = Rng::new(77);
    let mut w: Vec<u16> = (0..n).map(|_| rng.next_u32() as u16).collect();
    let mut out = vec![w.clone()];
    for _ in 0..steps {
        for _ in 0..perturbs {
            let i = rng.below(n as u64) as usize;
            w[i] = rng.next_u32() as u16;
        }
        out.push(w.clone());
    }
    out
}

/// Poll until `step` is committed from this consumer's view, then
/// synchronize once (exercising the cached-inventory single-scan
/// path). Asynchronous backends (relay) need the poll; synchronous
/// ones pass on the first iteration.
fn wait_sync<T: SyncTransport>(c: &mut Consumer<T>, step: u64) -> SyncStats {
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        if let Ok(Some(head)) = c.latest_ready() {
            if head >= step {
                return c.synchronize().unwrap();
            }
        }
        assert!(Instant::now() < deadline, "step {} never became ready", step);
        std::thread::sleep(Duration::from_millis(3));
    }
}

/// Drive the canonical stream over (producer, consumer) transports:
/// publish each step, synchronize, assert per-step bit-identity.
/// Returns (final weights, total shard refetches).
fn run_stream<P: SyncTransport, C: SyncTransport>(
    prod: P,
    cons: C,
    anchor_interval: u64,
) -> (Vec<u16>, usize) {
    let layout = synthetic_layout(N, 64);
    let vs = views(N, STEPS, 400);
    let mut publisher = Publisher::over(prod, layout.clone(), vs[0].clone(), anchor_interval)
        .unwrap()
        .with_shards(SHARDS);
    let mut consumer = Consumer::over(cons, layout);
    let s0 = wait_sync(&mut consumer, 0);
    assert_eq!(s0.path, SyncPath::Slow, "cold start is the slow path");
    assert_eq!(consumer.weights.as_ref().unwrap(), &vs[0]);
    let mut refetches = 0usize;
    for step in 1..=STEPS {
        publisher.publish(step, &vs[step as usize]).unwrap();
        let cs = wait_sync(&mut consumer, step);
        refetches += cs.shard_refetches;
        assert!(cs.verified, "step {} unverified", step);
        assert_eq!(
            consumer.weights.as_ref().unwrap(),
            &vs[step as usize],
            "bit-identity broken at step {}",
            step
        );
    }
    assert_eq!(consumer.weights.as_ref().unwrap(), publisher.current_weights());
    (consumer.weights.clone().unwrap(), refetches)
}

/// The object-store run IS the pre-refactor path (same key scheme,
/// same objects); it doubles as the cross-backend reference.
fn object_store_reference() -> Vec<u16> {
    let store = ObjectStore::temp("conf_ref").unwrap();
    let (w, refetches) = run_stream(
        ObjectStoreTransport::new(store.clone(), "sync"),
        ObjectStoreTransport::new(store.clone(), "sync"),
        3,
    );
    assert_eq!(refetches, 0);
    std::fs::remove_dir_all(store.root()).unwrap();
    w
}

#[test]
fn all_backends_bit_identical_to_object_store_reference() {
    let reference = object_store_reference();

    // in-proc: producer and consumer share one staging window
    let fabric = InProcTransport::new();
    let (w_inproc, r) = run_stream(fabric.clone(), fabric.clone(), 3);
    assert_eq!(r, 0);
    assert_eq!(w_inproc, reference, "in-proc diverged from object store");

    // relay: real sockets, staging receiver, markers over the wire
    let relay = Arc::new(Relay::start().unwrap());
    let prod = RelayTransport::publisher(relay.clone());
    let cons = RelayTransport::subscribe(relay.port).unwrap();
    let (w_relay, r) = run_stream(prod, cons, 3);
    assert_eq!(r, 0);
    assert_eq!(w_relay, reference, "relay diverged from object store");
    relay.stop();

    // fault-injected (zero-fault plan): byte-for-byte transparent
    let inner = InProcTransport::new();
    let cons = FaultInjectingTransport::new(inner.clone(), 99, FaultPlan::default());
    let (w_fault, r) = run_stream(inner, cons, 3);
    assert_eq!(r, 0);
    assert_eq!(w_fault, reference, "fault decorator must be transparent at prob 0");

    // chained relay: the consumer subscribes to a RelayNode one hop
    // below the root — same subscribe API, one more staging hop
    let root = Arc::new(Relay::start().unwrap());
    let node = RelayNode::join(root.port).unwrap();
    let prod = RelayTransport::publisher(root.clone());
    let cons = RelayTransport::subscribe(node.port()).unwrap();
    let (w_chain, r) = run_stream(prod, cons, 3);
    assert_eq!(r, 0);
    assert_eq!(w_chain, reference, "chained relay diverged from object store");
    node.stop();
    root.stop();
}

/// Cold-start slow path + multi-step chain catch-up, on one backend.
fn chain_and_slow<P: SyncTransport, C: SyncTransport>(prod: P, cons: C) {
    let layout = synthetic_layout(N, 64);
    let vs = views(N, STEPS, 400);
    let mut publisher =
        Publisher::over(prod, layout.clone(), vs[0].clone(), 50).unwrap().with_shards(SHARDS);
    publisher.publish(1, &vs[1]).unwrap();
    publisher.publish(2, &vs[2]).unwrap();
    // cold start two steps in: anchor 0 + chain of sharded deltas
    let mut consumer = Consumer::over(cons, layout);
    let cs = wait_sync(&mut consumer, 2);
    assert_eq!(cs.path, SyncPath::Slow);
    assert_eq!(cs.anchors_restored, 1);
    assert_eq!(cs.patches_applied, 2);
    assert_eq!(consumer.weights.as_ref().unwrap(), &vs[2]);
    // fall three steps behind: chain path, no anchor
    for step in 3..=5u64 {
        publisher.publish(step, &vs[step as usize]).unwrap();
    }
    let cs = wait_sync(&mut consumer, 5);
    assert_eq!(cs.path, SyncPath::Chain);
    assert_eq!(cs.patches_applied, 3);
    assert_eq!(cs.anchors_restored, 0);
    assert_eq!(consumer.weights.as_ref().unwrap(), &vs[5]);
}

#[test]
fn chain_and_slow_paths_on_every_backend() {
    let store = ObjectStore::temp("conf_chain").unwrap();
    chain_and_slow(
        ObjectStoreTransport::new(store.clone(), "sync"),
        ObjectStoreTransport::new(store.clone(), "sync"),
    );
    std::fs::remove_dir_all(store.root()).unwrap();

    let fabric = InProcTransport::new();
    chain_and_slow(fabric.clone(), fabric);

    let relay = Arc::new(Relay::start().unwrap());
    let prod = RelayTransport::publisher(relay.clone());
    let cons = RelayTransport::subscribe(relay.port).unwrap();
    chain_and_slow(prod, cons);
    relay.stop();

    let root = Arc::new(Relay::start().unwrap());
    let node = RelayNode::join(root.port).unwrap();
    let prod = RelayTransport::publisher(root.clone());
    let cons = RelayTransport::subscribe(node.port()).unwrap();
    chain_and_slow(prod, cons);
    node.stop();
    root.stop();

    let inner = InProcTransport::new();
    let cons = FaultInjectingTransport::new(inner.clone(), 5, FaultPlan::default());
    chain_and_slow(inner, cons);
}

/// Corrupt exactly (step 2, shard 1) on the consumer side of `base`;
/// the stream must stay bit-identical with exactly one counted
/// refetch (acceptance: §J.5 recovery on every backend).
fn corruption_heals<P: SyncTransport, C: SyncTransport>(prod: P, cons: C) {
    let decorated = FaultInjectingTransport::targeting(cons, 2, 1);
    let (w, refetches) = run_stream(prod, decorated, 50);
    let vs = views(N, STEPS, 400);
    assert_eq!(w, vs[STEPS as usize]);
    assert_eq!(refetches, 1, "single corruption must heal with exactly one refetch");
}

#[test]
fn single_shard_corruption_heals_on_every_backend() {
    let store = ObjectStore::temp("conf_corrupt").unwrap();
    corruption_heals(
        ObjectStoreTransport::new(store.clone(), "sync"),
        ObjectStoreTransport::new(store.clone(), "sync"),
    );
    std::fs::remove_dir_all(store.root()).unwrap();

    let fabric = InProcTransport::new();
    corruption_heals(fabric.clone(), fabric);
}

#[test]
fn single_shard_corruption_heals_over_relay_via_nack() {
    // on the relay the repair seam is a real NACK: the relay must
    // retransmit exactly the corrupted shard to exactly this subscriber
    let relay = Arc::new(Relay::start().unwrap());
    let prod = RelayTransport::publisher(relay.clone());
    let cons = RelayTransport::subscribe(relay.port).unwrap();
    let decorated = FaultInjectingTransport::targeting(cons, 2, 1);
    let (w, refetches) = run_stream(prod, decorated, 50);
    let vs = views(N, STEPS, 400);
    assert_eq!(w, vs[STEPS as usize]);
    assert_eq!(refetches, 1);
    assert_eq!(relay.nacks_serviced(), 1, "the heal must be a relay retransmit");
    relay.stop();
}

#[test]
fn single_shard_corruption_at_leaf_heals_from_node_staging() {
    // chained topology: corruption at a LEAF consumer must heal with
    // exactly one refetch served from the mid-tree node's frame index
    // — the root never sees the NACK (acceptance: recursive fault
    // handling, repair locality)
    let root = Arc::new(Relay::start().unwrap());
    let node = RelayNode::join(root.port).unwrap();
    let prod = RelayTransport::publisher(root.clone());
    let cons = RelayTransport::subscribe(node.port()).unwrap();
    let decorated = FaultInjectingTransport::targeting(cons, 2, 1);
    let (w, refetches) = run_stream(prod, decorated, 50);
    let vs = views(N, STEPS, 400);
    assert_eq!(w, vs[STEPS as usize]);
    assert_eq!(refetches, 1, "single corruption must heal with exactly one refetch");
    assert_eq!(
        node.relay().nacks_serviced(),
        1,
        "the heal must be a retransmit from the node's own staging"
    );
    assert_eq!(node.relay().nacks_escalated(), 0);
    assert_eq!(root.nacks_serviced(), 0, "the NACK must never reach the root");
    node.stop();
    root.stop();
}

#[test]
fn dropped_shard_heals_with_one_refetch() {
    // a lost frame (fetch error) takes the same repair seam as
    // corruption: one counted refetch, bit-identity preserved
    let fabric = InProcTransport::new();
    let cons = FaultInjectingTransport::new(
        fabric.clone(),
        11,
        FaultPlan { drop_shard_prob: 1.0, ..FaultPlan::default() },
    );
    let (w, refetches) = run_stream(fabric, cons, 50);
    let vs = views(N, STEPS, 400);
    assert_eq!(w, vs[STEPS as usize]);
    // every shard of every delta step dropped once: S refetches per step
    assert_eq!(refetches, STEPS as usize * SHARDS);
}

#[test]
fn delayed_markers_only_defer_visibility() {
    // "reordering": the head marker is hidden from one poll; the next
    // poll sees it, and nothing else changes
    let fabric = InProcTransport::new();
    let cons = FaultInjectingTransport::new(
        fabric.clone(),
        13,
        FaultPlan { delay_marker_prob: 1.0, ..FaultPlan::default() },
    );
    let (w, refetches) = run_stream(fabric, cons, 3);
    let vs = views(N, STEPS, 400);
    assert_eq!(w, vs[STEPS as usize]);
    assert_eq!(refetches, 0);
}

/// Publish + sync the small stream over a fresh in-proc fabric with
/// the given consumer-side transport; returns the final weights.
fn small_leg<C: SyncTransport>(
    fabric: InProcTransport,
    cons: C,
    layout: &[pulse::sparse::TensorShape],
    vs: &[Vec<u16>],
) -> Vec<u16> {
    let mut publisher = Publisher::over(fabric, layout.to_vec(), vs[0].clone(), 2)
        .unwrap()
        .with_shards(3);
    let mut c = Consumer::over(cons, layout.to_vec());
    for (step, view) in vs.iter().enumerate().skip(1) {
        publisher.publish(step as u64, view).unwrap();
        let cs = c.synchronize().unwrap();
        assert!(cs.verified);
        assert_eq!(cs.shard_refetches, 0);
        assert_eq!(c.weights.as_ref().unwrap(), view, "step {}", step);
    }
    c.weights.clone().unwrap()
}

#[test]
fn fault_free_decorator_is_transparent_property() {
    // property (satellite): corruption probability 0 ⇒ the decorated
    // run is bit-identical to the undecorated one, for any seed
    let layout = synthetic_layout(6_000, 64);
    let vs = views(6_000, 4, 120);
    pulse::util::prop::check("fault prob 0 == inner", 5, |g| {
        let seed = g.rng.next_u64();
        let plain_fabric = InProcTransport::new();
        let plain = small_leg(plain_fabric.clone(), plain_fabric, &layout, &vs);
        let fab = InProcTransport::new();
        let decorated_cons =
            FaultInjectingTransport::new(fab.clone(), seed, FaultPlan::default());
        let decorated = small_leg(fab, decorated_cons, &layout, &vs);
        assert_eq!(plain, decorated, "decorated and plain runs diverged (seed {})", seed);
        assert_eq!(plain, vs[vs.len() - 1]);
    });
}

#[test]
fn any_single_shard_corruption_heals_once_property() {
    // property (satellite): for ANY (step, shard) target, the stream
    // heals with exactly one shard_refetches increment
    let n = 8_000usize;
    let layout = synthetic_layout(n, 64);
    let steps = 4u64;
    let vs = views(n, steps, 150);
    pulse::util::prop::check("single corruption heals once", 8, |g| {
        let step = 1 + g.rng.below(steps);
        let shard = g.rng.below(4) as u32;
        let fabric = InProcTransport::new();
        let mut publisher = Publisher::over(fabric.clone(), layout.clone(), vs[0].clone(), 50)
            .unwrap()
            .with_shards(4);
        let mut c =
            Consumer::over(FaultInjectingTransport::targeting(fabric, step, shard), layout.clone());
        c.synchronize().unwrap();
        let mut refetches = 0usize;
        for s in 1..=steps {
            publisher.publish(s, &vs[s as usize]).unwrap();
            let cs = c.synchronize().unwrap();
            refetches += cs.shard_refetches;
            assert!(cs.verified);
            assert_eq!(c.weights.as_ref().unwrap(), &vs[s as usize]);
        }
        assert_eq!(
            refetches, 1,
            "target ({}, {}) must heal with exactly one refetch",
            step, shard
        );
    });
}

#[test]
fn poll_then_sync_costs_one_scan_on_object_store() {
    // regression (satellite): Consumer::latest_ready + synchronize
    // used to run retention::scan twice; the cached inventory makes
    // the pair cost exactly one ObjectStore list pass
    let store = ObjectStore::temp("conf_scans").unwrap();
    let layout = synthetic_layout(4_000, 64);
    let vs = views(4_000, 2, 60);
    let mut publisher = Publisher::over(
        ObjectStoreTransport::new(store.clone(), "sync"),
        layout.clone(),
        vs[0].clone(),
        50,
    )
    .unwrap();
    let consumer_transport = ObjectStoreTransport::new(store.clone(), "sync");
    let handle = consumer_transport.clone(); // clones share counters
    let mut c = Consumer::over(consumer_transport, layout);
    c.synchronize().unwrap(); // cold start: one scan
    assert_eq!(handle.counters().inventory_scans, 1);
    for step in 1..=2u64 {
        publisher.publish(step, &vs[step as usize]).unwrap();
        let before = handle.counters().inventory_scans;
        assert_eq!(c.latest_ready().unwrap(), Some(step));
        let cs = c.synchronize().unwrap();
        assert!(cs.verified);
        assert_eq!(
            handle.counters().inventory_scans,
            before + 1,
            "poll + sync must cost exactly one scan"
        );
    }
    std::fs::remove_dir_all(store.root()).unwrap();
}

// ------------------------------------------------------ remote store

/// An origin [`StoreServer`] over a fresh temp [`ObjectStore`]; the
/// caller stops the server and removes `store.root()`.
fn origin_server(label: &str) -> (StoreServer, ObjectStore) {
    let store = ObjectStore::temp(label).unwrap();
    let server = StoreServer::serve(Arc::new(DirectStore::new(store.clone())), None).unwrap();
    (server, store)
}

#[test]
fn remote_store_direct_and_cached_bit_identical_to_reference() {
    let reference = object_store_reference();

    // direct: producer and consumer both speak the store wire to the
    // origin — the networked sibling of the object-store run
    let (origin, store) = origin_server("conf_rs_direct");
    let prod = RemoteStoreTransport::connect(origin.port(), "sync");
    let cons = RemoteStoreTransport::connect(origin.port(), "sync");
    let (w, r) = run_stream(prod, cons, 3);
    assert_eq!(r, 0);
    assert_eq!(w, reference, "remote store diverged from object store");
    origin.stop();
    std::fs::remove_dir_all(store.root()).unwrap();

    // behind one caching hop: same stream, consumer one hop out
    let (origin, store) = origin_server("conf_rs_hop");
    let (hop, cache) = caching_hop(origin.port(), RetentionPolicy::default(), None).unwrap();
    let prod = RemoteStoreTransport::connect(origin.port(), "sync");
    let cons = RemoteStoreTransport::connect(hop.port(), "sync");
    let (w, r) = run_stream(prod, cons, 3);
    assert_eq!(r, 0);
    assert_eq!(w, reference, "cached remote store diverged from object store");
    assert!(cache.counters.origin_fetches.load(Ordering::Relaxed) > 0);
    hop.stop();
    origin.stop();
    std::fs::remove_dir_all(store.root()).unwrap();
}

#[test]
fn single_shard_corruption_heals_on_remote_store() {
    // direct to the origin
    let (origin, store) = origin_server("conf_rs_corrupt");
    corruption_heals(
        RemoteStoreTransport::connect(origin.port(), "sync"),
        RemoteStoreTransport::connect(origin.port(), "sync"),
    );
    origin.stop();
    std::fs::remove_dir_all(store.root()).unwrap();

    // behind a caching hop: the refetch is served from the hop's
    // cached (intact) copy — corruption at the leaf never re-reads
    // the origin's object a second time
    let (origin, store) = origin_server("conf_rs_corrupt_hop");
    let (hop, _cache) = caching_hop(origin.port(), RetentionPolicy::default(), None).unwrap();
    corruption_heals(
        RemoteStoreTransport::connect(origin.port(), "sync"),
        RemoteStoreTransport::connect(hop.port(), "sync"),
    );
    hop.stop();
    origin.stop();
    std::fs::remove_dir_all(store.root()).unwrap();
}

#[test]
fn any_single_shard_corruption_heals_once_on_remote_store_property() {
    // property (satellite): FaultInjectingTransport<RemoteStoreTransport>
    // — for ANY (step, shard) corruption target the stream heals with
    // exactly one counted refetch, same as every local backend
    let n = 8_000usize;
    let layout = synthetic_layout(n, 64);
    let steps = 4u64;
    let vs = views(n, steps, 150);
    let (origin, store) = origin_server("conf_rs_prop");
    pulse::util::prop::check("remote store single corruption heals once", 6, |g| {
        let step = 1 + g.rng.below(steps);
        let shard = g.rng.below(4) as u32;
        // a fresh prefix per case keeps the streams isolated
        let prefix = format!("sync_{}_{}", step, shard);
        let mut publisher = Publisher::over(
            RemoteStoreTransport::connect(origin.port(), &prefix),
            layout.clone(),
            vs[0].clone(),
            50,
        )
        .unwrap()
        .with_shards(4);
        let cons = RemoteStoreTransport::connect(origin.port(), &prefix);
        let mut c = Consumer::over(
            FaultInjectingTransport::targeting(cons, step, shard),
            layout.clone(),
        );
        c.synchronize().unwrap();
        let mut refetches = 0usize;
        for s in 1..=steps {
            publisher.publish(s, &vs[s as usize]).unwrap();
            let cs = c.synchronize().unwrap();
            refetches += cs.shard_refetches;
            assert!(cs.verified);
            assert_eq!(c.weights.as_ref().unwrap(), &vs[s as usize]);
        }
        assert_eq!(
            refetches, 1,
            "target ({}, {}) must heal with exactly one refetch",
            step, shard
        );
    });
    origin.stop();
    std::fs::remove_dir_all(store.root()).unwrap();
}

#[test]
fn dropped_shards_heal_over_remote_store() {
    // seeded drop plan over the store wire: every shard of every delta
    // dropped once at the consumer, healed by counted refetches
    let (origin, store) = origin_server("conf_rs_drop");
    let prod = RemoteStoreTransport::connect(origin.port(), "sync");
    let cons = FaultInjectingTransport::new(
        RemoteStoreTransport::connect(origin.port(), "sync"),
        11,
        FaultPlan { drop_shard_prob: 1.0, ..FaultPlan::default() },
    );
    let (w, refetches) = run_stream(prod, cons, 50);
    let vs = views(N, STEPS, 400);
    assert_eq!(w, vs[STEPS as usize]);
    assert_eq!(refetches, STEPS as usize * SHARDS);
    origin.stop();
    std::fs::remove_dir_all(store.root()).unwrap();
}

#[test]
fn cold_tree_syncs_bit_identical_with_bounded_origin_egress() {
    // acceptance: a 2-level tree of 6 cold leaves behind two caching
    // hops ends bit-identical to the object-store reference while the
    // origin serves each data object at most once per hop (O(depth)
    // origin reads, not O(leaves))
    let reference = object_store_reference();
    let (origin, store) = origin_server("conf_rs_tree");
    let layout = synthetic_layout(N, 64);
    let vs = views(N, STEPS, 400);

    // publish the whole stream up front — every leaf starts cold
    let mut publisher = Publisher::over(
        RemoteStoreTransport::connect(origin.port(), "sync"),
        layout.clone(),
        vs[0].clone(),
        50,
    )
    .unwrap()
    .with_shards(SHARDS);
    for step in 1..=STEPS {
        publisher.publish(step, &vs[step as usize]).unwrap();
    }

    let (hop_a, _ca) = caching_hop(origin.port(), RetentionPolicy::default(), None).unwrap();
    let (hop_b, _cb) = caching_hop(origin.port(), RetentionPolicy::default(), None).unwrap();

    // leaves sync SEQUENTIALLY (the store plane has no single-flight
    // dedup — see net::store docs), alternating between the two hops
    let mut leaf_origin_fetches = Vec::new();
    for i in 0..6u64 {
        let port = if i % 2 == 0 { hop_a.port() } else { hop_b.port() };
        let mut c = Consumer::over(RemoteStoreTransport::connect(port, "sync"), layout.clone());
        let s = wait_sync(&mut c, STEPS);
        assert_eq!(s.path, SyncPath::Slow, "leaf {} must cold-start", i);
        assert!(s.verified);
        assert_eq!(c.weights.as_ref().unwrap(), &reference, "leaf {} diverged", i);
        leaf_origin_fetches.push(s.origin_fetches);
        if i >= 2 {
            // both hops are warm: later leaves ride the cache entirely
            assert_eq!(s.origin_fetches, 0, "leaf {} should be all cache hits", i);
            assert!(s.cache_hits > 0, "leaf {} must report its cache hits", i);
        }
    }

    // the egress bound: no data object left the origin more than once
    // per hop, regardless of leaf count
    let stats = origin.stats();
    assert!(stats.gets.load(Ordering::Relaxed) > 0);
    assert!(
        stats.max_body_serves(".bin") <= 2,
        "origin served a data object more than once per hop (max {})",
        stats.max_body_serves(".bin")
    );
    // only the first leaf behind each hop pulled from the origin
    assert!(leaf_origin_fetches[0] > 0 && leaf_origin_fetches[1] > 0);
    assert_eq!(leaf_origin_fetches[2..].iter().sum::<u64>(), 0);

    hop_a.stop();
    hop_b.stop();
    origin.stop();
    std::fs::remove_dir_all(store.root()).unwrap();
}

#[test]
fn cached_tree_survives_chaotic_store_wire() {
    // chaos leg (CI sweeps PULSE_CHAOS_SEED over this test; any red
    // run reproduces with the same seed): a cached tree where BOTH
    // store wires — hop→origin and leaf→hop — run under a budgeted
    // chaos mix; client retries must absorb every fault and the
    // leaves must end bit-identical
    let seed: u64 =
        std::env::var("PULSE_CHAOS_SEED").ok().and_then(|v| v.parse().ok()).unwrap_or(1);
    let chaos = ChaosConfig::light(seed).with_budget(48);
    let n = 8_000usize;
    let steps = 4u64;
    let layout = synthetic_layout(n, 64);
    let vs = views(n, steps, 150);
    let (origin, store) = origin_server("conf_rs_chaos");
    let mut publisher = Publisher::over(
        RemoteStoreTransport::connect(origin.port(), "sync"),
        layout.clone(),
        vs[0].clone(),
        2,
    )
    .unwrap()
    .with_shards(4);
    for step in 1..=steps {
        publisher.publish(step, &vs[step as usize]).unwrap();
    }
    let (hop, _cache) = caching_hop(origin.port(), RetentionPolicy::default(), Some(chaos)).unwrap();
    for leaf in 0..2 {
        let mut c = Consumer::over(RemoteStoreTransport::connect(hop.port(), "sync"), layout.clone());
        let s = wait_sync(&mut c, steps);
        assert!(s.verified, "leaf {} unverified under chaos seed {}", leaf, seed);
        assert_eq!(
            c.weights.as_ref().unwrap(),
            &vs[steps as usize],
            "leaf {} diverged under chaos seed {}",
            leaf,
            seed
        );
    }
    hop.stop();
    origin.stop();
    std::fs::remove_dir_all(store.root()).unwrap();
}

#[test]
fn poll_then_sync_costs_one_list_on_remote_store() {
    // regression (satellite): retention::scan used to re-list the full
    // prefix on every call; on the remote path the transport now lists
    // once and parses the snapshot (`retention::parse_inventory`), so
    // poll + sync is exactly one LIST rpc at the server
    let (origin, store) = origin_server("conf_rs_scans");
    let layout = synthetic_layout(4_000, 64);
    let vs = views(4_000, 2, 60);
    let mut publisher = Publisher::over(
        RemoteStoreTransport::connect(origin.port(), "sync"),
        layout.clone(),
        vs[0].clone(),
        50,
    )
    .unwrap();
    let mut c = Consumer::over(RemoteStoreTransport::connect(origin.port(), "sync"), layout);
    c.synchronize().unwrap();
    let stats = origin.stats();
    for step in 1..=2u64 {
        publisher.publish(step, &vs[step as usize]).unwrap();
        let scans_before = c.transport.counters().inventory_scans;
        let lists_before = stats.lists.load(Ordering::Relaxed);
        assert_eq!(c.latest_ready().unwrap(), Some(step));
        let cs = c.synchronize().unwrap();
        assert!(cs.verified);
        assert_eq!(
            c.transport.counters().inventory_scans,
            scans_before + 1,
            "poll + sync must cost exactly one scan on the remote path"
        );
        assert_eq!(
            stats.lists.load(Ordering::Relaxed),
            lists_before + 1,
            "poll + sync must cost exactly one LIST rpc at the server"
        );
    }
    origin.stop();
    std::fs::remove_dir_all(store.root()).unwrap();
}
