//! Integration: the Rust runtime loads every tiny-size artifact,
//! executes it on the PJRT CPU client, and reproduces the numeric
//! oracle that `python/compile/aot.py` recorded with in-process jax.
//!
//! Requires `make artifacts` (tiny size) to have run.

use pulse::runtime::{artifacts_dir, ModelRuntime};

/// Load the tiny runtime, or skip the test: artifacts may be absent
/// (`make artifacts` not run) or PJRT unavailable (offline build with
/// the stub `xla` crate — see vendor/README.md).
fn runtime() -> Option<ModelRuntime> {
    let dir = artifacts_dir();
    if !dir.join("tiny.meta.json").exists() {
        eprintln!("skipping: artifacts missing — run `make artifacts` ({})", dir.display());
        return None;
    }
    match ModelRuntime::load(&dir, "tiny", &[]) {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("skipping: runtime unavailable: {e:#}");
            None
        }
    }
}

macro_rules! require_runtime {
    () => {
        match runtime() {
            Some(rt) => rt,
            None => return,
        }
    };
}

fn oracle_tokens(rt: &ModelRuntime) -> Vec<i32> {
    let d = &rt.manifest.dims;
    (0..d.batch * d.seq).map(|i| (i % d.vocab) as i32).collect()
}

#[test]
fn score_matches_python_oracle() {
    let rt = require_runtime!();
    let flat = rt.load_init(&artifacts_dir()).unwrap();
    let tokens = oracle_tokens(&rt);
    let (lp, ent) = rt.score(&flat, &tokens).unwrap();
    let oracle = rt.manifest.oracle.clone().expect("tiny manifest has an oracle");
    let sum: f64 = lp.iter().map(|&x| x as f64).sum();
    let rel = (sum - oracle.logprob_sum).abs() / oracle.logprob_sum.abs().max(1.0);
    assert!(rel < 2e-3, "logprob_sum {} vs oracle {}", sum, oracle.logprob_sum);
    for (i, &want) in oracle.logprob_first8.iter().enumerate() {
        let got = lp[i] as f64;
        assert!(
            (got - want).abs() < 5e-3 * want.abs().max(1.0),
            "lp[{}] {} vs {}",
            i,
            got,
            want
        );
    }
    let ent_mean: f64 = ent.iter().map(|&x| x as f64).sum::<f64>() / ent.len() as f64;
    assert!(
        (ent_mean - oracle.entropy_mean).abs() < 5e-3 * oracle.entropy_mean.max(1.0),
        "entropy {} vs {}",
        ent_mean,
        oracle.entropy_mean
    );
}

#[test]
fn rollout_generates_and_is_greedy_deterministic() {
    let rt = require_runtime!();
    let flat = rt.load_init(&artifacts_dir()).unwrap();
    let d = rt.manifest.dims.clone();
    let prompts: Vec<i32> =
        (0..d.batch * d.prompt_len).map(|i| (i % d.vocab) as i32).collect();
    let a = rt.rollout(&flat, &prompts, [1, 2], 0.0).unwrap();
    let b = rt.rollout(&flat, &prompts, [9, 9], 0.0).unwrap();
    assert_eq!(a.tokens, b.tokens, "greedy must ignore the PRNG key");
    // prompt preserved
    for row in 0..d.batch {
        for p in 0..d.prompt_len {
            assert_eq!(a.tokens[row * d.seq + p], prompts[row * d.prompt_len + p]);
        }
    }
    // sampling differs across keys
    let c = rt.rollout(&flat, &prompts, [1, 2], 1.0).unwrap();
    let e = rt.rollout(&flat, &prompts, [9, 9], 1.0).unwrap();
    assert_ne!(c.tokens, e.tokens, "sampling must use the key");
    // behaviour logprobs consistent with score() (bf16 fusion tolerance)
    let (lp, _) = rt.score(&flat, &c.tokens).unwrap();
    for i in 0..lp.len() {
        assert!(
            (lp[i] - c.logprobs[i]).abs() < 2e-2,
            "lp[{}] {} vs rollout {}",
            i,
            lp[i],
            c.logprobs[i]
        );
    }
}

#[test]
fn grad_zero_advantage_is_zero() {
    let rt = require_runtime!();
    let flat = rt.load_init(&artifacts_dir()).unwrap();
    let d = rt.manifest.dims.clone();
    let tokens = oracle_tokens(&rt);
    let (old_lp, _) = rt.score(&flat, &tokens).unwrap();
    let adv = vec![0.0f32; d.batch];
    let mask = vec![1.0f32; d.batch * d.gen_len];
    let out = rt.grad(&flat, &tokens, &adv, &old_lp, &mask).unwrap();
    assert!(out.loss.abs() < 1e-7);
    let max = out.grads.iter().fold(0.0f32, |m, &g| m.max(g.abs()));
    assert!(max < 1e-7, "max grad {}", max);
}

#[test]
fn grad_is_dense_and_descends() {
    let rt = require_runtime!();
    let mut flat = rt.load_init(&artifacts_dir()).unwrap();
    let d = rt.manifest.dims.clone();
    let prompts: Vec<i32> =
        (0..d.batch * d.prompt_len).map(|i| (i % d.vocab) as i32).collect();
    let ro = rt.rollout(&flat, &prompts, [3, 4], 1.0).unwrap();
    // synthetic advantages: +1 for even rows, -1 for odd
    let adv: Vec<f32> =
        (0..d.batch).map(|b| if b % 2 == 0 { 1.0 } else { -1.0 }).collect();
    let mask = vec![1.0f32; d.batch * d.gen_len];
    let out = rt.grad(&flat, &ro.tokens, &adv, &ro.logprobs, &mask).unwrap();
    assert!(out.grad_density > 0.98, "grad density {}", out.grad_density);
    // take a large step along -grad: surrogate loss must decrease
    let g2 = out.grads.clone();
    for (p, g) in flat.iter_mut().zip(&g2) {
        *p -= 1.0 * g;
    }
    let out2 = rt.grad(&flat, &ro.tokens, &adv, &ro.logprobs, &mask).unwrap();
    assert!(out2.loss < out.loss, "loss {} -> {}", out.loss, out2.loss);
}

#[test]
fn aot_gate_kernel_matches_native_gate() {
    let rt = require_runtime!();
    let n = rt.manifest.n_params;
    let mut rng = pulse::util::rng::Rng::new(5);
    let theta: Vec<f32> = (0..n).map(|_| rng.normal() as f32 * 0.02).collect();
    let s: Vec<f32> = (0..n).map(|_| rng.normal() as f32 * 1e-4).collect();
    let mask = rt.gate(&theta, &s).unwrap();
    let native = pulse::gate::gate_bf16(&theta, &s);
    let from_kernel: Vec<u64> = mask
        .iter()
        .enumerate()
        .filter(|(_, &m)| m != 0)
        .map(|(i, _)| i as u64)
        .collect();
    assert_eq!(from_kernel, native, "AOT gate and native gate disagree");
}

#[test]
fn aot_adam_kernel_matches_native_adamw() {
    let rt = require_runtime!();
    let n = rt.manifest.n_params;
    let mut rng = pulse::util::rng::Rng::new(6);
    let p0: Vec<f32> = (0..n).map(|_| rng.normal() as f32 * 0.02).collect();
    let g: Vec<f32> = (0..n).map(|_| rng.normal() as f32 * 0.1).collect();
    let cfg = pulse::optim::AdamConfig {
        clip_global_norm: 0.0,
        warmup_steps: 0,
        ..Default::default()
    };
    // native
    let mut opt = pulse::optim::AdamW::new(n, cfg);
    let mut p_native = p0.clone();
    opt.step(&mut p_native, &g);
    // AOT kernel (t = 1)
    let bc1 = 1.0 - cfg.beta1;
    let bc2 = 1.0 - cfg.beta2;
    let (p_kernel, m_kernel, _v) = rt
        .adam([cfg.lr, bc1, bc2], &p0, &vec![0.0; n], &vec![0.0; n], &g)
        .unwrap();
    for i in 0..n {
        assert!(
            (p_native[i] - p_kernel[i]).abs() <= 1e-10 + p_native[i].abs() * 1e-4,
            "i={} native {} kernel {}",
            i,
            p_native[i],
            p_kernel[i]
        );
    }
    // FMA/fusion differences between XLA and the native loop: a few ULPs.
    assert!((m_kernel[0] - opt.m[0]).abs() <= 1e-9 + opt.m[0].abs() * 1e-5);
}
