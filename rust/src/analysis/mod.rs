//! Closed-form analysis pieces of the paper: Adam update bounds
//! (Table 1, Fig. 9), the BF16 absorption geometry (Fig. 3), the
//! utilization model (Fig. 1 — see also [`crate::net`]), and the
//! lower-precision projection (Table 6, §D).

pub mod lint;

use crate::bf16::Dtype;

/// Adam moments simulator for the adversarial-ρ experiment (Fig. 9):
/// feeds an arbitrary gradient sequence through Adam's EMAs and records
/// ρ_t = |m̂_t| / √v̂_t.
pub struct RhoTrace {
    pub beta1: f64,
    pub beta2: f64,
    m: f64,
    v: f64,
    t: u64,
}

impl RhoTrace {
    pub fn new(beta1: f64, beta2: f64) -> RhoTrace {
        RhoTrace { beta1, beta2, m: 0.0, v: 0.0, t: 0 }
    }

    /// Push one gradient; returns ρ_t.
    pub fn push(&mut self, g: f64) -> f64 {
        self.t += 1;
        self.m = self.beta1 * self.m + (1.0 - self.beta1) * g;
        self.v = self.beta2 * self.v + (1.0 - self.beta2) * g * g;
        let mhat = self.m / (1.0 - self.beta1.powi(self.t as i32));
        let vhat = self.v / (1.0 - self.beta2.powi(self.t as i32));
        if vhat <= 0.0 {
            0.0
        } else {
            mhat.abs() / vhat.sqrt()
        }
    }
}

/// The paper's adversarial sequence (§A.4): `quiet` near-zero gradients
/// followed by `loud` constant gradients of magnitude 1. Returns the
/// ρ trace over the loud phase.
pub fn adversarial_rho(beta1: f64, beta2: f64, quiet: usize, loud: usize) -> Vec<f64> {
    let mut tr = RhoTrace::new(beta1, beta2);
    for _ in 0..quiet {
        tr.push(1e-20);
    }
    (0..loud).map(|_| tr.push(1.0)).collect()
}

/// Critical weight magnitude |w|_crit = η/τ_D (paper Eq. 20): weights
/// above this scale absorb an effective-bound (≈η) one-step update.
pub fn critical_weight(eta: f64, dtype: Dtype) -> f64 {
    eta / dtype.tau()
}

/// Worst-case critical scale 256·η·√((1−β1)/(1−β2)) (Cor. A.5, BF16).
pub fn critical_weight_worstcase(eta: f64, beta1: f64, beta2: f64) -> f64 {
    256.0 * eta * ((1.0 - beta1) / (1.0 - beta2)).sqrt()
}

/// Weight-magnitude statistics over a parameter vector (Table 2).
#[derive(Debug, Clone, Default)]
pub struct WeightStats {
    pub median: f64,
    pub mean: f64,
    pub p5: f64,
    pub p95: f64,
    /// Fraction with |w| > crit.
    pub frac_above_crit: f64,
    pub crit: f64,
}

pub fn weight_stats(weights: &[f32], crit: f64) -> WeightStats {
    let mags: Vec<f64> = weights.iter().map(|&w| w.abs() as f64).collect();
    let above = mags.iter().filter(|&&m| m > crit).count();
    WeightStats {
        median: crate::util::percentile(&mags, 50.0),
        mean: crate::util::mean(&mags),
        p5: crate::util::percentile(&mags, 5.0),
        p95: crate::util::percentile(&mags, 95.0),
        frac_above_crit: above as f64 / mags.len().max(1) as f64,
        crit,
    }
}

/// Table 6 row: projected absorption threshold and sparsity floor for a
/// receiver format, against a measured weight-magnitude distribution.
#[derive(Debug, Clone)]
pub struct LowPrecisionRow {
    pub dtype: Dtype,
    pub mantissa_bits: u32,
    pub tau: f64,
    pub crit: f64,
    pub frac_above: f64,
}

pub fn lower_precision_projection(weights: &[f32], eta: f64) -> Vec<LowPrecisionRow> {
    [Dtype::Bf16, Dtype::Fp8E4M3, Dtype::Mxfp4]
        .iter()
        .map(|&d| {
            let crit = critical_weight(eta, d);
            let above = weights.iter().filter(|w| (w.abs() as f64) >= crit).count();
            LowPrecisionRow {
                dtype: d,
                mantissa_bits: d.mantissa_bits(),
                tau: d.tau(),
                crit,
                frac_above: above as f64 / weights.len().max(1) as f64,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn adversarial_peak_matches_paper() {
        // Paper Fig. 9: (0.9, 0.999), 1e5 quiet steps → ρ peaks ≈ 6.57
        // after 12 loud gradients, then decays toward 1.
        let trace = adversarial_rho(0.9, 0.999, 100_000, 3000);
        let (argmax, max) = trace
            .iter()
            .enumerate()
            .fold((0, 0.0), |(ai, am), (i, &x)| if x > am { (i, x) } else { (ai, am) });
        assert!((max - 6.57).abs() < 0.1, "peak {}", max);
        assert_eq!(argmax + 1, 12, "peak at loud step {}", argmax + 1);
        // decays back toward 1 (v's half-life at β2=0.999 is ~700 steps)
        assert!(trace[2999] < 1.1, "rho after decay {}", trace[2999]);
        // and never exceeds the Thm A.4 bound of 10
        assert!(trace.iter().all(|&x| x <= 10.0));
    }

    #[test]
    fn constant_gradients_rho_is_one() {
        let mut tr = RhoTrace::new(0.9, 0.999);
        let mut last = 0.0;
        for _ in 0..2000 {
            last = tr.push(0.5);
        }
        assert!((last - 1.0).abs() < 1e-3, "rho {}", last);
    }

    #[test]
    fn critical_scales_match_paper() {
        // Eq. 16/20 at η = 3e-6.
        assert!((critical_weight(3e-6, Dtype::Bf16) - 7.68e-4).abs() < 1e-6);
        assert!((critical_weight(3e-6, Dtype::Fp8E4M3) - 4.8e-5).abs() < 1e-7);
        assert!((critical_weight(3e-6, Dtype::Mxfp4) - 1.2e-5).abs() < 1e-8);
        // Cor. A.5: PyTorch defaults → 2560·η
        let wc = critical_weight_worstcase(3e-6, 0.9, 0.999);
        assert!((wc / 3e-6 - 2560.0).abs() < 1.0);
    }

    #[test]
    fn projection_is_monotone_in_precision() {
        let mut rng = crate::util::rng::Rng::new(3);
        let w: Vec<f32> = (0..50_000)
            .map(|_| {
                let z = rng.normal();
                let sigma = if z < 0.0 { 1.48 } else { 0.72 };
                ((-4.47 + sigma * z).exp()) as f32
            })
            .collect();
        let rows = lower_precision_projection(&w, 3e-6);
        // coarser formats → smaller crit → more weights above
        assert!(rows[0].frac_above < rows[1].frac_above);
        assert!(rows[1].frac_above < rows[2].frac_above);
        assert!(rows[0].frac_above > 0.9);
    }

    #[test]
    fn weight_stats_sane() {
        let w = vec![0.01f32; 99].into_iter().chain([1.0f32]).collect::<Vec<_>>();
        let s = weight_stats(&w, 7.7e-4);
        assert!((s.median - 0.01).abs() < 1e-9);
        assert_eq!(s.frac_above_crit, 1.0);
    }
}
