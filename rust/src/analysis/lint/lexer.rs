//! Source lexer for the repo lint (`paper lint`). No external parser
//! crates exist in `vendor/`, so this is a self-contained scanner: it
//! strips comments and string/char literals (so rule patterns never
//! match inside them), collects `pallas-lint:` pragmas from comments,
//! records string literals separately (the counter↔CSV rule reads the
//! column-name literals), and tracks just enough scope structure —
//! `#[cfg(test)]` / `#[test]` / `mod tests` regions, enclosing `fn` /
//! `impl` / `struct` / `mod` names — for the rules to tell test code
//! from wire-path code.
//!
//! This is a lexer, not a parser: it understands tokens and brace
//! nesting, not grammar. The known blind spots (attributes split by
//! stray semicolons, generic `impl<T> Foo<T>` headers resolving to the
//! first trailing token) do not occur in this codebase and are
//! acceptable for a repo-internal lint.

/// One source line after comment/string stripping, with the scope
/// state the rules key on.
#[derive(Debug, Clone)]
pub struct Line {
    /// 1-based line number.
    pub number: usize,
    /// Line text with comments removed and string/char literal
    /// *contents* blanked (the delimiters remain, so code shape holds).
    pub code: String,
    /// True when any part of the line sits inside a `#[cfg(test)]`
    /// item, a `#[test]` fn, or a `mod tests` block.
    pub in_test: bool,
    /// Innermost enclosing function name, if any.
    pub fn_name: Option<String>,
    /// Innermost enclosing `impl` target name, if any.
    pub impl_name: Option<String>,
    /// Innermost enclosing `struct`/`enum` name, if any.
    pub struct_name: Option<String>,
    /// Innermost enclosing `mod` name, if any.
    pub mod_name: Option<String>,
}

/// A `// pallas-lint: allow(<rule>): <reason>` pragma, or a malformed
/// attempt at one (surfaced as its own finding — suppressions must be
/// machine-readable or they are not suppressions).
#[derive(Debug, Clone)]
pub struct Pragma {
    pub line: usize,
    pub rule: String,
    pub reason: String,
    /// Parse error, if the pragma text did not match the grammar.
    pub malformed: Option<String>,
}

/// A string literal's content, with the scope it appeared in.
#[derive(Debug, Clone)]
pub struct StrLit {
    pub line: usize,
    pub text: String,
    pub fn_name: Option<String>,
    pub impl_name: Option<String>,
}

/// Lexed view of one source file.
#[derive(Debug, Default)]
pub struct FileScan {
    pub lines: Vec<Line>,
    pub pragmas: Vec<Pragma>,
    pub strings: Vec<StrLit>,
}

/// What kind of block a `{` opened.
#[derive(Debug, Clone, PartialEq)]
enum ScopeKind {
    Mod(String),
    Fn(String),
    Impl(String),
    Struct(String),
    Block,
}

#[derive(Debug, Clone)]
struct Scope {
    kind: ScopeKind,
    is_test: bool,
}

/// Lex one file. `scan` never fails: unterminated constructs degrade
/// to "rest of file is literal/comment", which is also what rustc's
/// recovery does before erroring.
pub fn scan(source: &str) -> FileScan {
    let stripped = strip(source);
    let lines = scope(&stripped.code);
    let strings = stripped
        .strings
        .into_iter()
        .map(|mut s| {
            if let Some(l) = lines.get(s.line.saturating_sub(1)) {
                s.fn_name = l.fn_name.clone();
                s.impl_name = l.impl_name.clone();
            }
            s
        })
        .collect();
    FileScan { lines, pragmas: stripped.pragmas, strings }
}

struct Stripped {
    /// Per line: code with comments/literal contents removed.
    code: Vec<String>,
    /// Raw string-literal contents, per line of appearance.
    strings: Vec<StrLit>,
    pragmas: Vec<Pragma>,
}

/// Pass 1 (char level): remove comments, blank literal contents,
/// collect comment pragmas and string literals.
fn strip(source: &str) -> Stripped {
    let chars: Vec<char> = source.chars().collect();
    let mut code_lines: Vec<String> = Vec::new();
    let mut strings: Vec<StrLit> = Vec::new();
    let mut pragmas: Vec<Pragma> = Vec::new();
    let mut line = String::new();
    let mut lineno = 1usize;
    let mut i = 0usize;

    let mut flush_line = |line: &mut String, lineno: &mut usize| {
        code_lines.push(std::mem::take(line));
        *lineno += 1;
    };

    while i < chars.len() {
        let c = chars[i];
        if c == '\n' {
            flush_line(&mut line, &mut lineno);
            i += 1;
        } else if c == '/' && chars.get(i + 1) == Some(&'/') {
            // line comment: capture text (for pragmas), drop from code
            let start = i + 2;
            let mut j = start;
            while j < chars.len() && chars[j] != '\n' {
                j += 1;
            }
            let text: String = chars[start..j].iter().collect();
            if let Some(p) = parse_pragma(&text, lineno) {
                pragmas.push(p);
            }
            i = j;
        } else if c == '/' && chars.get(i + 1) == Some(&'*') {
            // block comment — Rust block comments nest
            let mut depth = 1;
            let mut j = i + 2;
            while j < chars.len() && depth > 0 {
                if chars[j] == '\n' {
                    flush_line(&mut line, &mut lineno);
                    j += 1;
                } else if chars[j] == '/' && chars.get(j + 1) == Some(&'*') {
                    depth += 1;
                    j += 2;
                } else if chars[j] == '*' && chars.get(j + 1) == Some(&'/') {
                    depth -= 1;
                    j += 2;
                } else {
                    j += 1;
                }
            }
            line.push(' ');
            i = j;
        } else if is_raw_str_start(&chars, i) {
            // r"...", r#"..."#, b-prefixed variants
            let mut j = i;
            while chars.get(j) == Some(&'b') || chars.get(j) == Some(&'r') {
                j += 1;
            }
            let mut hashes = 0;
            while chars.get(j) == Some(&'#') {
                hashes += 1;
                j += 1;
            }
            j += 1; // opening quote
            let start_line = lineno;
            let mut text = String::new();
            while j < chars.len() {
                if chars[j] == '"' && closes_raw(&chars, j + 1, hashes) {
                    j += 1 + hashes;
                    break;
                }
                if chars[j] == '\n' {
                    flush_line(&mut line, &mut lineno);
                } else {
                    text.push(chars[j]);
                }
                j += 1;
            }
            line.push_str("\"\"");
            strings.push(StrLit { line: start_line, text, fn_name: None, impl_name: None });
            i = j;
        } else if c == '"' {
            let start_line = lineno;
            let mut text = String::new();
            let mut j = i + 1;
            while j < chars.len() {
                match chars[j] {
                    '\\' => {
                        if let Some(&e) = chars.get(j + 1) {
                            text.push('\\');
                            text.push(e);
                        }
                        j += 2;
                    }
                    '"' => {
                        j += 1;
                        break;
                    }
                    '\n' => {
                        flush_line(&mut line, &mut lineno);
                        j += 1;
                    }
                    other => {
                        text.push(other);
                        j += 1;
                    }
                }
            }
            line.push_str("\"\"");
            strings.push(StrLit { line: start_line, text, fn_name: None, impl_name: None });
            i = j;
        } else if c == '\'' {
            // char literal vs lifetime
            if chars.get(i + 1) == Some(&'\\') {
                // escaped char literal: skip to closing quote
                let mut j = i + 2;
                if j < chars.len() {
                    j += 1; // the escaped char
                }
                while j < chars.len() && chars[j] != '\'' {
                    j += 1;
                }
                line.push_str("''");
                i = j + 1;
            } else if chars.get(i + 2) == Some(&'\'') && chars.get(i + 1) != Some(&'\'') {
                // plain char literal 'x'
                line.push_str("''");
                i += 3;
            } else {
                // lifetime — keep as code
                line.push(c);
                i += 1;
            }
        } else {
            line.push(c);
            i += 1;
        }
    }
    code_lines.push(line);
    Stripped { code: code_lines, strings, pragmas }
}

fn is_raw_str_start(chars: &[char], i: usize) -> bool {
    // r"..." / r#"..." / br"..." / brand new identifiers like `for r in`
    // must NOT match: require the char before `i` to not be part of an
    // identifier.
    if i > 0 && (chars[i - 1].is_alphanumeric() || chars[i - 1] == '_') {
        return false;
    }
    let mut j = i;
    if chars.get(j) == Some(&'b') {
        j += 1;
    }
    if chars.get(j) != Some(&'r') {
        return false;
    }
    j += 1;
    while chars.get(j) == Some(&'#') {
        j += 1;
    }
    chars.get(j) == Some(&'"')
}

fn closes_raw(chars: &[char], mut j: usize, hashes: usize) -> bool {
    for _ in 0..hashes {
        if chars.get(j) != Some(&'#') {
            return false;
        }
        j += 1;
    }
    true
}

/// Parse a pragma out of one comment's text. A pragma comment must
/// *begin* with `pallas-lint:` (after whitespace) — prose that merely
/// mentions the marker mid-sentence is not a suppression. Returns
/// None when the comment is not a pragma at all.
fn parse_pragma(comment: &str, line: usize) -> Option<Pragma> {
    let rest = comment.trim_start().strip_prefix("pallas-lint:")?.trim();
    let bad = |msg: &str| Pragma {
        line,
        rule: String::new(),
        reason: String::new(),
        malformed: Some(msg.to_string()),
    };
    let Some(body) = rest.strip_prefix("allow(") else {
        return Some(bad("expected `allow(<rule>): <reason>` after `pallas-lint:`"));
    };
    let Some(close) = body.find(')') else {
        return Some(bad("unclosed `allow(` in pragma"));
    };
    let rule = body[..close].trim().to_string();
    let tail = body[close + 1..].trim_start();
    let Some(reason) = tail.strip_prefix(':') else {
        return Some(bad("pragma is missing the `: <reason>` clause"));
    };
    let reason = reason.trim().to_string();
    if reason.is_empty() {
        return Some(bad("pragma reason must not be empty"));
    }
    if rule.is_empty() {
        return Some(bad("pragma rule name must not be empty"));
    }
    Some(Pragma { line, rule, reason, malformed: None })
}

/// Pass 2 (line level over stripped code): brace-depth scope tracking.
fn scope(code_lines: &[String]) -> Vec<Line> {
    let mut scopes: Vec<Scope> = Vec::new();
    // decl text accumulated since the last `{`, `}`, or `;` — what a
    // `{` is classified from.
    let mut decl = String::new();
    let mut out: Vec<Line> = Vec::new();

    for (idx, code) in code_lines.iter().enumerate() {
        // merge the scope state across the whole line, so a one-line
        // `fn f() { ... }` still reports its fn name and a line that
        // opens `mod tests {` already counts as test code
        let mut state = snapshot(&scopes);
        for c in code.chars() {
            match c {
                '{' => {
                    let (kind, own_test) = classify(&decl);
                    let inherited = scopes.last().map(|s| s.is_test).unwrap_or(false);
                    scopes.push(Scope { kind, is_test: own_test || inherited });
                    merge(&mut state, snapshot(&scopes));
                    decl.clear();
                }
                '}' => {
                    scopes.pop();
                    decl.clear();
                }
                ';' => decl.clear(),
                other => decl.push(other),
            }
        }
        merge(&mut state, snapshot(&scopes));
        out.push(Line {
            number: idx + 1,
            code: code.clone(),
            in_test: state.0,
            fn_name: state.1,
            impl_name: state.2,
            struct_name: state.3,
            mod_name: state.4,
        });
    }
    out
}

fn merge(into: &mut ScopeSnapshot, other: ScopeSnapshot) {
    into.0 |= other.0;
    if into.1.is_none() {
        into.1 = other.1;
    }
    if into.2.is_none() {
        into.2 = other.2;
    }
    if into.3.is_none() {
        into.3 = other.3;
    }
    if into.4.is_none() {
        into.4 = other.4;
    }
}

type ScopeSnapshot =
    (bool, Option<String>, Option<String>, Option<String>, Option<String>);

fn snapshot(scopes: &[Scope]) -> ScopeSnapshot {
    let in_test = scopes.iter().any(|s| s.is_test);
    let mut fn_name = None;
    let mut impl_name = None;
    let mut struct_name = None;
    let mut mod_name = None;
    for s in scopes.iter().rev() {
        match &s.kind {
            ScopeKind::Fn(n) if fn_name.is_none() => fn_name = Some(n.clone()),
            ScopeKind::Impl(n) if impl_name.is_none() => impl_name = Some(n.clone()),
            ScopeKind::Struct(n) if struct_name.is_none() => struct_name = Some(n.clone()),
            ScopeKind::Mod(n) if mod_name.is_none() => mod_name = Some(n.clone()),
            _ => {}
        }
    }
    (in_test, fn_name, impl_name, struct_name, mod_name)
}

/// Classify the block a `{` opens from the declaration text before it.
/// Returns the scope kind and whether the decl itself marks test code.
fn classify(decl: &str) -> (ScopeKind, bool) {
    let is_test = decl.contains("#[cfg(test)]") || decl.contains("#[test]");
    let tokens: Vec<&str> = decl
        .split(|c: char| !(c.is_alphanumeric() || c == '_'))
        .filter(|t| !t.is_empty())
        .collect();
    let after = |kw: &str| {
        tokens
            .iter()
            .position(|t| *t == kw)
            .and_then(|p| tokens.get(p + 1))
            .map(|t| t.to_string())
            .unwrap_or_default()
    };
    // `fn` first: a fn signature may carry `impl Trait` in return
    // position, but an `impl` header never contains the token `fn`.
    let kind = if tokens.contains(&"fn") {
        ScopeKind::Fn(after("fn"))
    } else if tokens.contains(&"mod") {
        ScopeKind::Mod(after("mod"))
    } else if tokens.contains(&"struct") || tokens.contains(&"enum") || tokens.contains(&"union") {
        let kw = if tokens.contains(&"struct") {
            "struct"
        } else if tokens.contains(&"enum") {
            "enum"
        } else {
            "union"
        };
        ScopeKind::Struct(after(kw))
    } else if tokens.contains(&"impl") {
        let name = if tokens.contains(&"for") { after("for") } else { after("impl") };
        ScopeKind::Impl(name)
    } else {
        ScopeKind::Block
    };
    (kind, is_test)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strips_comments_and_strings() {
        let s = scan("let a = \"Instant::now()\"; // Instant::now()\nlet b = 1; /* x */ let c = 2;\n");
        assert_eq!(s.lines[0].code, "let a = \"\"; ");
        assert!(s.lines[1].code.contains("let b = 1;"));
        assert!(s.lines[1].code.contains("let c = 2;"));
        assert!(!s.lines[1].code.contains("x"));
        assert_eq!(s.strings[0].text, "Instant::now()");
    }

    #[test]
    fn char_literals_and_lifetimes() {
        let s = scan("fn f<'a>(x: &'a str) -> char { let c = 'x'; let d = '\\n'; c }\n");
        assert!(s.lines[0].code.contains("&'a str"), "{}", s.lines[0].code);
        assert!(!s.lines[0].code.contains("'x'"));
    }

    #[test]
    fn raw_strings_are_captured() {
        let s = scan("let a = r#\"quote \" inside\"#; let b = 0;\n");
        assert_eq!(s.strings[0].text, "quote \" inside");
        assert!(s.lines[0].code.contains("let b = 0;"));
    }

    #[test]
    fn tracks_test_regions_and_fn_names() {
        let src = "fn live() { x(); }\n\
                   #[cfg(test)]\n\
                   mod tests {\n\
                   use super::*;\n\
                   #[test]\n\
                   fn truncated_decode_case() { y(); }\n\
                   }\n\
                   fn live2() { z(); }\n";
        let s = scan(src);
        assert!(!s.lines[0].in_test);
        assert_eq!(s.lines[0].fn_name.as_deref(), Some("live"));
        assert!(s.lines[3].in_test, "inside mod tests");
        assert!(s.lines[5].in_test);
        assert_eq!(s.lines[5].fn_name.as_deref(), Some("truncated_decode_case"));
        assert!(!s.lines[7].in_test, "after the tests mod closes");
        assert_eq!(s.lines[7].fn_name.as_deref(), Some("live2"));
    }

    #[test]
    fn tracks_struct_impl_and_mod_names() {
        let src = "pub mod kind {\npub const PATCH: u8 = 1;\n}\n\
                   pub struct Counters {\npub a: u64,\n}\n\
                   impl Meter {\nfn write_csv(&self) { let h = \"col_a\"; }\n}\n";
        let s = scan(src);
        assert_eq!(s.lines[1].mod_name.as_deref(), Some("kind"));
        assert_eq!(s.lines[4].struct_name.as_deref(), Some("Counters"));
        assert_eq!(s.lines[7].impl_name.as_deref(), Some("Meter"));
        let lit = s.strings.iter().find(|l| l.text == "col_a").unwrap();
        assert_eq!(lit.impl_name.as_deref(), Some("Meter"));
        assert_eq!(lit.fn_name.as_deref(), Some("write_csv"));
    }

    #[test]
    fn parses_pragmas() {
        let s = scan(
            "// pallas-lint: allow(clock-seam): bench loops time real work\n\
             let t = 1; // pallas-lint: allow(retry-discipline): bounded poll\n\
             // pallas-lint: allow(clock-seam) missing reason colon\n\
             // pallas-lint: allow(clock-seam):\n\
             // a normal comment\n",
        );
        assert_eq!(s.pragmas.len(), 4);
        assert_eq!(s.pragmas[0].rule, "clock-seam");
        assert_eq!(s.pragmas[0].reason, "bench loops time real work");
        assert!(s.pragmas[0].malformed.is_none());
        assert_eq!(s.pragmas[1].line, 2);
        assert!(s.pragmas[2].malformed.is_some(), "no `:` clause");
        assert!(s.pragmas[3].malformed.is_some(), "empty reason");
    }

    #[test]
    fn fn_with_impl_in_return_position_is_a_fn() {
        let s = scan("fn catchup(&self) -> impl Iterator<Item = u8> + '_ {\nlet x = 1;\n}\n");
        assert_eq!(s.lines[1].fn_name.as_deref(), Some("catchup"));
        assert!(s.lines[1].impl_name.is_none());
    }
}
