//! The repo-invariant rule set behind `paper lint`.
//!
//! Each rule machine-checks a discipline the sync plane's correctness
//! story rests on (see README §Static analysis for the table):
//!
//! * `clock-seam` — the only file that may read the wall clock
//!   unjustified is `sim/clock.rs` (the `Clock` seam). Every other
//!   `Instant::now()` / `SystemTime::now()` outside test code needs a
//!   pragma saying why virtual time cannot drive it — one stray read
//!   breaks bit-identical scale-sim replay.
//! * `retry-discipline` — no raw `thread::sleep` wait loops outside
//!   `util/retry.rs`: every wait rides a `RetryPolicy` (budgeted,
//!   seeded, sim-replayable) or justifies itself.
//! * `panic-free-net` — no `unwrap()` / `expect()` / `panic!` family
//!   macros in non-test `net/` code: a torn frame must surface as a
//!   propagated `Err` the retry machinery can heal, never a worker
//!   panic.
//! * `bounded-channels` — no unbounded `mpsc::channel` on net/sim
//!   paths; backpressure must be explicit (the repo's queues are
//!   depth-bounded by design, PR 2/4).
//! * `frame-kind-coverage` — every frame-kind constant in
//!   `net/tcp.rs` is (a) dispatched by non-test net code outside
//!   tcp.rs and (b) exercised by a truncated-decode test. (The chaos
//!   layer's partition logic is deliberately kind-agnostic — it keys
//!   on `FRAME_HEADER_LEN` writes — so coverage is checked where kinds
//!   actually matter: dispatch and decode.)
//! * `counter-csv-drift` — every numeric `TransportCounters` /
//!   `SyncStats` field surfaces as a `TransportMeter` CSV column, and
//!   every histogram registered in `Obs::hist_names` surfaces as an
//!   `ObsExport` CSV row, so a counter or latency histogram added in a
//!   future PR cannot silently vanish from `results/*.csv`.
//!
//! A finding is suppressible only by a pragma comment on the same line
//! or the line directly above, carrying the rule name and a non-empty
//! reason (grammar in [`super::lexer::Pragma`]). Malformed pragmas are
//! findings themselves (`pragma` rule) and cannot be suppressed.

use super::lexer::FileScan;

/// Rule names, paired with one-line descriptions (the `paper lint`
/// header and README table are generated from this).
pub const RULES: &[(&str, &str)] = &[
    ("clock-seam", "wall-clock reads only in sim/clock.rs, tests, or under a justification"),
    ("retry-discipline", "no raw thread::sleep outside util/retry.rs without a justification"),
    ("panic-free-net", "no unwrap/expect/panic! in non-test net/ code"),
    ("bounded-channels", "no unbounded mpsc::channel on net/ or sim/ paths"),
    ("frame-kind-coverage", "every tcp.rs frame kind is dispatched and truncation-tested"),
    ("counter-csv-drift", "every TransportCounters/SyncStats counter and Obs histogram lands in its CSV"),
];

/// The pseudo-rule malformed pragmas are reported under.
pub const PRAGMA_RULE: &str = "pragma";

/// One lint finding. `suppressed` carries the pragma reason when an
/// allow-pragma covers the finding (suppressed findings still land in
/// the JSON report — a suppression is an audit trail, not an eraser).
#[derive(Debug, Clone)]
pub struct Finding {
    pub rule: &'static str,
    pub file: String,
    pub line: usize,
    pub message: String,
    pub suppressed: Option<String>,
}

/// A lexed source file plus its path relative to the scan root
/// (forward slashes, e.g. `net/tcp.rs`).
pub struct SourceFile {
    pub path: String,
    pub scan: FileScan,
}

/// Run every rule over the file set and resolve suppressions.
pub fn evaluate(files: &[SourceFile]) -> Vec<Finding> {
    let mut findings = Vec::new();
    for f in files {
        clock_seam(f, &mut findings);
        retry_discipline(f, &mut findings);
        panic_free_net(f, &mut findings);
        bounded_channels(f, &mut findings);
        pragma_hygiene(f, &mut findings);
    }
    frame_kind_coverage(files, &mut findings);
    counter_csv_drift(files, &mut findings);
    suppress(files, &mut findings);
    findings.sort_by(|a, b| (&a.file, a.line).cmp(&(&b.file, b.line)));
    findings
}

// ------------------------------------------------------------ per-file

fn clock_seam(f: &SourceFile, out: &mut Vec<Finding>) {
    if f.path == "sim/clock.rs" {
        return;
    }
    for l in &f.scan.lines {
        if l.in_test {
            continue;
        }
        for pat in ["Instant::now", "SystemTime::now"] {
            if l.code.contains(pat) {
                out.push(Finding {
                    rule: "clock-seam",
                    file: f.path.clone(),
                    line: l.number,
                    message: format!(
                        "`{}` outside the sim clock seam — breaks virtual-time replay",
                        pat
                    ),
                    suppressed: None,
                });
                break;
            }
        }
    }
}

fn retry_discipline(f: &SourceFile, out: &mut Vec<Finding>) {
    if f.path == "util/retry.rs" {
        return;
    }
    for l in &f.scan.lines {
        if !l.in_test && l.code.contains("thread::sleep") {
            out.push(Finding {
                rule: "retry-discipline",
                file: f.path.clone(),
                line: l.number,
                message: "raw `thread::sleep` — waits must ride a RetryPolicy or justify \
                          themselves"
                    .to_string(),
                suppressed: None,
            });
        }
    }
}

fn panic_free_net(f: &SourceFile, out: &mut Vec<Finding>) {
    if !f.path.starts_with("net/") {
        return;
    }
    const PATTERNS: &[&str] =
        &[".unwrap()", ".expect(", "panic!", "unreachable!", "todo!", "unimplemented!"];
    for l in &f.scan.lines {
        if l.in_test {
            continue;
        }
        for pat in PATTERNS {
            if l.code.contains(pat) {
                out.push(Finding {
                    rule: "panic-free-net",
                    file: f.path.clone(),
                    line: l.number,
                    message: format!(
                        "`{}` on a wire path — net/ code must propagate errors, not panic",
                        pat.trim_start_matches('.')
                    ),
                    suppressed: None,
                });
                break;
            }
        }
    }
}

fn bounded_channels(f: &SourceFile, out: &mut Vec<Finding>) {
    if !(f.path.starts_with("net/") || f.path.starts_with("sim/")) {
        return;
    }
    for l in &f.scan.lines {
        // `mpsc::sync_channel` (bounded) does not contain the pattern
        if !l.in_test && l.code.contains("mpsc::channel") {
            out.push(Finding {
                rule: "bounded-channels",
                file: f.path.clone(),
                line: l.number,
                message: "unbounded `mpsc::channel` on a net/sim path — use a depth-bounded \
                          queue (`mpsc::sync_channel` or the relay SubQueue pattern)"
                    .to_string(),
                suppressed: None,
            });
        }
    }
}

fn pragma_hygiene(f: &SourceFile, out: &mut Vec<Finding>) {
    for p in &f.scan.pragmas {
        if let Some(err) = &p.malformed {
            out.push(Finding {
                rule: PRAGMA_RULE,
                file: f.path.clone(),
                line: p.line,
                message: format!("malformed pragma: {}", err),
                suppressed: None,
            });
        } else if !RULES.iter().any(|(name, _)| *name == p.rule) {
            out.push(Finding {
                rule: PRAGMA_RULE,
                file: f.path.clone(),
                line: p.line,
                message: format!("pragma allows unknown rule `{}`", p.rule),
                suppressed: None,
            });
        }
    }
}

// -------------------------------------------------------------- global

/// True when `code` references `kind::<name>` as a full path segment
/// (so `kind::NACK` does not match `kind::NACK_MISS`).
fn references_kind(code: &str, name: &str) -> bool {
    let pat = format!("kind::{}", name);
    let mut from = 0;
    while let Some(pos) = code[from..].find(&pat) {
        let end = from + pos + pat.len();
        let boundary = code[end..]
            .chars()
            .next()
            .map(|c| !(c.is_alphanumeric() || c == '_'))
            .unwrap_or(true);
        if boundary {
            return true;
        }
        from = end;
    }
    false
}

fn frame_kind_coverage(files: &[SourceFile], out: &mut Vec<Finding>) {
    // the frame-kind registry: `pub const NAME: u8 = n;` inside
    // `mod kind` in net/tcp.rs
    let Some(tcp) = files.iter().find(|f| f.path == "net/tcp.rs") else {
        return;
    };
    let mut kinds: Vec<(String, usize)> = Vec::new();
    for l in &tcp.scan.lines {
        if l.in_test || l.mod_name.as_deref() != Some("kind") {
            continue;
        }
        let toks: Vec<&str> = l
            .code
            .split(|c: char| !(c.is_alphanumeric() || c == '_'))
            .filter(|t| !t.is_empty())
            .collect();
        if let Some(p) = toks.iter().position(|t| *t == "const") {
            if let Some(name) = toks.get(p + 1) {
                kinds.push((name.to_string(), l.number));
            }
        }
    }
    for (name, def_line) in kinds {
        let mut dispatched = false;
        let mut truncation_tested = false;
        for f in files {
            for l in &f.scan.lines {
                if !references_kind(&l.code, &name) {
                    continue;
                }
                if !l.in_test && f.path.starts_with("net/") && f.path != "net/tcp.rs" {
                    dispatched = true;
                }
                if l.in_test
                    && l.fn_name.as_deref().is_some_and(|n| n.contains("truncated"))
                {
                    truncation_tested = true;
                }
            }
        }
        if !dispatched {
            out.push(Finding {
                rule: "frame-kind-coverage",
                file: tcp.path.clone(),
                line: def_line,
                message: format!(
                    "frame kind `{}` is never dispatched by non-test net/ code outside tcp.rs",
                    name
                ),
                suppressed: None,
            });
        }
        if !truncation_tested {
            out.push(Finding {
                rule: "frame-kind-coverage",
                file: tcp.path.clone(),
                line: def_line,
                message: format!(
                    "frame kind `{}` has no truncated-decode test (no `*truncated*` test fn \
                     references it)",
                    name
                ),
                suppressed: None,
            });
        }
    }
}

/// Parse `pub <name>: <type>` off a struct-field line; returns the
/// field name when the type is a scalar counter type.
fn counter_field(code: &str) -> Option<String> {
    let (lhs, rhs) = code.split_once(':')?;
    let lhs_toks: Vec<&str> = lhs
        .split(|c: char| !(c.is_alphanumeric() || c == '_'))
        .filter(|t| !t.is_empty())
        .collect();
    if lhs_toks.first() != Some(&"pub") {
        return None;
    }
    let name = (*lhs_toks.last()?).to_string();
    let ty = rhs
        .split(|c: char| !(c.is_alphanumeric() || c == '_'))
        .find(|t| !t.is_empty())?;
    matches!(ty, "u64" | "u32" | "usize").then_some(name)
}

fn counter_csv_drift(files: &[SourceFile], out: &mut Vec<Finding>) {
    // counter sources: TransportCounters (net/transport.rs) and
    // SyncStats (pulse/sync.rs); numeric fields only — enum/str/bool
    // fields (path, transport, verified) have no column representation
    let mut fields: Vec<(String, String, usize)> = Vec::new(); // (file, field, line)
    for f in files {
        let want = match f.path.as_str() {
            "net/transport.rs" => "TransportCounters",
            "pulse/sync.rs" => "SyncStats",
            _ => continue,
        };
        for l in &f.scan.lines {
            if l.in_test || l.struct_name.as_deref() != Some(want) {
                continue;
            }
            if let Some(name) = counter_field(&l.code) {
                fields.push((f.path.clone(), name, l.number));
            }
        }
    }
    if fields.is_empty() {
        return;
    }
    // the CSV surface: string literals inside TransportMeter::write_csv
    let columns: Vec<String> = files
        .iter()
        .filter(|f| f.path == "coordinator/metrics.rs")
        .flat_map(|f| f.scan.strings.iter())
        .filter(|s| {
            s.impl_name.as_deref() == Some("TransportMeter")
                && s.fn_name.as_deref() == Some("write_csv")
        })
        .map(|s| s.text.clone())
        .collect();
    for (file, field, line) in fields {
        if !columns.iter().any(|c| *c == field) {
            out.push(Finding {
                rule: "counter-csv-drift",
                file,
                line,
                message: format!(
                    "counter field `{}` has no TransportMeter CSV column — the observability \
                     surface drifted",
                    field
                ),
                suppressed: None,
            });
        }
    }
    hist_csv_drift(files, out);
}

/// The histogram leg of `counter-csv-drift`: every name registered in
/// `Obs::hist_names` (obs/mod.rs) must appear as a string literal in
/// `ObsExport::write_csv` (coordinator/metrics.rs), so a latency
/// histogram added to the hub cannot be dropped from
/// `results/obs_hist.csv`.
fn hist_csv_drift(files: &[SourceFile], out: &mut Vec<Finding>) {
    let hists: Vec<(String, String, usize)> = files // (file, name, line)
        .iter()
        .filter(|f| f.path == "obs/mod.rs")
        .flat_map(|f| f.scan.strings.iter().map(move |s| (f, s)))
        .filter(|(_, s)| {
            s.impl_name.as_deref() == Some("Obs") && s.fn_name.as_deref() == Some("hist_names")
        })
        .map(|(f, s)| (f.path.clone(), s.text.clone(), s.line))
        .collect();
    if hists.is_empty() {
        return;
    }
    let rows: Vec<String> = files
        .iter()
        .filter(|f| f.path == "coordinator/metrics.rs")
        .flat_map(|f| f.scan.strings.iter())
        .filter(|s| {
            s.impl_name.as_deref() == Some("ObsExport")
                && s.fn_name.as_deref() == Some("write_csv")
        })
        .map(|s| s.text.clone())
        .collect();
    for (file, name, line) in hists {
        if !rows.iter().any(|r| *r == name) {
            out.push(Finding {
                rule: "counter-csv-drift",
                file,
                line,
                message: format!(
                    "histogram `{}` has no ObsExport CSV row — the latency surface drifted",
                    name
                ),
                suppressed: None,
            });
        }
    }
}

// -------------------------------------------------------- suppressions

fn suppress(files: &[SourceFile], findings: &mut [Finding]) {
    for fin in findings.iter_mut() {
        if fin.rule == PRAGMA_RULE {
            continue;
        }
        let Some(src) = files.iter().find(|f| f.path == fin.file) else {
            continue;
        };
        for p in &src.scan.pragmas {
            if p.malformed.is_none()
                && p.rule == fin.rule
                && (p.line == fin.line || p.line + 1 == fin.line)
            {
                fin.suppressed = Some(p.reason.clone());
                break;
            }
        }
    }
}
