//! `paper lint` — the repo-invariant static analysis pass.
//!
//! PULSE's bit-identical-sync claim rests on source disciplines that
//! reviewers previously enforced by memory (the PR 7 wall-clock audit,
//! PR 6's "RetryPolicy behind every wait", the counter↔CSV columns
//! kept in sync by hand across PRs 4–8). This module machine-checks
//! them: [`lexer`] strips comments/strings and tracks test regions,
//! [`rules`] runs the six repo rules over the lexed files, and
//! [`run_lint`] walks `rust/src` and produces a [`LintReport`] the
//! `paper lint` subcommand renders (human + `results/lint.json`) and
//! CI blocks on.
//!
//! Suppressions are pragmas only — a comment *starting* with
//! `pallas-lint: allow(<rule>): <reason>` on the violating line or the
//! line directly above. The reason is mandatory; malformed pragmas are
//! findings themselves and cannot be suppressed. Suppressed findings
//! stay in the JSON report as an audit trail.
//!
//! Scope: `rust/src/**/*.rs`. Integration tests (`rust/tests/`),
//! benches, and `vendor/` are outside the wire-path surface the rules
//! guard and are not scanned.

pub mod lexer;
pub mod rules;

use std::path::Path;

use anyhow::{Context, Result};

pub use rules::{evaluate, Finding, SourceFile, PRAGMA_RULE, RULES};

use crate::util::json::Json;

/// Everything one lint run produced.
#[derive(Debug)]
pub struct LintReport {
    /// Number of `.rs` files scanned.
    pub files_scanned: usize,
    /// All findings, active and suppressed, sorted by (file, line).
    pub findings: Vec<Finding>,
}

impl LintReport {
    /// Findings not covered by an allow-pragma — these fail the run.
    pub fn active(&self) -> impl Iterator<Item = &Finding> {
        self.findings.iter().filter(|f| f.suppressed.is_none())
    }

    /// Findings covered by an allow-pragma (audit trail).
    pub fn suppressed(&self) -> impl Iterator<Item = &Finding> {
        self.findings.iter().filter(|f| f.suppressed.is_some())
    }

    pub fn is_clean(&self) -> bool {
        self.active().next().is_none()
    }

    /// Human-readable rendering: one line per active finding, then the
    /// summary. Suppressed findings are listed in brief because a
    /// suppression is a reviewable decision, not a deletion.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for f in self.active() {
            out.push_str(&format!("{}:{} [{}] {}\n", f.file, f.line, f.rule, f.message));
        }
        let n_active = self.active().count();
        let n_supp = self.suppressed().count();
        if n_supp > 0 {
            out.push_str(&format!("suppressed ({}):\n", n_supp));
            for f in self.suppressed() {
                out.push_str(&format!(
                    "  {}:{} [{}] allowed: {}\n",
                    f.file,
                    f.line,
                    f.rule,
                    f.suppressed.as_deref().unwrap_or("")
                ));
            }
        }
        out.push_str(&format!(
            "lint: {} file(s), {} finding(s), {} suppressed — {}\n",
            self.files_scanned,
            n_active,
            n_supp,
            if n_active == 0 { "clean" } else { "FAIL" }
        ));
        out
    }

    /// Machine-readable report (`results/lint.json`).
    pub fn to_json(&self) -> Json {
        let mut root = Json::obj();
        root.set("files_scanned", self.files_scanned.into());
        root.set("active", self.active().count().into());
        root.set("suppressed", self.suppressed().count().into());
        root.set("clean", self.is_clean().into());
        let rules: Vec<Json> = RULES
            .iter()
            .map(|(name, desc)| {
                let mut r = Json::obj();
                r.set("name", (*name).into());
                r.set("description", (*desc).into());
                r
            })
            .collect();
        root.set("rules", Json::Arr(rules));
        let findings: Vec<Json> = self
            .findings
            .iter()
            .map(|f| {
                let mut j = Json::obj();
                j.set("rule", f.rule.into());
                j.set("file", f.file.as_str().into());
                j.set("line", f.line.into());
                j.set("message", f.message.as_str().into());
                j.set(
                    "suppressed",
                    match &f.suppressed {
                        Some(reason) => reason.as_str().into(),
                        None => Json::Null,
                    },
                );
                j
            })
            .collect();
        root.set("findings", Json::Arr(findings));
        root
    }
}

/// Lint a set of in-memory sources, given as (repo-src-relative path,
/// source text) pairs. This is the fixture-testable core; [`run_lint`]
/// feeds it from disk.
pub fn lint_sources(sources: &[(&str, &str)]) -> LintReport {
    let files: Vec<SourceFile> = sources
        .iter()
        .map(|(path, text)| SourceFile { path: path.to_string(), scan: lexer::scan(text) })
        .collect();
    let findings = evaluate(&files);
    LintReport { files_scanned: files.len(), findings }
}

/// Walk `src_root` for `.rs` files and lint them. Paths in findings are
/// relative to `src_root` with forward slashes (`net/tcp.rs`).
pub fn run_lint(src_root: &Path) -> Result<LintReport> {
    let mut paths: Vec<std::path::PathBuf> = Vec::new();
    collect_rs(src_root, &mut paths)
        .with_context(|| format!("walking {}", src_root.display()))?;
    paths.sort();
    let mut files: Vec<SourceFile> = Vec::new();
    for p in &paths {
        let text = std::fs::read_to_string(p)
            .with_context(|| format!("reading {}", p.display()))?;
        let rel = p
            .strip_prefix(src_root)
            .unwrap_or(p)
            .components()
            .map(|c| c.as_os_str().to_string_lossy().into_owned())
            .collect::<Vec<_>>()
            .join("/");
        files.push(SourceFile { path: rel, scan: lexer::scan(&text) });
    }
    let findings = evaluate(&files);
    Ok(LintReport { files_scanned: files.len(), findings })
}

fn collect_rs(dir: &Path, out: &mut Vec<std::path::PathBuf>) -> Result<()> {
    for entry in std::fs::read_dir(dir)? {
        let entry = entry?;
        let path = entry.path();
        if entry.file_type()?.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn active_rules(report: &LintReport) -> Vec<&'static str> {
        report.active().map(|f| f.rule).collect()
    }

    // ---------------------------------------------------- clock-seam

    #[test]
    fn clock_seam_failing_suppressed_clean() {
        // failing: wall-clock read in non-test code outside the seam
        let r = lint_sources(&[(
            "pulse/sync.rs",
            "fn poll() { let t = std::time::Instant::now(); }\n",
        )]);
        assert_eq!(active_rules(&r), ["clock-seam"]);
        assert_eq!(r.findings[0].line, 1);

        // suppressed: pragma on the line above, with a reason
        let r = lint_sources(&[(
            "pulse/sync.rs",
            "fn poll() {\n\
             // pallas-lint: allow(clock-seam): measuring real wall time for the report\n\
             let t = std::time::Instant::now();\n\
             }\n",
        )]);
        assert!(r.is_clean(), "{}", r.render());
        assert_eq!(r.suppressed().count(), 1);
        assert_eq!(
            r.suppressed().next().unwrap().suppressed.as_deref(),
            Some("measuring real wall time for the report")
        );

        // clean: test code and the sim clock seam itself may read time
        let r = lint_sources(&[
            (
                "pulse/sync.rs",
                "#[cfg(test)]\nmod tests {\nfn t() { let t = Instant::now(); }\n}\n",
            ),
            ("sim/clock.rs", "fn now() -> Instant { Instant::now() }\n"),
        ]);
        assert!(r.is_clean(), "{}", r.render());
        assert_eq!(r.suppressed().count(), 0);
    }

    #[test]
    fn clock_seam_catches_system_time() {
        let r = lint_sources(&[("util/x.rs", "fn f() { let t = SystemTime::now(); }\n")]);
        assert_eq!(active_rules(&r), ["clock-seam"]);
    }

    // ----------------------------------------------- retry-discipline

    #[test]
    fn retry_discipline_failing_suppressed_clean() {
        let src = "fn wait() { std::thread::sleep(d); }\n";
        // failing: raw sleep outside util/retry.rs
        let r = lint_sources(&[("net/relay.rs", src)]);
        assert_eq!(active_rules(&r), ["retry-discipline"]);

        // suppressed: same-line pragma
        let r = lint_sources(&[(
            "net/relay.rs",
            "fn wait() { std::thread::sleep(d); } \
             // pallas-lint: allow(retry-discipline): bounded drain poll, max 100 iters\n",
        )]);
        assert!(r.is_clean(), "{}", r.render());
        assert_eq!(r.suppressed().count(), 1);

        // clean: util/retry.rs owns the sleep; test code may sleep
        let r = lint_sources(&[
            ("util/retry.rs", src),
            ("net/relay.rs", "#[cfg(test)]\nmod tests {\nfn t() { std::thread::sleep(d); }\n}\n"),
        ]);
        assert!(r.is_clean(), "{}", r.render());
    }

    // ------------------------------------------------- panic-free-net

    #[test]
    fn panic_free_net_failing_suppressed_clean() {
        // failing: each panic-family pattern on a non-test net/ line
        for bad in
            ["x.unwrap();", "x.expect(\"y\");", "panic!(\"z\");", "unreachable!();"]
        {
            let src = format!("fn decode() {{ {} }}\n", bad);
            let r = lint_sources(&[("net/tcp.rs", &src)]);
            assert_eq!(active_rules(&r), ["panic-free-net"], "pattern {}", bad);
        }

        // suppressed: the poisoned-lock idiom with an annotated allow
        let r = lint_sources(&[(
            "net/store.rs",
            "fn stats(&self) {\n\
             // pallas-lint: allow(panic-free-net): lock poisoning is unrecoverable here\n\
             let g = self.inner.lock().unwrap();\n\
             }\n",
        )]);
        assert!(r.is_clean(), "{}", r.render());

        // clean: unwrap in net/ test code, and anywhere outside net/
        let r = lint_sources(&[
            ("net/tcp.rs", "#[cfg(test)]\nmod tests {\nfn t() { x.unwrap(); }\n}\n"),
            ("codec/mod.rs", "fn f() { x.unwrap(); }\n"),
        ]);
        assert!(r.is_clean(), "{}", r.render());
    }

    #[test]
    fn panic_free_net_ignores_patterns_inside_strings() {
        let r = lint_sources(&[(
            "net/tcp.rs",
            "fn f() -> String { format!(\"do not panic!({})\", x) }\n",
        )]);
        assert!(r.is_clean(), "{}", r.render());
    }

    // ----------------------------------------------- bounded-channels

    #[test]
    fn bounded_channels_failing_suppressed_clean() {
        // failing: unbounded channel on a sim/ path
        let r = lint_sources(&[("sim/mod.rs", "fn f() { let (tx, rx) = mpsc::channel(); }\n")]);
        assert_eq!(active_rules(&r), ["bounded-channels"]);

        // suppressed
        let r = lint_sources(&[(
            "net/relay.rs",
            "// pallas-lint: allow(bounded-channels): drained synchronously below, depth <= 1\n\
             fn f() { let (tx, rx) = mpsc::channel(); }\n",
        )]);
        assert!(r.is_clean(), "{}", r.render());

        // clean: sync_channel is bounded; non-net/sim paths are out of scope
        let r = lint_sources(&[
            ("net/relay.rs", "fn f() { let (tx, rx) = mpsc::sync_channel(8); }\n"),
            ("coordinator/mod.rs", "fn f() { let (tx, rx) = mpsc::channel(); }\n"),
        ]);
        assert!(r.is_clean(), "{}", r.render());
    }

    // ------------------------------------------- frame-kind-coverage

    fn tcp_with_kinds(kinds: &str) -> String {
        format!("pub mod kind {{\n{}}}\n", kinds)
    }

    #[test]
    fn frame_kind_coverage_failing_suppressed_clean() {
        // failing: a kind with neither dispatch nor truncation test
        let tcp = tcp_with_kinds("pub const PATCH: u8 = 1;\n");
        let r = lint_sources(&[("net/tcp.rs", &tcp)]);
        let rules = active_rules(&r);
        assert_eq!(rules, ["frame-kind-coverage", "frame-kind-coverage"]);
        assert!(r.findings.iter().all(|f| f.line == 2), "anchors at the const");

        // suppressed: one pragma above the const covers both legs
        let tcp = tcp_with_kinds(
            "// pallas-lint: allow(frame-kind-coverage): reserved kind, dispatch lands in PR 10\n\
             pub const PATCH: u8 = 1;\n",
        );
        let r = lint_sources(&[("net/tcp.rs", &tcp)]);
        assert!(r.is_clean(), "{}", r.render());
        assert_eq!(r.suppressed().count(), 2);

        // clean: dispatched by non-test net/ code outside tcp.rs AND
        // referenced by a truncated-decode test
        let tcp = tcp_with_kinds("pub const PATCH: u8 = 1;\n");
        let relay = "fn route(k: u8) { if k == kind::PATCH { stage(); } }\n";
        let tests = "#[cfg(test)]\nmod tests {\n#[test]\n\
                     fn truncated_patch() { decode(kind::PATCH); }\n}\n";
        let r = lint_sources(&[
            ("net/tcp.rs", &tcp),
            ("net/relay.rs", relay),
            ("net/node.rs", tests),
        ]);
        assert!(r.is_clean(), "{}", r.render());
    }

    #[test]
    fn frame_kind_reference_needs_word_boundary() {
        // kind::NACK_MISS references must NOT satisfy kind::NACK
        let tcp = tcp_with_kinds("pub const NACK: u8 = 5;\npub const NACK_MISS: u8 = 6;\n");
        let relay = "fn route(k: u8) { if k == kind::NACK_MISS { fall_back(); } }\n";
        let tests = "#[cfg(test)]\nmod tests {\n#[test]\n\
                     fn truncated_nacks() { decode(kind::NACK); decode(kind::NACK_MISS); }\n}\n";
        let r = lint_sources(&[
            ("net/tcp.rs", &tcp),
            ("net/relay.rs", relay),
            ("net/node.rs", tests),
        ]);
        // NACK_MISS is fully covered; NACK still lacks dispatch
        let act: Vec<_> = r.active().collect();
        assert_eq!(act.len(), 1, "{}", r.render());
        assert!(act[0].message.contains("`NACK`"), "{}", act[0].message);
        assert!(act[0].message.contains("dispatched"), "{}", act[0].message);
    }

    // -------------------------------------------- counter-csv-drift

    const COUNTERS: &str = "pub struct TransportCounters {\n\
                            pub frames_published: u64,\n\
                            pub retries: u64,\n\
                            }\n";
    const STATS: &str = "pub struct SyncStats {\n\
                         pub bytes_downloaded: u64,\n\
                         pub verified: bool,\n\
                         }\n";

    #[test]
    fn counter_csv_drift_failing_suppressed_clean() {
        let meter_full = "pub struct TransportMeter {}\nimpl TransportMeter {\n\
                          fn write_csv(&self) {\n\
                          let cols = [\"frames_published\", \"retries\", \"bytes_downloaded\"];\n\
                          }\n}\n";
        let meter_missing = "pub struct TransportMeter {}\nimpl TransportMeter {\n\
                             fn write_csv(&self) {\n\
                             let cols = [\"frames_published\", \"retries\"];\n\
                             }\n}\n";

        // failing: SyncStats.bytes_downloaded has no column
        let r = lint_sources(&[
            ("net/transport.rs", COUNTERS),
            ("pulse/sync.rs", STATS),
            ("coordinator/metrics.rs", meter_missing),
        ]);
        assert_eq!(active_rules(&r), ["counter-csv-drift"]);
        let f = r.active().next().unwrap();
        assert_eq!(f.file, "pulse/sync.rs");
        assert!(f.message.contains("bytes_downloaded"), "{}", f.message);

        // suppressed: pragma above the drifting field
        let stats = "pub struct SyncStats {\n\
                     // pallas-lint: allow(counter-csv-drift): per-call bracket, meaningless summed\n\
                     pub bytes_downloaded: u64,\n\
                     }\n";
        let r = lint_sources(&[
            ("net/transport.rs", COUNTERS),
            ("pulse/sync.rs", stats),
            ("coordinator/metrics.rs", meter_missing),
        ]);
        assert!(r.is_clean(), "{}", r.render());

        // clean: every numeric field has a column; bool/str fields and
        // column names outside TransportMeter::write_csv are ignored
        let r = lint_sources(&[
            ("net/transport.rs", COUNTERS),
            ("pulse/sync.rs", STATS),
            ("coordinator/metrics.rs", meter_full),
        ]);
        assert!(r.is_clean(), "{}", r.render());
    }

    #[test]
    fn csv_columns_outside_write_csv_do_not_count() {
        let meter = "pub struct TransportMeter {}\nimpl TransportMeter {\n\
                     fn other(&self) { let x = \"frames_published\"; }\n\
                     fn write_csv(&self) { let cols = [\"nope\"]; }\n}\n";
        let r = lint_sources(&[
            ("net/transport.rs", "pub struct TransportCounters {\npub frames_published: u64,\n}\n"),
            ("coordinator/metrics.rs", meter),
        ]);
        assert_eq!(active_rules(&r), ["counter-csv-drift"]);
    }

    const OBS_HUB: &str = "pub struct Obs {}\nimpl Obs {\n\
                           pub fn hist_names() -> [&'static str; 2] {\n\
                           [\"nack_repair_us\", \"e2e_step_us\"]\n\
                           }\n}\n";

    #[test]
    fn hist_csv_drift_failing_suppressed_clean() {
        let export_full = "pub struct ObsExport {}\nimpl ObsExport {\n\
                           fn write_csv(&self) {\n\
                           let rows = [\"nack_repair_us\", \"e2e_step_us\"];\n\
                           }\n}\n";
        let export_missing = "pub struct ObsExport {}\nimpl ObsExport {\n\
                              fn write_csv(&self) {\n\
                              let rows = [\"nack_repair_us\"];\n\
                              }\n}\n";

        // failing: e2e_step_us is registered but never exported
        let r = lint_sources(&[
            ("obs/mod.rs", OBS_HUB),
            ("coordinator/metrics.rs", export_missing),
        ]);
        assert_eq!(active_rules(&r), ["counter-csv-drift"]);
        let f = r.active().next().unwrap();
        assert_eq!(f.file, "obs/mod.rs");
        assert!(f.message.contains("e2e_step_us"), "{}", f.message);
        assert!(f.message.contains("ObsExport"), "{}", f.message);

        // suppressed: pragma above the registry line
        let obs_supp = "pub struct Obs {}\nimpl Obs {\n\
                        pub fn hist_names() -> [&'static str; 2] {\n\
                        // pallas-lint: allow(counter-csv-drift): exporter row lands next PR\n\
                        [\"nack_repair_us\", \"e2e_step_us\"]\n\
                        }\n}\n";
        let r = lint_sources(&[
            ("obs/mod.rs", obs_supp),
            ("coordinator/metrics.rs", export_missing),
        ]);
        assert!(r.is_clean(), "{}", r.render());
        assert_eq!(r.suppressed().count(), 1);

        // clean: every registered histogram has an export row
        let r = lint_sources(&[
            ("obs/mod.rs", OBS_HUB),
            ("coordinator/metrics.rs", export_full),
        ]);
        assert!(r.is_clean(), "{}", r.render());
    }

    #[test]
    fn hist_names_outside_obs_or_rows_outside_write_csv_do_not_count() {
        // the registry only reads Obs::hist_names; a same-file helper
        // listing names is not a registry
        let stray = "pub struct Obs {}\nimpl Obs {\n\
                     fn labels() { let x = [\"nack_repair_us\"]; }\n\
                     pub fn hist_names() -> [&'static str; 1] { [\"e2e_step_us\"] }\n\
                     }\n";
        // rows outside ObsExport::write_csv do not satisfy the surface
        let export = "pub struct ObsExport {}\nimpl ObsExport {\n\
                      fn other(&self) { let x = \"e2e_step_us\"; }\n\
                      fn write_csv(&self) { let rows = [\"nack_repair_us\"]; }\n}\n";
        let r = lint_sources(&[
            ("obs/mod.rs", stray),
            ("coordinator/metrics.rs", export),
        ]);
        let act: Vec<_> = r.active().collect();
        assert_eq!(act.len(), 1, "{}", r.render());
        assert!(act[0].message.contains("`e2e_step_us`"), "{}", act[0].message);
    }

    // ------------------------------------------------ pragma hygiene

    #[test]
    fn malformed_pragmas_are_findings_and_unsuppressible() {
        // missing reason clause
        let r = lint_sources(&[(
            "net/relay.rs",
            "// pallas-lint: allow(clock-seam) forgot the colon\nfn f() {}\n",
        )]);
        assert_eq!(active_rules(&r), ["pragma"]);

        // empty reason
        let r = lint_sources(&[("a.rs", "// pallas-lint: allow(clock-seam):\n")]);
        assert_eq!(active_rules(&r), ["pragma"]);

        // unknown rule name
        let r = lint_sources(&[("a.rs", "// pallas-lint: allow(no-such-rule): why\n")]);
        assert_eq!(active_rules(&r), ["pragma"]);
        assert!(r.findings[0].message.contains("no-such-rule"));

        // a malformed pragma cannot suppress itself or a real finding
        let r = lint_sources(&[(
            "net/x.rs",
            "// pallas-lint: allow(panic-free-net) oops\nfn f() { x.unwrap(); }\n",
        )]);
        let mut rules = active_rules(&r);
        rules.sort();
        assert_eq!(rules, ["panic-free-net", "pragma"]);

        // prose that merely mentions the marker is not a pragma
        let r = lint_sources(&[(
            "a.rs",
            "// suppressions use pallas-lint: allow(...) comments, see README\n",
        )]);
        assert!(r.is_clean(), "{}", r.render());
    }

    #[test]
    fn pragma_only_reaches_its_own_rule_and_adjacent_lines() {
        // wrong rule name → no suppression
        let r = lint_sources(&[(
            "net/x.rs",
            "// pallas-lint: allow(clock-seam): wrong rule\nfn f() { x.unwrap(); }\n",
        )]);
        assert_eq!(active_rules(&r), ["panic-free-net"]);

        // two lines above → out of range
        let r = lint_sources(&[(
            "net/x.rs",
            "// pallas-lint: allow(panic-free-net): too far away\n\nfn f() { x.unwrap(); }\n",
        )]);
        assert_eq!(active_rules(&r), ["panic-free-net"]);
    }

    // ------------------------------------------------ report surface

    #[test]
    fn json_report_shape() {
        let r = lint_sources(&[(
            "net/x.rs",
            "fn f() { x.unwrap(); }\n\
             // pallas-lint: allow(panic-free-net): demo\n\
             fn g() { y.unwrap(); }\n",
        )]);
        let j = r.to_json();
        assert_eq!(j.req_usize("files_scanned").unwrap(), 1);
        assert_eq!(j.req_usize("active").unwrap(), 1);
        assert_eq!(j.req_usize("suppressed").unwrap(), 1);
        assert!(!j.get("clean").unwrap().as_bool().unwrap());
        assert_eq!(j.get("rules").unwrap().as_arr().unwrap().len(), RULES.len());
        let f0 = j.get("findings").unwrap().idx(0).unwrap();
        assert_eq!(f0.req_str("file").unwrap(), "net/x.rs");
        assert_eq!(f0.req_str("rule").unwrap(), "panic-free-net");
        // round-trips through the parser
        assert!(Json::parse(&j.to_pretty()).is_ok());
    }

    // -------------------------------------------- the real repo gate

    /// Tier-1 regression gate: the repo itself must be lint-clean.
    /// CI's blocking `lint` job runs `paper lint`; this test makes the
    /// same check part of every local `cargo test`.
    #[test]
    fn repo_is_lint_clean() {
        let src = Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
        let report = run_lint(&src).expect("scan rust/src");
        assert!(report.files_scanned > 40, "walker found {} files", report.files_scanned);
        assert!(report.is_clean(), "repo lint findings:\n{}", report.render());
    }
}
