//! Optimizers (paper §A.3, §4.3).
//!
//! * [`AdamW`] — FP32-master-weight AdamW with bias correction, optional
//!   decoupled weight decay and global-norm clipping, plus the ρ =
//!   |m̂|/√v̂ instrumentation used by the Fig. 9 analysis.
//! * [`Nesterov`] — the Sutskever-form outer optimizer DiLoCo and
//!   PULSELoCo apply to aggregated pseudo-gradients (µ=0.9, α=0.7).

use crate::util::pool;

/// AdamW hyperparameters. Defaults match the paper's controlled sparsity
/// analysis (Table 8): PyTorch betas, zero weight decay.
#[derive(Debug, Clone, Copy)]
pub struct AdamConfig {
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub weight_decay: f32,
    /// Global-norm gradient clipping (0 disables). Paper uses 1.0.
    pub clip_global_norm: f32,
    /// Linear LR warmup steps (paper §G.4 uses 20).
    pub warmup_steps: u64,
}

impl Default for AdamConfig {
    fn default() -> Self {
        AdamConfig {
            lr: 3e-6,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            weight_decay: 0.0,
            clip_global_norm: 1.0,
            warmup_steps: 20,
        }
    }
}

impl AdamConfig {
    /// Post-training setting used by grail / PULSELoCo (β2 = 0.95,
    /// η = 1e-6; paper §F.4).
    pub fn post_training() -> Self {
        AdamConfig { lr: 1e-6, beta2: 0.95, ..Default::default() }
    }

    /// Asymptotic Adam update bound η·√((1−β1)/(1−β2)) (Thm. A.4).
    pub fn update_bound(&self) -> f64 {
        self.lr as f64 * ((1.0 - self.beta1 as f64) / (1.0 - self.beta2 as f64)).sqrt()
    }

    /// Step-t bound (Thm. A.4, finite-t form).
    pub fn update_bound_at(&self, t: u64) -> f64 {
        let (b1, b2) = (self.beta1 as f64, self.beta2 as f64);
        let t = t.max(1) as f64;
        self.lr as f64
            * ((1.0 - b1) / (1.0 - b2) * (1.0 - b2.powf(t)) / (1.0 - b1.powf(t))).sqrt()
    }

    /// Sharper Cauchy supremum (paper Eq. 18), infinite horizon.
    pub fn cauchy_supremum(&self) -> f64 {
        let (b1, b2) = (self.beta1 as f64, self.beta2 as f64);
        (1.0 - b1) / ((1.0 - b2) * (1.0 - b1 * b1 / b2)).sqrt()
    }
}

/// AdamW state over a flat FP32 parameter vector.
#[derive(Debug, Clone)]
pub struct AdamW {
    pub cfg: AdamConfig,
    pub m: Vec<f32>,
    pub v: Vec<f32>,
    pub step: u64,
}

/// Per-step diagnostics.
#[derive(Debug, Clone, Copy, Default)]
pub struct StepStats {
    /// Effective LR after warmup.
    pub lr: f32,
    /// Global grad norm before clipping.
    pub grad_norm: f64,
    /// max_i |Δw_i| actually applied.
    pub max_update: f32,
    /// max_i |m̂|/√v̂ (the ρ of Fig. 9), sampled.
    pub rho_max: f32,
    /// mean |m̂|/(√v̂+ε), sampled.
    pub rho_mean: f32,
}

impl AdamW {
    pub fn new(n: usize, cfg: AdamConfig) -> Self {
        AdamW { cfg, m: vec![0.0; n], v: vec![0.0; n], step: 0 }
    }

    /// Effective learning rate at optimizer step `t` (1-based) with
    /// linear warmup.
    pub fn lr_at(&self, t: u64) -> f32 {
        if self.cfg.warmup_steps == 0 || t >= self.cfg.warmup_steps {
            self.cfg.lr
        } else {
            self.cfg.lr * (t as f32 / self.cfg.warmup_steps as f32)
        }
    }

    /// One AdamW step on FP32 master weights. `grads` is consumed
    /// read-only; `params` updated in place. Parallel over chunks.
    pub fn step(&mut self, params: &mut [f32], grads: &[f32]) -> StepStats {
        assert_eq!(params.len(), grads.len());
        assert_eq!(params.len(), self.m.len());
        self.step += 1;
        let t = self.step;
        let lr = self.lr_at(t);
        // global-norm clip
        let sq: f64 = pool::par_ranges(grads.len(), 1 << 16, |r| {
            let mut s = 0.0f64;
            for i in r {
                s += (grads[i] as f64) * (grads[i] as f64);
            }
            s
        })
        .into_iter()
        .sum();
        let norm = sq.sqrt();
        let clip = self.cfg.clip_global_norm;
        let scale = if clip > 0.0 && norm > clip as f64 { clip as f64 / norm } else { 1.0 } as f32;

        let (b1, b2, eps, wd) = (self.cfg.beta1, self.cfg.beta2, self.cfg.eps, self.cfg.weight_decay);
        let bc1 = 1.0 - b1.powi(t as i32);
        let bc2 = 1.0 - b2.powi(t as i32);

        // parallel fused update; collect per-chunk stats
        struct ChunkStat {
            max_update: f32,
            rho_max: f32,
            rho_sum: f64,
            n: usize,
        }
        let m_ptr = SendPtr(self.m.as_mut_ptr());
        let v_ptr = SendPtr(self.v.as_mut_ptr());
        let p_ptr = SendPtr(params.as_mut_ptr());
        let stats = pool::par_ranges(grads.len(), 1 << 15, |r| {
            let mut st = ChunkStat { max_update: 0.0, rho_max: 0.0, rho_sum: 0.0, n: 0 };
            // SAFETY: ranges are disjoint; each index touched by one task.
            let (m, v, p) = (m_ptr, v_ptr, p_ptr);
            for i in r {
                unsafe {
                    let g = grads[i] * scale;
                    let mi = m.0.add(i);
                    let vi = v.0.add(i);
                    let pi = p.0.add(i);
                    *mi = b1 * *mi + (1.0 - b1) * g;
                    *vi = b2 * *vi + (1.0 - b2) * g * g;
                    let mhat = *mi / bc1;
                    let vhat = *vi / bc2;
                    let denom = vhat.sqrt() + eps;
                    let rho = (mhat / denom).abs();
                    let delta = lr * mhat / denom + lr * wd * *pi;
                    *pi -= delta;
                    let ad = delta.abs();
                    if ad > st.max_update {
                        st.max_update = ad;
                    }
                    if rho > st.rho_max {
                        st.rho_max = rho;
                    }
                    st.rho_sum += rho as f64;
                    st.n += 1;
                }
            }
            st
        });
        let mut out = StepStats { lr, grad_norm: norm, ..Default::default() };
        let mut rho_sum = 0.0f64;
        let mut n = 0usize;
        for st in stats {
            out.max_update = out.max_update.max(st.max_update);
            out.rho_max = out.rho_max.max(st.rho_max);
            rho_sum += st.rho_sum;
            n += st.n;
        }
        out.rho_mean = if n > 0 { (rho_sum / n as f64) as f32 } else { 0.0 };
        out
    }
}

#[derive(Clone, Copy)]
struct SendPtr(*mut f32);
unsafe impl Send for SendPtr {}
unsafe impl Sync for SendPtr {}

/// Sutskever-form Nesterov outer optimizer (Alg. 2 lines 15–16):
///   m ← µ·m + g ;  θ ← θ − α·(µ·m + g)
#[derive(Debug, Clone)]
pub struct Nesterov {
    pub momentum: f32,
    pub alpha: f32,
    pub m: Vec<f32>,
}

impl Nesterov {
    /// Paper defaults: µ=0.9, α=0.7.
    pub fn new(n: usize) -> Self {
        Nesterov { momentum: 0.9, alpha: 0.7, m: vec![0.0; n] }
    }

    pub fn with(n: usize, momentum: f32, alpha: f32) -> Self {
        Nesterov { momentum, alpha, m: vec![0.0; n] }
    }

    /// Apply the aggregated (possibly sparse-reconstructed) outer
    /// gradient `g` to `theta` in place.
    pub fn step(&mut self, theta: &mut [f32], g: &[f32]) {
        assert_eq!(theta.len(), g.len());
        assert_eq!(theta.len(), self.m.len());
        let (mu, alpha) = (self.momentum, self.alpha);
        let m_ptr = SendPtr(self.m.as_mut_ptr());
        let t_ptr = SendPtr(theta.as_mut_ptr());
        pool::par_ranges(theta.len(), 1 << 16, |r| {
            let (m, t) = (m_ptr, t_ptr);
            for i in r {
                unsafe {
                    let mi = m.0.add(i);
                    *mi = mu * *mi + g[i];
                    *t.0.add(i) -= alpha * (mu * *mi + g[i]);
                }
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Scalar reference AdamW for cross-checking the fused kernel.
    fn ref_adamw(
        cfg: &AdamConfig,
        lr: f32,
        p: &mut f32,
        m: &mut f32,
        v: &mut f32,
        g: f32,
        t: u64,
    ) {
        *m = cfg.beta1 * *m + (1.0 - cfg.beta1) * g;
        *v = cfg.beta2 * *v + (1.0 - cfg.beta2) * g * g;
        let mhat = *m / (1.0 - cfg.beta1.powi(t as i32));
        let vhat = *v / (1.0 - cfg.beta2.powi(t as i32));
        *p -= lr * mhat / (vhat.sqrt() + cfg.eps) + lr * cfg.weight_decay * *p;
    }

    #[test]
    fn matches_scalar_reference() {
        let cfg = AdamConfig { clip_global_norm: 0.0, warmup_steps: 0, ..Default::default() };
        let n = 500;
        let mut rng = Rng::new(1);
        let mut params: Vec<f32> = (0..n).map(|_| rng.normal() as f32 * 0.02).collect();
        let mut refp = params.clone();
        let mut refm = vec![0.0f32; n];
        let mut refv = vec![0.0f32; n];
        let mut opt = AdamW::new(n, cfg);
        for t in 1..=10u64 {
            let grads: Vec<f32> = (0..n).map(|_| rng.normal() as f32 * 0.1).collect();
            opt.step(&mut params, &grads);
            for i in 0..n {
                ref_adamw(&cfg, cfg.lr, &mut refp[i], &mut refm[i], &mut refv[i], grads[i], t);
            }
        }
        for i in 0..n {
            assert!(
                (params[i] - refp[i]).abs() <= 1e-12 + refp[i].abs() * 1e-6,
                "i={} {} vs {}",
                i,
                params[i],
                refp[i]
            );
        }
    }

    #[test]
    fn update_bound_holds() {
        // Thm A.4: |Δw| ≤ η √((1−β1)/(1−β2) · (1−β2^t)/(1−β1^t)) under
        // any gradient sequence (no clipping, no wd).
        crate::util::prop::check("adam bound", 25, |g| {
            let cfg = AdamConfig {
                lr: 3e-6,
                clip_global_norm: 0.0,
                warmup_steps: 0,
                ..Default::default()
            };
            let n = 64;
            let mut params = vec![0.0f32; n];
            let mut opt = AdamW::new(n, cfg);
            for _ in 0..20 {
                let grads: Vec<f32> = (0..n)
                    .map(|_| {
                        (g.rng.normal() as f32)
                            * 10f32.powi(g.rng.range_i64(-12, 3) as i32)
                    })
                    .collect();
                let st = opt.step(&mut params, &grads);
                let bound = cfg.update_bound_at(opt.step) * (1.0 + 1e-5);
                assert!(
                    (st.max_update as f64) <= bound,
                    "step {}: {} > {}",
                    opt.step,
                    st.max_update,
                    bound
                );
            }
        });
    }

    #[test]
    fn bound_table_matches_paper() {
        // Table 1: PyTorch defaults → 10η; β2=0.95 → √2·η ≈ 1.41η.
        let d = AdamConfig::default();
        assert!((d.update_bound() / d.lr as f64 - 10.0).abs() < 1e-3);
        let p = AdamConfig { beta2: 0.95, ..Default::default() };
        assert!((p.update_bound() / p.lr as f64 - 2f64.sqrt()).abs() < 1e-3);
        // Eq. 18: sharper suprema 7.27 and 1.16.
        assert!((d.cauchy_supremum() - 7.2688).abs() < 1e-2);
        assert!((p.cauchy_supremum() - 1.1626).abs() < 1e-2);
    }

    #[test]
    fn constant_gradients_give_rho_near_one() {
        // Paper §A.4: for constant gradients ρ → 1.
        let cfg = AdamConfig { clip_global_norm: 0.0, warmup_steps: 0, ..Default::default() };
        let n = 16;
        let mut params = vec![0.1f32; n];
        let mut opt = AdamW::new(n, cfg);
        let grads = vec![0.5f32; n];
        let mut last = StepStats::default();
        for _ in 0..50 {
            last = opt.step(&mut params, &grads);
        }
        assert!((last.rho_mean - 1.0).abs() < 0.05, "rho_mean={}", last.rho_mean);
    }

    #[test]
    fn warmup_ramps_lr() {
        let cfg = AdamConfig { warmup_steps: 10, ..Default::default() };
        let mut opt = AdamW::new(4, cfg);
        let mut p = vec![0.0f32; 4];
        let g = vec![1.0f32; 4];
        let s1 = opt.step(&mut p, &g);
        assert!((s1.lr - cfg.lr * 0.1).abs() < 1e-12);
        for _ in 0..12 {
            opt.step(&mut p, &g);
        }
        let sn = opt.step(&mut p, &g);
        assert_eq!(sn.lr, cfg.lr);
    }

    #[test]
    fn clipping_caps_norm() {
        let cfg = AdamConfig { clip_global_norm: 1.0, warmup_steps: 0, ..Default::default() };
        let mut opt = AdamW::new(3, cfg);
        let mut p = vec![0.0f32; 3];
        let st = opt.step(&mut p, &[100.0, 100.0, 100.0]);
        assert!(st.grad_norm > 100.0); // measured pre-clip
        // post-clip the effective step is bounded by the Adam bound
        assert!((st.max_update as f64) < cfg.update_bound_at(1) * 1.001);
    }

    #[test]
    fn nesterov_matches_reference() {
        let n = 100;
        let mut rng = Rng::new(4);
        let mut theta: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let mut reft = theta.clone();
        let mut refm = vec![0.0f32; n];
        let mut opt = Nesterov::new(n);
        for _ in 0..5 {
            let g: Vec<f32> = (0..n).map(|_| rng.normal() as f32 * 0.01).collect();
            opt.step(&mut theta, &g);
            for i in 0..n {
                refm[i] = 0.9 * refm[i] + g[i];
                reft[i] -= 0.7 * (0.9 * refm[i] + g[i]);
            }
        }
        for i in 0..n {
            assert!((theta[i] - reft[i]).abs() < 1e-6);
        }
    }
}
