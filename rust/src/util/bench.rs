//! Micro-benchmark harness (criterion substitute) used by the
//! `cargo bench` targets (declared with `harness = false`).
//!
//! Methodology: warmup iterations, then timed batches until both a
//! minimum wall time and a minimum iteration count are reached; reports
//! mean / median / p10 / p90 and derived throughput. Results can be
//! appended to a CSV so the perf pass can diff before/after.

use std::time::Instant;

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub p10_ns: f64,
    pub p90_ns: f64,
    /// Optional bytes processed per iteration (enables MB/s reporting).
    pub bytes_per_iter: Option<u64>,
}

impl BenchResult {
    pub fn mbps(&self) -> Option<f64> {
        self.bytes_per_iter.map(|b| (b as f64 / 1e6) / (self.mean_ns / 1e9))
    }

    pub fn report(&self) -> String {
        let base = format!(
            "{:<44} {:>10} iters  mean {:>12}  median {:>12}  p90 {:>12}",
            self.name,
            self.iters,
            fmt_ns(self.mean_ns),
            fmt_ns(self.median_ns),
            fmt_ns(self.p90_ns)
        );
        match self.mbps() {
            Some(m) => format!("{}  {:>10.1} MB/s", base, m),
            None => base,
        }
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{:.1} ns", ns)
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

pub struct Bench {
    /// Minimum total measured time per benchmark (seconds).
    pub min_time: f64,
    /// Minimum measured iterations.
    pub min_iters: usize,
    /// Warmup time (seconds).
    pub warmup: f64,
    pub results: Vec<BenchResult>,
}

impl Default for Bench {
    fn default() -> Self {
        // PULSE_BENCH_FAST=1 runs a quick smoke pass (used by `make test`).
        let fast = std::env::var("PULSE_BENCH_FAST").ok().as_deref() == Some("1");
        Bench {
            min_time: if fast { 0.05 } else { 0.3 },
            min_iters: if fast { 3 } else { 5 },
            warmup: if fast { 0.01 } else { 0.2 },
            results: Vec::new(),
        }
    }
}

impl Bench {
    pub fn new() -> Self {
        Self::default()
    }

    /// Time `f`, which performs one logical iteration per call.
    pub fn run<F: FnMut()>(&mut self, name: &str, mut f: F) -> &BenchResult {
        self.run_with_bytes(name, None, &mut f)
    }

    /// Time `f` and report throughput for `bytes` processed per call.
    pub fn run_bytes<F: FnMut()>(&mut self, name: &str, bytes: u64, mut f: F) -> &BenchResult {
        self.run_with_bytes(name, Some(bytes), &mut f)
    }

    fn run_with_bytes(
        &mut self,
        name: &str,
        bytes: Option<u64>,
        f: &mut dyn FnMut(),
    ) -> &BenchResult {
        // Warmup.
        // pallas-lint: allow(clock-seam): benchmarks time real work by definition
        let w = Instant::now();
        while w.elapsed().as_secs_f64() < self.warmup {
            f();
        }
        // Measure.
        let mut samples_ns: Vec<f64> = Vec::new();
        // pallas-lint: allow(clock-seam): benchmarks time real work by definition
        let start = Instant::now();
        while start.elapsed().as_secs_f64() < self.min_time || samples_ns.len() < self.min_iters {
            // pallas-lint: allow(clock-seam): the per-iteration sample itself
            let t = Instant::now();
            f();
            samples_ns.push(t.elapsed().as_nanos() as f64);
            if samples_ns.len() > 2_000_000 {
                break;
            }
        }
        let mut sorted = samples_ns.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let res = BenchResult {
            name: name.to_string(),
            iters: samples_ns.len(),
            mean_ns: samples_ns.iter().sum::<f64>() / samples_ns.len() as f64,
            median_ns: sorted[sorted.len() / 2],
            p10_ns: sorted[sorted.len() / 10],
            p90_ns: sorted[sorted.len() * 9 / 10],
            bytes_per_iter: bytes,
        };
        println!("{}", res.report());
        self.results.push(res);
        self.results.last().unwrap()
    }

    /// Write all results as one JSON snapshot (overwrites). This is the
    /// machine-readable artifact the CI bench-smoke job uploads
    /// (`BENCH_*.json`), so the perf trajectory accumulates per PR.
    pub fn write_json(&self, path: &std::path::Path) -> std::io::Result<()> {
        use crate::util::json::Json;
        let rows: Vec<Json> = self
            .results
            .iter()
            .map(|r| {
                let mut j = Json::obj();
                j.set("name", r.name.as_str().into())
                    .set("iters", (r.iters as u64).into())
                    .set("mean_ns", r.mean_ns.into())
                    .set("median_ns", r.median_ns.into())
                    .set("p10_ns", r.p10_ns.into())
                    .set("p90_ns", r.p90_ns.into());
                if let Some(m) = r.mbps() {
                    j.set("mbps", m.into());
                }
                j
            })
            .collect();
        let mut root = Json::obj();
        root.set("results", Json::Arr(rows));
        if let Some(p) = path.parent() {
            std::fs::create_dir_all(p)?;
        }
        std::fs::write(path, root.to_pretty())
    }

    /// Append all results to a CSV file (created with header if missing).
    pub fn write_csv(&self, path: &std::path::Path) -> std::io::Result<()> {
        use std::io::Write;
        let exists = path.exists();
        if let Some(p) = path.parent() {
            std::fs::create_dir_all(p)?;
        }
        let mut f = std::fs::OpenOptions::new().create(true).append(true).open(path)?;
        if !exists {
            writeln!(f, "name,iters,mean_ns,median_ns,p10_ns,p90_ns,mbps")?;
        }
        for r in &self.results {
            writeln!(
                f,
                "{},{},{:.1},{:.1},{:.1},{:.1},{}",
                r.name,
                r.iters,
                r.mean_ns,
                r.median_ns,
                r.p10_ns,
                r.p90_ns,
                r.mbps().map(|m| format!("{:.1}", m)).unwrap_or_default()
            )?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        std::env::set_var("PULSE_BENCH_FAST", "1");
        let mut b = Bench::new();
        let mut acc = 0u64;
        let r = b.run("noop-ish", || {
            acc = acc.wrapping_add(std::hint::black_box(1));
        });
        assert!(r.iters >= 3);
        assert!(r.mean_ns >= 0.0);
    }

    #[test]
    fn throughput_reported() {
        std::env::set_var("PULSE_BENCH_FAST", "1");
        let mut b = Bench::new();
        let data = vec![1u8; 1 << 16];
        let r = b.run_bytes("sum-64k", data.len() as u64, || {
            std::hint::black_box(data.iter().map(|&x| x as u64).sum::<u64>());
        });
        assert!(r.mbps().unwrap() > 0.0);
    }

    #[test]
    fn json_snapshot_written() {
        std::env::set_var("PULSE_BENCH_FAST", "1");
        let mut b = Bench::new();
        b.run("noop", || {
            std::hint::black_box(1u8);
        });
        let dir = std::env::temp_dir().join(format!("pulse_benchjson_{}", std::process::id()));
        let p = dir.join("BENCH_test.json");
        b.write_json(&p).unwrap();
        let j = crate::util::json::Json::parse_file(&p).unwrap();
        let rows = j.req("results").unwrap().as_arr().unwrap_or(&[]).to_vec();
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].req_str("name").unwrap(), "noop");
        assert!(rows[0].req_f64("mean_ns").unwrap() >= 0.0);
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
