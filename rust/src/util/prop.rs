//! Miniature property-testing harness (proptest substitute).
//!
//! `check(name, cases, |g| { ... })` runs the closure `cases` times with a
//! fresh deterministic [`Gen`] each time. On failure it re-raises the panic
//! annotated with the failing case seed so `PULSE_PROP_SEED=<seed>` can
//! replay exactly that case. There is no shrinking — generators are asked
//! to produce a size spectrum instead (small sizes early).

use super::rng::Rng;

/// Per-case generator: a seeded RNG plus a "size" knob that grows with the
/// case index so early cases are small (poor man's shrinking).
pub struct Gen {
    pub rng: Rng,
    pub size: usize,
    pub seed: u64,
}

impl Gen {
    /// A vector length from 0..=size (biased small).
    pub fn len(&mut self) -> usize {
        let n = self.rng.below(self.size as u64 + 1) as usize;
        if self.rng.f64() < 0.2 {
            n / 8
        } else {
            n
        }
    }

    /// Random f32 vector with mixed magnitudes incl. special values.
    pub fn f32_vec(&mut self, len: usize) -> Vec<f32> {
        (0..len)
            .map(|_| match self.rng.below(20) {
                0 => 0.0,
                1 => -0.0,
                2 => f32::MIN_POSITIVE,
                3 => 1.0,
                4 => -1.0,
                5 => (self.rng.f32() - 0.5) * 1e-6,
                6 => (self.rng.f32() - 0.5) * 1e6,
                _ => (self.rng.normal() as f32) * 10f32.powi(self.rng.range_i64(-8, 2) as i32),
            })
            .collect()
    }

    /// Random bytes with tunable entropy (some runs highly compressible).
    pub fn bytes(&mut self, len: usize) -> Vec<u8> {
        let alphabet = 1usize << self.rng.below(9); // 1..=256 symbols
        (0..len).map(|_| self.rng.below(alphabet as u64) as u8).collect()
    }

    /// Sorted unique indices below `universe`.
    pub fn sorted_indices(&mut self, universe: usize, approx_count: usize) -> Vec<u64> {
        if universe == 0 {
            return Vec::new();
        }
        let mut v: Vec<u64> =
            (0..approx_count).map(|_| self.rng.below(universe as u64)).collect();
        v.sort_unstable();
        v.dedup();
        v
    }
}

/// Run a property. Panics (failing the enclosing test) on the first
/// failing case, reporting the case seed.
pub fn check<F: Fn(&mut Gen) + std::panic::RefUnwindSafe>(name: &str, cases: usize, f: F) {
    // Replay mode: run only the requested seed.
    if let Ok(seed_s) = std::env::var("PULSE_PROP_SEED") {
        if let Ok(seed) = seed_s.parse::<u64>() {
            let mut g = Gen { rng: Rng::new(seed), size: 4096, seed };
            f(&mut g);
            return;
        }
    }
    let mut master = Rng::new(0xC0FFEE ^ name.len() as u64 ^ hash_name(name));
    for case in 0..cases {
        let seed = master.next_u64();
        // size grows from 4 to 4096 across the run
        let size = 4 + (case * 4096) / cases.max(1);
        let result = std::panic::catch_unwind(|| {
            let mut g = Gen { rng: Rng::new(seed), size, seed };
            f(&mut g);
        });
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property '{}' failed on case {}/{} (replay with PULSE_PROP_SEED={}): {}",
                name, case, cases, seed, msg
            );
        }
    }
}

fn hash_name(name: &str) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivially_true_property() {
        check("reverse twice is identity", 50, |g| {
            let n = g.len();
            let v = g.bytes(n);
            let mut w = v.clone();
            w.reverse();
            w.reverse();
            assert_eq!(v, w);
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn reports_failing_case() {
        check("always fails", 10, |g| {
            assert!(g.len() == usize::MAX, "intentional failure");
        });
    }

    #[test]
    fn generator_hits_specials() {
        use std::sync::atomic::{AtomicBool, Ordering};
        let seen_zero = AtomicBool::new(false);
        let seen_tiny = AtomicBool::new(false);
        check("gen coverage", 30, |g| {
            for x in g.f32_vec(64) {
                if x == 0.0 {
                    seen_zero.store(true, Ordering::Relaxed);
                }
                if x != 0.0 && x.abs() < 1e-5 {
                    seen_tiny.store(true, Ordering::Relaxed);
                }
            }
        });
        assert!(seen_zero.load(Ordering::Relaxed) && seen_tiny.load(Ordering::Relaxed));
    }
}
