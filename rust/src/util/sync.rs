//! Poison-tolerant lock/condvar helpers for the wire plane.
//!
//! `Mutex::lock().unwrap()` was the single biggest `unwrap()`
//! population in `net/` (~100 sites) before the `panic-free-net` lint
//! rule landed. Propagating a `PoisonError` would be the wrong fix:
//! the sync plane's correctness story deliberately does not rest on
//! lock-state invariants — every consumer verifies end-to-end against
//! the container hash tree and every wait rides a budgeted
//! `RetryPolicy` — so the most a poisoned lock can leak is a stale
//! counter or a queue entry the retry machinery re-requests. A worker
//! panicking while holding one of these locks must therefore not
//! cascade into every peer thread panicking on acquire.
//!
//! [`LockExt::plock`] ("poison-tolerant lock") acquires the mutex and,
//! on poison, takes the inner guard anyway. [`CondvarExt::pwait_timeout`]
//! does the same for `Condvar::wait_timeout`.

use std::sync::{Condvar, Mutex, MutexGuard};
use std::time::Duration;

/// Poison-tolerant `Mutex` acquisition.
pub trait LockExt<T> {
    /// Lock, recovering the guard from a poisoned mutex instead of
    /// panicking. Use on every wire-plane lock; data behind these
    /// locks is re-verified or re-requested end-to-end.
    fn plock(&self) -> MutexGuard<'_, T>;
}

impl<T> LockExt<T> for Mutex<T> {
    fn plock(&self) -> MutexGuard<'_, T> {
        self.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
    }
}

/// Poison-tolerant `Condvar` waits.
pub trait CondvarExt {
    /// `wait_timeout`, recovering the guard from a poisoned mutex and
    /// dropping the (unused on the wire plane) timeout flag.
    fn pwait_timeout<'a, T>(
        &self,
        guard: MutexGuard<'a, T>,
        dur: Duration,
    ) -> MutexGuard<'a, T>;
}

impl CondvarExt for Condvar {
    fn pwait_timeout<'a, T>(
        &self,
        guard: MutexGuard<'a, T>,
        dur: Duration,
    ) -> MutexGuard<'a, T> {
        self.wait_timeout(guard, dur)
            .unwrap_or_else(|poisoned| poisoned.into_inner())
            .0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::{Arc, Condvar, Mutex};

    #[test]
    fn plock_survives_poison() {
        let m = Arc::new(Mutex::new(7u32));
        let m2 = Arc::clone(&m);
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison the lock");
        })
        .join();
        assert!(m.is_poisoned());
        assert_eq!(*m.plock(), 7, "guard still accessible after poison");
        *m.plock() = 8;
        assert_eq!(*m.plock(), 8);
    }

    #[test]
    fn pwait_timeout_returns_guard() {
        let pair = Arc::new((Mutex::new(false), Condvar::new()));
        let (lock, cv) = &*pair;
        let g = lock.plock();
        let g = cv.pwait_timeout(g, Duration::from_millis(1));
        assert!(!*g, "timed out without a notify; state unchanged");
    }

    #[test]
    fn pwait_timeout_survives_poison() {
        let pair = Arc::new((Mutex::new(0u32), Condvar::new()));
        let pair2 = Arc::clone(&pair);
        let _ = std::thread::spawn(move || {
            let _g = pair2.0.lock().unwrap();
            panic!("poison");
        })
        .join();
        let (lock, cv) = &*pair;
        let g = cv.pwait_timeout(lock.plock(), Duration::from_millis(1));
        assert_eq!(*g, 0);
    }
}
