//! Tiny argv parser (clap substitute): `--key value`, `--flag`, and
//! positional arguments, with typed getters and a generated usage string.

use std::collections::BTreeMap;

#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse `std::env::args()` (skipping argv[0]).
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1).collect())
    }

    pub fn parse(argv: Vec<String>) -> Args {
        let mut a = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let tok = &argv[i];
            if let Some(name) = tok.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    a.options.insert(k.to_string(), v.to_string());
                } else if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    a.options.insert(name.to_string(), argv[i + 1].clone());
                    i += 1;
                } else {
                    a.flags.push(name.to_string());
                }
            } else {
                a.positional.push(tok.clone());
            }
            i += 1;
        }
        a
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, name: &str, default: usize) -> usize {
        self.get(name).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn u64_or(&self, name: &str, default: u64) -> u64 {
        self.get(name).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn f64_or(&self, name: &str, default: f64) -> f64 {
        self.get(name).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    /// Comma-separated list of usize, e.g. `--ks 1,8,16`.
    pub fn usize_list_or(&self, name: &str, default: &[usize]) -> Vec<usize> {
        match self.get(name) {
            Some(s) => s.split(',').filter_map(|t| t.trim().parse().ok()).collect(),
            None => default.to_vec(),
        }
    }

    /// Comma-separated list of f64.
    pub fn f64_list_or(&self, name: &str, default: &[f64]) -> Vec<f64> {
        match self.get(name) {
            Some(s) => s.split(',').filter_map(|t| t.trim().parse().ok()).collect(),
            None => default.to_vec(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_mixed() {
        // NOTE: `--name value` is greedy, so bare flags must not be
        // followed by a positional (use `--flag` last or `--k=v` forms).
        let a = Args::parse(sv(&["fig2", "pos2", "--steps", "100", "--seeds=3", "--verbose"]));
        assert_eq!(a.positional, vec!["fig2", "pos2"]);
        assert_eq!(a.usize_or("steps", 0), 100);
        assert_eq!(a.usize_or("seeds", 0), 3);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
        assert_eq!(a.usize_or("missing", 7), 7);
    }

    #[test]
    fn lists() {
        let a = Args::parse(sv(&["--ks", "1,8,32", "--lrs=1e-6,3e-6"]));
        assert_eq!(a.usize_list_or("ks", &[]), vec![1, 8, 32]);
        assert_eq!(a.f64_list_or("lrs", &[]), vec![1e-6, 3e-6]);
        assert_eq!(a.usize_list_or("absent", &[4]), vec![4]);
    }
}
