//! Small self-contained utilities standing in for crates that are not
//! available in the offline build image (serde_json, clap, rayon,
//! proptest, criterion, rand). See DESIGN.md §2.

pub mod bench;
pub mod cli;
pub mod json;
pub mod pool;
pub mod prop;
pub mod retry;
pub mod rng;
pub mod sync;

use std::path::Path;

/// Format a byte count human-readably (e.g. "1.77 GB").
pub fn fmt_bytes(bytes: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KB", "MB", "GB", "TB"];
    let mut v = bytes as f64;
    let mut u = 0;
    while v >= 1000.0 && u + 1 < UNITS.len() {
        v /= 1000.0;
        u += 1;
    }
    if u == 0 {
        format!("{} {}", bytes, UNITS[0])
    } else {
        format!("{:.2} {}", v, UNITS[u])
    }
}

/// Mean of a slice (0.0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Sample standard deviation (0.0 for <2 elements).
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Standard error of the mean.
pub fn sem(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    stddev(xs) / (xs.len() as f64).sqrt()
}

/// p-th percentile (0..=100) by linear interpolation on a sorted copy.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut s: Vec<f64> = xs.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (s.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        s[lo]
    } else {
        s[lo] + (s[hi] - s[lo]) * (rank - lo as f64)
    }
}

/// Write `contents` to `path` atomically (write temp + rename), creating
/// parent directories. Atomicity is what the object-store ready-marker
/// protocol relies on.
pub fn atomic_write(path: &Path, contents: &[u8]) -> std::io::Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let tmp = path.with_extension(format!(
        "tmp.{}.{}",
        std::process::id(),
        // pallas-lint: allow(clock-seam): entropy for a unique temp name, never compared as time
        std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.subsec_nanos())
            .unwrap_or(0)
    ));
    std::fs::write(&tmp, contents)?;
    std::fs::rename(&tmp, path)?;
    Ok(())
}

/// Hex-encode bytes.
pub fn hex(bytes: &[u8]) -> String {
    let mut s = String::with_capacity(bytes.len() * 2);
    for b in bytes {
        s.push_str(&format!("{:02x}", b));
    }
    s
}

/// SHA-256 of a byte slice, hex-encoded.
pub fn sha256_hex(bytes: &[u8]) -> String {
    use sha2::{Digest, Sha256};
    let mut h = Sha256::new();
    h.update(bytes);
    hex(&h.finalize())
}

/// SHA-256 of an `f32` slice viewed as raw little-endian bytes.
pub fn sha256_f32(xs: &[f32]) -> String {
    use sha2::{Digest, Sha256};
    let mut h = Sha256::new();
    h.update(f32_as_bytes(xs));
    hex(&h.finalize())
}

/// View an f32 slice as raw bytes (little-endian host assumed).
pub fn f32_as_bytes(xs: &[f32]) -> &[u8] {
    unsafe { std::slice::from_raw_parts(xs.as_ptr() as *const u8, xs.len() * 4) }
}

/// View a u16 slice as raw bytes.
pub fn u16_as_bytes(xs: &[u16]) -> &[u8] {
    unsafe { std::slice::from_raw_parts(xs.as_ptr() as *const u8, xs.len() * 2) }
}

/// Parse raw little-endian bytes into f32s.
pub fn bytes_to_f32(bytes: &[u8]) -> Vec<f32> {
    assert!(bytes.len() % 4 == 0, "byte length not a multiple of 4");
    bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect()
}

/// Parse raw little-endian bytes into u16s.
pub fn bytes_to_u16(bytes: &[u8]) -> Vec<u16> {
    assert!(bytes.len() % 2 == 0, "byte length not a multiple of 2");
    bytes.chunks_exact(2).map(|c| u16::from_le_bytes([c[0], c[1]])).collect()
}

/// Simple monotonic stopwatch.
pub struct Stopwatch(std::time::Instant);

impl Stopwatch {
    pub fn start() -> Self {
        // pallas-lint: allow(clock-seam): Stopwatch IS the wall-time seam for bench/report timing
        Stopwatch(std::time::Instant::now())
    }
    pub fn secs(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }
    pub fn millis(&self) -> f64 {
        self.0.elapsed().as_secs_f64() * 1e3
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fmt_bytes_scales() {
        assert_eq!(fmt_bytes(999), "999 B");
        assert_eq!(fmt_bytes(140_000_000), "140.00 MB");
        assert_eq!(fmt_bytes(14_000_000_000), "14.00 GB");
    }

    #[test]
    fn stats_basics() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-12);
        assert!((stddev(&xs) - 1.2909944487358056).abs() < 1e-12);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-12);
        assert!((percentile(&xs, 100.0) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn f32_bytes_roundtrip() {
        let xs = vec![1.5f32, -2.25, 0.0, f32::MAX];
        let b = f32_as_bytes(&xs).to_vec();
        assert_eq!(bytes_to_f32(&b), xs);
    }

    #[test]
    fn atomic_write_creates_dirs() {
        let dir = std::env::temp_dir().join(format!("pulse_util_{}", std::process::id()));
        let p = dir.join("a/b/c.txt");
        atomic_write(&p, b"hello").unwrap();
        assert_eq!(std::fs::read(&p).unwrap(), b"hello");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn sha256_known_vector() {
        // sha256("abc")
        assert_eq!(
            sha256_hex(b"abc"),
            "ba7816bf8f01cfea414140de5dae2223b00361a396177a9cb410ff61f20015ad"
        );
    }
}
