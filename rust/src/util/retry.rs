//! One retry/backoff policy for every recovery loop in the sync
//! plane: bounded exponential backoff with deterministic jitter.
//!
//! Before this module each layer hardcoded its own wait — the repair
//! path waited a flat `NACK_TIMEOUT`, control supervisors retried "next
//! tick" on a fixed 20 ms cadence, and the relay re-escalated on
//! whatever cadence its clients happened to NACK. A [`RetryPolicy`]
//! names the same four numbers everywhere (first delay, growth factor,
//! per-attempt cap, total budget) and draws its jitter from
//! [`crate::util::rng::splitmix64`] keyed by `(seed, attempt)`, so a
//! given seed always produces the same backoff schedule — no wall-clock
//! entropy, which keeps chaos runs (`net/chaos`) reproducible.

use std::time::{Duration, Instant};

use crate::util::rng::splitmix64;

/// Bounded exponential backoff: attempt `n` waits
/// `min(cap, base * factor^n)`, jittered deterministically into
/// `[0.75, 1.25)` of itself, until the `total` budget is spent.
#[derive(Clone, Debug)]
pub struct RetryPolicy {
    /// Delay before the first retry.
    pub base: Duration,
    /// Multiplier between consecutive attempts.
    pub factor: f64,
    /// Upper bound on any single delay.
    pub cap: Duration,
    /// Total time budget across all attempts; a caller that drains it
    /// gives up (and should say so in its counters).
    pub total: Duration,
    /// Jitter seed; same seed, same schedule.
    pub seed: u64,
}

impl RetryPolicy {
    pub fn new(base: Duration, factor: f64, cap: Duration, total: Duration) -> RetryPolicy {
        RetryPolicy { base, factor, cap, total, seed: 0 }
    }

    /// Builder-style jitter seed override.
    pub fn with_seed(mut self, seed: u64) -> RetryPolicy {
        self.seed = seed;
        self
    }

    /// Repair-NACK policy: re-send the NACK at ~250 ms, ~500 ms, ~1 s,
    /// ~2 s, and give up after 5 s total — the same overall budget as
    /// the flat `NACK_TIMEOUT` this replaces, so existing behavior at
    /// the deadline is unchanged.
    pub fn nack_default() -> RetryPolicy {
        RetryPolicy::new(
            Duration::from_millis(250),
            2.0,
            Duration::from_secs(2),
            Duration::from_secs(5),
        )
    }

    /// Connect/re-attach policy for control supervisors: first retry
    /// after the old 20 ms tick, backing off to 250 ms, with a 1 s
    /// budget for bounded joins (supervisor loops ignore the budget
    /// and just keep calling [`RetryPolicy::delay_for`]).
    pub fn connect_default() -> RetryPolicy {
        RetryPolicy::new(
            Duration::from_millis(20),
            2.0,
            Duration::from_millis(250),
            Duration::from_secs(1),
        )
    }

    /// Relay upstream-escalation policy: a slot already escalated is
    /// not re-escalated for ~200 ms, doubling to 2 s, so a storm of
    /// client NACK resends costs one upstream frame per backoff window.
    pub fn escalate_default() -> RetryPolicy {
        RetryPolicy::new(
            Duration::from_millis(200),
            2.0,
            Duration::from_secs(2),
            Duration::from_secs(30),
        )
    }

    /// Jittered delay for the `attempt`-th retry (0-based), capped.
    /// Pure in `(self, attempt)` — no clock, no global state.
    pub fn delay_for(&self, attempt: u32) -> Duration {
        let exp = self.base.as_secs_f64() * self.factor.powi(attempt.min(64) as i32);
        let raw = exp.min(self.cap.as_secs_f64());
        let mut s = self
            .seed
            .wrapping_add(0x9E3779B97F4A7C15u64.wrapping_mul(attempt as u64 + 1));
        let unit = (splitmix64(&mut s) >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
        Duration::from_secs_f64(raw * (0.75 + 0.5 * unit))
    }

    /// Begin a budgeted retry sequence anchored at "now" on the wall
    /// clock. Wall-plane convenience over [`RetryPolicy::start_at`].
    pub fn start(&self) -> Retry {
        // pallas-lint: allow(clock-seam): the wall anchor for socket-plane retries; sim uses start_at
        Retry { inner: self.start_at(Duration::ZERO), anchor: Instant::now() }
    }

    /// Begin a budgeted retry sequence anchored at an explicit reading
    /// (`now` from any monotone origin — the wall anchor or a virtual
    /// clock). This is the clock-agnostic core: the scale simulator
    /// (`crate::sim`) drives the *same* schedule/budget arithmetic the
    /// socket plane uses, off its event-loop time instead of real time.
    pub fn start_at(&self, now: Duration) -> RetryAt {
        RetryAt { policy: self.clone(), attempt: 0, deadline: now + self.total }
    }
}

/// In-flight state of one budgeted retry sequence, parameterized by an
/// external time source: every query takes the caller's current `now`
/// reading. [`Retry`] wraps this for wall-clock callers.
pub struct RetryAt {
    policy: RetryPolicy,
    attempt: u32,
    deadline: Duration,
}

impl RetryAt {
    /// Delay to wait before the next attempt given the current reading,
    /// or `None` once waiting would overrun the total budget — the
    /// caller should give up (the absolute cutoff is
    /// [`RetryAt::deadline`]).
    pub fn next_delay_at(&mut self, now: Duration) -> Option<Duration> {
        let d = self.policy.delay_for(self.attempt);
        if now + d >= self.deadline {
            return None;
        }
        self.attempt += 1;
        Some(d)
    }

    /// Retries handed out so far.
    pub fn attempts(&self) -> u32 {
        self.attempt
    }

    /// Absolute give-up reading (start + total budget), on the same
    /// origin the sequence was started with.
    pub fn deadline(&self) -> Duration {
        self.deadline
    }
}

/// In-flight state of one budgeted retry sequence on the wall clock
/// (a [`RetryAt`] anchored at its creation instant).
pub struct Retry {
    inner: RetryAt,
    anchor: Instant,
}

impl Retry {
    /// Delay to wait before the next attempt, or `None` once waiting
    /// would overrun the total budget — the caller should give up (the
    /// absolute cutoff is [`Retry::deadline`]).
    pub fn next_delay(&mut self) -> Option<Duration> {
        let now = self.anchor.elapsed();
        self.inner.next_delay_at(now)
    }

    /// Retries handed out so far.
    pub fn attempts(&self) -> u32 {
        self.inner.attempts()
    }

    /// Absolute give-up instant (start + total budget).
    pub fn deadline(&self) -> Instant {
        self.anchor + self.inner.deadline()
    }
}

/// A wall-clock deadline for bounded poll loops — the socket plane's
/// "wait up to N seconds for X" primitive. Open-coded versions of this
/// (`let t0 = Instant::now(); while t0.elapsed() < budget { sleep }`)
/// are exactly what the `clock-seam` and `retry-discipline` lint rules
/// flag; `Deadline` centralizes the two wall reads and the sleep here,
/// in the one file those rules exempt, so callers stay clean. Waits
/// that need backoff should ride a [`RetryPolicy`] instead — this is
/// for fixed-cadence convergence polls (tests, CLI drivers, heartbeat
/// pacing).
pub struct Deadline {
    end: Instant,
}

impl Deadline {
    /// A deadline `budget` from now.
    pub fn after(budget: Duration) -> Deadline {
        // pallas-lint: allow(clock-seam): wall anchor of the bounded-wait seam; sim polls its own clock
        Deadline { end: Instant::now() + budget }
    }

    /// True once the budget is spent.
    pub fn expired(&self) -> bool {
        // pallas-lint: allow(clock-seam): the matching wall read of the bounded-wait seam
        Instant::now() >= self.end
    }

    /// Sleep one poll step (never past useful precision; a zero step
    /// yields the scheduler slot).
    pub fn tick(&self, step: Duration) {
        std::thread::sleep(step);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deadline_expires_after_budget() {
        let d = Deadline::after(Duration::from_millis(5));
        assert!(!d.expired(), "fresh deadline not yet expired");
        let mut polls = 0;
        while !d.expired() {
            d.tick(Duration::from_millis(1));
            polls += 1;
            assert!(polls < 10_000, "deadline must expire");
        }
        assert!(d.expired());
    }

    #[test]
    fn zero_budget_deadline_is_immediately_expired() {
        assert!(Deadline::after(Duration::ZERO).expired());
    }

    #[test]
    fn schedule_is_deterministic_per_seed() {
        let a = RetryPolicy::nack_default().with_seed(7);
        let b = RetryPolicy::nack_default().with_seed(7);
        for n in 0..10 {
            assert_eq!(a.delay_for(n), b.delay_for(n));
        }
        let c = RetryPolicy::nack_default().with_seed(8);
        assert_ne!(a.delay_for(0), c.delay_for(0), "different seeds jitter differently");
    }

    #[test]
    fn delays_grow_exponentially_then_cap() {
        let p = RetryPolicy::new(
            Duration::from_millis(100),
            2.0,
            Duration::from_secs(1),
            Duration::from_secs(60),
        );
        for n in 0..20u32 {
            let d = p.delay_for(n).as_secs_f64();
            let nominal = (0.1 * 2f64.powi(n as i32)).min(1.0);
            assert!(
                d >= nominal * 0.75 && d < nominal * 1.25,
                "attempt {}: {} outside jitter band of {}",
                n,
                d,
                nominal
            );
        }
        // deep attempts stay finite and capped
        assert!(p.delay_for(63).as_secs_f64() <= 1.25);
    }

    #[test]
    fn budget_exhaustion_returns_none() {
        let p = RetryPolicy::new(
            Duration::from_millis(50),
            2.0,
            Duration::from_millis(50),
            Duration::from_millis(1),
        );
        let mut r = p.start();
        assert!(r.next_delay().is_none(), "a 50ms delay cannot fit a 1ms budget");
        assert_eq!(r.attempts(), 0);
    }

    #[test]
    fn virtual_time_sequence_matches_policy_schedule() {
        // RetryAt under an explicitly advanced clock hands out exactly
        // the policy's jittered delays until the budget is spent —
        // this is the schedule the scale simulator replays.
        let p = RetryPolicy::nack_default().with_seed(3);
        let mut r = p.start_at(Duration::from_secs(10));
        assert_eq!(r.deadline(), Duration::from_secs(15));
        let mut now = Duration::from_secs(10);
        let mut handed = Vec::new();
        while let Some(d) = r.next_delay_at(now) {
            now += d;
            handed.push(d);
            assert!(handed.len() < 64, "budget must bound the sequence");
        }
        assert!(!handed.is_empty(), "a 5s budget fits several 250ms+ delays");
        for (n, d) in handed.iter().enumerate() {
            assert_eq!(*d, p.delay_for(n as u32), "delays come from the shared policy");
        }
        assert!(now + p.delay_for(r.attempts()) >= r.deadline());
    }

    #[test]
    fn nack_default_keeps_the_old_five_second_budget() {
        assert_eq!(RetryPolicy::nack_default().total, Duration::from_secs(5));
    }

    #[test]
    fn budgeted_sequence_hands_out_several_attempts() {
        let p = RetryPolicy::new(
            Duration::from_millis(1),
            2.0,
            Duration::from_millis(2),
            Duration::from_secs(5),
        );
        let mut r = p.start();
        let mut got = 0;
        for _ in 0..5 {
            if r.next_delay().is_some() {
                got += 1;
            }
        }
        assert_eq!(got, 5, "tiny delays all fit a 5s budget");
    }
}
