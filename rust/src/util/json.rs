//! Minimal JSON parser/serializer (serde_json substitute).
//!
//! Used for artifact manifests written by `python/compile/aot.py`, for
//! the coordinator config files, and for metrics/CSV sidecars. Supports
//! the full JSON grammar except for exotic number forms beyond f64.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use a BTreeMap so serialization is
/// deterministic (important for signed manifests).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn set(&mut self, key: &str, value: Json) -> &mut Self {
        if let Json::Obj(m) = self {
            m.insert(key.to_string(), value);
        } else {
            panic!("set() on non-object");
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(v) => v.get(i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Required-field helpers that produce useful errors.
    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key).ok_or_else(|| anyhow::anyhow!("missing JSON key '{}'", key))
    }

    pub fn req_str(&self, key: &str) -> anyhow::Result<&str> {
        self.req(key)?
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("JSON key '{}' is not a string", key))
    }

    pub fn req_f64(&self, key: &str) -> anyhow::Result<f64> {
        self.req(key)?
            .as_f64()
            .ok_or_else(|| anyhow::anyhow!("JSON key '{}' is not a number", key))
    }

    pub fn req_usize(&self, key: &str) -> anyhow::Result<usize> {
        Ok(self.req_f64(key)? as usize)
    }

    /// Fetch `key` or return a default number.
    pub fn num_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|j| j.as_f64()).unwrap_or(default)
    }

    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).and_then(|j| j.as_str()).unwrap_or(default)
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(|j| j.as_bool()).unwrap_or(default)
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    /// Serialize with 2-space indentation.
    pub fn to_pretty(&self) -> String {
        let mut s = String::new();
        self.write_pretty(&mut s, 0);
        s.push('\n');
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => write_num(*n, out),
            Json::Str(s) => write_str(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_str(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    fn write_pretty(&self, out: &mut String, indent: usize) {
        let pad = "  ".repeat(indent + 1);
        let pad_close = "  ".repeat(indent);
        match self {
            Json::Arr(v) if !v.is_empty() => {
                out.push_str("[\n");
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    out.push_str(&pad);
                    x.write_pretty(out, indent + 1);
                }
                out.push('\n');
                out.push_str(&pad_close);
                out.push(']');
            }
            Json::Obj(m) if !m.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    out.push_str(&pad);
                    write_str(k, out);
                    out.push_str(": ");
                    v.write_pretty(out, indent + 1);
                }
                out.push('\n');
                out.push_str(&pad_close);
                out.push('}');
            }
            other => other.write(out),
        }
    }

    /// Parse a JSON document.
    pub fn parse(text: &str) -> anyhow::Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            anyhow::bail!("trailing characters at offset {}", p.i);
        }
        Ok(v)
    }

    /// Parse the JSON file at `path`.
    pub fn parse_file(path: &std::path::Path) -> anyhow::Result<Json> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {}: {}", path.display(), e))?;
        Json::parse(&text).map_err(|e| anyhow::anyhow!("parsing {}: {}", path.display(), e))
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string())
    }
}

impl From<f64> for Json {
    fn from(n: f64) -> Json {
        Json::Num(n)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Json {
        Json::Num(n as f64)
    }
}
impl From<u64> for Json {
    fn from(n: u64) -> Json {
        Json::Num(n as f64)
    }
}
impl From<i64> for Json {
    fn from(n: i64) -> Json {
        Json::Num(n as f64)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

fn write_num(n: f64, out: &mut String) {
    if !n.is_finite() {
        out.push_str("null"); // JSON has no Inf/NaN
    } else if n == n.trunc() && n.abs() < 9.0e15 {
        out.push_str(&format!("{}", n as i64));
    } else {
        // 17 significant digits round-trips f64 exactly.
        out.push_str(&format!("{:e}", n).replace('e', "e"));
    }
}

fn write_str(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> anyhow::Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            anyhow::bail!("expected '{}' at offset {}", c as char, self.i)
        }
    }

    fn value(&mut self) -> anyhow::Result<Json> {
        self.ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => anyhow::bail!("unexpected {:?} at offset {}", other.map(|c| c as char), self.i),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> anyhow::Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            anyhow::bail!("invalid literal at offset {}", self.i)
        }
    }

    fn object(&mut self) -> anyhow::Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => anyhow::bail!("expected ',' or '}}' at offset {}", self.i),
            }
        }
    }

    fn array(&mut self) -> anyhow::Result<Json> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => anyhow::bail!("expected ',' or ']' at offset {}", self.i),
            }
        }
    }

    fn string(&mut self) -> anyhow::Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => anyhow::bail!("unterminated string"),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            // Surrogate pairs: join if a high surrogate.
                            if (0xD800..0xDC00).contains(&cp)
                                && self.b.get(self.i + 5) == Some(&b'\\')
                                && self.b.get(self.i + 6) == Some(&b'u')
                            {
                                let hex2 = std::str::from_utf8(&self.b[self.i + 7..self.i + 11])?;
                                let lo = u32::from_str_radix(hex2, 16)?;
                                let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                s.push(char::from_u32(c).unwrap_or('\u{FFFD}'));
                                self.i += 10;
                            } else {
                                s.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                                self.i += 4;
                            }
                        }
                        other => anyhow::bail!("bad escape {:?}", other.map(|c| c as char)),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.b[self.i..])?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> anyhow::Result<Json> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>()?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let txt = r#"{"a": 1, "b": [true, null, "x\ny"], "c": {"d": -2.5e3}}"#;
        let v = Json::parse(txt).unwrap();
        assert_eq!(v.req_f64("a").unwrap(), 1.0);
        assert_eq!(v.get("b").unwrap().idx(2).unwrap().as_str().unwrap(), "x\ny");
        assert_eq!(v.get("c").unwrap().req_f64("d").unwrap(), -2500.0);
        // serialize → parse → equal
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
        let v3 = Json::parse(&v.to_pretty()).unwrap();
        assert_eq!(v, v3);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn unicode_escapes() {
        let v = Json::parse(r#""A😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "A😀");
    }

    #[test]
    fn builder_api() {
        let mut j = Json::obj();
        j.set("name", "pulse".into()).set("n", 3usize.into()).set(
            "xs",
            vec![1.0f64, 2.0, 3.0].into(),
        );
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed.req_str("name").unwrap(), "pulse");
        assert_eq!(parsed.get("xs").unwrap().as_arr().unwrap().len(), 3);
    }

    #[test]
    fn big_and_small_numbers() {
        for n in [0.0, 1e-30, 3.0e-6, 123456789.0, -7.62e9, 0.1] {
            let v = Json::parse(&Json::Num(n).to_string()).unwrap();
            assert_eq!(v.as_f64().unwrap(), n, "n={}", n);
        }
    }
}
