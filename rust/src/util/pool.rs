//! Data-parallel helpers over `std::thread::scope` (rayon substitute).
//!
//! The hot paths that use these are embarrassingly parallel over disjoint
//! chunks (bitwise diff, gate, Adam step), so scoped threads with static
//! partitioning are enough — and allocation-free once the closure is set.

/// Number of worker threads to use: respects `PULSE_THREADS`, defaults
/// to available parallelism capped at 16.
pub fn num_threads() -> usize {
    if let Ok(v) = std::env::var("PULSE_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(16)
}

/// Run `f(chunk_index, chunk)` over disjoint mutable chunks of `data` in
/// parallel. Chunks are contiguous and cover the slice exactly.
pub fn par_chunks_mut<T: Send, F>(data: &mut [T], min_chunk: usize, f: F)
where
    F: Fn(usize, usize, &mut [T]) + Sync,
{
    let n = data.len();
    if n == 0 {
        return;
    }
    let workers = num_threads().min(n.div_ceil(min_chunk)).max(1);
    if workers == 1 {
        f(0, 0, data);
        return;
    }
    let chunk = n.div_ceil(workers);
    std::thread::scope(|s| {
        for (i, piece) in data.chunks_mut(chunk).enumerate() {
            let f = &f;
            s.spawn(move || f(i, i * chunk, piece));
        }
    });
}

/// Parallel map over index ranges: splits `0..n` into contiguous ranges,
/// calls `f(range)` on each in parallel, returns the per-range outputs in
/// order.
pub fn par_ranges<R: Send, F>(n: usize, min_chunk: usize, f: F) -> Vec<R>
where
    F: Fn(std::ops::Range<usize>) -> R + Sync,
{
    if n == 0 {
        return Vec::new();
    }
    let workers = num_threads().min(n.div_ceil(min_chunk)).max(1);
    let chunk = n.div_ceil(workers);
    let mut bounds = Vec::new();
    let mut lo = 0;
    while lo < n {
        let hi = (lo + chunk).min(n);
        bounds.push(lo..hi);
        lo = hi;
    }
    if bounds.len() == 1 {
        return vec![f(bounds.pop().unwrap())];
    }
    let mut out: Vec<Option<R>> = (0..bounds.len()).map(|_| None).collect();
    std::thread::scope(|s| {
        for (slot, range) in out.iter_mut().zip(bounds.into_iter()) {
            let f = &f;
            s.spawn(move || {
                *slot = Some(f(range));
            });
        }
    });
    out.into_iter().map(|o| o.unwrap()).collect()
}

/// Run N independent jobs in parallel and collect their outputs in order.
/// Used by the coordinator to run R trainer workers per round.
pub fn par_map<T: Send, R: Send, F>(inputs: Vec<T>, f: F) -> Vec<R>
where
    F: Fn(usize, T) -> R + Sync,
{
    let n = inputs.len();
    let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
    std::thread::scope(|s| {
        for (i, (slot, input)) in out.iter_mut().zip(inputs.into_iter()).enumerate() {
            let f = &f;
            s.spawn(move || {
                *slot = Some(f(i, input));
            });
        }
    });
    out.into_iter().map(|o| o.unwrap()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunks_cover_everything() {
        let mut v = vec![0u64; 100_000];
        par_chunks_mut(&mut v, 1024, |_, base, chunk| {
            for (i, x) in chunk.iter_mut().enumerate() {
                *x = (base + i) as u64;
            }
        });
        for (i, x) in v.iter().enumerate() {
            assert_eq!(*x, i as u64);
        }
    }

    #[test]
    fn ranges_sum() {
        let total: usize = par_ranges(1000, 16, |r| r.sum::<usize>()).into_iter().sum();
        assert_eq!(total, 1000 * 999 / 2);
    }

    #[test]
    fn par_map_order() {
        let out = par_map((0..32).collect::<Vec<_>>(), |i, x| {
            assert_eq!(i, x);
            x * 2
        });
        assert_eq!(out, (0..32).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn empty_ok() {
        let mut v: Vec<u8> = vec![];
        par_chunks_mut(&mut v, 8, |_, _, _| panic!("should not run"));
        assert!(par_ranges(0, 8, |_| 0).is_empty());
    }
}
