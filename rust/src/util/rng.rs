//! Deterministic PRNG (PCG64 / SplitMix64) plus the handful of
//! distributions the library needs. Stands in for the `rand` crate,
//! which is not in the offline image.

/// SplitMix64 — used for seeding and cheap stateless hashing.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// PCG-XSH-RR 64/32: small, fast, statistically solid PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
    inc: u64,
}

impl Rng {
    /// Create from a seed; distinct seeds give independent streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let state = splitmix64(&mut sm);
        let inc = splitmix64(&mut sm) | 1;
        let mut rng = Rng { state, inc };
        rng.next_u32();
        rng
    }

    /// Derive an independent child stream (for per-worker RNGs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        let mut s = self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15);
        Rng::new(splitmix64(&mut s))
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(6364136223846793005).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut lo = m as u64;
        if lo < n {
            let t = n.wrapping_neg() % n;
            while lo < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                lo = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform in [lo, hi].
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as i64
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal with given mean and std.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Log-normal: exp(N(mu, sigma)).
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        self.normal_ms(mu, sigma).exp()
    }

    /// Fill a slice with N(0, std) f32s.
    pub fn fill_normal_f32(&mut self, out: &mut [f32], std: f32) {
        for x in out.iter_mut() {
            *x = (self.normal() as f32) * std;
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "weighted() needs positive total weight");
        let mut t = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            t -= w;
            if t <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(8);
        assert_ne!(Rng::new(7).next_u64(), c.next_u64());
    }

    #[test]
    fn uniform_mean_is_half() {
        let mut r = Rng::new(1);
        let n = 20000;
        let m: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((m - 0.5).abs() < 0.01, "mean {}", m);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(2);
        let n = 50000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let m = xs.iter().sum::<f64>() / n as f64;
        let v = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / n as f64;
        assert!(m.abs() < 0.02, "mean {}", m);
        assert!((v - 1.0).abs() < 0.05, "var {}", v);
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            let x = r.below(10);
            assert!(x < 10);
        }
        // all residues hit
        let mut seen = [false; 10];
        for _ in 0..1000 {
            seen[r.below(10) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn fork_streams_differ() {
        let mut root = Rng::new(42);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn weighted_respects_weights() {
        let mut r = Rng::new(5);
        let w = [0.0, 1.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..4000 {
            counts[r.weighted(&w)] += 1;
        }
        assert_eq!(counts[0], 0);
        assert!(counts[2] > counts[1] * 2);
    }
}
