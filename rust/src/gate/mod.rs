//! The compute-visibility gate (paper Eq. 1):
//!
//! ```text
//!   G_D(θ, s) = { i : cast_D(θ_i) ≠ cast_D(θ_i − s_i) }
//! ```
//!
//! An update entry is transmitted iff it would change the value the next
//! forward pass sees in compute dtype `D`. The gate has **no tunable
//! threshold** — sparsity is set entirely by the forward precision.

pub mod feedback;

use crate::bf16::{self, Dtype};
use crate::util::pool;

/// Apply the gate for dtype `d` over FP32 parameters `theta` and a
/// proposed update `s` (new value would be `theta[i] - s[i]`). Returns
/// the sorted indices that pass (i.e. are compute-visible).
pub fn gate(d: Dtype, theta: &[f32], s: &[f32]) -> Vec<u64> {
    assert_eq!(theta.len(), s.len());
    match d {
        Dtype::Bf16 => gate_bf16(theta, s),
        Dtype::Fp8E4M3 => gate_fp8(theta, s),
        Dtype::Mxfp4 => gate_mxfp4(theta, s),
    }
}

/// BF16 gate, parallel over chunks. This is the hot path: a branch-free
/// bit compare of the two RNE casts per element.
pub fn gate_bf16(theta: &[f32], s: &[f32]) -> Vec<u64> {
    let parts = pool::par_ranges(theta.len(), 1 << 16, |r| {
        let mut v = Vec::new();
        for i in r {
            let before = bf16::f32_to_bf16_bits(theta[i]);
            let after = bf16::f32_to_bf16_bits(theta[i] - s[i]);
            if before != after {
                v.push(i as u64);
            }
        }
        v
    });
    concat(parts)
}

fn gate_fp8(theta: &[f32], s: &[f32]) -> Vec<u64> {
    let parts = pool::par_ranges(theta.len(), 1 << 16, |r| {
        let mut v = Vec::new();
        for i in r {
            if bf16::fp8::f32_to_fp8_bits(theta[i]) != bf16::fp8::f32_to_fp8_bits(theta[i] - s[i])
            {
                v.push(i as u64);
            }
        }
        v
    });
    concat(parts)
}

/// MXFP4 gate: per-block scale is taken from the *pre-update* block
/// (fixed-scale assumption of paper §D).
fn gate_mxfp4(theta: &[f32], s: &[f32]) -> Vec<u64> {
    use crate::bf16::mxfp4;
    let nblocks = theta.len().div_ceil(mxfp4::BLOCK);
    let parts = pool::par_ranges(nblocks, 256, |r| {
        let mut v = Vec::new();
        for b in r {
            let lo = b * mxfp4::BLOCK;
            let hi = (lo + mxfp4::BLOCK).min(theta.len());
            let scale = mxfp4::block_scale(&theta[lo..hi]);
            for i in lo..hi {
                if mxfp4::visible_in_block(theta[i], theta[i] - s[i], scale) {
                    v.push(i as u64);
                }
            }
        }
        v
    });
    concat(parts)
}

fn concat(parts: Vec<Vec<u64>>) -> Vec<u64> {
    let total: usize = parts.iter().map(|p| p.len()).sum();
    let mut out = Vec::with_capacity(total);
    for p in parts {
        out.extend(p);
    }
    out
}

/// Count-only variant of the BF16 gate (for sparsity metering without
/// allocating the index list).
pub fn count_visible_bf16(theta: &[f32], s: &[f32]) -> usize {
    pool::par_ranges(theta.len(), 1 << 16, |r| {
        let mut c = 0usize;
        for i in r {
            if bf16::f32_to_bf16_bits(theta[i]) != bf16::f32_to_bf16_bits(theta[i] - s[i]) {
                c += 1;
            }
        }
        c
    })
    .into_iter()
    .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn zero_update_invisible() {
        let theta = vec![0.5f32; 1000];
        let s = vec![0.0f32; 1000];
        assert!(gate_bf16(&theta, &s).is_empty());
    }

    #[test]
    fn tiny_updates_absorbed_large_visible() {
        // |w| = 0.5, cell radius ≈ 0.5/256 ≈ 2e-3.
        let theta = vec![0.5f32; 100];
        let tiny = vec![1e-6f32; 100]; // far below threshold
        assert!(gate_bf16(&theta, &tiny).is_empty());
        let big = vec![0.01f32; 100]; // ≈ 5x the cell
        assert_eq!(gate_bf16(&theta, &big).len(), 100);
    }

    #[test]
    fn gate_matches_cast_diff_exactly() {
        // Equivalence: i ∈ G ⇔ cast(θ) ≠ cast(θ − s). Cross-check against
        // an independent diff of cast slices.
        crate::util::prop::check("gate == cast diff", 30, |g| {
            let n = g.len().max(1);
            let theta = g.f32_vec(n);
            let s: Vec<f32> = theta
                .iter()
                .map(|_| (g.rng.normal() as f32) * 10f32.powi(g.rng.range_i64(-9, -1) as i32))
                .collect();
            let idx = gate_bf16(&theta, &s);
            let mut old_bits = Vec::new();
            let mut new_bits = Vec::new();
            crate::bf16::cast_slice(&theta, &mut old_bits);
            let after: Vec<f32> = theta.iter().zip(&s).map(|(&t, &u)| t - u).collect();
            crate::bf16::cast_slice(&after, &mut new_bits);
            let expect = crate::sparse::diff_bf16(&old_bits, &new_bits);
            assert_eq!(idx, expect);
        });
    }

    #[test]
    fn learning_rate_controls_sparsity() {
        // The paper's core claim in miniature: at LLM-like |w| (≈0.01)
        // and η=3e-6, Adam-scale updates are ~99% absorbed; at 100x the
        // LR they are mostly visible (Fig. 15).
        let mut rng = Rng::new(7);
        let n = 50_000;
        // Two-piece lognormal calibrated to Table 2 (median 0.0114,
        // 5th %ile 0.0010, 95th %ile 0.0374): heavier left tail.
        // BF16-align the masters (cell centers) so the gate reduces to
        // the binary |Δ| vs half-ULP threshold of Def. A.3. (With
        // arbitrary intra-cell positions the crossing probability is
        // |Δ|/cell per step — the drift regime measured in fig2.)
        let theta: Vec<f32> = (0..n)
            .map(|_| {
                let z = rng.normal();
                let sigma = if z < 0.0 { 1.48 } else { 0.72 };
                crate::bf16::bf16_round((-4.47 + sigma * z).exp() as f32)
            })
            .collect();
        let unit: Vec<f32> = (0..n).map(|_| if rng.f64() < 0.5 { 1.0 } else { -1.0 }).collect();
        let small: Vec<f32> = unit.iter().map(|u| u * 3e-6).collect();
        let large: Vec<f32> = unit.iter().map(|u| u * 3e-4).collect();
        let sp_small = 1.0 - gate_bf16(&theta, &small).len() as f64 / n as f64;
        let sp_large = 1.0 - gate_bf16(&theta, &large).len() as f64 / n as f64;
        assert!(sp_small > 0.95, "small-LR sparsity {}", sp_small);
        assert!(sp_large < 0.55, "large-LR sparsity {}", sp_large);
    }

    #[test]
    fn lower_precision_gates_are_sparser() {
        // §D: coarser formats absorb more. Same weights+updates, the
        // visible set should shrink monotonically BF16 ⊇ FP8 ⊇ MXFP4
        // in count (not necessarily by inclusion for MXFP4).
        let mut rng = Rng::new(8);
        let n = 20_000;
        let theta: Vec<f32> = (0..n).map(|_| rng.lognormal(-4.5, 1.1) as f32).collect();
        let s: Vec<f32> = (0..n).map(|_| (rng.normal() as f32) * 3e-5).collect();
        let nb = gate(Dtype::Bf16, &theta, &s).len();
        let nf = gate(Dtype::Fp8E4M3, &theta, &s).len();
        let nm = gate(Dtype::Mxfp4, &theta, &s).len();
        assert!(nf <= nb, "fp8 {} vs bf16 {}", nf, nb);
        assert!(nm <= nf, "mxfp4 {} vs fp8 {}", nm, nf);
    }

    #[test]
    fn count_matches_gather() {
        let mut rng = Rng::new(9);
        let n = 30_000;
        let theta: Vec<f32> = (0..n).map(|_| rng.normal() as f32 * 0.02).collect();
        let s: Vec<f32> = (0..n).map(|_| rng.normal() as f32 * 1e-4).collect();
        assert_eq!(count_visible_bf16(&theta, &s), gate_bf16(&theta, &s).len());
    }
}
