//! FP32 error-feedback buffer for PULSELoCo (paper §4.3, Alg. 2 lines
//! 8–11).
//!
//! Entries that fail the gate are *kept, not dropped*: they accumulate in
//! the buffer and are reconsidered (added to the next pseudo-gradient)
//! every round — mirroring how sub-cell updates accumulate in FP32
//! master weights until they cross a BF16 boundary.

use crate::bf16::Dtype;

/// Per-worker error-feedback state.
#[derive(Debug, Clone)]
pub struct ErrorFeedback {
    pub residual: Vec<f32>,
    pub dtype: Dtype,
}

/// Output of one gating round.
pub struct Gated {
    /// Indices selected for synchronization (sorted).
    pub indices: Vec<u64>,
    /// FP32 values of the *combined* update (Δ + e) at those indices.
    pub values: Vec<f32>,
    /// Total combined-update entries considered.
    pub total: usize,
}

impl Gated {
    pub fn sparsity(&self) -> f64 {
        crate::sparse::sparsity(self.indices.len(), self.total)
    }
}

impl ErrorFeedback {
    pub fn new(n: usize, dtype: Dtype) -> Self {
        ErrorFeedback { residual: vec![0.0; n], dtype }
    }

    /// Alg. 2 lines 8–11: form `s = delta + e`, gate it against `theta`,
    /// zero the sent entries of `e`, and keep the unsent entries.
    /// Returns the sparse payload to synchronize.
    pub fn gate_and_update(&mut self, theta: &[f32], delta: &[f32]) -> Gated {
        assert_eq!(theta.len(), delta.len());
        assert_eq!(theta.len(), self.residual.len());
        // s_r^(t) = Δ_r^(t) + e_r^(t-1)
        let s: Vec<f32> =
            delta.iter().zip(&self.residual).map(|(&d, &e)| d + e).collect();
        let indices = super::gate(self.dtype, theta, &s);
        let values: Vec<f32> = indices.iter().map(|&i| s[i as usize]).collect();
        // e[sent] = 0 ; e[unsent] = s[unsent]
        self.residual.copy_from_slice(&s);
        for &i in &indices {
            self.residual[i as usize] = 0.0;
        }
        Gated { indices, values, total: theta.len() }
    }

    /// L∞ of the residual (diagnostic).
    pub fn residual_linf(&self) -> f32 {
        self.residual.iter().fold(0.0f32, |m, &x| m.max(x.abs()))
    }

    /// Sum |e| (diagnostic: how much update is in flight).
    pub fn residual_l1(&self) -> f64 {
        self.residual.iter().map(|&x| x.abs() as f64).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn conservation_sent_plus_kept_equals_s() {
        // Invariant: after gating, payload(i)+residual(i) reconstruct
        // s = delta + e_prev exactly at every position.
        crate::util::prop::check("error feedback conserves mass", 30, |g| {
            let n = g.len().max(1);
            let theta = g.f32_vec(n);
            let mut ef = ErrorFeedback::new(n, Dtype::Bf16);
            for x in ef.residual.iter_mut() {
                *x = (g.rng.normal() as f32) * 1e-6;
            }
            let e_prev = ef.residual.clone();
            let delta: Vec<f32> = (0..n).map(|_| (g.rng.normal() as f32) * 1e-5).collect();
            let out = ef.gate_and_update(&theta, &delta);
            // reconstruct s from (payload, residual)
            let mut s_rec = ef.residual.clone();
            for (&i, &v) in out.indices.iter().zip(&out.values) {
                assert_eq!(s_rec[i as usize], 0.0, "sent entry must be cleared");
                s_rec[i as usize] = v;
            }
            for i in 0..n {
                let expect = delta[i] + e_prev[i];
                assert_eq!(s_rec[i], expect, "i={}", i);
            }
        });
    }

    #[test]
    fn small_updates_accumulate_until_visible() {
        // A constant sub-cell update must eventually pass the gate via
        // the error buffer (paper: "accumulate until they become
        // visible").
        let theta = vec![0.5f32; 4];
        let mut ef = ErrorFeedback::new(4, Dtype::Bf16);
        // cell radius at 0.5 is ~0.5/256 ≈ 1.95e-3; send 1e-4 per round
        let delta = vec![1e-4f32; 4];
        let mut sent_round = None;
        for round in 0..100 {
            let out = ef.gate_and_update(&theta, &delta);
            if !out.indices.is_empty() {
                sent_round = Some(round);
                break;
            }
        }
        let r = sent_round.expect("update never became visible");
        assert!(r >= 5 && r <= 40, "accumulated for {} rounds", r);
    }

    #[test]
    fn visible_updates_sent_immediately_and_buffer_stays_clean() {
        let mut rng = Rng::new(3);
        let n = 1000;
        let theta: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let mut ef = ErrorFeedback::new(n, Dtype::Bf16);
        let delta: Vec<f32> = theta.iter().map(|&t| t * 0.05).collect(); // 5% change
        let out = ef.gate_and_update(&theta, &delta);
        assert!(out.indices.len() > n * 9 / 10);
        for &i in &out.indices {
            assert_eq!(ef.residual[i as usize], 0.0);
        }
    }
}
