//! S3-like object store substrate (paper §E.1: "All coordination occurs
//! through S3-compatible object storage").
//!
//! File-backed implementation with the semantics the grail / PULSESync
//! protocols rely on: atomic single-object puts (write-temp + rename),
//! prefix listing, signed manifests, and explicit *ready markers*
//! (paper §J.1) so a consumer never observes a partially-uploaded
//! checkpoint. Retention policy per §J.7 lives in [`retention`].

pub mod retention;

use crate::util::{atomic_write, sha256_hex};
use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};

/// A bucket rooted at a local directory. Keys are `/`-separated paths.
#[derive(Debug, Clone)]
pub struct ObjectStore {
    root: PathBuf,
    /// Simulated per-object latency knob used by deployment sims (s).
    pub put_latency: f64,
}

impl ObjectStore {
    pub fn open(root: impl Into<PathBuf>) -> Result<ObjectStore> {
        let root = root.into();
        std::fs::create_dir_all(&root)
            .with_context(|| format!("creating bucket root {}", root.display()))?;
        Ok(ObjectStore { root, put_latency: 0.0 })
    }

    /// Create a store under a fresh temp directory (tests).
    pub fn temp(tag: &str) -> Result<ObjectStore> {
        let dir = std::env::temp_dir().join(format!(
            "pulse_store_{}_{}_{}",
            tag,
            std::process::id(),
            // pallas-lint: allow(clock-seam): entropy for a unique temp-dir name, never compared as time
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .unwrap()
                .as_nanos()
        ));
        ObjectStore::open(dir)
    }

    pub fn root(&self) -> &Path {
        &self.root
    }

    fn path_of(&self, key: &str) -> Result<PathBuf> {
        if key.is_empty() || key.starts_with('/') || key.split('/').any(|c| c == "..") {
            bail!("invalid object key '{}'", key);
        }
        Ok(self.root.join(key))
    }

    /// Atomic put: the object is either fully visible or absent.
    pub fn put(&self, key: &str, data: &[u8]) -> Result<()> {
        let p = self.path_of(key)?;
        atomic_write(&p, data).with_context(|| format!("put {}", key))?;
        Ok(())
    }

    pub fn get(&self, key: &str) -> Result<Vec<u8>> {
        let p = self.path_of(key)?;
        std::fs::read(&p).with_context(|| format!("get {}", key))
    }

    pub fn exists(&self, key: &str) -> bool {
        self.path_of(key).map(|p| p.exists()).unwrap_or(false)
    }

    pub fn delete(&self, key: &str) -> Result<()> {
        let p = self.path_of(key)?;
        if p.exists() {
            std::fs::remove_file(&p).with_context(|| format!("delete {}", key))?;
        }
        Ok(())
    }

    pub fn size(&self, key: &str) -> Result<u64> {
        let p = self.path_of(key)?;
        Ok(std::fs::metadata(&p)?.len())
    }

    /// List keys under `prefix` (recursive), sorted.
    pub fn list(&self, prefix: &str) -> Result<Vec<String>> {
        let base = if prefix.is_empty() { self.root.clone() } else { self.path_of(prefix)? };
        let mut out = Vec::new();
        if base.is_dir() {
            walk(&base, &self.root, &mut out)?;
        } else if base.is_file() {
            out.push(prefix.to_string());
        }
        out.sort();
        Ok(out)
    }
}

fn walk(dir: &Path, root: &Path, out: &mut Vec<String>) -> Result<()> {
    for entry in std::fs::read_dir(dir)? {
        let entry = entry?;
        let p = entry.path();
        if p.is_dir() {
            walk(&p, root, out)?;
        } else if let Ok(rel) = p.strip_prefix(root) {
            // skip in-flight temp files from atomic_write
            if rel.to_string_lossy().contains(".tmp.") {
                continue;
            }
            out.push(rel.to_string_lossy().replace('\\', "/"));
        }
    }
    Ok(())
}

/// A signed manifest over a set of objects (paper §J.4 "file-level
/// integrity"): per-file SHA-256 plus a signature binding the manifest
/// to the trainer key (SHA-256(key || canonical entries)).
#[derive(Debug, Clone)]
pub struct Manifest {
    pub entries: Vec<(String, String)>, // (key, sha256hex)
    pub signature: String,
}

impl Manifest {
    pub fn build(store: &ObjectStore, keys: &[String], signing_key: &str) -> Result<Manifest> {
        let mut entries = Vec::with_capacity(keys.len());
        for k in keys {
            let data = store.get(k)?;
            entries.push((k.clone(), sha256_hex(&data)));
        }
        entries.sort();
        let signature = sign(&entries, signing_key);
        Ok(Manifest { entries, signature })
    }

    pub fn to_json(&self) -> String {
        use crate::util::json::Json;
        let mut j = Json::obj();
        let files: Vec<Json> = self
            .entries
            .iter()
            .map(|(k, h)| {
                let mut e = Json::obj();
                e.set("key", k.as_str().into()).set("sha256", h.as_str().into());
                e
            })
            .collect();
        j.set("files", Json::Arr(files)).set("signature", self.signature.as_str().into());
        j.to_pretty()
    }

    pub fn from_json(text: &str) -> Result<Manifest> {
        use crate::util::json::Json;
        let j = Json::parse(text)?;
        let mut entries = Vec::new();
        for f in j.req("files")?.as_arr().unwrap_or(&[]) {
            entries.push((f.req_str("key")?.to_string(), f.req_str("sha256")?.to_string()));
        }
        Ok(Manifest { entries, signature: j.req_str("signature")?.to_string() })
    }

    /// Verify the signature and every object hash.
    pub fn verify(&self, store: &ObjectStore, signing_key: &str) -> Result<()> {
        if sign(&self.entries, signing_key) != self.signature {
            bail!("manifest signature mismatch");
        }
        for (k, h) in &self.entries {
            let data = store.get(k)?;
            let got = sha256_hex(&data);
            if &got != h {
                bail!("object '{}' hash mismatch (expected {}, got {})", k, h, got);
            }
        }
        Ok(())
    }
}

fn sign(entries: &[(String, String)], key: &str) -> String {
    let mut buf = Vec::new();
    buf.extend_from_slice(key.as_bytes());
    for (k, h) in entries {
        buf.extend_from_slice(k.as_bytes());
        buf.push(0);
        buf.extend_from_slice(h.as_bytes());
        buf.push(0);
    }
    sha256_hex(&buf)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn put_get_list_delete() {
        let s = ObjectStore::temp("basic").unwrap();
        s.put("ckpt/step_1/delta.bin", b"abc").unwrap();
        s.put("ckpt/step_1/READY", b"").unwrap();
        s.put("ckpt/step_2/delta.bin", b"def").unwrap();
        assert_eq!(s.get("ckpt/step_1/delta.bin").unwrap(), b"abc");
        assert!(s.exists("ckpt/step_1/READY"));
        let keys = s.list("ckpt").unwrap();
        assert_eq!(keys.len(), 3);
        assert_eq!(keys[0], "ckpt/step_1/READY");
        s.delete("ckpt/step_1/READY").unwrap();
        assert!(!s.exists("ckpt/step_1/READY"));
        std::fs::remove_dir_all(s.root()).unwrap();
    }

    #[test]
    fn rejects_path_escape() {
        let s = ObjectStore::temp("escape").unwrap();
        assert!(s.put("../evil", b"x").is_err());
        assert!(s.put("/abs", b"x").is_err());
        assert!(s.put("a/../../b", b"x").is_err());
        std::fs::remove_dir_all(s.root()).unwrap();
    }

    #[test]
    fn manifest_sign_verify_tamper() {
        let s = ObjectStore::temp("manifest").unwrap();
        s.put("w/a.bin", b"payload-a").unwrap();
        s.put("w/b.bin", b"payload-b").unwrap();
        let keys = vec!["w/a.bin".to_string(), "w/b.bin".to_string()];
        let m = Manifest::build(&s, &keys, "trainer-key").unwrap();
        let m2 = Manifest::from_json(&m.to_json()).unwrap();
        m2.verify(&s, "trainer-key").unwrap();
        assert!(m2.verify(&s, "other-key").is_err());
        s.put("w/a.bin", b"EVIL").unwrap();
        assert!(m2.verify(&s, "trainer-key").is_err());
        std::fs::remove_dir_all(s.root()).unwrap();
    }
}
