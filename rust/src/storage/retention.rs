//! Retention policy (paper §J.7): keep the most recent `max_deltas`
//! delta checkpoints and `max_anchors` full anchors, plus any anchor
//! still referenced by a retained delta.

use super::ObjectStore;
use anyhow::Result;
use std::collections::BTreeSet;

#[derive(Debug, Clone, Copy)]
pub struct RetentionPolicy {
    pub max_deltas: usize,
    pub max_anchors: usize,
}

impl Default for RetentionPolicy {
    fn default() -> Self {
        // Paper defaults: 100 deltas, 10 anchors.
        RetentionPolicy { max_deltas: 100, max_anchors: 10 }
    }
}

/// Inventory of checkpoint steps currently in the store, derived from
/// ready markers under `prefix` (see `pulse::sync` for the key scheme).
#[derive(Debug, Default, Clone)]
pub struct Inventory {
    pub delta_steps: Vec<u64>,
    pub anchor_steps: Vec<u64>,
}

pub fn scan(store: &ObjectStore, prefix: &str) -> Result<Inventory> {
    Ok(parse_inventory(&store.list(prefix)?, prefix))
}

/// Build an [`Inventory`] from an already-fetched key listing. Remote
/// backends (`net::store`) call this on the result of a single LIST so
/// `latest_ready()` costs exactly one round trip — re-listing the full
/// prefix per call is the O(objects) trap `scan` used to hide.
pub fn parse_inventory(keys: &[String], prefix: &str) -> Inventory {
    let mut inv = Inventory::default();
    for key in keys {
        let rel = key.strip_prefix(prefix).unwrap_or(key).trim_start_matches('/');
        if let Some(step) = parse_marker(rel, "delta_ready_") {
            inv.delta_steps.push(step);
        } else if let Some(step) = parse_marker(rel, "anchor_ready_") {
            inv.anchor_steps.push(step);
        }
    }
    inv.delta_steps.sort_unstable();
    inv.anchor_steps.sort_unstable();
    inv
}

fn parse_marker(rel: &str, kind: &str) -> Option<u64> {
    rel.strip_prefix(kind).and_then(|s| s.parse().ok())
}

/// Steps to delete under the policy. Never removes an anchor that a
/// retained delta chain needs: the newest anchor ≤ the oldest retained
/// delta is always preserved (slow-path recovery, §J.1).
pub fn plan(inv: &Inventory, policy: RetentionPolicy) -> (Vec<u64>, Vec<u64>) {
    let keep_deltas: BTreeSet<u64> = inv
        .delta_steps
        .iter()
        .rev()
        .take(policy.max_deltas)
        .copied()
        .collect();
    let mut keep_anchors: BTreeSet<u64> = inv
        .anchor_steps
        .iter()
        .rev()
        .take(policy.max_anchors)
        .copied()
        .collect();
    // anchor referenced by the oldest retained delta
    if let Some(&oldest_delta) = keep_deltas.iter().next() {
        if let Some(&base) = inv.anchor_steps.iter().filter(|&&a| a <= oldest_delta).next_back() {
            keep_anchors.insert(base);
        }
    }
    let drop_deltas =
        inv.delta_steps.iter().filter(|s| !keep_deltas.contains(s)).copied().collect();
    let drop_anchors =
        inv.anchor_steps.iter().filter(|s| !keep_anchors.contains(s)).copied().collect();
    (drop_deltas, drop_anchors)
}

/// Maximum storage bound of Eq. 31 for given payload sizes.
pub fn storage_bound(policy: RetentionPolicy, anchor_bytes: u64, delta_bytes: u64) -> u64 {
    policy.max_anchors as u64 * anchor_bytes + policy.max_deltas as u64 * delta_bytes
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn keeps_recent_and_referenced() {
        let inv = Inventory {
            delta_steps: (1..=200).collect(),
            anchor_steps: vec![0, 50, 100, 150, 200],
        };
        let policy = RetentionPolicy { max_deltas: 100, max_anchors: 2 };
        let (dd, da) = plan(&inv, policy);
        // deltas 1..=100 dropped
        assert_eq!(dd, (1..=100).collect::<Vec<u64>>());
        // newest 2 anchors kept (150, 200) + anchor 100 referenced by
        // oldest retained delta (101)
        assert_eq!(da, vec![0, 50]);
    }

    #[test]
    fn never_orphans_a_chain() {
        crate::util::prop::check("retention keeps chain base", 40, |g| {
            let n = 1 + g.rng.below(300);
            let k = 1 + g.rng.below(60);
            let deltas: Vec<u64> = (1..=n).collect();
            let anchors: Vec<u64> = (0..=n).step_by(k as usize).collect();
            let inv = Inventory { delta_steps: deltas, anchor_steps: anchors.clone() };
            let policy = RetentionPolicy {
                max_deltas: 1 + g.rng.below(100) as usize,
                max_anchors: 1 + g.rng.below(5) as usize,
            };
            let (dd, da) = plan(&inv, policy);
            let kept_deltas: Vec<u64> =
                (1..=n).filter(|s| !dd.contains(s)).collect();
            let kept_anchors: Vec<u64> =
                anchors.iter().filter(|s| !da.contains(s)).copied().collect();
            if let Some(&oldest) = kept_deltas.first() {
                // some kept anchor must be ≤ oldest retained delta
                assert!(
                    kept_anchors.iter().any(|&a| a <= oldest),
                    "oldest kept delta {} has no base anchor (kept {:?})",
                    oldest,
                    kept_anchors
                );
            }
        });
    }

    #[test]
    fn storage_bound_matches_paper() {
        // Eq. 31: 10 × 14 GB + 100 × 108 MB ≈ 151 GB
        let b = storage_bound(RetentionPolicy::default(), 14_000_000_000, 108_000_000);
        assert_eq!(b, 150_800_000_000);
    }

    #[test]
    fn scan_parses_markers() {
        let s = ObjectStore::temp("retention").unwrap();
        s.put("sync/delta_ready_3", b"").unwrap();
        s.put("sync/delta_ready_4", b"").unwrap();
        s.put("sync/anchor_ready_0", b"").unwrap();
        s.put("sync/other_junk", b"").unwrap();
        let inv = scan(&s, "sync").unwrap();
        assert_eq!(inv.delta_steps, vec![3, 4]);
        assert_eq!(inv.anchor_steps, vec![0]);
        std::fs::remove_dir_all(s.root()).unwrap();
    }
}
