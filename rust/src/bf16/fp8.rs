//! FP8 E4M3 cast (OCP FP8 / NVIDIA variant: no infinities, single NaN at
//! S.1111.111). Used by the lower-precision-receiver projection (§D).

/// Largest finite E4M3 magnitude: 1.75 * 2^8 = 448.
pub const FP8_MAX: f32 = 448.0;
/// Smallest positive normal: 2^-6.
pub const FP8_MIN_NORMAL: f32 = 0.015625;
/// Smallest positive subnormal: 2^-9.
pub const FP8_MIN_SUBNORMAL: f32 = 0.001953125;

/// Round-to-nearest-even cast f32 → E4M3 bit pattern (u8).
/// Values above FP8_MAX saturate to the max finite value (OCP behaviour);
/// NaN maps to 0x7F.
pub fn f32_to_fp8_bits(x: f32) -> u8 {
    if x.is_nan() {
        return 0x7F;
    }
    let sign = if x.is_sign_negative() { 0x80u8 } else { 0 };
    let a = x.abs();
    if a >= FP8_MAX * (1.0 + 1.0 / 32.0) {
        // beyond the rounding boundary past max → saturate (no inf)
        return sign | 0x7E;
    }
    if a == 0.0 {
        return sign;
    }
    // Decompose: a = m * 2^e with m in [1, 2)
    let e = a.log2().floor() as i32;
    let e = e.clamp(-9, 8);
    if e < -6 {
        // subnormal range: value = f * 2^-9, f in [0, 8)
        let f = a / 2f32.powi(-9);
        let r = round_half_even(f);
        if r >= 8.0 {
            return sign | 0x08; // rounds up into normals: 1.0 * 2^-6
        }
        return sign | (r as u8);
    }
    // normal: mantissa field m3 = round((a / 2^e - 1) * 8)
    let frac = a / 2f32.powi(e) - 1.0;
    let m = round_half_even(frac * 8.0);
    let (e, m) = if m >= 8.0 { (e + 1, 0.0) } else { (e, m) };
    if e > 8 {
        return sign | 0x7E; // saturate
    }
    let exp_field = (e + 7) as u8; // bias 7
    let bits = sign | (exp_field << 3) | (m as u8);
    // 0x7F is NaN; the largest finite is 0x7E (= 448)
    if bits & 0x7F == 0x7F {
        sign | 0x7E
    } else {
        bits
    }
}

fn round_half_even(x: f32) -> f32 {
    let fl = x.floor();
    let diff = x - fl;
    if diff > 0.5 {
        fl + 1.0
    } else if diff < 0.5 {
        fl
    } else if (fl as i64) % 2 == 0 {
        fl
    } else {
        fl + 1.0
    }
}

/// Expand an E4M3 bit pattern to f32.
pub fn fp8_bits_to_f32(bits: u8) -> f32 {
    let sign = if bits & 0x80 != 0 { -1.0f32 } else { 1.0 };
    let exp = (bits >> 3) & 0x0F;
    let man = bits & 0x07;
    if exp == 0x0F && man == 0x07 {
        return f32::NAN;
    }
    if exp == 0 {
        return sign * (man as f32) * 2f32.powi(-9);
    }
    sign * (1.0 + man as f32 / 8.0) * 2f32.powi(exp as i32 - 7)
}

/// `cast_FP8` as a value.
pub fn fp8_round(x: f32) -> f32 {
    fp8_bits_to_f32(f32_to_fp8_bits(x))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_values_roundtrip() {
        for bits in 0u8..=255 {
            let v = fp8_bits_to_f32(bits);
            if v.is_nan() {
                continue;
            }
            let back = f32_to_fp8_bits(v);
            // -0 and +0 both decode to 0.0; accept either encoding.
            assert_eq!(
                fp8_bits_to_f32(back),
                v,
                "bits={:02x} v={} back={:02x}",
                bits,
                v,
                back
            );
        }
    }

    #[test]
    fn known_constants() {
        assert_eq!(fp8_round(448.0), 448.0);
        assert_eq!(fp8_round(1.0), 1.0);
        assert_eq!(fp8_round(0.015625), 0.015625);
        assert_eq!(fp8_round(1e9), 448.0); // saturation
        assert_eq!(fp8_round(-1e9), -448.0);
        assert_eq!(fp8_round(0.0), 0.0);
        assert!(fp8_round(f32::NAN).is_nan());
    }

    #[test]
    fn rounding_is_nearest() {
        // between 1.0 and 1.125, midpoint 1.0625 → ties to even (1.0)
        assert_eq!(fp8_round(1.0624), 1.0);
        assert_eq!(fp8_round(1.0625), 1.0);
        assert_eq!(fp8_round(1.0626), 1.125);
    }

    #[test]
    fn idempotent() {
        let mut rng = crate::util::rng::Rng::new(17);
        for _ in 0..20_000 {
            let x = (rng.normal() as f32) * 10f32.powi(rng.range_i64(-6, 3) as i32);
            let once = fp8_round(x);
            assert_eq!(fp8_round(once), once, "x={}", x);
        }
    }

    #[test]
    fn cast_error_bounded_by_half_ulp() {
        let mut rng = crate::util::rng::Rng::new(19);
        for _ in 0..20_000 {
            let x = rng.f32() * 400.0;
            let r = fp8_round(x);
            // relative error ≤ 1/16 for normal range
            if x >= FP8_MIN_NORMAL {
                assert!((r - x).abs() / x <= 1.0 / 16.0 + 1e-6, "x={} r={}", x, r);
            }
        }
    }
}
