//! MXFP4: OCP Microscaling FP4 — E2M1 elements sharing an 8-bit
//! power-of-two scale per block of 32 (paper §D).
//!
//! The gate treats the block scale as fixed during a single optimizer
//! step (paper's assumption), so casting a block = pick scale from the
//! block max, then quantize each element to E2M1 × scale.

/// Block size fixed by the OCP MX spec.
pub const BLOCK: usize = 32;

/// The 8 non-negative E2M1 magnitudes: 0, 0.5, 1, 1.5, 2, 3, 4, 6.
pub const E2M1_VALUES: [f32; 8] = [0.0, 0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0];

/// Quantize one element to E2M1 (round-to-nearest, ties toward even
/// index) and return the 4-bit code (sign<<3 | mag).
pub fn e2m1_code(x: f32) -> u8 {
    let sign = if x.is_sign_negative() { 0x8u8 } else { 0 };
    let a = x.abs();
    let mut best = 0usize;
    let mut best_d = f32::INFINITY;
    for (i, &v) in E2M1_VALUES.iter().enumerate() {
        let d = (a - v).abs();
        if d < best_d || (d == best_d && i % 2 == 0) {
            best_d = d;
            best = i;
        }
    }
    sign | best as u8
}

/// Decode a 4-bit E2M1 code.
pub fn e2m1_decode(code: u8) -> f32 {
    let v = E2M1_VALUES[(code & 0x7) as usize];
    if code & 0x8 != 0 {
        -v
    } else {
        v
    }
}

/// Power-of-two block scale chosen so the block max maps near the top
/// E2M1 value (the OCP recommendation: scale = 2^(floor(log2 max) - 2)).
pub fn block_scale(block: &[f32]) -> f32 {
    let max = block.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
    if max == 0.0 || !max.is_finite() {
        return 1.0;
    }
    let e = max.log2().floor() as i32;
    // E2M1 max magnitude is 6 = 1.5 * 2^2: align so max lands in [4, 8).
    2f32.powi((e - 2).clamp(-127, 127))
}

/// Cast a block (≤32 elements) to its MXFP4 representation: returns the
/// codes and the scale used.
pub fn cast_block(block: &[f32]) -> (Vec<u8>, f32) {
    let s = block_scale(block);
    (block.iter().map(|&x| e2m1_code(x / s)).collect(), s)
}

/// `cast_MXFP4` of a full slice: element-wise reconstructed values.
pub fn mxfp4_round_slice(xs: &[f32]) -> Vec<f32> {
    let mut out = Vec::with_capacity(xs.len());
    for block in xs.chunks(BLOCK) {
        let (codes, s) = cast_block(block);
        out.extend(codes.iter().map(|&c| e2m1_decode(c) * s));
    }
    out
}

/// Element visibility under MXFP4: whether `cast(x)` and `cast(x - d)`
/// differ *within the same block context*. The caller supplies the block
/// scale (from the pre-update block) per the fixed-scale assumption.
pub fn visible_in_block(x: f32, x_new: f32, scale: f32) -> bool {
    e2m1_code(x / scale) != e2m1_code(x_new / scale)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn codes_roundtrip() {
        for code in 0u8..16 {
            let v = e2m1_decode(code);
            let back = e2m1_code(v);
            assert_eq!(e2m1_decode(back), v, "code={:x}", code);
        }
    }

    #[test]
    fn block_scale_places_max_high() {
        let mut block = vec![0.01f32; 32];
        block[7] = 5.0;
        let s = block_scale(&block);
        let top = 5.0 / s;
        assert!((4.0..8.0).contains(&top), "top={}", top);
    }

    #[test]
    fn zero_block() {
        let block = vec![0.0f32; 32];
        let (codes, s) = cast_block(&block);
        assert_eq!(s, 1.0);
        assert!(codes.iter().all(|&c| c & 0x7 == 0));
    }

    #[test]
    fn small_elements_coarser_than_bf16() {
        // An element far below the block max gets absorbed for updates
        // that BF16 would see — MXFP4's cell is coarser (paper §D).
        let mut block = vec![0.0f32; 32];
        block[0] = 1.0; // sets scale
        block[1] = 0.01;
        let s = block_scale(&block);
        let before = e2m1_code(block[1] / s);
        let after = e2m1_code((block[1] + 0.01) / s);
        assert_eq!(before, after); // +100% relative change, still invisible
        assert_ne!(
            crate::bf16::f32_to_bf16_bits(0.01),
            crate::bf16::f32_to_bf16_bits(0.02)
        );
    }

    #[test]
    fn round_slice_idempotent() {
        let mut rng = crate::util::rng::Rng::new(23);
        let xs: Vec<f32> = (0..256).map(|_| rng.normal() as f32 * 0.05).collect();
        let once = mxfp4_round_slice(&xs);
        let twice = mxfp4_round_slice(&once);
        assert_eq!(once, twice);
    }
}
