//! Low-precision numeric formats and rounding-cell math (paper §A.2, §D).
//!
//! The whole paper hinges on one operation: casting an FP32 master weight
//! to the compute dtype of the next forward pass and asking whether the
//! bit pattern changed. This module implements those casts in software —
//! BF16 (round-to-nearest-even, matching jnp/torch `.bfloat16()`), FP8
//! E4M3, and MXFP4 (OCP E2M1 with a shared block-32 power-of-two scale) —
//! plus the ULP / rounding-cell helpers used by the analysis harnesses.

pub mod fp8;
pub mod mxfp4;

/// Round-to-nearest-even cast f32 → bf16 bit pattern (u16).
///
/// NaNs are canonicalized to a quiet NaN so bitwise comparisons treat all
/// NaNs as equal (matches XLA behaviour closely enough for the gate —
/// training never produces NaNs in a healthy run).
#[inline(always)]
pub fn f32_to_bf16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    if x.is_nan() {
        return 0x7FC0;
    }
    // RNE: add 0x7FFF + LSB of the kept part, then truncate.
    let rounding_bias = 0x7FFFu32 + ((bits >> 16) & 1);
    ((bits + rounding_bias) >> 16) as u16
}

/// Expand a bf16 bit pattern back to f32 (exact).
#[inline(always)]
pub fn bf16_bits_to_f32(bits: u16) -> f32 {
    f32::from_bits((bits as u32) << 16)
}

/// `cast_BF16` as a value: f32 → nearest bf16 → f32.
#[inline(always)]
pub fn bf16_round(x: f32) -> f32 {
    bf16_bits_to_f32(f32_to_bf16_bits(x))
}

/// Cast a whole slice to bf16 bit patterns.
pub fn cast_slice(xs: &[f32], out: &mut Vec<u16>) {
    out.clear();
    out.reserve(xs.len());
    for &x in xs {
        out.push(f32_to_bf16_bits(x));
    }
}

/// Cast a whole slice to bf16 bit patterns, in parallel, reusing `out`.
pub fn cast_slice_par(xs: &[f32], out: &mut Vec<u16>) {
    out.resize(xs.len(), 0);
    let src = xs;
    crate::util::pool::par_chunks_mut(out, 1 << 16, |_, base, chunk| {
        for (i, o) in chunk.iter_mut().enumerate() {
            *o = f32_to_bf16_bits(src[base + i]);
        }
    });
}

/// BF16 unit-in-the-last-place at value `x` (spacing of representable
/// values in x's binade): `2^(e-7)` for normalized `2^e <= |x| < 2^(e+1)`.
pub fn bf16_ulp(x: f32) -> f32 {
    if x == 0.0 || !x.is_finite() {
        // subnormal bf16 spacing: 2^-126 * 2^-7 = 2^-133
        return 2f32.powi(-133);
    }
    let e = x.abs().log2().floor() as i32;
    2f32.powi(e - 7)
}

/// Distance from `x` (an f32 master weight) to the nearest BF16 rounding
/// boundary — the exact per-parameter absorption threshold of Def. A.3.
/// An update `|Δ| <` this distance cannot change `cast_BF16(x)`.
pub fn bf16_boundary_distance(x: f32) -> f32 {
    let cur = f32_to_bf16_bits(x);
    // Boundaries are midpoints between adjacent bf16 values around x.
    let lo = bf16_bits_to_f32(prev_bf16(cur));
    let mid_lo = midpoint(lo, bf16_bits_to_f32(cur));
    let hi = bf16_bits_to_f32(next_bf16(cur));
    let mid_hi = midpoint(bf16_bits_to_f32(cur), hi);
    (x - mid_lo).abs().min((mid_hi - x).abs())
}

fn midpoint(a: f32, b: f32) -> f32 {
    (a as f64 * 0.5 + b as f64 * 0.5) as f32
}

/// Next representable bf16 (toward +inf), saturating at +inf.
pub fn next_bf16(bits: u16) -> u16 {
    if bits & 0x8000 == 0 {
        // positive: increment magnitude
        if bits >= 0x7F80 {
            bits
        } else {
            bits + 1
        }
    } else if bits == 0x8000 {
        // -0 → smallest positive
        0x0001
    } else {
        bits - 1
    }
}

/// Previous representable bf16 (toward -inf), saturating at -inf.
pub fn prev_bf16(bits: u16) -> u16 {
    if bits & 0x8000 != 0 {
        if bits >= 0xFF80 {
            bits
        } else {
            bits + 1
        }
    } else if bits == 0x0000 {
        // +0 → smallest negative
        0x8001
    } else {
        bits - 1
    }
}

/// The paper's characteristic relative cell radius: |Δw|/|w| ≈ 2^-8
/// (half a ULP). `|w| / 256` is the visibility threshold of Fig. 3b.
pub fn visibility_threshold(w: f32) -> f32 {
    w.abs() / 256.0
}

/// The compute dtypes the gate supports (paper §D).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dtype {
    Bf16,
    Fp8E4M3,
    Mxfp4,
}

impl Dtype {
    pub fn parse(s: &str) -> anyhow::Result<Dtype> {
        match s.to_ascii_lowercase().as_str() {
            "bf16" => Ok(Dtype::Bf16),
            "fp8" | "fp8e4m3" | "fp8_e4m3" => Ok(Dtype::Fp8E4M3),
            "mxfp4" => Ok(Dtype::Mxfp4),
            other => anyhow::bail!("unknown dtype '{}'", other),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Dtype::Bf16 => "bf16",
            Dtype::Fp8E4M3 => "fp8e4m3",
            Dtype::Mxfp4 => "mxfp4",
        }
    }

    /// Mantissa bits (effective, for MXFP4) — τ_D = 2^-(m+1) (Eq. 19).
    pub fn mantissa_bits(&self) -> u32 {
        match self {
            Dtype::Bf16 => 7,
            Dtype::Fp8E4M3 => 3,
            Dtype::Mxfp4 => 1,
        }
    }

    /// Relative absorption threshold τ_D (paper Eq. 19 / Table 6).
    pub fn tau(&self) -> f64 {
        2f64.powi(-(self.mantissa_bits() as i32 + 1))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Reference cast via f64 midpoint logic, for cross-checking RNE.
    fn ref_bf16(x: f32) -> u16 {
        if x.is_nan() {
            return 0x7FC0;
        }
        if x.is_infinite() {
            return if x > 0.0 { 0x7F80 } else { 0xFF80 };
        }
        // brute force: truncate, then compare distances to the two
        // candidates, breaking ties to even.
        let trunc = (x.to_bits() >> 16) as u16;
        let lo = bf16_bits_to_f32(trunc);
        let hi_bits = if x >= 0.0 { next_bf16(trunc) } else { prev_bf16(trunc) };
        // note: for negative x, truncation moves toward zero, so "hi" is
        // the next value away from zero.
        // If the next value saturates to infinity, RNE still uses the
        // virtual next step 2^128 as the rounding boundary.
        let hi = bf16_bits_to_f32(hi_bits);
        let hi_virtual: f64 = if hi.is_infinite() {
            if hi > 0.0 {
                2f64.powi(128)
            } else {
                -(2f64.powi(128))
            }
        } else {
            hi as f64
        };
        let (a, b) = (lo as f64, hi_virtual);
        let d_lo = (x as f64 - a).abs();
        let d_hi = (x as f64 - b).abs();
        if d_lo < d_hi {
            trunc
        } else if d_hi < d_lo {
            hi_bits
        } else if trunc & 1 == 0 {
            trunc
        } else {
            hi_bits
        }
    }

    #[test]
    fn known_values() {
        assert_eq!(bf16_round(1.0), 1.0);
        assert_eq!(bf16_round(-2.0), -2.0);
        // 1.0 + 2^-9 rounds back down to 1.0 (inside the cell)
        assert_eq!(bf16_round(1.0 + 2f32.powi(-9)), 1.0);
        // 1.0 + 2^-8 is exactly the midpoint → ties-to-even → 1.0
        assert_eq!(bf16_round(1.0 + 2f32.powi(-8)), 1.0);
        // slightly above the midpoint → rounds up to 1.0078125
        assert!(bf16_round(1.0 + 2f32.powi(-8) + 2f32.powi(-12)) > 1.0);
    }

    #[test]
    fn matches_reference_cast_exhaustively_sampled() {
        let mut rng = crate::util::rng::Rng::new(11);
        for _ in 0..200_000 {
            let bits = rng.next_u32();
            let x = f32::from_bits(bits);
            if x.is_nan() {
                continue;
            }
            assert_eq!(
                f32_to_bf16_bits(x),
                ref_bf16(x),
                "mismatch for {:e} ({:08x})",
                x,
                bits
            );
        }
    }

    #[test]
    fn roundtrip_is_idempotent() {
        let mut rng = crate::util::rng::Rng::new(3);
        for _ in 0..10_000 {
            let x = (rng.normal() as f32) * 10f32.powi(rng.range_i64(-10, 4) as i32);
            let once = bf16_round(x);
            assert_eq!(bf16_round(once), once);
        }
    }

    #[test]
    fn ulp_scales_with_binade() {
        assert_eq!(bf16_ulp(1.5), 2f32.powi(-7));
        assert_eq!(bf16_ulp(10.0), 2f32.powi(3 - 7));
        assert_eq!(bf16_ulp(0.01), 2f32.powi(-7 - 7));
    }

    #[test]
    fn boundary_distance_bounds_absorption() {
        let mut rng = crate::util::rng::Rng::new(5);
        for _ in 0..20_000 {
            let w = (rng.normal() as f32) * 0.02;
            if w == 0.0 {
                continue;
            }
            let d = bf16_boundary_distance(w);
            // Any |delta| strictly below the boundary distance is absorbed.
            let delta = d * 0.49;
            assert_eq!(
                f32_to_bf16_bits(w),
                f32_to_bf16_bits(w - delta),
                "w={:e} d={:e}",
                w,
                d
            );
            // A push of 1.5 cells always changes the cast.
            let big = 1.5 * bf16_ulp(w).max(f32::MIN_POSITIVE);
            assert_ne!(f32_to_bf16_bits(w), f32_to_bf16_bits(w + big), "w={:e}", w);
        }
    }

    #[test]
    fn next_prev_are_inverse() {
        for bits in [0x0000u16, 0x0001, 0x3F80, 0x7F00, 0x8000, 0x8001, 0xBF80] {
            let n = next_bf16(bits);
            if n != bits {
                assert_eq!(prev_bf16(n), normalize_zero(bits), "bits={:04x}", bits);
            }
        }
    }

    fn normalize_zero(b: u16) -> u16 {
        // prev(next(-0)) lands on +0; treat zeros as equal.
        if b == 0x8000 {
            0x0000
        } else {
            b
        }
    }

    #[test]
    fn visibility_threshold_matches_ulp_scale() {
        // |w|/256 is within a factor 2 of half a ULP for any w.
        let mut rng = crate::util::rng::Rng::new(6);
        for _ in 0..10_000 {
            let w: f32 = (rng.lognormal(-4.5, 1.0) as f32).max(1e-30);
            let half_ulp = bf16_ulp(w) / 2.0;
            let thr = visibility_threshold(w);
            assert!(thr <= half_ulp * 2.0 && thr >= half_ulp / 2.0, "w={:e}", w);
        }
    }

    #[test]
    fn tau_table_matches_paper() {
        assert_eq!(Dtype::Bf16.tau(), 1.0 / 256.0);
        assert_eq!(Dtype::Fp8E4M3.tau(), 1.0 / 16.0);
        assert_eq!(Dtype::Mxfp4.tau(), 1.0 / 4.0);
    }

    #[test]
    fn par_cast_matches_serial() {
        let mut rng = crate::util::rng::Rng::new(9);
        let xs: Vec<f32> = (0..100_000).map(|_| rng.normal() as f32).collect();
        let mut a = Vec::new();
        let mut b = Vec::new();
        cast_slice(&xs, &mut a);
        cast_slice_par(&xs, &mut b);
        assert_eq!(a, b);
    }
}
