//! # PULSE — compute-visible sparsification for distributed RL
//!
//! Rust reproduction of *"Understanding and Exploiting Weight Update
//! Sparsity for Communication-Efficient Distributed RL"* (2026).
//!
//! The library is organized in three layers:
//!
//! * **L3 (this crate)** — the coordination contribution: the
//!   compute-visibility gate ([`gate`]), sparse patch formats
//!   ([`sparse`], [`codec`]), PULSESync / PULSELoCo ([`pulse`]),
//!   dense baselines ([`baselines`]), GRPO training ([`rl`]), the
//!   grail deployment substrate ([`grail`], [`storage`], [`net`]),
//!   the multi-trainer coordinator ([`coordinator`]) and the sync-plane
//!   observability layer ([`obs`]).
//! * **L2 (python/compile/model.py)** — the JAX model graphs, lowered
//!   once to HLO text and executed from [`runtime`] via PJRT.
//! * **L1 (python/compile/kernels/)** — Pallas kernels (attention,
//!   visibility gate, fused AdamW) that lower into the L2 graphs.
//!
//! Python never runs on the request path: after `make artifacts`, the
//! Rust binary is self-contained.

pub mod analysis;
pub mod baselines;
pub mod bf16;
pub mod codec;
pub mod coordinator;
pub mod gate;
pub mod grail;
pub mod net;
pub mod obs;
pub mod optim;
pub mod pulse;
pub mod rl;
pub mod runtime;
pub mod sim;
pub mod sparse;
pub mod storage;
pub mod util;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
