//! Relay→relay chaining: [`RelayNode`] subscribes to an upstream relay
//! (or another node) and republishes downstream through its own
//! [`Relay`], so relays compose into distribution **trees** — the
//! topology that lets one publisher feed hundreds of inference workers
//! without saturating the trainer uplink (paper Fig. 5 scaled out;
//! ROADMAP ">100-subscriber fan-out").
//!
//! # What a hop guarantees
//!
//! Each node re-stages the stream in its own relay exactly as a root
//! relay would, which makes fault handling *recursive*:
//!
//! * **Late joiners** are served the anchor + tail catch-up bundle
//!   from the node's staging — no upstream traffic.
//! * **Per-shard NACK repair** is served from the node's bounded frame
//!   index. Only when the index has evicted the slot does the node
//!   escalate the NACK upstream ([`Relay::set_escalation`]); the
//!   retransmit that comes back is delivered to exactly the waiting
//!   downstream subscribers ([`Relay::deliver_retransmit`]) and
//!   re-indexed so the next repair of that slot stays local.
//! * An upstream **NACK_MISS** (the slot is gone everywhere on the
//!   path to the publisher) is forwarded to the waiting subscribers
//!   ([`Relay::fail_escalated`]), which then fall back to the anchor
//!   slow path instead of timing out.
//! * **MARKER and CLOSE** frames are republished verbatim, so the
//!   commit protocol (frames first, then the committing marker) and
//!   orderly shutdown survive any tree depth.
//! * A **slow subscriber** of a node coalesces inside that node's
//!   per-subscriber queue; siblings and the upstream are unaffected.
//!
//! Because every hop runs the same staging + coalescing + NACK logic,
//! end-to-end bit-identity holds at any depth: the transport
//! conformance suite (`tests/integration_transport.rs`) and the chain
//! suite (`tests/integration_chain.rs`) drive the same seeded stream
//! through chained topologies and assert it.
//!
//! # Attachment lifecycle (live re-parenting)
//!
//! The upstream subscription is **detachable**: a node built with
//! [`RelayNode::detached`] starts with no upstream, and
//! [`RelayNode::attach_upstream`] / [`RelayNode::detach_upstream`]
//! move it between parents *while its own subscribers stay connected*.
//! This is the mechanism the control plane
//! ([`crate::net::control`]) drives for failover: when a mid-tree
//! relay dies, its children re-attach to the surviving parent the next
//! epoch's ASSIGN names, pick up that hop's anchor + tail catch-up
//! preload as a fresh subscriber, and republish it downstream — the
//! subtree heals without a single leaf reconnecting. Hand-wired nodes
//! ([`RelayNode::join`]) keep the legacy behavior of forwarding a
//! CLOSE downstream when the upstream dies; detached-mode nodes hold
//! their subtree open instead (the control plane owns the failure
//! response). A detach fails all in-flight NACK escalations with
//! NACK_MISS ([`Relay::fail_all_escalated`]) so no subscriber waits on
//! a retransmit that can no longer arrive.
//!
//! # Topology bookkeeping
//!
//! On attach, the node sends a SUBSCRIBE upstream and learns its hop
//! depth from the HOP reply (root = 0, so a node directly under the
//! root reports 1). The depth is re-served to downstream SUBSCRIBEs,
//! so every peer in the tree knows its distance from the publisher —
//! `paper topology` prints the per-hop rows.
//!
//! # Wall-clock audit (scale-sim seam)
//!
//! This module holds **no timing logic** — no `Instant::now()`, no
//! sleeps, no backoff arithmetic. Every time-dependent decision a hop
//! makes (escalation backoff windows, coalescing, retry budgets) lives
//! in the state machines [`crate::net::relay`] extracts
//! (`RelayStage`, `EscalationLedger`, `coalesce_enqueue`) and in
//! [`crate::util::retry`], all parameterized by explicit clock
//! readings. That is what lets the scale simulator (`crate::sim`)
//! model a chained hop faithfully without ever instantiating the
//! socket-bound `RelayNode` itself.

use super::chaos::{ChaosConfig, Wire};
use super::relay::Relay;
use super::tcp::{self, kind, Frame};
use crate::util::sync::LockExt;
use anyhow::{Context, Result};
use std::net::Shutdown;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// One interior hop of a relay tree: an upstream subscription feeding
/// a downstream [`Relay`]. Construct with [`RelayNode::join`]
/// (hand-wired upstream) or [`RelayNode::detached`] (upstream managed
/// later, e.g. by the control plane); point subscribers (or further
/// nodes) at [`RelayNode::port`].
pub struct RelayNode {
    relay: Arc<Relay>,
    /// Write half of the current upstream connection (NACK escalation
    /// + the SUBSCRIBE handshake); the forward thread owns the read
    /// half. `None` while detached.
    upstream: Arc<Mutex<Option<Wire>>>,
    /// Fault injection for the node's wires (upstream attachments and
    /// accepted downstream subscribers); `None` = plain TCP.
    chaos: Option<ChaosConfig>,
    forward: Mutex<Option<std::thread::JoinHandle<()>>>,
    stop: Arc<AtomicBool>,
    /// Bumped on every detach; a forward thread whose generation is
    /// stale exits silently instead of reporting upstream loss.
    attach_gen: Arc<AtomicU64>,
    /// True once the CURRENT attachment's stream ended (CLOSE or
    /// socket error); reset by the next attach.
    upstream_closed: Arc<AtomicBool>,
    /// True only when the current attachment ended in a SOCKET ERROR —
    /// an orderly publisher CLOSE does not set it. This is the signal
    /// the control plane re-attaches on; treating an orderly
    /// end-of-stream as a failure would resubscribe forever.
    upstream_failed: Arc<AtomicBool>,
    /// Hand-wired nodes end their downstream stream (publish CLOSE)
    /// when the upstream dies; control-managed nodes hold the subtree
    /// open and wait to be re-parented.
    close_on_upstream_loss: bool,
}

impl RelayNode {
    /// Join an upstream relay (or node) on `upstream_port` with the
    /// default queue depth and frame-index bound.
    pub fn join(upstream_port: u16) -> Result<RelayNode> {
        RelayNode::join_with_opts(
            upstream_port,
            super::relay::DEFAULT_QUEUE_DEPTH,
            super::relay::INDEX_STEPS,
        )
    }

    /// Join with explicit per-subscriber queue depth and NACK
    /// frame-index bound for the node's own relay.
    pub fn join_with_opts(
        upstream_port: u16,
        queue_depth: usize,
        index_steps: usize,
    ) -> Result<RelayNode> {
        let node = RelayNode::new(queue_depth, index_steps, true, None)?;
        node.attach_upstream(upstream_port)?;
        Ok(node)
    }

    /// [`RelayNode::join_with_opts`] with seeded wire-level fault
    /// injection on both sides of the hop ([`crate::net::chaos`]).
    pub fn join_with_chaos(
        upstream_port: u16,
        queue_depth: usize,
        index_steps: usize,
        chaos: Option<ChaosConfig>,
    ) -> Result<RelayNode> {
        let node = RelayNode::new(queue_depth, index_steps, true, chaos)?;
        node.attach_upstream(upstream_port)?;
        Ok(node)
    }

    /// A node with no upstream yet: its relay accepts subscribers and
    /// serves whatever it has staged, but nothing flows until
    /// [`RelayNode::attach_upstream`]. Upstream loss does NOT end the
    /// downstream stream — the caller (the control plane) decides.
    pub fn detached() -> Result<RelayNode> {
        RelayNode::detached_with_opts(
            super::relay::DEFAULT_QUEUE_DEPTH,
            super::relay::INDEX_STEPS,
        )
    }

    /// [`RelayNode::detached`] with explicit queue depth and NACK
    /// frame-index bound.
    pub fn detached_with_opts(queue_depth: usize, index_steps: usize) -> Result<RelayNode> {
        RelayNode::new(queue_depth, index_steps, false, None)
    }

    /// [`RelayNode::detached_with_opts`] with seeded wire-level fault
    /// injection on BOTH sides of the hop: upstream attachments and
    /// every accepted downstream subscriber ([`crate::net::chaos`]).
    pub fn detached_with_chaos(
        queue_depth: usize,
        index_steps: usize,
        chaos: Option<ChaosConfig>,
    ) -> Result<RelayNode> {
        RelayNode::new(queue_depth, index_steps, false, chaos)
    }

    fn new(
        queue_depth: usize,
        index_steps: usize,
        close_on_upstream_loss: bool,
        chaos: Option<ChaosConfig>,
    ) -> Result<RelayNode> {
        let relay =
            Arc::new(Relay::start_with_chaos(queue_depth, index_steps, chaos.clone())?);
        let upstream: Arc<Mutex<Option<Wire>>> = Arc::new(Mutex::new(None));
        // escalation: a downstream NACK the node's index has evicted is
        // forwarded up the CURRENT upstream connection; the reply
        // (retransmit or NACK_MISS) comes back on the forward thread.
        // Installed once — re-attaching swaps the stream under the Arc.
        {
            let upstream = upstream.clone();
            relay.set_escalation(move |step, shard| {
                let mut conn = upstream.plock();
                match conn.as_mut() {
                    Some(conn) => tcp::write_frame(
                        conn,
                        &Frame { kind: kind::NACK, payload: tcp::shard_ack_payload(step, shard) },
                    )
                    .is_ok(),
                    None => false,
                }
            });
        }
        Ok(RelayNode {
            relay,
            upstream,
            chaos,
            forward: Mutex::new(None),
            stop: Arc::new(AtomicBool::new(false)),
            attach_gen: Arc::new(AtomicU64::new(0)),
            upstream_closed: Arc::new(AtomicBool::new(false)),
            upstream_failed: Arc::new(AtomicBool::new(false)),
            close_on_upstream_loss,
        })
    }

    /// Attach (or re-attach) the node under the relay/node listening on
    /// `upstream_port`: connect, run the SUBSCRIBE→HOP handshake, and
    /// start forwarding. An existing attachment is detached first, so
    /// this is the one call the control plane needs for re-parenting.
    /// As a fresh subscriber the node receives the new parent's anchor
    /// + tail catch-up preload and republishes it downstream — that IS
    /// the subtree's failover catch-up.
    pub fn attach_upstream(&self, upstream_port: u16) -> Result<()> {
        self.detach_upstream();
        let up = tcp::connect_local(upstream_port).context("connecting upstream")?;
        let mut up = Wire::wrap(up, self.chaos.as_ref());
        tcp::write_frame(
            &mut up,
            &Frame { kind: kind::SUBSCRIBE, payload: 0u64.to_le_bytes().to_vec() },
        )
        .context("subscribing upstream")?;
        let up_read = up.try_clone()?;
        self.upstream_closed.store(false, Ordering::SeqCst);
        self.upstream_failed.store(false, Ordering::SeqCst);
        *self.upstream.plock() = Some(up);
        let gen = self.attach_gen.load(Ordering::SeqCst);
        let handle = spawn_forward(
            up_read,
            self.relay.clone(),
            self.stop.clone(),
            self.attach_gen.clone(),
            gen,
            self.upstream_closed.clone(),
            self.upstream_failed.clone(),
            self.close_on_upstream_loss,
        );
        *self.forward.plock() = Some(handle);
        Ok(())
    }

    /// Detach from the current upstream (idempotent): stop the forward
    /// thread, close the connection, and fail all in-flight NACK
    /// escalations with NACK_MISS (their retransmits can no longer
    /// arrive here). Downstream subscribers stay connected and keep
    /// being served from the node's staging.
    pub fn detach_upstream(&self) {
        self.attach_gen.fetch_add(1, Ordering::SeqCst);
        if let Some(conn) = self.upstream.plock().take() {
            let _ = conn.shutdown(Shutdown::Both);
        }
        if let Some(h) = self.forward.plock().take() {
            let _ = h.join();
        }
        self.relay.fail_all_escalated();
    }

    /// True while an upstream connection is attached (it may still be
    /// closed-but-unreaped; see [`RelayNode::upstream_closed`]).
    pub fn upstream_attached(&self) -> bool {
        self.upstream.plock().is_some()
    }

    /// Port downstream subscribers (or further nodes) connect to.
    pub fn port(&self) -> u16 {
        self.relay.port
    }

    /// The node's downstream relay (staging, counters, subscribers).
    pub fn relay(&self) -> &Arc<Relay> {
        &self.relay
    }

    /// Hops between this node and the publisher (learned from the
    /// upstream's HOP reply; 0 until the reply has arrived).
    pub fn hop(&self) -> u32 {
        self.relay.hop()
    }

    /// True once the current attachment's stream ended (CLOSE or
    /// socket error); reset by the next [`RelayNode::attach_upstream`].
    /// For hand-wired nodes the CLOSE was republished downstream
    /// before this flips; detached-mode nodes hold the subtree open.
    pub fn upstream_closed(&self) -> bool {
        self.upstream_closed.load(Ordering::SeqCst)
    }

    /// True only when the current attachment died on a socket error
    /// (the re-attach signal); an orderly publisher CLOSE leaves this
    /// false. Reset by the next [`RelayNode::attach_upstream`].
    pub fn upstream_failed(&self) -> bool {
        self.upstream_failed.load(Ordering::SeqCst)
    }

    /// Stop the node: detach from the upstream, then stop the
    /// downstream relay (draining queues best-effort, like
    /// [`Relay::stop`]). Idempotent; takes `&self` so an
    /// `Arc<RelayNode>` shared with workers can still be stopped.
    pub fn stop(&self) {
        self.stop.store(true, Ordering::SeqCst);
        self.detach_upstream();
        self.relay.stop();
    }
}

/// Forward thread: reads the upstream stream and republishes it
/// downstream. PATCH frames for slots the node escalated are consumed
/// as retransmits (delivered to the waiting subscribers only, never
/// rebroadcast); everything else is ordinary stream traffic. A thread
/// whose attachment generation went stale (the node re-parented) exits
/// without touching the downstream stream.
#[allow(clippy::too_many_arguments)]
fn spawn_forward(
    mut stream: Wire,
    relay: Arc<Relay>,
    stop: Arc<AtomicBool>,
    attach_gen: Arc<AtomicU64>,
    gen: u64,
    upstream_closed: Arc<AtomicBool>,
    upstream_failed: Arc<AtomicBool>,
    close_on_upstream_loss: bool,
) -> std::thread::JoinHandle<()> {
    std::thread::spawn(move || {
        let mut forwarded_close = false;
        let stale = |current: &Arc<AtomicU64>| current.load(Ordering::SeqCst) != gen;
        loop {
            if stop.load(Ordering::SeqCst) || stale(&attach_gen) {
                return;
            }
            let frame = match tcp::read_frame(&mut stream) {
                Ok(f) => f,
                Err(_) => {
                    // a detach shut this socket down on purpose: say
                    // nothing. A genuine upstream death either ends
                    // the downstream stream (hand-wired trees) or is
                    // left for the control plane to re-parent around.
                    if stale(&attach_gen) {
                        return;
                    }
                    if close_on_upstream_loss && !forwarded_close {
                        relay.publish(Frame { kind: kind::CLOSE, payload: Vec::new() });
                    }
                    upstream_failed.store(true, Ordering::SeqCst);
                    upstream_closed.store(true, Ordering::SeqCst);
                    // crash-path flight-recorder dump (no-op unless
                    // PULSE_OBS_DUMP_DIR is set)
                    let _ = crate::obs::Obs::global()
                        .dump_incident(&format!("upstream socket error at hop {}", relay.hop()));
                    return;
                }
            };
            // a frame that was already in flight when a detach bumped
            // the generation belongs to the OLD attachment: it must
            // never reach the downstream stream (a stale CLOSE would
            // end the re-parented subtree for good)
            if stale(&attach_gen) {
                return;
            }
            match frame.kind {
                kind::PATCH => {
                    // an escalated-NACK retransmit is addressed to the
                    // waiting subscribers only; anything else is stream
                    // traffic for everyone
                    let meta = crate::sparse::container::peek_meta(&frame.payload).ok();
                    let bytes = frame.payload.len() as u64;
                    let mut consumed = false;
                    if let Some(m) = &meta {
                        if relay.deliver_retransmit(m.step, m.shard_index, frame.clone()) {
                            crate::obs::span(
                                crate::obs::Stage::Retransmit,
                                0,
                                m.step,
                                m.shard_index,
                                bytes,
                            );
                            consumed = true;
                        }
                    }
                    if !consumed {
                        relay.publish(frame);
                    }
                }
                kind::ANCHOR | kind::MARKER => relay.publish(frame),
                kind::CLOSE => {
                    // an orderly end-of-stream from the publisher: NOT
                    // a failure — the control plane must not re-parent
                    // around it (upstream_failed stays false)
                    relay.publish(frame);
                    forwarded_close = true;
                    upstream_closed.store(true, Ordering::SeqCst);
                    // keep reading: late NACK escalation replies may
                    // still arrive until the socket actually closes
                }
                kind::HOP => {
                    if let Ok(up_hop) = tcp::parse_hop(&frame.payload) {
                        relay.set_hop(up_hop + 1);
                    }
                }
                kind::NACK_MISS => {
                    if let Ok((step, shard)) = tcp::parse_shard_ack(&frame.payload) {
                        relay.fail_escalated(step, shard);
                    }
                }
                _ => {}
            }
        }
    })
}

impl Drop for RelayNode {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // full detach (not just socket teardown): waiting subscribers
        // get their NACK_MISS instead of burning the NACK timeout
        self.detach_upstream();
    }
}
