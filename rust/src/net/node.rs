//! Relay→relay chaining: [`RelayNode`] subscribes to an upstream relay
//! (or another node) and republishes downstream through its own
//! [`Relay`], so relays compose into distribution **trees** — the
//! topology that lets one publisher feed hundreds of inference workers
//! without saturating the trainer uplink (paper Fig. 5 scaled out;
//! ROADMAP ">100-subscriber fan-out").
//!
//! # What a hop guarantees
//!
//! Each node re-stages the stream in its own relay exactly as a root
//! relay would, which makes fault handling *recursive*:
//!
//! * **Late joiners** are served the anchor + tail catch-up bundle
//!   from the node's staging — no upstream traffic.
//! * **Per-shard NACK repair** is served from the node's bounded frame
//!   index. Only when the index has evicted the slot does the node
//!   escalate the NACK upstream ([`Relay::set_escalation`]); the
//!   retransmit that comes back is delivered to exactly the waiting
//!   downstream subscribers ([`Relay::deliver_retransmit`]) and
//!   re-indexed so the next repair of that slot stays local.
//! * An upstream **NACK_MISS** (the slot is gone everywhere on the
//!   path to the publisher) is forwarded to the waiting subscribers
//!   ([`Relay::fail_escalated`]), which then fall back to the anchor
//!   slow path instead of timing out.
//! * **MARKER and CLOSE** frames are republished verbatim, so the
//!   commit protocol (frames first, then the committing marker) and
//!   orderly shutdown survive any tree depth.
//! * A **slow subscriber** of a node coalesces inside that node's
//!   per-subscriber queue; siblings and the upstream are unaffected.
//!
//! Because every hop runs the same staging + coalescing + NACK logic,
//! end-to-end bit-identity holds at any depth: the transport
//! conformance suite (`tests/integration_transport.rs`) and the chain
//! suite (`tests/integration_chain.rs`) drive the same seeded stream
//! through chained topologies and assert it.
//!
//! # Topology bookkeeping
//!
//! On join, the node sends a SUBSCRIBE upstream and learns its hop
//! depth from the HOP reply (root = 0, so a node directly under the
//! root reports 1). The depth is re-served to downstream SUBSCRIBEs,
//! so every peer in the tree knows its distance from the publisher —
//! `paper topology` prints the per-hop rows.

use super::relay::Relay;
use super::tcp::{self, kind, Frame};
use anyhow::{Context, Result};
use std::net::{Shutdown, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};

/// One interior hop of a relay tree: an upstream subscription feeding
/// a downstream [`Relay`]. Construct with [`RelayNode::join`]; point
/// subscribers (or further nodes) at [`RelayNode::port`].
pub struct RelayNode {
    relay: Arc<Relay>,
    /// Write half of the upstream connection (NACK escalation + the
    /// SUBSCRIBE handshake); the forward thread owns the read half.
    upstream: Arc<Mutex<TcpStream>>,
    forward: Mutex<Option<std::thread::JoinHandle<()>>>,
    stop: Arc<AtomicBool>,
    /// True once the upstream stream ended (CLOSE or socket error).
    upstream_closed: Arc<AtomicBool>,
}

impl RelayNode {
    /// Join an upstream relay (or node) on `upstream_port` with the
    /// default queue depth and frame-index bound.
    pub fn join(upstream_port: u16) -> Result<RelayNode> {
        RelayNode::join_with_opts(
            upstream_port,
            super::relay::DEFAULT_QUEUE_DEPTH,
            super::relay::INDEX_STEPS,
        )
    }

    /// Join with explicit per-subscriber queue depth and NACK
    /// frame-index bound for the node's own relay.
    pub fn join_with_opts(
        upstream_port: u16,
        queue_depth: usize,
        index_steps: usize,
    ) -> Result<RelayNode> {
        let relay = Arc::new(Relay::start_with_opts(queue_depth, index_steps)?);
        let up = tcp::connect_local(upstream_port).context("connecting upstream")?;
        let up_read = up.try_clone()?;
        let upstream = Arc::new(Mutex::new(up));
        // topology handshake: ask the upstream for its hop depth
        {
            let mut conn = upstream.lock().unwrap();
            tcp::write_frame(
                &mut conn,
                &Frame { kind: kind::SUBSCRIBE, payload: 0u64.to_le_bytes().to_vec() },
            )
            .context("subscribing upstream")?;
        }
        // escalation: a downstream NACK the node's index has evicted is
        // forwarded up this same connection; the reply (retransmit or
        // NACK_MISS) comes back on the forward thread
        {
            let upstream = upstream.clone();
            relay.set_escalation(move |step, shard| {
                let mut conn = upstream.lock().unwrap();
                tcp::write_frame(
                    &mut conn,
                    &Frame { kind: kind::NACK, payload: tcp::shard_ack_payload(step, shard) },
                )
                .is_ok()
            });
        }
        let stop = Arc::new(AtomicBool::new(false));
        let upstream_closed = Arc::new(AtomicBool::new(false));
        let forward = spawn_forward(
            up_read,
            relay.clone(),
            stop.clone(),
            upstream_closed.clone(),
        );
        Ok(RelayNode {
            relay,
            upstream,
            forward: Mutex::new(Some(forward)),
            stop,
            upstream_closed,
        })
    }

    /// Port downstream subscribers (or further nodes) connect to.
    pub fn port(&self) -> u16 {
        self.relay.port
    }

    /// The node's downstream relay (staging, counters, subscribers).
    pub fn relay(&self) -> &Arc<Relay> {
        &self.relay
    }

    /// Hops between this node and the publisher (learned from the
    /// upstream's HOP reply; 0 until the reply has arrived).
    pub fn hop(&self) -> u32 {
        self.relay.hop()
    }

    /// True once the upstream stream ended (CLOSE or socket error).
    /// The CLOSE was republished downstream before this flips.
    pub fn upstream_closed(&self) -> bool {
        self.upstream_closed.load(Ordering::SeqCst)
    }

    /// Stop the node: detach from the upstream, then stop the
    /// downstream relay (draining queues best-effort, like
    /// [`Relay::stop`]). Idempotent; takes `&self` so an
    /// `Arc<RelayNode>` shared with workers can still be stopped.
    pub fn stop(&self) {
        self.stop.store(true, Ordering::SeqCst);
        let _ = self.upstream.lock().unwrap().shutdown(Shutdown::Both);
        if let Some(h) = self.forward.lock().unwrap().take() {
            let _ = h.join();
        }
        self.relay.stop();
    }
}

/// Forward thread: reads the upstream stream and republishes it
/// downstream. PATCH frames for slots the node escalated are consumed
/// as retransmits (delivered to the waiting subscribers only, never
/// rebroadcast); everything else is ordinary stream traffic.
fn spawn_forward(
    mut stream: TcpStream,
    relay: Arc<Relay>,
    stop: Arc<AtomicBool>,
    upstream_closed: Arc<AtomicBool>,
) -> std::thread::JoinHandle<()> {
    std::thread::spawn(move || {
        let mut forwarded_close = false;
        loop {
            if stop.load(Ordering::SeqCst) {
                return;
            }
            let frame = match tcp::read_frame(&mut stream) {
                Ok(f) => f,
                Err(_) => {
                    // upstream died: end the downstream stream so leaf
                    // consumers stop waiting (they resync when a new
                    // tree is built)
                    if !forwarded_close {
                        relay.publish(Frame { kind: kind::CLOSE, payload: Vec::new() });
                    }
                    upstream_closed.store(true, Ordering::SeqCst);
                    return;
                }
            };
            match frame.kind {
                kind::PATCH => {
                    // an escalated-NACK retransmit is addressed to the
                    // waiting subscribers only; anything else is stream
                    // traffic for everyone
                    let meta = crate::sparse::container::peek_meta(&frame.payload).ok();
                    let consumed = meta.is_some_and(|m| {
                        relay.deliver_retransmit(m.step, m.shard_index, frame.clone())
                    });
                    if !consumed {
                        relay.publish(frame);
                    }
                }
                kind::ANCHOR | kind::MARKER => relay.publish(frame),
                kind::CLOSE => {
                    relay.publish(frame);
                    forwarded_close = true;
                    upstream_closed.store(true, Ordering::SeqCst);
                    // keep reading: late NACK escalation replies may
                    // still arrive until the socket actually closes
                }
                kind::HOP => {
                    if let Ok(up_hop) = tcp::parse_hop(&frame.payload) {
                        relay.set_hop(up_hop + 1);
                    }
                }
                kind::NACK_MISS => {
                    if let Ok((step, shard)) = tcp::parse_shard_ack(&frame.payload) {
                        relay.fail_escalated(step, shard);
                    }
                }
                _ => {}
            }
        }
    })
}

impl Drop for RelayNode {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        let _ = self.upstream.lock().unwrap().shutdown(Shutdown::Both);
        if let Some(h) = self.forward.lock().unwrap().take() {
            let _ = h.join();
        }
    }
}
