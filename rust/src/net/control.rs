//! Control plane for relay distribution trees: cluster membership,
//! automatic fan-out planning, and live re-parenting.
//!
//! PR 4's mechanisms (relay chaining, NACK escalation, staged anchor +
//! tail catch-up) made deep trees *work*; this module makes them
//! *self-assembling and self-healing*. Peers never hard-wire an
//! upstream address — they JOIN a [`ControlPlane`] over the existing
//! `net::tcp` framing and are told where to attach:
//!
//! ```text
//!   peer                         control plane
//!    │ ── JOIN(role, port) ──────────▶ │  register, replan (epoch+1)
//!    │ ◀─────────── EPOCH(e) ───────── │  fence: nothing older than e
//!    │ ◀─ ASSIGN(e, id, upstream, hop) │  attach here
//!    │ ── HEARTBEAT(id, e) ──────────▶ │  every interval
//!    │        (silence × missed_heartbeats ⇒ dead ⇒ replan)
//! ```
//!
//! * **Membership** — every peer (interior relay or leaf subscriber)
//!   holds one TCP connection to the plane: JOINs register, heartbeats
//!   prove liveness, a closed socket or
//!   [`ControlConfig::missed_heartbeats`] silent intervals mark the
//!   peer dead.
//! * **Planning** — each membership change bumps the epoch and
//!   recomputes a [`crate::coordinator::planner::TopologyPlan`]
//!   (balanced k-ary tree from the *measured* leaf count, per-hop
//!   fan-out cap, optional forced depth). The plan is pushed as ASSIGN
//!   directives; peers that keep their upstream port don't rewire.
//!   Extra relays park as standbys — live spares for the next failure.
//! * **Re-parenting** — a [`ControlledNode`] wraps a detached-mode
//!   [`RelayNode`]: on a new directive it re-attaches its upstream
//!   *while its own subscribers stay connected*, receiving the new
//!   parent's anchor + tail staging as a fresh subscriber and
//!   republishing it downstream — the orphaned subtree catches up
//!   without one leaf resubscribing. Leaves that do sit directly on a
//!   failed relay use [`ControlSubscriberTransport`], which swaps its
//!   inner [`RelayTransport`] subscription on re-parent and counts the
//!   event (`TransportCounters::reparents`); the `Consumer`'s step
//!   tracking makes the replayed catch-up idempotent, so no frame is
//!   ever applied twice across an epoch boundary.
//! * **Epoch fencing** — ASSIGN/EPOCH frames carry the epoch; a client
//!   never applies a directive older than the newest epoch it has
//!   seen, so a delayed directive from a superseded plan (or a plane
//!   hiccup re-delivering one) cannot wire a demoted relay back into
//!   the tree.
//!
//! Hand-wiring ([`RelayNode::join`], `RelayTransport::subscribe`)
//! remains first-class for static single-host topologies; the control
//! plane earns its keep once relays can die or the leaf count is only
//! known at runtime. `tests/integration_control.rs` asserts the
//! acceptance bar: a 3-level tree self-assembles from JOINs alone, and
//! killing a mid-tree relay re-parents its subtree with every
//! surviving leaf bit-identical to the object-store reference.

use super::chaos::{ChaosConfig, Wire};
use super::node::RelayNode;
use super::relay;
use super::tcp::{self, kind, Frame};
use super::transport::{
    FrameId, MarkerId, RelayTransport, StepData, SyncTransport, TransportCounters,
};
use crate::coordinator::planner::{self, TopologyPlan, Upstream};
use crate::sim::clock::Clock;
use crate::storage::retention::Inventory;
use crate::util::retry::{Deadline, RetryPolicy};
use crate::util::sync::{CondvarExt, LockExt};
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::net::{Shutdown, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Peer roles carried in JOIN frames.
pub mod role {
    /// An interior relay: runs a [`super::RelayNode`], serves a
    /// downstream port, can parent other peers.
    pub const RELAY: u8 = 1;
    /// A leaf subscriber: consumes the stream, parents nobody.
    pub const LEAF: u8 = 2;
}

/// Default heartbeat cadence (clients) and the plane's default
/// liveness bookkeeping derives from it.
pub const DEFAULT_HEARTBEAT: Duration = Duration::from_millis(500);

/// Control-plane tuning knobs.
#[derive(Debug, Clone, Copy)]
pub struct ControlConfig {
    /// Per-hop fan-out cap the planner balances under (≥ 2).
    pub fanout_cap: usize,
    /// Force at least this many interior relay levels (0 = minimal
    /// depth; failover experiments force 3+ hop trees this way).
    pub min_relay_levels: usize,
    /// Expected peer heartbeat cadence. Clients must be constructed
    /// with the same value (it is not negotiated on the wire).
    pub heartbeat_interval: Duration,
    /// Consecutive missed heartbeats before a peer is declared dead
    /// and its subtree re-parented (≥ 1).
    pub missed_heartbeats: u32,
    /// How long an *unregistered* connection may sit silent before its
    /// handler thread gives up on it (a port scan or LB health check
    /// that never JOINs must not leak a blocked thread).
    pub probe_read_timeout: Duration,
    /// Write budget for directive pushes. `replan` pushes while holding
    /// the plane mutex: a peer that stops draining its control socket
    /// must fail the write (and be marked dead) rather than block the
    /// whole plane behind a full send buffer.
    pub push_write_timeout: Duration,
}

impl Default for ControlConfig {
    fn default() -> Self {
        ControlConfig {
            fanout_cap: 4,
            min_relay_levels: 0,
            heartbeat_interval: DEFAULT_HEARTBEAT,
            missed_heartbeats: 3,
            probe_read_timeout: Duration::from_secs(10),
            push_write_timeout: Duration::from_secs(2),
        }
    }
}

impl ControlConfig {
    fn death_timeout(&self) -> Duration {
        self.heartbeat_interval * self.missed_heartbeats.max(1)
    }
}

// =========================================================== Membership

/// One registered peer as the membership machine sees it (no socket —
/// the plane pairs these with [`Wire`] write halves, the simulator
/// with modeled nodes).
#[derive(Debug, Clone)]
pub struct MemberEntry {
    pub id: u64,
    pub role: u8,
    /// Downstream listen port (0 for leaves and simulated peers).
    pub listen_port: u16,
    /// Clock reading of the last JOIN/HEARTBEAT (see
    /// [`crate::sim::clock::Clock`]).
    pub last_heartbeat: Duration,
    pub alive: bool,
}

/// The socket-free membership + planning state machine: peer registry,
/// heartbeat liveness, death sweeps, and epoch-bumping replans through
/// the real [`planner::stable_relay_order`] + [`planner::bind`].
///
/// Extracted from the TCP control plane so the scale simulator
/// (`crate::sim`) drives the *same* membership arithmetic — timing
/// flows through explicit `now` readings, so heartbeat timeouts work
/// identically on the wall and in simulated time. The plane keeps the
/// sockets ([`ControlPlane`] pairs each entry with a [`Wire`]); this
/// struct decides *who is alive and where everyone attaches*.
#[derive(Default)]
pub struct Membership {
    peers: Vec<MemberEntry>,
    epoch: u64,
    next_id: u64,
    plan: Option<TopologyPlan>,
    replans: u64,
    deaths: u64,
}

impl Membership {
    pub fn new() -> Membership {
        Membership { next_id: 1, ..Default::default() }
    }

    /// Register a peer at clock reading `now`; returns its assigned id.
    /// Does NOT replan — the caller decides when (the plane replans per
    /// JOIN; the simulator batches a wave of joins into one replan).
    pub fn join(&mut self, role: u8, listen_port: u16, now: Duration) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        self.peers.push(MemberEntry {
            id,
            role,
            listen_port,
            last_heartbeat: now,
            alive: true,
        });
        id
    }

    /// Refresh a peer's liveness; true when this resurrected a peer the
    /// detector had given up on (the caller should replan — it re-enters
    /// the pool).
    pub fn heartbeat(&mut self, id: u64, now: Duration) -> bool {
        match self.peers.iter_mut().find(|p| p.id == id) {
            Some(p) => {
                p.last_heartbeat = now;
                let resurrected = !p.alive;
                p.alive = true;
                resurrected
            }
            None => false,
        }
    }

    /// Bulk liveness refresh: one pass over the registry, refreshing
    /// every peer `beating` reports as up. This is the simulator's
    /// heartbeat *transport* (a wave of beacons landing in one tick —
    /// per-id [`Membership::heartbeat`] would make a 100k-peer wave
    /// quadratic in registry scans); detection semantics are untouched
    /// and still live in [`Membership::sweep`]. Returns how many
    /// refreshed peers the detector had already declared dead (the
    /// caller should replan — they re-enter the pool).
    pub fn heartbeat_all(
        &mut self,
        now: Duration,
        mut beating: impl FnMut(u64) -> bool,
    ) -> u64 {
        let mut resurrected = 0u64;
        for p in self.peers.iter_mut() {
            if beating(p.id) {
                p.last_heartbeat = now;
                if !p.alive {
                    resurrected += 1;
                }
                p.alive = true;
            }
        }
        resurrected
    }

    /// Declare one peer dead (socket teardown, failed directive push).
    /// True when it was alive — the death is counted and the caller
    /// should replan around it.
    pub fn mark_dead(&mut self, id: u64) -> bool {
        match self.peers.iter_mut().find(|p| p.id == id && p.alive) {
            Some(p) => {
                p.alive = false;
                self.deaths += 1;
                true
            }
            None => false,
        }
    }

    /// Failure-detector sweep: every live peer silent past `timeout` at
    /// reading `now` is declared dead. Returns how many died (caller
    /// replans once for the whole sweep).
    pub fn sweep(&mut self, now: Duration, timeout: Duration) -> u64 {
        let mut died = 0u64;
        for p in self.peers.iter_mut().filter(|p| p.alive) {
            if now.saturating_sub(p.last_heartbeat) > timeout {
                p.alive = false;
                died += 1;
            }
        }
        self.deaths += died;
        died
    }

    /// Bump the epoch and bind a fresh plan for the current live
    /// membership: stable slots (survivors keep their place, spares
    /// fill dead peers' holes — so only a dead peer's own subtree
    /// rewires), then the planner's balanced k-ary bind. The plan is
    /// retained (for the next stable order) and returned for pushing.
    pub fn plan_next(&mut self, fanout_cap: usize, min_relay_levels: usize) -> &TopologyPlan {
        self.epoch += 1;
        self.replans += 1;
        let relays: Vec<u64> = self
            .peers
            .iter()
            .filter(|p| p.alive && p.role == role::RELAY)
            .map(|p| p.id)
            .collect();
        let leaves: Vec<u64> = self
            .peers
            .iter()
            .filter(|p| p.alive && p.role == role::LEAF)
            .map(|p| p.id)
            .collect();
        let relays = planner::stable_relay_order(self.plan.as_ref(), &relays);
        let plan = planner::bind(self.epoch, &relays, &leaves, fanout_cap, min_relay_levels);
        &*self.plan.insert(plan)
    }

    /// Current topology epoch (0 until the first replan).
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Replans so far (joins, deaths, forced).
    pub fn replans(&self) -> u64 {
        self.replans
    }

    /// Peers declared dead so far (heartbeat timeout, socket teardown,
    /// push failure).
    pub fn deaths(&self) -> u64 {
        self.deaths
    }

    /// The current plan (None before the first replan).
    pub fn plan(&self) -> Option<&TopologyPlan> {
        self.plan.as_ref()
    }

    /// Live `(relays, leaves)` counts.
    pub fn live_counts(&self) -> (usize, usize) {
        let relays =
            self.peers.iter().filter(|p| p.alive && p.role == role::RELAY).count();
        let leaves =
            self.peers.iter().filter(|p| p.alive && p.role == role::LEAF).count();
        (relays, leaves)
    }

    /// Whether `id` is registered and alive.
    pub fn is_alive(&self, id: u64) -> bool {
        self.peers.iter().any(|p| p.id == id && p.alive)
    }

    /// All registered peers (dead ones included), registration order.
    pub fn peers(&self) -> &[MemberEntry] {
        &self.peers
    }
}

// ========================================================= ControlPlane

/// A registered peer's control socket (write half for ASSIGN/EPOCH
/// pushes; the handler thread owns the read half). A [`Wire`] so a
/// chaos-enabled plane exercises its push-failure paths under injected
/// wire faults.
struct PeerConn {
    id: u64,
    conn: Wire,
}

struct PlaneState {
    members: Membership,
    conns: Vec<PeerConn>,
    root_port: u16,
    /// Wall on the socket plane; the simulator drives [`Membership`]
    /// directly off its virtual clock instead.
    clock: Clock,
}

impl PlaneState {
    /// Recompute the plan for the current live membership and push it
    /// to every live peer (EPOCH fence first, then the peer's ASSIGN).
    /// A peer whose control socket fails the push is dead: it is
    /// counted and the plan recomputed immediately WITHOUT it, so
    /// children the failed plan parented under it are re-homed in the
    /// same call instead of stranding until the next membership event.
    /// Terminates: every retry shrinks the live set by at least one.
    fn replan(&mut self, cfg: &ControlConfig) {
        while !self.replan_once(cfg) {}
    }

    /// One planning + push pass; false if a push failure killed a peer
    /// (the plan is stale and must be recomputed).
    fn replan_once(&mut self, cfg: &ControlConfig) -> bool {
        let plan = self.members.plan_next(cfg.fanout_cap, cfg.min_relay_levels).clone();
        let port_of: HashMap<u64, u16> =
            self.members.peers().iter().map(|p| (p.id, p.listen_port)).collect();
        let root_port = self.root_port;
        let epoch = plan.epoch;
        let mut push_deaths = 0u64;
        for pc in self.conns.iter_mut() {
            if !self.members.is_alive(pc.id) {
                continue;
            }
            let Some(a) = plan.assignment_of(pc.id) else { continue };
            let upstream_port = match a.upstream {
                Upstream::Root => root_port,
                Upstream::Peer(id) => port_of.get(&id).copied().unwrap_or(0),
                Upstream::Standby => 0,
            };
            let ok = tcp::write_frame(
                &mut pc.conn,
                &Frame { kind: kind::EPOCH, payload: tcp::epoch_payload(epoch) },
            )
            .and_then(|_| {
                tcp::write_frame(
                    &mut pc.conn,
                    &Frame {
                        kind: kind::ASSIGN,
                        payload: tcp::assign_payload(epoch, pc.id, upstream_port, a.hop),
                    },
                )
            })
            .is_ok();
            if !ok {
                self.members.mark_dead(pc.id);
                push_deaths += 1;
            }
        }
        push_deaths == 0
    }
}

/// The membership + planning service. One per distribution tree; holds
/// the root relay's port (the publisher's own relay — the stream
/// source, which never JOINs) and assigns every other peer its place.
pub struct ControlPlane {
    pub port: u16,
    cfg: ControlConfig,
    shared: Arc<Mutex<PlaneState>>,
    stop: Arc<AtomicBool>,
    accept: Mutex<Option<std::thread::JoinHandle<()>>>,
    monitor: Mutex<Option<std::thread::JoinHandle<()>>>,
}

impl ControlPlane {
    /// Start the plane on an ephemeral localhost port. `root_port` is
    /// the root relay every epoch's tree hangs under.
    pub fn start(root_port: u16, cfg: ControlConfig) -> Result<ControlPlane> {
        ControlPlane::start_with_chaos(root_port, cfg, None)
    }

    /// [`ControlPlane::start`] with seeded wire-fault injection on
    /// every accepted control connection: JOIN intake, directive
    /// pushes, and heartbeat reads all run over the faulty wire, so
    /// membership and replanning are exercised against the same
    /// failure modes as the data plane.
    pub fn start_with_chaos(
        root_port: u16,
        cfg: ControlConfig,
        chaos: Option<ChaosConfig>,
    ) -> Result<ControlPlane> {
        let (listener, port) = tcp::listen_local()?;
        listener.set_nonblocking(true)?;
        let shared = Arc::new(Mutex::new(PlaneState {
            members: Membership::new(),
            conns: Vec::new(),
            root_port,
            clock: Clock::wall(),
        }));
        let stop = Arc::new(AtomicBool::new(false));
        let accept = Mutex::new(Some(spawn_plane_accept(
            listener,
            shared.clone(),
            cfg,
            stop.clone(),
            chaos,
        )));
        let monitor = Mutex::new(Some(spawn_plane_monitor(shared.clone(), cfg, stop.clone())));
        Ok(ControlPlane { port, cfg, shared, stop, accept, monitor })
    }

    /// Current topology epoch (0 until the first peer joins).
    pub fn epoch(&self) -> u64 {
        self.shared.plock().members.epoch()
    }

    /// Replans so far (joins, deaths, forced).
    pub fn replans(&self) -> u64 {
        self.shared.plock().members.replans()
    }

    /// Peers declared dead by heartbeat timeout so far.
    pub fn deaths(&self) -> u64 {
        self.shared.plock().members.deaths()
    }

    /// Live `(relays, leaves)` counts.
    pub fn live_peers(&self) -> (usize, usize) {
        self.shared.plock().members.live_counts()
    }

    /// Snapshot of the current plan (None before the first JOIN).
    pub fn plan(&self) -> Option<TopologyPlan> {
        self.shared.plock().members.plan().cloned()
    }

    /// Root-to-leaf hop depth of the current plan.
    pub fn depth(&self) -> Option<usize> {
        self.plan().map(|p| p.depth())
    }

    /// Bump the epoch and push fresh ASSIGNs without a membership
    /// change (operational escape hatch).
    pub fn force_replan(&self) {
        self.shared.plock().replan(&self.cfg);
    }

    /// Stop the plane: no more joins, no more replans; peers keep
    /// their last assignment (the data plane keeps flowing — the
    /// control plane is not on the data path).
    pub fn stop(&self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.accept.plock().take() {
            let _ = h.join();
        }
        if let Some(h) = self.monitor.plock().take() {
            let _ = h.join();
        }
        let sh = self.shared.plock();
        for pc in &sh.conns {
            let _ = pc.conn.shutdown(Shutdown::Both);
        }
    }
}

impl Drop for ControlPlane {
    fn drop(&mut self) {
        self.stop();
    }
}

fn spawn_plane_accept(
    listener: TcpListener,
    shared: Arc<Mutex<PlaneState>>,
    cfg: ControlConfig,
    stop: Arc<AtomicBool>,
    chaos: Option<ChaosConfig>,
) -> std::thread::JoinHandle<()> {
    std::thread::spawn(move || loop {
        if stop.load(Ordering::SeqCst) {
            return;
        }
        match listener.accept() {
            Ok((stream, _)) => {
                stream.set_nodelay(true).ok();
                let stream = Wire::wrap(stream, chaos.as_ref());
                let shared = shared.clone();
                let stop = stop.clone();
                // handler threads are detached: they exit when their
                // socket dies, which ControlPlane::stop forces
                std::thread::spawn(move || plane_handler(stream, shared, cfg, stop));
            }
            Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                // pallas-lint: allow(retry-discipline): nonblocking-accept poll cadence, not a recovery wait
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(_) => return,
        }
    })
}

/// Per-peer handler: reads the peer's side of the control connection.
/// JOIN registers (and replans); HEARTBEAT refreshes liveness (and
/// resurrects a peer the monitor gave up on — it re-enters the pool at
/// the next replan); CLOSE or a dead socket marks the peer dead.
fn plane_handler(
    mut stream: Wire,
    shared: Arc<Mutex<PlaneState>>,
    cfg: ControlConfig,
    stop: Arc<AtomicBool>,
) {
    // until a JOIN lands this connection is unregistered — stop()
    // cannot find it to shut down, so a silent probe (port scan, LB
    // health check) must time itself out instead of leaking a
    // permanently-blocked thread
    let _ = stream.set_read_timeout(Some(cfg.probe_read_timeout));
    let mut my_id: Option<u64> = None;
    loop {
        if stop.load(Ordering::SeqCst) {
            return;
        }
        let frame = match tcp::read_frame(&mut stream) {
            Ok(f) => f,
            Err(_) => break,
        };
        match frame.kind {
            kind::JOIN => {
                let Ok((peer_role, listen_port)) = tcp::parse_join(&frame.payload) else {
                    break;
                };
                let Ok(conn) = stream.try_clone() else { break };
                // replan() pushes directives while holding the plane
                // mutex: a peer that stops draining its control socket
                // must fail the write (and be marked dead) rather than
                // block the whole plane — including failure detection —
                // behind a full send buffer
                let _ = conn.set_write_timeout(Some(cfg.push_write_timeout));
                // registered peers block on reads indefinitely (their
                // liveness is the heartbeat timeout, and stop() can now
                // reach this socket through the peer table)
                let _ = stream.set_read_timeout(None);
                let mut sh = shared.plock();
                let now = sh.clock.now();
                let id = sh.members.join(peer_role, listen_port, now);
                my_id = Some(id);
                sh.conns.push(PeerConn { id, conn });
                sh.replan(&cfg);
            }
            kind::HEARTBEAT => {
                if let Ok((id, _peer_epoch)) = tcp::parse_heartbeat(&frame.payload) {
                    let mut sh = shared.plock();
                    let now = sh.clock.now();
                    if sh.members.heartbeat(id, now) {
                        // resurrected a peer the monitor gave up on —
                        // it re-enters the pool at this replan
                        sh.replan(&cfg);
                    }
                }
            }
            kind::OBS_SNAP => {
                // introspection probes never JOIN — answer on the same
                // socket and keep the probe timeout armed
                let flags = tcp::parse_obs_snap(&frame.payload).unwrap_or(0);
                let mut c = crate::util::json::Json::obj();
                {
                    let sh = shared.plock();
                    let (relays, leaves) = sh.members.live_counts();
                    c.set("epoch", sh.members.epoch().into())
                        .set("replans", sh.members.replans().into())
                        .set("deaths", sh.members.deaths().into())
                        .set("live_relays", relays.into())
                        .set("live_leaves", leaves.into())
                        .set("root_port", (sh.root_port as u64).into());
                }
                let body = crate::obs::snapshot_reply("control", flags, c).to_string();
                let reply =
                    Frame { kind: kind::OBS_REPLY, payload: tcp::obs_reply_payload(&body) };
                if tcp::write_frame(&mut stream, &reply).is_err() {
                    break;
                }
            }
            kind::CLOSE => break,
            _ => {}
        }
    }
    // the peer's connection ended: it is gone (orderly or not). A
    // plane being stopped tears these sockets down itself — honor
    // stop()'s "no more replans" contract instead of replanning the
    // teardown.
    if stop.load(Ordering::SeqCst) {
        return;
    }
    if let Some(id) = my_id {
        let mut sh = shared.plock();
        if sh.members.mark_dead(id) {
            sh.replan(&cfg);
        }
    }
}

/// Failure detector: any live peer silent past the death timeout is
/// declared dead and the tree replans around it in one sweep.
///
/// Wall-clock audit (scale-sim seam): the `sleep(tick)` below is the
/// socket plane's polling cadence and intentionally stays real — this
/// thread only exists when a TCP plane is started. The *decision*
/// (who is silent past the timeout) lives in [`Membership::sweep`] and
/// runs off `Clock` readings, which is what the simulator drives from
/// virtual time without ever spawning this thread.
fn spawn_plane_monitor(
    shared: Arc<Mutex<PlaneState>>,
    cfg: ControlConfig,
    stop: Arc<AtomicBool>,
) -> std::thread::JoinHandle<()> {
    std::thread::spawn(move || {
        let tick = (cfg.heartbeat_interval / 2).max(Duration::from_millis(5));
        let timeout = cfg.death_timeout();
        loop {
            if stop.load(Ordering::SeqCst) {
                return;
            }
            // pallas-lint: allow(retry-discipline): failure-detector sweep cadence; the decision runs off Clock
            std::thread::sleep(tick);
            let mut sh = shared.plock();
            let now = sh.clock.now();
            if sh.members.sweep(now, timeout) > 0 {
                sh.replan(&cfg);
            }
        }
    })
}

// ======================================================== ControlClient

/// The peer-side epoch fence: a directive is only applied when it is
/// at least as new as the newest epoch the peer has seen (EPOCH
/// broadcast or accepted ASSIGN), so a delayed directive from a
/// superseded plan cannot wire a demoted relay back into the tree.
///
/// Extracted from the client reader so simulated peers (`crate::sim`)
/// fence modeled directives with the same arithmetic as TCP clients.
#[derive(Default, Debug, Clone)]
pub struct EpochFence {
    epoch: u64,
}

impl EpochFence {
    /// Record an EPOCH broadcast (monotone — never rewinds).
    pub fn observe(&mut self, epoch: u64) {
        self.epoch = self.epoch.max(epoch);
    }

    /// Admit or fence a directive carried at `epoch`: false when a
    /// newer epoch already superseded it. Admission advances the fence.
    pub fn admit(&mut self, epoch: u64) -> bool {
        if epoch < self.epoch {
            return false;
        }
        self.epoch = epoch;
        true
    }

    /// Newest epoch seen.
    pub fn epoch(&self) -> u64 {
        self.epoch
    }
}

#[derive(Default)]
struct ClientState {
    peer_id: Option<u64>,
    /// Newest epoch seen (EPOCH fence or accepted ASSIGN).
    fence: EpochFence,
    /// Latest accepted directive: `(upstream_port, hop)`; port 0 =
    /// standby. None until the first ASSIGN.
    directive: Option<(u16, u32)>,
    /// Bumps on every accepted ASSIGN (so the supervisor can tell a
    /// re-push of the same port from no news).
    directive_seq: u64,
    closed: bool,
}

/// A peer's side of the control connection: JOIN handshake, directive
/// intake with epoch fencing, heartbeat emission. Shared by
/// [`ControlledNode`] (relays) and [`ControlSubscriberTransport`]
/// (leaves).
struct ControlClient {
    conn: Arc<Mutex<TcpStream>>,
    state: Arc<(Mutex<ClientState>, Condvar)>,
    stop: Arc<AtomicBool>,
    /// Fault injection: stop emitting heartbeats while keeping the
    /// connection open — a hung process, as the detector sees it.
    silenced: Arc<AtomicBool>,
    reader: Mutex<Option<std::thread::JoinHandle<()>>>,
    heart: Mutex<Option<std::thread::JoinHandle<()>>>,
}

impl ControlClient {
    fn join(
        ctl_port: u16,
        peer_role: u8,
        listen_port: u16,
        heartbeat: Duration,
    ) -> Result<ControlClient> {
        let mut stream = tcp::connect_local(ctl_port).context("connecting control plane")?;
        let rstream = stream.try_clone()?;
        let state: Arc<(Mutex<ClientState>, Condvar)> = Arc::new(Default::default());
        let stop = Arc::new(AtomicBool::new(false));
        // reader first, so the JOIN's immediate ASSIGN cannot race it
        let reader = spawn_client_reader(rstream, state.clone(), stop.clone());
        tcp::write_frame(
            &mut stream,
            &Frame { kind: kind::JOIN, payload: tcp::join_payload(peer_role, listen_port) },
        )
        .context("sending JOIN")?;
        let conn = Arc::new(Mutex::new(stream));
        let silenced = Arc::new(AtomicBool::new(false));
        let heart = spawn_client_heartbeat(
            conn.clone(),
            state.clone(),
            stop.clone(),
            silenced.clone(),
            heartbeat,
        );
        Ok(ControlClient {
            conn,
            state,
            stop,
            silenced,
            reader: Mutex::new(Some(reader)),
            heart: Mutex::new(Some(heart)),
        })
    }

    /// Fault injection: go silent (no more heartbeats) without closing
    /// the control connection — the plane must discover the death by
    /// timeout, not by socket teardown.
    fn silence(&self) {
        self.silenced.store(true, Ordering::SeqCst);
    }

    fn snapshot(&self) -> (u64, u64, Option<(u16, u32)>, Option<u64>) {
        let st = self.state.0.plock();
        (st.fence.epoch(), st.directive_seq, st.directive, st.peer_id)
    }

    fn epoch(&self) -> u64 {
        self.state.0.plock().fence.epoch()
    }

    fn peer_id(&self) -> Option<u64> {
        self.state.0.plock().peer_id
    }

    /// Wait (bounded) for a directive newer than `seen_seq`; returns
    /// the new `(seq, port, hop)` or None on timeout/closed plane.
    ///
    /// Wall-clock audit: `Instant` here (and in the heartbeat thread's
    /// sliced sleep) bounds a real condvar wait on a real socket's
    /// state — client threads exist only on the TCP plane, so virtual
    /// runs cannot block on them. The epoch-fence arithmetic a
    /// simulated peer shares lives in [`EpochFence`], not here.
    fn wait_directive(&self, seen_seq: u64, timeout: Duration) -> Option<(u64, u16, u32)> {
        let (lock, cv) = &*self.state;
        // pallas-lint: allow(clock-seam): bounds a condvar wait on a live socket (see audit note above)
        let deadline = Instant::now() + timeout;
        let mut st = lock.plock();
        loop {
            if st.directive_seq > seen_seq {
                if let Some((port, hop)) = st.directive {
                    return Some((st.directive_seq, port, hop));
                }
            }
            if st.closed {
                return None;
            }
            // pallas-lint: allow(clock-seam): the matching wall reading of the bounded wait
            let now = Instant::now();
            if now >= deadline {
                return None;
            }
            st = cv.pwait_timeout(st, deadline - now);
        }
    }

    fn stop(&self) {
        self.stop.store(true, Ordering::SeqCst);
        let _ = self.conn.plock().shutdown(Shutdown::Both);
        if let Some(h) = self.reader.plock().take() {
            let _ = h.join();
        }
        if let Some(h) = self.heart.plock().take() {
            let _ = h.join();
        }
    }
}

impl Drop for ControlClient {
    fn drop(&mut self) {
        self.stop();
    }
}

/// Client reader: applies ASSIGN directives with the epoch fence —
/// nothing older than the newest epoch seen (EPOCH or ASSIGN) is ever
/// accepted, so a stale directive cannot wire a demoted peer back in.
fn spawn_client_reader(
    mut stream: TcpStream,
    state: Arc<(Mutex<ClientState>, Condvar)>,
    stop: Arc<AtomicBool>,
) -> std::thread::JoinHandle<()> {
    std::thread::spawn(move || loop {
        if stop.load(Ordering::SeqCst) {
            return;
        }
        let (lock, cv) = &*state;
        let frame = match tcp::read_frame(&mut stream) {
            Ok(f) => f,
            Err(_) => {
                lock.plock().closed = true;
                cv.notify_all();
                return;
            }
        };
        match frame.kind {
            kind::EPOCH => {
                if let Ok(e) = tcp::parse_epoch(&frame.payload) {
                    lock.plock().fence.observe(e);
                }
            }
            kind::ASSIGN => {
                if let Ok((epoch, id, port, hop)) = tcp::parse_assign(&frame.payload) {
                    let mut st = lock.plock();
                    if !st.fence.admit(epoch) {
                        continue; // fenced: a newer epoch superseded this
                    }
                    st.peer_id = Some(id);
                    st.directive = Some((port, hop));
                    st.directive_seq += 1;
                    cv.notify_all();
                }
            }
            kind::CLOSE => {
                lock.plock().closed = true;
                cv.notify_all();
                return;
            }
            _ => {}
        }
    })
}

fn spawn_client_heartbeat(
    conn: Arc<Mutex<TcpStream>>,
    state: Arc<(Mutex<ClientState>, Condvar)>,
    stop: Arc<AtomicBool>,
    silenced: Arc<AtomicBool>,
    interval: Duration,
) -> std::thread::JoinHandle<()> {
    std::thread::spawn(move || loop {
        // sliced wait so stop() never waits out a long interval
        let pause = Deadline::after(interval);
        while !pause.expired() {
            if stop.load(Ordering::SeqCst) {
                return;
            }
            pause.tick(Duration::from_millis(10).min(interval));
        }
        if stop.load(Ordering::SeqCst) {
            return;
        }
        if silenced.load(Ordering::SeqCst) {
            continue;
        }
        let (id, epoch) = {
            let st = state.0.plock();
            if st.closed {
                return;
            }
            (st.peer_id, st.fence.epoch())
        };
        let Some(id) = id else { continue };
        let mut c = conn.plock();
        if tcp::write_frame(
            &mut c,
            &Frame { kind: kind::HEARTBEAT, payload: tcp::heartbeat_payload(id, epoch) },
        )
        .is_err()
        {
            return;
        }
    })
}

// ======================================================= ControlledNode

/// An interior relay under control-plane management: a detached-mode
/// [`RelayNode`] whose upstream attachment follows ASSIGN directives.
/// Its own downstream subscribers never notice a re-parent — they are
/// served from the node's staging throughout, then receive the new
/// parent's catch-up republish.
pub struct ControlledNode {
    node: Arc<RelayNode>,
    client: Arc<ControlClient>,
    reparents: Arc<AtomicU64>,
    retries: Arc<AtomicU64>,
    stop: Arc<AtomicBool>,
    supervisor: Mutex<Option<std::thread::JoinHandle<()>>>,
}

impl ControlledNode {
    /// Join the plane at `ctl_port` with default relay options and the
    /// default heartbeat cadence.
    pub fn join(ctl_port: u16) -> Result<ControlledNode> {
        ControlledNode::join_with_opts(
            ctl_port,
            relay::DEFAULT_QUEUE_DEPTH,
            relay::INDEX_STEPS,
            DEFAULT_HEARTBEAT,
        )
    }

    /// Join with explicit queue depth / NACK index bound for the
    /// node's own relay, and an explicit heartbeat cadence (must match
    /// the plane's [`ControlConfig::heartbeat_interval`]).
    pub fn join_with_opts(
        ctl_port: u16,
        queue_depth: usize,
        index_steps: usize,
        heartbeat: Duration,
    ) -> Result<ControlledNode> {
        ControlledNode::join_with_chaos(ctl_port, queue_depth, index_steps, heartbeat, None)
    }

    /// [`ControlledNode::join_with_opts`] with seeded wire-fault
    /// injection on the node's *data* plane: its upstream attachments
    /// and every downstream subscriber it accepts run over the faulty
    /// wire (the control connection itself stays clean — pair with
    /// [`ControlPlane::start_with_chaos`] to break both planes).
    pub fn join_with_chaos(
        ctl_port: u16,
        queue_depth: usize,
        index_steps: usize,
        heartbeat: Duration,
        chaos: Option<ChaosConfig>,
    ) -> Result<ControlledNode> {
        let node = Arc::new(RelayNode::detached_with_chaos(queue_depth, index_steps, chaos)?);
        let client =
            Arc::new(ControlClient::join(ctl_port, role::RELAY, node.port(), heartbeat)?);
        let reparents = Arc::new(AtomicU64::new(0));
        let retries = Arc::new(AtomicU64::new(0));
        let stop = Arc::new(AtomicBool::new(false));
        let supervisor = Mutex::new(Some(spawn_node_supervisor(
            node.clone(),
            client.clone(),
            reparents.clone(),
            retries.clone(),
            stop.clone(),
        )));
        Ok(ControlledNode { node, client, reparents, retries, stop, supervisor })
    }

    /// Port downstream subscribers (or further nodes) connect to.
    pub fn port(&self) -> u16 {
        self.node.port()
    }

    /// The managed relay node (staging, counters, subscribers).
    pub fn node(&self) -> &Arc<RelayNode> {
        &self.node
    }

    /// Topology epoch last accepted from the plane.
    pub fn epoch(&self) -> u64 {
        self.client.epoch()
    }

    /// Plane-assigned peer id (None until the first ASSIGN arrives).
    pub fn peer_id(&self) -> Option<u64> {
        self.client.peer_id()
    }

    /// Upstream re-attachments beyond the first (failover/replan cost).
    pub fn reparents(&self) -> u64 {
        self.reparents.load(Ordering::Relaxed)
    }

    /// Failed upstream-attach attempts the supervisor retried with
    /// backoff (the assigned parent wasn't listening yet, or the
    /// connect itself failed under injected faults).
    pub fn connect_retries(&self) -> u64 {
        self.retries.load(Ordering::Relaxed)
    }

    /// Hops between this node and the publisher under the current
    /// attachment.
    pub fn hop(&self) -> u32 {
        self.node.hop()
    }

    /// Stop: leave the plane, detach upstream, stop the relay. The
    /// closed control connection is an *orderly* leave — the plane
    /// re-parents this node's subtree immediately, no timeout needed.
    pub fn stop(&self) {
        self.stop.store(true, Ordering::SeqCst);
        self.client.stop();
        if let Some(h) = self.supervisor.plock().take() {
            let _ = h.join();
        }
        self.node.stop();
    }

    /// Fault injection (failover tests and drills): crash the data
    /// plane — relay, upstream, subscribers — and go silent on the
    /// control plane while keeping the control socket OPEN. To the
    /// failure detector this is a hung process: the death is only
    /// discoverable by heartbeat timeout, which is exactly the path
    /// being exercised.
    pub fn fail_silently(&self) {
        self.client.silence();
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.supervisor.plock().take() {
            let _ = h.join();
        }
        self.node.stop();
    }
}

impl Drop for ControlledNode {
    fn drop(&mut self) {
        self.stop();
    }
}

impl RelayNode {
    /// Take the upstream from the control plane instead of a
    /// hard-coded address: JOIN the plane at `ctl_port` as a relay and
    /// follow its ASSIGN directives (initial attachment, standby, and
    /// live re-parenting across epochs).
    pub fn connect_via_control(ctl_port: u16) -> Result<ControlledNode> {
        ControlledNode::join(ctl_port)
    }
}

/// Node supervisor: applies directives to the underlying node. Rewires
/// only when the upstream PORT changes (or the current upstream died),
/// so an epoch bump that keeps a peer's parent costs nothing on the
/// data plane. Connect failures retry under
/// [`RetryPolicy::connect_default`] backoff — the upstream named by a
/// fresh plan may itself still be attaching — and the schedule resets
/// on success (the supervisor never gives up: a directive change
/// restarts it from the base delay).
fn spawn_node_supervisor(
    node: Arc<RelayNode>,
    client: Arc<ControlClient>,
    reparents: Arc<AtomicU64>,
    retries: Arc<AtomicU64>,
    stop: Arc<AtomicBool>,
) -> std::thread::JoinHandle<()> {
    std::thread::spawn(move || {
        let policy = RetryPolicy::connect_default();
        let mut seen_seq = 0u64;
        let mut applied_port: Option<u16> = None;
        let mut ever_attached = false;
        let mut failed_attempts = 0u32;
        loop {
            if stop.load(Ordering::SeqCst) {
                return;
            }
            let (_, seq, directive, _) = client.snapshot();
            seen_seq = seen_seq.max(seq);
            match directive {
                None | Some((0, _)) => {
                    // standby (or nothing yet): hold no upstream
                    if applied_port.is_some() {
                        node.detach_upstream();
                        applied_port = None;
                    }
                    failed_attempts = 0;
                }
                Some((port, hop)) => {
                    // re-attach on a directive change or a DEAD socket;
                    // an orderly CLOSE (upstream_closed without
                    // upstream_failed) is the stream ending, not the
                    // parent dying — never rewire around it
                    let need = applied_port != Some(port)
                        || (!node.upstream_attached())
                        || node.upstream_failed();
                    if need {
                        if node.attach_upstream(port).is_ok() {
                            if ever_attached {
                                reparents.fetch_add(1, Ordering::Relaxed);
                            }
                            ever_attached = true;
                            applied_port = Some(port);
                            failed_attempts = 0;
                        } else {
                            applied_port = None;
                            retries.fetch_add(1, Ordering::Relaxed);
                            failed_attempts = failed_attempts.saturating_add(1);
                        }
                    }
                    // the plan's hop is authoritative for a managed
                    // node (the SUBSCRIBE→HOP handshake may have read
                    // the parent before ITS hop settled); write only
                    // on drift so steady state costs one read per tick
                    if node.relay().hop() != hop {
                        node.relay().set_hop(hop);
                    }
                }
            }
            // wake promptly on a new directive, re-check health often;
            // while attach attempts are failing the tick IS the backoff
            // (a fresh directive still wakes the wait early)
            let tick = if failed_attempts > 0 {
                policy.delay_for(failed_attempts - 1)
            } else {
                Duration::from_millis(20)
            };
            client.wait_directive(seen_seq, tick);
        }
    })
}

// ============================================ ControlSubscriberTransport

/// Leaf-side sync transport under control-plane management: delegates
/// every consumer-side [`SyncTransport`] call to an inner
/// [`RelayTransport`] subscription that the plane can move between
/// relays. On re-parent the inner subscription is swapped for a fresh
/// one against the new upstream; the replayed anchor + tail stages
/// there and the `Consumer`'s step tracking skips everything already
/// applied — zero duplicate frames across the epoch boundary.
/// `counters()` reports the inner backend's traffic **since the last
/// re-parent**, plus the cumulative `reparents` count and the current
/// `epoch`.
pub struct ControlSubscriberTransport {
    client: Arc<ControlClient>,
    inner: Arc<Mutex<Option<Arc<RelayTransport>>>>,
    reparents: Arc<AtomicU64>,
    retries: Arc<AtomicU64>,
    stop: Arc<AtomicBool>,
    supervisor: Mutex<Option<std::thread::JoinHandle<()>>>,
}

impl ControlSubscriberTransport {
    /// JOIN the plane at `ctl_port` as a leaf with the default
    /// heartbeat cadence; the first ASSIGN produces the first
    /// subscription (consumer calls error with "no upstream assigned"
    /// until then — poll [`Consumer::latest_ready`] as usual).
    ///
    /// [`Consumer::latest_ready`]: crate::pulse::sync::Consumer::latest_ready
    pub fn join(ctl_port: u16) -> Result<ControlSubscriberTransport> {
        ControlSubscriberTransport::join_with_heartbeat(ctl_port, DEFAULT_HEARTBEAT)
    }

    /// [`ControlSubscriberTransport::join`] with an explicit heartbeat
    /// cadence (must match the plane's).
    pub fn join_with_heartbeat(
        ctl_port: u16,
        heartbeat: Duration,
    ) -> Result<ControlSubscriberTransport> {
        let client = Arc::new(ControlClient::join(ctl_port, role::LEAF, 0, heartbeat)?);
        let inner: Arc<Mutex<Option<Arc<RelayTransport>>>> = Arc::new(Mutex::new(None));
        let reparents = Arc::new(AtomicU64::new(0));
        let retries = Arc::new(AtomicU64::new(0));
        let stop = Arc::new(AtomicBool::new(false));
        let supervisor = Mutex::new(Some(spawn_leaf_supervisor(
            inner.clone(),
            client.clone(),
            reparents.clone(),
            retries.clone(),
            stop.clone(),
        )));
        Ok(ControlSubscriberTransport { client, inner, reparents, retries, stop, supervisor })
    }

    fn current(&self) -> Result<Arc<RelayTransport>> {
        self.inner
            .plock()
            .clone()
            .ok_or_else(|| anyhow::anyhow!("no upstream assigned yet by the control plane"))
    }

    /// Topology epoch last accepted from the plane.
    pub fn epoch(&self) -> u64 {
        self.client.epoch()
    }

    /// Plane-assigned peer id (None until the first ASSIGN arrives).
    pub fn peer_id(&self) -> Option<u64> {
        self.client.peer_id()
    }

    /// Re-subscriptions beyond the first (failover/replan cost).
    pub fn reparents(&self) -> u64 {
        self.reparents.load(Ordering::Relaxed)
    }

    /// Failed subscribe attempts the supervisor retried with backoff
    /// (also folded into `counters().retries`).
    pub fn connect_retries(&self) -> u64 {
        self.retries.load(Ordering::Relaxed)
    }

    /// Relay hops between this leaf and the publisher under the
    /// current subscription (None before the HOP reply lands).
    pub fn hops(&self) -> Option<u32> {
        self.inner.plock().as_ref().and_then(|t| t.hops())
    }
}

impl Drop for ControlSubscriberTransport {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        self.client.stop();
        if let Some(h) = self.supervisor.plock().take() {
            let _ = h.join();
        }
    }
}

/// Leaf supervisor: (re)subscribes the inner transport per directive.
/// The swap is an `Arc` replace — an in-flight fetch on the old
/// subscription finishes (or errors) on the old value and the next
/// call lands on the new one. Subscribe failures retry under
/// [`RetryPolicy::connect_default`] backoff, counted into `retries`.
fn spawn_leaf_supervisor(
    inner: Arc<Mutex<Option<Arc<RelayTransport>>>>,
    client: Arc<ControlClient>,
    reparents: Arc<AtomicU64>,
    retries: Arc<AtomicU64>,
    stop: Arc<AtomicBool>,
) -> std::thread::JoinHandle<()> {
    std::thread::spawn(move || {
        let policy = RetryPolicy::connect_default();
        let mut seen_seq = 0u64;
        let mut applied_port: Option<u16> = None;
        let mut failed_attempts = 0u32;
        loop {
            if stop.load(Ordering::SeqCst) {
                return;
            }
            let (_, seq, directive, _) = client.snapshot();
            seen_seq = seen_seq.max(seq);
            match directive {
                None | Some((0, _)) => {
                    if applied_port.is_some() {
                        *inner.plock() = None;
                        applied_port = None;
                    }
                    failed_attempts = 0;
                }
                Some((port, hop)) => {
                    let _ = hop; // leaves learn depth from the HOP reply
                    // re-subscribe on a directive change or a DEAD
                    // socket; an orderly CLOSE is the stream ending —
                    // resubscribing would flip stream_closed back to
                    // false and undo end-of-stream for the consumer
                    let dead = inner.plock().as_ref().is_some_and(|t| t.stream_failed());
                    if applied_port != Some(port) || dead {
                        if let Ok(t) = RelayTransport::subscribe(port) {
                            let had_previous = {
                                let mut cur = inner.plock();
                                let had = cur.is_some();
                                *cur = Some(Arc::new(t));
                                had
                            };
                            if had_previous {
                                reparents.fetch_add(1, Ordering::Relaxed);
                            }
                            applied_port = Some(port);
                            failed_attempts = 0;
                        } else {
                            applied_port = None;
                            retries.fetch_add(1, Ordering::Relaxed);
                            failed_attempts = failed_attempts.saturating_add(1);
                        }
                    }
                }
            }
            // the tick doubles as the connect backoff while attempts
            // fail; a fresh directive still wakes the wait early
            let tick = if failed_attempts > 0 {
                policy.delay_for(failed_attempts - 1)
            } else {
                Duration::from_millis(20)
            };
            client.wait_directive(seen_seq, tick);
        }
    })
}

impl SyncTransport for ControlSubscriberTransport {
    fn name(&self) -> &'static str {
        "control-relay"
    }

    fn publish_frame(&self, _id: FrameId, _bytes: &[u8]) -> Result<()> {
        bail!("control-plane leaf transport is consumer-side only")
    }

    fn publish_marker(&self, _id: MarkerId, _payload: &str) -> Result<()> {
        bail!("control-plane leaf transport is consumer-side only")
    }

    fn latest_ready(&self) -> Result<Inventory> {
        self.current()?.latest_ready()
    }

    fn fetch_step(&self, step: u64) -> Result<Option<StepData>> {
        self.current()?.fetch_step(step)
    }

    fn fetch_shard(&self, step: u64, shard: u32) -> Result<Vec<u8>> {
        self.current()?.fetch_shard(step, shard)
    }

    fn fetch_anchor(&self, step: u64) -> Result<(Vec<u8>, String)> {
        self.current()?.fetch_anchor(step)
    }

    fn counters(&self) -> TransportCounters {
        let mut c = match self.current() {
            Ok(t) => t.counters(),
            Err(_) => TransportCounters::default(),
        };
        c.reparents = self.reparents.load(Ordering::Relaxed);
        // supervisor-level subscribe retries join the inner backend's
        // NACK-resend retries under the one unified counter
        c.retries += self.retries.load(Ordering::Relaxed);
        c.epoch = self.client.epoch();
        c
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Drive the raw JOIN/EPOCH/ASSIGN protocol with hand-held sockets:
    /// a relay peer joins (standby while no leaves exist), then a leaf
    /// joins and the replan wires leaf → relay → root.
    #[test]
    fn join_assign_protocol_roundtrip() {
        let cfg = ControlConfig {
            fanout_cap: 2,
            min_relay_levels: 1,
            heartbeat_interval: Duration::from_millis(50),
            missed_heartbeats: 100, // liveness not under test here
            ..Default::default()
        };
        let plane = ControlPlane::start(4242, cfg).unwrap();
        let mut relay_conn = tcp::connect_local(plane.port).unwrap();
        tcp::write_frame(
            &mut relay_conn,
            &Frame { kind: kind::JOIN, payload: tcp::join_payload(role::RELAY, 7777) },
        )
        .unwrap();
        // epoch 1: no leaves yet → the relay parks as standby
        let f = tcp::read_frame(&mut relay_conn).unwrap();
        assert_eq!((f.kind, tcp::parse_epoch(&f.payload).unwrap()), (kind::EPOCH, 1));
        let f = tcp::read_frame(&mut relay_conn).unwrap();
        assert_eq!(f.kind, kind::ASSIGN);
        let (epoch, relay_id, port, _hop) = tcp::parse_assign(&f.payload).unwrap();
        assert_eq!((epoch, port), (1, 0), "no leaves → standby");
        // a leaf joins → epoch 2 wires leaf under the relay, relay
        // under the root (min_relay_levels = 1 forces the tier)
        let mut leaf_conn = tcp::connect_local(plane.port).unwrap();
        tcp::write_frame(
            &mut leaf_conn,
            &Frame { kind: kind::JOIN, payload: tcp::join_payload(role::LEAF, 0) },
        )
        .unwrap();
        let f = tcp::read_frame(&mut leaf_conn).unwrap();
        assert_eq!((f.kind, tcp::parse_epoch(&f.payload).unwrap()), (kind::EPOCH, 2));
        let f = tcp::read_frame(&mut leaf_conn).unwrap();
        let (epoch, leaf_id, port, hop) = tcp::parse_assign(&f.payload).unwrap();
        assert_eq!((epoch, port, hop), (2, 7777, 2), "leaf attaches under the relay");
        assert_ne!(leaf_id, relay_id);
        // the relay's epoch-2 directive: upstream = the root port
        let f = tcp::read_frame(&mut relay_conn).unwrap();
        assert_eq!(f.kind, kind::EPOCH);
        let f = tcp::read_frame(&mut relay_conn).unwrap();
        let (epoch, id, port, hop) = tcp::parse_assign(&f.payload).unwrap();
        assert_eq!((epoch, id, port, hop), (2, relay_id, 4242, 1));
        assert_eq!(plane.depth(), Some(2));
        assert_eq!(plane.live_peers(), (1, 1));

        // an OBS_SNAP probe (never JOINs) reads the same membership
        // counters the accessors expose
        let snap = crate::obs::fetch_snapshot(&format!("127.0.0.1:{}", plane.port), 0).unwrap();
        assert_eq!(snap.get("role").and_then(|r| r.as_str()), Some("control"));
        let c = snap.get("counters").expect("counters object");
        assert_eq!(c.get("epoch").and_then(|v| v.as_f64()), Some(2.0));
        assert_eq!(c.get("live_relays").and_then(|v| v.as_f64()), Some(1.0));
        assert_eq!(c.get("live_leaves").and_then(|v| v.as_f64()), Some(1.0));
        assert_eq!(c.get("root_port").and_then(|v| v.as_f64()), Some(4242.0));
        plane.stop();
    }

    /// The epoch fence: a directive older than the newest epoch seen
    /// is ignored, whether the fence came from an EPOCH frame or a
    /// newer ASSIGN.
    #[test]
    fn client_fences_stale_epochs() {
        let (listener, port) = tcp::listen_local().unwrap();
        let server = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            let join = tcp::read_frame(&mut s).unwrap();
            assert_eq!(join.kind, kind::JOIN);
            assert_eq!(tcp::parse_join(&join.payload).unwrap(), (role::RELAY, 9999));
            let assign = |epoch, port, hop| Frame {
                kind: kind::ASSIGN,
                payload: tcp::assign_payload(epoch, 1, port, hop),
            };
            // epoch 5 accepted; epoch 3 must be fenced
            tcp::write_frame(&mut s, &assign(5, 1000, 1)).unwrap();
            tcp::write_frame(&mut s, &assign(3, 2000, 9)).unwrap();
            // EPOCH 7 fences the following epoch-6 ASSIGN too
            tcp::write_frame(
                &mut s,
                &Frame { kind: kind::EPOCH, payload: tcp::epoch_payload(7) },
            )
            .unwrap();
            tcp::write_frame(&mut s, &assign(6, 3000, 9)).unwrap();
            tcp::write_frame(&mut s, &assign(8, 4000, 2)).unwrap();
            s // keep the socket open until the client is done
        });
        let client = ControlClient::join(
            port,
            role::RELAY,
            9999,
            Duration::from_secs(60), // no heartbeats during the test
        )
        .unwrap();
        // first directive: epoch 5
        let (seq, port5, hop) = client.wait_directive(0, Duration::from_secs(10)).unwrap();
        assert_eq!((port5, hop), (1000, 1));
        // the next ACCEPTED directive must be epoch 8's — epochs 3 and
        // 6 were fenced and never surface
        let (_, port8, hop) = client.wait_directive(seq, Duration::from_secs(10)).unwrap();
        assert_eq!((port8, hop), (4000, 2));
        assert_eq!(client.epoch(), 8);
        assert_eq!(client.peer_id(), Some(1));
        let _s = server.join().unwrap();
        client.stop();
    }

    /// Heartbeat silence kills a peer and the plan replans without it;
    /// a later heartbeat resurrects it into the next epoch.
    #[test]
    fn heartbeat_timeout_marks_dead_and_resurrects() {
        let cfg = ControlConfig {
            fanout_cap: 2,
            min_relay_levels: 0,
            heartbeat_interval: Duration::from_millis(20),
            missed_heartbeats: 3,
            ..Default::default()
        };
        let plane = ControlPlane::start(1, cfg).unwrap();
        // a raw relay peer that never heartbeats
        let mut conn = tcp::connect_local(plane.port).unwrap();
        tcp::write_frame(
            &mut conn,
            &Frame { kind: kind::JOIN, payload: tcp::join_payload(role::RELAY, 5555) },
        )
        .unwrap();
        let deadline = Instant::now() + Duration::from_secs(10);
        while plane.deaths() == 0 {
            assert!(Instant::now() < deadline, "silent peer never declared dead");
            std::thread::sleep(Duration::from_millis(5));
        }
        assert_eq!(plane.live_peers(), (0, 0));
        let epoch_after_death = plane.epoch();
        // read our id from the initial ASSIGN, then resurrect
        let mut id = None;
        loop {
            let f = tcp::read_frame(&mut conn).unwrap();
            if f.kind == kind::ASSIGN {
                id = Some(tcp::parse_assign(&f.payload).unwrap().1);
                break;
            }
        }
        tcp::write_frame(
            &mut conn,
            &Frame {
                kind: kind::HEARTBEAT,
                payload: tcp::heartbeat_payload(id.unwrap(), epoch_after_death),
            },
        )
        .unwrap();
        let deadline = Instant::now() + Duration::from_secs(10);
        while plane.live_peers() != (1, 0) {
            assert!(Instant::now() < deadline, "peer never resurrected");
            std::thread::sleep(Duration::from_millis(5));
        }
        assert!(plane.epoch() > epoch_after_death, "resurrection must bump the epoch");
        plane.stop();
    }

    // ── extracted state machines (shared with crate::sim) ──────────

    #[test]
    fn membership_machine_joins_sweeps_and_replans() {
        let mut m = Membership::new();
        let t = Duration::from_millis;
        let r1 = m.join(role::RELAY, 7001, t(0));
        let l1 = m.join(role::LEAF, 0, t(0));
        let l2 = m.join(role::LEAF, 0, t(0));
        assert_eq!((r1, l1, l2), (1, 2, 3), "ids are dense from 1");
        let plan = m.plan_next(4, 0).clone();
        assert_eq!(plan.epoch, 1);
        assert!(plan.assignment_of(l1).is_some() && plan.assignment_of(r1).is_some());
        assert_eq!(m.live_counts(), (1, 2));
        // l1 goes silent; l2 and r1 stay fresh
        assert!(!m.heartbeat(l2, t(900)), "routine heartbeat is not a resurrection");
        assert!(!m.heartbeat(r1, t(900)));
        assert_eq!(m.sweep(t(1000), t(500)), 1, "only the silent peer dies");
        assert_eq!(m.deaths(), 1);
        assert!(!m.is_alive(l1) && m.is_alive(l2));
        let plan2 = m.plan_next(4, 0).clone();
        assert_eq!(plan2.epoch, 2);
        assert!(plan2.assignment_of(l1).is_none(), "dead peers drop out of the plan");
        // the dead peer heartbeats again: resurrection is flagged
        assert!(m.heartbeat(l1, t(1200)), "late heartbeat resurrects");
        assert_eq!(m.live_counts(), (1, 2));
        // unknown ids are inert
        assert!(!m.heartbeat(99, t(0)) && !m.mark_dead(99));
        assert!(m.mark_dead(l2) && !m.mark_dead(l2), "mark_dead counts once");
        assert_eq!(m.deaths(), 2);
    }

    #[test]
    fn epoch_fence_blocks_stale_directives() {
        let mut f = EpochFence::default();
        assert!(f.admit(3), "first directive admits");
        f.observe(7);
        assert!(!f.admit(5), "older than the observed fence is rejected");
        assert!(f.admit(7), "equal to the fence admits (re-push of the live plan)");
        assert!(f.admit(9));
        assert_eq!(f.epoch(), 9);
        f.observe(4);
        assert_eq!(f.epoch(), 9, "observe never rewinds");
    }
}
