//! The store plane: a networked object store behind [`SyncTransport`]
//! (paper §E.1's "S3-compatible object storage", made real over our own
//! wire — the image has no crates.io, so GET/PUT/LIST/STAT ride the
//! existing `net::tcp` framing as new frame kinds).
//!
//! Layers, bottom up:
//!
//! * [`ObjectApi`] — the store verbs every layer speaks: ranged +
//!   conditional GET, PUT, LIST, STAT. Implemented by the local
//!   [`DirectStore`], the networked [`StoreClient`], and the
//!   [`CachingStore`] decorator, so a serving stack composes freely
//!   (an origin serves a `DirectStore`; a mid-tier hop serves a
//!   `CachingStore<StoreClient>` pointed at the origin).
//! * [`StoreServer`] — serves any `ObjectApi` over TCP. Connections are
//!   wrapped in the chaos [`Wire`], so seeded wire faults exercise the
//!   whole plane. Every request/reply payload carries a trailing
//!   FNV-1a checksum: a flipped wire bit turns into a retryable error
//!   instead of silently poisoning a key or an inventory listing
//!   (object *bodies* already verify end to end via container hashes).
//! * [`StoreClient`] — one persistent connection, a [`RetryPolicy`]
//!   behind every RPC (reconnect on io error / checksum mismatch /
//!   RETRY status), and a read timeout so a chaos partition that
//!   swallows a reply frame surfaces as a retry, not a hang.
//! * [`CachingStore`] — the CDN hop. **Coherence rule:** an object
//!   under a content address (`*.bin` data objects — their ETag is the
//!   container's hash-tree root) is immutable and served from cache
//!   without revalidation; ready markers are mutable (a restarted
//!   publisher may rewrite a step's marker under a bumped generation)
//!   and revalidate against the origin with a conditional GET on every
//!   read. The cache is bounded by the same [`retention::plan`] the
//!   store plane retires objects with.
//! * [`RemoteStoreTransport`] — [`SyncTransport`] over any
//!   `ObjectApi`, with the object-store key scheme; `latest_ready()`
//!   is exactly one LIST parsed by [`retention::parse_inventory`].
//!
//! Concurrent cold misses on one caching hop may each reach the origin
//! (no single-flight dedup, like a CDN without request coalescing);
//! origin reads per object are bounded by the hop count times the
//! concurrency, not by the leaf count.

use crate::net::chaos::{ChaosConfig, Wire};
use crate::net::tcp::{self, kind, Frame};
use crate::net::transport::{
    anchor_key, anchor_ready_key, delta_key, delta_ready_key, delta_shard_key,
    parse_sharded_marker, split_generation, FrameId, MarkerId, StepData, SyncTransport,
    TransportCounters,
};
use crate::storage::retention::{self, RetentionPolicy};
use crate::storage::ObjectStore;
use crate::util::retry::RetryPolicy;
use crate::util::sync::LockExt;
use anyhow::{bail, Context, Result};
use std::collections::{BTreeSet, HashMap, HashSet};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Reply status codes (the store plane's HTTP-ish status line).
pub mod status {
    pub const OK: u8 = 0;
    pub const NOT_FOUND: u8 = 1;
    /// Conditional GET: the object's ETag matches `if_none_match`.
    pub const NOT_MODIFIED: u8 = 2;
    /// Request failed for a reason a resend won't fix.
    pub const ERR: u8 = 3;
    /// The request envelope arrived damaged (checksum mismatch) — the
    /// client should resend the same request.
    pub const RETRY: u8 = 4;
}

/// Reply flag bit: the body was served from a caching hop without
/// touching its origin.
pub const FLAG_FROM_CACHE: u8 = 1;

// ------------------------------------------------------------ wire codec

fn fnv1a(bytes: &[u8]) -> u32 {
    let mut h: u32 = 0x811C_9DC5;
    for &b in bytes {
        h ^= b as u32;
        h = h.wrapping_mul(0x0100_0193);
    }
    h
}

/// Append the payload checksum (every store frame carries one).
fn seal(mut payload: Vec<u8>) -> Vec<u8> {
    let c = fnv1a(&payload);
    payload.extend_from_slice(&c.to_le_bytes());
    payload
}

/// Verify and strip the trailing checksum.
fn unseal(payload: &[u8]) -> Result<&[u8]> {
    if payload.len() < 4 {
        bail!("store payload too short ({} bytes)", payload.len());
    }
    let (body, tail) = payload.split_at(payload.len() - 4);
    let want = u32::from_le_bytes(tail.try_into()?);
    if fnv1a(body) != want {
        bail!("store payload checksum mismatch");
    }
    Ok(body)
}

fn put_str(out: &mut Vec<u8>, s: &str) {
    out.extend_from_slice(&(s.len() as u16).to_le_bytes());
    out.extend_from_slice(s.as_bytes());
}

fn read_str<'a>(b: &'a [u8], o: &mut usize) -> Result<&'a str> {
    if b.len() < *o + 2 {
        bail!("store payload truncated at string length");
    }
    let n = u16::from_le_bytes(b[*o..*o + 2].try_into()?) as usize;
    *o += 2;
    if b.len() < *o + n {
        bail!("store payload truncated at string body");
    }
    let s = std::str::from_utf8(&b[*o..*o + n]).context("store string is not utf8")?;
    *o += n;
    Ok(s)
}

fn read_u64(b: &[u8], o: &mut usize) -> Result<u64> {
    if b.len() < *o + 8 {
        bail!("store payload truncated at u64");
    }
    let v = u64::from_le_bytes(b[*o..*o + 8].try_into()?);
    *o += 8;
    Ok(v)
}

/// GET request payload: key, byte range (`(0, u64::MAX)` = whole
/// object), `if_none_match` ETag (empty = unconditional).
pub fn encode_get(key: &str, range: Option<(u64, u64)>, if_none_match: Option<&str>) -> Vec<u8> {
    let mut p = Vec::with_capacity(key.len() + 24);
    put_str(&mut p, key);
    let (off, len) = range.unwrap_or((0, u64::MAX));
    p.extend_from_slice(&off.to_le_bytes());
    p.extend_from_slice(&len.to_le_bytes());
    put_str(&mut p, if_none_match.unwrap_or(""));
    seal(p)
}

pub fn parse_get(payload: &[u8]) -> Result<(String, Option<(u64, u64)>, Option<String>)> {
    let b = unseal(payload)?;
    let mut o = 0;
    let key = read_str(b, &mut o)?.to_string();
    let off = read_u64(b, &mut o)?;
    let len = read_u64(b, &mut o)?;
    let etag = read_str(b, &mut o)?;
    let range = if off == 0 && len == u64::MAX { None } else { Some((off, len)) };
    let inm = if etag.is_empty() { None } else { Some(etag.to_string()) };
    Ok((key, range, inm))
}

/// PUT request payload: key + body.
pub fn encode_put(key: &str, bytes: &[u8]) -> Vec<u8> {
    let mut p = Vec::with_capacity(key.len() + bytes.len() + 8);
    put_str(&mut p, key);
    p.extend_from_slice(&(bytes.len() as u32).to_le_bytes());
    p.extend_from_slice(bytes);
    seal(p)
}

pub fn parse_put(payload: &[u8]) -> Result<(String, Vec<u8>)> {
    let b = unseal(payload)?;
    let mut o = 0;
    let key = read_str(b, &mut o)?.to_string();
    if b.len() < o + 4 {
        bail!("store PUT payload truncated at body length");
    }
    let n = u32::from_le_bytes(b[o..o + 4].try_into()?) as usize;
    o += 4;
    if b.len() != o + n {
        bail!("store PUT body length {} != declared {}", b.len() - o, n);
    }
    Ok((key, b[o..].to_vec()))
}

/// LIST / STAT request payload: one string (prefix / key).
pub fn encode_key(key: &str) -> Vec<u8> {
    let mut p = Vec::with_capacity(key.len() + 2);
    put_str(&mut p, key);
    seal(p)
}

pub fn parse_key(payload: &[u8]) -> Result<String> {
    let b = unseal(payload)?;
    let mut o = 0;
    let key = read_str(b, &mut o)?.to_string();
    if o != b.len() {
        bail!("trailing bytes in store key payload");
    }
    Ok(key)
}

/// One STORE_REPLY: status + flags + ETag + body (ERR/RETRY: utf8
/// message; LIST: newline-joined keys; STAT: size u64 LE).
#[derive(Debug, Clone, PartialEq)]
pub struct Reply {
    pub status: u8,
    pub flags: u8,
    pub etag: String,
    pub body: Vec<u8>,
}

impl Reply {
    pub fn ok(etag: String, body: Vec<u8>, from_cache: bool) -> Reply {
        let flags = if from_cache { FLAG_FROM_CACHE } else { 0 };
        Reply { status: status::OK, flags, etag, body }
    }

    pub fn not_found() -> Reply {
        Reply { status: status::NOT_FOUND, flags: 0, etag: String::new(), body: Vec::new() }
    }

    pub fn not_modified(etag: String, from_cache: bool) -> Reply {
        let flags = if from_cache { FLAG_FROM_CACHE } else { 0 };
        Reply { status: status::NOT_MODIFIED, flags, etag, body: Vec::new() }
    }

    fn failure(status: u8, msg: String) -> Reply {
        Reply { status, flags: 0, etag: String::new(), body: msg.into_bytes() }
    }

    pub fn encode(&self) -> Vec<u8> {
        let mut p = Vec::with_capacity(self.etag.len() + self.body.len() + 12);
        p.push(self.status);
        p.push(self.flags);
        put_str(&mut p, &self.etag);
        p.extend_from_slice(&(self.body.len() as u32).to_le_bytes());
        p.extend_from_slice(&self.body);
        seal(p)
    }

    pub fn parse(payload: &[u8]) -> Result<Reply> {
        let b = unseal(payload)?;
        if b.len() < 2 {
            bail!("store reply too short");
        }
        let (status, flags) = (b[0], b[1]);
        let mut o = 2;
        let etag = read_str(b, &mut o)?.to_string();
        if b.len() < o + 4 {
            bail!("store reply truncated at body length");
        }
        let n = u32::from_le_bytes(b[o..o + 4].try_into()?) as usize;
        o += 4;
        if b.len() != o + n {
            bail!("store reply body length {} != declared {}", b.len() - o, n);
        }
        Ok(Reply { status, flags, etag, body: b[o..].to_vec() })
    }
}

// ------------------------------------------------------------- ObjectApi

/// Outcome of an [`ObjectApi::get`].
#[derive(Debug, Clone, PartialEq)]
pub enum GetOutcome {
    /// The (possibly range-sliced) body, its ETag, and whether a
    /// caching hop answered without touching its origin.
    Body { bytes: Vec<u8>, etag: String, from_cache: bool },
    /// Conditional GET: the caller's ETag still names the current
    /// content.
    NotModified { etag: String },
    Missing,
}

/// The store verbs (HTTP-ish GET/PUT/LIST/STAT) every layer of the
/// store plane speaks. ETags are content addresses: the v3 container's
/// hash-tree root when the object is a patch container ([`object_etag`]),
/// SHA-256 of the bytes otherwise.
pub trait ObjectApi: Send + Sync {
    /// Ranged + conditional read. `range` slices the body *after* the
    /// ETag check (the ETag always names the whole object).
    fn get(
        &self,
        key: &str,
        range: Option<(u64, u64)>,
        if_none_match: Option<&str>,
    ) -> Result<GetOutcome>;

    fn put(&self, key: &str, bytes: &[u8]) -> Result<()>;

    /// Keys under `prefix`, sorted.
    fn list(&self, prefix: &str) -> Result<Vec<String>>;

    /// `(size, etag)` of an object, `None` if absent.
    fn stat(&self, key: &str) -> Result<Option<(u64, String)>>;

    /// `(retries, gave_up)` spent by networked layers underneath (0 for
    /// local stacks) — surfaced into [`TransportCounters`].
    fn net_retries(&self) -> (u64, u64) {
        (0, 0)
    }

    /// Conditional-GET revalidations answered NOT_MODIFIED by caching
    /// layers in this stack (0 when no cache is mounted).
    fn not_modified_total(&self) -> u64 {
        0
    }

    /// `[hits, misses, origin_fetches, not_modified, evictions]` of the
    /// cache layer in this stack (all zero when no cache is mounted) —
    /// surfaced through `OBS_SNAP` so hop-side cache behaviour is
    /// visible without a handle on the [`CachingStore`] itself.
    fn cache_stats(&self) -> [u64; 5] {
        [0; 5]
    }
}

impl<T: ObjectApi + ?Sized> ObjectApi for Arc<T> {
    fn get(
        &self,
        key: &str,
        range: Option<(u64, u64)>,
        if_none_match: Option<&str>,
    ) -> Result<GetOutcome> {
        (**self).get(key, range, if_none_match)
    }
    fn put(&self, key: &str, bytes: &[u8]) -> Result<()> {
        (**self).put(key, bytes)
    }
    fn list(&self, prefix: &str) -> Result<Vec<String>> {
        (**self).list(prefix)
    }
    fn stat(&self, key: &str) -> Result<Option<(u64, String)>> {
        (**self).stat(key)
    }
    fn net_retries(&self) -> (u64, u64) {
        (**self).net_retries()
    }
    fn not_modified_total(&self) -> u64 {
        (**self).not_modified_total()
    }
    fn cache_stats(&self) -> [u64; 5] {
        (**self).cache_stats()
    }
}

/// ETag of object bytes: the container's committed hash-tree root when
/// the bytes parse as a patch container header (the content address the
/// consumer's verification already pins), SHA-256 of the bytes
/// otherwise (anchors, markers, arbitrary objects).
pub fn object_etag(bytes: &[u8]) -> String {
    container_root(bytes).unwrap_or_else(|| crate::util::sha256_hex(bytes))
}

/// The 32-byte result-hash field of a container header, as hex. `None`
/// when the bytes are not a container or the field is zero (pseudo-
/// gradient payloads carry no commitment).
fn container_root(buf: &[u8]) -> Option<String> {
    use crate::sparse::container as c;
    if buf.len() < 81 || buf[0..4] != c::MAGIC {
        return None;
    }
    // header: magic 4 + version/tags 5 + five u64s = 49, then +8 for
    // v2's chunk_elems, then +56 for v3's shard fields; the 32-byte
    // result hash follows (see container::decode)
    let off = match buf[4] {
        c::VERSION_V1 => 49,
        c::VERSION => 57,
        c::VERSION_V3 => 113,
        _ => return None,
    };
    if buf.len() < off + 32 {
        return None;
    }
    let h = &buf[off..off + 32];
    if h.iter().all(|&b| b == 0) {
        return None;
    }
    Some(crate::util::hex(h))
}

fn slice_range(bytes: &[u8], range: Option<(u64, u64)>) -> Vec<u8> {
    match range {
        None => bytes.to_vec(),
        Some((off, len)) => {
            let start = (off as usize).min(bytes.len());
            let end = start.saturating_add(len.min(usize::MAX as u64) as usize).min(bytes.len());
            bytes[start..end].to_vec()
        }
    }
}

// ----------------------------------------------------------- DirectStore

/// [`ObjectApi`] over a local [`ObjectStore`] — what an origin server
/// serves.
#[derive(Clone)]
pub struct DirectStore {
    pub store: ObjectStore,
}

impl DirectStore {
    pub fn new(store: ObjectStore) -> DirectStore {
        DirectStore { store }
    }
}

impl ObjectApi for DirectStore {
    fn get(
        &self,
        key: &str,
        range: Option<(u64, u64)>,
        if_none_match: Option<&str>,
    ) -> Result<GetOutcome> {
        if !self.store.exists(key) {
            return Ok(GetOutcome::Missing);
        }
        let bytes = self.store.get(key)?;
        let etag = object_etag(&bytes);
        if if_none_match == Some(etag.as_str()) {
            return Ok(GetOutcome::NotModified { etag });
        }
        Ok(GetOutcome::Body { bytes: slice_range(&bytes, range), etag, from_cache: false })
    }

    fn put(&self, key: &str, bytes: &[u8]) -> Result<()> {
        self.store.put(key, bytes)
    }

    fn list(&self, prefix: &str) -> Result<Vec<String>> {
        self.store.list(prefix)
    }

    fn stat(&self, key: &str) -> Result<Option<(u64, String)>> {
        if !self.store.exists(key) {
            return Ok(None);
        }
        let bytes = self.store.get(key)?;
        Ok(Some((bytes.len() as u64, object_etag(&bytes))))
    }
}

// ----------------------------------------------------------- StoreServer

/// Per-server operation counters, including per-key body-serve counts —
/// the accounting the "origin serves each object O(1) times" assertion
/// reads.
#[derive(Default)]
pub struct StoreStats {
    pub gets: AtomicU64,
    pub puts: AtomicU64,
    pub lists: AtomicU64,
    pub stat_ops: AtomicU64,
    pub not_modified: AtomicU64,
    pub bytes_served: AtomicU64,
    body_serves: Mutex<HashMap<String, u64>>,
}

impl StoreStats {
    fn note_serve(&self, key: &str, bytes: usize) {
        self.bytes_served.fetch_add(bytes as u64, Ordering::Relaxed);
        *self.body_serves.plock().entry(key.to_string()).or_insert(0) += 1;
    }

    /// Times this server sent `key`'s body (NOT_MODIFIED replies don't
    /// count — no body moved).
    pub fn body_serves_of(&self, key: &str) -> u64 {
        self.body_serves.plock().get(key).copied().unwrap_or(0)
    }

    /// Total bodies sent across all keys (the `OBS_SNAP` aggregate of
    /// the per-key map).
    pub fn total_body_serves(&self) -> u64 {
        self.body_serves.plock().values().sum()
    }

    /// Max body serves over keys ending with `suffix` (e.g. `".bin"`
    /// for "no data object left the origin more than N times").
    pub fn max_body_serves(&self, suffix: &str) -> u64 {
        self.body_serves
            .plock()
            .iter()
            .filter(|(k, _)| k.ends_with(suffix))
            .map(|(_, &n)| n)
            .max()
            .unwrap_or(0)
    }
}

/// Serves any [`ObjectApi`] over the tcp framing; one thread per
/// connection, chaos [`Wire`] under the framing when configured.
pub struct StoreServer {
    port: u16,
    stats: Arc<StoreStats>,
    stop: Arc<AtomicBool>,
}

impl StoreServer {
    pub fn serve(api: Arc<dyn ObjectApi>, chaos: Option<ChaosConfig>) -> Result<StoreServer> {
        let (listener, port) = tcp::listen_local()?;
        let stats = Arc::new(StoreStats::default());
        let stop = Arc::new(AtomicBool::new(false));
        let (stats2, stop2) = (stats.clone(), stop.clone());
        std::thread::spawn(move || {
            for conn in listener.incoming() {
                if stop2.load(Ordering::Relaxed) {
                    return;
                }
                let stream = match conn {
                    Ok(s) => s,
                    Err(_) => continue,
                };
                let _ = stream.set_nodelay(true);
                let wire = Wire::wrap(stream, chaos.as_ref());
                let api = api.clone();
                let stats = stats2.clone();
                std::thread::spawn(move || serve_conn(wire, api, stats));
            }
        });
        Ok(StoreServer { port, stats, stop })
    }

    pub fn port(&self) -> u16 {
        self.port
    }

    pub fn stats(&self) -> Arc<StoreStats> {
        self.stats.clone()
    }

    pub fn stop(&self) {
        self.stop.store(true, Ordering::Relaxed);
        // unblock the accept loop
        let _ = TcpStream::connect(("127.0.0.1", self.port));
    }
}

impl Drop for StoreServer {
    fn drop(&mut self) {
        self.stop();
    }
}

fn serve_conn(mut wire: Wire, api: Arc<dyn ObjectApi>, stats: Arc<StoreStats>) {
    loop {
        let req = match tcp::read_frame(&mut wire) {
            Ok(f) => f,
            Err(_) => return,
        };
        if req.kind == kind::CLOSE {
            return;
        }
        if req.kind == kind::OBS_SNAP {
            let flags = tcp::parse_obs_snap(&req.payload).unwrap_or(0);
            let cs = api.cache_stats();
            let (retries, gave_up) = api.net_retries();
            let mut c = crate::util::json::Json::obj();
            c.set("gets", stats.gets.load(Ordering::Relaxed).into())
                .set("puts", stats.puts.load(Ordering::Relaxed).into())
                .set("lists", stats.lists.load(Ordering::Relaxed).into())
                .set("stat_ops", stats.stat_ops.load(Ordering::Relaxed).into())
                .set("not_modified", stats.not_modified.load(Ordering::Relaxed).into())
                .set("bytes_served", stats.bytes_served.load(Ordering::Relaxed).into())
                .set("body_serves", stats.total_body_serves().into())
                .set("cache_hits", cs[0].into())
                .set("cache_misses", cs[1].into())
                .set("origin_fetches", cs[2].into())
                .set("cache_not_modified", cs[3].into())
                .set("cache_evictions", cs[4].into())
                .set("net_retries", retries.into())
                .set("net_gave_up", gave_up.into());
            let body = crate::obs::snapshot_reply("store", flags, c).to_string();
            let frame = Frame { kind: kind::OBS_REPLY, payload: tcp::obs_reply_payload(&body) };
            if tcp::write_frame(&mut wire, &frame).is_err() {
                return;
            }
            continue;
        }
        let reply = handle_request(&api, &stats, &req);
        let frame = Frame { kind: kind::STORE_REPLY, payload: reply.encode() };
        if tcp::write_frame(&mut wire, &frame).is_err() {
            return;
        }
    }
}

fn handle_request(api: &Arc<dyn ObjectApi>, stats: &Arc<StoreStats>, req: &Frame) -> Reply {
    // a damaged request envelope asks for a resend; anything else that
    // fails is a terminal ERR with the reason in the body
    if unseal(&req.payload).is_err() {
        return Reply::failure(status::RETRY, "request checksum mismatch".to_string());
    }
    let out = (|| -> Result<Reply> {
        match req.kind {
            kind::STORE_GET => {
                let (key, range, inm) = parse_get(&req.payload)?;
                stats.gets.fetch_add(1, Ordering::Relaxed);
                Ok(match api.get(&key, range, inm.as_deref())? {
                    GetOutcome::Body { bytes, etag, from_cache } => {
                        stats.note_serve(&key, bytes.len());
                        Reply::ok(etag, bytes, from_cache)
                    }
                    GetOutcome::NotModified { etag } => {
                        stats.not_modified.fetch_add(1, Ordering::Relaxed);
                        Reply::not_modified(etag, false)
                    }
                    GetOutcome::Missing => Reply::not_found(),
                })
            }
            kind::STORE_PUT => {
                let (key, bytes) = parse_put(&req.payload)?;
                api.put(&key, &bytes)?;
                stats.puts.fetch_add(1, Ordering::Relaxed);
                Ok(Reply::ok(String::new(), Vec::new(), false))
            }
            kind::STORE_LIST => {
                let prefix = parse_key(&req.payload)?;
                let keys = api.list(&prefix)?;
                stats.lists.fetch_add(1, Ordering::Relaxed);
                Ok(Reply::ok(String::new(), keys.join("\n").into_bytes(), false))
            }
            kind::STORE_STAT => {
                let key = parse_key(&req.payload)?;
                stats.stat_ops.fetch_add(1, Ordering::Relaxed);
                Ok(match api.stat(&key)? {
                    Some((size, etag)) => Reply::ok(etag, size.to_le_bytes().to_vec(), false),
                    None => Reply::not_found(),
                })
            }
            k => bail!("unknown store frame kind {}", k),
        }
    })();
    out.unwrap_or_else(|e| Reply::failure(status::ERR, format!("{:#}", e)))
}

// ----------------------------------------------------------- StoreClient

/// Networked [`ObjectApi`]: one persistent connection to a
/// [`StoreServer`], every RPC behind a [`RetryPolicy`] (reconnect and
/// resend on io errors, reply-checksum mismatches, and RETRY statuses
/// — all store verbs are idempotent, so a resend is always safe).
pub struct StoreClient {
    port: u16,
    chaos: Option<ChaosConfig>,
    retry: RetryPolicy,
    read_timeout: Duration,
    conn: Mutex<Option<Wire>>,
    retries: AtomicU64,
    gave_up: AtomicU64,
}

impl StoreClient {
    /// Client for the store server on a local `port`. Connects lazily.
    pub fn new(port: u16) -> StoreClient {
        StoreClient {
            port,
            chaos: None,
            retry: RetryPolicy::new(
                Duration::from_millis(25),
                2.0,
                Duration::from_millis(500),
                Duration::from_secs(10),
            ),
            read_timeout: Duration::from_secs(2),
            conn: Mutex::new(None),
            retries: AtomicU64::new(0),
            gave_up: AtomicU64::new(0),
        }
    }

    /// Wrap this client's connections in a chaos domain (client-side
    /// wire faults; the server wraps its own side).
    pub fn with_chaos(mut self, chaos: Option<ChaosConfig>) -> StoreClient {
        self.chaos = chaos;
        self
    }

    pub fn with_retry(mut self, retry: RetryPolicy) -> StoreClient {
        self.retry = retry;
        self
    }

    /// Read timeout per RPC — a swallowed reply frame (chaos partition)
    /// becomes a retryable error instead of a hang.
    pub fn with_read_timeout(mut self, d: Duration) -> StoreClient {
        self.read_timeout = d;
        self
    }

    fn attempt(&self, req: &Frame) -> Result<Reply> {
        let mut guard = self.conn.plock();
        if guard.is_none() {
            let stream = tcp::connect_local(self.port)?;
            let wire = Wire::wrap(stream, self.chaos.as_ref());
            wire.set_read_timeout(Some(self.read_timeout))?;
            *guard = Some(wire);
        }
        let Some(wire) = guard.as_mut() else {
            bail!("store connection slot empty after dial");
        };
        tcp::write_frame(wire, req)?;
        let frame = tcp::read_frame(wire)?;
        if frame.kind != kind::STORE_REPLY {
            bail!("unexpected store reply kind {}", frame.kind);
        }
        let reply = Reply::parse(&frame.payload)?;
        if reply.status == status::RETRY {
            bail!("server asked for resend: {}", String::from_utf8_lossy(&reply.body));
        }
        Ok(reply)
    }

    fn rpc(&self, req: &Frame) -> Result<Reply> {
        let t = crate::util::Stopwatch::start();
        let mut retry = self.retry.start();
        loop {
            match self.attempt(req) {
                Ok(r) => {
                    crate::obs::hist_secs(crate::obs::HistKind::StoreRpc, t.secs());
                    return Ok(r);
                }
                Err(e) => {
                    // the exchange may be desynced (late reply, torn
                    // frame) — drop the connection and redial
                    *self.conn.plock() = None;
                    match retry.next_delay() {
                        Some(d) => {
                            self.retries.fetch_add(1, Ordering::Relaxed);
                            // pallas-lint: allow(retry-discipline): the delay IS a RetryPolicy schedule
                            std::thread::sleep(d);
                        }
                        None => {
                            self.gave_up.fetch_add(1, Ordering::Relaxed);
                            return Err(e).context("store rpc retry budget drained");
                        }
                    }
                }
            }
        }
    }
}

impl ObjectApi for StoreClient {
    fn get(
        &self,
        key: &str,
        range: Option<(u64, u64)>,
        if_none_match: Option<&str>,
    ) -> Result<GetOutcome> {
        let req = Frame { kind: kind::STORE_GET, payload: encode_get(key, range, if_none_match) };
        let r = self.rpc(&req)?;
        match r.status {
            status::OK => Ok(GetOutcome::Body {
                bytes: r.body,
                etag: r.etag,
                from_cache: r.flags & FLAG_FROM_CACHE != 0,
            }),
            status::NOT_FOUND => Ok(GetOutcome::Missing),
            status::NOT_MODIFIED => Ok(GetOutcome::NotModified { etag: r.etag }),
            _ => bail!("store GET '{}' failed: {}", key, String::from_utf8_lossy(&r.body)),
        }
    }

    fn put(&self, key: &str, bytes: &[u8]) -> Result<()> {
        let req = Frame { kind: kind::STORE_PUT, payload: encode_put(key, bytes) };
        let r = self.rpc(&req)?;
        if r.status != status::OK {
            bail!("store PUT '{}' failed: {}", key, String::from_utf8_lossy(&r.body));
        }
        Ok(())
    }

    fn list(&self, prefix: &str) -> Result<Vec<String>> {
        let req = Frame { kind: kind::STORE_LIST, payload: encode_key(prefix) };
        let r = self.rpc(&req)?;
        if r.status != status::OK {
            bail!("store LIST '{}' failed: {}", prefix, String::from_utf8_lossy(&r.body));
        }
        let text = String::from_utf8(r.body).context("store LIST body is not utf8")?;
        Ok(text.split('\n').filter(|s| !s.is_empty()).map(str::to_string).collect())
    }

    fn stat(&self, key: &str) -> Result<Option<(u64, String)>> {
        let req = Frame { kind: kind::STORE_STAT, payload: encode_key(key) };
        let r = self.rpc(&req)?;
        match r.status {
            status::OK => {
                if r.body.len() != 8 {
                    bail!("store STAT body length {}", r.body.len());
                }
                Ok(Some((u64::from_le_bytes(r.body[..].try_into()?), r.etag)))
            }
            status::NOT_FOUND => Ok(None),
            _ => bail!("store STAT '{}' failed: {}", key, String::from_utf8_lossy(&r.body)),
        }
    }

    fn net_retries(&self) -> (u64, u64) {
        (self.retries.load(Ordering::Relaxed), self.gave_up.load(Ordering::Relaxed))
    }
}

// ---------------------------------------------------------- CachingStore

/// Cache-layer counters (one caching hop's view; the `paper cache`
/// table reads these directly).
#[derive(Default)]
pub struct CacheCounters {
    pub hits: AtomicU64,
    pub misses: AtomicU64,
    pub origin_fetches: AtomicU64,
    pub not_modified: AtomicU64,
    pub evictions: AtomicU64,
}

struct CacheEntry {
    body: Vec<u8>,
    etag: String,
}

/// The caching hop. Data objects (`*.bin`) are immutable under their
/// content address and served from cache without revalidation; ready
/// markers revalidate against the origin with a conditional GET on
/// every read (the coherence rule — see module docs). Bounded by the
/// retention plan over cached steps.
pub struct CachingStore<U: ObjectApi> {
    origin: U,
    policy: RetentionPolicy,
    cache: Mutex<HashMap<String, CacheEntry>>,
    pub counters: Arc<CacheCounters>,
}

/// True for objects that are immutable under their content address.
fn is_data_key(key: &str) -> bool {
    key.ends_with(".bin")
}

/// `(is_anchor, step)` of any store-plane key (data object or ready
/// marker), `None` for foreign keys.
fn cached_step(key: &str) -> Option<(bool, u64)> {
    let base = key.rsplit('/').next().unwrap_or(key);
    let (anchor, rest) = if let Some(r) = base.strip_prefix("anchor_ready_") {
        (true, r)
    } else if let Some(r) = base.strip_prefix("delta_ready_") {
        (false, r)
    } else if let Some(r) = base.strip_prefix("anchor_") {
        (true, r)
    } else if let Some(r) = base.strip_prefix("delta_") {
        (false, r)
    } else {
        return None;
    };
    rest.split('.').next()?.parse().ok().map(|s| (anchor, s))
}

impl<U: ObjectApi> CachingStore<U> {
    pub fn new(origin: U, policy: RetentionPolicy) -> CachingStore<U> {
        CachingStore {
            origin,
            policy,
            cache: Mutex::new(HashMap::new()),
            counters: Arc::new(CacheCounters::default()),
        }
    }

    pub fn origin(&self) -> &U {
        &self.origin
    }

    /// Objects currently cached.
    pub fn cached_objects(&self) -> usize {
        self.cache.plock().len()
    }

    fn serve(&self, entry: &CacheEntry, range: Option<(u64, u64)>, inm: Option<&str>, from_cache: bool) -> GetOutcome {
        if inm == Some(entry.etag.as_str()) {
            return GetOutcome::NotModified { etag: entry.etag.clone() };
        }
        GetOutcome::Body {
            bytes: slice_range(&entry.body, range),
            etag: entry.etag.clone(),
            from_cache,
        }
    }

    fn insert(&self, key: &str, body: Vec<u8>, etag: String) {
        let mut cache = self.cache.plock();
        cache.insert(key.to_string(), CacheEntry { body, etag });
        self.evict(&mut cache);
    }

    /// Drop cached steps outside the retention plan (the cache never
    /// holds more steps than the store itself would retain).
    fn evict(&self, cache: &mut HashMap<String, CacheEntry>) {
        let mut delta_steps: BTreeSet<u64> = BTreeSet::new();
        let mut anchor_steps: BTreeSet<u64> = BTreeSet::new();
        for key in cache.keys() {
            match cached_step(key) {
                Some((true, s)) => {
                    anchor_steps.insert(s);
                }
                Some((false, s)) => {
                    delta_steps.insert(s);
                }
                None => {}
            }
        }
        if delta_steps.len() <= self.policy.max_deltas
            && anchor_steps.len() <= self.policy.max_anchors
        {
            return;
        }
        let inv = retention::Inventory {
            delta_steps: delta_steps.into_iter().collect(),
            anchor_steps: anchor_steps.into_iter().collect(),
        };
        let (dd, da) = retention::plan(&inv, self.policy);
        let dd: HashSet<u64> = dd.into_iter().collect();
        let da: HashSet<u64> = da.into_iter().collect();
        let before = cache.len();
        cache.retain(|k, _| match cached_step(k) {
            Some((true, s)) => !da.contains(&s),
            Some((false, s)) => !dd.contains(&s),
            None => true,
        });
        self.counters.evictions.fetch_add((before - cache.len()) as u64, Ordering::Relaxed);
    }
}

impl<U: ObjectApi> ObjectApi for CachingStore<U> {
    fn get(
        &self,
        key: &str,
        range: Option<(u64, u64)>,
        if_none_match: Option<&str>,
    ) -> Result<GetOutcome> {
        let immutable = is_data_key(key);
        // snapshot the entry; never hold the lock across an origin call
        let cached_etag = {
            let cache = self.cache.plock();
            match cache.get(key) {
                Some(e) if immutable => {
                    // immutable hit: serve without touching the origin
                    self.counters.hits.fetch_add(1, Ordering::Relaxed);
                    return Ok(self.serve(e, range, if_none_match, true));
                }
                Some(e) => Some(e.etag.clone()),
                None => None,
            }
        };
        if let Some(etag) = cached_etag {
            // mutable (ready marker): revalidate with a conditional GET
            match self.origin.get(key, None, Some(&etag))? {
                GetOutcome::NotModified { .. } => {
                    self.counters.not_modified.fetch_add(1, Ordering::Relaxed);
                    self.counters.hits.fetch_add(1, Ordering::Relaxed);
                    let cache = self.cache.plock();
                    if let Some(e) = cache.get(key) {
                        return Ok(self.serve(e, range, if_none_match, true));
                    }
                    // evicted between snapshot and revalidation — fall
                    // through to a cold fetch
                }
                GetOutcome::Body { bytes, etag, .. } => {
                    self.counters.misses.fetch_add(1, Ordering::Relaxed);
                    self.counters.origin_fetches.fetch_add(1, Ordering::Relaxed);
                    let out = self.serve(
                        &CacheEntry { body: bytes.clone(), etag: etag.clone() },
                        range,
                        if_none_match,
                        false,
                    );
                    self.insert(key, bytes, etag);
                    return Ok(out);
                }
                GetOutcome::Missing => {
                    self.cache.plock().remove(key);
                    return Ok(GetOutcome::Missing);
                }
            }
        }
        // cold path: fetch the whole object, cache it, serve the slice
        self.counters.misses.fetch_add(1, Ordering::Relaxed);
        match self.origin.get(key, None, None)? {
            GetOutcome::Body { bytes, etag, .. } => {
                self.counters.origin_fetches.fetch_add(1, Ordering::Relaxed);
                let out = self.serve(
                    &CacheEntry { body: bytes.clone(), etag: etag.clone() },
                    range,
                    if_none_match,
                    false,
                );
                self.insert(key, bytes, etag);
                Ok(out)
            }
            GetOutcome::NotModified { .. } => {
                bail!("origin answered NOT_MODIFIED to an unconditional GET for '{}'", key)
            }
            GetOutcome::Missing => Ok(GetOutcome::Missing),
        }
    }

    fn put(&self, key: &str, bytes: &[u8]) -> Result<()> {
        // write-through; the local copy warms the hop for its subtree
        self.origin.put(key, bytes)?;
        self.insert(key, bytes.to_vec(), object_etag(bytes));
        Ok(())
    }

    fn list(&self, prefix: &str) -> Result<Vec<String>> {
        self.origin.list(prefix)
    }

    fn stat(&self, key: &str) -> Result<Option<(u64, String)>> {
        if is_data_key(key) {
            if let Some(e) = self.cache.plock().get(key) {
                self.counters.hits.fetch_add(1, Ordering::Relaxed);
                return Ok(Some((e.body.len() as u64, e.etag.clone())));
            }
        }
        self.origin.stat(key)
    }

    fn net_retries(&self) -> (u64, u64) {
        self.origin.net_retries()
    }

    fn not_modified_total(&self) -> u64 {
        self.counters.not_modified.load(Ordering::Relaxed) + self.origin.not_modified_total()
    }

    fn cache_stats(&self) -> [u64; 5] {
        let deeper = self.origin.cache_stats();
        let own = [
            self.counters.hits.load(Ordering::Relaxed),
            self.counters.misses.load(Ordering::Relaxed),
            self.counters.origin_fetches.load(Ordering::Relaxed),
            self.counters.not_modified.load(Ordering::Relaxed),
            self.counters.evictions.load(Ordering::Relaxed),
        ];
        [
            own[0] + deeper[0],
            own[1] + deeper[1],
            own[2] + deeper[2],
            own[3] + deeper[3],
            own[4] + deeper[4],
        ]
    }
}

/// Mount a caching hop: a [`StoreServer`] serving a
/// [`CachingStore`]<[`StoreClient`]> pointed at the origin server on
/// `origin_port`. Returns the server and the hop's cache layer (for
/// counters).
pub fn caching_hop(
    origin_port: u16,
    policy: RetentionPolicy,
    chaos: Option<ChaosConfig>,
) -> Result<(StoreServer, Arc<CachingStore<StoreClient>>)> {
    let client = StoreClient::new(origin_port).with_chaos(chaos.clone());
    let hop = Arc::new(CachingStore::new(client, policy));
    let server = StoreServer::serve(hop.clone(), chaos)?;
    Ok((server, hop))
}

// -------------------------------------------------- RemoteStoreTransport

#[derive(Default)]
struct RemoteCounters {
    inventory_scans: AtomicU64,
    frames_published: AtomicU64,
    bytes_published: AtomicU64,
    markers_published: AtomicU64,
    frames_fetched: AtomicU64,
    bytes_fetched: AtomicU64,
    cache_hits: AtomicU64,
    cache_misses: AtomicU64,
    origin_fetches: AtomicU64,
}

/// [`SyncTransport`] over any [`ObjectApi`] — the networked sibling of
/// `ObjectStoreTransport`, same key scheme, same marker grammar.
/// `latest_ready()` costs exactly one LIST
/// ([`retention::parse_inventory`] on the listed keys), so the
/// consumer's cached-inventory snapshot keeps poll-then-sync at one
/// LIST per cycle on the remote path too.
pub struct RemoteStoreTransport<A: ObjectApi = StoreClient> {
    api: A,
    prefix: String,
    counters: Arc<RemoteCounters>,
}

impl RemoteStoreTransport<StoreClient> {
    /// Transport over a plain client to the store server on `port`.
    pub fn connect(port: u16, prefix: &str) -> RemoteStoreTransport<StoreClient> {
        RemoteStoreTransport::over(StoreClient::new(port), prefix)
    }
}

impl<A: ObjectApi> RemoteStoreTransport<A> {
    /// Transport over any store stack (a client, a client behind a
    /// local [`CachingStore`], a [`DirectStore`] for tests).
    pub fn over(api: A, prefix: &str) -> RemoteStoreTransport<A> {
        RemoteStoreTransport {
            api,
            prefix: prefix.trim_end_matches('/').to_string(),
            counters: Arc::new(RemoteCounters::default()),
        }
    }

    pub fn api(&self) -> &A {
        &self.api
    }

    fn key(&self, k: String) -> String {
        format!("{}/{}", self.prefix, k)
    }

    /// Count one served GET body by where it came from.
    fn note(&self, from_cache: bool) {
        if from_cache {
            self.counters.cache_hits.fetch_add(1, Ordering::Relaxed);
        } else {
            self.counters.cache_misses.fetch_add(1, Ordering::Relaxed);
            self.counters.origin_fetches.fetch_add(1, Ordering::Relaxed);
        }
    }

    fn get_required(&self, key: &str, what: &str) -> Result<Vec<u8>> {
        match self.api.get(key, None, None).with_context(|| format!("fetching {}", what))? {
            GetOutcome::Body { bytes, from_cache, .. } => {
                self.note(from_cache);
                Ok(bytes)
            }
            GetOutcome::NotModified { .. } => {
                bail!("unexpected NOT_MODIFIED for {} ('{}')", what, key)
            }
            GetOutcome::Missing => bail!("{} missing ('{}')", what, key),
        }
    }

    fn get_optional(&self, key: &str) -> Result<Option<Vec<u8>>> {
        match self.api.get(key, None, None)? {
            GetOutcome::Body { bytes, from_cache, .. } => {
                self.note(from_cache);
                Ok(Some(bytes))
            }
            GetOutcome::NotModified { .. } => bail!("unexpected NOT_MODIFIED for '{}'", key),
            GetOutcome::Missing => Ok(None),
        }
    }
}

impl<A: ObjectApi> SyncTransport for RemoteStoreTransport<A> {
    fn name(&self) -> &'static str {
        "remote-store"
    }

    fn publish_frame(&self, id: FrameId, bytes: &[u8]) -> Result<()> {
        self.api.put(&self.key(id.object_key()), bytes)?;
        self.counters.frames_published.fetch_add(1, Ordering::Relaxed);
        self.counters.bytes_published.fetch_add(bytes.len() as u64, Ordering::Relaxed);
        Ok(())
    }

    fn publish_marker(&self, id: MarkerId, payload: &str) -> Result<()> {
        self.api.put(&self.key(id.object_key()), payload.as_bytes())?;
        self.counters.markers_published.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }

    fn latest_ready(&self) -> Result<retention::Inventory> {
        self.counters.inventory_scans.fetch_add(1, Ordering::Relaxed);
        let keys = self.api.list(&self.prefix)?;
        Ok(retention::parse_inventory(&keys, &self.prefix))
    }

    fn fetch_step(&self, step: u64) -> Result<Option<StepData>> {
        // a missing marker is the §J.5 "anchor replaced the delta"
        // signal, not a transport failure
        let marker = match self.get_optional(&self.key(delta_ready_key(step)))? {
            Some(m) => String::from_utf8_lossy(&m).into_owned(),
            None => return Ok(None),
        };
        let (_, marker) = split_generation(&marker);
        if let Some((shard_count, root)) = parse_sharded_marker(marker) {
            return Ok(Some(StepData::Sharded { shard_count, root: root.to_string() }));
        }
        let obj = self.get_required(&self.key(delta_key(step)), "delta object")?;
        self.counters.frames_fetched.fetch_add(1, Ordering::Relaxed);
        self.counters.bytes_fetched.fetch_add(obj.len() as u64, Ordering::Relaxed);
        Ok(Some(StepData::Whole(obj)))
    }

    fn fetch_shard(&self, step: u64, shard: u32) -> Result<Vec<u8>> {
        let obj = self
            .get_required(&self.key(delta_shard_key(step, shard)), "shard frame")
            .with_context(|| format!("shard {} of step {}", shard, step))?;
        self.counters.frames_fetched.fetch_add(1, Ordering::Relaxed);
        self.counters.bytes_fetched.fetch_add(obj.len() as u64, Ordering::Relaxed);
        Ok(obj)
    }

    fn fetch_anchor(&self, step: u64) -> Result<(Vec<u8>, String)> {
        let obj = self
            .get_required(&self.key(anchor_key(step)), "anchor object")
            .with_context(|| format!("anchor {}", step))?;
        let marker = self
            .get_required(&self.key(anchor_ready_key(step)), "anchor marker")
            .with_context(|| format!("anchor marker {}", step))?;
        self.counters.frames_fetched.fetch_add(1, Ordering::Relaxed);
        self.counters.bytes_fetched.fetch_add(obj.len() as u64, Ordering::Relaxed);
        Ok((obj, String::from_utf8_lossy(&marker).into_owned()))
    }

    fn counters(&self) -> TransportCounters {
        let c = &self.counters;
        let (retries, gave_up) = self.api.net_retries();
        TransportCounters {
            inventory_scans: c.inventory_scans.load(Ordering::Relaxed),
            frames_published: c.frames_published.load(Ordering::Relaxed),
            bytes_published: c.bytes_published.load(Ordering::Relaxed),
            markers_published: c.markers_published.load(Ordering::Relaxed),
            frames_fetched: c.frames_fetched.load(Ordering::Relaxed),
            bytes_fetched: c.bytes_fetched.load(Ordering::Relaxed),
            retries,
            gave_up,
            cache_hits: c.cache_hits.load(Ordering::Relaxed),
            cache_misses: c.cache_misses.load(Ordering::Relaxed),
            origin_fetches: c.origin_fetches.load(Ordering::Relaxed),
            conditional_not_modified: self.api.not_modified_total(),
            ..TransportCounters::default()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::container::{self, Patch, Values};

    fn temp_store(tag: &str) -> ObjectStore {
        ObjectStore::temp(tag).unwrap()
    }

    #[test]
    fn request_and_reply_payloads_roundtrip() {
        let (k, r, e) = parse_get(&encode_get("a/b.bin", Some((8, 100)), Some("etag1"))).unwrap();
        assert_eq!((k.as_str(), r, e.as_deref()), ("a/b.bin", Some((8, 100)), Some("etag1")));
        let (k, r, e) = parse_get(&encode_get("x", None, None)).unwrap();
        assert_eq!((k.as_str(), r, e), ("x", None, None));
        let (k, b) = parse_put(&encode_put("k", b"body")).unwrap();
        assert_eq!((k.as_str(), b.as_slice()), ("k", b"body".as_slice()));
        assert_eq!(parse_key(&encode_key("pfx")).unwrap(), "pfx");
        let rep = Reply::ok("e".into(), vec![1, 2, 3], true);
        let back = Reply::parse(&rep.encode()).unwrap();
        assert_eq!(back, rep);
        assert_eq!(back.flags & FLAG_FROM_CACHE, FLAG_FROM_CACHE);
    }

    #[test]
    fn checksums_reject_flipped_bits() {
        let mut p = encode_get("key", None, None);
        let n = p.len();
        p[n - 6] ^= 0x04;
        assert!(parse_get(&p).is_err());
        let mut rep = Reply::ok("e".into(), vec![9; 64], false).encode();
        rep[10] ^= 0x01;
        assert!(Reply::parse(&rep).is_err());
    }

    #[test]
    fn etag_is_container_root_or_sha256() {
        assert_eq!(object_etag(b"junk"), crate::util::sha256_hex(b"junk"));
        let layout = crate::sparse::synthetic_layout(64, 64);
        let mut p = Patch::default();
        p.total_params = 64;
        p.indices = vec![3];
        p.values = Values::Bf16(vec![7]);
        p.result_hash = crate::util::sha256_hex(b"root");
        let bytes = container::encode(&p, &layout, Default::default()).unwrap();
        assert_eq!(object_etag(&bytes), p.result_hash, "etag is the committed root");
    }

    #[test]
    fn direct_store_conditional_and_ranged_get() {
        let store = temp_store("direct");
        let api = DirectStore::new(store.clone());
        api.put("sync/blob", b"0123456789").unwrap();
        let etag = match api.get("sync/blob", None, None).unwrap() {
            GetOutcome::Body { bytes, etag, from_cache } => {
                assert_eq!(bytes, b"0123456789");
                assert!(!from_cache);
                etag
            }
            o => panic!("{:?}", o),
        };
        match api.get("sync/blob", None, Some(&etag)).unwrap() {
            GetOutcome::NotModified { etag: e } => assert_eq!(e, etag),
            o => panic!("{:?}", o),
        }
        match api.get("sync/blob", Some((2, 3)), None).unwrap() {
            GetOutcome::Body { bytes, .. } => assert_eq!(bytes, b"234"),
            o => panic!("{:?}", o),
        }
        // range past the end clamps instead of erroring
        match api.get("sync/blob", Some((8, 100)), None).unwrap() {
            GetOutcome::Body { bytes, .. } => assert_eq!(bytes, b"89"),
            o => panic!("{:?}", o),
        }
        assert_eq!(api.get("sync/nope", None, None).unwrap(), GetOutcome::Missing);
        assert_eq!(api.stat("sync/blob").unwrap().unwrap().0, 10);
        std::fs::remove_dir_all(store.root()).unwrap();
    }

    #[test]
    fn server_and_client_speak_the_wire() {
        let store = temp_store("wire");
        let server =
            StoreServer::serve(Arc::new(DirectStore::new(store.clone())), None).unwrap();
        let client = StoreClient::new(server.port());
        client.put("s/delta_ready_1", b"marker-1").unwrap();
        client.put("s/obj.bin", b"payload").unwrap();
        match client.get("s/obj.bin", None, None).unwrap() {
            GetOutcome::Body { bytes, etag, from_cache } => {
                assert_eq!(bytes, b"payload");
                assert_eq!(etag, crate::util::sha256_hex(b"payload"));
                assert!(!from_cache);
            }
            o => panic!("{:?}", o),
        }
        match client.get("s/obj.bin", Some((1, 3)), None).unwrap() {
            GetOutcome::Body { bytes, .. } => assert_eq!(bytes, b"ayl"),
            o => panic!("{:?}", o),
        }
        let etag = crate::util::sha256_hex(b"payload");
        assert_eq!(
            client.get("s/obj.bin", None, Some(&etag)).unwrap(),
            GetOutcome::NotModified { etag: etag.clone() }
        );
        assert_eq!(client.get("s/ghost", None, None).unwrap(), GetOutcome::Missing);
        assert_eq!(client.list("s").unwrap(), vec!["s/delta_ready_1", "s/obj.bin"]);
        assert_eq!(client.stat("s/obj.bin").unwrap().unwrap(), (7, etag));
        assert_eq!(client.stat("s/ghost").unwrap(), None);
        assert_eq!(server.stats().gets.load(Ordering::Relaxed), 4);
        assert_eq!(server.stats().body_serves_of("s/obj.bin"), 2);
        assert_eq!(server.stats().not_modified.load(Ordering::Relaxed), 1);
        std::fs::remove_dir_all(store.root()).unwrap();
    }

    #[test]
    fn caching_hop_serves_repeat_reads_without_origin() {
        let store = temp_store("hop");
        let origin =
            StoreServer::serve(Arc::new(DirectStore::new(store.clone())), None).unwrap();
        let (hop, cache) = caching_hop(origin.port(), RetentionPolicy::default(), None).unwrap();
        let direct = StoreClient::new(origin.port());
        direct.put("s/delta_00000001.bin", b"immutable-data").unwrap();
        direct.put("s/delta_ready_1", b"marker-v1").unwrap();

        let leaf = StoreClient::new(hop.port());
        // cold: the hop misses and pulls from the origin
        match leaf.get("s/delta_00000001.bin", None, None).unwrap() {
            GetOutcome::Body { from_cache, .. } => assert!(!from_cache),
            o => panic!("{:?}", o),
        }
        // warm: served from the hop's cache, origin untouched
        match leaf.get("s/delta_00000001.bin", None, None).unwrap() {
            GetOutcome::Body { bytes, from_cache, .. } => {
                assert_eq!(bytes, b"immutable-data");
                assert!(from_cache);
            }
            o => panic!("{:?}", o),
        }
        assert_eq!(origin.stats().body_serves_of("s/delta_00000001.bin"), 1);
        assert_eq!(cache.counters.hits.load(Ordering::Relaxed), 1);
        assert_eq!(cache.counters.misses.load(Ordering::Relaxed), 1);

        // markers revalidate: first read caches, second costs the
        // origin only a NOT_MODIFIED (no body)
        for _ in 0..2 {
            match leaf.get("s/delta_ready_1", None, None).unwrap() {
                GetOutcome::Body { bytes, .. } => assert_eq!(bytes, b"marker-v1"),
                o => panic!("{:?}", o),
            }
        }
        assert_eq!(cache.counters.not_modified.load(Ordering::Relaxed), 1);
        assert_eq!(origin.stats().body_serves_of("s/delta_ready_1"), 1);

        // the marker changes (publisher restart): revalidation sees the
        // new content, cache coherence holds
        direct.put("s/delta_ready_1", b"g2;marker-v2").unwrap();
        match leaf.get("s/delta_ready_1", None, None).unwrap() {
            GetOutcome::Body { bytes, from_cache, .. } => {
                assert_eq!(bytes, b"g2;marker-v2");
                assert!(!from_cache);
            }
            o => panic!("{:?}", o),
        }
        std::fs::remove_dir_all(store.root()).unwrap();
    }

    #[test]
    fn cache_is_bounded_by_the_retention_plan() {
        let store = temp_store("bound");
        let cache = CachingStore::new(
            DirectStore::new(store.clone()),
            RetentionPolicy { max_deltas: 4, max_anchors: 2 },
        );
        for step in 1..=10u64 {
            cache.put(&format!("s/{}", delta_key(step)), b"d").unwrap();
            cache.put(&format!("s/{}", delta_ready_key(step)), b"m").unwrap();
        }
        // ≤ 4 delta steps cached (data + marker per step), evictions
        // counted
        assert!(cache.cached_objects() <= 8, "{} objects", cache.cached_objects());
        assert!(cache.counters.evictions.load(Ordering::Relaxed) > 0);
        // the newest step is still warm
        match cache.get(&format!("s/{}", delta_key(10)), None, None).unwrap() {
            GetOutcome::Body { from_cache, .. } => assert!(from_cache),
            o => panic!("{:?}", o),
        }
        std::fs::remove_dir_all(store.root()).unwrap();
    }

    #[test]
    fn client_retries_through_wire_corruption() {
        let store = temp_store("chaos_client");
        // server-side chaos: every ~3rd write flips a payload bit until
        // the budget drains; the reply checksum turns that into a
        // client retry, never bad data
        let mut chaos = ChaosConfig::quiet(11).with_budget(6);
        chaos.corrupt_mille = 300;
        let server =
            StoreServer::serve(Arc::new(DirectStore::new(store.clone())), Some(chaos)).unwrap();
        let client = StoreClient::new(server.port());
        client.put("s/obj.bin", &vec![0xA5u8; 4096]).unwrap();
        for _ in 0..20 {
            match client.get("s/obj.bin", None, None).unwrap() {
                GetOutcome::Body { bytes, .. } => assert_eq!(bytes, vec![0xA5u8; 4096]),
                o => panic!("{:?}", o),
            }
        }
        let (retries, gave_up) = client.net_retries();
        assert!(retries > 0, "chaos never fired");
        assert_eq!(gave_up, 0);
        std::fs::remove_dir_all(store.root()).unwrap();
    }

    #[test]
    fn remote_transport_latest_ready_is_one_list() {
        let store = temp_store("one_list");
        let server =
            StoreServer::serve(Arc::new(DirectStore::new(store.clone())), None).unwrap();
        let t = RemoteStoreTransport::connect(server.port(), "sync");
        t.publish_frame(FrameId::Delta { step: 1 }, b"obj").unwrap();
        t.publish_marker(MarkerId::Delta(1), &"ab".repeat(32)).unwrap();
        t.publish_frame(FrameId::Anchor { step: 0 }, b"anch").unwrap();
        t.publish_marker(MarkerId::Anchor(0), "m0").unwrap();
        let inv = t.latest_ready().unwrap();
        assert_eq!(inv.delta_steps, vec![1]);
        assert_eq!(inv.anchor_steps, vec![0]);
        assert_eq!(t.counters().inventory_scans, 1);
        assert_eq!(server.stats().lists.load(Ordering::Relaxed), 1, "one LIST on the wire");
        // fetches never re-list
        assert_eq!(t.fetch_step(1).unwrap(), Some(StepData::Whole(b"obj".to_vec())));
        assert_eq!(t.fetch_anchor(0).unwrap(), (b"anch".to_vec(), "m0".to_string()));
        assert_eq!(t.fetch_step(99).unwrap(), None, "missing marker is the §J.5 signal");
        assert_eq!(server.stats().lists.load(Ordering::Relaxed), 1);
        std::fs::remove_dir_all(store.root()).unwrap();
    }

    #[test]
    fn remote_transport_counts_cache_traffic() {
        let store = temp_store("remote_cache");
        let origin =
            StoreServer::serve(Arc::new(DirectStore::new(store.clone())), None).unwrap();
        let (hop, _cache) = caching_hop(origin.port(), RetentionPolicy::default(), None).unwrap();
        let producer = RemoteStoreTransport::connect(origin.port(), "sync");
        producer.publish_frame(FrameId::Delta { step: 1 }, b"obj").unwrap();
        producer.publish_marker(MarkerId::Delta(1), &"ab".repeat(32)).unwrap();
        let a = RemoteStoreTransport::connect(hop.port(), "sync");
        let b = RemoteStoreTransport::connect(hop.port(), "sync");
        a.fetch_step(1).unwrap();
        b.fetch_step(1).unwrap();
        assert_eq!(a.counters().cache_misses, 2, "marker + object, both cold");
        assert_eq!(b.counters().cache_hits, 2, "marker + object served from the hop");
        assert_eq!(b.counters().origin_fetches, 0);
        assert_eq!(origin.stats().body_serves_of("sync/delta_00000001.bin"), 1);
        std::fs::remove_dir_all(store.root()).unwrap();
    }

    #[test]
    fn obs_snap_surfaces_store_and_cache_counters() {
        let store = temp_store("obs_snap");
        let origin =
            StoreServer::serve(Arc::new(DirectStore::new(store.clone())), None).unwrap();
        let (hop, _cache) = caching_hop(origin.port(), RetentionPolicy::default(), None).unwrap();
        let direct = StoreClient::new(origin.port());
        direct.put("s/delta_00000001.bin", b"immutable-data").unwrap();
        let leaf = StoreClient::new(hop.port());
        for _ in 0..3 {
            leaf.get("s/delta_00000001.bin", None, None).unwrap();
        }
        leaf.list("s/").unwrap();

        let snap = crate::obs::fetch_snapshot(&format!("127.0.0.1:{}", hop.port()), 0).unwrap();
        assert_eq!(snap.get("role").and_then(|r| r.as_str()), Some("store"));
        let c = snap.get("counters").expect("counters object");
        assert_eq!(c.get("gets").and_then(|v| v.as_f64()), Some(3.0));
        assert_eq!(c.get("lists").and_then(|v| v.as_f64()), Some(1.0));
        assert_eq!(c.get("body_serves").and_then(|v| v.as_f64()), Some(3.0));
        // 1 cold miss + 2 warm hits on the hop's cache layer
        assert_eq!(c.get("cache_hits").and_then(|v| v.as_f64()), Some(2.0));
        assert_eq!(c.get("cache_misses").and_then(|v| v.as_f64()), Some(1.0));
        assert_eq!(c.get("origin_fetches").and_then(|v| v.as_f64()), Some(1.0));
        assert!(snap.get("histograms").is_some(), "histograms ride every snapshot");
        std::fs::remove_dir_all(store.root()).unwrap();
    }
}
