//! Patch relay (paper Fig. 5 / §E: "a relay network distributes sparse
//! BF16 weight patches from trainers to inference workers").
//!
//! The relay accepts one publisher connection and N subscriber
//! connections, fanning every PATCH/ANCHOR frame out to all subscribers.
//! Subscribers that connect late first receive the most recent ANCHOR
//! then the subsequent patches (mirroring the slow path of Alg. 5).

use super::tcp::{self, kind, Frame};
use anyhow::Result;
use std::net::{TcpListener, TcpStream};
use std::sync::{Arc, Mutex};

struct Shared {
    subscribers: Vec<TcpStream>,
    last_anchor: Option<Frame>,
    /// Patches since the last anchor, in order.
    tail: Vec<Frame>,
}

/// Relay server handle.
pub struct Relay {
    pub port: u16,
    shared: Arc<Mutex<Shared>>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
    stop: Arc<std::sync::atomic::AtomicBool>,
}

impl Relay {
    /// Start a relay on an ephemeral localhost port.
    pub fn start() -> Result<Relay> {
        let (listener, port) = tcp::listen_local()?;
        listener.set_nonblocking(true)?;
        let shared = Arc::new(Mutex::new(Shared {
            subscribers: Vec::new(),
            last_anchor: None,
            tail: Vec::new(),
        }));
        let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let accept_thread = Some(spawn_accept(listener, shared.clone(), stop.clone()));
        Ok(Relay { port, shared, accept_thread, stop })
    }

    /// Publish a frame to all current subscribers (and remember anchors
    /// for late joiners). Called by the trainer-side connection pump or
    /// directly in-process.
    pub fn publish(&self, frame: Frame) {
        let mut sh = self.shared.lock().unwrap();
        match frame.kind {
            kind::ANCHOR => {
                sh.last_anchor = Some(frame.clone());
                sh.tail.clear();
            }
            kind::PATCH => sh.tail.push(frame.clone()),
            _ => {}
        }
        sh.subscribers.retain_mut(|s| tcp::write_frame(s, &frame).is_ok());
    }

    pub fn subscriber_count(&self) -> usize {
        self.shared.lock().unwrap().subscribers.len()
    }

    pub fn stop(mut self) {
        self.stop.store(true, std::sync::atomic::Ordering::SeqCst);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

fn spawn_accept(
    listener: TcpListener,
    shared: Arc<Mutex<Shared>>,
    stop: Arc<std::sync::atomic::AtomicBool>,
) -> std::thread::JoinHandle<()> {
    std::thread::spawn(move || loop {
        if stop.load(std::sync::atomic::Ordering::SeqCst) {
            return;
        }
        match listener.accept() {
            Ok((mut stream, _)) => {
                stream.set_nodelay(true).ok();
                // catch-up: send last anchor + tail before live frames
                let mut sh = shared.lock().unwrap();
                let mut ok = true;
                if let Some(a) = &sh.last_anchor {
                    ok = tcp::write_frame(&mut stream, a).is_ok();
                }
                if ok {
                    for p in &sh.tail {
                        if tcp::write_frame(&mut stream, p).is_err() {
                            ok = false;
                            break;
                        }
                    }
                }
                if ok {
                    sh.subscribers.push(stream);
                }
            }
            Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(std::time::Duration::from_millis(5));
            }
            Err(_) => return,
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn frame(kind_: u8, tag: u8) -> Frame {
        Frame { kind: kind_, payload: vec![tag; 16] }
    }

    #[test]
    fn fan_out_and_late_join_catchup() {
        let relay = Relay::start().unwrap();
        // early subscriber
        let mut early = tcp::connect_local(relay.port).unwrap();
        // wait until registered
        for _ in 0..200 {
            if relay.subscriber_count() == 1 {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        assert_eq!(relay.subscriber_count(), 1);
        relay.publish(frame(kind::ANCHOR, 1));
        relay.publish(frame(kind::PATCH, 2));
        relay.publish(frame(kind::PATCH, 3));
        // early subscriber sees all three in order
        for tag in [1u8, 2, 3] {
            let f = tcp::read_frame(&mut early).unwrap();
            assert_eq!(f.payload[0], tag);
        }
        // late joiner gets anchor + tail replay
        let mut late = tcp::connect_local(relay.port).unwrap();
        for tag in [1u8, 2, 3] {
            let f = tcp::read_frame(&mut late).unwrap();
            assert_eq!(f.payload[0], tag);
        }
        // new publishes reach both
        relay.publish(frame(kind::PATCH, 4));
        assert_eq!(tcp::read_frame(&mut early).unwrap().payload[0], 4);
        assert_eq!(tcp::read_frame(&mut late).unwrap().payload[0], 4);
        relay.stop();
    }

    #[test]
    fn dead_subscribers_are_pruned() {
        let relay = Relay::start().unwrap();
        {
            let _conn = tcp::connect_local(relay.port).unwrap();
            for _ in 0..200 {
                if relay.subscriber_count() == 1 {
                    break;
                }
                std::thread::sleep(std::time::Duration::from_millis(5));
            }
        } // dropped
        // publishing enough data eventually hits the broken pipe and prunes
        for _ in 0..50 {
            relay.publish(Frame { kind: kind::PATCH, payload: vec![0; 1 << 16] });
            if relay.subscriber_count() == 0 {
                break;
            }
        }
        assert_eq!(relay.subscriber_count(), 0);
        relay.stop();
    }
}
