//! Patch relay (paper Fig. 5 / §E: "a relay network distributes sparse
//! BF16 weight patches from trainers to inference workers").
//!
//! The relay accepts one publisher and N subscriber connections and
//! fans every PATCH/ANCHOR/MARKER frame out to all subscribers.
//! Subscribers that connect late first receive the most recent ANCHOR
//! plus the subsequent tail (mirroring the slow path of Alg. 5).
//!
//! # Fan-out architecture: per-subscriber queues
//!
//! [`Relay::publish`] never touches a socket. Each subscriber owns a
//! bounded outbound queue drained by a dedicated writer thread, so a
//! slow or stalled subscriber blocks only *its own* writer — N-worker
//! fan-out degrades per subscriber, not globally (the previous design
//! held one mutex around all subscribers and wrote frames serially, so
//! one full TCP send buffer stalled every worker).
//!
//! A dedicated per-subscriber **reader thread** drains the subscriber's
//! upstream direction: NACK frames are serviced from the relay's frame
//! index (below), CLOSE or a dead socket marks the subscriber dead so
//! the next publish prunes it.
//!
//! # Coalescing catch-up policy
//!
//! Patch frames are chained deltas, so dropping one at random would
//! corrupt a subscriber's stream. Instead, per subscriber:
//!
//! * **ANCHOR** frames supersede everything queued before them: the
//!   queue is cleared and restarts at the anchor.
//! * **Any other frame** that would overflow the bounded queue
//!   replaces the queue contents with the canonical catch-up bundle —
//!   last ANCHOR + everything published since (`tail`, patches *and*
//!   markers) — which is exactly the late-joiner stream and therefore
//!   always a consistent restart. (The depth bound used to be checked
//!   only for PATCH frames, so a marker-heavy stream grew a slow
//!   subscriber's queue past the bound without ever coalescing.)
//!   Repeated overflow re-coalesces, so a lagging subscriber's memory
//!   stays bounded by the catch-up bundle — anchor + one epoch of tail
//!   — while it receives superseded patches at most once.
//! * MARKER frames ride in the tail (they are part of the replayable
//!   stream — a step is only committed once its marker lands), so a
//!   coalesced or late-joining subscriber still sees every surviving
//!   step's commit.
//! * Other control frames (CLOSE, …) are never dropped; a coalesce
//!   re-queues them after the catch-up bundle.
//!
//! # Per-shard NACK routing and escalation
//!
//! PATCH payloads that parse as patch containers are indexed by
//! `(step, shard_index)` (via `container::peek_meta`; opaque payloads
//! are simply not indexed). A NACK read from a subscriber's socket is
//! answered by enqueueing the indexed frame **onto that subscriber's
//! queue only** — a shard retransmit never rebroadcasts to the other
//! subscribers. The index is bounded to the most recent `index_steps`
//! distinct steps ([`INDEX_STEPS`] by default). A NACK for an evicted
//! slot is either **escalated upstream** (chained relays: see
//! [`crate::net::node::RelayNode`] and [`Relay::set_escalation`] —
//! the requester keeps waiting and the retransmit is delivered to it
//! alone via [`Relay::deliver_retransmit`]) or, with no upstream to
//! ask, answered with an explicit [`kind::NACK_MISS`] reply so the
//! subscriber falls back to the anchor slow path immediately instead
//! of timing out.
//!
//! Escalations are **storm-suppressed**: while a slot's escalation is
//! inside its backoff window ([`RetryPolicy::escalate_default`]), any
//! further NACK for it — from the same subscriber re-sending or from k
//! other leaves missing the same frame — just rides the pending entry
//! ([`Relay::nacks_suppressed`]); the single upstream retransmit then
//! fans back to every rider. Past the window the slot is re-escalated
//! once and the window doubles, so even a mute upstream is asked on a
//! bounded schedule, not per client NACK.
//!
//! # Topology (relay trees)
//!
//! A subscriber that sends a [`kind::SUBSCRIBE`] frame gets a
//! [`kind::HOP`] reply carrying this relay's distance from the
//! publisher (0 = root). [`crate::net::node::RelayNode`] chains relays
//! into distribution trees: each hop re-stages the anchor + tail and
//! serves catch-up and NACK repair from its *own* staging, so fan-out
//! scales with the tree's leaves while the trainer uplink still
//! carries each frame once.
//!
//! Writers that hit a dead socket mark themselves dead and are pruned
//! on the next publish. [`Relay::stop`] waits briefly for queues to
//! drain, then shuts the sockets down, so a stalled subscriber cannot
//! wedge shutdown (it may lose in-flight frames — it was going to
//! resync from an anchor anyway).
//!
//! # Wall-clock audit (scale-sim seam)
//!
//! The relay's time-dependent *decisions* — staging/index eviction
//! ([`RelayStage`]), per-subscriber coalescing ([`coalesce_enqueue`]),
//! and escalation storm suppression ([`EscalationLedger`]) — are
//! extracted state machines driven by explicit clock readings
//! ([`crate::sim::clock::Clock`]), shared verbatim with the scale
//! simulator. The wall-clock uses that remain are socket pump loops
//! (accept poll, writer condvar timeout, `stop`'s drain grace) which
//! exist only when a real TCP relay is started; simulated runs never
//! spawn these threads and so cannot block on real time.

use super::chaos::{ChaosConfig, Wire};
use super::tcp::{self, kind, Frame};
use crate::obs;
use crate::sim::clock::Clock;
use crate::util::retry::RetryPolicy;
use crate::util::sync::{CondvarExt, LockExt};
use anyhow::Result;
use std::collections::{HashMap, VecDeque};
use std::net::{Shutdown, TcpListener};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

/// Default bound on a subscriber's outbound queue, in frames.
pub const DEFAULT_QUEUE_DEPTH: usize = 64;

/// Distinct steps the NACK frame index retains.
pub const INDEX_STEPS: usize = 8;

/// Per-hop staging state: the last anchor, the tail published since it
/// (patches *and* markers — the canonical catch-up bundle), and the
/// bounded `(step, shard)` frame index NACK repair is served from.
///
/// Extracted from the socket relay so the scale simulator
/// (`crate::sim`) runs the *same* staging/eviction logic per simulated
/// hop — no fork of the catch-up or index-eviction policy.
pub struct RelayStage {
    last_anchor: Option<Arc<Frame>>,
    /// Patches + markers since the last anchor, in publish order.
    tail: Vec<Arc<Frame>>,
    /// Container PATCH frames by (step, shard_index) for NACK service.
    frame_index: HashMap<(u64, u32), Arc<Frame>>,
    /// Distinct steps present in `frame_index`, insertion order.
    index_steps: VecDeque<u64>,
    /// Bound on `index_steps` (defaults to [`INDEX_STEPS`]).
    max_index_steps: usize,
}

impl RelayStage {
    /// Empty staging with an index bound of `index_steps` (≥ 1).
    pub fn new(index_steps: usize) -> RelayStage {
        RelayStage {
            last_anchor: None,
            tail: Vec::new(),
            frame_index: HashMap::new(),
            index_steps: VecDeque::new(),
            max_index_steps: index_steps.max(1),
        }
    }

    /// Stage one published frame: ANCHOR supersedes the tail, PATCH and
    /// MARKER extend it (markers are part of the replayable stream — a
    /// step is only committed once its marker lands). `shard_meta` is
    /// the frame's `(step, shard_index)` when it parses as a patch
    /// container (socket plane: `container::peek_meta`; simulator:
    /// carried on the modeled frame) — such frames are indexed for
    /// per-shard NACK service; opaque payloads just aren't NACKable.
    pub fn stage(&mut self, frame: &Arc<Frame>, shard_meta: Option<(u64, u32)>) {
        match frame.kind {
            kind::ANCHOR => {
                self.last_anchor = Some(frame.clone());
                self.tail.clear();
            }
            kind::PATCH => {
                self.tail.push(frame.clone());
                if let Some((step, shard)) = shard_meta {
                    self.index_frame(step, shard, frame.clone());
                }
            }
            kind::MARKER => self.tail.push(frame.clone()),
            _ => {}
        }
    }

    /// Index one container PATCH frame for per-shard NACK service,
    /// evicting the oldest indexed steps past the bound.
    pub fn index_frame(&mut self, step: u64, shard: u32, frame: Arc<Frame>) {
        if !self.index_steps.contains(&step) {
            self.index_steps.push_back(step);
            while self.index_steps.len() > self.max_index_steps {
                if let Some(old) = self.index_steps.pop_front() {
                    self.frame_index.retain(|&(s, _), _| s != old);
                }
            }
        }
        self.frame_index.insert((step, shard), frame);
    }

    /// The indexed frame for `(step, shard)`, if not yet evicted.
    pub fn lookup(&self, step: u64, shard: u32) -> Option<Arc<Frame>> {
        self.frame_index.get(&(step, shard)).cloned()
    }

    /// The canonical catch-up bundle: last anchor + everything published
    /// since. This is exactly the late-joiner stream, and what a
    /// coalesced subscriber's queue is rebuilt from.
    pub fn catchup(&self) -> impl Iterator<Item = Arc<Frame>> + '_ {
        self.last_anchor.iter().cloned().chain(self.tail.iter().cloned())
    }

    /// Frames in the catch-up bundle (anchor + tail).
    pub fn catchup_len(&self) -> usize {
        self.last_anchor.is_some() as usize + self.tail.len()
    }
}

/// The per-subscriber coalescing policy (module docs, "Coalescing
/// catch-up policy"), extracted so the simulator enqueues through the
/// exact code the socket relay uses:
///
/// * ANCHOR clears the queued stream (control replies survive) and
///   restarts it at the anchor.
/// * Any frame overflowing `depth` swaps the queue for the catch-up
///   bundle from `stage` (+ surviving control frames; + the frame
///   itself unless it already rides in the rebuilt tail).
/// * Everything else appends.
///
/// Returns `(coalesced, dropped)`: whether an overflow catch-up swap
/// happened, and how many queued stream frames were superseded.
pub fn coalesce_enqueue(
    q: &mut VecDeque<Arc<Frame>>,
    frame: &Arc<Frame>,
    stage: &RelayStage,
    depth: usize,
) -> (bool, u64) {
    let is_stream =
        |f: &Frame| f.kind == kind::PATCH || f.kind == kind::ANCHOR || f.kind == kind::MARKER;
    match frame.kind {
        kind::ANCHOR => {
            // the anchor supersedes the queued stream; control replies
            // (HOP, NACK_MISS, CLOSE, …) survive the clear exactly as
            // they survive a coalesce — otherwise an anchor racing a
            // SUBSCRIBE handshake would eat the HOP reply for good
            let keep: Vec<Arc<Frame>> =
                q.iter().filter(|f| !is_stream(f)).cloned().collect();
            let dropped = (q.len() - keep.len()) as u64;
            q.clear();
            q.push_back(frame.clone());
            q.extend(keep);
            (false, dropped)
        }
        // the depth bound applies to EVERY enqueue, not just patches: a
        // marker- or control-heavy stream must coalesce a slow
        // subscriber exactly like a patch stream would
        _ if q.len() >= depth => {
            // slow subscriber: swap the queue for the canonical
            // catch-up bundle (anchor + tail), keeping control frames;
            // superseded patches/markers are dropped once (the tail
            // replays surviving markers)
            let keep: Vec<Arc<Frame>> =
                q.iter().filter(|f| !is_stream(f)).cloned().collect();
            let dropped = (q.len() - keep.len()) as u64;
            q.clear();
            q.extend(stage.catchup());
            q.extend(keep);
            // PATCH/MARKER frames already ride in the rebuilt tail;
            // anything else (CLOSE, …) follows the bundle
            if frame.kind != kind::PATCH && frame.kind != kind::MARKER {
                q.push_back(frame.clone());
            }
            (true, dropped)
        }
        _ => {
            q.push_back(frame.clone());
            (false, 0)
        }
    }
}

/// One escalated `(step, shard)` slot: the riders waiting on the
/// retransmit, and the backoff state that keeps a NACK storm from
/// multiplying upstream.
struct PendingSlot<R> {
    riders: Vec<R>,
    attempts: u32,
    /// Clock reading of the last escalation actually sent upstream.
    last: Duration,
}

/// NACK-storm suppression ledger, generic over the rider handle (the
/// socket relay rides subscriber channels; the simulator rides peer
/// ids). While a slot's escalation is inside its backoff window
/// ([`RetryPolicy::escalate_default`]), further NACKs for it just ride
/// the pending entry; past the window the slot is re-escalated once
/// and the window doubles. All timing flows through explicit `now`
/// readings (see [`crate::sim::clock::Clock`]), so the same dedup
/// arithmetic runs on the wall and in simulated time.
pub struct EscalationLedger<R> {
    pending: HashMap<(u64, u32), PendingSlot<R>>,
    policy: RetryPolicy,
}

impl<R> EscalationLedger<R> {
    pub fn new(policy: RetryPolicy) -> EscalationLedger<R> {
        EscalationLedger { pending: HashMap::new(), policy }
    }

    /// Override the escalation backoff schedule.
    pub fn set_policy(&mut self, policy: RetryPolicy) {
        self.policy = policy;
    }

    /// Record one NACK for `(step, shard)` from `rider` at time `now`.
    /// Returns whether the caller should escalate upstream *now*:
    /// true for the first NACK on a slot or once the current backoff
    /// window has expired (the window grows per attempt); false while
    /// in-window — the rider is registered and the caller should count
    /// a suppression. `same` dedups riders (channel pointer equality on
    /// the socket plane, id equality in the simulator).
    pub fn on_nack(
        &mut self,
        step: u64,
        shard: u32,
        rider: R,
        same: impl Fn(&R, &R) -> bool,
        now: Duration,
    ) -> bool {
        use std::collections::hash_map::Entry;
        match self.pending.entry((step, shard)) {
            Entry::Occupied(mut o) => {
                let p = o.get_mut();
                if !p.riders.iter().any(|r| same(r, &rider)) {
                    p.riders.push(rider);
                }
                let window = self.policy.delay_for(p.attempts.saturating_sub(1));
                if now.saturating_sub(p.last) < window {
                    false
                } else {
                    p.attempts += 1;
                    p.last = now;
                    true
                }
            }
            Entry::Vacant(v) => {
                v.insert(PendingSlot { riders: vec![rider], attempts: 1, last: now });
                true
            }
        }
    }

    /// Resolve one slot (retransmit arrived, or the escalation failed):
    /// every registered rider, or None when nothing was pending.
    pub fn resolve(&mut self, step: u64, shard: u32) -> Option<Vec<R>> {
        self.pending.remove(&(step, shard)).map(|p| p.riders)
    }

    /// Resolve EVERY pending slot (upstream torn down).
    pub fn resolve_all(&mut self) -> Vec<((u64, u32), Vec<R>)> {
        self.pending.drain().map(|(k, p)| (k, p.riders)).collect()
    }

    /// Riders currently waiting on `(step, shard)` (0 when none).
    pub fn riders(&self, step: u64, shard: u32) -> usize {
        self.pending.get(&(step, shard)).map_or(0, |p| p.riders.len())
    }

    /// Slots currently escalated and unanswered.
    pub fn pending_slots(&self) -> usize {
        self.pending.len()
    }
}

struct SubQueue {
    /// Frames are `Arc`-shared across subscribers/tail, so enqueueing
    /// (and coalescing) is pointer bumps, not payload copies, under the
    /// shared lock.
    q: VecDeque<Arc<Frame>>,
    dead: bool,
    /// Frames dropped/superseded for this subscriber by coalescing.
    dropped: u64,
}

type Chan = Arc<(Mutex<SubQueue>, Condvar)>;

/// Push one frame onto a subscriber channel (bypassing the coalescing
/// policy — used for NACK retransmits and control replies, which are
/// already minimal) and wake its writer. No-op on a dead subscriber.
fn push_direct(chan: &Chan, frame: Arc<Frame>) {
    let (lock, cv) = &**chan;
    let mut q = lock.plock();
    if !q.dead {
        q.q.push_back(frame);
        cv.notify_one();
    }
}

/// Count and answer one unserviceable NACK with a NACK_MISS reply to
/// exactly the requesting subscriber.
fn reply_miss(sh: &mut Shared, chan: &Chan, step: u64, shard: u32) {
    miss_waiters(sh, step, shard, std::slice::from_ref(chan));
}

/// Fail one escalated `(step, shard)` slot: count every waiter and
/// push it a NACK_MISS so it degrades to the anchor slow path now
/// instead of waiting out its NACK timeout. Caller holds the lock on
/// `sh`.
fn miss_waiters(sh: &mut Shared, step: u64, shard: u32, chans: &[Chan]) {
    sh.nacks_unserviceable += chans.len() as u64;
    obs::span_at(
        sh.clock.now().as_micros() as u64,
        obs::Stage::NackMiss,
        0,
        step,
        shard,
        chans.len() as u64,
    );
    let miss =
        Arc::new(Frame { kind: kind::NACK_MISS, payload: tcp::shard_ack_payload(step, shard) });
    for chan in chans {
        push_direct(chan, miss.clone());
    }
}

struct SubHandle {
    chan: Chan,
    /// Clone of the subscriber socket, kept so `stop()` can unblock a
    /// writer stuck in `write` (the reader holds its own clone).
    stream: Wire,
    writer: Option<std::thread::JoinHandle<()>>,
    reader: Option<std::thread::JoinHandle<()>>,
}

/// Upstream escalation hook (relay chaining): sends a NACK for one
/// `(step, shard)` slot towards the publisher; returns false when the
/// upstream is unreachable (the requester then gets a NACK_MISS).
type Escalate = Arc<dyn Fn(u64, u32) -> bool + Send + Sync>;

struct Shared {
    subs: Vec<SubHandle>,
    /// Anchor + tail staging and the NACK frame index — the hop state
    /// machine shared with the simulator ([`RelayStage`]).
    stage: RelayStage,
    queue_depth: usize,
    /// Total coalescing events across subscribers (observability).
    coalesced: u64,
    /// Shard NACKs serviced from the index (observability/tests).
    nacks_serviced: u64,
    /// NACKs forwarded upstream because the local index missed.
    nacks_escalated: u64,
    /// NACKs answered with NACK_MISS (no upstream, or upstream missed).
    nacks_unserviceable: u64,
    /// NACKs absorbed as riders on an in-window escalation instead of
    /// going upstream again (storm suppression).
    nacks_suppressed: u64,
    /// Storm-suppression state: slots escalated upstream → subscriber
    /// channels awaiting the retransmit, with per-slot backoff.
    ledger: EscalationLedger<Chan>,
    /// Upstream NACK hook; None for a root relay.
    escalate: Option<Escalate>,
    /// This relay's distance from the publisher (0 = root); replied to
    /// SUBSCRIBE frames as a HOP frame.
    hop: u32,
    /// Time source for escalation backoff windows (wall on the socket
    /// plane; the sim drives the extracted state machines off a virtual
    /// clock instead).
    clock: Clock,
}

/// Relay server handle.
pub struct Relay {
    pub port: u16,
    shared: Arc<Mutex<Shared>>,
    accept_thread: Mutex<Option<std::thread::JoinHandle<()>>>,
    stop: Arc<AtomicBool>,
}

impl Relay {
    /// Start a relay on an ephemeral localhost port with the default
    /// queue depth.
    pub fn start() -> Result<Relay> {
        Relay::start_with_depth(DEFAULT_QUEUE_DEPTH)
    }

    /// Start with an explicit per-subscriber queue bound (≥ 1).
    pub fn start_with_depth(queue_depth: usize) -> Result<Relay> {
        Relay::start_with_opts(queue_depth, INDEX_STEPS)
    }

    /// Start with explicit queue depth and NACK frame-index bound
    /// (both ≥ 1). A smaller `index_steps` evicts repair slots sooner —
    /// chained-relay tests use this to force upstream escalation.
    pub fn start_with_opts(queue_depth: usize, index_steps: usize) -> Result<Relay> {
        Relay::start_with_chaos(queue_depth, index_steps, None)
    }

    /// Start with seeded wire-level fault injection on every accepted
    /// subscriber socket ([`crate::net::chaos`]); `None` is a plain
    /// wire, bit-for-bit the un-chaotic relay.
    pub fn start_with_chaos(
        queue_depth: usize,
        index_steps: usize,
        chaos: Option<ChaosConfig>,
    ) -> Result<Relay> {
        let (listener, port) = tcp::listen_local()?;
        listener.set_nonblocking(true)?;
        let shared = Arc::new(Mutex::new(Shared {
            subs: Vec::new(),
            stage: RelayStage::new(index_steps),
            queue_depth: queue_depth.max(1),
            coalesced: 0,
            nacks_serviced: 0,
            nacks_escalated: 0,
            nacks_unserviceable: 0,
            nacks_suppressed: 0,
            ledger: EscalationLedger::new(RetryPolicy::escalate_default()),
            escalate: None,
            hop: 0,
            clock: Clock::wall(),
        }));
        let stop = Arc::new(AtomicBool::new(false));
        let accept_thread =
            Mutex::new(Some(spawn_accept(listener, shared.clone(), stop.clone(), chaos)));
        Ok(Relay { port, shared, accept_thread, stop })
    }

    /// Install the upstream NACK hook (relay chaining): called when a
    /// subscriber NACKs a slot the local frame index has evicted. The
    /// hook sends the NACK towards the publisher and returns whether
    /// the send succeeded; the requester is answered later via
    /// [`Relay::deliver_retransmit`] or [`Relay::fail_escalated`].
    pub fn set_escalation(&self, f: impl Fn(u64, u32) -> bool + Send + Sync + 'static) {
        self.shared.plock().escalate = Some(Arc::new(f));
    }

    /// Override the escalation backoff schedule (tests pin it far out
    /// to make rider counting deterministic, or shrink it to force
    /// re-escalation quickly).
    pub fn set_escalation_policy(&self, policy: RetryPolicy) {
        self.shared.plock().ledger.set_policy(policy);
    }

    /// Set this relay's hop distance from the publisher (0 = root),
    /// replied to SUBSCRIBE frames so downstream peers learn theirs.
    pub fn set_hop(&self, hop: u32) {
        self.shared.plock().hop = hop;
    }

    /// Hop distance from the publisher (0 = root relay).
    pub fn hop(&self) -> u32 {
        self.shared.plock().hop
    }

    /// Publish a frame to all current subscribers (and remember anchors
    /// + tail for late joiners and catch-up). Never blocks on a
    /// subscriber socket: enqueue only, with the coalescing policy
    /// above.
    pub fn publish(&self, frame: Frame) {
        let frame = Arc::new(frame);
        let mut guard = self.shared.plock();
        let sh: &mut Shared = &mut guard;
        // index container frames for per-shard NACK service; opaque
        // payloads just aren't NACKable
        let shard_meta = if frame.kind == kind::PATCH {
            crate::sparse::container::peek_meta(&frame.payload)
                .ok()
                .map(|m| (m.step, m.shard_index))
        } else {
            None
        };
        sh.stage.stage(&frame, shard_meta);
        // trace seam: relay-side spans stamp the relay's own clock (the
        // wall anchor on real sockets), keyed like the publisher's
        let now_us = sh.clock.now().as_micros() as u64;
        if let Some((step, shard)) = shard_meta {
            obs::span_at(now_us, obs::Stage::RelayStage, 0, step, shard, sh.hop as u64);
        }
        let Shared { subs, stage, queue_depth, coalesced, .. } = sh;
        let depth = *queue_depth;
        subs.retain_mut(|sub| {
            let (lock, cv) = &*sub.chan;
            let mut q = lock.plock();
            if q.dead {
                drop(q);
                // unblock a writer stuck in write() / a reader stuck in
                // read() before joining the writer; the reader handle is
                // dropped (detached) — it exits on the socket error and
                // never blocks on anything we hold
                let _ = sub.stream.shutdown(Shutdown::Both);
                if let Some(h) = sub.writer.take() {
                    let _ = h.join();
                }
                drop(sub.reader.take());
                return false;
            }
            // one coalescing policy for the socket plane and the
            // simulator — see `coalesce_enqueue`
            let (was_coalesced, dropped) = coalesce_enqueue(&mut q.q, &frame, stage, depth);
            if was_coalesced {
                *coalesced += 1;
                if let Some((step, shard)) = shard_meta {
                    obs::span_at(now_us, obs::Stage::Coalesce, 0, step, shard, q.q.len() as u64);
                }
            }
            if dropped > 0 {
                if let Some((step, shard)) = shard_meta {
                    obs::span_at(now_us, obs::Stage::Evict, 0, step, shard, dropped);
                }
            }
            q.dropped += dropped;
            cv.notify_one();
            true
        });
    }

    /// Live (non-dead) subscriber connections.
    pub fn subscriber_count(&self) -> usize {
        let sh = self.shared.plock();
        sh.subs.iter().filter(|s| !s.chan.0.plock().dead).count()
    }

    /// Total coalescing (catch-up) events so far, across subscribers.
    pub fn coalesced_catchups(&self) -> u64 {
        self.shared.plock().coalesced
    }

    /// Frames dropped as superseded across current subscribers.
    pub fn dropped_frames(&self) -> u64 {
        let sh = self.shared.plock();
        sh.subs.iter().map(|s| s.chan.0.plock().dropped).sum()
    }

    /// Shard NACKs answered from the frame index so far.
    pub fn nacks_serviced(&self) -> u64 {
        self.shared.plock().nacks_serviced
    }

    /// NACKs forwarded upstream because the local index had evicted
    /// the slot (0 unless this relay is a chained node).
    pub fn nacks_escalated(&self) -> u64 {
        self.shared.plock().nacks_escalated
    }

    /// NACKs answered with an explicit NACK_MISS (no upstream to ask,
    /// or the upstream missed too).
    pub fn nacks_unserviceable(&self) -> u64 {
        self.shared.plock().nacks_unserviceable
    }

    /// NACKs absorbed as riders on an escalation already in flight
    /// (inside its backoff window) instead of going upstream again.
    pub fn nacks_suppressed(&self) -> u64 {
        self.shared.plock().nacks_suppressed
    }

    /// Subscribers currently waiting on an escalated `(step, shard)`
    /// slot (0 when nothing is pending for it) — storm tests use this
    /// to know every rider has registered before answering.
    pub fn pending_riders(&self, step: u64, shard: u32) -> usize {
        self.shared.plock().ledger.riders(step, shard)
    }

    /// Deliver an upstream retransmit for an escalated `(step, shard)`
    /// slot: re-index the frame (so the next NACK for it is served
    /// locally) and enqueue it to exactly the subscribers that were
    /// waiting on the escalation. Returns false when nothing was
    /// pending for the slot — the caller should then treat the frame
    /// as ordinary stream traffic.
    pub fn deliver_retransmit(&self, step: u64, shard: u32, frame: Frame) -> bool {
        let frame = Arc::new(frame);
        let mut sh = self.shared.plock();
        let riders = match sh.ledger.resolve(step, shard) {
            Some(r) => r,
            None => return false,
        };
        sh.stage.index_frame(step, shard, frame.clone());
        sh.nacks_serviced += 1;
        for chan in &riders {
            push_direct(chan, frame.clone());
        }
        true
    }

    /// The upstream answered an escalated `(step, shard)` slot with
    /// NACK_MISS: forward the miss to the waiting subscribers so they
    /// stop waiting and take the anchor slow path.
    pub fn fail_escalated(&self, step: u64, shard: u32) {
        let mut sh = self.shared.plock();
        if let Some(riders) = sh.ledger.resolve(step, shard) {
            miss_waiters(&mut sh, step, shard, &riders);
            drop(sh);
            let _ = obs::Obs::global()
                .dump_incident(&format!("escalation failed step {} shard {}", step, shard));
        }
    }

    /// Fail EVERY escalated slot with NACK_MISS: called when the
    /// upstream connection is torn down (re-parenting, orderly
    /// detach), because the retransmits those escalations were waiting
    /// for can no longer arrive on it. The waiting subscribers degrade
    /// to the anchor slow path immediately instead of burning their
    /// NACK timeouts across the failover.
    pub fn fail_all_escalated(&self) {
        let mut sh = self.shared.plock();
        let failed = sh.ledger.resolve_all();
        let any = !failed.is_empty();
        for ((step, shard), riders) in failed {
            miss_waiters(&mut sh, step, shard, &riders);
        }
        drop(sh);
        if any {
            let _ = obs::Obs::global().dump_incident("upstream lost, all escalations failed");
        }
    }

    /// Graceful-best-effort shutdown: waits briefly for queues to
    /// drain, then closes subscriber sockets (unblocking any stalled
    /// writer or reader) and joins all threads. Takes `&self` so an
    /// `Arc<Relay>` shared with a transport can still be stopped.
    pub fn stop(&self) {
        self.stop.store(true, Ordering::SeqCst);
        // join the accept thread FIRST (it polls the stop flag every
        // ~5ms), so no subscriber can register after we drain the list
        // — otherwise its writer/reader threads would leak
        if let Some(t) = self.accept_thread.plock().take() {
            let _ = t.join();
        }
        let subs = {
            let mut sh = self.shared.plock();
            std::mem::take(&mut sh.subs)
        };
        for mut sub in subs {
            let (lock, cv) = &*sub.chan;
            for _ in 0..100 {
                let q = lock.plock();
                if q.q.is_empty() || q.dead {
                    break;
                }
                drop(q);
                // pallas-lint: allow(retry-discipline): stop()'s bounded drain grace, not a recovery wait
                std::thread::sleep(std::time::Duration::from_millis(10));
            }
            lock.plock().dead = true;
            cv.notify_all();
            let _ = sub.stream.shutdown(Shutdown::Both);
            if let Some(h) = sub.writer.take() {
                let _ = h.join();
            }
            if let Some(h) = sub.reader.take() {
                let _ = h.join();
            }
        }
    }
}

/// Writer thread: drains one subscriber's queue onto its socket. Only
/// this thread ever blocks on the socket's write half, so a stalled
/// subscriber cannot delay anyone else.
fn spawn_writer(
    mut stream: Wire,
    chan: Chan,
    stop: Arc<AtomicBool>,
) -> std::thread::JoinHandle<()> {
    std::thread::spawn(move || loop {
        let frame = {
            let (lock, cv) = &*chan;
            let mut q = lock.plock();
            loop {
                if q.dead {
                    return;
                }
                if let Some(f) = q.q.pop_front() {
                    break f;
                }
                if stop.load(Ordering::SeqCst) {
                    return;
                }
                q = cv.pwait_timeout(q, std::time::Duration::from_millis(20));
            }
        };
        if tcp::write_frame(&mut stream, &frame).is_err() {
            let (lock, cv) = &*chan;
            lock.plock().dead = true;
            cv.notify_all();
            return;
        }
    })
}

/// Reader thread: drains one subscriber's upstream direction. A NACK
/// for an indexed (step, shard) frame re-queues that frame **onto this
/// subscriber's queue only**; an evicted slot is escalated upstream
/// (when an escalation hook is installed) or answered with NACK_MISS.
/// SUBSCRIBE gets a HOP reply carrying this relay's depth. EOF, a
/// socket error, or CLOSE marks the subscriber dead (and shuts the
/// socket down so the writer unblocks).
///
/// Lock order matches `publish`: `shared` first, then the subscriber
/// chan — never the reverse — so NACK routing cannot deadlock against
/// a concurrent publish. The escalation hook is invoked with no lock
/// held (it writes to the upstream socket).
fn spawn_reader(
    mut stream: Wire,
    chan: Chan,
    shared: Arc<Mutex<Shared>>,
    stop: Arc<AtomicBool>,
) -> std::thread::JoinHandle<()> {
    std::thread::spawn(move || loop {
        if stop.load(Ordering::SeqCst) {
            return;
        }
        match tcp::read_frame(&mut stream) {
            Ok(f) if f.kind == kind::NACK => {
                if let Ok((step, shard)) = tcp::parse_shard_ack(&f.payload) {
                    let mut sh = shared.plock();
                    if let Some(frame) = sh.stage.lookup(step, shard) {
                        sh.nacks_serviced += 1;
                        obs::span_at(
                            sh.clock.now().as_micros() as u64,
                            obs::Stage::NackServe,
                            0,
                            step,
                            shard,
                            frame.payload.len() as u64,
                        );
                        // a retransmit bypasses the coalescing policy:
                        // it is already the minimal repair
                        push_direct(&chan, frame);
                        continue;
                    }
                    // evicted or never indexed: escalate upstream when
                    // we can, otherwise tell the requester explicitly
                    // so it degrades to the anchor slow path instead
                    // of waiting out its NACK timeout
                    let esc = match sh.escalate.clone() {
                        Some(esc) => esc,
                        None => {
                            reply_miss(&mut sh, &chan, step, shard);
                            continue;
                        }
                    };
                    // one escalation answers every rider: k clients
                    // NACKing the slot inside the current backoff
                    // window cost exactly one upstream frame (the
                    // storm suppression of module docs); only a
                    // window expiry re-asks the upstream, with the
                    // window growing per attempt so a mute upstream
                    // is re-asked on a bounded schedule — the window
                    // arithmetic lives in EscalationLedger, shared
                    // with the simulator
                    let now = sh.clock.now();
                    let escalate_now = sh.ledger.on_nack(
                        step,
                        shard,
                        chan.clone(),
                        |a, b| Arc::ptr_eq(a, b),
                        now,
                    );
                    if !escalate_now {
                        sh.nacks_suppressed += 1;
                        continue;
                    }
                    sh.nacks_escalated += 1;
                    obs::span_at(
                        sh.clock.now().as_micros() as u64,
                        obs::Stage::Escalate,
                        0,
                        step,
                        shard,
                        sh.ledger.riders(step, shard) as u64,
                    );
                    drop(sh);
                    if !esc(step, shard) {
                        // upstream unreachable: the escalation never
                        // went out, so answer EVERY waiter (riders
                        // included) with a miss
                        let mut sh = shared.plock();
                        if let Some(riders) = sh.ledger.resolve(step, shard) {
                            miss_waiters(&mut sh, step, shard, &riders);
                        }
                    }
                }
            }
            Ok(f) if f.kind == kind::SUBSCRIBE => {
                // topology handshake: reply with this relay's hop depth
                let hop = shared.plock().hop;
                push_direct(
                    &chan,
                    Arc::new(Frame { kind: kind::HOP, payload: tcp::hop_payload(hop) }),
                );
            }
            Ok(f) if f.kind == kind::OBS_SNAP => {
                // live introspection (`paper obs`): this relay's fan-out
                // counters + the process obs hub, served off the data
                // path through the subscriber's ordinary writer queue
                let flags = tcp::parse_obs_snap(&f.payload).unwrap_or(0);
                let mut c = crate::util::json::Json::obj();
                {
                    let sh = shared.plock();
                    let live = sh.subs.iter().filter(|s| !s.chan.0.plock().dead).count();
                    c.set("hop", (sh.hop as u64).into())
                        .set("subscribers", live.into())
                        .set("coalesced", sh.coalesced.into())
                        .set("nacks_serviced", sh.nacks_serviced.into())
                        .set("nacks_escalated", sh.nacks_escalated.into())
                        .set("nacks_unserviceable", sh.nacks_unserviceable.into())
                        .set("nacks_suppressed", sh.nacks_suppressed.into())
                        .set("pending_escalations", sh.ledger.pending_slots().into());
                }
                let body = obs::snapshot_reply("relay", flags, c).to_string();
                push_direct(
                    &chan,
                    Arc::new(Frame {
                        kind: kind::OBS_REPLY,
                        payload: tcp::obs_reply_payload(&body),
                    }),
                );
            }
            // ACK is accepted and ignored (observability hooks may
            // consume it later); CLOSE and socket errors end the
            // subscription
            Ok(f) if f.kind != kind::CLOSE => {}
            _ => {
                let (lock, cv) = &*chan;
                lock.plock().dead = true;
                cv.notify_all();
                let _ = stream.shutdown(Shutdown::Both);
                return;
            }
        }
    })
}

fn spawn_accept(
    listener: TcpListener,
    shared: Arc<Mutex<Shared>>,
    stop: Arc<AtomicBool>,
    chaos: Option<ChaosConfig>,
) -> std::thread::JoinHandle<()> {
    std::thread::spawn(move || loop {
        if stop.load(Ordering::SeqCst) {
            return;
        }
        match listener.accept() {
            Ok((stream, _)) => {
                stream.set_nodelay(true).ok();
                // one chaos domain per subscriber connection; clones
                // share its fault state so both socket halves see one
                // op sequence
                let stream = Wire::wrap(stream, chaos.as_ref());
                let (clone, rclone) = match (stream.try_clone(), stream.try_clone()) {
                    (Ok(c), Ok(r)) => (c, r),
                    _ => continue,
                };
                let mut sh = shared.plock();
                // catch-up preload: anchor + tail (patches and markers);
                // the writer thread delivers it, so a slow joiner cannot
                // stall accept
                let q: VecDeque<Arc<Frame>> = sh.stage.catchup().collect();
                let chan: Chan =
                    Arc::new((Mutex::new(SubQueue { q, dead: false, dropped: 0 }), Condvar::new()));
                let writer = spawn_writer(stream, chan.clone(), stop.clone());
                let reader = spawn_reader(rclone, chan.clone(), shared.clone(), stop.clone());
                sh.subs.push(SubHandle {
                    chan,
                    stream: clone,
                    writer: Some(writer),
                    reader: Some(reader),
                });
            }
            Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                // pallas-lint: allow(retry-discipline): nonblocking-accept poll cadence, not a recovery wait
                std::thread::sleep(std::time::Duration::from_millis(5));
            }
            Err(_) => return,
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::container::{self, EncodeOpts, Patch, Values};
    use crate::sparse::synthetic_layout;

    fn frame(kind_: u8, tag: u8) -> Frame {
        Frame { kind: kind_, payload: vec![tag; 16] }
    }

    #[test]
    fn fan_out_and_late_join_catchup() {
        let relay = Relay::start().unwrap();
        // early subscriber
        let mut early = tcp::connect_local(relay.port).unwrap();
        // wait until registered
        for _ in 0..200 {
            if relay.subscriber_count() == 1 {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        assert_eq!(relay.subscriber_count(), 1);
        relay.publish(frame(kind::ANCHOR, 1));
        relay.publish(frame(kind::PATCH, 2));
        relay.publish(frame(kind::PATCH, 3));
        // early subscriber sees all three in order
        for tag in [1u8, 2, 3] {
            let f = tcp::read_frame(&mut early).unwrap();
            assert_eq!(f.payload[0], tag);
        }
        // late joiner gets anchor + tail replay
        let mut late = tcp::connect_local(relay.port).unwrap();
        for tag in [1u8, 2, 3] {
            let f = tcp::read_frame(&mut late).unwrap();
            assert_eq!(f.payload[0], tag);
        }
        // new publishes reach both
        relay.publish(frame(kind::PATCH, 4));
        assert_eq!(tcp::read_frame(&mut early).unwrap().payload[0], 4);
        assert_eq!(tcp::read_frame(&mut late).unwrap().payload[0], 4);
        relay.stop();
    }

    #[test]
    fn markers_ride_the_tail() {
        let relay = Relay::start().unwrap();
        relay.publish(frame(kind::ANCHOR, 1));
        relay.publish(Frame {
            kind: kind::MARKER,
            payload: tcp::marker_frame_payload(true, 0, "m0"),
        });
        relay.publish(frame(kind::PATCH, 2));
        relay.publish(Frame {
            kind: kind::MARKER,
            payload: tcp::marker_frame_payload(false, 1, "m1"),
        });
        // a late joiner replays anchor, anchor marker, patch, marker —
        // in publish order
        let mut late = tcp::connect_local(relay.port).unwrap();
        let kinds: Vec<u8> =
            (0..4).map(|_| tcp::read_frame(&mut late).unwrap().kind).collect();
        assert_eq!(kinds, vec![kind::ANCHOR, kind::MARKER, kind::PATCH, kind::MARKER]);
        relay.stop();
    }

    #[test]
    fn dead_subscribers_are_pruned() {
        let relay = Relay::start().unwrap();
        {
            let _conn = tcp::connect_local(relay.port).unwrap();
            for _ in 0..200 {
                if relay.subscriber_count() == 1 {
                    break;
                }
                std::thread::sleep(std::time::Duration::from_millis(5));
            }
        } // dropped
        // publish until the writer/reader notices the dead socket and
        // the dead entry is pruned on a subsequent publish
        let mut pruned = false;
        for _ in 0..400 {
            relay.publish(Frame { kind: kind::PATCH, payload: vec![0; 1 << 16] });
            if relay.subscriber_count() == 0 {
                pruned = true;
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        assert!(pruned, "dead subscriber was never pruned");
        relay.stop();
    }

    #[test]
    fn anchor_supersedes_queued_patches() {
        // a subscriber that never reads: once its socket buffers fill,
        // patches queue up, and the next anchor replaces them instead
        // of letting them accumulate
        let relay = Relay::start_with_depth(16).unwrap();
        let conn = tcp::connect_local(relay.port).unwrap();
        for _ in 0..200 {
            if relay.subscriber_count() == 1 {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        relay.publish(Frame { kind: kind::ANCHOR, payload: vec![1u8; 1 << 16] });
        // 20 MB of patches against a non-reading subscriber: far more
        // than kernel send+recv buffering, so the writer blocks and the
        // queue holds at least one frame when the anchor arrives
        for i in 0..10u8 {
            relay.publish(Frame { kind: kind::PATCH, payload: vec![10 + i; 2 << 20] });
        }
        relay.publish(Frame { kind: kind::ANCHOR, payload: vec![2u8; 1 << 16] });
        {
            let sh = relay.shared.plock();
            let q = sh.subs[0].chan.0.plock();
            assert_eq!(q.q.len(), 1, "anchor must clear the queue");
            assert_eq!(q.q[0].kind, kind::ANCHOR);
            assert_eq!(q.q[0].payload[0], 2);
            assert!(q.dropped >= 1, "superseded patches must be counted");
        }
        drop(conn);
        relay.stop();
    }

    #[test]
    fn marker_flood_coalesces_like_patches() {
        // regression: the depth bound used to apply only to PATCH
        // frames, so a marker-heavy stream pushed a slow subscriber's
        // queue past the bound without ever coalescing. Markers must
        // trigger the same catch-up bundle swap.
        let depth = 4usize;
        let relay = Relay::start_with_depth(depth).unwrap();
        let conn = tcp::connect_local(relay.port).unwrap();
        for _ in 0..200 {
            if relay.subscriber_count() == 1 {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        // big frames so the writer blocks on the kernel send buffer
        // against a non-reading subscriber and the queue really fills
        relay.publish(Frame { kind: kind::ANCHOR, payload: vec![1u8; 2 << 20] });
        let marker = |i: u64| Frame {
            kind: kind::MARKER,
            payload: {
                let mut p = tcp::marker_frame_payload(false, i, "m");
                p.resize(2 << 20, 0x6d);
                p
            },
        };
        for i in 1..=(3 * depth as u64) {
            relay.publish(marker(i));
        }
        assert!(
            relay.coalesced_catchups() >= 1,
            "a marker flood past queue_depth must coalesce"
        );
        {
            let sh = relay.shared.plock();
            let q = sh.subs[0].chan.0.plock();
            // the queue is exactly the canonical catch-up bundle:
            // anchor first, then the surviving tail — never more than
            // bundle-size frames, however many markers flooded past
            assert!(
                q.q.len() <= sh.stage.catchup_len(),
                "queue ({}) exceeds the catch-up bundle ({})",
                q.q.len(),
                sh.stage.catchup_len()
            );
            assert_eq!(q.q[0].kind, kind::ANCHOR, "coalesce must restart at the anchor");
        }
        drop(conn);
        relay.stop();
    }

    #[test]
    fn unindexed_nack_gets_explicit_miss() {
        // regression: a NACK for an evicted / never-indexed slot used
        // to be silently ignored, leaving the subscriber to wait out
        // its timeout; a root relay must answer NACK_MISS immediately
        let relay = Relay::start().unwrap();
        let mut conn = tcp::connect_local(relay.port).unwrap();
        for _ in 0..200 {
            if relay.subscriber_count() == 1 {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        tcp::write_frame(
            &mut conn,
            &Frame { kind: kind::NACK, payload: tcp::shard_ack_payload(42, 3) },
        )
        .unwrap();
        let reply = tcp::read_frame(&mut conn).unwrap();
        assert_eq!(reply.kind, kind::NACK_MISS);
        assert_eq!(tcp::parse_shard_ack(&reply.payload).unwrap(), (42, 3));
        assert_eq!(relay.nacks_unserviceable(), 1);
        assert_eq!(relay.nacks_serviced(), 0);
        relay.stop();
    }

    #[test]
    fn subscribe_gets_hop_reply() {
        let relay = Relay::start().unwrap();
        relay.set_hop(2);
        let mut conn = tcp::connect_local(relay.port).unwrap();
        for _ in 0..200 {
            if relay.subscriber_count() == 1 {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        tcp::write_frame(
            &mut conn,
            &Frame { kind: kind::SUBSCRIBE, payload: 0u64.to_le_bytes().to_vec() },
        )
        .unwrap();
        let reply = tcp::read_frame(&mut conn).unwrap();
        assert_eq!(reply.kind, kind::HOP);
        assert_eq!(tcp::parse_hop(&reply.payload).unwrap(), 2);
        relay.stop();
    }

    #[test]
    fn obs_snap_gets_live_snapshot() {
        let relay = Relay::start().unwrap();
        relay.set_hop(1);
        let mut conn = tcp::connect_local(relay.port).unwrap();
        for _ in 0..200 {
            if relay.subscriber_count() == 1 {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        tcp::write_frame(
            &mut conn,
            &Frame { kind: kind::OBS_SNAP, payload: tcp::obs_snap_payload(0) },
        )
        .unwrap();
        let reply = tcp::read_frame(&mut conn).unwrap();
        assert_eq!(reply.kind, kind::OBS_REPLY);
        let j = crate::util::json::Json::parse(&tcp::parse_obs_reply(&reply.payload).unwrap())
            .unwrap();
        assert_eq!(j.req_str("role").unwrap(), "relay");
        let c = j.get("counters").unwrap();
        assert_eq!(c.req_f64("hop").unwrap(), 1.0);
        assert_eq!(c.req_f64("subscribers").unwrap(), 1.0);
        assert!(j.get("histograms").unwrap().get("nack_repair_us").is_some());
        // flags bit 0 omitted → recorder summary only, no event dump
        assert!(j.get("recorder").unwrap().get("events").is_none());
        relay.stop();
    }

    /// A v3-shaped shard frame whose header peeks as (step, shard, S).
    fn shard_frame(step: u64, shard: u32, of: u32, tag: u8) -> Frame {
        let n = 2048usize;
        let layout = synthetic_layout(n, 64);
        let per = n as u64 / of as u64;
        let patch = Patch {
            step,
            base_step: step.saturating_sub(1),
            total_params: n as u64,
            indices: vec![shard as u64 * per],
            values: Values::Bf16(vec![tag as u16]),
            result_hash: "ab".repeat(32),
            chunk_elems: 64,
            shard_index: shard,
            shard_count: of,
            elem_offset: shard as u64 * per,
            elem_len: per,
            shard_root: "cd".repeat(32),
        };
        let bytes = container::encode(&patch, &layout, EncodeOpts::default()).unwrap();
        Frame { kind: kind::PATCH, payload: bytes }
    }

    #[test]
    fn nack_storm_collapses_to_one_escalation() {
        // six leaves NACK the same evicted (step, shard) slot inside
        // one backoff window: exactly ONE escalation goes upstream,
        // the other five ride it as suppressed, and the single
        // retransmit heals all six
        let relay = Relay::start().unwrap();
        let escalations = Arc::new(std::sync::atomic::AtomicU64::new(0));
        {
            let e = escalations.clone();
            relay.set_escalation(move |_, _| {
                e.fetch_add(1, Ordering::SeqCst);
                true // accepted; the test answers it explicitly
            });
        }
        // pin the window far past the test horizon so rider counting
        // cannot race a re-escalation
        relay.set_escalation_policy(RetryPolicy::new(
            std::time::Duration::from_secs(30),
            2.0,
            std::time::Duration::from_secs(30),
            std::time::Duration::from_secs(120),
        ));
        let mut conns: Vec<_> =
            (0..6).map(|_| tcp::connect_local(relay.port).unwrap()).collect();
        for _ in 0..400 {
            if relay.subscriber_count() == 6 {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        assert_eq!(relay.subscriber_count(), 6);
        for conn in &mut conns {
            tcp::write_frame(
                conn,
                &Frame { kind: kind::NACK, payload: tcp::shard_ack_payload(9, 1) },
            )
            .unwrap();
        }
        // readers are asynchronous: wait until every rider registered
        for _ in 0..400 {
            if relay.pending_riders(9, 1) == 6 {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        assert_eq!(relay.pending_riders(9, 1), 6, "all six must ride the slot");
        assert_eq!(escalations.load(Ordering::SeqCst), 1, "exactly one upstream NACK");
        assert_eq!(relay.nacks_escalated(), 1);
        assert_eq!(relay.nacks_suppressed(), 5);
        // one retransmit fans back to every rider
        let f = shard_frame(9, 1, 2, 3);
        assert!(relay.deliver_retransmit(9, 1, f.clone()));
        for conn in &mut conns {
            assert_eq!(tcp::read_frame(conn).unwrap(), f, "every rider must heal");
        }
        assert_eq!(relay.pending_riders(9, 1), 0);
        assert_eq!(relay.nacks_unserviceable(), 0);
        // the retransmit was re-indexed: the next NACK is served
        // locally, no new escalation
        tcp::write_frame(
            &mut conns[0],
            &Frame { kind: kind::NACK, payload: tcp::shard_ack_payload(9, 1) },
        )
        .unwrap();
        assert_eq!(tcp::read_frame(&mut conns[0]).unwrap(), f);
        assert_eq!(escalations.load(Ordering::SeqCst), 1);
        relay.stop();
    }

    #[test]
    fn nack_resends_only_to_requester() {
        let relay = Relay::start().unwrap();
        let mut a = tcp::connect_local(relay.port).unwrap();
        let mut b = tcp::connect_local(relay.port).unwrap();
        for _ in 0..200 {
            if relay.subscriber_count() == 2 {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        let f0 = shard_frame(7, 0, 2, 1);
        let f1 = shard_frame(7, 1, 2, 2);
        relay.publish(f0.clone());
        relay.publish(f1.clone());
        // both subscribers get the broadcast pair
        for conn in [&mut a, &mut b] {
            for expect in [&f0, &f1] {
                let f = tcp::read_frame(conn).unwrap();
                assert_eq!(&f, expect);
            }
        }
        // A NACKs shard 1 of step 7: only A receives the retransmit
        tcp::write_frame(
            &mut a,
            &Frame { kind: kind::NACK, payload: tcp::shard_ack_payload(7, 1) },
        )
        .unwrap();
        let resent = tcp::read_frame(&mut a).unwrap();
        assert_eq!(resent, f1, "requester must get exactly the NACKed shard");
        for _ in 0..100 {
            if relay.nacks_serviced() == 1 {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        assert_eq!(relay.nacks_serviced(), 1);
        // B's stream continues with the next broadcast, no duplicate
        relay.publish(frame(kind::CLOSE, 0));
        let next_b = tcp::read_frame(&mut b).unwrap();
        assert_eq!(next_b.kind, kind::CLOSE, "B must not see the retransmit");
        // a NACK for an unindexed slot is ignored, not fatal
        tcp::write_frame(
            &mut a,
            &Frame { kind: kind::NACK, payload: tcp::shard_ack_payload(99, 0) },
        )
        .unwrap();
        assert_eq!(tcp::read_frame(&mut a).unwrap().kind, kind::CLOSE);
        relay.stop();
    }

    // ── extracted state machines (shared with crate::sim) ──────────

    #[test]
    fn stage_machine_anchors_tails_and_evicts() {
        let mut st = RelayStage::new(2);
        let af = Arc::new(frame(kind::ANCHOR, 0xa));
        let pf = |tag| Arc::new(frame(kind::PATCH, tag));
        let mf = Arc::new(frame(kind::MARKER, 0xb));
        st.stage(&pf(1), Some((1, 0)));
        st.stage(&mf, None);
        assert_eq!(st.catchup_len(), 2, "patch + marker tail before any anchor");
        st.stage(&af, None);
        assert_eq!(st.catchup_len(), 1, "anchor supersedes the tail");
        assert!(st.lookup(1, 0).is_some(), "the index survives an anchor");
        // index bound: 2 distinct steps — staging a third evicts step 1
        st.stage(&pf(2), Some((2, 0)));
        st.stage(&pf(3), Some((3, 0)));
        assert!(st.lookup(1, 0).is_none(), "oldest step evicted past the bound");
        assert!(st.lookup(2, 0).is_some() && st.lookup(3, 0).is_some());
        let kinds: Vec<u8> = st.catchup().map(|f| f.kind).collect();
        assert_eq!(kinds, vec![kind::ANCHOR, kind::PATCH, kind::PATCH]);
    }

    #[test]
    fn coalesce_enqueue_matches_policy() {
        let mut st = RelayStage::new(INDEX_STEPS);
        st.stage(&Arc::new(frame(kind::ANCHOR, 0xa)), None);
        st.stage(&Arc::new(frame(kind::PATCH, 1)), None);
        let mut q: VecDeque<Arc<Frame>> = VecDeque::new();
        q.push_back(Arc::new(frame(kind::HOP, 0))); // control reply
        q.push_back(Arc::new(frame(kind::PATCH, 9)));
        // anchor: stream cleared, control survives after the anchor
        let (c, d) = coalesce_enqueue(&mut q, &Arc::new(frame(kind::ANCHOR, 0xa)), &st, 8);
        assert!(!c && d == 1);
        assert_eq!(q.iter().map(|f| f.kind).collect::<Vec<_>>(), vec![kind::ANCHOR, kind::HOP]);
        // overflow at depth 2: queue becomes catch-up bundle + control
        let (c, d) = coalesce_enqueue(&mut q, &Arc::new(frame(kind::PATCH, 2)), &st, 2);
        assert!(c, "overflow must coalesce");
        assert_eq!(d, 1, "the queued anchor is superseded by the bundle");
        assert_eq!(
            q.iter().map(|f| f.kind).collect::<Vec<_>>(),
            vec![kind::ANCHOR, kind::PATCH, kind::HOP],
            "bundle (anchor+tail) then surviving control frames"
        );
    }

    #[test]
    fn escalation_ledger_windows_and_riders() {
        use std::time::Duration;
        let mut led: EscalationLedger<u64> =
            EscalationLedger::new(RetryPolicy::escalate_default().with_seed(1));
        let t0 = Duration::from_secs(1);
        assert!(led.on_nack(5, 0, 10, |a, b| a == b, t0), "first NACK escalates");
        // in-window re-NACKs (same or other rider) are suppressed
        assert!(!led.on_nack(5, 0, 10, |a, b| a == b, t0 + Duration::from_millis(1)));
        assert!(!led.on_nack(5, 0, 11, |a, b| a == b, t0 + Duration::from_millis(2)));
        assert_eq!(led.riders(5, 0), 2, "riders dedup by identity");
        // past the first window (≤ 250ms jittered) the slot re-escalates
        assert!(led.on_nack(5, 0, 10, |a, b| a == b, t0 + Duration::from_millis(300)));
        assert_eq!(led.riders(5, 0), 2);
        let riders = led.resolve(5, 0).unwrap();
        assert_eq!(riders, vec![10, 11]);
        assert_eq!(led.pending_slots(), 0);
        assert!(led.resolve(5, 0).is_none(), "resolve is one-shot");
    }
}
