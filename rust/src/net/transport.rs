//! `SyncTransport`: one sync plane over interchangeable fabrics.
//!
//! PULSESync's protocol (paper Alg. 5 + §J) is fabric-agnostic: a
//! producer stores *frames* (delta containers, shard frames, anchor
//! objects) and then commits each step with a *ready marker*; a
//! consumer discovers committed steps, fetches their frames, and
//! verifies them against the hash-tree commitments the frames carry.
//! This module turns that contract into a trait so the same
//! `Publisher`/`Consumer` state machines ([`crate::pulse::sync`]) run
//! unchanged over an S3-like object store, a TCP relay, an in-process
//! staging map, or any of those wrapped in deterministic fault
//! injection.
//!
//! # The contract
//!
//! * **Commit ordering.** A producer publishes every frame of a step
//!   *before* its marker ([`SyncTransport::publish_marker`]). A step
//!   listed by [`SyncTransport::latest_ready`] is committed: its
//!   marker has landed. Fetching a committed step's data may still
//!   fail (retention, relay coalescing, corruption) — the consumer
//!   treats any fetch or verification failure as a signal to degrade
//!   to the anchor slow path, so a backend never has to guarantee
//!   perfect delivery, only eventual anchor availability.
//! * **Integrity is end-to-end, not transport-level.** Frames carry
//!   their own hash-tree commitments; a backend may deliver corrupted
//!   bytes and the consumer heals (per-shard refetch, then anchor
//!   fallback). [`SyncTransport::fetch_shard`] is the designated
//!   repair seam: calling it again for the same `(step, shard)` asks
//!   the backend for a *fresh* copy (the relay backend turns that into
//!   a NACK retransmit; stores simply re-read).
//! * **Markers are opaque strings** with the same grammar on every
//!   backend: a bare 64-hex root for an unsharded delta,
//!   `v3:<shards>:<root>` for a sharded step
//!   ([`sharded_marker`]/[`parse_sharded_marker`]), and
//!   `v2:<chunk_elems>:<root>` (or a legacy bare scalar hash) for
//!   anchors. Any of them may carry an optional `g<gen>;` prefix — the
//!   publisher generation ([`split_generation`]); its absence means
//!   generation 0, so pre-generation stores stay readable.
//!
//! # Adding a backend
//!
//! Implement the seven methods; the conformance suite
//! (`rust/tests/integration_transport.rs`) is generic over
//! `T: SyncTransport` — run your backend through it to inherit the
//! bit-identity, chain/slow-path, and corruption-recovery checks. The
//! split between producer-side and consumer-side methods is
//! intentional: symmetric backends ([`ObjectStoreTransport`],
//! [`InProcTransport`]) implement both on one value; directional
//! fabrics ([`RelayTransport`]) construct per-role values whose
//! wrong-side methods error. [`RelayTransport::subscribe`] works
//! unchanged against a root relay or any chained
//! [`crate::net::node::RelayNode`], so the chained topology rides the
//! same conformance suite as the flat backends.

use crate::net::relay::Relay;
use crate::net::tcp::{self, kind, Frame};
use crate::sparse::container;
use crate::storage::retention::{self, Inventory, RetentionPolicy};
use crate::storage::ObjectStore;
use crate::util::retry::RetryPolicy;
use crate::util::rng::splitmix64;
use crate::util::sync::{CondvarExt, LockExt};
use anyhow::{bail, Context, Result};
use std::collections::{BTreeMap, HashSet};
use std::net::Shutdown;
use std::net::TcpStream;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Upper bound on the shard count accepted from untrusted markers and
/// headers (a corrupted marker must not drive per-shard allocations).
pub const MAX_SHARDS: u32 = 4096;

/// Marker substring carried by the error [`RelayTransport::fetch_shard`]
/// returns when the relay answered a repair NACK with NACK_MISS (the
/// slot is evicted along the whole path to the publisher). Detected
/// with [`is_unserviceable`], which only relies on error formatting so
/// it survives `.context()` wrapping and an `anyhow` swap alike.
pub const UNSERVICEABLE_MARK: &str = "retransmit unserviceable";

/// True when `e` (anywhere in its context chain) reports an
/// unserviceable shard repair — the consumer should stop retrying the
/// slot and recover via the anchor slow path.
pub fn is_unserviceable(e: &anyhow::Error) -> bool {
    format!("{:#}", e).contains(UNSERVICEABLE_MARK)
}

// ---------------------------------------------------------------- keys

/// Object key of an unsharded delta container (store-plane layout).
pub fn delta_key(step: u64) -> String {
    format!("delta_{:08}.bin", step)
}
/// Object key of one shard frame of a sharded step.
pub fn delta_shard_key(step: u64, shard: u32) -> String {
    format!("delta_{:08}.s{:03}.bin", step, shard)
}
/// Ready-marker key committing a delta step.
pub fn delta_ready_key(step: u64) -> String {
    format!("delta_ready_{}", step)
}
/// Object key of a full anchor checkpoint.
pub fn anchor_key(step: u64) -> String {
    format!("anchor_{:08}.bin", step)
}
/// Ready-marker key committing an anchor.
pub fn anchor_ready_key(step: u64) -> String {
    format!("anchor_ready_{}", step)
}

/// Sharded delta ready-marker payload: `v3:<shard_count>:<root_hex>`.
pub fn sharded_marker(shard_count: u32, root: &str) -> String {
    format!("v3:{}:{}", shard_count, root)
}

/// Split an optional publisher-generation prefix off a marker:
/// `g<n>;<body>` yields `(n, body)`, anything else `(0, whole)`.
///
/// The prefix is how a restarted publisher ([`crate::pulse::sync`])
/// tags everything it commits after resuming from the latest anchor,
/// so consumers can tell a rewound-and-republished step from the
/// original. `g` is not a hex digit, so the prefix can never collide
/// with a bare-root marker; a malformed prefix is treated as body (the
/// downstream grammar then rejects it).
pub fn split_generation(marker: &str) -> (u64, &str) {
    if let Some(rest) = marker.strip_prefix('g') {
        if let Some((num, body)) = rest.split_once(';') {
            if !num.is_empty() && num.bytes().all(|b| b.is_ascii_digit()) {
                if let Ok(g) = num.parse::<u64>() {
                    return (g, body);
                }
            }
        }
    }
    (0, marker)
}

/// Parse a sharded delta marker; `None` for unsharded (bare-root)
/// markers or anything malformed / out of the trusted shard range.
pub fn parse_sharded_marker(s: &str) -> Option<(u32, &str)> {
    let rest = s.strip_prefix("v3:")?;
    let (count, root) = rest.split_once(':')?;
    let count: u32 = count.parse().ok()?;
    if !(2..=MAX_SHARDS).contains(&count) || root.len() != 64 {
        return None;
    }
    Some((count, root))
}

// --------------------------------------------------------------- types

/// Address of one stored frame on the sync plane.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum FrameId {
    /// Unsharded delta container for a step.
    Delta { step: u64 },
    /// One shard frame of a sharded step.
    Shard { step: u64, shard: u32 },
    /// Full anchor object for a step.
    Anchor { step: u64 },
}

impl FrameId {
    /// The store-plane object key for this frame.
    pub fn object_key(&self) -> String {
        match *self {
            FrameId::Delta { step } => delta_key(step),
            FrameId::Shard { step, shard } => delta_shard_key(step, shard),
            FrameId::Anchor { step } => anchor_key(step),
        }
    }

    pub fn step(&self) -> u64 {
        match *self {
            FrameId::Delta { step }
            | FrameId::Shard { step, .. }
            | FrameId::Anchor { step } => step,
        }
    }

    fn is_anchor(&self) -> bool {
        matches!(self, FrameId::Anchor { .. })
    }
}

/// Address of a ready marker.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum MarkerId {
    Delta(u64),
    Anchor(u64),
}

impl MarkerId {
    pub fn object_key(&self) -> String {
        match *self {
            MarkerId::Delta(step) => delta_ready_key(step),
            MarkerId::Anchor(step) => anchor_ready_key(step),
        }
    }

    pub fn step(&self) -> u64 {
        match *self {
            MarkerId::Delta(s) | MarkerId::Anchor(s) => s,
        }
    }

    pub fn is_anchor(&self) -> bool {
        matches!(self, MarkerId::Anchor(_))
    }
}

/// What [`SyncTransport::fetch_step`] returns for a committed step.
#[derive(Debug, Clone, PartialEq)]
pub enum StepData {
    /// Unsharded delta: the container object (v1/v2).
    Whole(Vec<u8>),
    /// Sharded step: parsed `v3` marker; frames come via
    /// [`SyncTransport::fetch_shard`].
    Sharded { shard_count: u32, root: String },
}

/// Snapshot of a backend's operation counters — the observability
/// surface the regression tests (single inventory scan per
/// synchronize) and [`crate::coordinator::metrics::TransportMeter`]
/// read.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct TransportCounters {
    pub inventory_scans: u64,
    pub frames_published: u64,
    pub bytes_published: u64,
    pub markers_published: u64,
    pub frames_fetched: u64,
    pub bytes_fetched: u64,
    /// Relay backend only: shard retransmits requested.
    pub nacks_sent: u64,
    /// Relay backend only: NACKs answered with NACK_MISS — the slot
    /// was evicted along the whole relay path, so the repair degraded
    /// to the anchor slow path.
    pub nacks_unserviceable: u64,
    /// Recovery attempts re-issued on a [`RetryPolicy`] backoff
    /// boundary (NACK re-sends, supervisor re-connects) — 0 on a
    /// healthy fabric.
    pub retries: u64,
    /// Recovery sequences that drained their whole retry budget and
    /// abandoned the slot (the consumer then degrades to the anchor
    /// slow path).
    pub gave_up: u64,
    /// Duplicate repair requests absorbed by in-flight dedup instead
    /// of reaching the wire (client side: concurrent fetches of one
    /// slot ride a single outstanding NACK).
    pub nack_suppressed: u64,
    /// Fault decorator only: faults actually injected.
    pub faults_injected: u64,
    /// Control-plane fabrics only: times the subscription was
    /// re-parented onto a new upstream relay (failover or replan);
    /// 0 for statically-wired backends.
    pub reparents: u64,
    /// Control-plane fabrics only: the topology epoch this peer last
    /// accepted (0 for statically-wired backends, which never replan).
    pub epoch: u64,
    /// Store plane only: GETs answered from a cache (a `CachingStore`
    /// hop or a revalidated local entry) without an origin body read.
    pub cache_hits: u64,
    /// Store plane only: GETs that had to go past every cache.
    pub cache_misses: u64,
    /// Store plane only: object bodies actually pulled from the
    /// origin — the egress the caching tree exists to bound.
    pub origin_fetches: u64,
    /// Store plane only: conditional GETs answered NOT_MODIFIED (the
    /// ETag — the container's hash-tree root — still matched).
    pub conditional_not_modified: u64,
}

#[derive(Default)]
struct CounterCell {
    inventory_scans: AtomicU64,
    frames_published: AtomicU64,
    bytes_published: AtomicU64,
    markers_published: AtomicU64,
    frames_fetched: AtomicU64,
    bytes_fetched: AtomicU64,
    nacks_sent: AtomicU64,
    nacks_unserviceable: AtomicU64,
    retries: AtomicU64,
    gave_up: AtomicU64,
    nack_suppressed: AtomicU64,
}

impl CounterCell {
    fn snapshot(&self) -> TransportCounters {
        TransportCounters {
            inventory_scans: self.inventory_scans.load(Ordering::Relaxed),
            frames_published: self.frames_published.load(Ordering::Relaxed),
            bytes_published: self.bytes_published.load(Ordering::Relaxed),
            markers_published: self.markers_published.load(Ordering::Relaxed),
            frames_fetched: self.frames_fetched.load(Ordering::Relaxed),
            bytes_fetched: self.bytes_fetched.load(Ordering::Relaxed),
            nacks_sent: self.nacks_sent.load(Ordering::Relaxed),
            nacks_unserviceable: self.nacks_unserviceable.load(Ordering::Relaxed),
            retries: self.retries.load(Ordering::Relaxed),
            gave_up: self.gave_up.load(Ordering::Relaxed),
            nack_suppressed: self.nack_suppressed.load(Ordering::Relaxed),
            faults_injected: 0,
            reparents: 0,
            epoch: 0,
            cache_hits: 0,
            cache_misses: 0,
            origin_fetches: 0,
            conditional_not_modified: 0,
        }
    }

    fn bump(&self, c: &AtomicU64) {
        c.fetch_add(1, Ordering::Relaxed);
    }

    fn fetched(&self, bytes: usize) {
        self.frames_fetched.fetch_add(1, Ordering::Relaxed);
        self.bytes_fetched.fetch_add(bytes as u64, Ordering::Relaxed);
    }

    fn published(&self, bytes: usize) {
        self.frames_published.fetch_add(1, Ordering::Relaxed);
        self.bytes_published.fetch_add(bytes as u64, Ordering::Relaxed);
    }
}

// --------------------------------------------------------------- trait

/// One sync plane over interchangeable fabrics (see module docs for
/// the contract). Producer-side methods: [`Self::publish_frame`],
/// [`Self::publish_marker`]. Consumer-side: [`Self::latest_ready`],
/// [`Self::fetch_step`], [`Self::fetch_shard`], [`Self::fetch_anchor`].
pub trait SyncTransport: Send + Sync {
    /// Stable backend label (used in stats rows and bench names).
    fn name(&self) -> &'static str;

    /// Store one frame. Must complete before the step's marker is
    /// published; concurrent calls for different frames of one step
    /// are allowed (the sharded fan-out uploads shards in parallel).
    fn publish_frame(&self, id: FrameId, bytes: &[u8]) -> Result<()>;

    /// Commit a step by publishing its ready marker (see module docs
    /// for the marker grammar).
    fn publish_marker(&self, id: MarkerId, payload: &str) -> Result<()>;

    /// One snapshot of committed steps — a single backend scan serves
    /// both the head lookup and the slow-path anchor choice.
    fn latest_ready(&self) -> Result<Inventory>;

    /// A committed step's delta descriptor; `Ok(None)` when the step
    /// has no delta marker (a §J.5 anchor replaced the delta).
    fn fetch_step(&self, step: u64) -> Result<Option<StepData>>;

    /// One shard frame of a sharded step. Calling again for the same
    /// slot requests a fresh copy (the repair seam).
    fn fetch_shard(&self, step: u64, shard: u32) -> Result<Vec<u8>>;

    /// A committed anchor: `(object bytes, marker payload)`.
    fn fetch_anchor(&self, step: u64) -> Result<(Vec<u8>, String)>;

    /// Operation counters (zero for backends that don't track them).
    fn counters(&self) -> TransportCounters {
        TransportCounters::default()
    }
}

// -------------------------------------------------- ObjectStoreTransport

/// The paper's deployment fabric (§E.1): frames and markers are
/// objects under `prefix/` in an S3-like [`ObjectStore`], committed
/// steps are discovered by scanning ready markers
/// ([`retention::scan`]). This wraps exactly the key scheme the
/// pre-trait `Publisher`/`Consumer` used, so stores written before the
/// refactor remain readable.
#[derive(Clone)]
pub struct ObjectStoreTransport {
    pub store: ObjectStore,
    pub prefix: String,
    counters: Arc<CounterCell>,
}

impl ObjectStoreTransport {
    pub fn new(store: ObjectStore, prefix: &str) -> ObjectStoreTransport {
        ObjectStoreTransport {
            store,
            prefix: prefix.trim_end_matches('/').to_string(),
            counters: Arc::new(CounterCell::default()),
        }
    }

    fn key(&self, k: String) -> String {
        format!("{}/{}", self.prefix, k)
    }
}

impl SyncTransport for ObjectStoreTransport {
    fn name(&self) -> &'static str {
        "object-store"
    }

    fn publish_frame(&self, id: FrameId, bytes: &[u8]) -> Result<()> {
        self.store.put(&self.key(id.object_key()), bytes)?;
        self.counters.published(bytes.len());
        Ok(())
    }

    fn publish_marker(&self, id: MarkerId, payload: &str) -> Result<()> {
        self.store.put(&self.key(id.object_key()), payload.as_bytes())?;
        self.counters.bump(&self.counters.markers_published);
        Ok(())
    }

    fn latest_ready(&self) -> Result<Inventory> {
        self.counters.bump(&self.counters.inventory_scans);
        retention::scan(&self.store, &self.prefix)
    }

    fn fetch_step(&self, step: u64) -> Result<Option<StepData>> {
        // a missing marker is the §J.5 "anchor replaced the delta"
        // signal, not a transport failure
        let marker = match self.store.get(&self.key(delta_ready_key(step))) {
            Ok(m) => String::from_utf8_lossy(&m).into_owned(),
            Err(_) => return Ok(None),
        };
        let (_, marker) = split_generation(&marker);
        if let Some((shard_count, root)) = parse_sharded_marker(marker) {
            return Ok(Some(StepData::Sharded { shard_count, root: root.to_string() }));
        }
        let obj = self.store.get(&self.key(delta_key(step)))?;
        self.counters.fetched(obj.len());
        Ok(Some(StepData::Whole(obj)))
    }

    fn fetch_shard(&self, step: u64, shard: u32) -> Result<Vec<u8>> {
        let obj = self
            .store
            .get(&self.key(delta_shard_key(step, shard)))
            .with_context(|| format!("shard {} of step {}", shard, step))?;
        self.counters.fetched(obj.len());
        Ok(obj)
    }

    fn fetch_anchor(&self, step: u64) -> Result<(Vec<u8>, String)> {
        let obj = self
            .store
            .get(&self.key(anchor_key(step)))
            .with_context(|| format!("anchor {}", step))?;
        let marker = String::from_utf8_lossy(&self.store.get(&self.key(anchor_ready_key(step)))?)
            .into_owned();
        self.counters.fetched(obj.len());
        Ok((obj, marker))
    }

    fn counters(&self) -> TransportCounters {
        self.counters.snapshot()
    }
}

// ------------------------------------------------------ InProcTransport

/// Zero-I/O in-memory backend for tests and benches: a bounded staging
/// window shared by every clone (producer and consumer hold clones of
/// one value). The window is the channel bound: once more than
/// `max_deltas` committed steps are staged, the oldest are evicted
/// under [`retention::plan`] semantics — a consumer that falls behind
/// the window recovers via the anchor slow path, exactly like store
/// retention or relay coalescing.
#[derive(Clone)]
pub struct InProcTransport {
    state: Arc<Mutex<InProcState>>,
    counters: Arc<CounterCell>,
    max_deltas: usize,
    max_anchors: usize,
}

#[derive(Default)]
struct InProcState {
    deltas: BTreeMap<u64, Vec<u8>>,
    shards: BTreeMap<(u64, u32), Vec<u8>>,
    anchors: BTreeMap<u64, Vec<u8>>,
    delta_markers: BTreeMap<u64, String>,
    anchor_markers: BTreeMap<u64, String>,
}

impl InProcTransport {
    /// Default window: 1024 delta steps, 16 anchors.
    pub fn new() -> InProcTransport {
        InProcTransport::with_window(1024, 16)
    }

    /// Explicit staging bounds (≥ 1 each).
    pub fn with_window(max_deltas: usize, max_anchors: usize) -> InProcTransport {
        InProcTransport {
            state: Arc::new(Mutex::new(InProcState::default())),
            counters: Arc::new(CounterCell::default()),
            max_deltas: max_deltas.max(1),
            max_anchors: max_anchors.max(1),
        }
    }

    fn evict(&self, st: &mut InProcState) {
        if st.delta_markers.len() <= self.max_deltas
            && st.anchor_markers.len() <= self.max_anchors
        {
            return;
        }
        let inv = Inventory {
            delta_steps: st.delta_markers.keys().copied().collect(),
            anchor_steps: st.anchor_markers.keys().copied().collect(),
        };
        let policy =
            RetentionPolicy { max_deltas: self.max_deltas, max_anchors: self.max_anchors };
        let (drop_deltas, drop_anchors) = retention::plan(&inv, policy);
        let dropped: HashSet<u64> = drop_deltas.iter().copied().collect();
        for s in &drop_deltas {
            st.deltas.remove(s);
            st.delta_markers.remove(s);
        }
        if !dropped.is_empty() {
            st.shards.retain(|(s, _), _| !dropped.contains(s));
        }
        for s in &drop_anchors {
            st.anchors.remove(s);
            st.anchor_markers.remove(s);
        }
    }
}

impl Default for InProcTransport {
    fn default() -> Self {
        InProcTransport::new()
    }
}

impl SyncTransport for InProcTransport {
    fn name(&self) -> &'static str {
        "in-proc"
    }

    fn publish_frame(&self, id: FrameId, bytes: &[u8]) -> Result<()> {
        let mut st = self.state.plock();
        match id {
            FrameId::Delta { step } => {
                st.deltas.insert(step, bytes.to_vec());
            }
            FrameId::Shard { step, shard } => {
                st.shards.insert((step, shard), bytes.to_vec());
            }
            FrameId::Anchor { step } => {
                st.anchors.insert(step, bytes.to_vec());
            }
        }
        self.counters.published(bytes.len());
        Ok(())
    }

    fn publish_marker(&self, id: MarkerId, payload: &str) -> Result<()> {
        let mut st = self.state.plock();
        match id {
            MarkerId::Delta(step) => {
                st.delta_markers.insert(step, payload.to_string());
            }
            MarkerId::Anchor(step) => {
                st.anchor_markers.insert(step, payload.to_string());
            }
        }
        self.evict(&mut st);
        self.counters.bump(&self.counters.markers_published);
        Ok(())
    }

    fn latest_ready(&self) -> Result<Inventory> {
        self.counters.bump(&self.counters.inventory_scans);
        let st = self.state.plock();
        Ok(Inventory {
            delta_steps: st.delta_markers.keys().copied().collect(),
            anchor_steps: st.anchor_markers.keys().copied().collect(),
        })
    }

    fn fetch_step(&self, step: u64) -> Result<Option<StepData>> {
        let st = self.state.plock();
        let marker = match st.delta_markers.get(&step) {
            Some(m) => m.clone(),
            None => return Ok(None),
        };
        let (_, marker) = split_generation(&marker);
        if let Some((shard_count, root)) = parse_sharded_marker(marker) {
            return Ok(Some(StepData::Sharded { shard_count, root: root.to_string() }));
        }
        let obj = st
            .deltas
            .get(&step)
            .cloned()
            .ok_or_else(|| anyhow::anyhow!("delta object for step {} not staged", step))?;
        self.counters.fetched(obj.len());
        Ok(Some(StepData::Whole(obj)))
    }

    fn fetch_shard(&self, step: u64, shard: u32) -> Result<Vec<u8>> {
        let st = self.state.plock();
        let obj = st
            .shards
            .get(&(step, shard))
            .cloned()
            .with_context(|| format!("shard {} of step {}", shard, step))?;
        self.counters.fetched(obj.len());
        Ok(obj)
    }

    fn fetch_anchor(&self, step: u64) -> Result<(Vec<u8>, String)> {
        let st = self.state.plock();
        let obj = st
            .anchors
            .get(&step)
            .cloned()
            .with_context(|| format!("anchor {}", step))?;
        let marker = st
            .anchor_markers
            .get(&step)
            .cloned()
            .with_context(|| format!("anchor marker {}", step))?;
        self.counters.fetched(obj.len());
        Ok((obj, marker))
    }

    fn counters(&self) -> TransportCounters {
        self.counters.snapshot()
    }
}

// ------------------------------------------------------- RelayTransport

/// The TCP relay fabric (paper Fig. 5), pull-shaped: the producer role
/// pushes frames/markers into an in-process [`Relay`]; the subscriber
/// role connects over TCP, stages everything a background receiver
/// thread reads, and answers the consumer-side trait methods from that
/// staging. A second [`SyncTransport::fetch_shard`] call for the same
/// slot sends a NACK and waits for the relay's per-subscriber
/// retransmit — the wire realization of the repair seam. This promotes
/// the wiring that used to live only in `examples/live_sync.rs` into
/// the library.
pub struct RelayTransport {
    role: RelayRole,
}

enum RelayRole {
    Publisher { relay: Arc<Relay>, counters: Arc<CounterCell> },
    Subscriber(Box<Subscriber>),
}

struct Subscriber {
    state: Arc<(Mutex<SubState>, Condvar)>,
    /// Write half for NACKs (the receiver thread owns the read half).
    conn: Mutex<TcpStream>,
    reader: Option<std::thread::JoinHandle<()>>,
    counters: Arc<CounterCell>,
    /// Backoff/budget for the NACK repair seam
    /// ([`RetryPolicy::nack_default`] unless overridden).
    nack_policy: RetryPolicy,
}

#[derive(Default)]
struct SubState {
    deltas: BTreeMap<u64, DeltaStage>,
    anchors: BTreeMap<u64, AnchorStage>,
    /// Slots already served once: a second fetch means "repair".
    /// Pruned when an anchor supersedes the steps (and capped as a
    /// backstop) — NOT when a step is merely evicted from `deltas`:
    /// eviction forgets the frames, not the serves.
    served: HashSet<(u64, u32)>,
    /// Slots whose repair NACK the relay answered with NACK_MISS; a
    /// waiting `fetch_shard` consumes its entry and errors out so the
    /// consumer degrades to the anchor slow path immediately.
    unserviceable: HashSet<(u64, u32)>,
    /// Slots with a NACK currently outstanding on the wire: concurrent
    /// fetches of the same slot ride the first one's answer instead of
    /// multiplying repair traffic (counted as `nack_suppressed`).
    nack_inflight: HashSet<(u64, u32)>,
    /// Relay hops between this subscriber and the publisher (from the
    /// HOP reply to our SUBSCRIBE; None until it arrives).
    hops: Option<u32>,
    closed: bool,
    /// True only when the stream ended in a SOCKET ERROR; an orderly
    /// CLOSE frame leaves it false. Control-plane supervisors
    /// re-subscribe on failure, never on an orderly end-of-stream.
    failed: bool,
}

impl SubState {
    /// A complete anchor at `anchor_step` supersedes every delta at or
    /// below it (the slow path restarts from the newest anchor), so
    /// their staged frames — and their served-slot bookkeeping — can
    /// go. This is what keeps a long-running subscriber's memory
    /// bounded by the anchor interval instead of the stream length.
    fn prune_superseded(&mut self, anchor_step: u64) {
        self.deltas.retain(|&s, _| s > anchor_step);
        self.served.retain(|&(s, _)| s > anchor_step);
        self.unserviceable.retain(|&(s, _)| s > anchor_step);
    }

    /// Enforce the staging window after an insert.
    fn trim(&mut self) {
        self.trim_to(STAGE_STEPS, STAGE_ANCHORS, SERVED_CAP);
    }

    /// Window enforcement with explicit bounds (tests shrink them).
    ///
    /// `served` deliberately survives delta eviction: a step that is
    /// evicted and later *restaged* (a late retransmit) must still
    /// treat the next fetch of an already-served slot as a repair —
    /// pruning `served` to the staged minimum (the old behavior) lost
    /// that bookkeeping, so the repair silently became a "first serve"
    /// of the stale staged bytes and no NACK was ever sent. Anchor
    /// pruning (`prune_superseded`) is what bounds `served` in any
    /// anchored stream; `served_cap` is a backstop for anchor-free
    /// streams, dropping the lowest (oldest) steps first.
    fn trim_to(&mut self, max_steps: usize, max_anchors: usize, served_cap: usize) {
        while self.deltas.len() > max_steps {
            self.deltas.pop_first();
        }
        while self.anchors.len() > max_anchors {
            self.anchors.pop_first();
        }
        if self.served.len() > served_cap {
            let mut steps: Vec<u64> = self.served.iter().map(|&(s, _)| s).collect();
            steps.sort_unstable();
            let cut = steps[steps.len() / 2];
            self.served.retain(|&(s, _)| s > cut);
            self.unserviceable.retain(|&(s, _)| s > cut);
        }
    }
}

#[derive(Default)]
struct DeltaStage {
    marker: Option<String>,
    /// shard index → (frame bytes, arrival generation).
    frames: BTreeMap<u32, (Vec<u8>, u64)>,
}

#[derive(Default)]
struct AnchorStage {
    marker: Option<String>,
    object: Option<Vec<u8>>,
}

impl DeltaStage {
    /// Shards this step's marker promises (1 for unsharded).
    fn expected_shards(&self) -> Option<u32> {
        let m = self.marker.as_deref()?;
        let (_, m) = split_generation(m);
        Some(parse_sharded_marker(m).map(|(s, _)| s).unwrap_or(1))
    }

    fn complete(&self) -> bool {
        match self.expected_shards() {
            Some(s) => (0..s).all(|i| self.frames.contains_key(&i)),
            None => false,
        }
    }
}

/// Staged delta steps retained by a subscriber before the oldest are
/// dropped (a consumer that lags further recovers via the anchor).
const STAGE_STEPS: usize = 4096;
const STAGE_ANCHORS: usize = 32;
/// Backstop bound on served-slot bookkeeping for anchor-free streams
/// (anchored streams are pruned by `prune_superseded` long before).
const SERVED_CAP: usize = 8 * STAGE_STEPS;

impl RelayTransport {
    /// Producer role over an in-process relay handle.
    pub fn publisher(relay: Arc<Relay>) -> RelayTransport {
        RelayTransport {
            role: RelayRole::Publisher { relay, counters: Arc::new(CounterCell::default()) },
        }
    }

    /// Subscriber role: connect to a relay port and start staging.
    /// Works unchanged against a root [`Relay`] or a chained
    /// [`crate::net::node::RelayNode`] — the subscriber cannot tell
    /// (and need not care) how deep in the tree its relay sits; the
    /// HOP reply to the SUBSCRIBE handshake reports it for metrics.
    pub fn subscribe(port: u16) -> Result<RelayTransport> {
        let mut stream = tcp::connect_local(port)?;
        tcp::write_frame(
            &mut stream,
            &Frame { kind: kind::SUBSCRIBE, payload: 0u64.to_le_bytes().to_vec() },
        )
        .context("subscribe handshake")?;
        let rstream = stream.try_clone()?;
        let state: Arc<(Mutex<SubState>, Condvar)> = Arc::new(Default::default());
        let reader = spawn_receiver(rstream, state.clone());
        Ok(RelayTransport {
            role: RelayRole::Subscriber(Box::new(Subscriber {
                state,
                conn: Mutex::new(stream),
                reader: Some(reader),
                counters: Arc::new(CounterCell::default()),
                nack_policy: RetryPolicy::nack_default(),
            })),
        })
    }

    /// Subscriber role: override the NACK repair backoff/budget
    /// (chaos tests shrink it; latency-sensitive deployments tune it).
    pub fn set_nack_policy(&mut self, policy: RetryPolicy) -> Result<()> {
        match &mut self.role {
            RelayRole::Subscriber(sub) => {
                sub.nack_policy = policy;
                Ok(())
            }
            RelayRole::Publisher { .. } => {
                bail!("publisher-side relay transport has no NACK policy")
            }
        }
    }

    /// Publisher role: broadcast an orderly end-of-stream.
    pub fn close(&self) {
        if let RelayRole::Publisher { relay, .. } = &self.role {
            relay.publish(Frame { kind: kind::CLOSE, payload: Vec::new() });
        }
    }

    /// Subscriber role: true once the stream ended (CLOSE or socket
    /// error). Always false for the producer role.
    pub fn stream_closed(&self) -> bool {
        match &self.role {
            RelayRole::Subscriber(sub) => sub.state.0.plock().closed,
            RelayRole::Publisher { .. } => false,
        }
    }

    /// Subscriber role: true only when the stream died on a SOCKET
    /// ERROR — an orderly CLOSE leaves this false. The control plane's
    /// leaf supervisor re-subscribes on this, so an orderly
    /// end-of-stream is never mistaken for a dead relay.
    pub fn stream_failed(&self) -> bool {
        match &self.role {
            RelayRole::Subscriber(sub) => sub.state.0.plock().failed,
            RelayRole::Publisher { .. } => false,
        }
    }

    /// Relay hops between this peer and the publisher: `Some(0)` for
    /// the producer role (it feeds the root relay in-process); for a
    /// subscriber, the upstream relay's depth + 1 once the HOP reply
    /// to the SUBSCRIBE handshake has arrived (None before that).
    pub fn hops(&self) -> Option<u32> {
        match &self.role {
            RelayRole::Subscriber(sub) => sub.state.0.plock().hops,
            RelayRole::Publisher { .. } => Some(0),
        }
    }

    fn pub_side(&self) -> Result<(&Arc<Relay>, &Arc<CounterCell>)> {
        match &self.role {
            RelayRole::Publisher { relay, counters } => Ok((relay, counters)),
            RelayRole::Subscriber(_) => {
                bail!("subscriber-side relay transport cannot publish")
            }
        }
    }

    fn sub_side(&self) -> Result<&Subscriber> {
        match &self.role {
            RelayRole::Subscriber(sub) => Ok(sub),
            RelayRole::Publisher { .. } => {
                bail!("publisher-side relay transport cannot fetch")
            }
        }
    }
}

impl Drop for RelayTransport {
    fn drop(&mut self) {
        if let RelayRole::Subscriber(sub) = &mut self.role {
            let _ = sub.conn.plock().shutdown(Shutdown::Both);
            if let Some(h) = sub.reader.take() {
                let _ = h.join();
            }
        }
    }
}

/// Background receiver: stages PATCH/ANCHOR/MARKER frames from the
/// relay stream. Frames identify themselves (container header / PLSA
/// anchor header / marker payload), so arrival order within a step
/// does not matter.
fn spawn_receiver(
    mut stream: TcpStream,
    state: Arc<(Mutex<SubState>, Condvar)>,
) -> std::thread::JoinHandle<()> {
    std::thread::spawn(move || loop {
        let frame = match tcp::read_frame(&mut stream) {
            Ok(f) => f,
            Err(_) => {
                let (lock, cv) = &*state;
                let mut st = lock.plock();
                st.closed = true;
                st.failed = true;
                drop(st);
                cv.notify_all();
                return;
            }
        };
        let (lock, cv) = &*state;
        match frame.kind {
            kind::PATCH => {
                if let Ok(meta) = container::peek_meta(&frame.payload) {
                    let mut st = lock.plock();
                    let stage = st.deltas.entry(meta.step).or_default();
                    let generation = stage
                        .frames
                        .get(&meta.shard_index)
                        .map(|(_, g)| *g)
                        .unwrap_or(0)
                        + 1;
                    stage.frames.insert(meta.shard_index, (frame.payload, generation));
                    st.trim();
                    cv.notify_all();
                }
            }
            kind::ANCHOR => {
                // anchors travel as the store-plane PLSA object, so the
                // step rides in the header
                if frame.payload.len() >= 20 && &frame.payload[0..4] == b"PLSA" {
                    let Ok(step_bytes) = <[u8; 8]>::try_from(&frame.payload[4..12]) else {
                        continue;
                    };
                    let step = u64::from_le_bytes(step_bytes);
                    let mut st = lock.plock();
                    let stage = st.anchors.entry(step).or_default();
                    stage.object = Some(frame.payload);
                    if stage.marker.is_some() {
                        st.prune_superseded(step);
                    }
                    st.trim();
                    cv.notify_all();
                }
            }
            kind::MARKER => {
                if let Ok((is_anchor, step, marker)) = tcp::parse_marker_frame(&frame.payload) {
                    let mut st = lock.plock();
                    if is_anchor {
                        let stage = st.anchors.entry(step).or_default();
                        stage.marker = Some(marker);
                        if stage.object.is_some() {
                            st.prune_superseded(step);
                        }
                    } else {
                        st.deltas.entry(step).or_default().marker = Some(marker);
                    }
                    st.trim();
                    cv.notify_all();
                }
            }
            kind::NACK_MISS => {
                // the relay path cannot retransmit this slot: flag it
                // so a waiting fetch_shard stops immediately instead
                // of running out its NACK timeout
                if let Ok((step, shard)) = tcp::parse_shard_ack(&frame.payload) {
                    let mut st = lock.plock();
                    st.unserviceable.insert((step, shard));
                    cv.notify_all();
                }
            }
            kind::HOP => {
                // reply to our SUBSCRIBE: upstream relay depth → ours
                if let Ok(h) = tcp::parse_hop(&frame.payload) {
                    lock.plock().hops = Some(h + 1);
                }
            }
            kind::CLOSE => {
                lock.plock().closed = true;
                cv.notify_all();
                return;
            }
            _ => {}
        }
    })
}

/// Put one repair NACK for `(step, shard)` on the wire and count it.
fn send_nack(sub: &Subscriber, step: u64, shard: u32) -> Result<()> {
    let mut conn = sub.conn.plock();
    tcp::write_frame(
        &mut conn,
        &Frame { kind: kind::NACK, payload: tcp::shard_ack_payload(step, shard) },
    )
    .context("sending shard NACK")?;
    sub.counters.bump(&sub.counters.nacks_sent);
    Ok(())
}

impl SyncTransport for RelayTransport {
    fn name(&self) -> &'static str {
        "relay"
    }

    fn publish_frame(&self, id: FrameId, bytes: &[u8]) -> Result<()> {
        let (relay, counters) = self.pub_side()?;
        let kind_ = if id.is_anchor() { kind::ANCHOR } else { kind::PATCH };
        relay.publish(Frame { kind: kind_, payload: bytes.to_vec() });
        counters.published(bytes.len());
        Ok(())
    }

    fn publish_marker(&self, id: MarkerId, payload: &str) -> Result<()> {
        let (relay, counters) = self.pub_side()?;
        relay.publish(Frame {
            kind: kind::MARKER,
            payload: tcp::marker_frame_payload(id.is_anchor(), id.step(), payload),
        });
        counters.bump(&counters.markers_published);
        Ok(())
    }

    fn latest_ready(&self) -> Result<Inventory> {
        let sub = self.sub_side()?;
        sub.counters.bump(&sub.counters.inventory_scans);
        let st = sub.state.0.plock();
        Ok(Inventory {
            // only fully-staged steps are committed from this
            // subscriber's point of view: a coalesced-away step simply
            // never becomes visible, and the consumer anchors past it
            delta_steps: st
                .deltas
                .iter()
                .filter(|(_, d)| d.complete())
                .map(|(&s, _)| s)
                .collect(),
            anchor_steps: st
                .anchors
                .iter()
                .filter(|(_, a)| a.marker.is_some() && a.object.is_some())
                .map(|(&s, _)| s)
                .collect(),
        })
    }

    fn fetch_step(&self, step: u64) -> Result<Option<StepData>> {
        let sub = self.sub_side()?;
        let st = sub.state.0.plock();
        let stage = match st.deltas.get(&step) {
            Some(d) => d,
            None => return Ok(None),
        };
        let marker = match &stage.marker {
            Some(m) => m.clone(),
            None => return Ok(None),
        };
        let (_, marker) = split_generation(&marker);
        if let Some((shard_count, root)) = parse_sharded_marker(marker) {
            return Ok(Some(StepData::Sharded { shard_count, root: root.to_string() }));
        }
        let obj = stage
            .frames
            .get(&0)
            .map(|(b, _)| b.clone())
            .ok_or_else(|| anyhow::anyhow!("delta frame for step {} not staged", step))?;
        sub.counters.fetched(obj.len());
        Ok(Some(StepData::Whole(obj)))
    }

    fn fetch_shard(&self, step: u64, shard: u32) -> Result<Vec<u8>> {
        let sub = self.sub_side()?;
        let (lock, cv) = &*sub.state;
        let (first, staged) = {
            let mut st = lock.plock();
            let first = st.served.insert((step, shard));
            let staged = st
                .deltas
                .get(&step)
                .and_then(|d| d.frames.get(&shard))
                .map(|(b, g)| (b.clone(), *g));
            (first, staged)
        };
        if first {
            if let Some((bytes, _)) = staged {
                sub.counters.fetched(bytes.len());
                return Ok(bytes);
            }
        }
        // repair (or a frame that never arrived): NACK the slot and
        // wait for the relay's per-subscriber retransmit to land as a
        // new generation — or for an explicit NACK_MISS saying the
        // slot is unserviceable along the whole relay path. Exactly
        // one NACK per slot is outstanding at a time: concurrent
        // fetches ride it (`nack_suppressed`), and the owner re-sends
        // on the RetryPolicy backoff schedule (`retries`, for a NACK
        // or retransmit lost on a faulty wire) until the budget is
        // spent (`gave_up`).
        let base_generation = staged.map(|(_, g)| g).unwrap_or(0);
        let t_repair = crate::util::Stopwatch::start();
        let owner = {
            let mut st = lock.plock();
            if st.nack_inflight.insert((step, shard)) {
                // a stale miss flag from an earlier attempt must not
                // short-circuit this fresh NACK's answer
                st.unserviceable.remove(&(step, shard));
                true
            } else {
                sub.counters.bump(&sub.counters.nack_suppressed);
                false
            }
        };
        if owner {
            if let Err(e) = send_nack(sub, step, shard) {
                lock.plock().nack_inflight.remove(&(step, shard));
                return Err(e);
            }
            crate::obs::span(crate::obs::Stage::NackSent, 0, step, shard, 0);
        }
        // Wall-clock audit (scale-sim seam): this wait is intentionally
        // real time. It parks the calling thread on a condvar fed by a
        // live socket reader, which only exists on the TCP plane — the
        // simulator never enters this loop (modeled leaves schedule
        // NACK resends as events off the same RetryPolicy via
        // `RetryPolicy::start_at`). Moving this behind the virtual
        // clock would mean virtualizing the condvar wakeup itself,
        // i.e. simulating the thread scheduler — out of scope.
        let mut retry = sub.nack_policy.start();
        let deadline = retry.deadline();
        let mut next_resend = if owner {
            // pallas-lint: allow(clock-seam): schedules the next wall-time NACK resend (see audit note above)
            retry.next_delay().map(|d| Instant::now() + d)
        } else {
            None
        };
        let mut st = lock.plock();
        loop {
            if let Some((bytes, g)) = st.deltas.get(&step).and_then(|d| d.frames.get(&shard)) {
                if *g > base_generation {
                    let out = bytes.clone();
                    if owner {
                        st.nack_inflight.remove(&(step, shard));
                        cv.notify_all();
                    }
                    sub.counters.fetched(out.len());
                    crate::obs::hist_secs(crate::obs::HistKind::NackRepair, t_repair.secs());
                    return Ok(out);
                }
            }
            if st.unserviceable.remove(&(step, shard)) {
                if owner {
                    st.nack_inflight.remove(&(step, shard));
                    cv.notify_all();
                }
                sub.counters.bump(&sub.counters.nacks_unserviceable);
                bail!(
                    "shard {} of step {}: {} (slot evicted along the relay path)",
                    shard,
                    step,
                    UNSERVICEABLE_MARK
                );
            }
            if st.closed {
                if owner {
                    st.nack_inflight.remove(&(step, shard));
                }
                bail!("relay stream closed awaiting shard {} of step {}", shard, step);
            }
            // pallas-lint: allow(clock-seam): wall reading against the live-socket NACK deadline
            let now = Instant::now();
            if now >= deadline {
                if owner {
                    st.nack_inflight.remove(&(step, shard));
                    cv.notify_all();
                }
                sub.counters.bump(&sub.counters.gave_up);
                crate::obs::span(
                    crate::obs::Stage::GaveUp,
                    0,
                    step,
                    shard,
                    retry.attempts() as u64,
                );
                let _ = crate::obs::Obs::global()
                    .dump_incident(&format!("nack gave up step {} shard {}", step, shard));
                bail!(
                    "timed out awaiting retransmit of shard {} step {} ({} resends)",
                    shard,
                    step,
                    retry.attempts().saturating_sub(1)
                );
            }
            if let Some(t) = next_resend {
                if now >= t {
                    // backoff window expired unanswered: the NACK (or
                    // its retransmit) may have died on a faulty wire —
                    // re-send and count the retry
                    drop(st);
                    if let Err(e) = send_nack(sub, step, shard) {
                        lock.plock().nack_inflight.remove(&(step, shard));
                        return Err(e);
                    }
                    sub.counters.bump(&sub.counters.retries);
                    // pallas-lint: allow(clock-seam): re-arms the wall-time resend schedule
                    next_resend = retry.next_delay().map(|d| Instant::now() + d);
                    st = lock.plock();
                    continue;
                }
            }
            let wake = next_resend.map_or(deadline, |t| t.min(deadline));
            st = cv.pwait_timeout(st, wake - now);
        }
    }

    fn fetch_anchor(&self, step: u64) -> Result<(Vec<u8>, String)> {
        let sub = self.sub_side()?;
        let st = sub.state.0.plock();
        let stage = st.anchors.get(&step).with_context(|| format!("anchor {}", step))?;
        match (&stage.object, &stage.marker) {
            (Some(obj), Some(marker)) => {
                sub.counters.fetched(obj.len());
                Ok((obj.clone(), marker.clone()))
            }
            _ => bail!("anchor {} not fully staged", step),
        }
    }

    fn counters(&self) -> TransportCounters {
        match &self.role {
            RelayRole::Publisher { counters, .. } => counters.snapshot(),
            RelayRole::Subscriber(sub) => sub.counters.snapshot(),
        }
    }
}

// ---------------------------------------------- FaultInjectingTransport

/// What a [`FaultInjectingTransport`] may do to consumer-side traffic.
/// All decisions are pure functions of `(seed, step, shard)` — never
/// of call order — so a failing run replays exactly.
#[derive(Debug, Clone, Copy, Default)]
pub struct FaultPlan {
    /// Probability a shard frame is mangled on its *first* serve
    /// (truncated below the container header minimum, so decode fails
    /// deterministically and the consumer's single-shard refetch
    /// heals it). Repairs always pass through clean.
    pub corrupt_shard_prob: f64,
    /// Probability the first fetch of a shard errors outright (a lost
    /// frame); the refetch succeeds.
    pub drop_shard_prob: f64,
    /// Probability the newest committed step is hidden from one
    /// [`SyncTransport::latest_ready`] snapshot (a reordered/late
    /// marker); the next poll sees it.
    pub delay_marker_prob: f64,
    /// Force-corrupt exactly this slot (first serve), independent of
    /// the probabilities — the targeted §J.5 recovery scenario.
    pub target: Option<(u64, u32)>,
    /// Poison exactly this slot's REPAIR seam: the first serve is
    /// corrupted (like [`FaultPlan::target`]) and every repair fetch
    /// errors with [`UNSERVICEABLE_MARK`] — modelling a relay path
    /// that delivered bad bytes and has since evicted the slot. The
    /// consumer must abandon the step to the anchor slow path and
    /// count the event (`SyncStats::nacks_unserviceable`).
    pub target_unserviceable: Option<(u64, u32)>,
}

/// Decorator that deterministically corrupts, drops, and delays
/// consumer-side traffic of any inner backend, so §J.5 self-healing is
/// exercisable on *every* fabric. Producer-side calls pass through
/// untouched.
pub struct FaultInjectingTransport<T> {
    inner: T,
    plan: FaultPlan,
    seed: u64,
    served: Mutex<HashSet<(u64, u32)>>,
    delayed: Mutex<HashSet<u64>>,
    injected: AtomicU64,
}

const SALT_CORRUPT: u64 = 0xC0;
const SALT_DROP: u64 = 0xD0;
const SALT_DELAY: u64 = 0xDE;

impl<T: SyncTransport> FaultInjectingTransport<T> {
    pub fn new(inner: T, seed: u64, plan: FaultPlan) -> FaultInjectingTransport<T> {
        FaultInjectingTransport {
            inner,
            plan,
            seed,
            served: Mutex::new(HashSet::new()),
            delayed: Mutex::new(HashSet::new()),
            injected: AtomicU64::new(0),
        }
    }

    /// Convenience: corrupt exactly one `(step, shard)` slot.
    pub fn targeting(inner: T, step: u64, shard: u32) -> FaultInjectingTransport<T> {
        FaultInjectingTransport::new(
            inner,
            0,
            FaultPlan { target: Some((step, shard)), ..FaultPlan::default() },
        )
    }

    /// Convenience: corrupt one slot's first serve AND poison its
    /// repair seam (every refetch reports unserviceable).
    pub fn unserviceable(inner: T, step: u64, shard: u32) -> FaultInjectingTransport<T> {
        FaultInjectingTransport::new(
            inner,
            0,
            FaultPlan { target_unserviceable: Some((step, shard)), ..FaultPlan::default() },
        )
    }

    pub fn inner(&self) -> &T {
        &self.inner
    }

    /// Faults injected so far.
    pub fn injected(&self) -> u64 {
        self.injected.load(Ordering::Relaxed)
    }

    /// Deterministic uniform [0,1) from (seed, step, shard, salt).
    fn roll(&self, step: u64, shard: u32, salt: u64) -> f64 {
        let mut s = self
            .seed
            .wrapping_mul(0x9E3779B97F4A7C15)
            ^ step.wrapping_mul(0xA24BAED4963EE407)
            ^ ((shard as u64) << 32)
            ^ salt;
        (splitmix64(&mut s) >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }
}

impl<T: SyncTransport> SyncTransport for FaultInjectingTransport<T> {
    fn name(&self) -> &'static str {
        "fault-injected"
    }

    fn publish_frame(&self, id: FrameId, bytes: &[u8]) -> Result<()> {
        self.inner.publish_frame(id, bytes)
    }

    fn publish_marker(&self, id: MarkerId, payload: &str) -> Result<()> {
        self.inner.publish_marker(id, payload)
    }

    fn latest_ready(&self) -> Result<Inventory> {
        let mut inv = self.inner.latest_ready()?;
        if self.plan.delay_marker_prob > 0.0 {
            if let Some(&head) = inv.delta_steps.last() {
                if self.roll(head, 0, SALT_DELAY) < self.plan.delay_marker_prob
                    && self.delayed.plock().insert(head)
                {
                    self.injected.fetch_add(1, Ordering::Relaxed);
                    inv.delta_steps.pop();
                }
            }
        }
        Ok(inv)
    }

    fn fetch_step(&self, step: u64) -> Result<Option<StepData>> {
        self.inner.fetch_step(step)
    }

    fn fetch_shard(&self, step: u64, shard: u32) -> Result<Vec<u8>> {
        let first = self.served.plock().insert((step, shard));
        if !first && self.plan.target_unserviceable == Some((step, shard)) {
            // the repair seam is dead for this slot: report it the way
            // the relay backend reports a NACK_MISS, so the consumer's
            // anchor fallback (and its counting) is exercisable on any
            // inner backend
            self.injected.fetch_add(1, Ordering::Relaxed);
            bail!(
                "injected: shard {} of step {} {}",
                shard,
                step,
                UNSERVICEABLE_MARK
            );
        }
        if first
            && self.plan.drop_shard_prob > 0.0
            && self.roll(step, shard, SALT_DROP) < self.plan.drop_shard_prob
        {
            self.injected.fetch_add(1, Ordering::Relaxed);
            bail!("injected drop of shard {} step {}", shard, step);
        }
        let mut bytes = self.inner.fetch_shard(step, shard)?;
        let corrupt = self.plan.target == Some((step, shard))
            || self.plan.target_unserviceable == Some((step, shard))
            || (self.plan.corrupt_shard_prob > 0.0
                && self.roll(step, shard, SALT_CORRUPT) < self.plan.corrupt_shard_prob);
        if first && corrupt {
            self.injected.fetch_add(1, Ordering::Relaxed);
            // truncate below the container header minimum: decode fails
            // deterministically, never "accidentally valid" bytes
            bytes.truncate(8.min(bytes.len()));
        }
        Ok(bytes)
    }

    fn fetch_anchor(&self, step: u64) -> Result<(Vec<u8>, String)> {
        self.inner.fetch_anchor(step)
    }

    fn counters(&self) -> TransportCounters {
        let mut c = self.inner.counters();
        c.faults_injected += self.injected.load(Ordering::Relaxed);
        c
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn object_store_transport_uses_the_store_key_scheme() {
        let store = ObjectStore::temp("transport_store").unwrap();
        let t = ObjectStoreTransport::new(store.clone(), "sync/");
        assert_eq!(t.prefix, "sync");
        t.publish_frame(FrameId::Delta { step: 3 }, b"obj3").unwrap();
        t.publish_frame(FrameId::Shard { step: 4, shard: 1 }, b"s41").unwrap();
        t.publish_frame(FrameId::Anchor { step: 0 }, b"anch").unwrap();
        t.publish_marker(MarkerId::Anchor(0), "m0").unwrap();
        assert_eq!(store.get("sync/delta_00000003.bin").unwrap(), b"obj3");
        assert_eq!(store.get("sync/delta_00000004.s001.bin").unwrap(), b"s41");
        assert_eq!(store.get("sync/anchor_00000000.bin").unwrap(), b"anch");
        // no delta marker yet → fetch_step sees the §J.5 signal
        assert_eq!(t.fetch_step(3).unwrap(), None);
        t.publish_marker(MarkerId::Delta(3), &"ab".repeat(32)).unwrap();
        assert_eq!(t.fetch_step(3).unwrap(), Some(StepData::Whole(b"obj3".to_vec())));
        t.publish_marker(MarkerId::Delta(4), &sharded_marker(2, &"cd".repeat(32)))
            .unwrap();
        assert_eq!(
            t.fetch_step(4).unwrap(),
            Some(StepData::Sharded { shard_count: 2, root: "cd".repeat(32) })
        );
        assert_eq!(t.fetch_shard(4, 1).unwrap(), b"s41");
        assert_eq!(t.fetch_anchor(0).unwrap(), (b"anch".to_vec(), "m0".to_string()));
        let inv = t.latest_ready().unwrap();
        assert_eq!(inv.delta_steps, vec![3, 4]);
        assert_eq!(inv.anchor_steps, vec![0]);
        assert_eq!(t.counters().inventory_scans, 1);
        std::fs::remove_dir_all(store.root()).unwrap();
    }

    #[test]
    fn inproc_window_evicts_with_chain_base_kept() {
        let t = InProcTransport::with_window(4, 2);
        t.publish_frame(FrameId::Anchor { step: 0 }, b"a0").unwrap();
        t.publish_marker(MarkerId::Anchor(0), "m0").unwrap();
        for step in 1..=10u64 {
            t.publish_frame(FrameId::Delta { step }, format!("d{}", step).as_bytes())
                .unwrap();
            t.publish_marker(MarkerId::Delta(step), &"ab".repeat(32)).unwrap();
            if step % 5 == 0 {
                t.publish_frame(FrameId::Anchor { step }, b"a").unwrap();
                t.publish_marker(MarkerId::Anchor(step), "m").unwrap();
            }
        }
        let inv = t.latest_ready().unwrap();
        assert_eq!(inv.delta_steps, vec![7, 8, 9, 10], "window keeps the newest 4");
        // anchors 5 and 10 retained; anchor 5 is the chain base for
        // delta 7 even though only 2 anchors fit
        assert!(inv.anchor_steps.contains(&10));
        assert!(inv.anchor_steps.iter().any(|&a| a <= 7));
        assert_eq!(t.fetch_step(2).unwrap(), None, "evicted step reads as replaced");
        assert_eq!(
            t.fetch_step(8).unwrap(),
            Some(StepData::Whole(b"d8".to_vec()))
        );
    }

    #[test]
    fn clones_share_inproc_state() {
        let producer = InProcTransport::new();
        let consumer = producer.clone();
        producer.publish_frame(FrameId::Delta { step: 1 }, b"x").unwrap();
        producer.publish_marker(MarkerId::Delta(1), &"ee".repeat(32)).unwrap();
        assert_eq!(consumer.latest_ready().unwrap().delta_steps, vec![1]);
        assert_eq!(consumer.fetch_step(1).unwrap(), Some(StepData::Whole(b"x".to_vec())));
    }

    #[test]
    fn fault_decorator_is_deterministic_and_heals_on_refetch() {
        let make = || {
            let inner = InProcTransport::new();
            inner
                .publish_frame(FrameId::Shard { step: 5, shard: 2 }, &vec![7u8; 256])
                .unwrap();
            inner
        };
        // targeted corruption: first serve truncated, repair clean
        let t = FaultInjectingTransport::targeting(make(), 5, 2);
        let first = t.fetch_shard(5, 2).unwrap();
        assert_eq!(first.len(), 8, "first serve must be truncated");
        let second = t.fetch_shard(5, 2).unwrap();
        assert_eq!(second, vec![7u8; 256], "repair must pass through clean");
        assert_eq!(t.injected(), 1);
        assert_eq!(t.counters().faults_injected, 1);
        // zero probabilities, no target → bitwise passthrough
        let clean = FaultInjectingTransport::new(make(), 123, FaultPlan::default());
        assert_eq!(clean.fetch_shard(5, 2).unwrap(), vec![7u8; 256]);
        assert_eq!(clean.injected(), 0);
        // decisions are a pure function of (seed, step, shard)
        let a = FaultInjectingTransport::new(
            make(),
            42,
            FaultPlan { corrupt_shard_prob: 0.5, ..FaultPlan::default() },
        );
        let b = FaultInjectingTransport::new(
            make(),
            42,
            FaultPlan { corrupt_shard_prob: 0.5, ..FaultPlan::default() },
        );
        assert_eq!(a.fetch_shard(5, 2).unwrap(), b.fetch_shard(5, 2).unwrap());
    }

    #[test]
    fn fault_decorator_drop_errors_once_then_serves() {
        let inner = InProcTransport::new();
        inner.publish_frame(FrameId::Shard { step: 9, shard: 0 }, b"frame").unwrap();
        let t = FaultInjectingTransport::new(
            inner,
            7,
            FaultPlan { drop_shard_prob: 1.0, ..FaultPlan::default() },
        );
        assert!(t.fetch_shard(9, 0).is_err(), "first fetch must drop");
        assert_eq!(t.fetch_shard(9, 0).unwrap(), b"frame", "refetch must serve");
        assert_eq!(t.injected(), 1);
    }

    #[test]
    fn fault_decorator_delays_head_marker_once() {
        let inner = InProcTransport::new();
        for step in 1..=3u64 {
            inner.publish_frame(FrameId::Delta { step }, b"d").unwrap();
            inner.publish_marker(MarkerId::Delta(step), &"ab".repeat(32)).unwrap();
        }
        let t = FaultInjectingTransport::new(
            inner,
            1,
            FaultPlan { delay_marker_prob: 1.0, ..FaultPlan::default() },
        );
        assert_eq!(t.latest_ready().unwrap().delta_steps, vec![1, 2], "head hidden once");
        assert_eq!(t.latest_ready().unwrap().delta_steps, vec![1, 2, 3], "then visible");
    }

    #[test]
    fn relay_transport_roundtrips_markers_and_frames() {
        let relay = Arc::new(Relay::start().unwrap());
        let producer = RelayTransport::publisher(relay.clone());
        let consumer = RelayTransport::subscribe(relay.port).unwrap();
        // wrong-side calls error instead of hanging
        assert!(producer.latest_ready().is_err());
        assert!(consumer.publish_marker(MarkerId::Delta(1), "x").is_err());
        // a PLSA-framed anchor + marker, then an unsharded container
        let mut anchor = Vec::new();
        anchor.extend_from_slice(b"PLSA");
        anchor.extend_from_slice(&0u64.to_le_bytes());
        anchor.extend_from_slice(&0u64.to_le_bytes());
        anchor.extend_from_slice(b"payload");
        producer.publish_frame(FrameId::Anchor { step: 0 }, &anchor).unwrap();
        producer.publish_marker(MarkerId::Anchor(0), "anchor-marker").unwrap();
        let patch = container::Patch {
            step: 1,
            total_params: 64,
            result_hash: "ab".repeat(32),
            chunk_elems: 64,
            ..Default::default()
        };
        let obj = container::encode(
            &patch,
            &crate::sparse::synthetic_layout(64, 64),
            container::EncodeOpts::default(),
        )
        .unwrap();
        producer.publish_frame(FrameId::Delta { step: 1 }, &obj).unwrap();
        producer.publish_marker(MarkerId::Delta(1), &"ab".repeat(32)).unwrap();
        // staging is asynchronous: poll until committed
        let deadline = Instant::now() + Duration::from_secs(10);
        loop {
            let inv = consumer.latest_ready().unwrap();
            if inv.delta_steps == vec![1] && inv.anchor_steps == vec![0] {
                break;
            }
            assert!(Instant::now() < deadline, "staging never completed");
            std::thread::sleep(Duration::from_millis(5));
        }
        assert_eq!(consumer.fetch_step(1).unwrap(), Some(StepData::Whole(obj)));
        assert_eq!(
            consumer.fetch_anchor(0).unwrap(),
            (anchor, "anchor-marker".to_string())
        );
        assert_eq!(consumer.fetch_step(2).unwrap(), None);
        producer.close();
        let deadline = Instant::now() + Duration::from_secs(10);
        while !consumer.stream_closed() {
            assert!(Instant::now() < deadline, "close never observed");
            std::thread::sleep(Duration::from_millis(5));
        }
        drop(consumer);
        relay.stop();
    }

    #[test]
    fn served_slots_survive_eviction_and_restaging() {
        // regression: `trim` used to prune `served` to the staged
        // minimum, so a step evicted from `deltas` and later restaged
        // by a late retransmit was treated as never-served — the next
        // fetch of the slot skipped the NACK repair path entirely
        let mut st = SubState::default();
        st.deltas.insert(1, DeltaStage::default());
        st.served.insert((1, 0));
        // steps 2..=5 arrive; window of 4 evicts step 1
        for s in 2..=5u64 {
            st.deltas.insert(s, DeltaStage::default());
            st.trim_to(4, 4, 1 << 20);
        }
        assert!(!st.deltas.contains_key(&1), "step 1 must be evicted");
        assert!(
            st.served.contains(&(1, 0)),
            "eviction must forget the frames, not the serves"
        );
        // late retransmit restages step 1: the slot still reads as
        // served, so the next fetch takes the repair path
        st.deltas.insert(1, DeltaStage::default());
        assert!(!st.served.insert((1, 0)), "restaged slot must still count as served");
        // anchors DO prune serves (those steps can never be refetched)
        st.prune_superseded(3);
        assert!(!st.served.contains(&(1, 0)));
        // the cap backstop drops oldest steps first
        let mut st = SubState::default();
        for s in 0..10u64 {
            st.served.insert((s, 0));
        }
        st.trim_to(4, 4, 6);
        assert!(st.served.len() <= 6);
        assert!(st.served.contains(&(9, 0)), "newest serves must survive the cap");
        assert!(!st.served.contains(&(0, 0)), "oldest serves go first");
    }

    #[test]
    fn served_consistency_property() {
        // property: under ANY interleaving of staging, eviction,
        // restaging, and anchor pruning, a slot is in `served` iff it
        // was served and not superseded by an anchor (while under the
        // cap) — i.e. eviction alone never forgets a serve
        crate::util::prop::check("served tracks serves, not staging", 12, |g| {
            let mut st = SubState::default();
            let mut model: HashSet<(u64, u32)> = HashSet::new();
            let mut max_anchor = 0u64;
            for _ in 0..200 {
                let step = 1 + g.rng.below(40);
                let shard = g.rng.below(3) as u32;
                match g.rng.below(4) {
                    0 => {
                        // stage (or restage) a frame, then window-trim
                        st.deltas.entry(step).or_default();
                        st.trim_to(6, 4, 1 << 20);
                    }
                    1 => {
                        // serve a staged slot
                        if st.deltas.contains_key(&step) && step > max_anchor {
                            st.served.insert((step, shard));
                            model.insert((step, shard));
                        }
                    }
                    2 => {
                        // a complete anchor supersedes steps <= step
                        st.prune_superseded(step);
                        max_anchor = max_anchor.max(step);
                        model.retain(|&(s, _)| s > step);
                    }
                    _ => {
                        // heavy staging burst forces evictions
                        for s in step..step + 8 {
                            st.deltas.entry(s).or_default();
                            st.trim_to(6, 4, 1 << 20);
                        }
                    }
                }
                assert_eq!(
                    st.served, model,
                    "served diverged from the serve/supersede model"
                );
            }
        });
    }

    #[test]
    fn relay_fetch_shard_fails_fast_on_unserviceable_nack() {
        // a repair NACK for a slot the relay never indexed (or has
        // evicted) must error out via the explicit NACK_MISS reply —
        // quickly, not by burning the full NACK timeout
        let relay = Arc::new(Relay::start().unwrap());
        let consumer = RelayTransport::subscribe(relay.port).unwrap();
        // stage a committed sharded step so fetch_shard(1, 1) has a
        // marker to believe in, but shard 1's frame never arrives
        producer_stage_marker(&relay, 1, 2);
        let deadline = Instant::now() + Duration::from_secs(10);
        while consumer.sub_side().unwrap().state.0.plock().deltas.is_empty() {
            assert!(Instant::now() < deadline, "marker never staged");
            std::thread::sleep(Duration::from_millis(3));
        }
        let t0 = Instant::now();
        let err = consumer.fetch_shard(1, 1).unwrap_err();
        assert!(is_unserviceable(&err), "error must carry the marker: {:#}", err);
        assert!(
            t0.elapsed() < RetryPolicy::nack_default().total / 2,
            "NACK_MISS must fail fast, not wait out the retry budget"
        );
        assert_eq!(consumer.counters().nacks_unserviceable, 1);
        assert_eq!(relay.nacks_unserviceable(), 1);
        // context wrapping keeps the marker detectable
        let wrapped = Err::<(), _>(err).context("outer").unwrap_err();
        assert!(is_unserviceable(&wrapped));
        drop(consumer);
        relay.stop();
    }

    /// Publish a sharded v3 marker for `step` with `shards` shards so
    /// a subscriber stages the step (without any shard frames).
    fn producer_stage_marker(relay: &Arc<Relay>, step: u64, shards: u32) {
        let producer = RelayTransport::publisher(relay.clone());
        producer
            .publish_marker(MarkerId::Delta(step), &sharded_marker(shards, &"ab".repeat(32)))
            .unwrap();
    }

    /// A v3-shaped shard frame whose container header peeks as
    /// `(step, shard, of)` — what a relay retransmit carries.
    fn shard_frame_bytes(step: u64, shard: u32, of: u32) -> Vec<u8> {
        let n = 2048usize;
        let layout = crate::sparse::synthetic_layout(n, 64);
        let per = n as u64 / of as u64;
        let patch = container::Patch {
            step,
            base_step: step.saturating_sub(1),
            total_params: n as u64,
            indices: vec![shard as u64 * per],
            values: container::Values::Bf16(vec![7u16]),
            result_hash: "ab".repeat(32),
            chunk_elems: 64,
            shard_index: shard,
            shard_count: of,
            elem_offset: shard as u64 * per,
            elem_len: per,
            shard_root: "cd".repeat(32),
        };
        container::encode(&patch, &layout, container::EncodeOpts::default()).unwrap()
    }

    /// Block until the subscriber has staged at least one delta step.
    fn wait_staged(consumer: &RelayTransport) {
        let deadline = Instant::now() + Duration::from_secs(10);
        while consumer.sub_side().unwrap().state.0.plock().deltas.is_empty() {
            assert!(Instant::now() < deadline, "marker never staged");
            std::thread::sleep(Duration::from_millis(3));
        }
    }

    #[test]
    fn concurrent_fetches_of_one_slot_send_one_nack() {
        // client-side storm suppression: two fetches of the same
        // evicted slot put exactly one NACK on the wire; the second
        // rides the first one's answer and is counted as suppressed
        let relay = Arc::new(Relay::start().unwrap());
        let escalations = Arc::new(AtomicU64::new(0));
        {
            let e = escalations.clone();
            relay.set_escalation(move |_, _| {
                e.fetch_add(1, Ordering::SeqCst);
                true // accepted upstream; answered later by the test
            });
        }
        let mut consumer = RelayTransport::subscribe(relay.port).unwrap();
        // a resend schedule far past the test horizon keeps the wire
        // deterministic: exactly one NACK unless the test misbehaves
        consumer
            .set_nack_policy(RetryPolicy::new(
                Duration::from_secs(5),
                2.0,
                Duration::from_secs(5),
                Duration::from_secs(20),
            ))
            .unwrap();
        let consumer = Arc::new(consumer);
        producer_stage_marker(&relay, 1, 2);
        wait_staged(&consumer);
        let c1 = consumer.clone();
        let h1 = std::thread::spawn(move || c1.fetch_shard(1, 1));
        let deadline = Instant::now() + Duration::from_secs(10);
        while consumer.counters().nacks_sent < 1 || escalations.load(Ordering::SeqCst) < 1 {
            assert!(Instant::now() < deadline, "first NACK never escalated");
            std::thread::sleep(Duration::from_millis(3));
        }
        let c2 = consumer.clone();
        let h2 = std::thread::spawn(move || c2.fetch_shard(1, 1));
        while consumer.counters().nack_suppressed < 1 {
            assert!(Instant::now() < deadline, "second fetch never suppressed");
            std::thread::sleep(Duration::from_millis(3));
        }
        // answer the single escalated slot once; both fetches heal
        assert!(relay.deliver_retransmit(
            1,
            1,
            Frame { kind: kind::PATCH, payload: shard_frame_bytes(1, 1, 2) },
        ));
        let a = h1.join().unwrap().unwrap();
        let b = h2.join().unwrap().unwrap();
        assert_eq!(a, b, "both fetches must heal from the single retransmit");
        assert_eq!(escalations.load(Ordering::SeqCst), 1, "one upstream escalation");
        let c = consumer.counters();
        assert_eq!(c.nacks_sent, 1, "one NACK on the wire");
        assert_eq!(c.nack_suppressed, 1);
        assert_eq!(c.gave_up, 0);
        drop(consumer);
        relay.stop();
    }

    #[test]
    fn nack_resends_are_counted_as_retries() {
        // a mute upstream (escalation accepted, never answered) forces
        // the owner through its backoff schedule; each boundary
        // re-sends the NACK and counts a retry, and the late
        // retransmit still heals the fetch
        let relay = Arc::new(Relay::start().unwrap());
        relay.set_escalation(|_, _| true);
        let mut consumer = RelayTransport::subscribe(relay.port).unwrap();
        consumer
            .set_nack_policy(RetryPolicy::new(
                Duration::from_millis(30),
                2.0,
                Duration::from_millis(60),
                Duration::from_secs(10),
            ))
            .unwrap();
        let consumer = Arc::new(consumer);
        producer_stage_marker(&relay, 1, 2);
        wait_staged(&consumer);
        let c1 = consumer.clone();
        let h = std::thread::spawn(move || c1.fetch_shard(1, 1));
        let deadline = Instant::now() + Duration::from_secs(10);
        while consumer.counters().retries < 2 {
            assert!(Instant::now() < deadline, "resends never happened");
            std::thread::sleep(Duration::from_millis(5));
        }
        assert!(relay.deliver_retransmit(
            1,
            1,
            Frame { kind: kind::PATCH, payload: shard_frame_bytes(1, 1, 2) },
        ));
        let bytes = h.join().unwrap().unwrap();
        assert!(!bytes.is_empty());
        let c = consumer.counters();
        assert!(c.retries >= 2, "retries={}", c.retries);
        assert!(c.nacks_sent >= 3, "initial + resends, got {}", c.nacks_sent);
        assert_eq!(c.gave_up, 0);
        drop(consumer);
        relay.stop();
    }

    #[test]
    fn nack_budget_exhaustion_counts_gave_up() {
        // the upstream swallows the escalation forever: the fetch must
        // drain its (tiny) retry budget, count gave_up, and error with
        // a timeout — NOT the unserviceable marker (nothing said the
        // slot is gone; the consumer may still slow-path past it)
        let relay = Arc::new(Relay::start().unwrap());
        relay.set_escalation(|_, _| true);
        let mut consumer = RelayTransport::subscribe(relay.port).unwrap();
        consumer
            .set_nack_policy(RetryPolicy::new(
                Duration::from_millis(20),
                2.0,
                Duration::from_millis(40),
                Duration::from_millis(150),
            ))
            .unwrap();
        producer_stage_marker(&relay, 1, 2);
        wait_staged(&consumer);
        let err = consumer.fetch_shard(1, 1).unwrap_err();
        assert!(
            format!("{:#}", err).contains("timed out"),
            "budget exhaustion must read as a timeout: {:#}",
            err
        );
        assert!(!is_unserviceable(&err));
        let c = consumer.counters();
        assert_eq!(c.gave_up, 1);
        assert_eq!(c.nack_suppressed, 0);
        drop(consumer);
        relay.stop();
    }

    #[test]
    fn generation_prefix_grammar() {
        assert_eq!(split_generation("abc"), (0, "abc"));
        assert_eq!(split_generation("g3;v3:4:root"), (3, "v3:4:root"));
        assert_eq!(split_generation("g0;x"), (0, "x"));
        // malformed prefixes fall through whole
        assert_eq!(split_generation("g;x"), (0, "g;x"));
        assert_eq!(split_generation("g12"), (0, "g12"));
        assert_eq!(split_generation("gg;x"), (0, "gg;x"));
        // a generation-tagged sharded marker still parses after split
        let m = format!("g2;{}", sharded_marker(4, &"ab".repeat(32)));
        let (g, body) = split_generation(&m);
        assert_eq!(g, 2);
        assert_eq!(parse_sharded_marker(body).unwrap().0, 4);
    }

    #[test]
    fn fetch_step_sees_through_the_generation_prefix() {
        let t = InProcTransport::new();
        t.publish_frame(FrameId::Delta { step: 1 }, b"obj").unwrap();
        t.publish_marker(MarkerId::Delta(1), &format!("g2;{}", "ab".repeat(32))).unwrap();
        assert_eq!(t.fetch_step(1).unwrap(), Some(StepData::Whole(b"obj".to_vec())));
        t.publish_marker(
            MarkerId::Delta(2),
            &format!("g2;{}", sharded_marker(2, &"cd".repeat(32))),
        )
        .unwrap();
        assert_eq!(
            t.fetch_step(2).unwrap(),
            Some(StepData::Sharded { shard_count: 2, root: "cd".repeat(32) }),
            "a g-prefixed v3 marker must still read as sharded"
        );
    }

    #[test]
    fn fault_decorator_marker_delay_is_deterministic_per_seed() {
        let mk = || {
            let inner = InProcTransport::new();
            for step in 1..=6u64 {
                inner.publish_frame(FrameId::Delta { step }, b"d").unwrap();
                inner.publish_marker(MarkerId::Delta(step), &"ab".repeat(32)).unwrap();
            }
            inner
        };
        let plan = FaultPlan { delay_marker_prob: 0.5, ..FaultPlan::default() };
        let a = FaultInjectingTransport::new(mk(), 9, plan);
        let b = FaultInjectingTransport::new(mk(), 9, plan);
        assert_eq!(
            a.latest_ready().unwrap().delta_steps,
            b.latest_ready().unwrap().delta_steps,
            "same seed must hide (or not hide) the same head"
        );
        assert_eq!(a.injected(), b.injected());
    }

    #[test]
    fn marker_grammar_roundtrip() {
        assert_eq!(sharded_marker(4, &"ab".repeat(32)), format!("v3:4:{}", "ab".repeat(32)));
        let m = sharded_marker(4, &"ab".repeat(32));
        let (s, r) = parse_sharded_marker(&m).unwrap();
        assert_eq!((s, r), (4, "ab".repeat(32).as_str()));
        assert!(parse_sharded_marker(&"ab".repeat(32)).is_none(), "bare root is unsharded");
        assert!(parse_sharded_marker("v3:1:root").is_none());
        assert!(parse_sharded_marker(&format!("v3:99999:{}", "ab".repeat(32))).is_none());
        assert!(parse_sharded_marker("v3:4:short").is_none());
    }
}
