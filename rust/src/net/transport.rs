//! `SyncTransport`: one sync plane over interchangeable fabrics.
//!
//! PULSESync's protocol (paper Alg. 5 + §J) is fabric-agnostic: a
//! producer stores *frames* (delta containers, shard frames, anchor
//! objects) and then commits each step with a *ready marker*; a
//! consumer discovers committed steps, fetches their frames, and
//! verifies them against the hash-tree commitments the frames carry.
//! This module turns that contract into a trait so the same
//! `Publisher`/`Consumer` state machines ([`crate::pulse::sync`]) run
//! unchanged over an S3-like object store, a TCP relay, an in-process
//! staging map, or any of those wrapped in deterministic fault
//! injection.
//!
//! # The contract
//!
//! * **Commit ordering.** A producer publishes every frame of a step
//!   *before* its marker ([`SyncTransport::publish_marker`]). A step
//!   listed by [`SyncTransport::latest_ready`] is committed: its
//!   marker has landed. Fetching a committed step's data may still
//!   fail (retention, relay coalescing, corruption) — the consumer
//!   treats any fetch or verification failure as a signal to degrade
//!   to the anchor slow path, so a backend never has to guarantee
//!   perfect delivery, only eventual anchor availability.
//! * **Integrity is end-to-end, not transport-level.** Frames carry
//!   their own hash-tree commitments; a backend may deliver corrupted
//!   bytes and the consumer heals (per-shard refetch, then anchor
//!   fallback). [`SyncTransport::fetch_shard`] is the designated
//!   repair seam: calling it again for the same `(step, shard)` asks
//!   the backend for a *fresh* copy (the relay backend turns that into
//!   a NACK retransmit; stores simply re-read).
//! * **Markers are opaque strings** with the same grammar on every
//!   backend: a bare 64-hex root for an unsharded delta,
//!   `v3:<shards>:<root>` for a sharded step
//!   ([`sharded_marker`]/[`parse_sharded_marker`]), and
//!   `v2:<chunk_elems>:<root>` (or a legacy bare scalar hash) for
//!   anchors.
//!
//! # Adding a backend
//!
//! Implement the seven methods; the conformance suite
//! (`rust/tests/integration_transport.rs`) is generic over
//! `T: SyncTransport` — run your backend through it to inherit the
//! bit-identity, chain/slow-path, and corruption-recovery checks. The
//! split between producer-side and consumer-side methods is
//! intentional: symmetric backends ([`ObjectStoreTransport`],
//! [`InProcTransport`]) implement both on one value; directional
//! fabrics ([`RelayTransport`]) construct per-role values whose
//! wrong-side methods error.

use crate::net::relay::Relay;
use crate::net::tcp::{self, kind, Frame};
use crate::sparse::container;
use crate::storage::retention::{self, Inventory, RetentionPolicy};
use crate::storage::ObjectStore;
use crate::util::rng::splitmix64;
use anyhow::{bail, Context, Result};
use std::collections::{BTreeMap, HashSet};
use std::net::Shutdown;
use std::net::TcpStream;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Upper bound on the shard count accepted from untrusted markers and
/// headers (a corrupted marker must not drive per-shard allocations).
pub const MAX_SHARDS: u32 = 4096;

/// How long the relay backend waits for a NACKed shard retransmit.
pub const NACK_TIMEOUT: Duration = Duration::from_secs(5);

// ---------------------------------------------------------------- keys

/// Object key of an unsharded delta container (store-plane layout).
pub fn delta_key(step: u64) -> String {
    format!("delta_{:08}.bin", step)
}
/// Object key of one shard frame of a sharded step.
pub fn delta_shard_key(step: u64, shard: u32) -> String {
    format!("delta_{:08}.s{:03}.bin", step, shard)
}
/// Ready-marker key committing a delta step.
pub fn delta_ready_key(step: u64) -> String {
    format!("delta_ready_{}", step)
}
/// Object key of a full anchor checkpoint.
pub fn anchor_key(step: u64) -> String {
    format!("anchor_{:08}.bin", step)
}
/// Ready-marker key committing an anchor.
pub fn anchor_ready_key(step: u64) -> String {
    format!("anchor_ready_{}", step)
}

/// Sharded delta ready-marker payload: `v3:<shard_count>:<root_hex>`.
pub fn sharded_marker(shard_count: u32, root: &str) -> String {
    format!("v3:{}:{}", shard_count, root)
}

/// Parse a sharded delta marker; `None` for unsharded (bare-root)
/// markers or anything malformed / out of the trusted shard range.
pub fn parse_sharded_marker(s: &str) -> Option<(u32, &str)> {
    let rest = s.strip_prefix("v3:")?;
    let (count, root) = rest.split_once(':')?;
    let count: u32 = count.parse().ok()?;
    if !(2..=MAX_SHARDS).contains(&count) || root.len() != 64 {
        return None;
    }
    Some((count, root))
}

// --------------------------------------------------------------- types

/// Address of one stored frame on the sync plane.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum FrameId {
    /// Unsharded delta container for a step.
    Delta { step: u64 },
    /// One shard frame of a sharded step.
    Shard { step: u64, shard: u32 },
    /// Full anchor object for a step.
    Anchor { step: u64 },
}

impl FrameId {
    /// The store-plane object key for this frame.
    pub fn object_key(&self) -> String {
        match *self {
            FrameId::Delta { step } => delta_key(step),
            FrameId::Shard { step, shard } => delta_shard_key(step, shard),
            FrameId::Anchor { step } => anchor_key(step),
        }
    }

    pub fn step(&self) -> u64 {
        match *self {
            FrameId::Delta { step }
            | FrameId::Shard { step, .. }
            | FrameId::Anchor { step } => step,
        }
    }

    fn is_anchor(&self) -> bool {
        matches!(self, FrameId::Anchor { .. })
    }
}

/// Address of a ready marker.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum MarkerId {
    Delta(u64),
    Anchor(u64),
}

impl MarkerId {
    pub fn object_key(&self) -> String {
        match *self {
            MarkerId::Delta(step) => delta_ready_key(step),
            MarkerId::Anchor(step) => anchor_ready_key(step),
        }
    }

    pub fn step(&self) -> u64 {
        match *self {
            MarkerId::Delta(s) | MarkerId::Anchor(s) => s,
        }
    }

    pub fn is_anchor(&self) -> bool {
        matches!(self, MarkerId::Anchor(_))
    }
}

/// What [`SyncTransport::fetch_step`] returns for a committed step.
#[derive(Debug, Clone, PartialEq)]
pub enum StepData {
    /// Unsharded delta: the container object (v1/v2).
    Whole(Vec<u8>),
    /// Sharded step: parsed `v3` marker; frames come via
    /// [`SyncTransport::fetch_shard`].
    Sharded { shard_count: u32, root: String },
}

/// Snapshot of a backend's operation counters — the observability
/// surface the regression tests (single inventory scan per
/// synchronize) and [`crate::coordinator::metrics::TransportMeter`]
/// read.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct TransportCounters {
    pub inventory_scans: u64,
    pub frames_published: u64,
    pub bytes_published: u64,
    pub markers_published: u64,
    pub frames_fetched: u64,
    pub bytes_fetched: u64,
    /// Relay backend only: shard retransmits requested.
    pub nacks_sent: u64,
    /// Fault decorator only: faults actually injected.
    pub faults_injected: u64,
}

#[derive(Default)]
struct CounterCell {
    inventory_scans: AtomicU64,
    frames_published: AtomicU64,
    bytes_published: AtomicU64,
    markers_published: AtomicU64,
    frames_fetched: AtomicU64,
    bytes_fetched: AtomicU64,
    nacks_sent: AtomicU64,
}

impl CounterCell {
    fn snapshot(&self) -> TransportCounters {
        TransportCounters {
            inventory_scans: self.inventory_scans.load(Ordering::Relaxed),
            frames_published: self.frames_published.load(Ordering::Relaxed),
            bytes_published: self.bytes_published.load(Ordering::Relaxed),
            markers_published: self.markers_published.load(Ordering::Relaxed),
            frames_fetched: self.frames_fetched.load(Ordering::Relaxed),
            bytes_fetched: self.bytes_fetched.load(Ordering::Relaxed),
            nacks_sent: self.nacks_sent.load(Ordering::Relaxed),
            faults_injected: 0,
        }
    }

    fn bump(&self, c: &AtomicU64) {
        c.fetch_add(1, Ordering::Relaxed);
    }

    fn fetched(&self, bytes: usize) {
        self.frames_fetched.fetch_add(1, Ordering::Relaxed);
        self.bytes_fetched.fetch_add(bytes as u64, Ordering::Relaxed);
    }

    fn published(&self, bytes: usize) {
        self.frames_published.fetch_add(1, Ordering::Relaxed);
        self.bytes_published.fetch_add(bytes as u64, Ordering::Relaxed);
    }
}

// --------------------------------------------------------------- trait

/// One sync plane over interchangeable fabrics (see module docs for
/// the contract). Producer-side methods: [`Self::publish_frame`],
/// [`Self::publish_marker`]. Consumer-side: [`Self::latest_ready`],
/// [`Self::fetch_step`], [`Self::fetch_shard`], [`Self::fetch_anchor`].
pub trait SyncTransport: Send + Sync {
    /// Stable backend label (used in stats rows and bench names).
    fn name(&self) -> &'static str;

    /// Store one frame. Must complete before the step's marker is
    /// published; concurrent calls for different frames of one step
    /// are allowed (the sharded fan-out uploads shards in parallel).
    fn publish_frame(&self, id: FrameId, bytes: &[u8]) -> Result<()>;

    /// Commit a step by publishing its ready marker (see module docs
    /// for the marker grammar).
    fn publish_marker(&self, id: MarkerId, payload: &str) -> Result<()>;

    /// One snapshot of committed steps — a single backend scan serves
    /// both the head lookup and the slow-path anchor choice.
    fn latest_ready(&self) -> Result<Inventory>;

    /// A committed step's delta descriptor; `Ok(None)` when the step
    /// has no delta marker (a §J.5 anchor replaced the delta).
    fn fetch_step(&self, step: u64) -> Result<Option<StepData>>;

    /// One shard frame of a sharded step. Calling again for the same
    /// slot requests a fresh copy (the repair seam).
    fn fetch_shard(&self, step: u64, shard: u32) -> Result<Vec<u8>>;

    /// A committed anchor: `(object bytes, marker payload)`.
    fn fetch_anchor(&self, step: u64) -> Result<(Vec<u8>, String)>;

    /// Operation counters (zero for backends that don't track them).
    fn counters(&self) -> TransportCounters {
        TransportCounters::default()
    }
}

// -------------------------------------------------- ObjectStoreTransport

/// The paper's deployment fabric (§E.1): frames and markers are
/// objects under `prefix/` in an S3-like [`ObjectStore`], committed
/// steps are discovered by scanning ready markers
/// ([`retention::scan`]). This wraps exactly the key scheme the
/// pre-trait `Publisher`/`Consumer` used, so stores written before the
/// refactor remain readable.
#[derive(Clone)]
pub struct ObjectStoreTransport {
    pub store: ObjectStore,
    pub prefix: String,
    counters: Arc<CounterCell>,
}

impl ObjectStoreTransport {
    pub fn new(store: ObjectStore, prefix: &str) -> ObjectStoreTransport {
        ObjectStoreTransport {
            store,
            prefix: prefix.trim_end_matches('/').to_string(),
            counters: Arc::new(CounterCell::default()),
        }
    }

    fn key(&self, k: String) -> String {
        format!("{}/{}", self.prefix, k)
    }
}

impl SyncTransport for ObjectStoreTransport {
    fn name(&self) -> &'static str {
        "object-store"
    }

    fn publish_frame(&self, id: FrameId, bytes: &[u8]) -> Result<()> {
        self.store.put(&self.key(id.object_key()), bytes)?;
        self.counters.published(bytes.len());
        Ok(())
    }

    fn publish_marker(&self, id: MarkerId, payload: &str) -> Result<()> {
        self.store.put(&self.key(id.object_key()), payload.as_bytes())?;
        self.counters.bump(&self.counters.markers_published);
        Ok(())
    }

    fn latest_ready(&self) -> Result<Inventory> {
        self.counters.bump(&self.counters.inventory_scans);
        retention::scan(&self.store, &self.prefix)
    }

    fn fetch_step(&self, step: u64) -> Result<Option<StepData>> {
        // a missing marker is the §J.5 "anchor replaced the delta"
        // signal, not a transport failure
        let marker = match self.store.get(&self.key(delta_ready_key(step))) {
            Ok(m) => String::from_utf8_lossy(&m).into_owned(),
            Err(_) => return Ok(None),
        };
        if let Some((shard_count, root)) = parse_sharded_marker(&marker) {
            return Ok(Some(StepData::Sharded { shard_count, root: root.to_string() }));
        }
        let obj = self.store.get(&self.key(delta_key(step)))?;
        self.counters.fetched(obj.len());
        Ok(Some(StepData::Whole(obj)))
    }

    fn fetch_shard(&self, step: u64, shard: u32) -> Result<Vec<u8>> {
        let obj = self
            .store
            .get(&self.key(delta_shard_key(step, shard)))
            .with_context(|| format!("shard {} of step {}", shard, step))?;
        self.counters.fetched(obj.len());
        Ok(obj)
    }

    fn fetch_anchor(&self, step: u64) -> Result<(Vec<u8>, String)> {
        let obj = self
            .store
            .get(&self.key(anchor_key(step)))
            .with_context(|| format!("anchor {}", step))?;
        let marker = String::from_utf8_lossy(&self.store.get(&self.key(anchor_ready_key(step)))?)
            .into_owned();
        self.counters.fetched(obj.len());
        Ok((obj, marker))
    }

    fn counters(&self) -> TransportCounters {
        self.counters.snapshot()
    }
}

// ------------------------------------------------------ InProcTransport

/// Zero-I/O in-memory backend for tests and benches: a bounded staging
/// window shared by every clone (producer and consumer hold clones of
/// one value). The window is the channel bound: once more than
/// `max_deltas` committed steps are staged, the oldest are evicted
/// under [`retention::plan`] semantics — a consumer that falls behind
/// the window recovers via the anchor slow path, exactly like store
/// retention or relay coalescing.
#[derive(Clone)]
pub struct InProcTransport {
    state: Arc<Mutex<InProcState>>,
    counters: Arc<CounterCell>,
    max_deltas: usize,
    max_anchors: usize,
}

#[derive(Default)]
struct InProcState {
    deltas: BTreeMap<u64, Vec<u8>>,
    shards: BTreeMap<(u64, u32), Vec<u8>>,
    anchors: BTreeMap<u64, Vec<u8>>,
    delta_markers: BTreeMap<u64, String>,
    anchor_markers: BTreeMap<u64, String>,
}

impl InProcTransport {
    /// Default window: 1024 delta steps, 16 anchors.
    pub fn new() -> InProcTransport {
        InProcTransport::with_window(1024, 16)
    }

    /// Explicit staging bounds (≥ 1 each).
    pub fn with_window(max_deltas: usize, max_anchors: usize) -> InProcTransport {
        InProcTransport {
            state: Arc::new(Mutex::new(InProcState::default())),
            counters: Arc::new(CounterCell::default()),
            max_deltas: max_deltas.max(1),
            max_anchors: max_anchors.max(1),
        }
    }

    fn evict(&self, st: &mut InProcState) {
        if st.delta_markers.len() <= self.max_deltas
            && st.anchor_markers.len() <= self.max_anchors
        {
            return;
        }
        let inv = Inventory {
            delta_steps: st.delta_markers.keys().copied().collect(),
            anchor_steps: st.anchor_markers.keys().copied().collect(),
        };
        let policy =
            RetentionPolicy { max_deltas: self.max_deltas, max_anchors: self.max_anchors };
        let (drop_deltas, drop_anchors) = retention::plan(&inv, policy);
        let dropped: HashSet<u64> = drop_deltas.iter().copied().collect();
        for s in &drop_deltas {
            st.deltas.remove(s);
            st.delta_markers.remove(s);
        }
        if !dropped.is_empty() {
            st.shards.retain(|(s, _), _| !dropped.contains(s));
        }
        for s in &drop_anchors {
            st.anchors.remove(s);
            st.anchor_markers.remove(s);
        }
    }
}

impl Default for InProcTransport {
    fn default() -> Self {
        InProcTransport::new()
    }
}

impl SyncTransport for InProcTransport {
    fn name(&self) -> &'static str {
        "in-proc"
    }

    fn publish_frame(&self, id: FrameId, bytes: &[u8]) -> Result<()> {
        let mut st = self.state.lock().unwrap();
        match id {
            FrameId::Delta { step } => {
                st.deltas.insert(step, bytes.to_vec());
            }
            FrameId::Shard { step, shard } => {
                st.shards.insert((step, shard), bytes.to_vec());
            }
            FrameId::Anchor { step } => {
                st.anchors.insert(step, bytes.to_vec());
            }
        }
        self.counters.published(bytes.len());
        Ok(())
    }

    fn publish_marker(&self, id: MarkerId, payload: &str) -> Result<()> {
        let mut st = self.state.lock().unwrap();
        match id {
            MarkerId::Delta(step) => {
                st.delta_markers.insert(step, payload.to_string());
            }
            MarkerId::Anchor(step) => {
                st.anchor_markers.insert(step, payload.to_string());
            }
        }
        self.evict(&mut st);
        self.counters.bump(&self.counters.markers_published);
        Ok(())
    }

    fn latest_ready(&self) -> Result<Inventory> {
        self.counters.bump(&self.counters.inventory_scans);
        let st = self.state.lock().unwrap();
        Ok(Inventory {
            delta_steps: st.delta_markers.keys().copied().collect(),
            anchor_steps: st.anchor_markers.keys().copied().collect(),
        })
    }

    fn fetch_step(&self, step: u64) -> Result<Option<StepData>> {
        let st = self.state.lock().unwrap();
        let marker = match st.delta_markers.get(&step) {
            Some(m) => m.clone(),
            None => return Ok(None),
        };
        if let Some((shard_count, root)) = parse_sharded_marker(&marker) {
            return Ok(Some(StepData::Sharded { shard_count, root: root.to_string() }));
        }
        let obj = st
            .deltas
            .get(&step)
            .cloned()
            .ok_or_else(|| anyhow::anyhow!("delta object for step {} not staged", step))?;
        self.counters.fetched(obj.len());
        Ok(Some(StepData::Whole(obj)))
    }

    fn fetch_shard(&self, step: u64, shard: u32) -> Result<Vec<u8>> {
        let st = self.state.lock().unwrap();
        let obj = st
            .shards
            .get(&(step, shard))
            .cloned()
            .with_context(|| format!("shard {} of step {}", shard, step))?;
        self.counters.fetched(obj.len());
        Ok(obj)
    }

    fn fetch_anchor(&self, step: u64) -> Result<(Vec<u8>, String)> {
        let st = self.state.lock().unwrap();
        let obj = st
            .anchors
            .get(&step)
            .cloned()
            .with_context(|| format!("anchor {}", step))?;
        let marker = st
            .anchor_markers
            .get(&step)
            .cloned()
            .with_context(|| format!("anchor marker {}", step))?;
        self.counters.fetched(obj.len());
        Ok((obj, marker))
    }

    fn counters(&self) -> TransportCounters {
        self.counters.snapshot()
    }
}

// ------------------------------------------------------- RelayTransport

/// The TCP relay fabric (paper Fig. 5), pull-shaped: the producer role
/// pushes frames/markers into an in-process [`Relay`]; the subscriber
/// role connects over TCP, stages everything a background receiver
/// thread reads, and answers the consumer-side trait methods from that
/// staging. A second [`SyncTransport::fetch_shard`] call for the same
/// slot sends a NACK and waits for the relay's per-subscriber
/// retransmit — the wire realization of the repair seam. This promotes
/// the wiring that used to live only in `examples/live_sync.rs` into
/// the library.
pub struct RelayTransport {
    role: RelayRole,
}

enum RelayRole {
    Publisher { relay: Arc<Relay>, counters: Arc<CounterCell> },
    Subscriber(Box<Subscriber>),
}

struct Subscriber {
    state: Arc<(Mutex<SubState>, Condvar)>,
    /// Write half for NACKs (the receiver thread owns the read half).
    conn: Mutex<TcpStream>,
    reader: Option<std::thread::JoinHandle<()>>,
    counters: Arc<CounterCell>,
}

#[derive(Default)]
struct SubState {
    deltas: BTreeMap<u64, DeltaStage>,
    anchors: BTreeMap<u64, AnchorStage>,
    /// Slots already served once: a second fetch means "repair".
    /// Pruned together with `deltas` so a long-lived subscriber stays
    /// bounded.
    served: HashSet<(u64, u32)>,
    closed: bool,
}

impl SubState {
    /// A complete anchor at `anchor_step` supersedes every delta at or
    /// below it (the slow path restarts from the newest anchor), so
    /// their staged frames — and their served-slot bookkeeping — can
    /// go. This is what keeps a long-running subscriber's memory
    /// bounded by the anchor interval instead of the stream length.
    fn prune_superseded(&mut self, anchor_step: u64) {
        self.deltas.retain(|&s, _| s > anchor_step);
        self.served.retain(|&(s, _)| s > anchor_step);
    }

    /// Enforce the staging window after an insert, keeping `served`
    /// consistent with the retained steps.
    fn trim(&mut self) {
        let mut popped = false;
        while self.deltas.len() > STAGE_STEPS {
            self.deltas.pop_first();
            popped = true;
        }
        while self.anchors.len() > STAGE_ANCHORS {
            self.anchors.pop_first();
        }
        if popped {
            if let Some((&min_staged, _)) = self.deltas.iter().next() {
                self.served.retain(|&(s, _)| s >= min_staged);
            }
        }
    }
}

#[derive(Default)]
struct DeltaStage {
    marker: Option<String>,
    /// shard index → (frame bytes, arrival generation).
    frames: BTreeMap<u32, (Vec<u8>, u64)>,
}

#[derive(Default)]
struct AnchorStage {
    marker: Option<String>,
    object: Option<Vec<u8>>,
}

impl DeltaStage {
    /// Shards this step's marker promises (1 for unsharded).
    fn expected_shards(&self) -> Option<u32> {
        let m = self.marker.as_deref()?;
        Some(parse_sharded_marker(m).map(|(s, _)| s).unwrap_or(1))
    }

    fn complete(&self) -> bool {
        match self.expected_shards() {
            Some(s) => (0..s).all(|i| self.frames.contains_key(&i)),
            None => false,
        }
    }
}

/// Staged delta steps retained by a subscriber before the oldest are
/// dropped (a consumer that lags further recovers via the anchor).
const STAGE_STEPS: usize = 4096;
const STAGE_ANCHORS: usize = 32;

impl RelayTransport {
    /// Producer role over an in-process relay handle.
    pub fn publisher(relay: Arc<Relay>) -> RelayTransport {
        RelayTransport {
            role: RelayRole::Publisher { relay, counters: Arc::new(CounterCell::default()) },
        }
    }

    /// Subscriber role: connect to a relay port and start staging.
    pub fn subscribe(port: u16) -> Result<RelayTransport> {
        let stream = tcp::connect_local(port)?;
        let rstream = stream.try_clone()?;
        let state: Arc<(Mutex<SubState>, Condvar)> = Arc::new(Default::default());
        let reader = spawn_receiver(rstream, state.clone());
        Ok(RelayTransport {
            role: RelayRole::Subscriber(Box::new(Subscriber {
                state,
                conn: Mutex::new(stream),
                reader: Some(reader),
                counters: Arc::new(CounterCell::default()),
            })),
        })
    }

    /// Publisher role: broadcast an orderly end-of-stream.
    pub fn close(&self) {
        if let RelayRole::Publisher { relay, .. } = &self.role {
            relay.publish(Frame { kind: kind::CLOSE, payload: Vec::new() });
        }
    }

    /// Subscriber role: true once the stream ended (CLOSE or socket
    /// error). Always false for the producer role.
    pub fn stream_closed(&self) -> bool {
        match &self.role {
            RelayRole::Subscriber(sub) => sub.state.0.lock().unwrap().closed,
            RelayRole::Publisher { .. } => false,
        }
    }

    fn pub_side(&self) -> Result<(&Arc<Relay>, &Arc<CounterCell>)> {
        match &self.role {
            RelayRole::Publisher { relay, counters } => Ok((relay, counters)),
            RelayRole::Subscriber(_) => {
                bail!("subscriber-side relay transport cannot publish")
            }
        }
    }

    fn sub_side(&self) -> Result<&Subscriber> {
        match &self.role {
            RelayRole::Subscriber(sub) => Ok(sub),
            RelayRole::Publisher { .. } => {
                bail!("publisher-side relay transport cannot fetch")
            }
        }
    }
}

impl Drop for RelayTransport {
    fn drop(&mut self) {
        if let RelayRole::Subscriber(sub) = &mut self.role {
            let _ = sub.conn.lock().unwrap().shutdown(Shutdown::Both);
            if let Some(h) = sub.reader.take() {
                let _ = h.join();
            }
        }
    }
}

/// Background receiver: stages PATCH/ANCHOR/MARKER frames from the
/// relay stream. Frames identify themselves (container header / PLSA
/// anchor header / marker payload), so arrival order within a step
/// does not matter.
fn spawn_receiver(
    mut stream: TcpStream,
    state: Arc<(Mutex<SubState>, Condvar)>,
) -> std::thread::JoinHandle<()> {
    std::thread::spawn(move || loop {
        let frame = match tcp::read_frame(&mut stream) {
            Ok(f) => f,
            Err(_) => {
                let (lock, cv) = &*state;
                lock.lock().unwrap().closed = true;
                cv.notify_all();
                return;
            }
        };
        let (lock, cv) = &*state;
        match frame.kind {
            kind::PATCH => {
                if let Ok(meta) = container::peek_meta(&frame.payload) {
                    let mut st = lock.lock().unwrap();
                    let stage = st.deltas.entry(meta.step).or_default();
                    let generation = stage
                        .frames
                        .get(&meta.shard_index)
                        .map(|(_, g)| *g)
                        .unwrap_or(0)
                        + 1;
                    stage.frames.insert(meta.shard_index, (frame.payload, generation));
                    st.trim();
                    cv.notify_all();
                }
            }
            kind::ANCHOR => {
                // anchors travel as the store-plane PLSA object, so the
                // step rides in the header
                if frame.payload.len() >= 20 && &frame.payload[0..4] == b"PLSA" {
                    let step = u64::from_le_bytes(frame.payload[4..12].try_into().unwrap());
                    let mut st = lock.lock().unwrap();
                    let stage = st.anchors.entry(step).or_default();
                    stage.object = Some(frame.payload);
                    if stage.marker.is_some() {
                        st.prune_superseded(step);
                    }
                    st.trim();
                    cv.notify_all();
                }
            }
            kind::MARKER => {
                if let Ok((is_anchor, step, marker)) = tcp::parse_marker_frame(&frame.payload) {
                    let mut st = lock.lock().unwrap();
                    if is_anchor {
                        let stage = st.anchors.entry(step).or_default();
                        stage.marker = Some(marker);
                        if stage.object.is_some() {
                            st.prune_superseded(step);
                        }
                    } else {
                        st.deltas.entry(step).or_default().marker = Some(marker);
                    }
                    st.trim();
                    cv.notify_all();
                }
            }
            kind::CLOSE => {
                lock.lock().unwrap().closed = true;
                cv.notify_all();
                return;
            }
            _ => {}
        }
    })
}

impl SyncTransport for RelayTransport {
    fn name(&self) -> &'static str {
        "relay"
    }

    fn publish_frame(&self, id: FrameId, bytes: &[u8]) -> Result<()> {
        let (relay, counters) = self.pub_side()?;
        let kind_ = if id.is_anchor() { kind::ANCHOR } else { kind::PATCH };
        relay.publish(Frame { kind: kind_, payload: bytes.to_vec() });
        counters.published(bytes.len());
        Ok(())
    }

    fn publish_marker(&self, id: MarkerId, payload: &str) -> Result<()> {
        let (relay, counters) = self.pub_side()?;
        relay.publish(Frame {
            kind: kind::MARKER,
            payload: tcp::marker_frame_payload(id.is_anchor(), id.step(), payload),
        });
        counters.bump(&counters.markers_published);
        Ok(())
    }

    fn latest_ready(&self) -> Result<Inventory> {
        let sub = self.sub_side()?;
        sub.counters.bump(&sub.counters.inventory_scans);
        let st = sub.state.0.lock().unwrap();
        Ok(Inventory {
            // only fully-staged steps are committed from this
            // subscriber's point of view: a coalesced-away step simply
            // never becomes visible, and the consumer anchors past it
            delta_steps: st
                .deltas
                .iter()
                .filter(|(_, d)| d.complete())
                .map(|(&s, _)| s)
                .collect(),
            anchor_steps: st
                .anchors
                .iter()
                .filter(|(_, a)| a.marker.is_some() && a.object.is_some())
                .map(|(&s, _)| s)
                .collect(),
        })
    }

    fn fetch_step(&self, step: u64) -> Result<Option<StepData>> {
        let sub = self.sub_side()?;
        let st = sub.state.0.lock().unwrap();
        let stage = match st.deltas.get(&step) {
            Some(d) => d,
            None => return Ok(None),
        };
        let marker = match &stage.marker {
            Some(m) => m.clone(),
            None => return Ok(None),
        };
        if let Some((shard_count, root)) = parse_sharded_marker(&marker) {
            return Ok(Some(StepData::Sharded { shard_count, root: root.to_string() }));
        }
        let obj = stage
            .frames
            .get(&0)
            .map(|(b, _)| b.clone())
            .ok_or_else(|| anyhow::anyhow!("delta frame for step {} not staged", step))?;
        sub.counters.fetched(obj.len());
        Ok(Some(StepData::Whole(obj)))
    }

    fn fetch_shard(&self, step: u64, shard: u32) -> Result<Vec<u8>> {
        let sub = self.sub_side()?;
        let (lock, cv) = &*sub.state;
        let (first, staged) = {
            let mut st = lock.lock().unwrap();
            let first = st.served.insert((step, shard));
            let staged = st
                .deltas
                .get(&step)
                .and_then(|d| d.frames.get(&shard))
                .map(|(b, g)| (b.clone(), *g));
            (first, staged)
        };
        if first {
            if let Some((bytes, _)) = staged {
                sub.counters.fetched(bytes.len());
                return Ok(bytes);
            }
        }
        // repair (or a frame that never arrived): NACK the slot and
        // wait for the relay's per-subscriber retransmit to land as a
        // new generation
        let base_generation = staged.map(|(_, g)| g).unwrap_or(0);
        {
            let mut conn = sub.conn.lock().unwrap();
            tcp::write_frame(
                &mut conn,
                &Frame { kind: kind::NACK, payload: tcp::shard_ack_payload(step, shard) },
            )
            .context("sending shard NACK")?;
            sub.counters.bump(&sub.counters.nacks_sent);
        }
        let deadline = Instant::now() + NACK_TIMEOUT;
        let mut st = lock.lock().unwrap();
        loop {
            if let Some((bytes, g)) = st.deltas.get(&step).and_then(|d| d.frames.get(&shard)) {
                if *g > base_generation {
                    let out = bytes.clone();
                    sub.counters.fetched(out.len());
                    return Ok(out);
                }
            }
            if st.closed {
                bail!("relay stream closed awaiting shard {} of step {}", shard, step);
            }
            let now = Instant::now();
            if now >= deadline {
                bail!("timed out awaiting retransmit of shard {} step {}", shard, step);
            }
            st = cv.wait_timeout(st, deadline - now).unwrap().0;
        }
    }

    fn fetch_anchor(&self, step: u64) -> Result<(Vec<u8>, String)> {
        let sub = self.sub_side()?;
        let st = sub.state.0.lock().unwrap();
        let stage = st.anchors.get(&step).with_context(|| format!("anchor {}", step))?;
        match (&stage.object, &stage.marker) {
            (Some(obj), Some(marker)) => {
                sub.counters.fetched(obj.len());
                Ok((obj.clone(), marker.clone()))
            }
            _ => bail!("anchor {} not fully staged", step),
        }
    }

    fn counters(&self) -> TransportCounters {
        match &self.role {
            RelayRole::Publisher { counters, .. } => counters.snapshot(),
            RelayRole::Subscriber(sub) => sub.counters.snapshot(),
        }
    }
}

// ---------------------------------------------- FaultInjectingTransport

/// What a [`FaultInjectingTransport`] may do to consumer-side traffic.
/// All decisions are pure functions of `(seed, step, shard)` — never
/// of call order — so a failing run replays exactly.
#[derive(Debug, Clone, Copy, Default)]
pub struct FaultPlan {
    /// Probability a shard frame is mangled on its *first* serve
    /// (truncated below the container header minimum, so decode fails
    /// deterministically and the consumer's single-shard refetch
    /// heals it). Repairs always pass through clean.
    pub corrupt_shard_prob: f64,
    /// Probability the first fetch of a shard errors outright (a lost
    /// frame); the refetch succeeds.
    pub drop_shard_prob: f64,
    /// Probability the newest committed step is hidden from one
    /// [`SyncTransport::latest_ready`] snapshot (a reordered/late
    /// marker); the next poll sees it.
    pub delay_marker_prob: f64,
    /// Force-corrupt exactly this slot (first serve), independent of
    /// the probabilities — the targeted §J.5 recovery scenario.
    pub target: Option<(u64, u32)>,
}

/// Decorator that deterministically corrupts, drops, and delays
/// consumer-side traffic of any inner backend, so §J.5 self-healing is
/// exercisable on *every* fabric. Producer-side calls pass through
/// untouched.
pub struct FaultInjectingTransport<T> {
    inner: T,
    plan: FaultPlan,
    seed: u64,
    served: Mutex<HashSet<(u64, u32)>>,
    delayed: Mutex<HashSet<u64>>,
    injected: AtomicU64,
}

const SALT_CORRUPT: u64 = 0xC0;
const SALT_DROP: u64 = 0xD0;
const SALT_DELAY: u64 = 0xDE;

impl<T: SyncTransport> FaultInjectingTransport<T> {
    pub fn new(inner: T, seed: u64, plan: FaultPlan) -> FaultInjectingTransport<T> {
        FaultInjectingTransport {
            inner,
            plan,
            seed,
            served: Mutex::new(HashSet::new()),
            delayed: Mutex::new(HashSet::new()),
            injected: AtomicU64::new(0),
        }
    }

    /// Convenience: corrupt exactly one `(step, shard)` slot.
    pub fn targeting(inner: T, step: u64, shard: u32) -> FaultInjectingTransport<T> {
        FaultInjectingTransport::new(
            inner,
            0,
            FaultPlan { target: Some((step, shard)), ..FaultPlan::default() },
        )
    }

    pub fn inner(&self) -> &T {
        &self.inner
    }

    /// Faults injected so far.
    pub fn injected(&self) -> u64 {
        self.injected.load(Ordering::Relaxed)
    }

    /// Deterministic uniform [0,1) from (seed, step, shard, salt).
    fn roll(&self, step: u64, shard: u32, salt: u64) -> f64 {
        let mut s = self
            .seed
            .wrapping_mul(0x9E3779B97F4A7C15)
            ^ step.wrapping_mul(0xA24BAED4963EE407)
            ^ ((shard as u64) << 32)
            ^ salt;
        (splitmix64(&mut s) >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }
}

impl<T: SyncTransport> SyncTransport for FaultInjectingTransport<T> {
    fn name(&self) -> &'static str {
        "fault-injected"
    }

    fn publish_frame(&self, id: FrameId, bytes: &[u8]) -> Result<()> {
        self.inner.publish_frame(id, bytes)
    }

    fn publish_marker(&self, id: MarkerId, payload: &str) -> Result<()> {
        self.inner.publish_marker(id, payload)
    }

    fn latest_ready(&self) -> Result<Inventory> {
        let mut inv = self.inner.latest_ready()?;
        if self.plan.delay_marker_prob > 0.0 {
            if let Some(&head) = inv.delta_steps.last() {
                if self.roll(head, 0, SALT_DELAY) < self.plan.delay_marker_prob
                    && self.delayed.lock().unwrap().insert(head)
                {
                    self.injected.fetch_add(1, Ordering::Relaxed);
                    inv.delta_steps.pop();
                }
            }
        }
        Ok(inv)
    }

    fn fetch_step(&self, step: u64) -> Result<Option<StepData>> {
        self.inner.fetch_step(step)
    }

    fn fetch_shard(&self, step: u64, shard: u32) -> Result<Vec<u8>> {
        let first = self.served.lock().unwrap().insert((step, shard));
        if first
            && self.plan.drop_shard_prob > 0.0
            && self.roll(step, shard, SALT_DROP) < self.plan.drop_shard_prob
        {
            self.injected.fetch_add(1, Ordering::Relaxed);
            bail!("injected drop of shard {} step {}", shard, step);
        }
        let mut bytes = self.inner.fetch_shard(step, shard)?;
        let corrupt = self.plan.target == Some((step, shard))
            || (self.plan.corrupt_shard_prob > 0.0
                && self.roll(step, shard, SALT_CORRUPT) < self.plan.corrupt_shard_prob);
        if first && corrupt {
            self.injected.fetch_add(1, Ordering::Relaxed);
            // truncate below the container header minimum: decode fails
            // deterministically, never "accidentally valid" bytes
            bytes.truncate(8.min(bytes.len()));
        }
        Ok(bytes)
    }

    fn fetch_anchor(&self, step: u64) -> Result<(Vec<u8>, String)> {
        self.inner.fetch_anchor(step)
    }

    fn counters(&self) -> TransportCounters {
        let mut c = self.inner.counters();
        c.faults_injected += self.injected.load(Ordering::Relaxed);
        c
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn object_store_transport_uses_the_store_key_scheme() {
        let store = ObjectStore::temp("transport_store").unwrap();
        let t = ObjectStoreTransport::new(store.clone(), "sync/");
        assert_eq!(t.prefix, "sync");
        t.publish_frame(FrameId::Delta { step: 3 }, b"obj3").unwrap();
        t.publish_frame(FrameId::Shard { step: 4, shard: 1 }, b"s41").unwrap();
        t.publish_frame(FrameId::Anchor { step: 0 }, b"anch").unwrap();
        t.publish_marker(MarkerId::Anchor(0), "m0").unwrap();
        assert_eq!(store.get("sync/delta_00000003.bin").unwrap(), b"obj3");
        assert_eq!(store.get("sync/delta_00000004.s001.bin").unwrap(), b"s41");
        assert_eq!(store.get("sync/anchor_00000000.bin").unwrap(), b"anch");
        // no delta marker yet → fetch_step sees the §J.5 signal
        assert_eq!(t.fetch_step(3).unwrap(), None);
        t.publish_marker(MarkerId::Delta(3), &"ab".repeat(32)).unwrap();
        assert_eq!(t.fetch_step(3).unwrap(), Some(StepData::Whole(b"obj3".to_vec())));
        t.publish_marker(MarkerId::Delta(4), &sharded_marker(2, &"cd".repeat(32)))
            .unwrap();
        assert_eq!(
            t.fetch_step(4).unwrap(),
            Some(StepData::Sharded { shard_count: 2, root: "cd".repeat(32) })
        );
        assert_eq!(t.fetch_shard(4, 1).unwrap(), b"s41");
        assert_eq!(t.fetch_anchor(0).unwrap(), (b"anch".to_vec(), "m0".to_string()));
        let inv = t.latest_ready().unwrap();
        assert_eq!(inv.delta_steps, vec![3, 4]);
        assert_eq!(inv.anchor_steps, vec![0]);
        assert_eq!(t.counters().inventory_scans, 1);
        std::fs::remove_dir_all(store.root()).unwrap();
    }

    #[test]
    fn inproc_window_evicts_with_chain_base_kept() {
        let t = InProcTransport::with_window(4, 2);
        t.publish_frame(FrameId::Anchor { step: 0 }, b"a0").unwrap();
        t.publish_marker(MarkerId::Anchor(0), "m0").unwrap();
        for step in 1..=10u64 {
            t.publish_frame(FrameId::Delta { step }, format!("d{}", step).as_bytes())
                .unwrap();
            t.publish_marker(MarkerId::Delta(step), &"ab".repeat(32)).unwrap();
            if step % 5 == 0 {
                t.publish_frame(FrameId::Anchor { step }, b"a").unwrap();
                t.publish_marker(MarkerId::Anchor(step), "m").unwrap();
            }
        }
        let inv = t.latest_ready().unwrap();
        assert_eq!(inv.delta_steps, vec![7, 8, 9, 10], "window keeps the newest 4");
        // anchors 5 and 10 retained; anchor 5 is the chain base for
        // delta 7 even though only 2 anchors fit
        assert!(inv.anchor_steps.contains(&10));
        assert!(inv.anchor_steps.iter().any(|&a| a <= 7));
        assert_eq!(t.fetch_step(2).unwrap(), None, "evicted step reads as replaced");
        assert_eq!(
            t.fetch_step(8).unwrap(),
            Some(StepData::Whole(b"d8".to_vec()))
        );
    }

    #[test]
    fn clones_share_inproc_state() {
        let producer = InProcTransport::new();
        let consumer = producer.clone();
        producer.publish_frame(FrameId::Delta { step: 1 }, b"x").unwrap();
        producer.publish_marker(MarkerId::Delta(1), &"ee".repeat(32)).unwrap();
        assert_eq!(consumer.latest_ready().unwrap().delta_steps, vec![1]);
        assert_eq!(consumer.fetch_step(1).unwrap(), Some(StepData::Whole(b"x".to_vec())));
    }

    #[test]
    fn fault_decorator_is_deterministic_and_heals_on_refetch() {
        let make = || {
            let inner = InProcTransport::new();
            inner
                .publish_frame(FrameId::Shard { step: 5, shard: 2 }, &vec![7u8; 256])
                .unwrap();
            inner
        };
        // targeted corruption: first serve truncated, repair clean
        let t = FaultInjectingTransport::targeting(make(), 5, 2);
        let first = t.fetch_shard(5, 2).unwrap();
        assert_eq!(first.len(), 8, "first serve must be truncated");
        let second = t.fetch_shard(5, 2).unwrap();
        assert_eq!(second, vec![7u8; 256], "repair must pass through clean");
        assert_eq!(t.injected(), 1);
        assert_eq!(t.counters().faults_injected, 1);
        // zero probabilities, no target → bitwise passthrough
        let clean = FaultInjectingTransport::new(make(), 123, FaultPlan::default());
        assert_eq!(clean.fetch_shard(5, 2).unwrap(), vec![7u8; 256]);
        assert_eq!(clean.injected(), 0);
        // decisions are a pure function of (seed, step, shard)
        let a = FaultInjectingTransport::new(
            make(),
            42,
            FaultPlan { corrupt_shard_prob: 0.5, ..FaultPlan::default() },
        );
        let b = FaultInjectingTransport::new(
            make(),
            42,
            FaultPlan { corrupt_shard_prob: 0.5, ..FaultPlan::default() },
        );
        assert_eq!(a.fetch_shard(5, 2).unwrap(), b.fetch_shard(5, 2).unwrap());
    }

    #[test]
    fn fault_decorator_drop_errors_once_then_serves() {
        let inner = InProcTransport::new();
        inner.publish_frame(FrameId::Shard { step: 9, shard: 0 }, b"frame").unwrap();
        let t = FaultInjectingTransport::new(
            inner,
            7,
            FaultPlan { drop_shard_prob: 1.0, ..FaultPlan::default() },
        );
        assert!(t.fetch_shard(9, 0).is_err(), "first fetch must drop");
        assert_eq!(t.fetch_shard(9, 0).unwrap(), b"frame", "refetch must serve");
        assert_eq!(t.injected(), 1);
    }

    #[test]
    fn fault_decorator_delays_head_marker_once() {
        let inner = InProcTransport::new();
        for step in 1..=3u64 {
            inner.publish_frame(FrameId::Delta { step }, b"d").unwrap();
            inner.publish_marker(MarkerId::Delta(step), &"ab".repeat(32)).unwrap();
        }
        let t = FaultInjectingTransport::new(
            inner,
            1,
            FaultPlan { delay_marker_prob: 1.0, ..FaultPlan::default() },
        );
        assert_eq!(t.latest_ready().unwrap().delta_steps, vec![1, 2], "head hidden once");
        assert_eq!(t.latest_ready().unwrap().delta_steps, vec![1, 2, 3], "then visible");
    }

    #[test]
    fn relay_transport_roundtrips_markers_and_frames() {
        let relay = Arc::new(Relay::start().unwrap());
        let producer = RelayTransport::publisher(relay.clone());
        let consumer = RelayTransport::subscribe(relay.port).unwrap();
        // wrong-side calls error instead of hanging
        assert!(producer.latest_ready().is_err());
        assert!(consumer.publish_marker(MarkerId::Delta(1), "x").is_err());
        // a PLSA-framed anchor + marker, then an unsharded container
        let mut anchor = Vec::new();
        anchor.extend_from_slice(b"PLSA");
        anchor.extend_from_slice(&0u64.to_le_bytes());
        anchor.extend_from_slice(&0u64.to_le_bytes());
        anchor.extend_from_slice(b"payload");
        producer.publish_frame(FrameId::Anchor { step: 0 }, &anchor).unwrap();
        producer.publish_marker(MarkerId::Anchor(0), "anchor-marker").unwrap();
        let patch = container::Patch {
            step: 1,
            total_params: 64,
            result_hash: "ab".repeat(32),
            chunk_elems: 64,
            ..Default::default()
        };
        let obj = container::encode(
            &patch,
            &crate::sparse::synthetic_layout(64, 64),
            container::EncodeOpts::default(),
        )
        .unwrap();
        producer.publish_frame(FrameId::Delta { step: 1 }, &obj).unwrap();
        producer.publish_marker(MarkerId::Delta(1), &"ab".repeat(32)).unwrap();
        // staging is asynchronous: poll until committed
        let deadline = Instant::now() + Duration::from_secs(10);
        loop {
            let inv = consumer.latest_ready().unwrap();
            if inv.delta_steps == vec![1] && inv.anchor_steps == vec![0] {
                break;
            }
            assert!(Instant::now() < deadline, "staging never completed");
            std::thread::sleep(Duration::from_millis(5));
        }
        assert_eq!(consumer.fetch_step(1).unwrap(), Some(StepData::Whole(obj)));
        assert_eq!(
            consumer.fetch_anchor(0).unwrap(),
            (anchor, "anchor-marker".to_string())
        );
        assert_eq!(consumer.fetch_step(2).unwrap(), None);
        producer.close();
        let deadline = Instant::now() + Duration::from_secs(10);
        while !consumer.stream_closed() {
            assert!(Instant::now() < deadline, "close never observed");
            std::thread::sleep(Duration::from_millis(5));
        }
        drop(consumer);
        relay.stop();
    }

    #[test]
    fn marker_grammar_roundtrip() {
        assert_eq!(sharded_marker(4, &"ab".repeat(32)), format!("v3:4:{}", "ab".repeat(32)));
        let m = sharded_marker(4, &"ab".repeat(32));
        let (s, r) = parse_sharded_marker(&m).unwrap();
        assert_eq!((s, r), (4, "ab".repeat(32).as_str()));
        assert!(parse_sharded_marker(&"ab".repeat(32)).is_none(), "bare root is unsharded");
        assert!(parse_sharded_marker("v3:1:root").is_none());
        assert!(parse_sharded_marker(&format!("v3:99999:{}", "ab".repeat(32))).is_none());
        assert!(parse_sharded_marker("v3:4:short").is_none());
    }
}
