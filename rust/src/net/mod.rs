//! Networking substrate: a deterministic bandwidth/latency model used by
//! every bench (Fig. 1, Table 14), a real framed TCP transport and relay
//! (paper Fig. 5's relay network), relay→relay chaining ([`node`]) that
//! composes relays into distribution trees for >100-subscriber fan-out,
//! and the [`transport`] module — the `SyncTransport` trait that runs
//! the whole PULSESync plane over the object store, the relay (star or
//! chained), an in-proc staging map, or fault-injected wrappers of any
//! of them. The [`control`] module adds the operational layer: cluster
//! membership (JOIN/HEARTBEAT), automatic fan-out planning from the
//! measured leaf count ([`crate::coordinator::planner`]), and live
//! re-parenting of relay subtrees when a hop dies. The [`chaos`]
//! module injects seeded wire-level faults (partial writes, mid-frame
//! resets, corruption, latency, one-way partitions) under any of those
//! layers, so the recovery machinery is exercised where commodity
//! networks actually fail. The [`store`] module is the store plane: a
//! networked GET/PUT/LIST/STAT object server over the same framing, a
//! `RemoteStoreTransport` that runs the sync protocol against it, and
//! `CachingStore` hops that turn a tree of cold consumers into
//! O(depth) origin reads — a CDN for weight patches.

pub mod chaos;
pub mod control;
pub mod node;
pub mod relay;
pub mod store;
pub mod tcp;
pub mod transport;

/// A point-to-point link with a bandwidth/latency cost model.
/// `transfer_time(bytes)` is the paper's accounting primitive: all of
/// Fig. 1 / Fig. 11 / Table 14 are this arithmetic on measured payloads.
#[derive(Debug, Clone, Copy)]
pub struct SimLink {
    /// Link rate in bits per second.
    pub bandwidth_bps: f64,
    /// One-way latency in seconds.
    pub latency_s: f64,
}

impl SimLink {
    pub fn mbit(mbps: f64) -> SimLink {
        SimLink { bandwidth_bps: mbps * 1e6, latency_s: 0.0 }
    }

    pub fn gbit(gbps: f64) -> SimLink {
        SimLink { bandwidth_bps: gbps * 1e9, latency_s: 0.0 }
    }

    /// Seconds to move `bytes` over this link.
    pub fn transfer_time(&self, bytes: u64) -> f64 {
        self.latency_s + (bytes as f64 * 8.0) / self.bandwidth_bps
    }
}

/// End-to-end transfer time with codec overheads (paper Eq. 26):
///   T = T_encode + S/(R·B) + T_decode
/// where `payload` is the uncompressed sparse payload, `ratio` the codec
/// compression ratio, and throughputs are in MB/s.
pub fn total_transfer_time(
    payload_bytes: u64,
    ratio: f64,
    encode_mbps: f64,
    decode_mbps: f64,
    link: SimLink,
) -> f64 {
    let s = payload_bytes as f64;
    let t_enc = s / (encode_mbps * 1e6);
    let t_dec = s / (decode_mbps * 1e6);
    let wire = (s / ratio).ceil() as u64;
    t_enc + link.transfer_time(wire) + t_dec
}

/// Crossover bandwidth between codecs A and B (paper Eq. 27), in bps.
/// Below the returned rate the higher-ratio codec wins.
pub fn crossover_bandwidth(
    payload_bytes: u64,
    ratio_a: f64,
    enc_dec_secs_a: f64,
    ratio_b: f64,
    enc_dec_secs_b: f64,
) -> f64 {
    let s = payload_bytes as f64 * 8.0; // bits
    let num = s * (1.0 / ratio_b - 1.0 / ratio_a);
    let den = enc_dec_secs_a - enc_dec_secs_b;
    num / den
}

/// Compute utilization under periodic communication (Fig. 1): a worker
/// computes for `compute_s` seconds, then must move `bytes`; utilization
/// is compute / (compute + comm) assuming no overlap.
pub fn utilization(compute_s: f64, bytes: u64, link: SimLink) -> f64 {
    let comm = link.transfer_time(bytes);
    compute_s / (compute_s + comm)
}

/// Bandwidth (bps) needed to reach `target` utilization for a payload
/// moved every `compute_s` seconds (the "0.2 / 2.6 / 20 / 44 Gbit/s"
/// thresholds quoted in Fig. 1).
pub fn bandwidth_for_utilization(compute_s: f64, bytes: u64, target: f64) -> f64 {
    // target = c / (c + bytes*8/B)  ⇒  B = bytes*8 * target / (c (1-target))
    (bytes as f64 * 8.0) * target / (compute_s * (1.0 - target))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transfer_time_linear() {
        let l = SimLink::mbit(400.0);
        assert!((l.transfer_time(50_000_000) - 1.0).abs() < 1e-9);
        let g = SimLink::gbit(1.0);
        assert!((g.transfer_time(125_000_000) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn fig1_thresholds_reproduce() {
        // Paper Fig. 1: with a 50 s compute interval, full 14 GB BF16
        // sync needs ~20 Gbit/s for 90% utilization; a 140 MB PULSESync
        // patch needs ~0.2 Gbit/s.
        let full = bandwidth_for_utilization(50.0, 14_000_000_000, 0.9) / 1e9;
        assert!((full - 20.16).abs() < 0.5, "full={}", full);
        let patch = bandwidth_for_utilization(50.0, 140_000_000, 0.9) / 1e9;
        assert!((patch - 0.2016).abs() < 0.01, "patch={}", patch);
        // Right panel: DiLoCo 30.5 GB → ~44 Gbit/s; PULSELoCo 1.77 GB →
        // ~2.6 Gbit/s.
        let diloco = bandwidth_for_utilization(50.0, 30_500_000_000, 0.9) / 1e9;
        assert!((diloco - 43.9).abs() < 1.0, "diloco={}", diloco);
        let ploco = bandwidth_for_utilization(50.0, 1_770_000_000, 0.9) / 1e9;
        assert!((ploco - 2.55).abs() < 0.1, "ploco={}", ploco);
    }

    #[test]
    fn utilization_monotone_in_bandwidth() {
        let bytes = 1_000_000_000;
        let mut last = 0.0;
        for mbps in [10.0, 100.0, 1000.0, 10_000.0] {
            let u = utilization(50.0, bytes, SimLink::mbit(mbps));
            assert!(u > last);
            last = u;
        }
        assert!(last < 1.0);
    }

    #[test]
    fn crossover_formula_consistent() {
        // At the crossover bandwidth the two codecs tie.
        let payload = 194_000_000u64;
        let (ra, ta) = (2.40, payload as f64 / 830e6 + payload as f64 / 1484e6); // lz4
        let (rb, tb) = (3.33, payload as f64 / 534e6 + payload as f64 / 851e6); // zstd-1
        let b = crossover_bandwidth(payload, rb, tb, ra, ta);
        let link = SimLink { bandwidth_bps: b, latency_s: 0.0 };
        let t_a = ta + link.transfer_time((payload as f64 / ra) as u64);
        let t_b = tb + link.transfer_time((payload as f64 / rb) as u64);
        assert!((t_a - t_b).abs() / t_a < 1e-3, "{} vs {}", t_a, t_b);
        // and it lands in the high-hundreds-of-Mbit regime (§H.4.5)
        assert!(b > 2e8 && b < 3e9, "crossover {} bps", b);
    }
}
