//! Seeded wire-level fault injection for the TCP sync plane.
//!
//! [`crate::net::transport::FaultInjectingTransport`] corrupts
//! *decoded* fetches — useful for exercising the consumer's repair
//! seams, blind to everything below them. This module injects faults
//! where commodity networks actually fail: on the socket, under the
//! framing. A [`FaultyStream`] wraps a `TcpStream` and deterministically
//! injects
//!
//! * **partial writes** — a short count handed back mid-buffer, so the
//!   framing layer's `write_all` retry loop really runs;
//! * **mid-frame resets** — the connection is shut down both ways and
//!   the write errors, tearing the frame in flight;
//! * **byte corruption** — one bit flipped in a frame *payload* (never
//!   the 5-byte header: header damage would silently desync the framing
//!   for the life of the connection, a failure mode this module models
//!   with resets instead — content damage is what payload corruption
//!   models, and every payload is covered end to end by container
//!   hashes, the hash tree, or the marker-frame checksum);
//! * **added latency** — a real sleep before the bytes move;
//! * **one-way partitions** — writes silently swallowed for a window,
//!   engaged and disengaged only at frame boundaries (a 5-byte header
//!   write) so the peer sees missing frames, never torn ones.
//!
//! Every decision is a pure function of `(seed, connection, op)` via
//! [`crate::util::rng::splitmix64`] — no wall-clock entropy, so a
//! failing chaos run replays from its seed. The state-damaging faults
//! (reset, corruption, partition) draw from a shared **fault budget**;
//! once it drains the wire goes permanently quiet, which is how the
//! chaos integration suite guarantees convergence: fault freely, then
//! publish clean steps past the damage. Partial writes and latency are
//! self-healing by construction and stay outside the budget.
//!
//! A [`Wire`] is the drop-in connection type the relay, node, and
//! control planes carry instead of a bare `TcpStream`: `Plain` is a
//! zero-cost passthrough, `Chaos` wraps a [`FaultyStream`]. Install
//! chaos on a layer by passing a [`ChaosConfig`] to
//! `Relay::start_with_chaos`, `RelayNode::{detached,join}_with_chaos`,
//! or `ControlPlane::start_with_chaos`; configuration from the
//! environment comes from [`ChaosConfig::from_env`]
//! (`PULSE_CHAOS_SEED`, `PULSE_CHAOS_BUDGET`).

use std::io::{self, Read, Write};
use std::net::{Shutdown, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicI64, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::net::tcp::FRAME_HEADER_LEN;
use crate::util::rng::splitmix64;

const SALT_PARTIAL: u64 = 0x5041_5254;
const SALT_RESET: u64 = 0x5245_5354;
const SALT_CORRUPT: u64 = 0xC0_44;
const SALT_DELAY: u64 = 0xDE_1A;
const SALT_PARTITION: u64 = 0x1_3A97;

/// Fault mix for one chaos domain. Probabilities are per-mille per
/// write op (0 disables a fault class); the config is `Clone` and all
/// clones share the same fault budget and connection counter, so one
/// config threaded through a whole tree behaves as one domain.
#[derive(Clone, Debug)]
pub struct ChaosConfig {
    /// Root seed; every injected fault is a pure function of
    /// `(seed, connection, op)`.
    pub seed: u64,
    /// Per-mille chance a write returns a short count.
    pub partial_write_mille: u32,
    /// Per-mille chance a write tears the connection down mid-frame.
    pub reset_mille: u32,
    /// Per-mille chance one payload bit is flipped in flight.
    pub corrupt_mille: u32,
    /// Per-mille chance a write sleeps for [`ChaosConfig::delay`].
    pub delay_mille: u32,
    /// Added latency when a delay fault fires.
    pub delay: Duration,
    /// Per-mille chance (evaluated at frame boundaries) that a one-way
    /// partition opens.
    pub partition_mille: u32,
    /// Frames a one-way partition swallows once open.
    pub partition_frames: u32,
    /// Shared budget for state-damaging faults (reset, corruption,
    /// partition): each one spends a token, and at zero the wire goes
    /// permanently quiet. `None` = unlimited.
    budget: Option<Arc<AtomicI64>>,
    /// Per-domain connection counter salting each wrapped stream.
    next_conn: Arc<AtomicU64>,
}

impl ChaosConfig {
    /// All fault classes disabled; enable them field by field.
    pub fn quiet(seed: u64) -> ChaosConfig {
        ChaosConfig {
            seed,
            partial_write_mille: 0,
            reset_mille: 0,
            corrupt_mille: 0,
            delay_mille: 0,
            delay: Duration::from_millis(2),
            partition_mille: 0,
            partition_frames: 25,
            budget: None,
            next_conn: Arc::new(AtomicU64::new(0)),
        }
    }

    /// A commodity-network-ish mix: frequent short writes and small
    /// delays, occasional corruption, rare resets and partitions.
    pub fn light(seed: u64) -> ChaosConfig {
        ChaosConfig {
            partial_write_mille: 40,
            reset_mille: 4,
            corrupt_mille: 8,
            delay_mille: 25,
            partition_mille: 3,
            ..ChaosConfig::quiet(seed)
        }
    }

    /// Cap the number of state-damaging faults across every connection
    /// sharing this config (clones share the pool).
    pub fn with_budget(mut self, tokens: i64) -> ChaosConfig {
        self.budget = Some(Arc::new(AtomicI64::new(tokens)));
        self
    }

    /// Remaining fault tokens (`None` = unlimited). Never below zero.
    pub fn budget_remaining(&self) -> Option<i64> {
        self.budget.as_ref().map(|b| b.load(Ordering::Relaxed).max(0))
    }

    /// Build from the environment: `PULSE_CHAOS_SEED=<u64>` selects
    /// the [`ChaosConfig::light`] mix with that seed (absent/invalid →
    /// `None`, chaos off), `PULSE_CHAOS_BUDGET=<i64>` optionally caps
    /// the damaging faults.
    pub fn from_env() -> Option<ChaosConfig> {
        let seed: u64 = std::env::var("PULSE_CHAOS_SEED").ok()?.parse().ok()?;
        let cfg = ChaosConfig::light(seed);
        match std::env::var("PULSE_CHAOS_BUDGET").ok().and_then(|v| v.parse().ok()) {
            Some(tokens) => Some(cfg.with_budget(tokens)),
            None => Some(cfg),
        }
    }
}

/// Per-connection fault state, shared by every [`FaultyStream`] clone
/// of the same underlying socket (`try_clone` halves see one op
/// sequence per direction and one partition state).
#[derive(Debug)]
struct ChaosState {
    cfg: ChaosConfig,
    conn: u64,
    write_ops: AtomicU64,
    read_ops: AtomicU64,
    /// Frames an open one-way partition still swallows.
    partition_left: AtomicI64,
    /// Mid-frame: the current frame's header was swallowed, so its
    /// payload must be too (keeps partitions frame-aligned).
    swallow: AtomicBool,
    faults: AtomicU64,
}

impl ChaosState {
    /// Deterministic per-op fault decision.
    fn roll(&self, op: u64, salt: u64, mille: u32) -> bool {
        if mille == 0 {
            return false;
        }
        let mut s = self
            .cfg
            .seed
            .wrapping_add(self.conn.wrapping_mul(0x9E37_79B9_7F4A_7C15))
            .wrapping_add(op.wrapping_mul(0xD1B5_4A32_D192_ED03))
            ^ salt;
        splitmix64(&mut s) % 1000 < mille as u64
    }

    /// Spend one token from the damaging-fault budget.
    fn spend(&self) -> bool {
        match &self.cfg.budget {
            None => true,
            Some(b) => b.fetch_sub(1, Ordering::Relaxed) > 0,
        }
    }

    fn fault(&self) {
        self.faults.fetch_add(1, Ordering::Relaxed);
    }
}

/// A `TcpStream` with deterministic wire faults. Construct via
/// [`Wire::wrap`]; clones share fault state.
#[derive(Debug)]
pub struct FaultyStream {
    inner: TcpStream,
    st: Arc<ChaosState>,
}

impl FaultyStream {
    fn new(inner: TcpStream, cfg: &ChaosConfig) -> FaultyStream {
        let conn = cfg.next_conn.fetch_add(1, Ordering::Relaxed);
        FaultyStream {
            inner,
            st: Arc::new(ChaosState {
                cfg: cfg.clone(),
                conn,
                write_ops: AtomicU64::new(0),
                read_ops: AtomicU64::new(0),
                partition_left: AtomicI64::new(0),
                swallow: AtomicBool::new(false),
                faults: AtomicU64::new(0),
            }),
        }
    }

    fn try_clone(&self) -> io::Result<FaultyStream> {
        Ok(FaultyStream { inner: self.inner.try_clone()?, st: self.st.clone() })
    }

    /// Faults injected on this connection so far.
    pub fn faults_injected(&self) -> u64 {
        self.st.faults.load(Ordering::Relaxed)
    }
}

impl Write for FaultyStream {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        if buf.is_empty() {
            return self.inner.write(buf);
        }
        let st = self.st.clone();
        let op = st.write_ops.fetch_add(1, Ordering::Relaxed);
        let header = buf.len() == FRAME_HEADER_LEN;
        if header {
            // partitions engage and disengage only here, at a frame
            // boundary, so the peer loses whole frames — never half of
            // one (which would desync the framing permanently)
            if st.partition_left.load(Ordering::Relaxed) > 0 {
                st.partition_left.fetch_sub(1, Ordering::Relaxed);
                st.swallow.store(true, Ordering::Relaxed);
                return Ok(buf.len());
            }
            st.swallow.store(false, Ordering::Relaxed);
            if st.roll(op, SALT_PARTITION, st.cfg.partition_mille) && st.spend() {
                st.fault();
                st.partition_left
                    .store(st.cfg.partition_frames.max(1) as i64 - 1, Ordering::Relaxed);
                st.swallow.store(true, Ordering::Relaxed);
                return Ok(buf.len());
            }
        } else if st.swallow.load(Ordering::Relaxed) {
            return Ok(buf.len());
        }
        if st.roll(op, SALT_DELAY, st.cfg.delay_mille) {
            st.fault();
            // pallas-lint: allow(retry-discipline): the injected-latency fault itself
            std::thread::sleep(st.cfg.delay);
        }
        if st.roll(op, SALT_RESET, st.cfg.reset_mille) && st.spend() {
            st.fault();
            let _ = self.inner.shutdown(Shutdown::Both);
            return Err(io::Error::new(
                io::ErrorKind::ConnectionReset,
                "chaos: injected mid-frame reset",
            ));
        }
        if !header
            && buf.len() > FRAME_HEADER_LEN
            && st.roll(op, SALT_CORRUPT, st.cfg.corrupt_mille)
            && st.spend()
        {
            st.fault();
            let mut copy = buf.to_vec();
            let mut s = st.cfg.seed ^ op ^ st.conn.rotate_left(32);
            let i = (splitmix64(&mut s) as usize) % copy.len();
            copy[i] ^= 1 << (splitmix64(&mut s) % 8);
            self.inner.write_all(&copy)?;
            return Ok(buf.len());
        }
        if buf.len() > 1 && st.roll(op, SALT_PARTIAL, st.cfg.partial_write_mille) {
            st.fault();
            let mut s = st.cfg.seed ^ op.rotate_left(7) ^ st.conn;
            let k = 1 + (splitmix64(&mut s) as usize) % (buf.len() - 1);
            return self.inner.write(&buf[..k]);
        }
        self.inner.write(buf)
    }

    fn flush(&mut self) -> io::Result<()> {
        self.inner.flush()
    }
}

impl Read for FaultyStream {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        let st = self.st.clone();
        let op = st.read_ops.fetch_add(1, Ordering::Relaxed);
        // read-side chaos is latency only: byte damage is injected on
        // the writing end (one faulty end per link suffices), and
        // read-side header corruption would desync the framing
        if st.roll(op ^ 0x5244, SALT_DELAY, st.cfg.delay_mille) {
            st.fault();
            // pallas-lint: allow(retry-discipline): the injected-latency fault itself
            std::thread::sleep(st.cfg.delay);
        }
        self.inner.read(buf)
    }
}

/// One sync-plane connection: a plain `TcpStream` or a chaos-wrapped
/// one, with the handful of socket controls the relay/node/control
/// layers use passed through.
#[derive(Debug)]
pub enum Wire {
    Plain(TcpStream),
    Chaos(FaultyStream),
}

impl Wire {
    /// Wrap `stream` in the chaos domain, or carry it untouched when
    /// chaos is off.
    pub fn wrap(stream: TcpStream, chaos: Option<&ChaosConfig>) -> Wire {
        match chaos {
            Some(cfg) => Wire::Chaos(FaultyStream::new(stream, cfg)),
            None => Wire::Plain(stream),
        }
    }

    pub fn plain(stream: TcpStream) -> Wire {
        Wire::Plain(stream)
    }

    pub fn try_clone(&self) -> io::Result<Wire> {
        Ok(match self {
            Wire::Plain(s) => Wire::Plain(s.try_clone()?),
            Wire::Chaos(s) => Wire::Chaos(s.try_clone()?),
        })
    }

    fn stream(&self) -> &TcpStream {
        match self {
            Wire::Plain(s) => s,
            Wire::Chaos(f) => &f.inner,
        }
    }

    pub fn shutdown(&self, how: Shutdown) -> io::Result<()> {
        self.stream().shutdown(how)
    }

    pub fn set_nodelay(&self, on: bool) -> io::Result<()> {
        self.stream().set_nodelay(on)
    }

    pub fn set_read_timeout(&self, d: Option<Duration>) -> io::Result<()> {
        self.stream().set_read_timeout(d)
    }

    pub fn set_write_timeout(&self, d: Option<Duration>) -> io::Result<()> {
        self.stream().set_write_timeout(d)
    }
}

impl Write for Wire {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        match self {
            Wire::Plain(s) => s.write(buf),
            Wire::Chaos(s) => s.write(buf),
        }
    }

    fn flush(&mut self) -> io::Result<()> {
        match self {
            Wire::Plain(s) => s.flush(),
            Wire::Chaos(s) => s.flush(),
        }
    }
}

impl Read for Wire {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        match self {
            Wire::Plain(s) => s.read(buf),
            Wire::Chaos(s) => s.read(buf),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::tcp::{self, Frame};

    /// One accepted/connected socket pair on loopback.
    fn pair() -> (TcpStream, TcpStream) {
        let (listener, port) = tcp::listen_local().unwrap();
        let client = tcp::connect_local(port).unwrap();
        let (server, _) = listener.accept().unwrap();
        (client, server)
    }

    fn frame(tag: u8, len: usize) -> Frame {
        Frame { kind: tcp::kind::PATCH, payload: vec![tag; len] }
    }

    #[test]
    fn quiet_config_is_a_passthrough() {
        let (c, s) = pair();
        let mut w = Wire::wrap(c, Some(&ChaosConfig::quiet(1)));
        let mut r = s;
        for i in 0..8u8 {
            tcp::write_frame(&mut w, &frame(i, 64)).unwrap();
        }
        for i in 0..8u8 {
            let f = tcp::read_frame(&mut r).unwrap();
            assert_eq!(f.payload, vec![i; 64]);
        }
    }

    #[test]
    fn partition_swallows_whole_frames_and_keeps_framing_aligned() {
        let (c, s) = pair();
        let mut cfg = ChaosConfig::quiet(3);
        cfg.partition_mille = 1000;
        cfg.partition_frames = 2;
        let cfg = cfg.with_budget(1);
        let mut w = Wire::wrap(c, Some(&cfg));
        let mut r = s;
        // frame 0 opens the partition (spending the only token) and is
        // swallowed with frame 1; frame 2 rolls a partition again but
        // the budget is dry, so it passes — intact
        for i in 0..3u8 {
            tcp::write_frame(&mut w, &frame(i, 300)).unwrap();
        }
        let f = tcp::read_frame(&mut r).unwrap();
        assert_eq!(f.payload, vec![2u8; 300], "only the post-budget frame arrives");
        assert_eq!(cfg.budget_remaining(), Some(0));
    }

    #[test]
    fn corruption_hits_payload_bytes_never_headers() {
        let (c, s) = pair();
        let mut cfg = ChaosConfig::quiet(5);
        cfg.corrupt_mille = 1000;
        let cfg = cfg.with_budget(1_000);
        let mut w = Wire::wrap(c, Some(&cfg));
        let mut r = s;
        for i in 0..6u8 {
            tcp::write_frame(&mut w, &frame(i, 200)).unwrap();
        }
        for i in 0..6u8 {
            // headers stay intact (kind + length decode), payloads are
            // each one flipped bit away from what was sent
            let f = tcp::read_frame(&mut r).unwrap();
            assert_eq!(f.kind, tcp::kind::PATCH);
            assert_eq!(f.payload.len(), 200);
            let flipped: u32 = f
                .payload
                .iter()
                .map(|&b| (b ^ i).count_ones())
                .sum();
            assert_eq!(flipped, 1, "exactly one bit flips per corrupted frame");
        }
    }

    #[test]
    fn reset_tears_the_connection_down() {
        let (c, s) = pair();
        let mut cfg = ChaosConfig::quiet(7);
        cfg.reset_mille = 1000;
        let cfg = cfg.with_budget(1);
        let mut w = Wire::wrap(c, Some(&cfg));
        let err = tcp::write_frame(&mut w, &frame(0, 64)).unwrap_err();
        assert!(err.to_string().contains("reset"), "err = {:#}", err);
        // the peer sees the teardown too
        let mut r = s;
        assert!(tcp::read_frame(&mut r).is_err());
    }

    #[test]
    fn partial_writes_heal_under_write_all() {
        let (c, s) = pair();
        let mut cfg = ChaosConfig::quiet(9);
        cfg.partial_write_mille = 1000; // every write comes up short
        let mut w = Wire::wrap(c, Some(&cfg));
        let mut r = s;
        for i in 0..5u8 {
            tcp::write_frame(&mut w, &frame(i, 500)).unwrap();
        }
        for i in 0..5u8 {
            assert_eq!(tcp::read_frame(&mut r).unwrap().payload, vec![i; 500]);
        }
    }

    #[test]
    fn same_seed_same_surviving_bytes() {
        let run = |seed: u64| -> Vec<Vec<u8>> {
            let (c, s) = pair();
            let mut cfg = ChaosConfig::quiet(seed);
            cfg.corrupt_mille = 300;
            cfg.partition_mille = 100;
            cfg.partition_frames = 2;
            let cfg = cfg.with_budget(1_000);
            let mut w = Wire::wrap(c, Some(&cfg));
            for i in 0..20u8 {
                tcp::write_frame(&mut w, &frame(i, 64)).unwrap();
            }
            drop(w);
            let mut out = Vec::new();
            let mut r = s;
            while let Ok(f) = tcp::read_frame(&mut r) {
                out.push(f.payload);
            }
            out
        };
        assert_eq!(run(42), run(42), "a seed fully determines the wire damage");
        assert_ne!(run(42), run(43), "distinct seeds damage differently");
    }

    #[test]
    fn from_env_reads_seed_and_budget() {
        // no env in this test process is assumed; set + clear locally
        std::env::set_var("PULSE_CHAOS_SEED", "11");
        std::env::set_var("PULSE_CHAOS_BUDGET", "5");
        let cfg = ChaosConfig::from_env().expect("seed set");
        assert_eq!(cfg.seed, 11);
        assert_eq!(cfg.budget_remaining(), Some(5));
        std::env::remove_var("PULSE_CHAOS_SEED");
        std::env::remove_var("PULSE_CHAOS_BUDGET");
    }
}
