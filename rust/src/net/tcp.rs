//! Framed TCP transport (std::net; the image has no tokio). Messages
//! are length-prefixed byte frames with a type tag — enough to carry
//! PULSESync patches and PULSELoCo payloads over real sockets for the
//! live-sync example.

use anyhow::{bail, Context, Result};
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};

pub const MAX_FRAME: usize = 1 << 30;

/// Bytes in the frame header: kind (u8) + payload length (u32 LE).
/// The chaos layer (`net::chaos`) keys its frame-boundary handling on
/// writes of exactly this length.
pub const FRAME_HEADER_LEN: usize = 5;

/// A framed message.
#[derive(Debug, Clone, PartialEq)]
pub struct Frame {
    pub kind: u8,
    pub payload: Vec<u8>,
}

/// Frame kinds used by the live-sync protocol.
pub mod kind {
    /// Publisher → relay/worker: a patch container (whole-step v1/v2,
    /// or one v3 shard frame of a sharded step — the container header
    /// is self-describing, see `sparse::container::peek_meta`).
    pub const PATCH: u8 = 1;
    /// Publisher → relay/worker: a full anchor object.
    pub const ANCHOR: u8 = 2;
    /// Worker → publisher: subscribe (payload = last known step, u64 LE).
    pub const SUBSCRIBE: u8 = 3;
    /// Acknowledgement (payload = step u64 LE, or step u64 ++ shard
    /// u32 for ACK-per-shard; see [`super::shard_ack_payload`]).
    // pallas-lint: allow(frame-kind-coverage): sent by the fan-out example (tests/integration_fanout.rs, outside the src scan); in-tree transports ack implicitly via NACK absence
    pub const ACK: u8 = 4;
    /// Orderly shutdown.
    pub const CLOSE: u8 = 5;
    /// Worker → publisher: negative acknowledgement for one shard
    /// frame (payload = step u64 ++ shard u32 LE); the publisher
    /// re-sends just that shard.
    pub const NACK: u8 = 6;
    /// Publisher → relay/worker: a ready marker committing a step
    /// (payload = marker kind u8 ++ step u64 LE ++ marker utf8; see
    /// [`super::marker_frame_payload`]). The sync-plane transport layer
    /// (`net::transport`) uses this to carry the same commit protocol
    /// the object store expresses with `*_ready_*` objects.
    pub const MARKER: u8 = 7;
    /// Relay → worker (or upstream relay → downstream relay): a NACK
    /// for one shard frame cannot be serviced — the `(step, shard)`
    /// slot was evicted from every frame index on the path to the
    /// publisher (payload = step u64 ++ shard u32 LE, same as NACK).
    /// The subscriber must stop waiting and recover via the anchor
    /// slow path instead of timing out.
    pub const NACK_MISS: u8 = 8;
    /// Relay → subscriber: topology info, sent in reply to SUBSCRIBE
    /// (payload = hop count u32 LE: 0 = root relay, 1 = one relay
    /// between this peer and the publisher, …). Lets chained
    /// relays/workers report their depth in the distribution tree.
    pub const HOP: u8 = 9;
    /// Peer → control plane: join the cluster (payload = role u8 ++
    /// listen port u16 LE; port 0 for leaves — see
    /// [`crate::net::control`] and [`super::join_payload`]).
    pub const JOIN: u8 = 10;
    /// Control plane → peer: topology directive (payload = epoch u64
    /// ++ peer id u64 ++ upstream port u16 ++ hop u32, all LE; see
    /// [`super::assign_payload`]). Upstream port 0 = standby (detach
    /// and wait). A peer ignores an ASSIGN whose epoch is older than
    /// the newest it has seen — the epoch fence.
    pub const ASSIGN: u8 = 11;
    /// Peer → control plane: liveness beacon (payload = peer id u64 ++
    /// epoch u64 LE). Missing several consecutive beacons (see
    /// `ControlConfig::missed_heartbeats`) marks the peer dead and
    /// triggers a replan.
    pub const HEARTBEAT: u8 = 12;
    /// Control plane → peers: epoch fence announcement (payload =
    /// epoch u64 LE), broadcast before the new epoch's ASSIGNs so a
    /// stale directive from an older epoch can never be applied after
    /// a newer one was seen.
    pub const EPOCH: u8 = 13;
    /// Client → store: fetch an object, optionally a byte range and/or
    /// conditional on an ETag (payload codec in [`crate::net::store`];
    /// every store payload carries a trailing FNV-1a checksum so a
    /// chaos bit-flip is detected and retried instead of applied).
    pub const STORE_GET: u8 = 14;
    /// Client → store: write an object atomically (key ++ body).
    pub const STORE_PUT: u8 = 15;
    /// Client → store: list keys under a prefix (newline-joined reply).
    pub const STORE_LIST: u8 = 16;
    /// Client → store: object size probe without the body.
    pub const STORE_STAT: u8 = 17;
    /// Store → client: the single reply frame for every store request
    /// (status u8 ++ flags u8 ++ etag ++ body; see
    /// [`crate::net::store::Reply`]).
    pub const STORE_REPLY: u8 = 18;
    /// Client → any sync-plane node (relay, relay node, store server,
    /// control plane): request a live metric+recorder snapshot
    /// (payload = flags u64 LE, bit 0 = include recorder events; see
    /// [`crate::obs`] and [`super::obs_snap_payload`]). Served outside
    /// the data path, so a `paper obs` probe never perturbs fan-out.
    pub const OBS_SNAP: u8 = 19;
    /// Node → client: the snapshot reply (payload = FNV-1a checksum
    /// u32 LE ++ utf8 JSON; see [`super::obs_reply_payload`]). JSON so
    /// new histograms/counters extend the snapshot without a wire
    /// version bump.
    pub const OBS_REPLY: u8 = 20;
}

/// Payload for an ACK/NACK addressing one shard of a step.
pub fn shard_ack_payload(step: u64, shard: u32) -> Vec<u8> {
    let mut p = Vec::with_capacity(12);
    p.extend_from_slice(&step.to_le_bytes());
    p.extend_from_slice(&shard.to_le_bytes());
    p
}

/// Decode an ACK/NACK payload. Legacy 8-byte step-only ACKs decode
/// with shard 0.
pub fn parse_shard_ack(payload: &[u8]) -> Result<(u64, u32)> {
    match payload.len() {
        8 => Ok((u64::from_le_bytes(payload.try_into()?), 0)),
        12 => Ok((
            u64::from_le_bytes(payload[0..8].try_into()?),
            u32::from_le_bytes(payload[8..12].try_into()?),
        )),
        n => bail!("bad ack payload length {}", n),
    }
}

/// Payload for a HOP frame: the sender's distance from the publisher
/// in relay hops (0 = root relay).
pub fn hop_payload(hops: u32) -> Vec<u8> {
    hops.to_le_bytes().to_vec()
}

/// Decode a HOP frame payload.
pub fn parse_hop(payload: &[u8]) -> Result<u32> {
    match payload.len() {
        4 => Ok(u32::from_le_bytes(payload.try_into()?)),
        n => bail!("bad hop payload length {}", n),
    }
}

/// Payload for a JOIN frame: the peer's role (see
/// [`crate::net::control::role`]) and the port its own relay listens
/// on (0 for leaves, which serve no downstream).
pub fn join_payload(role: u8, listen_port: u16) -> Vec<u8> {
    let mut p = Vec::with_capacity(3);
    p.push(role);
    p.extend_from_slice(&listen_port.to_le_bytes());
    p
}

/// Decode a JOIN payload into `(role, listen_port)`.
pub fn parse_join(payload: &[u8]) -> Result<(u8, u16)> {
    match payload.len() {
        3 => Ok((payload[0], u16::from_le_bytes(payload[1..3].try_into()?))),
        n => bail!("bad join payload length {}", n),
    }
}

/// Payload for an ASSIGN frame: `(epoch, peer_id, upstream_port, hop)`.
/// `upstream_port` 0 means standby (detach from any upstream and wait
/// for the next epoch); `hop` is the peer's distance from the
/// publisher under this plan (1 = directly under the root relay).
pub fn assign_payload(epoch: u64, peer_id: u64, upstream_port: u16, hop: u32) -> Vec<u8> {
    let mut p = Vec::with_capacity(22);
    p.extend_from_slice(&epoch.to_le_bytes());
    p.extend_from_slice(&peer_id.to_le_bytes());
    p.extend_from_slice(&upstream_port.to_le_bytes());
    p.extend_from_slice(&hop.to_le_bytes());
    p
}

/// Decode an ASSIGN payload into `(epoch, peer_id, upstream_port, hop)`.
pub fn parse_assign(payload: &[u8]) -> Result<(u64, u64, u16, u32)> {
    if payload.len() != 22 {
        bail!("bad assign payload length {}", payload.len());
    }
    Ok((
        u64::from_le_bytes(payload[0..8].try_into()?),
        u64::from_le_bytes(payload[8..16].try_into()?),
        u16::from_le_bytes(payload[16..18].try_into()?),
        u32::from_le_bytes(payload[18..22].try_into()?),
    ))
}

/// Payload for a HEARTBEAT frame: `(peer_id, epoch)` — the epoch is
/// the newest the peer has accepted, so the plane can see laggards.
pub fn heartbeat_payload(peer_id: u64, epoch: u64) -> Vec<u8> {
    let mut p = Vec::with_capacity(16);
    p.extend_from_slice(&peer_id.to_le_bytes());
    p.extend_from_slice(&epoch.to_le_bytes());
    p
}

/// Decode a HEARTBEAT payload into `(peer_id, epoch)`.
pub fn parse_heartbeat(payload: &[u8]) -> Result<(u64, u64)> {
    if payload.len() != 16 {
        bail!("bad heartbeat payload length {}", payload.len());
    }
    Ok((
        u64::from_le_bytes(payload[0..8].try_into()?),
        u64::from_le_bytes(payload[8..16].try_into()?),
    ))
}

/// Payload for an EPOCH fence frame.
pub fn epoch_payload(epoch: u64) -> Vec<u8> {
    epoch.to_le_bytes().to_vec()
}

/// Decode an EPOCH payload.
pub fn parse_epoch(payload: &[u8]) -> Result<u64> {
    match payload.len() {
        8 => Ok(u64::from_le_bytes(payload.try_into()?)),
        n => bail!("bad epoch payload length {}", n),
    }
}

/// FNV-1a over a MARKER frame's flag, step, and marker text. Patch and
/// anchor payloads verify end to end through container hashes and the
/// hash tree, but the marker — the commit signal itself — used to be
/// the one data-plane frame a flipped wire bit could poison silently:
/// a corrupted step field would stage a bogus head and wedge the
/// consumer. With the checksum, wire damage turns the marker into a
/// *dropped* frame (the receiver ignores it and the next marker
/// commits), which the retry machinery already heals.
fn marker_checksum(flag: u8, step: u64, marker: &[u8]) -> u32 {
    let mut h: u32 = 0x811C_9DC5;
    for &b in std::iter::once(&flag).chain(step.to_le_bytes().iter()).chain(marker.iter()) {
        h ^= b as u32;
        h = h.wrapping_mul(0x0100_0193);
    }
    h
}

/// Payload for a MARKER frame: `anchor` selects the marker namespace
/// (false = delta-ready, true = anchor-ready), `marker` is the exact
/// string the object-store plane would write under the ready key. A
/// 4-byte FNV-1a checksum binds flag + step + text, so wire corruption
/// surfaces as a dropped marker instead of a poisoned head.
pub fn marker_frame_payload(anchor: bool, step: u64, marker: &str) -> Vec<u8> {
    let flag = if anchor { 1 } else { 0 };
    let mut p = Vec::with_capacity(13 + marker.len());
    p.push(flag);
    p.extend_from_slice(&step.to_le_bytes());
    p.extend_from_slice(&marker_checksum(flag, step, marker.as_bytes()).to_le_bytes());
    p.extend_from_slice(marker.as_bytes());
    p
}

/// Decode a MARKER frame payload into `(is_anchor, step, marker)`,
/// rejecting any payload whose checksum disagrees with its content.
pub fn parse_marker_frame(payload: &[u8]) -> Result<(bool, u64, String)> {
    if payload.len() < 13 || payload[0] > 1 {
        bail!("bad marker frame payload ({} bytes)", payload.len());
    }
    let step = u64::from_le_bytes(payload[1..9].try_into()?);
    let crc = u32::from_le_bytes(payload[9..13].try_into()?);
    if marker_checksum(payload[0], step, &payload[13..]) != crc {
        bail!("marker frame checksum mismatch at step {}", step);
    }
    let marker = std::str::from_utf8(&payload[13..])
        .map_err(|_| anyhow::anyhow!("marker frame payload is not utf8"))?
        .to_string();
    Ok((payload[0] == 1, step, marker))
}

/// FNV-1a over an OBS_REPLY body (same construction as
/// [`marker_checksum`]): the snapshot travels next to chaos-wrapped
/// data frames, so a flipped bit must surface as a decode error the
/// prober can retry, not as silently wrong metrics.
fn obs_checksum(body: &[u8]) -> u32 {
    let mut h: u32 = 0x811C_9DC5;
    for &b in body {
        h ^= b as u32;
        h = h.wrapping_mul(0x0100_0193);
    }
    h
}

/// Payload for an OBS_SNAP request: request flags (bit 0 =
/// [`crate::obs::SNAP_WITH_EVENTS`], include recorder events).
pub fn obs_snap_payload(flags: u64) -> Vec<u8> {
    flags.to_le_bytes().to_vec()
}

/// Decode an OBS_SNAP payload into its flags word.
pub fn parse_obs_snap(payload: &[u8]) -> Result<u64> {
    match payload.len() {
        8 => Ok(u64::from_le_bytes(payload.try_into()?)),
        n => bail!("bad obs snap payload length {}", n),
    }
}

/// Payload for an OBS_REPLY frame: 4-byte FNV-1a checksum + the
/// snapshot JSON.
pub fn obs_reply_payload(json: &str) -> Vec<u8> {
    let mut p = Vec::with_capacity(4 + json.len());
    p.extend_from_slice(&obs_checksum(json.as_bytes()).to_le_bytes());
    p.extend_from_slice(json.as_bytes());
    p
}

/// Decode an OBS_REPLY payload into the snapshot JSON text, rejecting
/// truncated or corrupted payloads.
pub fn parse_obs_reply(payload: &[u8]) -> Result<String> {
    if payload.len() < 4 {
        bail!("bad obs reply payload ({} bytes)", payload.len());
    }
    let crc = u32::from_le_bytes(payload[0..4].try_into()?);
    if obs_checksum(&payload[4..]) != crc {
        bail!("obs reply checksum mismatch");
    }
    Ok(std::str::from_utf8(&payload[4..])
        .map_err(|_| anyhow::anyhow!("obs reply payload is not utf8"))?
        .to_string())
}

/// Write one frame: the 5-byte header, then the payload. Generic over
/// the sink so bare sockets, chaos-wrapped wires
/// ([`crate::net::chaos::Wire`]), and in-memory buffers all frame
/// identically.
pub fn write_frame<W: Write>(stream: &mut W, frame: &Frame) -> Result<()> {
    let mut header = [0u8; FRAME_HEADER_LEN];
    header[0] = frame.kind;
    header[1..5].copy_from_slice(&(frame.payload.len() as u32).to_le_bytes());
    stream.write_all(&header)?;
    stream.write_all(&frame.payload)?;
    stream.flush()?;
    Ok(())
}

/// Read one frame. Generic over the source (see [`write_frame`]).
pub fn read_frame<R: Read>(stream: &mut R) -> Result<Frame> {
    let mut header = [0u8; FRAME_HEADER_LEN];
    stream.read_exact(&mut header).context("reading frame header")?;
    let kind = header[0];
    let len = u32::from_le_bytes(header[1..5].try_into()?) as usize;
    if len > MAX_FRAME {
        bail!("frame too large: {}", len);
    }
    let mut payload = vec![0u8; len];
    stream.read_exact(&mut payload).context("reading frame payload")?;
    Ok(Frame { kind, payload })
}

/// Bind a listener on an ephemeral localhost port.
pub fn listen_local() -> Result<(TcpListener, u16)> {
    let listener = TcpListener::bind("127.0.0.1:0")?;
    let port = listener.local_addr()?.port();
    Ok((listener, port))
}

pub fn connect_local(port: u16) -> Result<TcpStream> {
    let s = TcpStream::connect(("127.0.0.1", port))?;
    s.set_nodelay(true)?;
    Ok(s)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frame_roundtrip_over_socket() {
        let (listener, port) = listen_local().unwrap();
        let server = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            let f = read_frame(&mut s).unwrap();
            assert_eq!(f.kind, kind::PATCH);
            write_frame(
                &mut s,
                &Frame { kind: kind::ACK, payload: 7u64.to_le_bytes().to_vec() },
            )
            .unwrap();
            f.payload
        });
        let mut c = connect_local(port).unwrap();
        let payload: Vec<u8> = (0..100_000u32).map(|i| i as u8).collect();
        write_frame(&mut c, &Frame { kind: kind::PATCH, payload: payload.clone() }).unwrap();
        let ack = read_frame(&mut c).unwrap();
        assert_eq!(ack.kind, kind::ACK);
        assert_eq!(server.join().unwrap(), payload);
    }

    #[test]
    fn shard_ack_roundtrip() {
        let p = shard_ack_payload(77, 3);
        assert_eq!(parse_shard_ack(&p).unwrap(), (77, 3));
        assert_eq!(parse_shard_ack(&9u64.to_le_bytes()).unwrap(), (9, 0));
        assert!(parse_shard_ack(&[1, 2, 3]).is_err());
    }

    #[test]
    fn hop_payload_roundtrip() {
        assert_eq!(parse_hop(&hop_payload(0)).unwrap(), 0);
        assert_eq!(parse_hop(&hop_payload(3)).unwrap(), 3);
        assert!(parse_hop(&[1, 2]).is_err());
        // NACK_MISS reuses the shard ack payload shape
        let p = shard_ack_payload(12, 4);
        assert_eq!(parse_shard_ack(&p).unwrap(), (12, 4));
    }

    #[test]
    fn control_payload_roundtrips() {
        assert_eq!(parse_join(&join_payload(1, 40123)).unwrap(), (1, 40123));
        assert_eq!(parse_join(&join_payload(2, 0)).unwrap(), (2, 0));
        assert!(parse_join(&[1, 2]).is_err());
        assert_eq!(
            parse_assign(&assign_payload(7, 3, 50111, 2)).unwrap(),
            (7, 3, 50111, 2)
        );
        assert_eq!(parse_assign(&assign_payload(0, 0, 0, 0)).unwrap(), (0, 0, 0, 0));
        assert!(parse_assign(&[0u8; 21]).is_err());
        assert_eq!(parse_heartbeat(&heartbeat_payload(9, 4)).unwrap(), (9, 4));
        assert!(parse_heartbeat(&[0u8; 8]).is_err());
        assert_eq!(parse_epoch(&epoch_payload(u64::MAX)).unwrap(), u64::MAX);
        assert!(parse_epoch(&[0u8; 4]).is_err());
    }

    #[test]
    fn marker_frame_roundtrip() {
        let p = marker_frame_payload(false, 12, "v3:4:abcd");
        assert_eq!(parse_marker_frame(&p).unwrap(), (false, 12, "v3:4:abcd".to_string()));
        let p = marker_frame_payload(true, 0, "");
        assert_eq!(parse_marker_frame(&p).unwrap(), (true, 0, String::new()));
        assert!(parse_marker_frame(&[0, 1]).is_err());
        assert!(parse_marker_frame(&[9, 0, 0, 0, 0, 0, 0, 0, 0]).is_err());
    }

    #[test]
    fn marker_frame_checksum_rejects_wire_corruption() {
        // one flipped bit in the utf8 body
        let body = "a".repeat(64);
        let mut p = marker_frame_payload(false, 5, &body);
        let n = p.len();
        p[n - 1] ^= 0x01;
        assert!(parse_marker_frame(&p).is_err());
        // one flipped bit in the step field (this used to poison the
        // staged head silently)
        let mut p2 = marker_frame_payload(true, 5, "x".repeat(16).as_str());
        p2[3] ^= 0x10;
        assert!(parse_marker_frame(&p2).is_err());
        // and in the checksum itself
        let mut p3 = marker_frame_payload(false, 9, &body);
        p3[10] ^= 0x01;
        assert!(parse_marker_frame(&p3).is_err());
    }

    #[test]
    fn truncated_frames_fail_with_stage_specific_errors() {
        use std::io::Cursor;
        // 3 of 5 header bytes
        let mut c = Cursor::new(vec![kind::PATCH, 1, 0]);
        let e = read_frame(&mut c).unwrap_err();
        assert!(format!("{:#}", e).contains("reading frame header"), "{:#}", e);
        // full header promising 100 payload bytes, only 10 present
        let mut buf = vec![kind::PATCH, 100, 0, 0, 0];
        buf.extend_from_slice(&[7u8; 10]);
        let mut c = Cursor::new(buf);
        let e = read_frame(&mut c).unwrap_err();
        assert!(format!("{:#}", e).contains("reading frame payload"), "{:#}", e);
        // oversize length is rejected before the payload allocation
        let mut h = vec![kind::PATCH];
        h.extend_from_slice(&(2_000_000_000u32).to_le_bytes());
        let mut c = Cursor::new(h);
        let e = read_frame(&mut c).unwrap_err();
        assert!(e.to_string().contains("frame too large"), "{:#}", e);
        // a well-formed in-memory buffer still roundtrips (the framing
        // is generic over Read/Write, not TcpStream-only)
        let mut out: Vec<u8> = Vec::new();
        write_frame(&mut out, &Frame { kind: kind::ACK, payload: vec![1, 2, 3] }).unwrap();
        let f = read_frame(&mut Cursor::new(out)).unwrap();
        assert_eq!((f.kind, f.payload), (kind::ACK, vec![1, 2, 3]));
    }

    #[test]
    fn truncated_decode_fails_for_every_frame_kind() {
        use std::io::Cursor;
        // every kind constant in `mod kind`, in declaration order — a
        // new kind must be added here or the frame-kind-coverage lint
        // rule flags its missing truncation test
        let kinds = [
            kind::PATCH,
            kind::ANCHOR,
            kind::SUBSCRIBE,
            kind::ACK,
            kind::CLOSE,
            kind::NACK,
            kind::MARKER,
            kind::NACK_MISS,
            kind::HOP,
            kind::JOIN,
            kind::ASSIGN,
            kind::HEARTBEAT,
            kind::EPOCH,
            kind::STORE_GET,
            kind::STORE_PUT,
            kind::STORE_LIST,
            kind::STORE_STAT,
            kind::STORE_REPLY,
            kind::OBS_SNAP,
            kind::OBS_REPLY,
        ];
        for (i, &k) in kinds.iter().enumerate() {
            assert_eq!(k as usize, i + 1, "kinds list out of sync with mod kind");
            // 3 of 5 header bytes
            let e = read_frame(&mut Cursor::new(vec![k, 1, 0])).unwrap_err();
            assert!(format!("{:#}", e).contains("reading frame header"), "kind {}: {:#}", k, e);
            // full header promising 100 payload bytes, only 10 present
            let mut buf = vec![k, 100, 0, 0, 0];
            buf.extend_from_slice(&[7u8; 10]);
            let e = read_frame(&mut Cursor::new(buf)).unwrap_err();
            assert!(format!("{:#}", e).contains("reading frame payload"), "kind {}: {:#}", k, e);
        }
        // truncated *payloads* of the fixed-size control codecs error
        // instead of panicking (these used to be unwrap() sites)
        assert!(parse_shard_ack(&[1, 2, 3]).is_err());
        assert!(parse_hop(&[1]).is_err());
        assert!(parse_join(&[1]).is_err());
        assert!(parse_assign(&[0u8; 5]).is_err());
        assert!(parse_heartbeat(&[0u8; 3]).is_err());
        assert!(parse_epoch(&[0u8; 2]).is_err());
        assert!(parse_marker_frame(&[0u8; 4]).is_err());
        assert!(parse_obs_snap(&[0u8; 3]).is_err());
        assert!(parse_obs_snap(&[0u8; 9]).is_err());
        assert!(parse_obs_reply(&[0u8; 2]).is_err());
    }

    #[test]
    fn obs_payload_roundtrips_and_rejects_corruption() {
        assert_eq!(parse_obs_snap(&obs_snap_payload(0)).unwrap(), 0);
        assert_eq!(parse_obs_snap(&obs_snap_payload(u64::MAX)).unwrap(), u64::MAX);
        let body = r#"{"role":"relay","histograms":{}}"#;
        assert_eq!(parse_obs_reply(&obs_reply_payload(body)).unwrap(), body);
        assert_eq!(parse_obs_reply(&obs_reply_payload("")).unwrap(), "");
        // one flipped bit in the JSON body
        let mut p = obs_reply_payload(body);
        let n = p.len();
        p[n - 1] ^= 0x01;
        assert!(parse_obs_reply(&p).is_err());
        // and in the checksum itself
        let mut p2 = obs_reply_payload(body);
        p2[1] ^= 0x40;
        assert!(parse_obs_reply(&p2).is_err());
    }

    #[test]
    fn oversized_frame_rejected() {
        let (listener, port) = listen_local().unwrap();
        let server = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            read_frame(&mut s).is_err()
        });
        let mut c = connect_local(port).unwrap();
        // hand-craft a header claiming 2 GB
        let mut header = [0u8; 5];
        header[0] = kind::PATCH;
        header[1..5].copy_from_slice(&(2_000_000_000u32).to_le_bytes());
        c.write_all(&header).unwrap();
        c.flush().unwrap();
        drop(c);
        assert!(server.join().unwrap());
    }
}
