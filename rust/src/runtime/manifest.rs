//! Model manifest: the JSON sidecar aot.py writes next to each HLO
//! artifact, describing the flat parameter layout, model dimensions,
//! artifact filenames, and the optional cross-language numeric oracle.

use crate::sparse::TensorShape;
use crate::util::json::Json;
use anyhow::Result;
use std::collections::BTreeMap;
use std::path::Path;

#[derive(Debug, Clone, Default)]
pub struct Dims {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub seq: usize,
    pub prompt_len: usize,
    pub gen_len: usize,
    pub batch: usize,
    pub d_ff: usize,
}

#[derive(Debug, Clone, Default)]
pub struct Oracle {
    pub logprob_sum: f64,
    pub logprob_first8: Vec<f64>,
    pub entropy_mean: f64,
}

#[derive(Debug, Clone)]
pub struct ModelManifest {
    pub name: String,
    pub n_params: usize,
    pub dims: Dims,
    /// (kind -> filename), e.g. "grad" -> "tiny.grad.hlo.txt".
    pub artifacts: BTreeMap<String, String>,
    /// Tensor layout for COO patch encoding (rows/cols per tensor).
    pub layout: Vec<TensorShape>,
    pub init: Option<String>,
    pub oracle: Option<Oracle>,
    pub eps_low: f64,
    pub eps_high: f64,
}

impl ModelManifest {
    pub fn load(path: &Path) -> Result<ModelManifest> {
        let j = Json::parse_file(path)?;
        let d = j.req("dims")?;
        let dims = Dims {
            vocab: d.req_usize("vocab")?,
            d_model: d.req_usize("d_model")?,
            n_layers: d.req_usize("n_layers")?,
            n_heads: d.req_usize("n_heads")?,
            seq: d.req_usize("seq")?,
            prompt_len: d.req_usize("prompt_len")?,
            gen_len: d.req_usize("gen_len")?,
            batch: d.req_usize("batch")?,
            d_ff: d.req_usize("d_ff")?,
        };
        let mut artifacts = BTreeMap::new();
        if let Json::Obj(m) = j.req("artifacts")? {
            for (k, v) in m {
                artifacts.insert(k.clone(), v.as_str().unwrap_or_default().to_string());
            }
        }
        let mut layout = Vec::new();
        for t in j.req("tensors")?.as_arr().unwrap_or(&[]) {
            let shape: Vec<usize> = t
                .req("shape")?
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .filter_map(|x| x.as_usize())
                .collect();
            let (rows, cols) = match shape.as_slice() {
                [a] => (1usize, *a),
                [a, b] => (*a, *b),
                other => anyhow::bail!("unsupported tensor rank {:?}", other),
            };
            layout.push(TensorShape {
                name: t.req_str("name")?.to_string(),
                offset: t.req_usize("offset")?,
                rows,
                cols,
            });
        }
        let oracle = j.get("oracle").map(|o| Oracle {
            logprob_sum: o.num_or("logprob_sum", 0.0),
            logprob_first8: o
                .get("logprob_first8")
                .and_then(|a| a.as_arr())
                .map(|a| a.iter().filter_map(|x| x.as_f64()).collect())
                .unwrap_or_default(),
            entropy_mean: o.num_or("entropy_mean", 0.0),
        });
        Ok(ModelManifest {
            name: j.req_str("name")?.to_string(),
            n_params: j.req_usize("n_params")?,
            dims,
            artifacts,
            layout,
            init: j.get("init").and_then(|x| x.as_str()).map(|s| s.to_string()),
            oracle,
            eps_low: j.num_or("eps_low", 0.2),
            eps_high: j.num_or("eps_high", 0.28),
        })
    }

    /// Sanity-check layout contiguity.
    pub fn validate(&self) -> Result<()> {
        let mut off = 0usize;
        for t in &self.layout {
            if t.offset != off {
                anyhow::bail!("tensor '{}' offset {} != expected {}", t.name, t.offset, off);
            }
            off += t.len();
        }
        if off != self.n_params {
            anyhow::bail!("layout covers {} params, manifest says {}", off, self.n_params);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "name": "t", "n_params": 20,
      "dims": {"vocab": 4, "d_model": 2, "n_layers": 1, "n_heads": 1,
               "seq": 3, "prompt_len": 2, "gen_len": 1, "batch": 2, "d_ff": 8},
      "artifacts": {"score": "t.score.hlo.txt"},
      "tensors": [
        {"name": "a", "shape": [4, 2], "offset": 0, "len": 8},
        {"name": "b", "shape": [12], "offset": 8, "len": 12}
      ],
      "eps_low": 0.2, "eps_high": 0.28,
      "oracle": {"logprob_sum": -1.5, "logprob_first8": [-0.1], "entropy_mean": 0.9}
    }"#;

    #[test]
    fn parses_and_validates() {
        let dir = std::env::temp_dir().join(format!("pulse_manifest_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.meta.json");
        std::fs::write(&p, SAMPLE).unwrap();
        let m = ModelManifest::load(&p).unwrap();
        m.validate().unwrap();
        assert_eq!(m.dims.batch, 2);
        assert_eq!(m.layout[0].rows, 4);
        assert_eq!(m.layout[1].rows, 1);
        assert_eq!(m.layout[1].cols, 12);
        assert_eq!(m.artifacts["score"], "t.score.hlo.txt");
        assert!((m.oracle.unwrap().logprob_sum + 1.5).abs() < 1e-12);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn rejects_bad_layout() {
        let bad = SAMPLE.replace("\"offset\": 8", "\"offset\": 9");
        let dir = std::env::temp_dir().join(format!("pulse_manifest_bad_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.meta.json");
        std::fs::write(&p, bad).unwrap();
        let m = ModelManifest::load(&p).unwrap();
        assert!(m.validate().is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
