//! PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt` +
//! `*.meta.json`) produced by `python/compile/aot.py` and executes them
//! on the CPU PJRT client. This is the only bridge between L3 and the
//! L2/L1 graphs — python never runs at request time.
//!
//! Pattern follows /opt/xla-example/load_hlo: HLO *text* →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`.

pub mod manifest;

pub use manifest::ModelManifest;

use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use xla::{ElementType, Literal, PjRtClient, PjRtLoadedExecutable};

/// Output of one GRPO gradient step.
#[derive(Debug, Clone)]
pub struct GradOut {
    pub grads: Vec<f32>,
    pub loss: f32,
    pub clip_frac: f32,
    pub mean_ratio: f32,
    pub grad_density: f32,
}

/// Output of one rollout batch.
#[derive(Debug, Clone)]
pub struct RolloutOut {
    /// [B, T] row-major.
    pub tokens: Vec<i32>,
    /// [B, G] row-major: behaviour-policy logprobs of generated tokens.
    pub logprobs: Vec<f32>,
}

/// A loaded model: manifest + compiled executables.
pub struct ModelRuntime {
    pub manifest: ModelManifest,
    client: PjRtClient,
    exes: HashMap<String, PjRtLoadedExecutable>,
}

fn f32_literal(dims: &[usize], data: &[f32]) -> Result<Literal> {
    Ok(Literal::create_from_shape_and_untyped_data(
        ElementType::F32,
        dims,
        crate::util::f32_as_bytes(data),
    )?)
}

fn i32_literal(dims: &[usize], data: &[i32]) -> Result<Literal> {
    let bytes =
        unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) };
    Ok(Literal::create_from_shape_and_untyped_data(ElementType::S32, dims, bytes)?)
}

fn u32_literal(dims: &[usize], data: &[u32]) -> Result<Literal> {
    let bytes =
        unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) };
    Ok(Literal::create_from_shape_and_untyped_data(ElementType::U32, dims, bytes)?)
}

impl ModelRuntime {
    /// Load `<size>.meta.json` from `artifacts_dir` and compile the
    /// executables named by `graphs` (or all if empty).
    pub fn load(artifacts_dir: &Path, size: &str, graphs: &[&str]) -> Result<ModelRuntime> {
        let manifest = ModelManifest::load(&artifacts_dir.join(format!("{}.meta.json", size)))?;
        manifest.validate()?;
        let client = PjRtClient::cpu()?;
        let mut exes = HashMap::new();
        for (kind, fname) in &manifest.artifacts {
            if !graphs.is_empty() && !graphs.contains(&kind.as_str()) {
                continue;
            }
            let path: PathBuf = artifacts_dir.join(fname);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 artifact path")?,
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp).with_context(|| format!("compiling {}", fname))?;
            exes.insert(kind.clone(), exe);
        }
        Ok(ModelRuntime { manifest, client, exes })
    }

    /// Load the f32 init vector shipped with the artifacts (tiny/small/
    /// med sizes).
    pub fn load_init(&self, artifacts_dir: &Path) -> Result<Vec<f32>> {
        let name = self
            .manifest
            .init
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("size '{}' ships no init.bin", self.manifest.name))?;
        let bytes = std::fs::read(artifacts_dir.join(name))?;
        let flat = crate::util::bytes_to_f32(&bytes);
        if flat.len() != self.manifest.n_params {
            bail!("init.bin length {} != n_params {}", flat.len(), self.manifest.n_params);
        }
        Ok(flat)
    }

    fn exe(&self, kind: &str) -> Result<&PjRtLoadedExecutable> {
        self.exes
            .get(kind)
            .ok_or_else(|| anyhow::anyhow!("graph '{}' not loaded", kind))
    }

    fn run(&self, kind: &str, inputs: &[Literal]) -> Result<Vec<Literal>> {
        let exe = self.exe(kind)?;
        let result = exe.execute::<Literal>(inputs)?[0][0].to_literal_sync()?;
        Ok(result.to_tuple()?)
    }

    /// score: (flat, tokens[B,T]) → (logprobs [B*G], entropy [B*G]).
    pub fn score(&self, flat: &[f32], tokens: &[i32]) -> Result<(Vec<f32>, Vec<f32>)> {
        let d = &self.manifest.dims;
        self.check_flat(flat)?;
        if tokens.len() != d.batch * d.seq {
            bail!("tokens len {} != B*T {}", tokens.len(), d.batch * d.seq);
        }
        let out = self.run(
            "score",
            &[f32_literal(&[flat.len()], flat)?, i32_literal(&[d.batch, d.seq], tokens)?],
        )?;
        Ok((out[0].to_vec::<f32>()?, out[1].to_vec::<f32>()?))
    }

    /// rollout: (flat, prompts[B,P], key, temperature) → tokens+logprobs.
    pub fn rollout(
        &self,
        flat: &[f32],
        prompts: &[i32],
        key: [u32; 2],
        temperature: f32,
    ) -> Result<RolloutOut> {
        let d = &self.manifest.dims;
        self.check_flat(flat)?;
        if prompts.len() != d.batch * d.prompt_len {
            bail!("prompts len {} != B*P {}", prompts.len(), d.batch * d.prompt_len);
        }
        let out = self.run(
            "rollout",
            &[
                f32_literal(&[flat.len()], flat)?,
                i32_literal(&[d.batch, d.prompt_len], prompts)?,
                u32_literal(&[2], &key)?,
                Literal::from(temperature),
            ],
        )?;
        Ok(RolloutOut { tokens: out[0].to_vec::<i32>()?, logprobs: out[1].to_vec::<f32>()? })
    }

    /// grad: GRPO clipped-surrogate gradients on a rollout batch.
    pub fn grad(
        &self,
        flat: &[f32],
        tokens: &[i32],
        advantages: &[f32],
        old_logprobs: &[f32],
        mask: &[f32],
    ) -> Result<GradOut> {
        let d = &self.manifest.dims;
        self.check_flat(flat)?;
        if tokens.len() != d.batch * d.seq
            || advantages.len() != d.batch
            || old_logprobs.len() != d.batch * d.gen_len
            || mask.len() != d.batch * d.gen_len
        {
            bail!("grad input shape mismatch");
        }
        let out = self.run(
            "grad",
            &[
                f32_literal(&[flat.len()], flat)?,
                i32_literal(&[d.batch, d.seq], tokens)?,
                f32_literal(&[d.batch], advantages)?,
                f32_literal(&[d.batch, d.gen_len], old_logprobs)?,
                f32_literal(&[d.batch, d.gen_len], mask)?,
            ],
        )?;
        Ok(GradOut {
            grads: out[0].to_vec::<f32>()?,
            loss: out[1].get_first_element::<f32>()?,
            clip_frac: out[2].get_first_element::<f32>()?,
            mean_ratio: out[3].get_first_element::<f32>()?,
            grad_density: out[4].get_first_element::<f32>()?,
        })
    }

    /// The AOT-compiled L1 visibility-gate kernel (ablation vs the
    /// native gate in `crate::gate`).
    pub fn gate(&self, theta: &[f32], s: &[f32]) -> Result<Vec<u8>> {
        self.check_flat(theta)?;
        let out = self.run(
            "gate",
            &[f32_literal(&[theta.len()], theta)?, f32_literal(&[s.len()], s)?],
        )?;
        Ok(out[0].to_vec::<u8>()?)
    }

    /// The AOT-compiled fused AdamW kernel (ablation vs `crate::optim`).
    /// `scalars` = [lr, bc1, bc2].
    #[allow(clippy::type_complexity)]
    pub fn adam(
        &self,
        scalars: [f32; 3],
        p: &[f32],
        m: &[f32],
        v: &[f32],
        g: &[f32],
    ) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        self.check_flat(p)?;
        let out = self.run(
            "adam",
            &[
                f32_literal(&[3], &scalars)?,
                f32_literal(&[p.len()], p)?,
                f32_literal(&[m.len()], m)?,
                f32_literal(&[v.len()], v)?,
                f32_literal(&[g.len()], g)?,
            ],
        )?;
        Ok((out[0].to_vec::<f32>()?, out[1].to_vec::<f32>()?, out[2].to_vec::<f32>()?))
    }

    fn check_flat(&self, flat: &[f32]) -> Result<()> {
        if flat.len() != self.manifest.n_params {
            bail!("flat params len {} != n_params {}", flat.len(), self.manifest.n_params);
        }
        Ok(())
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }
}

/// Default artifacts directory: `$PULSE_ARTIFACTS` or `<repo>/artifacts`.
pub fn artifacts_dir() -> PathBuf {
    if let Ok(dir) = std::env::var("PULSE_ARTIFACTS") {
        return PathBuf::from(dir);
    }
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}
