//! The clock seam between the socket plane and the scale simulator.
//!
//! Production code used to call `Instant::now()` directly, which makes
//! time untestable: a simulated 100k-leaf run would spend real seconds
//! inside escalation backoff windows and heartbeat sweeps. A [`Clock`]
//! is either the wall (anchored once per process, so readings are
//! monotone Durations) or a shared virtual counter the discrete-event
//! loop advances explicitly. State machines take `now: Duration`
//! readings from whichever clock they were built with — the *same*
//! comparison code runs under both, so the simulator cannot drift from
//! the TCP plane's timing logic.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, OnceLock};
use std::time::{Duration, Instant};

/// Process-wide wall anchor: all `Clock::Wall` readings are durations
/// since the first reading, so they compare like `Instant`s but share a
/// representation with virtual time.
fn wall_anchor() -> Instant {
    static ANCHOR: OnceLock<Instant> = OnceLock::new();
    *ANCHOR.get_or_init(Instant::now)
}

/// A monotone time source: the process wall clock or a simulator-driven
/// virtual counter (nanoseconds). Cloning a `Virtual` clock shares the
/// counter, so every hop in a simulated tree reads the same instant.
#[derive(Clone, Debug)]
pub enum Clock {
    /// Real time, anchored at first use.
    Wall,
    /// Simulated time in nanoseconds, advanced by the event loop.
    Virtual(Arc<AtomicU64>),
}

impl Default for Clock {
    fn default() -> Clock {
        Clock::Wall
    }
}

impl Clock {
    /// The wall clock (production default).
    pub fn wall() -> Clock {
        Clock::Wall
    }

    /// A fresh virtual clock starting at t=0.
    pub fn virtual_clock() -> Clock {
        Clock::Virtual(Arc::new(AtomicU64::new(0)))
    }

    /// Current reading. Wall readings are monotone durations since the
    /// process anchor; virtual readings are whatever the event loop
    /// last set.
    pub fn now(&self) -> Duration {
        match self {
            Clock::Wall => wall_anchor().elapsed(),
            Clock::Virtual(t) => Duration::from_nanos(t.load(Ordering::Acquire)),
        }
    }

    /// Advance a virtual clock to `ns` (no-op if already past — virtual
    /// time never rewinds, mirroring wall monotonicity). Panics on a
    /// wall clock: only the simulator owns time.
    pub fn advance_to(&self, ns: u64) {
        match self {
            Clock::Wall => panic!("advance_to on the wall clock"),
            Clock::Virtual(t) => {
                t.fetch_max(ns, Ordering::AcqRel);
            }
        }
    }

    /// True for `Clock::Virtual` — used by socket-plane loops to skip
    /// real sleeps that would stall a simulated run.
    pub fn is_virtual(&self) -> bool {
        matches!(self, Clock::Virtual(_))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wall_readings_are_monotone() {
        let c = Clock::wall();
        let a = c.now();
        let b = c.now();
        assert!(b >= a);
    }

    #[test]
    fn virtual_clock_is_shared_and_never_rewinds() {
        let c = Clock::virtual_clock();
        let d = c.clone();
        assert_eq!(c.now(), Duration::ZERO);
        c.advance_to(5_000);
        assert_eq!(d.now(), Duration::from_nanos(5_000), "clones share the counter");
        c.advance_to(1_000); // rewind attempt
        assert_eq!(d.now(), Duration::from_nanos(5_000), "time never rewinds");
        assert!(c.is_virtual() && !Clock::wall().is_virtual());
    }
}
