//! Scripted churn for the scale simulator: timed joins, silent
//! crashes, and slow-subscriber degradations.
//!
//! A [`ChurnScript`] is a fixed list of `(at, action)` pairs resolved
//! against the *live* population when each event fires (`nth` picks
//! the n-th live node of the role, modulo the live count, in id
//! order) — so a script composed before the run stays valid however
//! earlier events reshaped the cluster. [`ChurnScript::seeded`]
//! derives a mixed workload from a seed via
//! [`crate::util::rng::splitmix64`]; the same seed always yields the
//! same script, which is half of the determinism contract
//! (`crate::sim` module docs).

use std::time::Duration;

use crate::util::rng::splitmix64;

/// One scripted perturbation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ChurnAction {
    /// A new leaf registers with the control plane.
    JoinLeaf,
    /// A new (spare) relay registers with the control plane.
    JoinRelay,
    /// The n-th live relay (id order, modulo live count) freezes
    /// silently: it stops processing and heartbeating but its sockets
    /// stay "open" — death is discovered by the sweep, exactly like
    /// `ControlledNode::fail_silently` on the TCP plane.
    CrashRelay { nth: usize },
    /// The n-th live leaf freezes silently.
    CrashLeaf { nth: usize },
    /// The n-th live leaf's ingress edge drops to `1/factor` of its
    /// bandwidth — the slow-subscriber case coalescing exists for.
    SlowLeaf { nth: usize, factor: u32 },
}

/// One timed churn event.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ChurnEvent {
    /// Virtual time the action fires.
    pub at: Duration,
    pub action: ChurnAction,
}

/// An ordered churn schedule (construction order; the simulator's
/// event heap breaks same-instant ties by schedule order).
#[derive(Debug, Clone, Default)]
pub struct ChurnScript {
    pub events: Vec<ChurnEvent>,
}

impl ChurnScript {
    /// No churn.
    pub fn none() -> ChurnScript {
        ChurnScript::default()
    }

    /// Builder-style append.
    pub fn then(mut self, at: Duration, action: ChurnAction) -> ChurnScript {
        self.events.push(ChurnEvent { at, action });
        self
    }

    /// A deterministic mixed workload: `count` events spread evenly
    /// over `[start, start + span)` with seeded jitter, cycling
    /// through joins, crashes, and slowdowns with seeded selectors.
    pub fn seeded(seed: u64, count: usize, start: Duration, span: Duration) -> ChurnScript {
        let mut s = seed.wrapping_mul(0xA076_1D64_78BD_642F) ^ 0x5851_F42D_4C95_7F2D;
        let mut events = Vec::with_capacity(count);
        let span_ns = span.as_nanos() as u64;
        for i in 0..count {
            let slot = span_ns * i as u64 / count as u64;
            let jitter = splitmix64(&mut s) % (span_ns / count as u64).max(1);
            let at = start + Duration::from_nanos(slot + jitter);
            let nth = (splitmix64(&mut s) % 64) as usize;
            let action = match splitmix64(&mut s) % 5 {
                0 => ChurnAction::JoinLeaf,
                1 => ChurnAction::JoinRelay,
                2 => ChurnAction::CrashRelay { nth },
                3 => ChurnAction::CrashLeaf { nth },
                _ => ChurnAction::SlowLeaf { nth, factor: 4 << (splitmix64(&mut s) % 3) },
            };
            events.push(ChurnEvent { at, action });
        }
        ChurnScript { events }
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seeded_scripts_are_reproducible_and_seed_sensitive() {
        let span = Duration::from_secs(10);
        let a = ChurnScript::seeded(9, 16, Duration::from_secs(1), span);
        let b = ChurnScript::seeded(9, 16, Duration::from_secs(1), span);
        let c = ChurnScript::seeded(10, 16, Duration::from_secs(1), span);
        assert_eq!(a.events, b.events);
        assert_ne!(a.events, c.events);
        assert_eq!(a.len(), 16);
        // Events are ordered and inside the window.
        for w in a.events.windows(2) {
            assert!(w[0].at <= w[1].at);
        }
        assert!(a.events.iter().all(|e| {
            e.at >= Duration::from_secs(1) && e.at < Duration::from_secs(1) + span
        }));
    }

    #[test]
    fn builder_appends_in_order() {
        let s = ChurnScript::none()
            .then(Duration::from_secs(1), ChurnAction::CrashRelay { nth: 0 })
            .then(Duration::from_secs(2), ChurnAction::JoinLeaf);
        assert_eq!(s.len(), 2);
        assert_eq!(s.events[1].action, ChurnAction::JoinLeaf);
    }
}
