//! Per-edge link models for the scale simulator: bandwidth
//! (serialization time), propagation latency, and deterministic frame
//! loss.
//!
//! A frame occupying an edge serializes for `bytes * 8 / bandwidth`
//! (the edge is busy and the next queued frame waits), then propagates
//! for `latency` (pipelined — propagation does not block the next
//! frame). Loss is rolled per transmission with
//! [`crate::util::rng::splitmix64`] keyed by `(seed, from, to,
//! tx_seq)`, so a run's loss pattern is a pure function of the
//! simulation seed — same seed, same drops, bit-identical traces.

use std::time::Duration;

use crate::util::rng::splitmix64;

/// One directed edge's transmission model.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LinkModel {
    /// Serialization rate, bits per second.
    pub bandwidth_bps: u64,
    /// One-way propagation delay.
    pub latency: Duration,
    /// Frames lost per million transmissions (deterministic roll).
    pub loss_ppm: u32,
}

impl LinkModel {
    /// Datacenter-ish edge: 1 Gbit/s, 200 µs, lossless.
    pub fn lan() -> LinkModel {
        LinkModel {
            bandwidth_bps: 1_000_000_000,
            latency: Duration::from_micros(200),
            loss_ppm: 0,
        }
    }

    /// Wide-area edge (the paper's cross-region profile): 200 Mbit/s,
    /// 20 ms, lossless.
    pub fn wan() -> LinkModel {
        LinkModel {
            bandwidth_bps: 200_000_000,
            latency: Duration::from_millis(20),
            loss_ppm: 0,
        }
    }

    /// Same link with a loss rate, in frames per million.
    pub fn with_loss(mut self, loss_ppm: u32) -> LinkModel {
        self.loss_ppm = loss_ppm;
        self
    }

    /// Same link with bandwidth divided by `factor` — a degraded
    /// (slow-subscriber) edge. `factor` 0 is treated as 1.
    pub fn slowed(&self, factor: u32) -> LinkModel {
        LinkModel {
            bandwidth_bps: (self.bandwidth_bps / factor.max(1) as u64).max(1),
            ..*self
        }
    }

    /// Nanoseconds the edge is busy serializing `bytes`.
    pub fn serialize_ns(&self, bytes: u64) -> u64 {
        ((bytes as u128 * 8 * 1_000_000_000) / self.bandwidth_bps.max(1) as u128) as u64
    }

    /// Nanoseconds until `bytes` fully arrive at the far end
    /// (serialization + propagation).
    pub fn tx_ns(&self, bytes: u64) -> u64 {
        self.serialize_ns(bytes) + self.latency.as_nanos() as u64
    }
}

impl Default for LinkModel {
    fn default() -> LinkModel {
        LinkModel::lan()
    }
}

/// Deterministic per-transmission loss roll: a pure function of the
/// run seed, the directed edge, and the global transmission sequence
/// number. No wall-clock entropy anywhere.
pub fn frame_lost(seed: u64, from: u64, to: u64, tx_seq: u64, loss_ppm: u32) -> bool {
    if loss_ppm == 0 {
        return false;
    }
    let mut s = seed
        ^ from.rotate_left(17)
        ^ to.rotate_left(31)
        ^ tx_seq.wrapping_mul(0x9E37_79B9_7F4A_7C15);
    splitmix64(&mut s) % 1_000_000 < loss_ppm as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serialization_and_arrival_arithmetic() {
        let l = LinkModel {
            bandwidth_bps: 8_000_000, // 1 MB/s
            latency: Duration::from_millis(5),
            loss_ppm: 0,
        };
        // 1000 bytes at 1 MB/s = 1 ms serialization.
        assert_eq!(l.serialize_ns(1000), 1_000_000);
        assert_eq!(l.tx_ns(1000), 6_000_000);
        // Slowing 4x quarters the bandwidth, latency untouched.
        let s = l.slowed(4);
        assert_eq!(s.serialize_ns(1000), 4_000_000);
        assert_eq!(s.latency, l.latency);
        assert_eq!(l.slowed(0).bandwidth_bps, l.bandwidth_bps);
    }

    #[test]
    fn loss_roll_is_deterministic_and_seed_sensitive() {
        // Same key → same verdict, every time.
        for seq in 0..64 {
            assert_eq!(
                frame_lost(7, 1, 2, seq, 500_000),
                frame_lost(7, 1, 2, seq, 500_000)
            );
        }
        // A 50% rate actually loses something over 256 rolls, and two
        // seeds disagree somewhere.
        let a: Vec<bool> = (0..256).map(|s| frame_lost(1, 3, 4, s, 500_000)).collect();
        let b: Vec<bool> = (0..256).map(|s| frame_lost(2, 3, 4, s, 500_000)).collect();
        assert!(a.iter().any(|&x| x) && a.iter().any(|&x| !x));
        assert_ne!(a, b);
        // Zero rate never loses.
        assert!((0..256).all(|s| !frame_lost(1, 3, 4, s, 0)));
    }
}
