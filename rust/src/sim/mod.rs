//! Deterministic scale-model simulation of the PULSE sync plane.
//!
//! A seeded discrete-event simulator that drives the **real** plane
//! logic — no forks — over a modeled network, so 100k-leaf relay
//! trees converge in simulated time on a laptop-class CI runner:
//!
//! * Membership, failure detection, and fan-out planning run through
//!   the real [`crate::net::control::Membership`] (which itself calls
//!   [`crate::coordinator::planner::stable_relay_order`] +
//!   [`crate::coordinator::planner::bind`]); directives fence through
//!   the real [`crate::net::control::EpochFence`].
//! * Every hop stages and coalesces with the real
//!   [`crate::net::relay::RelayStage`] and
//!   [`crate::net::relay::coalesce_enqueue`]; NACK storms dedup
//!   through the real [`crate::net::relay::EscalationLedger`].
//! * Leaf NACK backoff uses the real
//!   [`crate::util::retry::RetryAt`] schedule; slow-path head/anchor
//!   selection is the real [`crate::pulse::sync::latest_of`] +
//!   [`crate::pulse::sync::slow_path_anchor`] arithmetic against a
//!   real [`crate::net::transport::SyncTransport`] (an
//!   [`crate::net::transport::InProcTransport`] store by default; a
//!   [`crate::net::transport::FaultInjectingTransport`] to model an
//!   unserviceable backstop).
//!
//! Time is a virtual [`clock::Clock`]: the event loop pops the
//! earliest `(t, seq)` event and advances the clock to it — a 100k
//! leaf run covering a minute of simulated time executes in seconds
//! of real time. Frames are real [`crate::net::tcp::Frame`] values
//! (step/shard carried in the first payload bytes, padded to the
//! modeled size) so the shared staging/coalescing code operates on
//! exactly what the socket plane ships.
//!
//! # Determinism contract
//!
//! A run is a pure function of `(SimConfig, seed)`: same config →
//! bit-identical metrics AND an identical event-trace hash (FNV-1a
//! over every processed event); a different seed diverges. Everything
//! random (loss rolls, churn scripts, retry jitter) derives from
//! [`crate::util::rng::splitmix64`]; no wall-clock reading enters any
//! decision; every cross-node collection is iterated in a
//! deterministic order (dense id vectors, `BTreeMap`s, or sorted
//! drains).
//!
//! Frame loss on a modeled edge stands in for the chaos faults the
//! socket plane injects (torn connections, truncated writes): a hole
//! the NACK path cannot repair falls back to the store, exactly like
//! the `NACK_MISS` escalation contract.

pub mod churn;
pub mod clock;
pub mod link;
pub mod topo;

use std::cmp::Ordering;
use std::collections::{BinaryHeap, HashMap, HashSet, VecDeque};
use std::sync::Arc;
use std::time::Duration;

use crate::coordinator::planner::{Assignment, Upstream};
use crate::net::control::{role, Membership};
use crate::net::relay::{coalesce_enqueue, DEFAULT_QUEUE_DEPTH, INDEX_STEPS};
use crate::net::tcp::{kind, Frame};
use crate::net::transport::{
    sharded_marker, FrameId, InProcTransport, MarkerId, StepData, SyncTransport,
};
use crate::obs::{fold_span, FlightRecorder, SpanEvent, Stage};
use crate::pulse::sync::{latest_of, slow_path_anchor};
use crate::util::retry::RetryPolicy;

use churn::{ChurnAction, ChurnScript};
use clock::Clock;
use link::{frame_lost, LinkModel};
use topo::{SimNode, TopoSpec};

/// Per-frame wire framing cost (kind byte + u32 length prefix).
pub const FRAME_WIRE_OVERHEAD: u64 = 5;
/// Modeled size of a MARKER frame payload.
const MARKER_BYTES: usize = 64;
/// Modeled size of a control frame payload (NACK, NACK_MISS).
const CTRL_BYTES: usize = 12;
/// The 64-char content root stamped into sim markers (the marker
/// grammar requires one; the sim never verifies it).
const SIM_ROOT: &str = "0000000000000000000000000000000000000000000000000000000000000000";

// ------------------------------------------------------- modeled frames

fn patch_frame(step: u64, shard: u32, bytes: usize) -> Frame {
    let mut payload = vec![0u8; bytes.max(CTRL_BYTES)];
    payload[0..8].copy_from_slice(&step.to_le_bytes());
    payload[8..12].copy_from_slice(&shard.to_le_bytes());
    Frame { kind: kind::PATCH, payload }
}

fn anchor_frame(step: u64, bytes: usize) -> Frame {
    let mut payload = vec![0u8; bytes.max(8)];
    payload[0..8].copy_from_slice(&step.to_le_bytes());
    Frame { kind: kind::ANCHOR, payload }
}

fn marker_frame(step: u64, shards: u32) -> Frame {
    let mut payload = vec![0u8; MARKER_BYTES];
    payload[0..8].copy_from_slice(&step.to_le_bytes());
    payload[8..12].copy_from_slice(&shards.to_le_bytes());
    Frame { kind: kind::MARKER, payload }
}

fn ctrl_frame(k: u8, step: u64, shard: u32) -> Frame {
    let mut payload = vec![0u8; CTRL_BYTES];
    payload[0..8].copy_from_slice(&step.to_le_bytes());
    payload[8..12].copy_from_slice(&shard.to_le_bytes());
    Frame { kind: k, payload }
}

/// Step number carried in a modeled frame (0 when too short).
fn frame_step(f: &Frame) -> u64 {
    f.payload
        .get(0..8)
        .map_or(0, |b| u64::from_le_bytes(b.try_into().unwrap()))
}

/// Shard index / shard count carried in a modeled frame.
fn frame_shard(f: &Frame) -> u32 {
    f.payload
        .get(8..12)
        .map_or(0, |b| u32::from_le_bytes(b.try_into().unwrap()))
}

// ------------------------------------------------------------ the events

enum Ev {
    /// The publisher emits step `step` (0 = the initial anchor).
    Publish { step: u64 },
    /// A frame finishes arriving at `to`.
    Deliver { from: u64, to: u64, frame: Arc<Frame> },
    /// `from → to` finishes serializing its current frame.
    EdgeFree { from: u64, to: u64 },
    /// One batched heartbeat wave lands at the control plane.
    Heartbeats,
    /// The failure detector sweeps the registry.
    Sweep,
    /// Scripted churn event `idx` fires.
    Churn { idx: usize },
    /// A leaf's NACK backoff timer for `(step, shard)` expires.
    LeafRetry { leaf: u64, step: u64, shard: u32 },
    /// A leaf's slow-path (store fallback) fetch completes.
    SlowDone { leaf: u64, target: u64, bytes: u64 },
    /// Post-publish stall probe: a leaf still short of the final head
    /// with no repair in flight falls back to the store (the consumer
    /// poll — with lossy links a tail-end marker can vanish with no
    /// later traffic to expose the hole).
    StallCheck { leaf: u64 },
}

struct Pending {
    t: u64,
    seq: u64,
    ev: Ev,
}

impl Ord for Pending {
    // BinaryHeap is a max-heap: invert so the earliest (t, seq) pops
    // first. seq breaks same-instant ties in schedule order — the
    // other half of the determinism contract.
    fn cmp(&self, other: &Pending) -> Ordering {
        other.t.cmp(&self.t).then_with(|| other.seq.cmp(&self.seq))
    }
}
impl PartialOrd for Pending {
    fn partial_cmp(&self, other: &Pending) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl PartialEq for Pending {
    fn eq(&self, other: &Pending) -> bool {
        self.t == other.t && self.seq == other.seq
    }
}
impl Eq for Pending {}

// ------------------------------------------------------------ the config

/// One simulation run's full parameterization. A run is a pure
/// function of this value (see module docs).
#[derive(Clone)]
pub struct SimConfig {
    pub topo: TopoSpec,
    /// Default tree-edge link model.
    pub link: LinkModel,
    /// Link model for slow-path store fetches.
    pub store_link: LinkModel,
    /// Seeds loss rolls; combine with churn scripts seeded likewise.
    pub seed: u64,
    /// Patch steps to publish (step numbers 1..=steps).
    pub steps: u64,
    pub step_interval: Duration,
    /// Shards per step (clamped ≥ 2 — the sharded marker grammar's
    /// floor).
    pub shards_per_step: u32,
    pub bytes_per_shard: usize,
    pub anchor_bytes: usize,
    /// Publish a fresh anchor every N steps (0 = only the initial
    /// anchor at t=0).
    pub anchor_every: u64,
    /// Per-subscriber queue bound (the coalescing trigger).
    pub queue_depth: usize,
    /// Per-hop NACK index bound, in distinct steps.
    pub index_steps: usize,
    pub heartbeat_interval: Duration,
    /// Sweep timeout = `heartbeat_interval * missed_heartbeats`.
    pub missed_heartbeats: u32,
    /// Leaf NACK retry schedule.
    pub nack_policy: RetryPolicy,
    /// Relay escalation backoff (storm suppression window).
    pub escalate_policy: RetryPolicy,
    pub churn: ChurnScript,
    /// How long a leaf may sit short of the final head with no repair
    /// in flight before the stall probe sends it to the store.
    pub stall_grace: Duration,
    /// Virtual-time cap: a run that hasn't converged by here reports
    /// `converged: false`.
    pub horizon: Duration,
    /// Event cap backstop against runaway configurations.
    pub max_events: u64,
    /// Capacity of the run's span flight recorder. The span *hash*
    /// always covers every span; the recorder keeps the newest
    /// `recorder_capacity` for reconstruction/dumps, so memory stays
    /// bounded at 100k leaves. `paper trace --sim` raises this so the
    /// whole run's spans survive for timeline reconstruction.
    pub recorder_capacity: usize,
}

impl SimConfig {
    pub fn new(topo: TopoSpec, seed: u64) -> SimConfig {
        SimConfig {
            topo,
            link: LinkModel::lan(),
            store_link: LinkModel::lan(),
            seed,
            steps: 5,
            step_interval: Duration::from_millis(100),
            shards_per_step: 4,
            bytes_per_shard: 4096,
            anchor_bytes: 65536,
            anchor_every: 0,
            queue_depth: DEFAULT_QUEUE_DEPTH,
            index_steps: INDEX_STEPS,
            heartbeat_interval: Duration::from_millis(500),
            missed_heartbeats: 3,
            nack_policy: RetryPolicy::nack_default(),
            escalate_policy: RetryPolicy::escalate_default(),
            churn: ChurnScript::none(),
            stall_grace: Duration::from_secs(1),
            horizon: Duration::from_secs(120),
            max_events: 100_000_000,
            recorder_capacity: crate::obs::DEFAULT_RING,
        }
    }
}

// ------------------------------------------------------------ the report

/// Everything one run measured. All byte counts include
/// [`FRAME_WIRE_OVERHEAD`] per frame.
#[derive(Debug, Clone, PartialEq)]
pub struct SimReport {
    pub seed: u64,
    /// Live population at the end of the run.
    pub leaves_live: usize,
    pub relays_live: usize,
    /// Tree depth (hops root→leaf) under the final plan.
    pub depth: usize,
    /// Every live leaf reached the final published head in time.
    pub converged: bool,
    pub converged_at: Duration,
    /// When the publisher finished its last step.
    pub publish_done_at: Duration,
    /// Convergence lag past the last publish.
    pub settle: Duration,
    pub head_step: u64,
    /// Total bytes that arrived at leaves (stream + repairs + slow
    /// paths).
    pub leaf_bytes: u64,
    pub bytes_per_leaf: u64,
    /// One clean copy of everything published, per leaf.
    pub ideal_bytes_per_leaf: u64,
    /// `bytes_per_leaf` over the ideal, as a percentage above 100.
    pub overhead_pct: f64,
    /// Bytes serialized across every tree edge.
    pub link_bytes: u64,
    pub frames_lost: u64,
    pub leaf_nacks: u64,
    pub leaf_nack_retries: u64,
    pub nacks_serviced: u64,
    pub nacks_escalated: u64,
    pub nacks_suppressed: u64,
    pub nacks_unserviceable: u64,
    pub nack_misses: u64,
    /// Retransmits relayed to riders at interior hops.
    pub retransmits: u64,
    /// NACKs the root answered out of the store rather than its index.
    pub store_repairs: u64,
    /// NACKed shards that a retransmit actually filled at a leaf.
    pub leaf_repairs: u64,
    /// Frames a leaf ignored as already-applied duplicates.
    pub dup_frames: u64,
    /// Frames that arrived at a crashed peer.
    pub delivered_to_dead: u64,
    pub slow_paths: u64,
    /// Bytes the origin store actually served for slow-path fetches
    /// under the caching-hop model (each object is charged once per
    /// cold relay subtree, not once per leaf — the store plane's
    /// `CachingStore` egress bound, priced at scale).
    pub origin_bytes: u64,
    /// Slow-path object reads served by a warm ancestor relay cache.
    pub store_hits: u64,
    /// Slow-path object reads that had to go to the origin.
    pub store_misses: u64,
    pub nack_budget_exhausted: u64,
    pub coalesced: u64,
    pub frames_superseded: u64,
    pub epochs: u64,
    pub replans: u64,
    pub deaths: u64,
    pub reparents: u64,
    pub fenced: u64,
    pub joins: u64,
    pub crashes: u64,
    pub slowdowns: u64,
    /// Deepest any subscriber queue got, in frames.
    pub max_queue_depth: usize,
    pub events: u64,
    /// FNV-1a over every processed event, in processing order.
    pub trace_hash: u64,
    /// Trace spans emitted across the run (publish → relay stage →
    /// NACK/escalate → apply, stamped in virtual microseconds).
    pub spans: u64,
    /// [`crate::obs::fold_span`] over every span, in emit order — the
    /// replay-identity witness for the span stream (bounded memory:
    /// the hash covers spans the recorder has since overwritten).
    pub span_hash: u64,
    /// The newest `recorder_capacity` spans, for timeline
    /// reconstruction ([`crate::obs::reconstruct`]) and CI artifact
    /// dumps.
    pub span_events: Vec<SpanEvent>,
}

impl SimReport {
    /// Header for the `results/sim_scale.csv` paper table.
    pub fn csv_header() -> &'static str {
        "leaves,relays,depth,seed,converged,settle_ms,bytes_per_leaf,\
         ideal_bytes_per_leaf,overhead_pct,nacks,slow_paths,origin_bytes,\
         store_hits,store_misses,coalesced,replans,deaths,max_queue,\
         events,trace_hash,spans,span_hash"
    }

    /// One CSV row matching [`SimReport::csv_header`].
    pub fn csv_row(&self) -> String {
        format!(
            "{},{},{},{},{},{:.1},{},{},{:.2},{},{},{},{},{},{},{},{},{},{},{:016x},{},{:016x}",
            self.leaves_live,
            self.relays_live,
            self.depth,
            self.seed,
            self.converged,
            self.settle.as_secs_f64() * 1e3,
            self.bytes_per_leaf,
            self.ideal_bytes_per_leaf,
            self.overhead_pct,
            self.leaf_nacks,
            self.slow_paths,
            self.origin_bytes,
            self.store_hits,
            self.store_misses,
            self.coalesced,
            self.replans,
            self.deaths,
            self.max_queue_depth,
            self.events,
            self.trace_hash,
            self.spans,
            self.span_hash,
        )
    }
}

// ------------------------------------------------------------- the edges

struct Edge {
    q: VecDeque<Arc<Frame>>,
    busy: bool,
    link: LinkModel,
}

#[derive(Default)]
struct Counters {
    leaf_bytes: u64,
    link_bytes: u64,
    frames_lost: u64,
    dup_frames: u64,
    to_dead: u64,
    leaf_nacks: u64,
    leaf_nack_retries: u64,
    leaf_repairs: u64,
    nacks_serviced: u64,
    nacks_escalated: u64,
    nacks_suppressed: u64,
    nacks_unserviceable: u64,
    nack_misses: u64,
    retransmits: u64,
    store_repairs: u64,
    slow_paths: u64,
    origin_bytes: u64,
    store_hits: u64,
    store_misses: u64,
    nack_budget_exhausted: u64,
    coalesced: u64,
    frames_superseded: u64,
    reparents: u64,
    fenced: u64,
    joins: u64,
    crashes: u64,
    slowdowns: u64,
    max_queue: usize,
}

// ------------------------------------------------------------ the engine

struct Sim {
    cfg: SimConfig,
    clock: Clock,
    members: Membership,
    store: Box<dyn SyncTransport>,
    nodes: Vec<SimNode>,
    edges: HashMap<(u64, u64), Edge>,
    heap: BinaryHeap<Pending>,
    seq: u64,
    tx_seq: u64,
    horizon_ns: u64,
    depth: usize,
    final_head: u64,
    publish_done: bool,
    publish_done_at: u64,
    live_leaves: usize,
    at_head_leaves: usize,
    converged_at: Option<u64>,
    done: bool,
    events: u64,
    hash: u64,
    recorder: FlightRecorder,
    spans: u64,
    span_hash: u64,
    m: Counters,
    /// Per-relay warm object sets for the caching-hop store model
    /// (`net::store::CachingStore`): a slow-path fetch warms every
    /// live relay on the leaf's ancestor path, and later fetches of
    /// the same object from that subtree are served there instead of
    /// billing origin egress.
    store_warm: HashMap<u64, HashSet<(u8, u64, u32)>>,
}

/// Run one simulation over the default in-process store.
pub fn run(cfg: SimConfig) -> SimReport {
    let window = (cfg.steps as usize).saturating_add(8).max(16);
    run_with_store(cfg, Box::new(InProcTransport::with_window(window, 16)))
}

/// Run one simulation over an explicit store backend (e.g. a
/// [`crate::net::transport::FaultInjectingTransport`] to model an
/// unserviceable backstop slot).
pub fn run_with_store(cfg: SimConfig, store: Box<dyn SyncTransport>) -> SimReport {
    let mut sim = Sim {
        horizon_ns: cfg.horizon.as_nanos() as u64,
        recorder: FlightRecorder::new(cfg.recorder_capacity),
        cfg,
        clock: Clock::virtual_clock(),
        members: Membership::new(),
        store,
        nodes: Vec::new(),
        edges: HashMap::new(),
        heap: BinaryHeap::new(),
        seq: 0,
        tx_seq: 0,
        depth: 0,
        final_head: 0,
        publish_done: false,
        publish_done_at: 0,
        live_leaves: 0,
        at_head_leaves: 0,
        converged_at: None,
        done: false,
        events: 0,
        hash: 0xcbf2_9ce4_8422_2325,
        spans: 0,
        span_hash: 0xcbf2_9ce4_8422_2325,
        m: Counters::default(),
        store_warm: HashMap::new(),
    };
    sim.bootstrap();
    while let Some(p) = sim.heap.pop() {
        if p.t > sim.horizon_ns || sim.events >= sim.cfg.max_events {
            break;
        }
        sim.clock.advance_to(p.t);
        sim.events += 1;
        sim.hash_event(&p);
        sim.dispatch(p.t, p.ev);
        if sim.done {
            break;
        }
    }
    sim.report()
}

impl Sim {
    fn schedule(&mut self, t: u64, ev: Ev) {
        self.seq += 1;
        self.heap.push(Pending { t, seq: self.seq, ev });
    }

    /// Emit one trace span at virtual time `t` (ns → µs). Spans use
    /// the same stage vocabulary as the socket plane's `obs` hub;
    /// generation is always 0 here (the sim models a single publisher
    /// lineage). Every span folds into `span_hash` in emit order — the
    /// recorder only retains the newest `recorder_capacity` of them.
    fn span(&mut self, t: u64, stage: Stage, step: u64, shard: u32, detail: u64) {
        let ev = SpanEvent {
            t_us: t / 1_000,
            generation: 0,
            step,
            shard,
            stage: stage as u8,
            detail,
        };
        self.recorder.record(ev);
        self.spans += 1;
        self.span_hash = fold_span(self.span_hash, &ev);
    }

    fn bootstrap(&mut self) {
        self.nodes.push(SimNode::root(self.cfg.index_steps));
        for _ in 0..self.cfg.topo.relays {
            let id = self.members.join(role::RELAY, 0, Duration::ZERO);
            self.nodes.push(SimNode::relay(
                id,
                self.cfg.index_steps,
                self.cfg.escalate_policy.clone(),
            ));
        }
        for _ in 0..self.cfg.topo.leaves {
            let id = self.members.join(role::LEAF, 0, Duration::ZERO);
            self.nodes.push(SimNode::leaf(id));
            self.live_leaves += 1;
        }
        // One batched replan for the bootstrap wave (the TCP plane
        // replans per JOIN; a simulated 100k-join wave batches).
        self.replan_apply(0);
        self.schedule(0, Ev::Publish { step: 0 });
        let hb = self.cfg.heartbeat_interval.as_nanos() as u64;
        self.schedule(hb, Ev::Heartbeats);
        self.schedule((hb / 2).max(1), Ev::Sweep);
        let churn: Vec<(u64, usize)> = self
            .cfg
            .churn
            .events
            .iter()
            .enumerate()
            .map(|(i, e)| (e.at.as_nanos() as u64, i))
            .collect();
        for (at, i) in churn {
            self.schedule(at, Ev::Churn { idx: i });
        }
    }

    // FNV-1a over the processed event stream: the trace hash two runs
    // of one config must agree on bit-for-bit.
    fn hash_event(&mut self, p: &Pending) {
        let (tag, a, b, c): (u64, u64, u64, u64) = match &p.ev {
            Ev::Publish { step } => (1, *step, 0, 0),
            Ev::Deliver { from, to, frame } => (
                2,
                *from,
                *to,
                ((frame.kind as u64) << 48) ^ (frame_step(frame) << 8) ^ frame_shard(frame) as u64,
            ),
            Ev::EdgeFree { from, to } => (3, *from, *to, 0),
            Ev::Heartbeats => (4, 0, 0, 0),
            Ev::Sweep => (5, 0, 0, 0),
            Ev::Churn { idx } => (6, *idx as u64, 0, 0),
            Ev::LeafRetry { leaf, step, shard } => (7, *leaf, *step, *shard as u64),
            Ev::SlowDone { leaf, target, bytes } => (8, *leaf, *target, *bytes),
            Ev::StallCheck { leaf } => (9, *leaf, 0, 0),
        };
        for word in [p.t, p.seq, tag, a, b, c] {
            for byte in word.to_le_bytes() {
                self.hash = (self.hash ^ byte as u64).wrapping_mul(0x100_0000_01b3);
            }
        }
    }

    fn dispatch(&mut self, t: u64, ev: Ev) {
        match ev {
            Ev::Publish { step } => self.publish_step(t, step),
            Ev::Deliver { from, to, frame } => self.deliver(t, from, to, frame),
            Ev::EdgeFree { from, to } => {
                if let Some(e) = self.edges.get_mut(&(from, to)) {
                    e.busy = false;
                }
                self.kick_edge(t, from, to);
            }
            Ev::Heartbeats => {
                let now = self.clock.now();
                let resurrected = {
                    let nodes = &self.nodes;
                    self.members.heartbeat_all(now, |id| {
                        nodes.get(id as usize).is_some_and(|n| n.up)
                    })
                };
                if resurrected > 0 {
                    self.replan_apply(t);
                }
                let next = t + self.cfg.heartbeat_interval.as_nanos() as u64;
                if next <= self.horizon_ns {
                    self.schedule(next, Ev::Heartbeats);
                }
            }
            Ev::Sweep => {
                let now = self.clock.now();
                let timeout = self.cfg.heartbeat_interval * self.cfg.missed_heartbeats;
                if self.members.sweep(now, timeout) > 0 {
                    self.replan_apply(t);
                }
                let next = t + (self.cfg.heartbeat_interval.as_nanos() as u64 / 2).max(1);
                if next <= self.horizon_ns {
                    self.schedule(next, Ev::Sweep);
                }
            }
            Ev::Churn { idx } => self.churn_apply(t, idx),
            Ev::LeafRetry { leaf, step, shard } => self.leaf_retry(t, leaf, step, shard),
            Ev::SlowDone { leaf, target, bytes } => {
                let idx = leaf as usize;
                if !self.nodes[idx].up {
                    return;
                }
                self.nodes[idx].in_slow = false;
                self.m.leaf_bytes += bytes;
                if target > self.nodes[idx].applied {
                    self.set_applied(t, leaf, target);
                }
                self.leaf_try_advance(t, leaf);
            }
            Ev::StallCheck { leaf } => self.stall_check(t, leaf),
        }
    }

    /// Post-publish consumer poll: a live leaf short of the final head
    /// with no NACK or slow path in flight has nothing left that could
    /// repair it — send it to the store, and keep probing until it
    /// arrives.
    fn stall_check(&mut self, t: u64, leaf: u64) {
        let idx = leaf as usize;
        let node = &self.nodes[idx];
        if !node.up || node.at_head || node.applied >= self.final_head {
            return;
        }
        if !node.in_slow && node.nacks.is_empty() {
            self.enter_slow(t, leaf);
        }
        let next = t + self.cfg.stall_grace.as_nanos() as u64;
        if next <= self.horizon_ns {
            self.schedule(next, Ev::StallCheck { leaf });
        }
    }

    // ------------------------------------------------------ publishing

    fn publish_step(&mut self, t: u64, step: u64) {
        if step == 0 {
            let f = Arc::new(anchor_frame(0, self.cfg.anchor_bytes));
            let _ = self.store.publish_frame(FrameId::Anchor { step: 0 }, &f.payload);
            let _ = self.store.publish_marker(MarkerId::Anchor(0), "ready");
            self.hop_stream(t, 0, f);
        } else {
            let shards = self.cfg.shards_per_step.max(2);
            for k in 0..shards {
                let f = Arc::new(patch_frame(step, k, self.cfg.bytes_per_shard));
                let _ = self
                    .store
                    .publish_frame(FrameId::Shard { step, shard: k }, &f.payload);
                self.span(t, Stage::Publish, step, k, f.payload.len() as u64);
                self.hop_stream(t, 0, f);
            }
            let _ = self
                .store
                .publish_marker(MarkerId::Delta(step), &sharded_marker(shards, SIM_ROOT));
            self.hop_stream(t, 0, Arc::new(marker_frame(step, shards)));
            if self.cfg.anchor_every > 0 && step % self.cfg.anchor_every == 0 {
                let f = Arc::new(anchor_frame(step, self.cfg.anchor_bytes));
                let _ = self.store.publish_frame(FrameId::Anchor { step }, &f.payload);
                let _ = self.store.publish_marker(MarkerId::Anchor(step), "ready");
                self.hop_stream(t, 0, f);
            }
        }
        if step < self.cfg.steps {
            self.schedule(
                t + self.cfg.step_interval.as_nanos() as u64,
                Ev::Publish { step: step + 1 },
            );
        } else {
            self.publish_done_at = t;
            self.note_publish_done(t);
        }
    }

    /// Stage a stream frame at hop `id` and fan it out through the
    /// real coalescing enqueue — the publish path and the relay
    /// forward path are the same code, as on the socket plane.
    fn hop_stream(&mut self, t: u64, id: u64, frame: Arc<Frame>) {
        let idx = id as usize;
        let meta = (frame.kind == kind::PATCH)
            .then(|| (frame_step(&frame), frame_shard(&frame)));
        self.nodes[idx].stage.as_mut().expect("hop has stage").stage(&frame, meta);
        if let Some((s, k)) = meta {
            self.span(t, Stage::RelayStage, s, k, id);
        }
        let children = self.nodes[idx].children.clone();
        for c in children {
            self.enqueue_stream(t, id, c, &frame);
        }
    }

    // ------------------------------------------------------ edge motion

    fn enqueue_stream(&mut self, t: u64, parent: u64, child: u64, frame: &Arc<Frame>) {
        let depth = self.cfg.queue_depth;
        let (coalesced, dropped) = {
            let stage = self.nodes[parent as usize].stage.as_ref().expect("hop has stage");
            let Some(edge) = self.edges.get_mut(&(parent, child)) else { return };
            let (coalesced, dropped) = coalesce_enqueue(&mut edge.q, frame, stage, depth);
            self.m.frames_superseded += dropped;
            self.m.max_queue = self.m.max_queue.max(edge.q.len());
            (coalesced, dropped)
        };
        if coalesced {
            self.m.coalesced += 1;
        }
        if frame.kind == kind::PATCH && (coalesced || dropped > 0) {
            let (s, k) = (frame_step(frame), frame_shard(frame));
            if coalesced {
                self.span(t, Stage::Coalesce, s, k, parent);
            }
            if dropped > 0 {
                self.span(t, Stage::Evict, s, k, dropped);
            }
        }
        self.kick_edge(t, parent, child);
    }

    /// Queue-order push that bypasses coalescing: NACK retransmits,
    /// NACK_MISS replies, and catch-up preloads (the socket plane's
    /// direct pushes).
    fn push_direct(&mut self, t: u64, from: u64, to: u64, frame: Arc<Frame>) {
        {
            let Some(edge) = self.edges.get_mut(&(from, to)) else { return };
            edge.q.push_back(frame);
            self.m.max_queue = self.m.max_queue.max(edge.q.len());
        }
        self.kick_edge(t, from, to);
    }

    fn kick_edge(&mut self, t: u64, from: u64, to: u64) {
        let frame;
        let ser_ns;
        let arrive_ns;
        let lost;
        {
            let Some(edge) = self.edges.get_mut(&(from, to)) else { return };
            if edge.busy || edge.q.is_empty() {
                return;
            }
            let f = edge.q.pop_front().unwrap();
            edge.busy = true;
            let bytes = f.payload.len() as u64 + FRAME_WIRE_OVERHEAD;
            ser_ns = edge.link.serialize_ns(bytes).max(1);
            arrive_ns = ser_ns + edge.link.latency.as_nanos() as u64;
            self.tx_seq += 1;
            lost = frame_lost(self.cfg.seed, from, to, self.tx_seq, edge.link.loss_ppm);
            self.m.link_bytes += bytes;
            frame = f;
        }
        self.schedule(t + ser_ns, Ev::EdgeFree { from, to });
        if lost {
            self.m.frames_lost += 1;
        } else {
            self.schedule(t + arrive_ns, Ev::Deliver { from, to, frame });
        }
    }

    /// Control frames ride the reverse (upstream) path outside the
    /// data queues — the subscriber socket's back-channel.
    fn send_ctrl(&mut self, t: u64, from: u64, to: u64, k: u8, step: u64, shard: u32) {
        let f = Arc::new(ctrl_frame(k, step, shard));
        let delay = self
            .cfg
            .link
            .tx_ns(f.payload.len() as u64 + FRAME_WIRE_OVERHEAD)
            .max(1);
        self.schedule(t + delay, Ev::Deliver { from, to, frame: f });
    }

    // -------------------------------------------------------- delivery

    fn deliver(&mut self, t: u64, from: u64, to: u64, frame: Arc<Frame>) {
        let idx = to as usize;
        if idx >= self.nodes.len() || !self.nodes[idx].up {
            self.m.to_dead += 1;
            return;
        }
        match frame.kind {
            kind::NACK => {
                let (s, k) = (frame_step(&frame), frame_shard(&frame));
                self.handle_nack(t, to, from, s, k);
            }
            kind::NACK_MISS => {
                let (s, k) = (frame_step(&frame), frame_shard(&frame));
                if self.nodes[idx].is_hop() {
                    // fan the miss out to every rider, as the socket
                    // relay's miss_waiters path does
                    let riders = self.nodes[idx]
                        .ledger
                        .as_mut()
                        .and_then(|l| l.resolve(s, k))
                        .unwrap_or_default();
                    for r in riders {
                        self.push_direct(t, to, r, Arc::new(ctrl_frame(kind::NACK_MISS, s, k)));
                    }
                } else {
                    self.m.nack_misses += 1;
                    self.nodes[idx].nacks.remove(&(s, k));
                    self.enter_slow(t, to);
                }
            }
            _ => {
                if self.nodes[idx].is_hop() {
                    self.hop_deliver(t, to, frame);
                } else {
                    self.leaf_deliver(t, to, frame);
                }
            }
        }
    }

    fn hop_deliver(&mut self, t: u64, id: u64, frame: Arc<Frame>) {
        let idx = id as usize;
        if frame.kind == kind::PATCH {
            // A PATCH answering an escalated slot is a retransmit:
            // index it and hand it only to the riders (the socket
            // plane's deliver_retransmit contract).
            let (s, k) = (frame_step(&frame), frame_shard(&frame));
            let riders = self.nodes[idx].ledger.as_mut().and_then(|l| l.resolve(s, k));
            if let Some(riders) = riders {
                self.nodes[idx]
                    .stage
                    .as_mut()
                    .expect("hop has stage")
                    .index_frame(s, k, frame.clone());
                self.m.retransmits += riders.len() as u64;
                self.span(t, Stage::Retransmit, s, k, riders.len() as u64);
                for r in riders {
                    self.push_direct(t, id, r, frame.clone());
                }
                return;
            }
        }
        self.hop_stream(t, id, frame);
    }

    fn handle_nack(&mut self, t: u64, id: u64, from: u64, step: u64, shard: u32) {
        let idx = id as usize;
        // Serve from this hop's frame index when it still has the slot.
        let hit = self.nodes[idx].stage.as_ref().and_then(|st| st.lookup(step, shard));
        if let Some(f) = hit {
            self.m.nacks_serviced += 1;
            self.span(t, Stage::NackServe, step, shard, id);
            self.push_direct(t, id, from, f);
            return;
        }
        if id == 0 {
            // The root's backstop is the store — the same role the
            // object store plays behind NACK_MISS on the socket plane.
            match self.store.fetch_shard(step, shard) {
                Ok(bytes) => {
                    let f = Arc::new(Frame { kind: kind::PATCH, payload: bytes });
                    self.nodes[0]
                        .stage
                        .as_mut()
                        .expect("root has stage")
                        .index_frame(step, shard, f.clone());
                    self.m.nacks_serviced += 1;
                    self.m.store_repairs += 1;
                    self.span(t, Stage::NackServe, step, shard, 0);
                    self.push_direct(t, 0, from, f);
                }
                Err(_) => {
                    self.m.nacks_unserviceable += 1;
                    self.span(t, Stage::NackMiss, step, shard, 0);
                    self.push_direct(
                        t,
                        0,
                        from,
                        Arc::new(ctrl_frame(kind::NACK_MISS, step, shard)),
                    );
                }
            }
            return;
        }
        // Interior relay: escalate upstream through the real
        // storm-suppression ledger (rider = downstream peer id).
        let now = self.clock.now();
        let escalate = self.nodes[idx]
            .ledger
            .as_mut()
            .expect("relay has ledger")
            .on_nack(step, shard, from, |a, b| a == b, now);
        if !escalate {
            self.m.nacks_suppressed += 1;
            return;
        }
        self.m.nacks_escalated += 1;
        self.span(t, Stage::Escalate, step, shard, id);
        match self.nodes[idx].parent {
            Some(p) => self.send_ctrl(t, id, p, kind::NACK, step, shard),
            None => {
                // Orphaned hop: nothing upstream to ask — fail the
                // slot so riders fall back to the store (the
                // fail_escalated contract).
                let riders = self.nodes[idx]
                    .ledger
                    .as_mut()
                    .expect("relay has ledger")
                    .resolve(step, shard)
                    .unwrap_or_default();
                self.m.nacks_unserviceable += 1;
                self.span(t, Stage::NackMiss, step, shard, id);
                for r in riders {
                    self.push_direct(t, id, r, Arc::new(ctrl_frame(kind::NACK_MISS, step, shard)));
                }
            }
        }
    }

    // ---------------------------------------------------- leaf assembly

    fn leaf_deliver(&mut self, t: u64, id: u64, frame: Arc<Frame>) {
        let idx = id as usize;
        self.m.leaf_bytes += frame.payload.len() as u64 + FRAME_WIRE_OVERHEAD;
        match frame.kind {
            kind::ANCHOR => {
                let s = frame_step(&frame);
                if s > self.nodes[idx].applied {
                    self.set_applied(t, id, s);
                    self.leaf_try_advance(t, id);
                }
            }
            kind::PATCH => {
                let (s, k) = (frame_step(&frame), frame_shard(&frame));
                if s <= self.nodes[idx].applied {
                    self.m.dup_frames += 1;
                    return;
                }
                self.nodes[idx].pending.entry(s).or_default().seen.insert(k);
                if self.nodes[idx].nacks.remove(&(s, k)).is_some() {
                    self.m.leaf_repairs += 1;
                }
                self.leaf_try_advance(t, id);
            }
            kind::MARKER => {
                let s = frame_step(&frame);
                let n = frame_shard(&frame);
                if s <= self.nodes[idx].applied {
                    self.m.dup_frames += 1;
                    return;
                }
                self.nodes[idx].pending.entry(s).or_default().total = Some(n);
                let applied = self.nodes[idx].applied;
                if s == applied + 1 {
                    let missing: Vec<u32> = {
                        let asm = self.nodes[idx].pending.get(&s).unwrap();
                        (0..n).filter(|k| !asm.seen.contains(k)).collect()
                    };
                    if missing.is_empty() {
                        self.leaf_try_advance(t, id);
                    } else if !self.nodes[idx].in_slow {
                        for k in missing {
                            self.leaf_start_nack(t, id, s, k);
                        }
                    }
                } else {
                    // A commit point beyond applied+1: the stream has
                    // a hole no NACK can name (a lost marker or a
                    // coalesced-away step) — store fallback.
                    self.enter_slow(t, id);
                }
            }
            _ => {}
        }
    }

    fn leaf_try_advance(&mut self, t: u64, id: u64) {
        let idx = id as usize;
        let mut new_applied = self.nodes[idx].applied;
        loop {
            let next = new_applied + 1;
            let complete = match self.nodes[idx].pending.get(&next) {
                Some(asm) => match asm.total {
                    Some(n) => (0..n).all(|k| asm.seen.contains(&k)),
                    None => false,
                },
                None => false,
            };
            if !complete {
                break;
            }
            new_applied = next;
        }
        if new_applied > self.nodes[idx].applied {
            self.set_applied(t, id, new_applied);
        }
    }

    fn set_applied(&mut self, t: u64, id: u64, new: u64) {
        let idx = id as usize;
        let (old, reached) = {
            let node = &mut self.nodes[idx];
            let old = node.applied;
            node.applied = new;
            node.pending = node.pending.split_off(&(new + 1));
            node.nacks.retain(|&(s, _), _| s > new);
            if self.publish_done && !node.at_head && new >= self.final_head {
                node.at_head = true;
                (old, true)
            } else {
                (old, false)
            }
        };
        // apply spans close every (step, shard) timeline this advance
        // covers — anchor jumps included, matching the consumer's
        // chain-apply semantics
        let shards = self.cfg.shards_per_step.max(2);
        for s in old + 1..=new.min(self.cfg.steps) {
            for k in 0..shards {
                self.span(t, Stage::Apply, s, k, id);
            }
        }
        if reached {
            self.at_head_leaves += 1;
            self.check_converged(t);
        }
    }

    fn leaf_start_nack(&mut self, t: u64, id: u64, step: u64, shard: u32) {
        let idx = id as usize;
        if self.nodes[idx].nacks.contains_key(&(step, shard)) {
            return;
        }
        let Some(parent) = self.nodes[idx].parent else {
            self.enter_slow(t, id);
            return;
        };
        let now = self.clock.now();
        let mut rt = self.cfg.nack_policy.start_at(now);
        self.m.leaf_nacks += 1;
        self.span(t, Stage::NackSent, step, shard, id);
        self.send_ctrl(t, id, parent, kind::NACK, step, shard);
        match rt.next_delay_at(now) {
            Some(d) => {
                self.nodes[idx].nacks.insert((step, shard), rt);
                self.schedule(
                    t + d.as_nanos() as u64,
                    Ev::LeafRetry { leaf: id, step, shard },
                );
            }
            None => {
                self.m.nack_budget_exhausted += 1;
                self.span(t, Stage::GaveUp, step, shard, id);
                self.enter_slow(t, id);
            }
        }
    }

    fn leaf_retry(&mut self, t: u64, leaf: u64, step: u64, shard: u32) {
        let idx = leaf as usize;
        if !self.nodes[idx].up || self.nodes[idx].in_slow {
            return;
        }
        if self.nodes[idx].applied >= step
            || !self.nodes[idx].nacks.contains_key(&(step, shard))
        {
            return;
        }
        let now = self.clock.now();
        let next = self.nodes[idx]
            .nacks
            .get_mut(&(step, shard))
            .unwrap()
            .next_delay_at(now);
        match next {
            Some(d) => {
                self.m.leaf_nack_retries += 1;
                if let Some(p) = self.nodes[idx].parent {
                    self.send_ctrl(t, leaf, p, kind::NACK, step, shard);
                }
                self.schedule(
                    t + d.as_nanos() as u64,
                    Ev::LeafRetry { leaf, step, shard },
                );
            }
            None => {
                self.nodes[idx].nacks.remove(&(step, shard));
                self.m.nack_budget_exhausted += 1;
                self.span(t, Stage::GaveUp, step, shard, leaf);
                self.enter_slow(t, leaf);
            }
        }
    }

    /// Store fallback: the real consumer slow-path arithmetic
    /// ([`latest_of`] + [`slow_path_anchor`]) against the real
    /// transport, with the fetch modeled as one bulk transfer over the
    /// store link.
    fn enter_slow(&mut self, t: u64, id: u64) {
        let idx = id as usize;
        if self.nodes[idx].in_slow || !self.nodes[idx].up {
            return;
        }
        let inv = match self.store.latest_ready() {
            Ok(i) => i,
            Err(_) => return,
        };
        let Some(target) = latest_of(&inv) else { return };
        let Some(anchor) = slow_path_anchor(&inv, target) else { return };
        self.nodes[idx].in_slow = true;
        self.nodes[idx].nacks.clear();
        self.m.slow_paths += 1;
        self.span(t, Stage::CatchUp, target, 0, id);
        // collect the fetched objects so each can be priced through
        // the caching-hop model individually (object tags: 0 = anchor,
        // 1 = whole delta, 2 = shard)
        let mut objects: Vec<((u8, u64, u32), u64)> = Vec::new();
        if let Ok((b, _)) = self.store.fetch_anchor(anchor) {
            objects.push(((0, anchor, 0), b.len() as u64));
        }
        for s in anchor + 1..=target {
            match self.store.fetch_step(s) {
                Ok(Some(StepData::Sharded { shard_count, .. })) => {
                    for k in 0..shard_count {
                        if let Ok(b) = self.store.fetch_shard(s, k) {
                            objects.push(((2, s, k), b.len() as u64));
                        }
                    }
                }
                Ok(Some(StepData::Whole(b))) => objects.push(((1, s, 0), b.len() as u64)),
                _ => {}
            }
        }
        let mut bytes = 0u64;
        for (obj, len) in objects {
            bytes += len;
            self.store_cache_account(id, obj, len);
        }
        let link = self.cfg.store_link.slowed(self.nodes[idx].slow_factor);
        let delay = link.tx_ns(bytes.max(1)).max(1);
        self.schedule(t + delay, Ev::SlowDone { leaf: id, target, bytes });
    }

    /// Caching-hop model for slow-path store reads (the sim face of
    /// `net::store::CachingStore`): a fetched object warms every live
    /// relay on the leaf's ancestor path; a later fetch of the same
    /// object from under a warm relay is served there. Origin egress
    /// (`origin_bytes`) is charged only on the cold misses, so a tree
    /// of cold consumers costs the origin O(subtrees) reads per
    /// object instead of O(leaves) — the bound the CI scale gate
    /// prices at 100k leaves. Pure accounting: delivery timing is
    /// unchanged, so the determinism contract is untouched.
    fn store_cache_account(&mut self, leaf: u64, obj: (u8, u64, u32), len: u64) {
        let mut path: Vec<u64> = Vec::new();
        let mut warm_hit = false;
        let mut cur = self.nodes[leaf as usize].parent;
        while let Some(p) = cur {
            if p == 0 {
                break; // the root is the origin itself
            }
            let n = &self.nodes[p as usize];
            if n.up && n.role == role::RELAY {
                if self.store_warm.get(&p).is_some_and(|s| s.contains(&obj)) {
                    warm_hit = true;
                    break;
                }
                path.push(p);
            }
            cur = n.parent;
        }
        if warm_hit {
            self.m.store_hits += 1;
        } else {
            self.m.store_misses += 1;
            self.m.origin_bytes += len;
        }
        for p in path {
            self.store_warm.entry(p).or_default().insert(obj);
        }
    }

    // ----------------------------------------------------- control plane

    fn replan_apply(&mut self, t: u64) {
        let plan = self
            .members
            .plan_next(self.cfg.topo.fanout_cap, self.cfg.topo.min_relay_levels)
            .clone();
        self.depth = plan.depth();
        let epoch = plan.epoch;
        for a in plan.relays.iter().chain(plan.leaves.iter()) {
            self.apply_assign(t, a, epoch);
        }
        // Anyone the plan no longer names (swept-dead peers) gets
        // detached: the plane stops streaming at a peer the instant it
        // leaves the membership — otherwise a frozen subtree keeps
        // soaking up transmissions until the horizon.
        let planned: std::collections::HashSet<u64> = plan
            .relays
            .iter()
            .chain(plan.leaves.iter())
            .map(|a| a.peer)
            .collect();
        let unplanned: Vec<u64> = self.nodes[1..]
            .iter()
            .filter(|n| n.parent.is_some() && !planned.contains(&n.id))
            .map(|n| n.id)
            .collect();
        for id in unplanned {
            self.detach(id);
        }
    }

    /// Remove `id` from its parent's fan-out and drop the edge (and
    /// whatever was queued on it).
    fn detach(&mut self, id: u64) {
        let idx = id as usize;
        if let Some(op) = self.nodes[idx].parent.take() {
            self.nodes[op as usize].children.retain(|&c| c != id);
            if let Some(e) = self.edges.remove(&(op, id)) {
                self.m.frames_superseded += e.q.len() as u64;
            }
        }
    }

    fn apply_assign(&mut self, t: u64, a: &Assignment, epoch: u64) {
        let idx = a.peer as usize;
        // A frozen peer's directive lands nowhere (silent crash: the
        // plane doesn't know yet).
        if idx >= self.nodes.len() || !self.nodes[idx].up {
            return;
        }
        self.nodes[idx].fence.observe(epoch);
        if !self.nodes[idx].fence.admit(epoch) {
            self.m.fenced += 1;
            return;
        }
        self.nodes[idx].hop = a.hop;
        let new_parent = match a.upstream {
            Upstream::Root => Some(0),
            Upstream::Peer(p) => Some(p),
            Upstream::Standby => None,
        };
        let old = self.nodes[idx].parent;
        if old == new_parent {
            return;
        }
        if old.is_some() {
            self.detach(a.peer);
            self.m.reparents += 1;
            // Escalations pending against the torn-down upstream fail
            // over to the store (sorted drain keeps the trace
            // deterministic) — the fail_all_escalated contract.
            let mut failed = self.nodes[idx]
                .ledger
                .as_mut()
                .map(|l| l.resolve_all())
                .unwrap_or_default();
            failed.sort_by_key(|(slot, _)| *slot);
            for ((s, k), riders) in failed {
                self.m.nacks_unserviceable += 1;
                for r in riders {
                    self.push_direct(t, a.peer, r, Arc::new(ctrl_frame(kind::NACK_MISS, s, k)));
                }
            }
        }
        self.nodes[idx].parent = new_parent;
        if let Some(np) = new_parent {
            let npi = np as usize;
            self.nodes[npi].children.push(a.peer);
            let link = if self.nodes[idx].role == role::LEAF {
                self.cfg.link.slowed(self.nodes[idx].slow_factor)
            } else {
                self.cfg.link
            };
            self.edges
                .insert((np, a.peer), Edge { q: VecDeque::new(), busy: false, link });
            // Catch-up preload: the accept-path bundle (anchor +
            // tail), pushed directly like spawn_accept does.
            if self.nodes[npi].up {
                let bundle: Vec<Arc<Frame>> = self.nodes[npi]
                    .stage
                    .as_ref()
                    .map(|s| s.catchup().collect())
                    .unwrap_or_default();
                for f in bundle {
                    self.push_direct(t, np, a.peer, f);
                }
            }
        }
    }

    fn churn_apply(&mut self, t: u64, idx: usize) {
        let action = self.cfg.churn.events[idx].action;
        let now = self.clock.now();
        match action {
            ChurnAction::JoinLeaf => {
                let id = self.members.join(role::LEAF, 0, now);
                debug_assert_eq!(id as usize, self.nodes.len());
                self.nodes.push(SimNode::leaf(id));
                self.live_leaves += 1;
                self.m.joins += 1;
                // the plane replans per JOIN
                self.replan_apply(t);
                if self.publish_done {
                    let probe = t + self.cfg.stall_grace.as_nanos() as u64;
                    self.schedule(probe, Ev::StallCheck { leaf: id });
                }
            }
            ChurnAction::JoinRelay => {
                let id = self.members.join(role::RELAY, 0, now);
                debug_assert_eq!(id as usize, self.nodes.len());
                self.nodes.push(SimNode::relay(
                    id,
                    self.cfg.index_steps,
                    self.cfg.escalate_policy.clone(),
                ));
                self.m.joins += 1;
                self.replan_apply(t);
            }
            ChurnAction::CrashRelay { nth } => {
                if let Some(v) = self.pick_nth_live(role::RELAY, nth) {
                    self.crash(t, v);
                }
            }
            ChurnAction::CrashLeaf { nth } => {
                if let Some(v) = self.pick_nth_live(role::LEAF, nth) {
                    self.crash(t, v);
                }
            }
            ChurnAction::SlowLeaf { nth, factor } => {
                if let Some(v) = self.pick_nth_live(role::LEAF, nth) {
                    let vi = v as usize;
                    self.nodes[vi].slow_factor = factor.max(1);
                    if let Some(p) = self.nodes[vi].parent {
                        if let Some(e) = self.edges.get_mut(&(p, v)) {
                            e.link = self.cfg.link.slowed(factor);
                        }
                    }
                    self.m.slowdowns += 1;
                }
            }
        }
    }

    fn pick_nth_live(&self, want: u8, nth: usize) -> Option<u64> {
        let live: Vec<u64> = self
            .nodes
            .iter()
            .filter(|n| n.up && n.role == want)
            .map(|n| n.id)
            .collect();
        if live.is_empty() {
            None
        } else {
            Some(live[nth % live.len()])
        }
    }

    /// Silent freeze: the node stops processing and heartbeating;
    /// discovery is the sweep's job (no mark_dead here — exactly the
    /// fail_silently fault on the TCP plane).
    fn crash(&mut self, t: u64, id: u64) {
        let idx = id as usize;
        self.nodes[idx].up = false;
        self.m.crashes += 1;
        // a crashed relay's store cache dies with it
        self.store_warm.remove(&id);
        if self.nodes[idx].role == role::LEAF {
            self.live_leaves -= 1;
            if self.nodes[idx].at_head {
                self.at_head_leaves -= 1;
            }
            // the straggler holding up convergence may just have died
            self.check_converged(t);
        }
    }

    // ------------------------------------------------------ convergence

    fn note_publish_done(&mut self, t: u64) {
        self.publish_done = true;
        self.final_head = self.cfg.steps;
        let mut at_head = 0usize;
        let mut stragglers: Vec<u64> = Vec::new();
        for n in self.nodes.iter_mut() {
            if n.up && n.role == role::LEAF {
                if n.applied >= self.final_head {
                    n.at_head = true;
                    at_head += 1;
                } else {
                    stragglers.push(n.id);
                }
            }
        }
        self.at_head_leaves = at_head;
        let probe = t + self.cfg.stall_grace.as_nanos() as u64;
        for id in stragglers {
            self.schedule(probe, Ev::StallCheck { leaf: id });
        }
        self.check_converged(t);
    }

    fn check_converged(&mut self, t: u64) {
        if self.publish_done
            && self.converged_at.is_none()
            && self.live_leaves > 0
            && self.at_head_leaves >= self.live_leaves
        {
            self.converged_at = Some(t);
            self.done = true;
        }
    }

    fn report(self) -> SimReport {
        let relays_live = self
            .nodes
            .iter()
            .filter(|n| n.up && n.role == role::RELAY)
            .count();
        let shards = self.cfg.shards_per_step.max(2) as u64;
        let per_step = shards * (self.cfg.bytes_per_shard.max(CTRL_BYTES) as u64 + FRAME_WIRE_OVERHEAD)
            + (MARKER_BYTES as u64 + FRAME_WIRE_OVERHEAD);
        let anchors = 1 + if self.cfg.anchor_every > 0 {
            self.cfg.steps / self.cfg.anchor_every
        } else {
            0
        };
        let ideal = anchors * (self.cfg.anchor_bytes.max(8) as u64 + FRAME_WIRE_OVERHEAD)
            + self.cfg.steps * per_step;
        let bytes_per_leaf = self.m.leaf_bytes / self.live_leaves.max(1) as u64;
        let converged_at = Duration::from_nanos(self.converged_at.unwrap_or(0));
        let publish_done_at = Duration::from_nanos(self.publish_done_at);
        SimReport {
            seed: self.cfg.seed,
            leaves_live: self.live_leaves,
            relays_live,
            depth: self.depth,
            converged: self.converged_at.is_some(),
            converged_at,
            publish_done_at,
            settle: converged_at.saturating_sub(publish_done_at),
            head_step: self.final_head,
            leaf_bytes: self.m.leaf_bytes,
            bytes_per_leaf,
            ideal_bytes_per_leaf: ideal,
            overhead_pct: (bytes_per_leaf as f64 / ideal.max(1) as f64 - 1.0) * 100.0,
            link_bytes: self.m.link_bytes,
            frames_lost: self.m.frames_lost,
            leaf_nacks: self.m.leaf_nacks,
            leaf_nack_retries: self.m.leaf_nack_retries,
            nacks_serviced: self.m.nacks_serviced,
            nacks_escalated: self.m.nacks_escalated,
            nacks_suppressed: self.m.nacks_suppressed,
            nacks_unserviceable: self.m.nacks_unserviceable,
            nack_misses: self.m.nack_misses,
            retransmits: self.m.retransmits,
            store_repairs: self.m.store_repairs,
            leaf_repairs: self.m.leaf_repairs,
            dup_frames: self.m.dup_frames,
            delivered_to_dead: self.m.to_dead,
            slow_paths: self.m.slow_paths,
            origin_bytes: self.m.origin_bytes,
            store_hits: self.m.store_hits,
            store_misses: self.m.store_misses,
            nack_budget_exhausted: self.m.nack_budget_exhausted,
            coalesced: self.m.coalesced,
            frames_superseded: self.m.frames_superseded,
            epochs: self.members.epoch(),
            replans: self.members.replans(),
            deaths: self.members.deaths(),
            reparents: self.m.reparents,
            fenced: self.m.fenced,
            joins: self.m.joins,
            crashes: self.m.crashes,
            slowdowns: self.m.slowdowns,
            max_queue_depth: self.m.max_queue,
            events: self.events,
            trace_hash: self.hash,
            spans: self.spans,
            span_hash: self.span_hash,
            span_events: self.recorder.snapshot(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny(seed: u64) -> SimConfig {
        let mut cfg = SimConfig::new(TopoSpec::kary(24, 4).with_spares(1), seed);
        cfg.steps = 3;
        cfg.shards_per_step = 2;
        cfg.bytes_per_shard = 256;
        cfg.anchor_bytes = 1024;
        cfg.step_interval = Duration::from_millis(10);
        cfg.horizon = Duration::from_secs(30);
        cfg
    }

    #[test]
    fn clean_run_converges_with_no_repair_traffic() {
        let r = run(tiny(1));
        assert!(r.converged, "clean 24-leaf run must converge: {:?}", r);
        assert_eq!(r.head_step, 3);
        assert_eq!(r.leaves_live, 24);
        assert_eq!(r.frames_lost, 0);
        assert_eq!(r.leaf_nacks, 0);
        assert_eq!(r.slow_paths, 0);
        assert!(r.depth >= 2, "cap 4 over 24 leaves needs a relay tier");
        // Every leaf got exactly one clean copy of the stream.
        assert_eq!(r.bytes_per_leaf, r.ideal_bytes_per_leaf);
        assert!(r.overhead_pct.abs() < 1e-9);
    }

    #[test]
    fn runs_are_bit_identical_per_seed_and_diverge_across_seeds() {
        let mut cfg = tiny(7);
        cfg.link = cfg.link.with_loss(20_000);
        let a = run(cfg.clone());
        let b = run(cfg.clone());
        assert_eq!(a, b, "same config+seed must be bit-identical");
        assert_eq!(a.trace_hash, b.trace_hash);
        assert_eq!(a.span_hash, b.span_hash, "span stream must replay identically");
        let mut other = cfg.clone();
        other.seed = 8;
        let c = run(other);
        assert_ne!(a.trace_hash, c.trace_hash, "different seed, different trace");
    }

    #[test]
    fn spans_cover_the_run_and_reconstruct_timelines() {
        let r = run(tiny(3));
        assert!(r.spans > 0, "a converging run must emit spans");
        assert_ne!(r.span_hash, 0xcbf2_9ce4_8422_2325, "hash must fold spans");
        assert_eq!(
            r.spans as usize,
            r.span_events.len(),
            "tiny run fits entirely in the default recorder ring"
        );
        let report = crate::obs::reconstruct(&r.span_events);
        assert!(!report.rows.is_empty());
        assert!(
            report.complete > 0,
            "clean run must close publish→apply timelines: {} rows",
            report.rows.len()
        );
        assert!(
            report.incomplete.is_empty(),
            "every published (step, shard) must reach every leaf: {:?}",
            report.incomplete
        );
    }

    #[test]
    fn slow_path_caching_bounds_origin_egress() {
        // Total tree-edge loss: every leaf converges through the store
        // slow path, so the caching-hop model gets the full cold-tree
        // workload. Leaves sharing a relay must warm it: the origin is
        // charged once per cold subtree, not once per leaf.
        let mut cfg = tiny(9);
        cfg.link = cfg.link.with_loss(1_000_000);
        cfg.horizon = Duration::from_secs(60);
        let r = run(cfg);
        assert!(r.converged, "all-loss run must converge via the store: {:?}", r);
        assert!(r.slow_paths > 0);
        assert!(r.store_misses > 0, "the first fetch per subtree is cold");
        assert!(r.store_hits > 0, "leaves sharing a relay must hit its warm cache");
        assert!(
            r.origin_bytes < r.leaf_bytes,
            "origin egress {} must be a fraction of delivered bytes {}",
            r.origin_bytes,
            r.leaf_bytes
        );
        // clean runs never touch the origin
        let clean = run(tiny(9));
        assert_eq!(clean.origin_bytes, 0);
        assert_eq!(clean.store_hits + clean.store_misses, 0);
    }

    #[test]
    fn lossy_run_repairs_through_nacks_and_converges() {
        let mut cfg = tiny(5);
        cfg.link = cfg.link.with_loss(30_000); // 3% frame loss
        let r = run(cfg);
        assert!(r.converged, "lossy run must still converge: {:?}", r);
        assert!(r.frames_lost > 0, "3% loss over ~hundreds of frames must drop some");
        // Repair traffic exists and costs overhead.
        assert!(r.leaf_nacks + r.slow_paths > 0);
        assert!(r.bytes_per_leaf >= r.ideal_bytes_per_leaf);
    }
}
