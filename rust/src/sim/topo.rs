//! Topology specification and per-node state for the scale simulator.
//!
//! A [`TopoSpec`] describes the modeled cluster (relay and leaf
//! counts, fan-out cap, forced relay depth); the actual tree comes
//! from the real planner via [`crate::net::control::Membership`] — the
//! spec only decides how many peers register. [`SimNode`] is one
//! modeled peer: relays carry the *real*
//! [`crate::net::relay::RelayStage`] and
//! [`crate::net::relay::EscalationLedger`] (rider = downstream peer
//! id); leaves carry the consumer-side assembly state (applied step,
//! pending shards, NACK retry schedules off the real
//! [`crate::util::retry::RetryAt`]) and the real
//! [`crate::net::control::EpochFence`].

use std::collections::{BTreeMap, HashMap, HashSet};

use crate::net::control::{role, EpochFence};
use crate::net::relay::{EscalationLedger, RelayStage};
use crate::util::retry::{RetryAt, RetryPolicy};

/// Cluster shape: how many peers of each role register at bootstrap.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TopoSpec {
    /// Interior relay peers registered at t=0 (the root relay is the
    /// publisher's own and is not a member).
    pub relays: usize,
    /// Leaf subscribers registered at t=0.
    pub leaves: usize,
    /// Planner fan-out cap per hop.
    pub fanout_cap: usize,
    /// Forced minimum relay depth (0 = whatever the planner needs).
    pub min_relay_levels: usize,
}

impl TopoSpec {
    /// A balanced k-ary spec: exactly enough relays for `leaves` under
    /// `fanout_cap`, computed with the same recurrence the planner's
    /// shape uses (each level parents up to `cap` children of the
    /// level below).
    pub fn kary(leaves: usize, fanout_cap: usize) -> TopoSpec {
        TopoSpec {
            relays: relays_for(leaves, fanout_cap),
            leaves,
            fanout_cap,
            min_relay_levels: 0,
        }
    }

    /// Same spec with `extra` spare relays (standby pool the planner
    /// promotes when a relay dies).
    pub fn with_spares(mut self, extra: usize) -> TopoSpec {
        self.relays += extra;
        self
    }

    /// Same spec with a forced relay depth.
    pub fn with_min_levels(mut self, levels: usize) -> TopoSpec {
        self.min_relay_levels = levels;
        self
    }

    /// Total peers registered at bootstrap.
    pub fn peers(&self) -> usize {
        self.relays + self.leaves
    }
}

/// Relays needed to parent `leaves` under `cap`: the bottom relay tier
/// needs `ceil(leaves / cap)` nodes, each tier above parents the one
/// below, until a tier fits under the root relay's own cap.
pub fn relays_for(leaves: usize, cap: usize) -> usize {
    let cap = cap.max(2);
    if leaves <= cap {
        return 0;
    }
    let mut tier = leaves.div_ceil(cap);
    let mut total = 0;
    loop {
        total += tier;
        if tier <= cap {
            return total;
        }
        tier = tier.div_ceil(cap);
    }
}

/// Leaf-side assembly of one uncommitted step: which shards arrived,
/// and the shard count once the step's marker landed.
#[derive(Debug, Default)]
pub struct StepAsm {
    pub total: Option<u32>,
    pub seen: HashSet<u32>,
}

/// One modeled peer. Index in the simulator's node table == its
/// control-plane peer id (id 0 is the root relay / publisher).
pub struct SimNode {
    pub id: u64,
    /// `role::RELAY`, `role::LEAF`, or 0 for the root.
    pub role: u8,
    /// False once crashed (frozen: delivered frames are ignored, no
    /// heartbeats refresh it).
    pub up: bool,
    pub parent: Option<u64>,
    /// Downstream peers, attach order (fan-out order is deterministic).
    pub children: Vec<u64>,
    pub hop: u32,
    /// Real directive fence — stale ASSIGNs bounce here, same as on
    /// the TCP plane.
    pub fence: EpochFence,
    /// Hop staging (root + relays): the real anchor/tail/index machine.
    pub stage: Option<RelayStage>,
    /// NACK-storm suppression (relays): riders are downstream peer ids.
    pub ledger: Option<EscalationLedger<u64>>,
    // ---- leaf assembly state ----
    /// Last committed step (0 = baseline).
    pub applied: u64,
    /// Whether this live leaf has reached the final published head.
    pub at_head: bool,
    /// A slow-path (store fallback) fetch is in flight.
    pub in_slow: bool,
    /// Ingress bandwidth divisor (1 = healthy; set by churn).
    pub slow_factor: u32,
    /// Uncommitted steps by number (ordered — pruning is a range op).
    pub pending: BTreeMap<u64, StepAsm>,
    /// Outstanding per-shard NACK retry schedules.
    pub nacks: HashMap<(u64, u32), RetryAt>,
}

impl SimNode {
    fn base(id: u64, role: u8) -> SimNode {
        SimNode {
            id,
            role,
            up: true,
            parent: None,
            children: Vec::new(),
            hop: 0,
            fence: EpochFence::default(),
            stage: None,
            ledger: None,
            applied: 0,
            at_head: false,
            in_slow: false,
            slow_factor: 1,
            pending: BTreeMap::new(),
            nacks: HashMap::new(),
        }
    }

    /// The root relay (peer id 0, hop 0, never a member).
    pub fn root(index_steps: usize) -> SimNode {
        let mut n = SimNode::base(0, 0);
        n.stage = Some(RelayStage::new(index_steps));
        n
    }

    /// An interior relay peer.
    pub fn relay(id: u64, index_steps: usize, escalate: RetryPolicy) -> SimNode {
        let mut n = SimNode::base(id, role::RELAY);
        n.stage = Some(RelayStage::new(index_steps));
        n.ledger = Some(EscalationLedger::new(escalate));
        n
    }

    /// A leaf subscriber peer.
    pub fn leaf(id: u64) -> SimNode {
        SimNode::base(id, role::LEAF)
    }

    /// Root or relay — anything that stages and fans out.
    pub fn is_hop(&self) -> bool {
        self.stage.is_some()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn relay_provisioning_matches_the_kary_recurrence() {
        // ≤ cap leaves sit directly under the root: no relays.
        assert_eq!(relays_for(8, 8), 0);
        // 64 leaves / cap 8 → one tier of 8.
        assert_eq!(relays_for(64, 8), 8);
        // 100k leaves / cap 8 → 12500 + 1563 + 196 + 25 + 4.
        assert_eq!(relays_for(100_000, 8), 14288);
        let spec = TopoSpec::kary(100_000, 8).with_spares(2);
        assert_eq!(spec.relays, 14290);
        assert_eq!(spec.peers(), 114_290);
    }

    #[test]
    fn node_constructors_set_roles_and_machines() {
        let r = SimNode::root(4);
        assert!(r.is_hop() && r.ledger.is_none() && r.id == 0);
        let relay = SimNode::relay(3, 4, RetryPolicy::escalate_default());
        assert!(relay.is_hop() && relay.ledger.is_some());
        assert_eq!(relay.role, role::RELAY);
        let leaf = SimNode::leaf(7);
        assert!(!leaf.is_hop());
        assert_eq!(leaf.role, role::LEAF);
        assert_eq!(leaf.applied, 0);
    }
}
