//! Self-describing patch container: the on-wire / on-store object that
//! PULSESync publishes (paper Alg. 3 + §J.4 integrity verification).
//!
//! Layout (v3; v2 omits the shard fields, v1 additionally omits
//! `chunk_elems`):
//! ```text
//!   magic  "PLSP" (4)            version u8 (1, 2 or 3)
//!   kind   u8 (0=bf16 weights, 1=f32 pseudo-gradient)
//!   format u8 (PatchFormat tag)  codec u8 (Codec tag)
//!   flags  u8 (bit0: byte-shuffled values)
//!   step u64 LE     base_step u64 LE
//!   total_params u64 LE   nnz u64 LE
//!   raw_len u64 LE (pre-codec payload length)
//!   chunk_elems u64 LE (v2+: hash-tree chunk size in elements)
//!   -- v3 only (sharded fan-out; see `pulse::sync`) --
//!   shard_index u32 LE    shard_count u32 LE
//!   elem_offset u64 LE (first flat element this shard covers)
//!   elem_len u64 LE (elements this shard covers)
//!   32-byte shard subtree root at chunk_elems over
//!       elem_offset..elem_offset+elem_len
//!       (`hashtree::HashTree::subtree_root_hex`)
//!   -- all versions --
//!   32-byte hash of the *resulting full weights* (zero for
//!       pseudo-gradient payloads, which are not checkpoints):
//!       v1 → scalar SHA-256 of the full buffer;
//!       v2/v3 → chunked hash-tree root at chunk_elems
//!            (see `sparse::hashtree`), verifiable in
//!            O(nnz · chunk_elems) instead of O(total)
//!   payload: codec(compress(index stream ++ value stream))
//! ```
//!
//! Index streams always carry **absolute** flat indices, so a v3 shard
//! frame is decodable with the same formats as a whole-step frame.
//! Every shard frame of a step carries the same `result_hash` (the
//! post-step global root) plus its own `shard_root`, so a consumer can
//! verify shards independently — a corrupted shard is re-fetched alone
//! — and still bind the assembled step end-to-end.
//!
//! `encode` writes v1 when `chunk_elems == 0` (scalar hash or no
//! hash), v2 for an unsharded hash-tree patch (`shard_count <= 1`),
//! and v3 when `shard_count > 1`; `decode` accepts all three, so
//! pre-hash-tree and pre-sharding objects in a store remain readable.

use super::{PatchFormat, TensorShape};
use crate::codec::{shuffle, Codec};
use anyhow::{bail, Result};

pub const MAGIC: [u8; 4] = *b"PLSP";
/// Legacy scalar-hash container version.
pub const VERSION_V1: u8 = 1;
/// Unsharded hash-tree version: carries the chunk size + root.
pub const VERSION: u8 = 2;
/// Sharded fan-out version: v2 plus shard header fields.
pub const VERSION_V3: u8 = 3;

/// What the values in the patch are.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PatchKind {
    /// BF16 weight values (PULSESync).
    Bf16Weights,
    /// FP32 pseudo-gradient values (PULSELoCo).
    F32Pseudograd,
}

impl PatchKind {
    fn tag(&self) -> u8 {
        match self {
            PatchKind::Bf16Weights => 0,
            PatchKind::F32Pseudograd => 1,
        }
    }
    fn from_tag(t: u8) -> Result<Self> {
        Ok(match t {
            0 => PatchKind::Bf16Weights,
            1 => PatchKind::F32Pseudograd,
            other => bail!("bad patch kind {}", other),
        })
    }
}

/// Decoded patch values.
#[derive(Debug, Clone, PartialEq)]
pub enum Values {
    Bf16(Vec<u16>),
    F32(Vec<f32>),
}

impl Values {
    pub fn len(&self) -> usize {
        match self {
            Values::Bf16(v) => v.len(),
            Values::F32(v) => v.len(),
        }
    }
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
    fn kind(&self) -> PatchKind {
        match self {
            Values::Bf16(_) => PatchKind::Bf16Weights,
            Values::F32(_) => PatchKind::F32Pseudograd,
        }
    }
    fn width(&self) -> usize {
        match self {
            Values::Bf16(_) => 2,
            Values::F32(_) => 4,
        }
    }
    fn to_bytes(&self) -> Vec<u8> {
        match self {
            Values::Bf16(v) => crate::util::u16_as_bytes(v).to_vec(),
            Values::F32(v) => crate::util::f32_as_bytes(v).to_vec(),
        }
    }
    fn from_bytes(kind: PatchKind, bytes: &[u8]) -> Result<Values> {
        Ok(match kind {
            PatchKind::Bf16Weights => Values::Bf16(crate::util::bytes_to_u16(bytes)),
            PatchKind::F32Pseudograd => Values::F32(crate::util::bytes_to_f32(bytes)),
        })
    }
}

/// A fully decoded patch.
#[derive(Debug, Clone)]
pub struct Patch {
    pub step: u64,
    pub base_step: u64,
    pub total_params: u64,
    pub indices: Vec<u64>,
    pub values: Values,
    /// Hex commitment to the full resulting weights, for §J.4
    /// end-to-end verification. Empty for pseudo-gradient payloads.
    /// When `chunk_elems == 0` this is the scalar SHA-256 of the whole
    /// buffer (v1); otherwise it is the `sparse::hashtree` root at that
    /// chunk size (v2).
    pub result_hash: String,
    /// Hash-tree chunk size in elements; 0 means `result_hash` is a
    /// scalar full-buffer hash (v1 container).
    pub chunk_elems: u64,
    /// This frame's shard index within the step (0 for unsharded).
    pub shard_index: u32,
    /// Shards the step was split into (1 for unsharded).
    pub shard_count: u32,
    /// First flat element this shard covers (0 for unsharded).
    pub elem_offset: u64,
    /// Elements this shard covers (== `total_params` for unsharded).
    pub elem_len: u64,
    /// Hex subtree root over this shard's element range after the step
    /// applies (empty for unsharded frames).
    pub shard_root: String,
}

impl Default for Patch {
    fn default() -> Patch {
        Patch {
            step: 0,
            base_step: 0,
            total_params: 0,
            indices: Vec::new(),
            values: Values::Bf16(Vec::new()),
            result_hash: String::new(),
            chunk_elems: 0,
            shard_index: 0,
            shard_count: 1,
            elem_offset: 0,
            elem_len: 0,
            shard_root: String::new(),
        }
    }
}

/// Cheap header peek: enough to route a frame (e.g. NACK/resend a
/// specific shard) without decompressing the payload.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardMeta {
    pub step: u64,
    pub shard_index: u32,
    pub shard_count: u32,
}

/// Read `(step, shard_index, shard_count)` from a container header.
pub fn peek_meta(buf: &[u8]) -> Result<ShardMeta> {
    if buf.len() < 9 + 5 * 8 + 32 {
        bail!("patch container too short ({} bytes)", buf.len());
    }
    if buf[0..4] != MAGIC {
        bail!("bad patch magic");
    }
    let version = buf[4];
    if version != VERSION_V1 && version != VERSION && version != VERSION_V3 {
        bail!("unsupported patch version {}", version);
    }
    let step = u64::from_le_bytes(buf[9..17].try_into().unwrap());
    if version == VERSION_V3 {
        if buf.len() < 65 {
            bail!("v3 patch container too short ({} bytes)", buf.len());
        }
        let shard_index = u32::from_le_bytes(buf[57..61].try_into().unwrap());
        let shard_count = u32::from_le_bytes(buf[61..65].try_into().unwrap());
        if shard_count < 2 || shard_index >= shard_count {
            bail!("bad shard header: index {} of {}", shard_index, shard_count);
        }
        Ok(ShardMeta { step, shard_index, shard_count })
    } else {
        Ok(ShardMeta { step, shard_index: 0, shard_count: 1 })
    }
}

/// Encoding options.
#[derive(Debug, Clone, Copy)]
pub struct EncodeOpts {
    pub format: PatchFormat,
    pub codec: Codec,
    /// Byte-shuffle the value stream before the codec (§F.3 variant).
    pub shuffle_values: bool,
}

impl Default for EncodeOpts {
    fn default() -> Self {
        EncodeOpts {
            format: PatchFormat::CooDownscaled,
            codec: Codec::Zstd1,
            shuffle_values: false,
        }
    }
}

/// Encode a patch into the container byte format.
pub fn encode(patch: &Patch, layout: &[TensorShape], opts: EncodeOpts) -> Result<Vec<u8>> {
    assert_eq!(patch.indices.len(), patch.values.len());
    // pre-codec payload: index stream ++ value stream
    let mut raw = opts.format.encode_indices(&patch.indices, layout);
    let vbytes = patch.values.to_bytes();
    if opts.shuffle_values && !vbytes.is_empty() {
        raw.extend(shuffle::shuffle(&vbytes, patch.values.width()));
    } else {
        raw.extend_from_slice(&vbytes);
    }
    let compressed = opts.codec.compress(&raw)?;

    if patch.chunk_elems > 0 && patch.chunk_elems < super::hashtree::MIN_WIRE_CHUNK_ELEMS as u64 {
        bail!("chunk_elems {} below wire minimum", patch.chunk_elems);
    }
    let sharded = patch.shard_count > 1;
    if sharded {
        if patch.chunk_elems == 0 {
            bail!("sharded patches require hash-tree geometry (chunk_elems > 0)");
        }
        if patch.shard_index >= patch.shard_count {
            bail!("shard index {} out of range {}", patch.shard_index, patch.shard_count);
        }
        if patch.shard_root.is_empty() {
            bail!("sharded patches require a shard subtree root");
        }
        if patch.elem_offset + patch.elem_len > patch.total_params {
            bail!("shard range exceeds total_params");
        }
    }
    let version = if sharded {
        VERSION_V3
    } else if patch.chunk_elems > 0 {
        VERSION
    } else {
        VERSION_V1
    };
    let mut out = Vec::with_capacity(compressed.len() + 160);
    out.extend_from_slice(&MAGIC);
    out.push(version);
    out.push(patch.values.kind().tag());
    out.push(opts.format.tag());
    out.push(opts.codec.tag());
    out.push(if opts.shuffle_values { 1 } else { 0 });
    out.extend_from_slice(&patch.step.to_le_bytes());
    out.extend_from_slice(&patch.base_step.to_le_bytes());
    out.extend_from_slice(&patch.total_params.to_le_bytes());
    out.extend_from_slice(&(patch.indices.len() as u64).to_le_bytes());
    out.extend_from_slice(&(raw.len() as u64).to_le_bytes());
    if version >= VERSION {
        out.extend_from_slice(&patch.chunk_elems.to_le_bytes());
    }
    if version == VERSION_V3 {
        out.extend_from_slice(&patch.shard_index.to_le_bytes());
        out.extend_from_slice(&patch.shard_count.to_le_bytes());
        out.extend_from_slice(&patch.elem_offset.to_le_bytes());
        out.extend_from_slice(&patch.elem_len.to_le_bytes());
        let bytes = hex_to_bytes(&patch.shard_root)?;
        out.extend_from_slice(&bytes);
    }
    let mut hash32 = [0u8; 32];
    if !patch.result_hash.is_empty() {
        let bytes = hex_to_bytes(&patch.result_hash)?;
        hash32.copy_from_slice(&bytes);
    }
    out.extend_from_slice(&hash32);
    out.extend_from_slice(&compressed);
    Ok(out)
}

/// Decode a container produced by [`encode`].
pub fn decode(buf: &[u8], layout: &[TensorShape]) -> Result<Patch> {
    if buf.len() < 9 + 5 * 8 + 32 {
        bail!("patch container too short ({} bytes)", buf.len());
    }
    if buf[0..4] != MAGIC {
        bail!("bad patch magic");
    }
    let version = buf[4];
    if version != VERSION_V1 && version != VERSION && version != VERSION_V3 {
        bail!("unsupported patch version {}", version);
    }
    let kind = PatchKind::from_tag(buf[5])?;
    let format = PatchFormat::from_tag(buf[6])?;
    let codec = Codec::from_tag(buf[7])?;
    let shuffled = buf[8] & 1 != 0;
    let mut o = 9usize;
    let read_u64 = |o: &mut usize| {
        let v = u64::from_le_bytes(buf[*o..*o + 8].try_into().unwrap());
        *o += 8;
        v
    };
    let step = read_u64(&mut o);
    let base_step = read_u64(&mut o);
    let total_params = read_u64(&mut o);
    let nnz = read_u64(&mut o) as usize;
    let raw_len = read_u64(&mut o) as usize;
    let chunk_elems = if version >= VERSION {
        if buf.len() < o + 8 + 32 {
            bail!("v2 patch container too short ({} bytes)", buf.len());
        }
        let ce = read_u64(&mut o);
        // untrusted geometry: a corrupted tiny value would make the
        // verifier allocate huge digest arrays (see hashtree docs)
        if ce < super::hashtree::MIN_WIRE_CHUNK_ELEMS as u64 {
            bail!("v2 chunk_elems {} below wire minimum", ce);
        }
        ce
    } else {
        0
    };
    let (shard_index, shard_count, elem_offset, elem_len, shard_root) = if version == VERSION_V3
    {
        // shard fields: u32 + u32 + u64 + u64 + 32-byte shard root =
        // 56 bytes, followed by the 32-byte result hash
        if buf.len() < o + 56 + 32 {
            bail!("v3 patch container too short ({} bytes)", buf.len());
        }
        let si = u32::from_le_bytes(buf[o..o + 4].try_into().unwrap());
        let sc = u32::from_le_bytes(buf[o + 4..o + 8].try_into().unwrap());
        o += 8;
        let eo = read_u64(&mut o);
        let el = read_u64(&mut o);
        let sr = &buf[o..o + 32];
        o += 32;
        if sc < 2 || si >= sc {
            bail!("bad shard header: index {} of {}", si, sc);
        }
        (si, sc, eo, el, crate::util::hex(sr))
    } else {
        (0u32, 1u32, 0u64, total_params, String::new())
    };
    let hash32 = &buf[o..o + 32];
    o += 32;
    let result_hash = if hash32.iter().all(|&b| b == 0) {
        String::new()
    } else {
        crate::util::hex(hash32)
    };

    let raw = codec.decompress(&buf[o..], raw_len)?;
    if raw.len() != raw_len {
        bail!("payload length {} != declared {}", raw.len(), raw_len);
    }
    let mut pos = 0usize;
    let indices = format.decode_indices(&raw, &mut pos, layout)?;
    if indices.len() != nnz {
        bail!("index count {} != declared nnz {}", indices.len(), nnz);
    }
    let width = match kind {
        PatchKind::Bf16Weights => 2,
        PatchKind::F32Pseudograd => 4,
    };
    let vlen = nnz * width;
    if raw.len() - pos != vlen {
        bail!("value stream length {} != expected {}", raw.len() - pos, vlen);
    }
    let vbytes = if shuffled && vlen > 0 {
        shuffle::unshuffle(&raw[pos..], width)
    } else {
        raw[pos..].to_vec()
    };
    let values = Values::from_bytes(kind, &vbytes)?;
    Ok(Patch {
        step,
        base_step,
        total_params,
        indices,
        values,
        result_hash,
        chunk_elems,
        shard_index,
        shard_count,
        elem_offset,
        elem_len,
        shard_root,
    })
}

fn hex_to_bytes(s: &str) -> Result<Vec<u8>> {
    if s.len() != 64 {
        bail!("hash must be 64 hex chars, got {}", s.len());
    }
    (0..32)
        .map(|i| {
            u8::from_str_radix(&s[2 * i..2 * i + 2], 16)
                .map_err(|e| anyhow::anyhow!("bad hex: {}", e))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::synthetic_layout;

    fn mk_patch(n: usize, nnz: usize, seed: u64) -> (Patch, Vec<TensorShape>) {
        let mut rng = crate::util::rng::Rng::new(seed);
        let layout = synthetic_layout(n, 512);
        let mut idx: Vec<u64> = (0..nnz).map(|_| rng.below(n as u64)).collect();
        idx.sort_unstable();
        idx.dedup();
        let vals: Vec<u16> = idx.iter().map(|_| rng.next_u32() as u16).collect();
        (
            Patch {
                step: 42,
                base_step: 41,
                total_params: n as u64,
                indices: idx,
                values: Values::Bf16(vals),
                result_hash: crate::util::sha256_hex(b"test"),
                chunk_elems: 0,
                ..Default::default()
            },
            layout,
        )
    }

    #[test]
    fn roundtrip_all_codecs_and_formats() {
        let (p, layout) = mk_patch(100_000, 1000, 1);
        for codec in [Codec::None, Codec::Lz4, Codec::Snappy, Codec::Zstd1, Codec::Gzip6] {
            for format in PatchFormat::ALL {
                for shuf in [false, true] {
                    let opts = EncodeOpts { format, codec, shuffle_values: shuf };
                    let buf = encode(&p, &layout, opts).unwrap();
                    let back = decode(&buf, &layout).unwrap();
                    assert_eq!(back.indices, p.indices);
                    assert_eq!(back.values, p.values);
                    assert_eq!(back.step, 42);
                    assert_eq!(back.base_step, 41);
                    assert_eq!(back.result_hash, p.result_hash);
                    assert_eq!(back.chunk_elems, 0);
                }
            }
        }
    }

    #[test]
    fn v2_header_roundtrips_chunk_size() {
        let (mut p, layout) = mk_patch(50_000, 500, 3);
        p.chunk_elems = 1024;
        let buf = encode(&p, &layout, EncodeOpts::default()).unwrap();
        assert_eq!(buf[4], VERSION);
        let back = decode(&buf, &layout).unwrap();
        assert_eq!(back.chunk_elems, 1024);
        assert_eq!(back.indices, p.indices);
        assert_eq!(back.values, p.values);
        assert_eq!(back.result_hash, p.result_hash);
        // v1 objects stay byte-compatible: chunk_elems == 0 → version 1
        p.chunk_elems = 0;
        let buf1 = encode(&p, &layout, EncodeOpts::default()).unwrap();
        assert_eq!(buf1[4], VERSION_V1);
        assert_eq!(buf1.len() + 8, buf.len());
        assert!(decode(&buf1, &layout).is_ok());
        // wire minimum enforced on both sides: encode refuses tiny
        // geometry, and a corrupted header field fails decode cleanly
        p.chunk_elems = 8;
        assert!(encode(&p, &layout, EncodeOpts::default()).is_err());
        let mut bad = buf.clone();
        bad[49..57].copy_from_slice(&1u64.to_le_bytes()); // chunk_elems field
        assert!(decode(&bad, &layout).is_err());
    }

    #[test]
    fn f32_pseudograd_roundtrip() {
        let mut rng = crate::util::rng::Rng::new(5);
        let layout = synthetic_layout(50_000, 512);
        let mut idx: Vec<u64> = (0..800).map(|_| rng.below(50_000)).collect();
        idx.sort_unstable();
        idx.dedup();
        let vals: Vec<f32> = idx.iter().map(|_| rng.normal() as f32).collect();
        let p = Patch {
            step: 7,
            base_step: 6,
            total_params: 50_000,
            indices: idx,
            values: Values::F32(vals),
            result_hash: String::new(),
            chunk_elems: 0,
            ..Default::default()
        };
        let opts =
            EncodeOpts { format: PatchFormat::FlatVarint, codec: Codec::Zstd1, shuffle_values: true };
        let buf = encode(&p, &layout, opts).unwrap();
        let back = decode(&buf, &layout).unwrap();
        assert_eq!(back.values, p.values);
        assert!(back.result_hash.is_empty());
    }

    #[test]
    fn corruption_detected() {
        let (p, layout) = mk_patch(10_000, 200, 9);
        let buf = encode(&p, &layout, EncodeOpts::default()).unwrap();
        // magic
        let mut b = buf.clone();
        b[0] ^= 0xFF;
        assert!(decode(&b, &layout).is_err());
        // version
        let mut b = buf.clone();
        b[4] = 99;
        assert!(decode(&b, &layout).is_err());
        // truncated payload
        assert!(decode(&buf[..buf.len() - 3], &layout).is_err());
    }

    #[test]
    fn empty_patch_roundtrip() {
        let layout = synthetic_layout(1000, 100);
        let p = Patch {
            step: 1,
            base_step: 0,
            total_params: 1000,
            indices: vec![],
            values: Values::Bf16(vec![]),
            result_hash: String::new(),
            chunk_elems: 0,
            ..Default::default()
        };
        let buf = encode(&p, &layout, EncodeOpts::default()).unwrap();
        let back = decode(&buf, &layout).unwrap();
        assert!(back.indices.is_empty());
    }

    #[test]
    fn v3_shard_header_roundtrips() {
        let (mut p, layout) = mk_patch(60_000, 700, 13);
        p.chunk_elems = 1024;
        p.shard_index = 2;
        p.shard_count = 4;
        p.elem_offset = 30_000;
        p.elem_len = 15_000;
        p.shard_root = crate::util::sha256_hex(b"shard");
        let buf = encode(&p, &layout, EncodeOpts::default()).unwrap();
        assert_eq!(buf[4], VERSION_V3);
        let meta = peek_meta(&buf).unwrap();
        assert_eq!(meta, ShardMeta { step: 42, shard_index: 2, shard_count: 4 });
        let back = decode(&buf, &layout).unwrap();
        assert_eq!(back.shard_index, 2);
        assert_eq!(back.shard_count, 4);
        assert_eq!(back.elem_offset, 30_000);
        assert_eq!(back.elem_len, 15_000);
        assert_eq!(back.shard_root, p.shard_root);
        assert_eq!(back.result_hash, p.result_hash);
        assert_eq!(back.indices, p.indices);
        assert_eq!(back.values, p.values);
        // unsharded defaults survive v1/v2 decode
        let mut un = p.clone();
        un.shard_count = 1;
        un.shard_index = 0;
        let buf2 = encode(&un, &layout, EncodeOpts::default()).unwrap();
        assert_eq!(buf2[4], VERSION);
        let back2 = decode(&buf2, &layout).unwrap();
        assert_eq!(back2.shard_count, 1);
        assert_eq!(back2.elem_len, un.total_params);
        assert!(back2.shard_root.is_empty());
        // sharded frames without hash-tree geometry are rejected
        let mut bad = p.clone();
        bad.chunk_elems = 0;
        assert!(encode(&bad, &layout, EncodeOpts::default()).is_err());
        // corrupted shard header fields fail decode cleanly
        let mut corrupt = buf.clone();
        corrupt[61..65].copy_from_slice(&0u32.to_le_bytes()); // shard_count = 0
        assert!(decode(&corrupt, &layout).is_err());
        assert!(peek_meta(&corrupt).is_err());
    }
}
