//! 1-D flat index encoding (paper §H.4.2, Table 11): global indices over
//! the flattened parameter vector, absolute or delta-coded, packed at a
//! fixed u32 width ("flat_int32" / "delta_flat_int32").

use crate::codec::varint::{get_uvarint, put_uvarint};

pub fn encode(indices: &[u64], delta: bool) -> Vec<u8> {
    let mut out = Vec::with_capacity(indices.len() * 4 + 8);
    put_uvarint(&mut out, indices.len() as u64);
    let mut prev = 0u64;
    for (k, &idx) in indices.iter().enumerate() {
        let v = if delta && k > 0 { idx - prev } else { idx };
        debug_assert!(v <= u32::MAX as u64, "flat_int32 overflow");
        out.extend_from_slice(&(v as u32).to_le_bytes());
        prev = idx;
    }
    out
}

pub fn decode(buf: &[u8], pos: &mut usize, delta: bool) -> anyhow::Result<Vec<u64>> {
    let n = get_uvarint(buf, pos)? as usize;
    if *pos + n * 4 > buf.len() {
        anyhow::bail!("flat: truncated index stream");
    }
    let mut out = Vec::with_capacity(n);
    let mut prev = 0u64;
    for k in 0..n {
        let c = &buf[*pos..*pos + 4];
        *pos += 4;
        let v = u32::from_le_bytes([c[0], c[1], c[2], c[3]]) as u64;
        let idx = if delta && k > 0 { prev + v } else { v };
        out.push(idx);
        prev = idx;
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_both_modes() {
        crate::util::prop::check("flat roundtrip", 40, |g| {
            let count = g.len();
            let idx = g.sorted_indices(1 << 31, count);
            for delta in [false, true] {
                let buf = encode(&idx, delta);
                let mut pos = 0;
                assert_eq!(decode(&buf, &mut pos, delta).unwrap(), idx);
                assert_eq!(pos, buf.len());
            }
        });
    }

    #[test]
    fn truncation_detected() {
        let buf = encode(&[1, 2, 3], true);
        let mut pos = 0;
        assert!(decode(&buf[..buf.len() - 1], &mut pos, true).is_err());
    }
}
