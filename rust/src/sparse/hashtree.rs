//! Chunked SHA-256 hash tree over a BF16 weight buffer (§J.4, made
//! incremental).
//!
//! The flat parameter vector is split into fixed-size chunks of
//! `chunk_elems` BF16 elements; each chunk gets its own SHA-256, and the
//! root commits to `(total_elems, chunk_elems, chunk hashes…)`. Two
//! properties make this the O(nnz) replacement for the full-buffer
//! scalar hash on the PULSESync hot path:
//!
//! * **Build** parallelizes over chunks via [`crate::util::pool`]
//!   (scalar SHA-256 of the whole buffer is inherently serial).
//! * **Update** after a sparse patch rehashes only the chunks that
//!   contain patched indices — O(touched_chunks · chunk_elems), which is
//!   at most O(nnz · chunk_elems) and independent of model size. The
//!   root fold is two-level (chunk digests → group digests → root), so
//!   an update refolds only the touched groups plus an
//!   O(num_chunks / GROUP) top fold — the per-patch fold stays tiny
//!   even at 10B+ parameters instead of scaling with the chunk count.
//!
//! [`HashTree::apply_and_rehash`] fuses the consumer's patch apply with
//! the chunk rehash so both share one pass over the touched chunks.
//!
//! The root is exactly as binding as the scalar hash for patch
//! verification: any corrupted value or misdirected index lands in some
//! chunk, changes that chunk's hash, and therefore changes the root.

use crate::util::{hex, pool, u16_as_bytes};
use sha2::{Digest, Sha256};

/// Default chunk size in BF16 elements (2 KB of data per chunk): small
/// enough that per-patch rehash cost ≈ nnz · chunk stays far below the
/// full buffer at realistic sparsities, large enough that the
/// per-chunk SHA-256 call overhead and the root fold stay negligible
/// (the chunk-hash array is 1/64 of the buffer).
pub const DEFAULT_CHUNK_ELEMS: usize = 1024;

/// Smallest chunk size accepted from *untrusted* geometry (v2 container
/// headers, anchor markers). [`HashTree::build`] itself accepts any
/// chunk size, but a corrupted header must degrade into a clean
/// verification error — not into one 32-byte digest per element
/// (`chunk_elems = 1` would allocate 16x the weight buffer before the
/// root comparison ever runs).
pub const MIN_WIRE_CHUNK_ELEMS: usize = 64;

/// Chunk digests folded per level-1 group. With 32-byte digests a group
/// covers GROUP·chunk_elems elements, so the top fold over group
/// digests is num_chunks/GROUP hashes — negligible at any model size.
const GROUP: usize = 1024;

fn hash_chunk(chunk: &[u16]) -> [u8; 32] {
    let mut h = Sha256::new();
    h.update(u16_as_bytes(chunk));
    let mut out = [0u8; 32];
    out.copy_from_slice(&h.finalize());
    out
}

fn hash_group(chunks: &[[u8; 32]]) -> [u8; 32] {
    let mut h = Sha256::new();
    for c in chunks {
        h.update(c);
    }
    let mut out = [0u8; 32];
    out.copy_from_slice(&h.finalize());
    out
}

/// Chunked hash tree: per-chunk SHA-256 digests, level-1 group digests
/// over runs of GROUP chunk digests, and a root that commits to the
/// geometry and every group digest (hence every chunk, hence every
/// element).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HashTree {
    chunk_elems: usize,
    total_elems: usize,
    chunks: Vec<[u8; 32]>,
    groups: Vec<[u8; 32]>,
    root: [u8; 32],
}

impl HashTree {
    /// Build from scratch, hashing chunks (and groups) in parallel.
    pub fn build(weights: &[u16], chunk_elems: usize) -> HashTree {
        let chunk_elems = chunk_elems.max(1);
        let n_chunks = weights.len().div_ceil(chunk_elems);
        let parts = pool::par_ranges(n_chunks, 8, |r| {
            r.map(|c| {
                let lo = c * chunk_elems;
                let hi = (lo + chunk_elems).min(weights.len());
                hash_chunk(&weights[lo..hi])
            })
            .collect::<Vec<[u8; 32]>>()
        });
        let mut chunks = Vec::with_capacity(n_chunks);
        for p in parts {
            chunks.extend(p);
        }
        let n_groups = n_chunks.div_ceil(GROUP);
        let gparts = pool::par_ranges(n_groups, 4, |r| {
            r.map(|g| {
                let lo = g * GROUP;
                let hi = (lo + GROUP).min(chunks.len());
                hash_group(&chunks[lo..hi])
            })
            .collect::<Vec<[u8; 32]>>()
        });
        let mut groups = Vec::with_capacity(n_groups);
        for p in gparts {
            groups.extend(p);
        }
        let mut t = HashTree {
            chunk_elems,
            total_elems: weights.len(),
            chunks,
            groups,
            root: [0u8; 32],
        };
        t.recompute_root();
        t
    }

    fn recompute_root(&mut self) {
        let mut h = Sha256::new();
        h.update((self.total_elems as u64).to_le_bytes());
        h.update((self.chunk_elems as u64).to_le_bytes());
        for g in &self.groups {
            h.update(g);
        }
        self.root.copy_from_slice(&h.finalize());
    }

    /// Refold the group digests containing `touched` (sorted chunk ids)
    /// and the root: O(touched_groups · GROUP + num_groups) digest
    /// bytes, independent of total model size for realistic patches.
    fn refold(&mut self, touched: &[usize]) {
        let mut last = usize::MAX;
        for &c in touched {
            let g = c / GROUP;
            if g != last {
                let lo = g * GROUP;
                let hi = (lo + GROUP).min(self.chunks.len());
                self.groups[g] = hash_group(&self.chunks[lo..hi]);
                last = g;
            }
        }
        self.recompute_root();
    }

    pub fn chunk_elems(&self) -> usize {
        self.chunk_elems
    }

    pub fn total_elems(&self) -> usize {
        self.total_elems
    }

    pub fn num_chunks(&self) -> usize {
        self.chunks.len()
    }

    pub fn root(&self) -> &[u8; 32] {
        &self.root
    }

    pub fn root_hex(&self) -> String {
        hex(&self.root)
    }

    /// Chunk ids containing any of the (sorted) flat indices, deduped.
    pub fn touched_chunks(&self, indices: &[u64]) -> Vec<usize> {
        let mut out = Vec::new();
        for &i in indices {
            let c = i as usize / self.chunk_elems;
            if out.last() != Some(&c) {
                out.push(c);
            }
        }
        out
    }

    /// Rehash only the chunks containing `indices` against the already-
    /// mutated `weights` and refold the root. `indices` must be sorted
    /// (patch index streams always are). Untouched chunk hashes are
    /// reused — this is the publisher-side incremental step.
    pub fn update(&mut self, weights: &[u16], indices: &[u64]) {
        assert_eq!(weights.len(), self.total_elems, "hash tree length mismatch");
        if indices.is_empty() {
            return;
        }
        let chunk_elems = self.chunk_elems;
        let total = self.total_elems;
        let touched = self.touched_chunks(indices);
        let parts = pool::par_ranges(touched.len(), 16, |r| {
            r.map(|k| {
                let c = touched[k];
                let lo = c * chunk_elems;
                let hi = (lo + chunk_elems).min(total);
                (c, hash_chunk(&weights[lo..hi]))
            })
            .collect::<Vec<(usize, [u8; 32])>>()
        });
        for part in parts {
            for (c, h) in part {
                self.chunks[c] = h;
            }
        }
        self.refold(&touched);
    }

    /// Fused consumer hot path: apply `weights[idx] = value` and rehash
    /// each touched chunk in the same pass (Alg. 4 + §J.4 verification
    /// sharing one walk over the touched chunks). `indices` must be
    /// sorted and values must pair with them.
    pub fn apply_and_rehash(&mut self, weights: &mut [u16], indices: &[u64], values: &[u16]) {
        assert_eq!(indices.len(), values.len());
        assert_eq!(weights.len(), self.total_elems, "hash tree length mismatch");
        let chunk_elems = self.chunk_elems;
        let mut touched = Vec::new();
        let mut k = 0usize;
        while k < indices.len() {
            let c = indices[k] as usize / chunk_elems;
            let lo = c * chunk_elems;
            let hi = (lo + chunk_elems).min(weights.len());
            while k < indices.len() && (indices[k] as usize) < hi {
                weights[indices[k] as usize] = values[k];
                k += 1;
            }
            self.chunks[c] = hash_chunk(&weights[lo..hi]);
            touched.push(c);
        }
        if !touched.is_empty() {
            self.refold(&touched);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn incremental_update_equals_rebuild() {
        // Property: after a random patch, the incremental update (both
        // the plain `update` and the fused `apply_and_rehash`) matches a
        // from-scratch rebuild — for random chunk sizes including ones
        // that do not divide the buffer length.
        prop::check("hashtree incremental == rebuild", 40, |g| {
            let n = g.len().max(1);
            let chunk = 1 + g.rng.below(3 * n as u64 / 2 + 2) as usize;
            let old: Vec<u16> = (0..n).map(|_| g.rng.next_u32() as u16).collect();
            let count = g.rng.below(n as u64 + 1) as usize;
            let idx = g.sorted_indices(n, count);
            let vals: Vec<u16> = idx.iter().map(|_| g.rng.next_u32() as u16).collect();

            // path A: plain apply then incremental update
            let mut wa = old.clone();
            let mut ta = HashTree::build(&wa, chunk);
            crate::sparse::apply_u16(&mut wa, &idx, &vals);
            ta.update(&wa, &idx);

            // path B: fused apply_and_rehash
            let mut wb = old.clone();
            let mut tb = HashTree::build(&wb, chunk);
            tb.apply_and_rehash(&mut wb, &idx, &vals);

            // path C: from-scratch rebuild of the mutated buffer
            let tc = HashTree::build(&wa, chunk);

            assert_eq!(wa, wb);
            assert_eq!(ta, tc, "update() diverged from rebuild (chunk={})", chunk);
            assert_eq!(tb, tc, "apply_and_rehash() diverged from rebuild (chunk={})", chunk);
        });
    }

    #[test]
    fn root_commits_to_every_position() {
        let mut rng = crate::util::rng::Rng::new(11);
        let n = 10_000usize;
        let mut w: Vec<u16> = (0..n).map(|_| rng.next_u32() as u16).collect();
        let tree = HashTree::build(&w, 257); // does not divide n
        assert_eq!(tree.num_chunks(), n.div_ceil(257));
        for &i in &[0usize, 256, 257, 5000, n - 1] {
            let orig = w[i];
            w[i] ^= 1;
            let flipped = HashTree::build(&w, 257);
            assert_ne!(tree.root_hex(), flipped.root_hex(), "flip at {} invisible", i);
            w[i] = orig;
        }
        assert_eq!(HashTree::build(&w, 257).root_hex(), tree.root_hex());
    }

    #[test]
    fn geometry_is_part_of_the_root() {
        let w: Vec<u16> = (0..4096).map(|i| i as u16).collect();
        let a = HashTree::build(&w, 512);
        let b = HashTree::build(&w, 1024);
        assert_ne!(a.root_hex(), b.root_hex());
        // same data + same chunking → same root
        assert_eq!(a.root_hex(), HashTree::build(&w, 512).root_hex());
    }

    #[test]
    fn edge_cases() {
        // empty buffer: zero chunks, but still a well-defined root
        let empty = HashTree::build(&[], 64);
        assert_eq!(empty.num_chunks(), 0);
        assert_eq!(empty.root_hex().len(), 64);
        // buffer smaller than one chunk
        let small = HashTree::build(&[1, 2, 3], 64);
        assert_eq!(small.num_chunks(), 1);
        // empty patch leaves the root untouched
        let mut w = vec![5u16; 100];
        let mut t = HashTree::build(&w, 7);
        let before = t.root_hex();
        t.update(&w, &[]);
        t.apply_and_rehash(&mut w, &[], &[]);
        assert_eq!(t.root_hex(), before);
    }

    #[test]
    fn touched_chunks_dedups_sorted_runs() {
        let w = vec![0u16; 1000];
        let t = HashTree::build(&w, 100);
        assert_eq!(t.touched_chunks(&[0, 1, 99, 100, 250, 999]), vec![0, 1, 2, 9]);
        assert!(t.touched_chunks(&[]).is_empty());
    }
}
