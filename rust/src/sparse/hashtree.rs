//! Chunked SHA-256 hash tree over a BF16 weight buffer (§J.4, made
//! incremental).
//!
//! The flat parameter vector is split into fixed-size chunks of
//! `chunk_elems` BF16 elements; each chunk gets its own SHA-256, and the
//! root commits to `(total_elems, chunk_elems, chunk hashes…)`. Two
//! properties make this the O(nnz) replacement for the full-buffer
//! scalar hash on the PULSESync hot path:
//!
//! * **Build** parallelizes over chunks via [`crate::util::pool`]
//!   (scalar SHA-256 of the whole buffer is inherently serial).
//! * **Update** after a sparse patch rehashes only the chunks that
//!   contain patched indices — O(touched_chunks · chunk_elems), which is
//!   at most O(nnz · chunk_elems) and independent of model size. The
//!   root fold is two-level (chunk digests → group digests → root), so
//!   an update refolds only the touched groups plus an
//!   O(num_chunks / GROUP) top fold — the per-patch fold stays tiny
//!   even at 10B+ parameters instead of scaling with the chunk count.
//!
//! [`HashTree::apply_and_rehash`] fuses the consumer's patch apply with
//! the chunk rehash so both share one pass over the touched chunks.
//!
//! The root is exactly as binding as the scalar hash for patch
//! verification: any corrupted value or misdirected index lands in some
//! chunk, changes that chunk's hash, and therefore changes the root.
//!
//! # Shard subtrees (sharded fan-out)
//!
//! The sharded patch fabric ([`crate::pulse::sync`]) splits the
//! parameter space into contiguous chunk-aligned element ranges
//! ([`shard_ranges`]). Because shards never split a chunk, a **shard
//! subtree root** ([`HashTree::subtree_root_hex`]) — a digest over the
//! shard's geometry plus its run of chunk digests — is computable by
//! publisher and consumer from the same per-chunk state, and a
//! corrupted shard perturbs only its own subtree root.
//! [`HashTree::apply_and_rehash_shards`] applies disjoint shard patches
//! in parallel (scoped threads over disjoint weight/digest slices),
//! verifies each shard's subtree root, and *restores a failed shard
//! exactly* (old values + old chunk digests, both saved at O(nnz)
//! cost), so one bad shard can be refetched while the others stay
//! applied.

use crate::util::{hex, pool, u16_as_bytes};
use sha2::{Digest, Sha256};

/// Default chunk size in BF16 elements (2 KB of data per chunk): small
/// enough that per-patch rehash cost ≈ nnz · chunk stays far below the
/// full buffer at realistic sparsities, large enough that the
/// per-chunk SHA-256 call overhead and the root fold stay negligible
/// (the chunk-hash array is 1/64 of the buffer).
pub const DEFAULT_CHUNK_ELEMS: usize = 1024;

/// Smallest chunk size accepted from *untrusted* geometry (v2 container
/// headers, anchor markers). [`HashTree::build`] itself accepts any
/// chunk size, but a corrupted header must degrade into a clean
/// verification error — not into one 32-byte digest per element
/// (`chunk_elems = 1` would allocate 16x the weight buffer before the
/// root comparison ever runs).
pub const MIN_WIRE_CHUNK_ELEMS: usize = 64;

/// Chunk digests folded per level-1 group. With 32-byte digests a group
/// covers GROUP·chunk_elems elements, so the top fold over group
/// digests is num_chunks/GROUP hashes — negligible at any model size.
const GROUP: usize = 1024;

fn hash_chunk(chunk: &[u16]) -> [u8; 32] {
    let mut h = Sha256::new();
    h.update(u16_as_bytes(chunk));
    let mut out = [0u8; 32];
    out.copy_from_slice(&h.finalize());
    out
}

fn hash_group(chunks: &[[u8; 32]]) -> [u8; 32] {
    let mut h = Sha256::new();
    for c in chunks {
        h.update(c);
    }
    let mut out = [0u8; 32];
    out.copy_from_slice(&h.finalize());
    out
}

/// Chunked hash tree: per-chunk SHA-256 digests, level-1 group digests
/// over runs of GROUP chunk digests, and a root that commits to the
/// geometry and every group digest (hence every chunk, hence every
/// element).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HashTree {
    chunk_elems: usize,
    total_elems: usize,
    chunks: Vec<[u8; 32]>,
    groups: Vec<[u8; 32]>,
    root: [u8; 32],
}

impl HashTree {
    /// Build from scratch, hashing chunks (and groups) in parallel.
    pub fn build(weights: &[u16], chunk_elems: usize) -> HashTree {
        let chunk_elems = chunk_elems.max(1);
        let n_chunks = weights.len().div_ceil(chunk_elems);
        let parts = pool::par_ranges(n_chunks, 8, |r| {
            r.map(|c| {
                let lo = c * chunk_elems;
                let hi = (lo + chunk_elems).min(weights.len());
                hash_chunk(&weights[lo..hi])
            })
            .collect::<Vec<[u8; 32]>>()
        });
        let mut chunks = Vec::with_capacity(n_chunks);
        for p in parts {
            chunks.extend(p);
        }
        let n_groups = n_chunks.div_ceil(GROUP);
        let gparts = pool::par_ranges(n_groups, 4, |r| {
            r.map(|g| {
                let lo = g * GROUP;
                let hi = (lo + GROUP).min(chunks.len());
                hash_group(&chunks[lo..hi])
            })
            .collect::<Vec<[u8; 32]>>()
        });
        let mut groups = Vec::with_capacity(n_groups);
        for p in gparts {
            groups.extend(p);
        }
        let mut t = HashTree {
            chunk_elems,
            total_elems: weights.len(),
            chunks,
            groups,
            root: [0u8; 32],
        };
        t.recompute_root();
        t
    }

    fn recompute_root(&mut self) {
        let mut h = Sha256::new();
        h.update((self.total_elems as u64).to_le_bytes());
        h.update((self.chunk_elems as u64).to_le_bytes());
        for g in &self.groups {
            h.update(g);
        }
        self.root.copy_from_slice(&h.finalize());
    }

    /// Refold the group digests containing `touched` (sorted chunk ids)
    /// and the root: O(touched_groups · GROUP + num_groups) digest
    /// bytes, independent of total model size for realistic patches.
    fn refold(&mut self, touched: &[usize]) {
        let mut last = usize::MAX;
        for &c in touched {
            let g = c / GROUP;
            if g != last {
                let lo = g * GROUP;
                let hi = (lo + GROUP).min(self.chunks.len());
                self.groups[g] = hash_group(&self.chunks[lo..hi]);
                last = g;
            }
        }
        self.recompute_root();
    }

    pub fn chunk_elems(&self) -> usize {
        self.chunk_elems
    }

    pub fn total_elems(&self) -> usize {
        self.total_elems
    }

    pub fn num_chunks(&self) -> usize {
        self.chunks.len()
    }

    pub fn root(&self) -> &[u8; 32] {
        &self.root
    }

    pub fn root_hex(&self) -> String {
        hex(&self.root)
    }

    /// Chunk ids containing any of the (sorted) flat indices, deduped.
    pub fn touched_chunks(&self, indices: &[u64]) -> Vec<usize> {
        let mut out = Vec::new();
        for &i in indices {
            let c = i as usize / self.chunk_elems;
            if out.last() != Some(&c) {
                out.push(c);
            }
        }
        out
    }

    /// Rehash only the chunks containing `indices` against the already-
    /// mutated `weights` and refold the root. `indices` must be sorted
    /// (patch index streams always are). Untouched chunk hashes are
    /// reused — this is the publisher-side incremental step.
    pub fn update(&mut self, weights: &[u16], indices: &[u64]) {
        assert_eq!(weights.len(), self.total_elems, "hash tree length mismatch");
        if indices.is_empty() {
            return;
        }
        let chunk_elems = self.chunk_elems;
        let total = self.total_elems;
        let touched = self.touched_chunks(indices);
        let parts = pool::par_ranges(touched.len(), 16, |r| {
            r.map(|k| {
                let c = touched[k];
                let lo = c * chunk_elems;
                let hi = (lo + chunk_elems).min(total);
                (c, hash_chunk(&weights[lo..hi]))
            })
            .collect::<Vec<(usize, [u8; 32])>>()
        });
        for part in parts {
            for (c, h) in part {
                self.chunks[c] = h;
            }
        }
        self.refold(&touched);
    }

    /// Fused consumer hot path: apply `weights[idx] = value` and rehash
    /// each touched chunk in the same pass (Alg. 4 + §J.4 verification
    /// sharing one walk over the touched chunks). `indices` must be
    /// sorted and values must pair with them.
    pub fn apply_and_rehash(&mut self, weights: &mut [u16], indices: &[u64], values: &[u16]) {
        assert_eq!(indices.len(), values.len());
        assert_eq!(weights.len(), self.total_elems, "hash tree length mismatch");
        let chunk_elems = self.chunk_elems;
        let mut touched = Vec::new();
        let mut k = 0usize;
        while k < indices.len() {
            let c = indices[k] as usize / chunk_elems;
            let lo = c * chunk_elems;
            let hi = (lo + chunk_elems).min(weights.len());
            while k < indices.len() && (indices[k] as usize) < hi {
                weights[indices[k] as usize] = values[k];
                k += 1;
            }
            self.chunks[c] = hash_chunk(&weights[lo..hi]);
            touched.push(c);
        }
        if !touched.is_empty() {
            self.refold(&touched);
        }
    }
}

/// Contiguous, chunk-aligned element ranges covering `0..total_elems`
/// for up to `shards` shards (fewer when there are fewer chunks than
/// shards). Both sides of the sharded fan-out derive the ranges from
/// `(total_elems, chunk_elems, shard_count)` with this function, so the
/// wire-level `elem_offset` is cross-checked, never trusted.
pub fn shard_ranges(
    total_elems: usize,
    chunk_elems: usize,
    shards: usize,
) -> Vec<std::ops::Range<usize>> {
    let ce = chunk_elems.max(1);
    let shards = shards.max(1);
    let n_chunks = total_elems.div_ceil(ce).max(1);
    let chunks_per_shard = n_chunks.div_ceil(shards);
    let mut out = Vec::new();
    let mut c = 0usize;
    while c < n_chunks {
        let lo = (c * ce).min(total_elems);
        let hi = (((c + chunks_per_shard).min(n_chunks)) * ce).min(total_elems);
        out.push(lo..hi);
        c += chunks_per_shard;
    }
    out
}

/// Load-balanced variant of [`shard_ranges`]: contiguous chunk-aligned
/// element ranges cut so every shard carries roughly `total_nnz /
/// shards` changed positions, given `counts[c]` = changed positions in
/// chunk `c` (from [`crate::sparse::count_diff_bf16_blocks`] at
/// `chunk_elems` blocks). Because cuts are only ever placed on chunk
/// boundaries, shard subtree roots remain valid exactly as with the
/// static split; a uniformly-zero profile degrades to [`shard_ranges`].
/// Produces *at most* `shards` ranges — a profile concentrated in the
/// final chunks can yield fewer (splitting a zero-nnz prefix would
/// only add frame overhead).
pub fn balanced_shard_ranges(
    counts: &[usize],
    chunk_elems: usize,
    total_elems: usize,
    shards: usize,
) -> Vec<std::ops::Range<usize>> {
    let ce = chunk_elems.max(1);
    let shards = shards.max(1);
    if total_elems == 0 {
        return shard_ranges(total_elems, ce, shards);
    }
    let n_chunks = total_elems.div_ceil(ce).max(1);
    assert_eq!(counts.len(), n_chunks, "one count per hash-tree chunk");
    let total_nnz: usize = counts.iter().sum();
    if total_nnz == 0 || shards == 1 {
        return shard_ranges(total_elems, ce, shards);
    }
    let mut out = Vec::with_capacity(shards.min(n_chunks));
    let mut cum = 0usize;
    let mut start_chunk = 0usize;
    for (c, &cnt) in counts.iter().enumerate() {
        cum += cnt;
        // cut after chunk c once the cumulative nnz crosses the next
        // equal-share boundary — unless this is the last chunk (the
        // final range always runs to the buffer end) or we already
        // produced shards-1 cuts
        let produced = out.len();
        if c + 1 < n_chunks
            && produced + 1 < shards
            && cum * shards >= total_nnz * (produced + 1)
        {
            out.push(start_chunk * ce..(c + 1) * ce);
            start_chunk = c + 1;
        }
    }
    out.push(start_chunk * ce..total_elems);
    out
}

/// One shard's patch, borrowed for [`HashTree::apply_and_rehash_shards`].
/// `indices` are absolute flat indices, sorted, all inside
/// `elem_lo..elem_hi`; `expect_root` is the publisher's subtree root
/// for this shard after the step applies.
#[derive(Debug, Clone, Copy)]
pub struct ShardPatchRef<'a> {
    pub elem_lo: usize,
    pub elem_hi: usize,
    pub indices: &'a [u64],
    pub values: &'a [u16],
    pub expect_root: &'a str,
}

/// Digest a shard subtree: geometry + the shard's run of chunk digests.
fn subtree_digest(
    chunk_elems: usize,
    elem_lo: usize,
    elem_hi: usize,
    digests: &[[u8; 32]],
) -> [u8; 32] {
    let mut h = Sha256::new();
    h.update(b"PULSE-shard-v3");
    h.update((elem_lo as u64).to_le_bytes());
    h.update((elem_hi as u64).to_le_bytes());
    h.update((chunk_elems as u64).to_le_bytes());
    for d in digests {
        h.update(d);
    }
    let mut out = [0u8; 32];
    out.copy_from_slice(&h.finalize());
    out
}

/// Apply one shard's patch on its disjoint weight/digest slices, rehash
/// its touched chunks, and verify its subtree root. On mismatch the
/// shard is restored exactly (saved values + saved digests). Returns
/// `(verified, touched global chunk ids)`.
fn shard_worker(
    w: &mut [u16],
    chunks: &mut [[u8; 32]],
    s: &ShardPatchRef<'_>,
    chunk_elems: usize,
) -> (bool, Vec<usize>) {
    let c_lo = s.elem_lo / chunk_elems;
    let saved_vals: Vec<u16> =
        s.indices.iter().map(|&i| w[i as usize - s.elem_lo]).collect();
    let mut saved_digests: Vec<(usize, [u8; 32])> = Vec::new();
    let mut touched_local: Vec<usize> = Vec::new();
    let mut k = 0usize;
    while k < s.indices.len() {
        let c = s.indices[k] as usize / chunk_elems; // global chunk id
        let lo = c * chunk_elems;
        let hi = ((c + 1) * chunk_elems).min(s.elem_hi);
        let cl = c - c_lo;
        saved_digests.push((cl, chunks[cl]));
        while k < s.indices.len() && (s.indices[k] as usize) < hi {
            w[s.indices[k] as usize - s.elem_lo] = s.values[k];
            k += 1;
        }
        chunks[cl] = hash_chunk(&w[lo - s.elem_lo..hi - s.elem_lo]);
        touched_local.push(cl);
    }
    let root = subtree_digest(chunk_elems, s.elem_lo, s.elem_hi, chunks);
    if hex(&root) == s.expect_root {
        (true, touched_local.into_iter().map(|cl| cl + c_lo).collect())
    } else {
        for (j, &i) in s.indices.iter().enumerate() {
            w[i as usize - s.elem_lo] = saved_vals[j];
        }
        for &(cl, d) in &saved_digests {
            chunks[cl] = d;
        }
        (false, Vec::new())
    }
}

impl HashTree {
    /// Subtree root over elements `elem_lo..elem_hi` — the per-shard
    /// commitment carried in v3 container headers. `elem_lo` must be
    /// chunk-aligned and `elem_hi` chunk-aligned or the buffer end
    /// (shards never split a chunk; see [`shard_ranges`]).
    pub fn subtree_root_hex(&self, elem_lo: usize, elem_hi: usize) -> String {
        let ce = self.chunk_elems;
        assert!(elem_lo % ce == 0, "shard lo must be chunk-aligned");
        assert!(
            elem_hi % ce == 0 || elem_hi == self.total_elems,
            "shard hi must be chunk-aligned or the buffer end"
        );
        assert!(elem_lo <= elem_hi && elem_hi <= self.total_elems);
        let digests = &self.chunks[elem_lo / ce..elem_hi.div_ceil(ce)];
        hex(&subtree_digest(ce, elem_lo, elem_hi, digests))
    }

    /// Apply disjoint shard patches in parallel (one scoped thread per
    /// shard over non-overlapping weight/digest slices), verifying each
    /// shard's subtree root independently. Shards that fail
    /// verification are restored exactly and reported `false`; the
    /// group/root fold runs once at the end over every verified shard's
    /// touched chunks. Shard ranges must be sorted, disjoint, and
    /// chunk-aligned — derive them with [`shard_ranges`], and validate
    /// index bounds/order before calling (out-of-range indices panic).
    pub fn apply_and_rehash_shards(
        &mut self,
        weights: &mut [u16],
        shards: &[ShardPatchRef<'_>],
    ) -> Vec<bool> {
        assert_eq!(weights.len(), self.total_elems, "hash tree length mismatch");
        let ce = self.chunk_elems;
        let mut prev_hi = 0usize;
        for s in shards {
            assert!(
                s.elem_lo >= prev_hi && s.elem_lo <= s.elem_hi,
                "shard ranges must be sorted and disjoint"
            );
            assert!(s.elem_lo % ce == 0, "shard lo must be chunk-aligned");
            assert!(
                (s.elem_hi % ce == 0 || s.elem_hi == self.total_elems)
                    && s.elem_hi <= self.total_elems,
                "shard hi must be chunk-aligned or the buffer end"
            );
            assert_eq!(s.indices.len(), s.values.len());
            if let (Some(&first), Some(&last)) = (s.indices.first(), s.indices.last()) {
                assert!(
                    first as usize >= s.elem_lo && (last as usize) < s.elem_hi,
                    "shard indices outside the shard range"
                );
            }
            prev_hi = s.elem_hi;
        }
        let mut results: Vec<(bool, Vec<usize>)> = Vec::new();
        std::thread::scope(|scope| {
            let mut handles = Vec::with_capacity(shards.len());
            let mut w_tail: &mut [u16] = weights;
            let mut c_tail: &mut [[u8; 32]] = &mut self.chunks;
            let mut w_off = 0usize;
            let mut c_off = 0usize;
            for s in shards {
                let c_lo = s.elem_lo / ce;
                let c_hi = s.elem_hi.div_ceil(ce);
                let tail = std::mem::take(&mut w_tail);
                let (_gap, rest) = tail.split_at_mut(s.elem_lo - w_off);
                let (w_mine, rest) = rest.split_at_mut(s.elem_hi - s.elem_lo);
                w_tail = rest;
                w_off = s.elem_hi;
                let tail = std::mem::take(&mut c_tail);
                let (_gap, rest) = tail.split_at_mut(c_lo - c_off);
                let (c_mine, rest) = rest.split_at_mut(c_hi - c_lo);
                c_tail = rest;
                c_off = c_hi;
                handles.push(scope.spawn(move || shard_worker(w_mine, c_mine, s, ce)));
            }
            results = handles.into_iter().map(|h| h.join().unwrap()).collect();
        });
        let mut touched_all: Vec<usize> = Vec::new();
        let mut verified = Vec::with_capacity(results.len());
        for (ok, touched) in results {
            verified.push(ok);
            touched_all.extend(touched);
        }
        touched_all.sort_unstable();
        if !touched_all.is_empty() {
            self.refold(&touched_all);
        }
        verified
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn incremental_update_equals_rebuild() {
        // Property: after a random patch, the incremental update (both
        // the plain `update` and the fused `apply_and_rehash`) matches a
        // from-scratch rebuild — for random chunk sizes including ones
        // that do not divide the buffer length.
        prop::check("hashtree incremental == rebuild", 40, |g| {
            let n = g.len().max(1);
            let chunk = 1 + g.rng.below(3 * n as u64 / 2 + 2) as usize;
            let old: Vec<u16> = (0..n).map(|_| g.rng.next_u32() as u16).collect();
            let count = g.rng.below(n as u64 + 1) as usize;
            let idx = g.sorted_indices(n, count);
            let vals: Vec<u16> = idx.iter().map(|_| g.rng.next_u32() as u16).collect();

            // path A: plain apply then incremental update
            let mut wa = old.clone();
            let mut ta = HashTree::build(&wa, chunk);
            crate::sparse::apply_u16(&mut wa, &idx, &vals);
            ta.update(&wa, &idx);

            // path B: fused apply_and_rehash
            let mut wb = old.clone();
            let mut tb = HashTree::build(&wb, chunk);
            tb.apply_and_rehash(&mut wb, &idx, &vals);

            // path C: from-scratch rebuild of the mutated buffer
            let tc = HashTree::build(&wa, chunk);

            assert_eq!(wa, wb);
            assert_eq!(ta, tc, "update() diverged from rebuild (chunk={})", chunk);
            assert_eq!(tb, tc, "apply_and_rehash() diverged from rebuild (chunk={})", chunk);
        });
    }

    #[test]
    fn root_commits_to_every_position() {
        let mut rng = crate::util::rng::Rng::new(11);
        let n = 10_000usize;
        let mut w: Vec<u16> = (0..n).map(|_| rng.next_u32() as u16).collect();
        let tree = HashTree::build(&w, 257); // does not divide n
        assert_eq!(tree.num_chunks(), n.div_ceil(257));
        for &i in &[0usize, 256, 257, 5000, n - 1] {
            let orig = w[i];
            w[i] ^= 1;
            let flipped = HashTree::build(&w, 257);
            assert_ne!(tree.root_hex(), flipped.root_hex(), "flip at {} invisible", i);
            w[i] = orig;
        }
        assert_eq!(HashTree::build(&w, 257).root_hex(), tree.root_hex());
    }

    #[test]
    fn geometry_is_part_of_the_root() {
        let w: Vec<u16> = (0..4096).map(|i| i as u16).collect();
        let a = HashTree::build(&w, 512);
        let b = HashTree::build(&w, 1024);
        assert_ne!(a.root_hex(), b.root_hex());
        // same data + same chunking → same root
        assert_eq!(a.root_hex(), HashTree::build(&w, 512).root_hex());
    }

    #[test]
    fn edge_cases() {
        // empty buffer: zero chunks, but still a well-defined root
        let empty = HashTree::build(&[], 64);
        assert_eq!(empty.num_chunks(), 0);
        assert_eq!(empty.root_hex().len(), 64);
        // buffer smaller than one chunk
        let small = HashTree::build(&[1, 2, 3], 64);
        assert_eq!(small.num_chunks(), 1);
        // empty patch leaves the root untouched
        let mut w = vec![5u16; 100];
        let mut t = HashTree::build(&w, 7);
        let before = t.root_hex();
        t.update(&w, &[]);
        t.apply_and_rehash(&mut w, &[], &[]);
        assert_eq!(t.root_hex(), before);
    }

    #[test]
    fn touched_chunks_dedups_sorted_runs() {
        let w = vec![0u16; 1000];
        let t = HashTree::build(&w, 100);
        assert_eq!(t.touched_chunks(&[0, 1, 99, 100, 250, 999]), vec![0, 1, 2, 9]);
        assert!(t.touched_chunks(&[]).is_empty());
    }

    #[test]
    fn shard_ranges_cover_and_align() {
        for (n, ce, s) in [
            (10_000usize, 64usize, 4usize),
            (10_000, 64, 1),
            (100, 64, 8),  // fewer chunks than shards
            (1000, 300, 3), // unaligned tail
            (0, 64, 4),
            (64, 64, 4),
        ] {
            let ranges = shard_ranges(n, ce, s);
            assert!(ranges.len() <= s.max(1), "n={} ce={} s={}", n, ce, s);
            let mut expect_lo = 0usize;
            for r in &ranges {
                assert_eq!(r.start, expect_lo);
                assert!(r.start % ce == 0);
                assert!(r.end % ce == 0 || r.end == n);
                expect_lo = r.end;
            }
            assert_eq!(expect_lo, n, "ranges must cover the buffer (n={})", n);
        }
        // empty buffer still yields one (empty) shard
        assert_eq!(shard_ranges(0, 64, 4), vec![0..0]);
    }

    #[test]
    fn balanced_ranges_cover_align_and_balance() {
        prop::check("balanced shard ranges partition + balance", 40, |g| {
            let n = g.len().max(1);
            let ce = 1 + g.rng.below(n as u64 / 2 + 2) as usize;
            let shards = 1 + g.rng.below(8) as usize;
            let n_chunks = n.div_ceil(ce);
            // skewed profile: a few hot chunks own most of the nnz
            let counts: Vec<usize> = (0..n_chunks)
                .map(|_| {
                    if g.rng.f64() < 0.2 {
                        g.rng.below(1000) as usize
                    } else {
                        g.rng.below(3) as usize
                    }
                })
                .collect();
            let ranges = balanced_shard_ranges(&counts, ce, n, shards);
            assert!(!ranges.is_empty() && ranges.len() <= shards);
            let mut expect_lo = 0usize;
            for r in &ranges {
                assert_eq!(r.start, expect_lo);
                assert!(r.start < r.end, "empty shard range");
                assert!(r.start % ce == 0, "shard lo must stay chunk-aligned");
                assert!(r.end % ce == 0 || r.end == n, "shard hi must stay chunk-aligned");
                expect_lo = r.end;
            }
            assert_eq!(expect_lo, n, "ranges must cover the buffer");
            // every proper prefix of ranges carries at least its equal
            // share of the nnz (the greedy cut invariant)
            let total: usize = counts.iter().sum();
            if total > 0 {
                let mut cum = 0usize;
                for (k, r) in ranges.iter().enumerate().take(ranges.len() - 1) {
                    let c_lo = r.start / ce;
                    let c_hi = r.end.div_ceil(ce);
                    cum += counts[c_lo..c_hi].iter().sum::<usize>();
                    assert!(
                        cum * shards >= total * (k + 1),
                        "prefix {} under-filled: {} of {}",
                        k,
                        cum,
                        total
                    );
                }
            }
        });
    }

    #[test]
    fn balanced_ranges_split_hot_region() {
        // all updates land in the first quarter: the static split gives
        // shard 0 everything; the balanced split cuts the hot quarter
        let n = 64 * 1024usize;
        let ce = 1024usize;
        let n_chunks = n / ce;
        let mut counts = vec![0usize; n_chunks];
        for c in 0..n_chunks / 4 {
            counts[c] = 100;
        }
        let ranges = balanced_shard_ranges(&counts, ce, n, 4);
        assert_eq!(ranges.len(), 4);
        // first three shards split the hot quarter ≈ evenly
        let hot_end = (n_chunks / 4) * ce;
        assert!(ranges[2].end <= hot_end, "cuts must land inside the hot region");
        let nnz_of = |r: &std::ops::Range<usize>| {
            counts[r.start / ce..r.end.div_ceil(ce)].iter().sum::<usize>()
        };
        let total: usize = counts.iter().sum();
        for r in ranges.iter().take(3) {
            let share = nnz_of(r) as f64 / total as f64;
            assert!(share > 0.15 && share < 0.45, "share {}", share);
        }
        // zero profile falls back to the static split
        assert_eq!(
            balanced_shard_ranges(&vec![0; n_chunks], ce, n, 4),
            shard_ranges(n, ce, 4)
        );
    }

    #[test]
    fn subtree_roots_localize_changes() {
        let mut rng = crate::util::rng::Rng::new(21);
        let n = 5_000usize;
        let ce = 128usize;
        let mut w: Vec<u16> = (0..n).map(|_| rng.next_u32() as u16).collect();
        let ranges = shard_ranges(n, ce, 4);
        let t = HashTree::build(&w, ce);
        let roots: Vec<String> =
            ranges.iter().map(|r| t.subtree_root_hex(r.start, r.end)).collect();
        // flip one element inside shard 2: only shard 2's root moves
        let victim = ranges[2].start + 7;
        w[victim] ^= 1;
        let t2 = HashTree::build(&w, ce);
        for (i, r) in ranges.iter().enumerate() {
            let root2 = t2.subtree_root_hex(r.start, r.end);
            if i == 2 {
                assert_ne!(roots[i], root2, "shard {} should change", i);
            } else {
                assert_eq!(roots[i], root2, "shard {} must be untouched", i);
            }
        }
        // the subtree commitment binds geometry, not just bytes
        assert_ne!(
            t.subtree_root_hex(0, ranges[0].end),
            t.subtree_root_hex(ranges[0].end, ranges[1].end)
        );
    }

    #[test]
    fn sharded_apply_matches_serial() {
        prop::check("sharded apply == serial apply", 30, |g| {
            let n = g.len().max(1);
            let ce = 1 + g.rng.below(n as u64 / 2 + 2) as usize;
            let nshards = 1 + g.rng.below(6) as usize;
            let old: Vec<u16> = (0..n).map(|_| g.rng.next_u32() as u16).collect();
            let count = g.rng.below(n as u64 + 1) as usize;
            let idx = g.sorted_indices(n, count);
            let vals: Vec<u16> = idx.iter().map(|_| g.rng.next_u32() as u16).collect();

            // serial reference
            let mut ws = old.clone();
            let mut ts = HashTree::build(&ws, ce);
            ts.apply_and_rehash(&mut ws, &idx, &vals);

            // sharded path: split the patch by shard range, use the
            // reference tree's subtree roots as the expected commitments
            let ranges = shard_ranges(n, ce, nshards);
            let mut wp = old.clone();
            let mut tp = HashTree::build(&wp, ce);
            let mut shards = Vec::new();
            for r in &ranges {
                let a = idx.partition_point(|&i| (i as usize) < r.start);
                let b = idx.partition_point(|&i| (i as usize) < r.end);
                shards.push((r.clone(), a, b, ts.subtree_root_hex(r.start, r.end)));
            }
            let refs: Vec<ShardPatchRef> = shards
                .iter()
                .map(|(r, a, b, root)| ShardPatchRef {
                    elem_lo: r.start,
                    elem_hi: r.end,
                    indices: &idx[*a..*b],
                    values: &vals[*a..*b],
                    expect_root: root,
                })
                .collect();
            let ok = tp.apply_and_rehash_shards(&mut wp, &refs);
            assert!(ok.iter().all(|&b| b), "all shards must verify");
            assert_eq!(wp, ws);
            assert_eq!(tp, ts, "sharded tree diverged from serial");
        });
    }

    #[test]
    fn failed_shard_restores_exactly_and_others_apply() {
        let mut rng = crate::util::rng::Rng::new(33);
        let n = 4_096usize;
        let ce = 64usize;
        let old: Vec<u16> = (0..n).map(|_| rng.next_u32() as u16).collect();
        let mut new = old.clone();
        for _ in 0..200 {
            let i = rng.below(n as u64) as usize;
            new[i] = rng.next_u32() as u16;
        }
        let (idx, vals) = crate::sparse::diff_gather_bf16(&old, &new);
        let expect_tree = HashTree::build(&new, ce);
        let ranges = shard_ranges(n, ce, 4);
        let mut per_shard: Vec<(usize, usize)> = Vec::new();
        for r in &ranges {
            let a = idx.partition_point(|&i| (i as usize) < r.start);
            let b = idx.partition_point(|&i| (i as usize) < r.end);
            per_shard.push((a, b));
        }
        // corrupt shard 1's values (but hand it the *correct* expected
        // root, as a consumer would have from the wire)
        let mut bad_vals = vals.clone();
        let (a1, b1) = per_shard[1];
        assert!(b1 > a1, "test needs changes in shard 1");
        bad_vals[a1] ^= 0x0101;
        let roots: Vec<String> =
            ranges.iter().map(|r| expect_tree.subtree_root_hex(r.start, r.end)).collect();
        let mut w = old.clone();
        let mut t = HashTree::build(&w, ce);
        let refs: Vec<ShardPatchRef> = ranges
            .iter()
            .enumerate()
            .map(|(i, r)| ShardPatchRef {
                elem_lo: r.start,
                elem_hi: r.end,
                indices: &idx[per_shard[i].0..per_shard[i].1],
                values: &bad_vals[per_shard[i].0..per_shard[i].1],
                expect_root: &roots[i],
            })
            .collect();
        let ok = t.apply_and_rehash_shards(&mut w, &refs);
        assert_eq!(ok.iter().filter(|&&b| !b).count(), 1);
        assert!(!ok[1]);
        // failed shard bit-identical to the pre-apply state, others new
        assert_eq!(w[ranges[1].clone()], old[ranges[1].clone()]);
        for (i, r) in ranges.iter().enumerate() {
            if i != 1 {
                assert_eq!(w[r.clone()], new[r.clone()], "shard {} must be applied", i);
            }
        }
        // tree matches a rebuild of the mixed buffer
        assert_eq!(t, HashTree::build(&w, ce));
        // retry shard 1 with the good values: everything converges
        let retry = [ShardPatchRef {
            elem_lo: ranges[1].start,
            elem_hi: ranges[1].end,
            indices: &idx[a1..b1],
            values: &vals[a1..b1],
            expect_root: &roots[1],
        }];
        let ok2 = t.apply_and_rehash_shards(&mut w, &retry);
        assert!(ok2[0]);
        assert_eq!(w, new);
        assert_eq!(t, expect_tree);
    }
}
