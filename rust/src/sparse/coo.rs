//! 2-D COO index encoding with optional delta coding and type
//! downscaling (paper §H.4.1/§H.4.2).
//!
//! Layout per tensor with ≥1 changed entry:
//!   uvarint tensor_id, uvarint nnz,
//!   [width tag][row stream], [width tag][col stream]
//! Row stream: absolute u32, or (delta mode) gap-from-previous-row.
//! Col stream: absolute u32, or (delta mode) gap-from-previous-col when
//! the row is unchanged, else the absolute column. Downscale mode packs
//! each stream at the narrowest width that fits (u8 rows / u16 cols for
//! typical LLM patches).

use super::TensorShape;
use crate::codec::delta::{pack, pick_width, unpack, Width};
use crate::codec::varint::{get_uvarint, put_uvarint};

pub fn encode(indices: &[u64], layout: &[TensorShape], delta: bool, downscale: bool) -> Vec<u8> {
    let mut out = Vec::new();
    put_uvarint(&mut out, indices.len() as u64);
    let mut i = 0usize;
    for (tid, t) in layout.iter().enumerate() {
        let end = (t.offset + t.len()) as u64;
        let start = i;
        while i < indices.len() && indices[i] < end {
            i += 1;
        }
        if i == start {
            continue;
        }
        let slice = &indices[start..i];
        put_uvarint(&mut out, tid as u64);
        put_uvarint(&mut out, slice.len() as u64);
        // split into rows/cols
        let mut rows = Vec::with_capacity(slice.len());
        let mut cols = Vec::with_capacity(slice.len());
        for &flat in slice {
            let local = (flat as usize) - t.offset;
            rows.push((local / t.cols) as u32);
            cols.push((local % t.cols) as u32);
        }
        if delta {
            let mut prev_row = 0u32;
            let mut prev_col = 0u32;
            for k in 0..rows.len() {
                let (r, c) = (rows[k], cols[k]);
                if k == 0 {
                    // keep absolute
                } else if r == prev_row {
                    rows[k] = 0;
                    cols[k] = c - prev_col;
                } else {
                    rows[k] = r - prev_row;
                    // new row: absolute column
                }
                prev_row = r;
                prev_col = c;
            }
        }
        let (rw, cw) = if downscale {
            (pick_width(&rows), pick_width(&cols))
        } else {
            (Width::U32, Width::U32)
        };
        out.push(rw.tag());
        pack(&rows, rw, &mut out);
        out.push(cw.tag());
        pack(&cols, cw, &mut out);
    }
    out
}

pub fn decode(
    buf: &[u8],
    pos: &mut usize,
    layout: &[TensorShape],
    delta: bool,
    _downscale: bool, // widths are self-describing; flag kept for symmetry
) -> anyhow::Result<Vec<u64>> {
    let total = get_uvarint(buf, pos)? as usize;
    let mut out = Vec::with_capacity(total);
    while out.len() < total {
        let tid = get_uvarint(buf, pos)? as usize;
        let t = layout
            .get(tid)
            .ok_or_else(|| anyhow::anyhow!("coo: tensor id {} out of range", tid))?;
        let nnz = get_uvarint(buf, pos)? as usize;
        let rw = Width::from_tag(*buf.get(*pos).ok_or_else(|| anyhow::anyhow!("coo: eof"))?)?;
        *pos += 1;
        let mut rows = unpack(buf, pos, nnz, rw)?;
        let cw = Width::from_tag(*buf.get(*pos).ok_or_else(|| anyhow::anyhow!("coo: eof"))?)?;
        *pos += 1;
        let mut cols = unpack(buf, pos, nnz, cw)?;
        if delta {
            let mut prev_row = 0u32;
            let mut prev_col = 0u32;
            for k in 0..nnz {
                if k == 0 {
                    prev_row = rows[0];
                    prev_col = cols[0];
                    continue;
                }
                let same_row = rows[k] == 0;
                rows[k] += prev_row;
                if same_row {
                    // same row: col is a gap
                    cols[k] += prev_col;
                } // else: new row, absolute col
                prev_row = rows[k];
                prev_col = cols[k];
            }
        }
        for k in 0..nnz {
            let (r, c) = (rows[k] as usize, cols[k] as usize);
            if r >= t.rows || c >= t.cols {
                anyhow::bail!("coo: index ({}, {}) outside tensor '{}'", r, c, t.name);
            }
            out.push((t.offset + r * t.cols + c) as u64);
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::synthetic_layout;

    #[test]
    fn dense_rows_use_u8_row_deltas() {
        // ~99% sparse patch on a 1024-col matrix: row deltas are 0/1,
        // so the row stream should downscale to u8 (paper §H.4.1).
        let cols = 1024usize;
        let layout = synthetic_layout(1024 * 1024, cols);
        let mut rng = crate::util::rng::Rng::new(91);
        let mut idx: Vec<u64> = (0..10_000).map(|_| rng.below(1024 * 1024)).collect();
        idx.sort_unstable();
        idx.dedup();
        let buf = encode(&idx, &layout, true, true);
        // row width tag is the byte right after the two leading uvarints
        // — just verify the overall size is near 3 bytes/entry.
        assert!(
            buf.len() < idx.len() * 4,
            "buf {} vs nnz {}",
            buf.len(),
            idx.len()
        );
        let mut pos = 0;
        assert_eq!(decode(&buf, &mut pos, &layout, true, true).unwrap(), idx);
    }

    #[test]
    fn empty_patch() {
        let layout = synthetic_layout(100, 10);
        let buf = encode(&[], &layout, true, true);
        let mut pos = 0;
        assert_eq!(decode(&buf, &mut pos, &layout, true, true).unwrap(), Vec::<u64>::new());
    }

    #[test]
    fn corrupt_tensor_id_rejected() {
        let layout = synthetic_layout(100, 10);
        let idx = vec![5u64, 50];
        let mut buf = encode(&idx, &layout, true, true);
        // tensor id byte is right after the leading count varint
        buf[1] = 9; // nonexistent tensor
        let mut pos = 0;
        assert!(decode(&buf, &mut pos, &layout, true, true).is_err());
    }
}
