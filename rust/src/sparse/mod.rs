//! Sparse weight-patch machinery (paper §4.2, Algorithms 1/3/4).
//!
//! A patch is the set of positions whose BF16 bit pattern changed between
//! two checkpoints, together with the **new values** (never arithmetic
//! differences — §H.6's losslessness argument relies on this). This
//! module provides the bitwise diff, the index-stream formats evaluated
//! in Tables 10/11, the self-describing container, and the chunked
//! [`hashtree`] used for end-to-end verification (§J.4).
//!
//! # Cost model of the steady-state hot path
//!
//! Both sides of PULSESync are proportional to the *update*, not the
//! model:
//!
//! * [`diff_bf16`] / [`diff_gather_bf16`] skip unchanged data one
//!   128-bit word (8 BF16 elements) at a time and only descend into
//!   words whose bit patterns differ, so the per-step diff is a
//!   memory-bandwidth scan with O(nnz) element work on top.
//! * Publish/verify use [`hashtree::HashTree`] instead of a full-buffer
//!   scalar SHA-256: an incremental update rehashes only the chunks a
//!   patch touches — O(nnz · chunk_elems) hashing instead of O(total) —
//!   and the consumer's [`hashtree::HashTree::apply_and_rehash`] fuses
//!   the patch apply with that rehash in one pass over touched chunks.
//!   Containers carrying a hash-tree root use the v2 header
//!   (chunk size + root; see [`container`]); v1 scalar-hash containers
//!   still decode and verify.

pub mod container;
pub mod coo;
pub mod flat;
pub mod hashtree;

use crate::util::pool;

/// Geometry of one tensor inside the flat parameter vector; COO formats
/// need (rows, cols). 1-D tensors are treated as a single row.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorShape {
    pub name: String,
    /// Offset in the flat vector (elements).
    pub offset: usize,
    pub rows: usize,
    pub cols: usize,
}

impl TensorShape {
    pub fn len(&self) -> usize {
        self.rows * self.cols
    }
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Make a single-tensor layout covering `n` flat elements (used when no
/// real manifest is available; `cols` bounds u16 col downscaling).
pub fn synthetic_layout(n: usize, cols: usize) -> Vec<TensorShape> {
    let cols = cols.max(1);
    let rows = n.div_ceil(cols);
    vec![TensorShape { name: "flat".into(), offset: 0, rows, cols }]
}

/// Scan `r` for positions where the BF16 bit patterns differ, calling
/// `emit(i)` for each in ascending order. Unchanged data is skipped one
/// 128-bit word (8 elements) at a time: with >99% of positions
/// unchanged, almost every word compares equal and the element loop
/// never runs. The 16-byte loads are unaligned (`&[u16]` only guarantees
/// 2-byte alignment), which `read_unaligned` makes sound.
#[inline]
fn diff_words<F: FnMut(usize)>(old: &[u16], new: &[u16], r: std::ops::Range<usize>, mut emit: F) {
    const W: usize = 8; // BF16 elements per u128 word
    let mut i = r.start;
    let end = r.end;
    while i + W <= end {
        let a = unsafe { (old.as_ptr().add(i) as *const u128).read_unaligned() };
        let b = unsafe { (new.as_ptr().add(i) as *const u128).read_unaligned() };
        if a != b {
            for j in i..i + W {
                if old[j] != new[j] {
                    emit(j);
                }
            }
        }
        i += W;
    }
    while i < end {
        if old[i] != new[i] {
            emit(i);
        }
        i += 1;
    }
}

/// Bitwise diff of two BF16 views: the sorted positions where the bit
/// patterns differ. This *is* the compute-visibility gate applied to
/// consecutive checkpoints (Alg. 1 line 2). Parallel over chunks and
/// word-at-a-time within each chunk.
pub fn diff_bf16(old: &[u16], new: &[u16]) -> Vec<u64> {
    assert_eq!(old.len(), new.len(), "checkpoint length mismatch");
    let parts = pool::par_ranges(old.len(), 1 << 16, |r| {
        let mut v = Vec::new();
        diff_words(old, new, r, |i| v.push(i as u64));
        v
    });
    let total: usize = parts.iter().map(|p| p.len()).sum();
    let mut out = Vec::with_capacity(total);
    for p in parts {
        out.extend(p);
    }
    out
}

/// Fused diff + gather: produces (sorted indices, new values) in one
/// pass over the buffers instead of a diff followed by a separate
/// gather. This is the publisher's per-step encode front half.
pub fn diff_gather_bf16(old: &[u16], new: &[u16]) -> (Vec<u64>, Vec<u16>) {
    assert_eq!(old.len(), new.len(), "checkpoint length mismatch");
    let parts = pool::par_ranges(old.len(), 1 << 16, |r| {
        let mut idx = Vec::new();
        let mut val = Vec::new();
        diff_words(old, new, r, |i| {
            idx.push(i as u64);
            val.push(new[i]);
        });
        (idx, val)
    });
    let total: usize = parts.iter().map(|(i, _)| i.len()).sum();
    let mut indices = Vec::with_capacity(total);
    let mut values = Vec::with_capacity(total);
    for (i, v) in parts {
        indices.extend(i);
        values.extend(v);
    }
    (indices, values)
}

/// Serial fused diff + gather over `r`, emitting **absolute** sorted
/// indices. This is the per-shard encode front half of the sharded
/// fan-out: each shard already runs on its own pool worker
/// ([`crate::pulse::sync::ShardedEncoder`]), so the scan inside a shard
/// stays serial instead of nesting a second thread fan-out.
pub fn diff_gather_bf16_range(
    old: &[u16],
    new: &[u16],
    r: std::ops::Range<usize>,
) -> (Vec<u64>, Vec<u16>) {
    assert_eq!(old.len(), new.len(), "checkpoint length mismatch");
    assert!(r.end <= new.len(), "diff range out of bounds");
    let mut idx = Vec::new();
    let mut val = Vec::new();
    diff_words(old, new, r, |i| {
        idx.push(i as u64);
        val.push(new[i]);
    });
    (idx, val)
}

/// Number of positions whose bit patterns differ (word-skipping, no
/// index materialization) — the counting core of the sparsity meter.
pub fn count_diff_bf16(old: &[u16], new: &[u16]) -> usize {
    assert_eq!(old.len(), new.len(), "checkpoint length mismatch");
    pool::par_ranges(old.len(), 1 << 16, |r| {
        let mut c = 0usize;
        diff_words(old, new, r, |_| c += 1);
        c
    })
    .into_iter()
    .sum()
}

/// Per-block changed-position counts: block `b` covers elements
/// `b*block_elems .. (b+1)*block_elems` (last block may be short).
/// One word-skipping parallel pass — the profile the load-balanced
/// shard split ([`hashtree::balanced_shard_ranges`]) partitions so
/// every shard carries ≈ nnz/S of the update stream.
pub fn count_diff_bf16_blocks(old: &[u16], new: &[u16], block_elems: usize) -> Vec<usize> {
    assert_eq!(old.len(), new.len(), "checkpoint length mismatch");
    let be = block_elems.max(1);
    let n_blocks = old.len().div_ceil(be);
    let parts = pool::par_ranges(n_blocks, 8, |r| {
        r.map(|b| {
            let lo = b * be;
            let hi = (lo + be).min(old.len());
            let mut c = 0usize;
            diff_words(old, new, lo..hi, |_| c += 1);
            c
        })
        .collect::<Vec<usize>>()
    });
    let mut out = Vec::with_capacity(n_blocks);
    for p in parts {
        out.extend(p);
    }
    out
}

/// Gather `values[i] = new[idx]` for a sorted index list.
pub fn gather_u16(new: &[u16], indices: &[u64]) -> Vec<u16> {
    indices.iter().map(|&i| new[i as usize]).collect()
}

pub fn gather_f32(new: &[f32], indices: &[u64]) -> Vec<f32> {
    indices.iter().map(|&i| new[i as usize]).collect()
}

/// Apply a patch: `weights[idx] = value` (Alg. 4 — a direct memory
/// overwrite, no floating-point arithmetic).
pub fn apply_u16(weights: &mut [u16], indices: &[u64], values: &[u16]) {
    assert_eq!(indices.len(), values.len());
    for (&i, &v) in indices.iter().zip(values) {
        weights[i as usize] = v;
    }
}

pub fn apply_f32(weights: &mut [f32], indices: &[u64], values: &[f32]) {
    assert_eq!(indices.len(), values.len());
    for (&i, &v) in indices.iter().zip(values) {
        weights[i as usize] = v;
    }
}

/// Sparsity of a patch: fraction of parameters *unchanged*.
pub fn sparsity(nnz: usize, total: usize) -> f64 {
    if total == 0 {
        1.0
    } else {
        1.0 - nnz as f64 / total as f64
    }
}

/// Index-stream encodings (paper Tables 10/11). `CooDownscaled` is the
/// production default (`delta_coo_downscaled`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PatchFormat {
    /// 2-D COO, absolute u32 rows/cols (Table 10 baseline "Raw COO").
    CooRaw,
    /// 2-D COO, sorted + delta-encoded rows/cols at u32 (Table 10 row 3,
    /// Table 11 "delta_coo_int32").
    CooDelta,
    /// 2-D COO, delta + narrowest width (u8 rows / u16 cols typically) —
    /// the paper's default pipeline (Table 10 row 4).
    CooDownscaled,
    /// 1-D flat absolute u32 indices.
    FlatAbs,
    /// 1-D flat delta u32 indices (Table 11 "delta_flat_int32").
    FlatDelta,
    /// 1-D flat delta-varint indices — the PULSELoCo wire stream (§F.3).
    FlatVarint,
}

impl PatchFormat {
    pub const ALL: [PatchFormat; 6] = [
        PatchFormat::CooRaw,
        PatchFormat::CooDelta,
        PatchFormat::CooDownscaled,
        PatchFormat::FlatAbs,
        PatchFormat::FlatDelta,
        PatchFormat::FlatVarint,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            PatchFormat::CooRaw => "coo_raw",
            PatchFormat::CooDelta => "delta_coo_int32",
            PatchFormat::CooDownscaled => "delta_coo_downscaled",
            PatchFormat::FlatAbs => "flat_int32",
            PatchFormat::FlatDelta => "delta_flat_int32",
            PatchFormat::FlatVarint => "delta_flat_varint",
        }
    }

    pub fn tag(&self) -> u8 {
        match self {
            PatchFormat::CooRaw => 0,
            PatchFormat::CooDelta => 1,
            PatchFormat::CooDownscaled => 2,
            PatchFormat::FlatAbs => 3,
            PatchFormat::FlatDelta => 4,
            PatchFormat::FlatVarint => 5,
        }
    }

    pub fn from_tag(t: u8) -> anyhow::Result<PatchFormat> {
        PatchFormat::ALL
            .iter()
            .copied()
            .find(|f| f.tag() == t)
            .ok_or_else(|| anyhow::anyhow!("bad patch format tag {}", t))
    }

    /// Encode an index stream (no values) for this format.
    pub fn encode_indices(&self, indices: &[u64], layout: &[TensorShape]) -> Vec<u8> {
        match self {
            PatchFormat::CooRaw => coo::encode(indices, layout, false, false),
            PatchFormat::CooDelta => coo::encode(indices, layout, true, false),
            PatchFormat::CooDownscaled => coo::encode(indices, layout, true, true),
            PatchFormat::FlatAbs => flat::encode(indices, false),
            PatchFormat::FlatDelta => flat::encode(indices, true),
            PatchFormat::FlatVarint => crate::codec::varint::encode_sorted_indices(indices),
        }
    }

    /// Decode an index stream.
    pub fn decode_indices(
        &self,
        buf: &[u8],
        pos: &mut usize,
        layout: &[TensorShape],
    ) -> anyhow::Result<Vec<u64>> {
        match self {
            PatchFormat::CooRaw => coo::decode(buf, pos, layout, false, false),
            PatchFormat::CooDelta => coo::decode(buf, pos, layout, true, false),
            PatchFormat::CooDownscaled => coo::decode(buf, pos, layout, true, true),
            PatchFormat::FlatAbs => flat::decode(buf, pos, false),
            PatchFormat::FlatDelta => flat::decode(buf, pos, true),
            PatchFormat::FlatVarint => crate::codec::varint::decode_sorted_indices(buf, pos),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn diff_finds_exact_positions() {
        let old = vec![1u16, 2, 3, 4, 5, 6];
        let mut new = old.clone();
        new[1] = 9;
        new[4] = 0;
        assert_eq!(diff_bf16(&old, &new), vec![1, 4]);
        assert_eq!(diff_bf16(&old, &old), Vec::<u64>::new());
    }

    #[test]
    fn diff_parallel_matches_serial_large() {
        let mut rng = crate::util::rng::Rng::new(51);
        let n = 300_000;
        let old: Vec<u16> = (0..n).map(|_| rng.next_u32() as u16).collect();
        let mut new = old.clone();
        let mut expect = Vec::new();
        for _ in 0..5000 {
            let i = rng.below(n as u64) as usize;
            if new[i] == old[i] {
                new[i] ^= 1;
            }
        }
        for i in 0..n {
            if old[i] != new[i] {
                expect.push(i as u64);
            }
        }
        assert_eq!(diff_bf16(&old, &new), expect);
    }

    #[test]
    fn word_diff_matches_scalar_reference() {
        // the word-skipping scan must agree with a plain element loop on
        // every length (word remainders) and change density
        crate::util::prop::check("word diff == scalar diff", 60, |g| {
            let n = g.len();
            let old: Vec<u16> = (0..n).map(|_| g.rng.next_u32() as u16).collect();
            let mut new = old.clone();
            for _ in 0..g.rng.below(n as u64 + 1) {
                let i = g.rng.below(n.max(1) as u64) as usize;
                if n > 0 {
                    new[i] = g.rng.next_u32() as u16;
                }
            }
            let expect: Vec<u64> = (0..n).filter(|&i| old[i] != new[i]).map(|i| i as u64).collect();
            assert_eq!(diff_bf16(&old, &new), expect);
            let (idx, vals) = diff_gather_bf16(&old, &new);
            assert_eq!(idx, expect);
            assert_eq!(vals, gather_u16(&new, &expect));
            assert_eq!(count_diff_bf16(&old, &new), expect.len());
        });
    }

    #[test]
    fn diff_gather_dense_change() {
        // every position changed: the word fast path must still emit all
        let old = vec![0u16; 37];
        let new = vec![1u16; 37];
        let (idx, vals) = diff_gather_bf16(&old, &new);
        assert_eq!(idx, (0..37).collect::<Vec<u64>>());
        assert_eq!(vals, vec![1u16; 37]);
        assert_eq!(count_diff_bf16(&old, &new), 37);
    }

    #[test]
    fn block_counts_sum_to_total_diff() {
        crate::util::prop::check("block counts partition the diff", 40, |g| {
            let n = g.len();
            let be = 1 + g.rng.below(n as u64 / 2 + 4) as usize;
            let old: Vec<u16> = (0..n).map(|_| g.rng.next_u32() as u16).collect();
            let mut new = old.clone();
            for _ in 0..g.rng.below(n as u64 + 1) {
                if n > 0 {
                    let i = g.rng.below(n as u64) as usize;
                    new[i] = g.rng.next_u32() as u16;
                }
            }
            let counts = count_diff_bf16_blocks(&old, &new, be);
            assert_eq!(counts.len(), n.div_ceil(be));
            assert_eq!(counts.iter().sum::<usize>(), count_diff_bf16(&old, &new));
            for (b, &c) in counts.iter().enumerate() {
                let lo = b * be;
                let hi = (lo + be).min(n);
                let expect = (lo..hi).filter(|&i| old[i] != new[i]).count();
                assert_eq!(c, expect, "block {}", b);
            }
        });
    }

    #[test]
    fn range_diff_composes_to_full_diff() {
        crate::util::prop::check("range diffs concat == full diff", 40, |g| {
            let n = g.len();
            let old: Vec<u16> = (0..n).map(|_| g.rng.next_u32() as u16).collect();
            let mut new = old.clone();
            for _ in 0..g.rng.below(n as u64 + 1) {
                if n > 0 {
                    let i = g.rng.below(n as u64) as usize;
                    new[i] = g.rng.next_u32() as u16;
                }
            }
            let cut1 = g.rng.below(n as u64 + 1) as usize;
            let cut2 = cut1 + g.rng.below((n - cut1) as u64 + 1) as usize;
            let mut idx = Vec::new();
            let mut vals = Vec::new();
            for r in [0..cut1, cut1..cut2, cut2..n] {
                let (i, v) = diff_gather_bf16_range(&old, &new, r);
                idx.extend(i);
                vals.extend(v);
            }
            let (full_idx, full_vals) = diff_gather_bf16(&old, &new);
            assert_eq!(idx, full_idx);
            assert_eq!(vals, full_vals);
        });
    }

    #[test]
    fn apply_inverts_diff() {
        crate::util::prop::check("patch apply reconstructs", 40, |g| {
            let n = g.len().max(1);
            let old: Vec<u16> = (0..n).map(|_| g.rng.next_u32() as u16).collect();
            let mut new = old.clone();
            for _ in 0..g.rng.below(n as u64 + 1) {
                let i = g.rng.below(n as u64) as usize;
                new[i] = g.rng.next_u32() as u16;
            }
            let idx = diff_bf16(&old, &new);
            let vals = gather_u16(&new, &idx);
            let mut rec = old.clone();
            apply_u16(&mut rec, &idx, &vals);
            assert_eq!(rec, new);
        });
    }

    #[test]
    fn all_formats_roundtrip_indices() {
        crate::util::prop::check("index formats roundtrip", 40, |g| {
            let cols = 1 + g.rng.below(2000) as usize;
            let rows = 1 + g.rng.below(200) as usize;
            let n = rows * cols;
            let layout = synthetic_layout(n, cols);
            let count = g.len();
            let idx = g.sorted_indices(n, count);
            for fmt in PatchFormat::ALL {
                let buf = fmt.encode_indices(&idx, &layout);
                let mut pos = 0;
                let back = fmt.decode_indices(&buf, &mut pos, &layout).unwrap();
                assert_eq!(back, idx, "format {}", fmt.name());
                assert_eq!(pos, buf.len(), "format {}", fmt.name());
            }
        });
    }

    #[test]
    fn downscaled_coo_smaller_than_raw() {
        // clustered indices → delta+downscale should win clearly (§H.4.1)
        let mut rng = crate::util::rng::Rng::new(61);
        let cols = 1024usize;
        let rows = 1000usize;
        let layout = synthetic_layout(rows * cols, cols);
        let mut idx: Vec<u64> = Vec::new();
        let mut cur = 0u64;
        while (cur as usize) < rows * cols && idx.len() < 20_000 {
            cur += 1 + rng.below(40);
            if (cur as usize) < rows * cols {
                idx.push(cur);
            }
        }
        let raw = PatchFormat::CooRaw.encode_indices(&idx, &layout).len();
        let down = PatchFormat::CooDownscaled.encode_indices(&idx, &layout).len();
        assert!(down * 2 < raw, "raw={} down={}", raw, down);
    }

    #[test]
    fn multi_tensor_layout_roundtrip() {
        let layout = vec![
            TensorShape { name: "a".into(), offset: 0, rows: 10, cols: 7 },
            TensorShape { name: "b".into(), offset: 70, rows: 1, cols: 33 },
            TensorShape { name: "c".into(), offset: 103, rows: 5, cols: 300 },
        ];
        let n = 103 + 1500;
        let mut rng = crate::util::rng::Rng::new(71);
        let mut idx: Vec<u64> = (0..200).map(|_| rng.below(n as u64)).collect();
        idx.sort_unstable();
        idx.dedup();
        for fmt in [PatchFormat::CooRaw, PatchFormat::CooDelta, PatchFormat::CooDownscaled] {
            let buf = fmt.encode_indices(&idx, &layout);
            let mut pos = 0;
            let back = fmt.decode_indices(&buf, &mut pos, &layout).unwrap();
            assert_eq!(back, idx, "format {}", fmt.name());
        }
    }
}
